"""The benchmark-regression gate (benchmarks/compare.py) — parsing and
pass/fail decisions.  Pure stdlib on both sides, so this runs in the
minimal CI image and in the no-hypothesis matrix leg."""
import json
import os
import sys

import pytest

sys.path.append(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.compare import (compare, load_merged, main,  # noqa: E402
                                parse_derived)


def _record(rows):
    return {"timestamp": 0.0, "errors": {},
            "sections": {"bfs": [{"name": n, "us_per_call": 1.0,
                                  "derived": d} for n, d in rows.items()]}}


def _write(tmp_path, name, rows):
    path = str(tmp_path / name)
    with open(path, "w") as f:
        json.dump(_record(rows), f)
    return path


class TestParseDerived:
    def test_counters_and_throughput(self):
        thr, cnt = parse_derived(
            "936 level states/s sorts/expansion=1.00 bytes/level=1.75e+05 "
            "speedup_vs_unfused=1.86x")
        assert thr == 936.0
        assert cnt == {"sorts/expansion": 1.0, "bytes/level": 1.75e5}

    def test_plain_states_per_s_and_ratio_skip(self):
        thr, cnt = parse_derived(
            "39.3 states/s lexsorts/level=0 bytes/level=64 "
            "speedup_vs_fused=0.90x")
        assert thr == 39.3
        assert cnt == {"lexsorts/level": 0.0, "bytes/level": 64.0}

    def test_no_throughput(self):
        thr, cnt = parse_derived("passes/level=1.17")
        assert thr is None and cnt == {"passes/level": 1.17}


class TestGate:
    BASE = {
        "bfs_a": "1000 level states/s sorts/expansion=1.00 bytes/level=100",
        "bfs_b": "500 states/s lexsorts/level=1 scatters/level=1",
        "bfs_c": "200 states/s",
    }

    def test_identical_passes(self, tmp_path):
        b = _write(tmp_path, "base.json", self.BASE)
        f = _write(tmp_path, "fresh.json", self.BASE)
        assert compare(f, b, 0.25, 0.02) == 0

    def test_uniform_slowdown_passes(self, tmp_path):
        # a 3x slower CI runner shifts every row: median-normalized, clean
        slow = {
            "bfs_a": "333 level states/s sorts/expansion=1.00 bytes/level=100",
            "bfs_b": "167 states/s lexsorts/level=1 scatters/level=1",
            "bfs_c": "67 states/s",
        }
        b = _write(tmp_path, "base.json", self.BASE)
        f = _write(tmp_path, "fresh.json", slow)
        assert compare(f, b, 0.25, 0.02) == 0

    def test_single_row_regression_fails(self, tmp_path):
        # one engine regressing 2x while the others hold trips the gate
        # even though the machine is otherwise identical
        bad = dict(self.BASE)
        bad["bfs_a"] = ("400 level states/s sorts/expansion=1.00 "
                        "bytes/level=100")
        b = _write(tmp_path, "base.json", self.BASE)
        f = _write(tmp_path, "fresh.json", bad)
        assert compare(f, b, 0.25, 0.02) == 1

    def test_counter_increase_fails(self, tmp_path):
        # the budgets are exact: one extra lexsort per level is red
        bad = dict(self.BASE)
        bad["bfs_b"] = "500 states/s lexsorts/level=2 scatters/level=1"
        b = _write(tmp_path, "base.json", self.BASE)
        f = _write(tmp_path, "fresh.json", bad)
        assert compare(f, b, 0.25, 0.02) == 1

    def test_byte_counter_increase_fails(self, tmp_path):
        bad = dict(self.BASE)
        bad["bfs_a"] = ("1000 level states/s sorts/expansion=1.00 "
                        "bytes/level=150")
        b = _write(tmp_path, "base.json", self.BASE)
        f = _write(tmp_path, "fresh.json", bad)
        assert compare(f, b, 0.25, 0.02) == 1

    def test_counter_decrease_passes(self, tmp_path):
        good = dict(self.BASE)
        good["bfs_a"] = ("1000 level states/s sorts/expansion=1.00 "
                         "bytes/level=50")
        b = _write(tmp_path, "base.json", self.BASE)
        f = _write(tmp_path, "fresh.json", good)
        assert compare(f, b, 0.25, 0.02) == 0

    def test_missing_row_fails_new_row_passes(self, tmp_path):
        fewer = {k: v for k, v in self.BASE.items() if k != "bfs_c"}
        b = _write(tmp_path, "base.json", self.BASE)
        f = _write(tmp_path, "fresh.json", fewer)
        assert compare(f, b, 0.25, 0.02) == 1
        more = dict(self.BASE)
        more["bfs_new"] = "123 states/s bytes/level=1"
        f2 = _write(tmp_path, "fresh2.json", more)
        assert compare(f2, b, 0.25, 0.02) == 0

    def test_majority_speedup_spares_untouched_rows(self, tmp_path):
        # a PR that makes most rows faster must not flag the rows it never
        # touched: their raw ratio is ~1.0, which vouches for them even
        # though they fall below the (now faster) median
        faster = {
            "bfs_a": "3000 level states/s sorts/expansion=1.00 bytes/level=100",
            "bfs_b": "1500 states/s lexsorts/level=1 scatters/level=1",
            "bfs_c": "200 states/s",               # untouched
        }
        b = _write(tmp_path, "base.json", self.BASE)
        f = _write(tmp_path, "fresh.json", faster)
        assert compare(f, b, 0.25, 0.02) == 0

    FAMILIES = {
        "bfs_x_tierD_fused": "1000 level states/s",
        "bfs_y_tierD_implicit": "4000 level states/s",
        "bfs_z_tierD_unfused": "500 level states/s",
        "bfs_x_tierJ_fused": "50 states/s",
        "bfs_y_tierJ_implicit": "40 states/s",
        "bfs_z_tierJ_unfused": "45 states/s",
    }

    def test_family_wide_drift_passes(self, tmp_path):
        # a jax release slowing every compile-bound tierJ row 2x while the
        # I/O-bound tierD rows hold: each family normalizes against its
        # own median, so nothing is flagged
        drift = dict(self.FAMILIES)
        drift["bfs_x_tierJ_fused"] = "25 states/s"
        drift["bfs_y_tierJ_implicit"] = "20 states/s"
        drift["bfs_z_tierJ_unfused"] = "22.5 states/s"
        b = _write(tmp_path, "base.json", self.FAMILIES)
        f = _write(tmp_path, "fresh.json", drift)
        assert compare(f, b, 0.25, 0.02) == 0

    def test_single_row_regression_within_family_fails(self, tmp_path):
        bad = dict(self.FAMILIES)
        bad["bfs_y_tierD_implicit"] = "1500 level states/s"   # 2.7x slower
        b = _write(tmp_path, "base.json", self.FAMILIES)
        f = _write(tmp_path, "fresh.json", bad)
        assert compare(f, b, 0.25, 0.02) == 1

    def test_best_of_merge_rescues_one_noisy_run(self, tmp_path):
        # one fresh run caught a transient slow window on one row; the
        # second run's clean sample wins the merge and the gate stays green
        noisy = dict(self.BASE)
        noisy["bfs_a"] = ("300 level states/s sorts/expansion=1.00 "
                         "bytes/level=100")
        b = _write(tmp_path, "base.json", self.BASE)
        f1 = _write(tmp_path, "fresh1.json", noisy)
        f2 = _write(tmp_path, "fresh2.json", self.BASE)
        assert compare(f1, b, 0.25, 0.02) == 1          # alone: red
        assert compare([f1, f2], b, 0.25, 0.02) == 0    # merged: green
        merged = load_merged([f1, f2])
        assert merged["bfs_a"] == self.BASE["bfs_a"]

    def test_merge_cannot_mask_counter_increase(self, tmp_path):
        # a faster sample with a WORSE counter must still fail the gate:
        # counters are deterministic, so both fresh runs carry the increase
        worse = dict(self.BASE)
        worse["bfs_b"] = "990 states/s lexsorts/level=2 scatters/level=1"
        worse2 = dict(self.BASE)
        worse2["bfs_b"] = "980 states/s lexsorts/level=2 scatters/level=1"
        b = _write(tmp_path, "base.json", self.BASE)
        f1 = _write(tmp_path, "fresh1.json", worse)
        f2 = _write(tmp_path, "fresh2.json", worse2)
        assert compare([f1, f2], b, 0.25, 0.02) == 1

    def test_counter_increase_in_losing_sample_still_fails(self, tmp_path):
        # budgets are checked in EVERY record: even when the sample carrying
        # the increase loses the throughput merge, the gate goes red
        worse_but_slower = dict(self.BASE)
        worse_but_slower["bfs_b"] = ("400 states/s lexsorts/level=2 "
                                     "scatters/level=1")
        b = _write(tmp_path, "base.json", self.BASE)
        f1 = _write(tmp_path, "fresh1.json", worse_but_slower)
        f2 = _write(tmp_path, "fresh2.json", self.BASE)   # clean, wins merge
        assert compare([f1, f2], b, 0.25, 0.02) == 1

    def test_empty_baseline_is_schema_error(self, tmp_path):
        b = _write(tmp_path, "base.json", {})
        f = _write(tmp_path, "fresh.json", self.BASE)
        assert compare(f, b, 0.25, 0.02) == 2

    def test_update_baseline_path(self, tmp_path):
        b = _write(tmp_path, "base.json", {"bfs_a": "1 states/s"})
        f = _write(tmp_path, "fresh.json", self.BASE)
        assert main([f, b, "--update-baseline"]) == 0
        # the installed baseline is the section-scoped merged form and
        # round-trips through the gate cleanly
        assert compare(f, b, 0.25, 0.02) == 0
        with open(b) as fh:
            installed = json.load(fh)
        assert set(installed["sections"]) == {"bfs"}
        assert installed["errors"] == {}
        # refuses to install an empty baseline
        empty = _write(tmp_path, "empty.json", {})
        assert main([empty, b, "--update-baseline"]) == 2

    def test_update_baseline_scopes_to_section(self, tmp_path):
        # a full run.py sweep carries other sections; installing it as the
        # baseline must keep only the gated section, or CI's --only bfs
        # runs would be permanently red with "rows missing"
        full = _record(self.BASE)
        full["sections"]["moe"] = [{"name": "moe_dispatch",
                                    "us_per_call": 1.0,
                                    "derived": "9 states/s"}]
        path = str(tmp_path / "full.json")
        with open(path, "w") as f:
            json.dump(full, f)
        b = str(tmp_path / "base.json")
        assert main([path, b, "--update-baseline"]) == 0
        fresh_bfs_only = _write(tmp_path, "fresh.json", self.BASE)
        assert compare(fresh_bfs_only, b, 0.25, 0.02) == 0

    def test_cli_exit_codes(self, tmp_path):
        b = _write(tmp_path, "base.json", self.BASE)
        f = _write(tmp_path, "fresh.json", self.BASE)
        assert main([f, b]) == 0
        with pytest.raises(SystemExit):
            main(["--nonsense"])
