"""Unit tests for the dry-run HLO collective parser and roofline math
(host-side logic only — no devices, no XLA flag)."""
import numpy as np
import pytest

from repro.launch.dryrun import collective_stats, _shape_bytes
from repro.launch.roofline import PEAK_FLOPS, HBM_BW, LINK_BW, analyze

HLO = """
ENTRY %main {
  %ar = f32[1024,256]{1,0} all-reduce(%x), replica_groups=[32,16]<=[512], to_apply=%add
  %ag.1 = bf16[4096]{0} all-gather(%y), replica_groups=[2,256]<=[512]T(1,0), dimensions={0}
  %rs = f32[128]{0} reduce-scatter(%z), replica_groups={{0,1,2,3}}, dimensions={0}
  %a2a = (bf16[64,32]{1,0}, bf16[64,32]{1,0}) all-to-all(%p, %q), replica_groups=[32,16]<=[512]
  %cp = u32[16]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %ard = f32[8] all-reduce-done(%h)
}
"""


class TestShapeBytes:
    def test_simple(self):
        assert _shape_bytes("f32[1024,256]{1,0}") == 1024 * 256 * 4
        assert _shape_bytes("bf16[4096]{0}") == 4096 * 2
        assert _shape_bytes("(bf16[64,32]{1,0}, bf16[64,32]{1,0})") \
            == 2 * 64 * 32 * 2
        assert _shape_bytes("pred[7]") == 7


class TestCollectiveStats:
    def test_parse_and_algebra(self):
        s = collective_stats(HLO, n_devices=512)
        ar = s["all-reduce"]
        assert ar["count"] == 1                      # -done line skipped? no:
        # all-reduce-done matches the base regex? the (-start)? group only
        # covers -start; '-done(' does not match 'all-reduce(' → excluded.
        b = 1024 * 256 * 4
        assert ar["operand_bytes"] == b
        np.testing.assert_allclose(ar["wire_bytes"], 2 * b * 15 / 16)

        ag = s["all-gather"]
        assert ag["count"] == 1
        assert ag["operand_bytes"] == 4096 * 2 // 256
        np.testing.assert_allclose(ag["wire_bytes"],
                                   (4096 * 2 // 256) * 255)

        rs = s["reduce-scatter"]
        assert rs["operand_bytes"] == 128 * 4 * 4    # explicit group of 4

        a2a = s["all-to-all"]
        assert a2a["operand_bytes"] == 2 * 64 * 32 * 2

        cp = s["collective-permute"]
        assert cp["operand_bytes"] == 16 * 4
        assert s["total_operand_bytes"] > 0


class TestRooflineMath:
    def _cell(self, **kw):
        base = {
            "arch": "x", "shape": "train_4k", "mesh": "16x16",
            "kind": "train", "seq_len": 4096, "global_batch": 256,
            "devices": 256, "active_params": 1_000_000_000,
            "flops_per_device_counted": 1e14,
            "bytes_per_device": 1e11,
            "collectives": {"total_wire_bytes": 1e10},
        }
        base.update(kw)
        return base

    def test_terms_and_dominance(self):
        r = analyze(self._cell())
        np.testing.assert_allclose(r["t_compute_s"], 1e14 / PEAK_FLOPS)
        np.testing.assert_allclose(r["t_memory_s"], 1e11 / HBM_BW)
        np.testing.assert_allclose(r["t_collective_s"], 1e10 / LINK_BW)
        assert r["dominant"] == "compute"
        model = 6.0 * 1e9 * 256 * 4096 / 256
        np.testing.assert_allclose(r["model_flops_per_device"], model)
        np.testing.assert_allclose(r["model_over_hlo"], model / 1e14)
        np.testing.assert_allclose(
            r["roofline_fraction"],
            (model / PEAK_FLOPS) / r["t_compute_s"])

    def test_decode_uses_2nd_and_one_token(self):
        r = analyze(self._cell(kind="decode",
                               flops_per_device_counted=1e9,
                               bytes_per_device=1e12))
        model = 2.0 * 1e9 * 256 / 256
        np.testing.assert_allclose(r["model_flops_per_device"], model)
        assert r["dominant"] == "memory"

    def test_skip_passthrough(self):
        r = analyze({"arch": "x", "shape": "long_500k", "mesh": "16x16",
                     "skipped": "full attention"})
        assert r["dominant"] == "skipped"
