"""Checkpoint/restart of mid-search BFS runs (disk/checkpoint.py).

Pins the two contracts of docs/checkpointing.md:

  * Resume equivalence — a search killed after ANY level and resumed
    produces level counts identical to an uninterrupted run, on both Tier
    D engines, single-process and sharded (inline workers, nshards=2).
  * Budget separation — kill + resume together pay exactly the
    uninterrupted run's sort/merge/array-pass budgets; checkpoint I/O is
    booked ONLY under the ``ckpt_*`` counters.

And the corruption paths: truncated manifest, stray ``.tmp`` snapshot
from a killed writer, version rollback, shard-count mismatch, and
owner-golden tampering all either adopt a previous checkpoint or fail
loudly (CheckpointError) — never silently corrupt.

Hypothesis-free (deterministic pancake inputs), like test_passes.py.
"""
import json
import math
import os
import shutil
import sys

import numpy as np
import pytest

from repro.core import ranking as R
from repro.core.disk import (CheckpointError, SearchCheckpoint,
                             breadth_first_search, implicit_bfs)
from repro.core.disk import bitarray as DBA
from repro.core.disk import extsort

sys.path.append(os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "examples"))
from pancake_bfs import GenNextNp, start_code          # noqa: E402
from pancake_bits import NeighborsNp                   # noqa: E402

N = 5
TOTAL = math.factorial(N)
START_ROWS = np.array([[start_code(N)]], np.uint32)
START_RANK = int(R.rank_np(np.arange(N)[None, :])[0])


def run_sorted(wd, nshards=1, **kw):
    sizes, handle = breadth_first_search(
        str(wd), START_ROWS, GenNextNp(N), width=1, chunk_rows=1 << 8,
        nshards=nshards, shard_mode="inline", **kw)
    handle.destroy()
    return sizes


def run_implicit(wd, nshards=1, **kw):
    sizes, bits = implicit_bfs(
        str(wd), TOTAL, [START_RANK], NeighborsNp(N), chunk_elems=1 << 6,
        nshards=nshards, shard_mode="inline", **kw)
    bits.destroy()
    return sizes


ENGINES = {"sorted": run_sorted, "implicit": run_implicit}


@pytest.fixture(scope="module")
def want():
    """Uninterrupted level counts (identical for both engines — pinned)."""
    import tempfile
    with tempfile.TemporaryDirectory() as wd:
        s = run_sorted(os.path.join(wd, "s"))
        i = run_implicit(os.path.join(wd, "i"))
    assert s == i and sum(s) == TOTAL
    return s


class TestResumeEquivalence:
    @pytest.mark.parametrize("engine", ["sorted", "implicit"])
    @pytest.mark.parametrize("nshards", [1, 2])
    @pytest.mark.parametrize("kill_after", [0, 2, 4])
    def test_kill_resume_equals_uninterrupted(self, tmp_path, want, engine,
                                              nshards, kill_after):
        run = ENGINES[engine]
        ckdir = str(tmp_path / "ck")
        partial = run(tmp_path / "w1", nshards=nshards, checkpoint_dir=ckdir,
                      checkpoint_every=1, max_levels=kill_after)
        assert partial == want[:kill_after + 1]
        got = run(tmp_path / "w2", nshards=nshards, checkpoint_dir=ckdir,
                  resume=True)
        assert got == want

    @pytest.mark.parametrize("engine", ["sorted", "implicit"])
    def test_checkpoint_every_coarser_than_kill(self, tmp_path, want, engine):
        """Kill between checkpoints: resume adopts the last published one
        and replays the gap — counts still identical."""
        run = ENGINES[engine]
        ckdir = str(tmp_path / "ck")
        run(tmp_path / "w1", checkpoint_dir=ckdir, checkpoint_every=2,
            max_levels=3)                  # checkpoints at levels 0 and 2
        got = run(tmp_path / "w2", checkpoint_dir=ckdir, resume=True)
        assert got == want

    @pytest.mark.parametrize("engine", ["sorted", "implicit"])
    def test_resume_without_checkpoint_starts_fresh(self, tmp_path, want,
                                                    engine):
        got = ENGINES[engine](tmp_path / "w", checkpoint_dir=str(
            tmp_path / "empty"), resume=True)
        assert got == want

    @pytest.mark.parametrize("engine", ["sorted", "implicit"])
    def test_resume_of_finished_search(self, tmp_path, want, engine):
        """Resuming a checkpoint of a COMPLETED search terminates with the
        full (unchanged) level counts."""
        run = ENGINES[engine]
        ckdir = str(tmp_path / "ck")
        assert run(tmp_path / "w1", checkpoint_dir=ckdir) == want
        assert run(tmp_path / "w2", checkpoint_dir=ckdir,
                   resume=True) == want

    def test_checkpoint_requires_fused(self, tmp_path):
        with pytest.raises(ValueError, match="fused"):
            run_sorted(tmp_path, checkpoint_dir=str(tmp_path / "ck"),
                       fused=False)
        with pytest.raises(ValueError, match="fused"):
            run_implicit(tmp_path, checkpoint_dir=str(tmp_path / "ck"),
                         fused=False)


class TestBudgetSeparation:
    """kill + resume == uninterrupted, counter for counter — checkpointing
    adds NO sort/merge/pass work, and books its I/O only under ckpt_*."""

    def _phases(self, run, tmp_path, kill_after):
        def measure(fn):
            extsort.reset_stats()
            DBA.reset_stats()
            fn()
            return dict(extsort.STATS), dict(DBA.STATS)

        full = measure(lambda: run(tmp_path / "full"))
        ckdir = str(tmp_path / "ck")
        kill = measure(lambda: run(tmp_path / "w1", checkpoint_dir=ckdir,
                                   checkpoint_every=1, max_levels=kill_after))
        res = measure(lambda: run(tmp_path / "w2", checkpoint_dir=ckdir,
                                  resume=True))
        return full, kill, res

    def test_sorted_pays_only_remaining_levels(self, tmp_path):
        full, kill, res = self._phases(run_sorted, tmp_path, kill_after=2)
        for key in ("sort_passes", "rows_sorted", "merge_passes"):
            assert kill[0][key] + res[0][key] == full[0][key], key
        # No checkpoint I/O leaks into a plain run; kill/resume book theirs
        # under the dedicated counters only.
        assert full[0]["ckpt_bytes_written"] == 0
        assert full[0]["ckpt_snapshots"] == 0
        assert kill[0]["ckpt_bytes_written"] > 0
        assert kill[0]["ckpt_snapshots"] == 3          # levels 0, 1, 2
        assert res[0]["ckpt_bytes_read"] > 0
        assert res[0]["ckpt_restores"] == 1

    def test_implicit_pays_only_remaining_passes(self, tmp_path):
        full, kill, res = self._phases(run_implicit, tmp_path, kill_after=2)
        for key in ("rw_passes", "read_passes", "piggybacked_stages"):
            assert kill[0][key] + res[0][key] == full[0][key], key
        # Array traversal bytes (total minus op-log bytes) — the implicit
        # engine's per-level budget unit — also sum exactly.
        for total_key, log_key in (("bytes_read", "log_bytes_read"),
                                   ("bytes_written", "log_bytes_written")):
            assert (kill[1][total_key] - kill[1][log_key]
                    + res[1][total_key] - res[1][log_key]
                    == full[1][total_key] - full[1][log_key]), total_key
        assert full[0]["ckpt_bytes_written"] == 0
        assert kill[0]["ckpt_bytes_written"] > 0
        assert res[0]["ckpt_restores"] == 1


class TestCorruptionPaths:
    """Never silently corrupt: adopt a previous checkpoint or fail loudly."""

    def _checkpointed(self, tmp_path, engine="implicit", max_levels=2):
        ckdir = str(tmp_path / "ck")
        ENGINES[engine](tmp_path / "w1", checkpoint_dir=ckdir,
                        checkpoint_every=1, max_levels=max_levels)
        return ckdir

    def test_truncated_manifest_adopts_sealed_snapshot(self, tmp_path, want):
        ckdir = self._checkpointed(tmp_path)
        with open(os.path.join(ckdir, "CHECKPOINT"), "w") as f:
            f.write('{"vers')                      # torn mid-write
        got = run_implicit(tmp_path / "w2", checkpoint_dir=ckdir,
                           resume=True)
        assert got == want

    def test_truncated_manifest_no_snapshot_fails_loudly(self, tmp_path):
        ckdir = self._checkpointed(tmp_path)
        with open(os.path.join(ckdir, "CHECKPOINT"), "w") as f:
            f.write("garbage")
        for fn in os.listdir(ckdir):               # remove all sealed dirs
            if fn != "CHECKPOINT":
                shutil.rmtree(os.path.join(ckdir, fn))
        with pytest.raises(CheckpointError, match="corrupt"):
            run_implicit(tmp_path / "w2", checkpoint_dir=ckdir, resume=True)

    def test_stray_tmp_snapshot_ignored(self, tmp_path, want):
        """A killed writer's half-staged v*.tmp is garbage: adoption uses
        the sealed previous version and the next publish sweeps the stray."""
        ckdir = self._checkpointed(tmp_path)
        ck = SearchCheckpoint(ckdir)
        sealed = ck.latest()["version"]
        stray = os.path.join(ckdir, f"v{sealed + 1:06d}.tmp")
        os.makedirs(stray)
        with open(os.path.join(stray, "halfwritten.bin"), "wb") as f:
            f.write(b"\x00" * 17)
        got = run_implicit(tmp_path / "w2", checkpoint_dir=ckdir,
                           resume=True)
        assert got == want
        assert not any(fn.endswith(".tmp") for fn in os.listdir(ckdir))

    def test_sealed_but_unpublished_version_ignored(self, tmp_path):
        """Crash between the snapshot seal and the manifest publish: the
        manifest's (older) version stays authoritative."""
        ckdir = self._checkpointed(tmp_path)
        ck = SearchCheckpoint(ckdir)
        meta = ck.latest()
        v = meta["version"]
        orphan = os.path.join(ckdir, f"v{v + 1:06d}")
        shutil.copytree(os.path.join(ckdir, f"v{v:06d}"), orphan)
        payload = json.load(open(os.path.join(orphan, "META.json")))
        payload["version"] = v + 1
        payload["level_sizes"] = [999]             # would corrupt if adopted
        json.dump(payload, open(os.path.join(orphan, "META.json"), "w"))
        assert SearchCheckpoint(ckdir).latest()["version"] == v
        assert SearchCheckpoint(ckdir).latest()["level_sizes"] != [999]

    def test_missing_manifest_adopts_highest_sealed(self, tmp_path, want):
        ckdir = self._checkpointed(tmp_path)
        os.remove(os.path.join(ckdir, "CHECKPOINT"))
        got = run_implicit(tmp_path / "w2", checkpoint_dir=ckdir,
                           resume=True)
        assert got == want

    def test_version_rollback_fails_loudly(self, tmp_path):
        """Manifest names a version whose snapshot is gone — refusing to
        guess beats resuming from the wrong state."""
        ckdir = self._checkpointed(tmp_path)
        with open(os.path.join(ckdir, "CHECKPOINT"), "w") as f:
            json.dump({"version": 1}, f)           # v1 was GC'd long ago
        with pytest.raises(CheckpointError, match="rollback"):
            run_implicit(tmp_path / "w2", checkpoint_dir=ckdir, resume=True)

    @pytest.mark.parametrize("engine", ["sorted", "implicit"])
    def test_shard_count_mismatch_fails_loudly(self, tmp_path, engine):
        ckdir = str(tmp_path / "ck")
        ENGINES[engine](tmp_path / "w1", nshards=2, checkpoint_dir=ckdir,
                        checkpoint_every=1, max_levels=2)
        with pytest.raises(CheckpointError, match="nshards"):
            ENGINES[engine](tmp_path / "w2", nshards=1, checkpoint_dir=ckdir,
                            resume=True)

    def test_golden_owner_tamper_fails_loudly(self, tmp_path):
        ckdir = self._checkpointed(tmp_path, engine="sorted")
        ck = SearchCheckpoint(ckdir)
        v = ck.latest()["version"]
        mpath = os.path.join(ckdir, f"v{v:06d}", "META.json")
        payload = json.load(open(mpath))
        payload["golden"]["hash"] = [7] * len(payload["golden"]["hash"])
        json.dump(payload, open(mpath, "w"))
        with pytest.raises(CheckpointError, match="golden"):
            run_sorted(tmp_path / "w2", checkpoint_dir=ckdir, resume=True)

    def test_engine_mismatch_fails_loudly(self, tmp_path):
        ckdir = self._checkpointed(tmp_path, engine="sorted")
        with pytest.raises(CheckpointError, match="engine"):
            run_implicit(tmp_path / "w2", checkpoint_dir=ckdir, resume=True)

    @pytest.mark.parametrize("engine", ["sorted", "implicit"])
    def test_single_process_checkpoint_vs_sharded_resume(self, tmp_path,
                                                         engine):
        """Single-process and sharded snapshots have different payload
        layouts — resuming one with the other (even at nshards=1, via an
        explicit runtime=) must raise, not KeyError its way into the
        payload."""
        from repro.core.disk import ShardRuntime
        ckdir = self._checkpointed(tmp_path, engine=engine)   # nshards=1
        rt = ShardRuntime(str(tmp_path / "rt"), 1, mode="inline")
        with pytest.raises(CheckpointError, match="single-process"):
            if engine == "sorted":
                breadth_first_search(
                    str(tmp_path / "w2"), START_ROWS, GenNextNp(N), width=1,
                    chunk_rows=1 << 8, runtime=rt,
                    checkpoint_dir=ckdir, resume=True)
            else:
                implicit_bfs(
                    str(tmp_path / "w2"), TOTAL, [START_RANK],
                    NeighborsNp(N), chunk_elems=1 << 6, runtime=rt,
                    checkpoint_dir=ckdir, resume=True)

    @pytest.mark.parametrize("key", ["nshards", "n_states", "golden"])
    def test_missing_structural_key_fails_loudly(self, tmp_path, key):
        """Deleting a structural key must not vacuously pass validation
        (a .get(key, caller_value) default would)."""
        ckdir = self._checkpointed(tmp_path)
        ck = SearchCheckpoint(ckdir)
        v = ck.latest()["version"]
        mpath = os.path.join(ckdir, f"v{v:06d}", "META.json")
        payload = json.load(open(mpath))
        del payload[key]
        json.dump(payload, open(mpath, "w"))
        with pytest.raises(CheckpointError, match="missing"):
            run_implicit(tmp_path / "w2", checkpoint_dir=ckdir, resume=True)


class TestIncrementalSnapshots:
    """Visited runs are immutable between compactions, so checkpoint L+1
    hard-links the runs checkpoint L already holds instead of re-copying:
    total checkpoint I/O stays O(|visited| + compaction), not
    O(levels x |visited|)."""

    def _run(self, wd, name, rows):
        from repro.core.disk import ChunkStore
        from repro.core.disk.extsort import sort_rows
        st = ChunkStore(os.path.join(str(wd), name), 1, chunk_rows=1 << 8,
                        fresh=True)
        st.append(sort_rows(np.asarray(rows, np.uint32).reshape(-1, 1)))
        st.flush(mark_sorted=True)
        return st

    def test_second_snapshot_links_previous_runs(self, tmp_path):
        from repro.core.disk import SortedRunSet
        from repro.core.disk import checkpoint as CK
        rs = SortedRunSet(str(tmp_path), 1, name="rs")
        rs.add_run(self._run(tmp_path, "lev0", [1, 2, 3]))
        rs.add_run(self._run(tmp_path, "lev1", [4, 5]))
        ck = SearchCheckpoint(str(tmp_path / "ck"))
        extsort.reset_stats()
        v = ck.next_version()
        s1 = CK.snapshot_sorted_state(ck.begin(v), rs, rs.runs[-1])
        sealed = ck.publish(v, {"state": s1})
        first_bytes = extsort.STATS["ckpt_bytes_written"]
        assert first_bytes > 0

        rs.add_run(self._run(tmp_path, "lev2", [6]))
        extsort.reset_stats()
        v = ck.next_version()
        s2 = CK.snapshot_sorted_state(ck.begin(v), rs, rs.runs[-1],
                                      prev_dir=sealed,
                                      prev_names=set(s1["runs"]))
        new_run_bytes = sum(
            os.path.getsize(os.path.join(str(tmp_path), "lev2", fn))
            for fn in os.listdir(os.path.join(str(tmp_path), "lev2")))
        # Only the NEW run paid copy I/O; lev0/lev1 were hard-linked.
        assert extsort.STATS["ckpt_bytes_written"] == new_run_bytes
        snap2 = ck.publish(v, {"state": s2})
        # The sealed snapshot is still complete and readable.
        from repro.core.disk import ChunkStore
        got = []
        for dname in s2["runs"]:
            got += ChunkStore(os.path.join(snap2, dname),
                              1).read_all()[:, 0].tolist()
        assert sorted(got) == [1, 2, 3, 4, 5, 6]

    def test_end_to_end_snapshot_stays_complete(self, tmp_path, want):
        run_sorted(tmp_path / "w", checkpoint_dir=str(tmp_path / "ck"),
                   checkpoint_every=1)
        ck = SearchCheckpoint(str(tmp_path / "ck"))
        meta = ck.latest()
        snap = ck.snapshot_dir(meta)
        from repro.core.disk import ChunkStore
        total = sum(ChunkStore(os.path.join(snap, dname), 1).size
                    for dname in meta["state"]["runs"])
        assert total == sum(want)
