"""Sharded Tier D runtime (disk/cluster.py + disk/buckets.py).

Covers the ISSUE-4 subsystem end to end:

  * golden-value pins of the owner functions (types.hash_rows /
    sharding.hash_owner / sharding.block_owner vs their jax-free numpy
    mirrors in buckets.py) — cross-process ownership agreement is what
    keeps a sharded structure uncorrupted,
  * bucket-file protocol: seal/consume roundtrip, deterministic source
    order, exact overflow ``dropped`` accounting (the Tier D mirror of
    the Tier J ``bin_by_dest`` tests), abort-safety (.tmp strays are
    ignorable and swept),
  * the sharded wrappers (list / hash table / bit array) against their
    single-process oracles, for nshards ∈ {1, 2, 4},
  * distributed BFS on BOTH engines: level counts identical to the
    single-process engines, and the PR 3 per-level pass budgets holding
    PER SHARD (no extra sorts / array traversals from the exchange),
  * spawn mode (real worker processes): a small always-on smoke test,
    plus the full pancake equivalence sweep when ROOMY_SHARDS is set
    (the CI matrix leg runs with ROOMY_SHARDS=2).

Module-level imports stay numpy-only on purpose: spawn workers re-import
this module to unpickle the generator classes below, and must not pay a
jax import for it (jax-needing tests import inside the test body).
"""
import math
import os
import sys

import numpy as np
import pytest

from repro.core.disk import bitarray as DBA
from repro.core.disk import buckets as B
from repro.core.disk import extsort
from repro.core.disk import breadth_first_search, implicit_bfs
from repro.core.disk.bitarray import CUR, DONE, DiskBitArray
from repro.core.disk.cluster import (ShardedDiskBitArray,
                                     ShardedDiskHashTable, ShardedDiskList,
                                     ShardRuntime)
from repro.core.disk.dhash import DiskHashTable
from repro.core.disk.dlist import DiskList

sys.path.append(os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "examples"))
from pancake_bfs import GenNextNp, start_code        # noqa: E402
from pancake_bits import NeighborsNp                 # noqa: E402

# The CI matrix leg sets ROOMY_SHARDS=2 to run the spawn-mode sweep.
ROOMY_SHARDS = int(os.environ.get("ROOMY_SHARDS", "0"))

SHARD_COUNTS = (1, 2, 4)


@pytest.fixture
def wd(tmp_path):
    return str(tmp_path)


class RingGen:
    """Picklable ring-graph neighbour generator (spawn-mode tests)."""

    def __init__(self, n: int):
        self.n = n

    def __call__(self, idx):
        idx = np.asarray(idx, np.int64)
        return np.stack([(idx + 1) % self.n, (idx - 1) % self.n], axis=1)


def _boom(ctx):
    raise ValueError("deliberate worker failure")


# ----------------------------------------------------- owner-function pins

class TestOwnerGolden:
    """A worker disagreeing with the coordinator about ownership silently
    corrupts a sharded structure — pin the maps to golden values AND to
    the Tier J implementations, so neither side can drift alone."""

    ROWS1 = np.array([[0], [1], [2], [0xFFFFFFFF], [0xDEADBEEF]], np.uint32)
    ROWS2 = np.array([[0, 0], [1, 2], [2, 1], [123456789, 987654321]],
                     np.uint32)
    GOLD1 = np.array([0x39E95042, 0xA381B84E, 0x99CA38EF, 0x8BB58942,
                      0xBE973D59], np.uint32)
    GOLD2 = np.array([0x4B71867D, 0x9C77B28B, 0x702BE32B, 0x65F056C5],
                     np.uint32)

    def test_hash_rows_np_golden(self):
        assert np.array_equal(B.hash_rows_np(self.ROWS1), self.GOLD1)
        assert np.array_equal(B.hash_rows_np(self.ROWS2), self.GOLD2)

    def test_hash_owner_np_golden(self):
        assert B.hash_owner_np(self.ROWS1, 4).tolist() == [2, 2, 3, 2, 1]
        assert B.hash_owner_np(self.ROWS1, 7).tolist() == [6, 3, 5, 2, 6]
        assert B.hash_owner_np(self.ROWS2, 4).tolist() == [1, 3, 3, 1]
        assert B.hash_owner_np(self.ROWS2, 7).tolist() == [3, 6, 1, 2]

    def test_block_owner_np_golden(self):
        idx = np.array([0, 1, 9, 10, 11, 63, 64, 99], np.int64)
        assert B.block_owner_np(idx, 100, 4).tolist() == [0, 0, 0, 0, 0,
                                                          2, 2, 3]
        assert B.block_owner_np(idx, 100, 3).tolist() == [0, 0, 0, 0, 0,
                                                          1, 1, 2]

    def test_tier_j_hash_rows_matches_numpy_mirror(self):
        import jax.numpy as jnp
        from repro.core import types as T
        rng = np.random.default_rng(0)
        for w in (1, 2, 3):
            rows = rng.integers(0, 1 << 32, (64, w), dtype=np.uint64
                                ).astype(np.uint32)
            assert np.array_equal(np.asarray(T.hash_rows(jnp.asarray(rows))),
                                  B.hash_rows_np(rows))
        assert np.array_equal(np.asarray(T.hash_rows(jnp.asarray(self.ROWS1))),
                              self.GOLD1)

    def test_tier_j_owners_match_numpy_mirrors(self):
        import jax.numpy as jnp
        from repro.core import sharding as S
        rng = np.random.default_rng(1)
        rows = rng.integers(0, 1 << 32, (64, 2), dtype=np.uint64
                            ).astype(np.uint32)
        for ns in SHARD_COUNTS + (7,):
            assert np.array_equal(
                np.asarray(S.hash_owner(jnp.asarray(rows), ns)),
                B.hash_owner_np(rows, ns))
        idx = rng.integers(0, 1000, 128)
        for ns in SHARD_COUNTS + (7,):
            assert np.array_equal(
                np.asarray(S.block_owner(jnp.asarray(idx), 1000, ns)),
                B.block_owner_np(idx, 1000, ns))


# -------------------------------------------------------- bucket protocol

class TestBuckets:
    def test_roundtrip_and_source_order(self, wd):
        w0 = B.BucketWriter(wd, src=0, nshards=2, width=2)
        w1 = B.BucketWriter(wd, src=1, nshards=2, width=2)
        w1.put([0, 0], np.array([[10, 11], [12, 13]], np.int64))
        w0.put([0, 1], np.array([[1, 2], [3, 4]], np.int64))
        assert w0.seal(epoch=5).sum() == 0
        assert w1.seal(epoch=5).sum() == 0
        got = list(B.iter_incoming(wd, dst=0, epoch=5, width=2))
        assert [src for src, _ in got] == [0, 1]          # ascending src
        assert np.array_equal(got[0][1], [[1, 2]])
        assert np.array_equal(got[1][1], [[10, 11], [12, 13]])
        # consumed: a second read sees nothing
        assert list(B.iter_incoming(wd, dst=0, epoch=5, width=2)) == []
        (src, rows), = B.iter_incoming(wd, dst=1, epoch=5, width=2)
        assert src == 0 and np.array_equal(rows, [[3, 4]])

    def test_epoch_isolation(self, wd):
        w = B.BucketWriter(wd, src=0, nshards=1, width=1)
        w.put([0], [[7]])
        w.seal(epoch=1)
        w.put([0], [[8]])
        w.seal(epoch=2)
        (_, rows), = B.iter_incoming(wd, 0, 1, 1)
        assert rows.tolist() == [[7]]
        (_, rows), = B.iter_incoming(wd, 0, 2, 1)
        assert rows.tolist() == [[8]]

    def _oracle_dropped(self, dest, nshards, capacity):
        return sum(max(0, np.sum(np.asarray(dest) == d) - capacity)
                   for d in range(nshards))

    def test_overflow_dropped_exact(self, wd):
        """The bin_by_dest convention on disk: per-(src,dst) buckets hold
        capacity rows per epoch; the overflow count is EXACT."""
        rng = np.random.default_rng(2)
        for case in range(8):
            ns = int(rng.integers(1, 5))
            cap = int(rng.integers(0, 6))
            m = int(rng.integers(1, 50))
            dest = rng.integers(0, ns, m)
            w = B.BucketWriter(os.path.join(wd, f"c{case}"), src=0,
                               nshards=ns, width=1, capacity=cap,
                               buf_rows=4)      # force mid-epoch spills
            # split across several put() calls — capacity is per EPOCH
            for lo in range(0, m, 7):
                sl = dest[lo:lo + 7]
                w.put(sl, np.arange(lo, lo + sl.shape[0], dtype=np.int64
                                    ).reshape(-1, 1))
            dropped = w.seal(epoch=0)
            assert dropped.sum() == self._oracle_dropped(dest, ns, cap)
            kept = sum(r.shape[0] for _s, r in
                       B.iter_incoming(os.path.join(wd, f"c{case}"), 0, 0, 1)
                       ) + sum(r.shape[0] for _s, r in
                               B.iter_incoming(os.path.join(wd, f"c{case}"),
                                               1, 0, 1) if ns > 1)
            # kept + dropped == issued for the destinations we read
            if ns <= 2:
                assert kept + dropped.sum() == m

    def test_zero_capacity_drops_everything(self, wd):
        w = B.BucketWriter(wd, src=0, nshards=2, width=1, capacity=0)
        w.put([0, 1, 1], np.zeros((3, 1), np.int64))
        assert w.seal(epoch=0).tolist() == [1, 2]
        assert list(B.iter_incoming(wd, 0, 0, 1)) == []
        assert list(B.iter_incoming(wd, 1, 0, 1)) == []

    def test_capacity_resets_per_epoch(self, wd):
        w = B.BucketWriter(wd, src=0, nshards=1, width=1, capacity=2)
        w.put([0, 0, 0], np.zeros((3, 1), np.int64))
        assert w.seal(epoch=0).tolist() == [1]
        w.put([0, 0], np.zeros((2, 1), np.int64))
        assert w.seal(epoch=1).tolist() == [0]

    def test_unsealed_tmp_is_invisible_and_swept(self, wd):
        """A worker killed mid-epoch leaves only .tmp files: readers see
        nothing, cleanup removes them, sealed files survive."""
        w = B.BucketWriter(wd, src=0, nshards=1, width=1, buf_rows=1)
        w.put([0], [[1]])                       # buf_rows=1 -> spilled .tmp
        assert any(f.endswith(".tmp") for f in os.listdir(wd))
        assert list(B.iter_incoming(wd, 0, 0, 1)) == []     # never sealed
        w2 = B.BucketWriter(wd, src=1, nshards=1, width=1)
        w2.put([0], [[2]])
        w2.seal(epoch=0)
        removed = B.cleanup_strays(wd)
        assert len(removed) == 1 and removed[0].endswith(".tmp")
        assert not any(f.endswith(".tmp") for f in os.listdir(wd))
        (src, rows), = B.iter_incoming(wd, 0, 0, 1)
        assert src == 1 and rows.tolist() == [[2]]


# ------------------------------------------------------- runtime basics

class TestShardRuntime:
    def test_inline_map_and_barrier(self, wd):
        with ShardRuntime(wd, 3, mode="inline") as rt:
            from repro.core.disk.cluster import _w_noop
            assert rt.map(_w_noop) == [0, 1, 2]
            rt.barrier()

    def test_fresh_runtime_sweeps_exchange_strays(self, wd):
        exch = os.path.join(wd, "exchange", "mystruct")
        os.makedirs(exch)
        stray = os.path.join(exch, "s000_d000.bin.tmp")
        open(stray, "wb").write(b"\x00" * 16)
        sealed = os.path.join(exch, "e000001_s000_d000.bin")
        open(sealed, "wb").write(np.zeros(2, np.int64).tobytes())
        # fresh=True wipes the whole exchange area
        ShardRuntime(wd, 2, mode="inline", fresh=True)
        assert not os.path.exists(stray) and not os.path.exists(sealed)
        # fresh=False sweeps only ignorable .tmp strays
        os.makedirs(exch, exist_ok=True)
        open(stray, "wb").write(b"\x00" * 16)
        open(sealed, "wb").write(np.zeros(2, np.int64).tobytes())
        ShardRuntime(wd, 2, mode="inline", fresh=False)
        assert not os.path.exists(stray)
        assert os.path.exists(sealed)

    def test_sync_surfaces_exact_dropped_per_structure(self, wd):
        """Satellite: ShardRuntime.sync() returns the EXACT overflow loss
        per registered structure (the disk mirror of the Tier J
        bin_by_dest overflow tests)."""
        with ShardRuntime(wd, 2, mode="inline") as rt:
            lst = ShardedDiskList(rt, width=1, capacity=2)
            big = ShardedDiskList(rt, width=1)          # unbounded
            rows = np.arange(64, dtype=np.uint32).reshape(-1, 1)
            owners = B.hash_owner_np(rows, 2)
            lst.add(rows)
            big.add(rows)
            want = sum(max(0, int((owners == d).sum()) - 2)
                       for d in range(2))
            dropped = rt.sync()
            assert dropped[lst.name] == want > 0
            assert dropped[big.name] == 0
            assert lst.size() + want == 64
            assert big.size() == 64


# ------------------------------------------------------ sharded wrappers

class TestShardedDiskList:
    @pytest.mark.parametrize("nshards", SHARD_COUNTS)
    def test_matches_single_process_oracle(self, wd, nshards):
        rng = np.random.default_rng(3)
        a_rows = rng.integers(0, 40, (200, 2)).astype(np.uint32)
        b_rows = rng.integers(0, 40, (60, 2)).astype(np.uint32)
        with ShardRuntime(os.path.join(wd, "rt"), nshards,
                          mode="inline") as rt:
            a = ShardedDiskList(rt, width=2, chunk_rows=32)
            b = ShardedDiskList(rt, width=2, chunk_rows=32)
            a.add(a_rows)
            b.add(b_rows)
            assert rt.sync() == {a.name: 0, b.name: 0}
            assert a.size() == 200 and b.size() == 60
            a.remove_dupes()
            a.remove_all(b)
            got = a.read_all()
            a.destroy()
            b.destroy()
        oa = DiskList(os.path.join(wd, "oracle"), 2, 32)
        ob = DiskList(os.path.join(wd, "oracle"), 2, 32)
        oa.add(a_rows)
        ob.add(b_rows)
        oa.remove_dupes()
        oa.remove_all(ob)
        assert np.array_equal(got, extsort.sort_rows(oa.read_all()))
        oa.destroy()
        ob.destroy()

    def test_multi_epoch_accumulates(self, wd):
        with ShardRuntime(wd, 2, mode="inline") as rt:
            lst = ShardedDiskList(rt, width=1)
            lst.add(np.array([[1]], np.uint32))
            lst.sync()
            lst.add(np.array([[2], [3]], np.uint32))
            lst.sync()
            assert lst.size() == 3
            assert lst.read_all().reshape(-1).tolist() == [1, 2, 3]


class TestShardedDiskHashTable:
    @pytest.mark.parametrize("nshards", SHARD_COUNTS)
    def test_insert_lookup_matches_dict(self, wd, nshards):
        rng = np.random.default_rng(4)
        keys = rng.integers(0, 50, (120, 1)).astype(np.uint32)
        vals = rng.integers(0, 1000, (120, 1)).astype(np.int64)
        with ShardRuntime(wd, nshards, mode="inline") as rt:
            ht = ShardedDiskHashTable(rt, key_width=1, val_width=1,
                                      nbuckets=4)
            ht.insert(keys, vals)
            assert ht.sync() == 0
            oracle = {}
            for k, v in zip(keys[:, 0], vals[:, 0]):
                oracle[int(k)] = int(v)       # overwrite = last PUT wins
            assert ht.size() == len(oracle)
            q = np.arange(60, dtype=np.uint32).reshape(-1, 1)
            out, found = ht.lookup(q)
            for i in range(60):
                assert found[i] == (i in oracle)
                if found[i]:
                    assert out[i, 0] == oracle[i]

    def test_del_put_order_survives_the_exchange(self, wd):
        """The bucket files must preserve per-key op order: DEL then PUT
        resurrects, PUT then DEL removes — the PR 3 sequential-op-log
        rule, now crossing process/shard boundaries."""
        with ShardRuntime(wd, 2, mode="inline") as rt:
            ht = ShardedDiskHashTable(rt, 1, 1)
            ks = np.arange(8, dtype=np.uint32).reshape(-1, 1)
            ht.insert(ks, np.full((8, 1), 10, np.int64))
            ht.sync()
            # one epoch: DEL k then PUT k (resurrect); PUT j then DEL j
            ht.remove(ks[:4])
            ht.insert(ks[:4], np.full((4, 1), 99, np.int64))
            ht.insert(ks[4:], np.full((4, 1), 77, np.int64))
            ht.remove(ks[4:])
            ht.sync()
            out, found = ht.lookup(ks)
            assert found[:4].all() and not found[4:].any()
            assert (out[:4, 0] == 99).all()
            assert ht.size() == 4


class TestShardedDiskBitArray:
    @pytest.mark.parametrize("nshards", SHARD_COUNTS)
    def test_matches_single_process_oracle(self, wd, nshards):
        n = 101                                 # NOT divisible: short last shard
        rng = np.random.default_rng(5)
        idx = rng.integers(-5, n + 5, 300)      # out-of-range must drop
        vals = rng.integers(0, 4, 300).astype(np.uint8)
        with ShardRuntime(os.path.join(wd, "rt"), nshards,
                          mode="inline") as rt:
            sb = ShardedDiskBitArray(rt, n, chunk_elems=16)
            sb.update(idx, vals)
            assert sb.sync() == 0
            got_all = sb.read_all()
            got_some = sb.get(np.arange(n))
            hist = sb.count_values()
            sb.destroy()
        ob = DiskBitArray(os.path.join(wd, "oracle"), n, chunk_elems=16)
        ob.update(idx, vals)
        ob.sync()
        want = ob.read_all()
        assert np.array_equal(got_all, want)
        assert np.array_equal(got_some, want)
        assert np.array_equal(hist, ob.count_values())
        ob.destroy()


# --------------------------------------------- distributed BFS equivalence

def _pancake_single(n, wd):
    sizes, all_obj = breadth_first_search(
        wd, np.array([[start_code(n)]], np.uint32), GenNextNp(n), width=1,
        chunk_rows=1 << 10)
    all_obj.destroy()
    return sizes


class TestShardedBFSEquivalence:
    @pytest.mark.parametrize("nshards", SHARD_COUNTS)
    def test_sorted_engine_levels_match(self, wd, nshards):
        n = 6
        want = _pancake_single(n, os.path.join(wd, "single"))
        # nshards=1 still goes through the full runtime/bucket protocol
        # when a runtime is passed explicitly
        rt = ShardRuntime(os.path.join(wd, "rt"), nshards, mode="inline")
        sizes, vis = breadth_first_search(
            os.path.join(wd, "shard"), np.array([[start_code(n)]], np.uint32),
            GenNextNp(n), width=1, chunk_rows=1 << 10, runtime=rt)
        assert sizes == want
        assert vis.dropped == 0
        assert vis.size() == math.factorial(n)
        assert vis.read_all().shape == (math.factorial(n), 1)
        vis.destroy()

    @pytest.mark.parametrize("nshards", SHARD_COUNTS)
    def test_implicit_engine_levels_match(self, wd, nshards):
        from repro.core import ranking as R
        n = 6
        total = math.factorial(n)
        start = int(R.rank_np(np.arange(n)[None, :])[0])
        want = [1, 5, 20, 79, 199, 281, 133, 2]          # == sorted engine
        rt = ShardRuntime(os.path.join(wd, "rt"), nshards, mode="inline")
        sizes, bits = implicit_bfs(
            wd, total, [start], NeighborsNp(n), chunk_elems=256, runtime=rt)
        assert sizes == want
        assert bits.dropped == 0
        hist = bits.count_values()
        assert hist[0] == 0 and hist[DONE] == total
        # every state ended DONE, in global (block) order
        assert np.array_equal(bits.read_all(),
                              np.full(total, DONE, np.uint8))
        bits.destroy()

    def test_sorted_engine_no_extra_sorts_per_shard(self, wd):
        """Acceptance pin: the exchange introduces ZERO extra sort work —
        total rows sorted across shards equals the single-process run,
        and each level costs at most one sort pass per shard."""
        n = 5
        extsort.reset_stats()
        want = _pancake_single(n, os.path.join(wd, "single"))
        single = dict(extsort.STATS)
        levels = len(want) - 1
        for nshards in (2, 4):
            extsort.reset_stats()
            sizes, vis = breadth_first_search(
                os.path.join(wd, f"s{nshards}"),
                np.array([[start_code(n)]], np.uint32), GenNextNp(n),
                width=1, chunk_rows=1 << 10, nshards=nshards,
                shard_mode="inline")
            vis.destroy()
            assert sizes == want
            # identical total rows sorted: every neighbour row is sorted
            # exactly once, on exactly one shard (seed row included)
            assert extsort.STATS["rows_sorted"] == single["rows_sorted"]
            # ≤ one sort pass per shard per level (+1 for its seed batch);
            # empty shard-levels pay zero
            assert (extsort.STATS["sort_passes"]
                    <= nshards * (levels + 1 + 1))
            assert extsort.STATS["sort_passes"] >= single["sort_passes"]

    def test_implicit_engine_one_rw_pass_per_level_per_shard(self, wd):
        """Acceptance pin: each shard pays exactly ONE fused read-write
        pass over ITS block per level — the bitarray byte counters can't
        hide an extra traversal."""
        n_states, nshards = 256, 2
        DBA.reset_stats()
        extsort.reset_stats()
        sizes, bits = implicit_bfs(wd, n_states, [0], RingGen(n_states),
                                   chunk_elems=64, nshards=nshards,
                                   shard_mode="inline")
        assert sum(sizes) == n_states
        passes = len(sizes) + 1       # seed pass + one per level transition
        # ONE sync (rw) pass per shard per level, ZERO scan passes anywhere
        # (a seed-less shard's dirty-only seed pass books as a read pass)
        assert DBA.STATS["sync_passes"] == nshards * passes
        assert DBA.STATS["scan_passes"] == 0
        assert (extsort.STATS["rw_passes"] + extsort.STATS["read_passes"]
                == nshards * passes)
        assert extsort.STATS["sort_passes"] == 0
        # array bytes: each shard traverses its 128-element block (32
        # packed bytes) once per non-seed pass; the dirty-only seed pass
        # touches only the seed's chunk (64 elems -> 16 packed bytes)
        per_shard_bytes = (n_states // nshards) // 4
        arr_read = DBA.STATS["bytes_read"] - DBA.STATS["log_bytes_read"]
        assert arr_read == nshards * (passes - 1) * per_shard_bytes + 16
        arr_written = (DBA.STATS["bytes_written"]
                       - DBA.STATS["log_bytes_written"])
        assert arr_written == nshards * (passes - 1) * per_shard_bytes + 16
        bits.destroy()


# ------------------------------------------------------ abort-safety sweep

class TestAbortSafety:
    def test_killed_worker_leaves_only_ignorable_tmp(self, wd):
        """Satellite: simulate a worker dying mid-epoch (rows spilled, no
        seal) — the next runtime boots clean, and a subsequent exchange
        of the same structure neither sees nor resurrects the strays."""
        rt = ShardRuntime(wd, 2, mode="inline")
        lst = ShardedDiskList(rt, width=1, name="surv")
        lst.add(np.array([[1], [2], [3]], np.uint32))
        # spill to .tmp but DON'T seal — the "kill point"
        rt.driver.writer(lst.spec)._spill()
        exch = rt.driver.exchange_dir("surv")
        assert any(f.endswith(".tmp") for f in os.listdir(exch))
        # reboot on the same root, keeping shard state (fresh=False)
        rt2 = ShardRuntime(wd, 2, mode="inline", fresh=False)
        assert not any(f.endswith(".tmp") for f in os.listdir(exch))
        lst2 = ShardedDiskList(rt2, width=1, name="surv2")
        lst2.add(np.array([[9]], np.uint32))
        assert lst2.sync() == 0
        assert lst2.read_all().reshape(-1).tolist() == [9]

    def test_pass_snapshot_readoption_inside_a_shard(self, wd):
        """The PR 3 ``.pass`` re-adoption guarantee extended to bucket
        dirs: a shard-local aborted pass snapshot AND a stray bucket
        .tmp coexist; the next sharded sync applies the snapshot ops,
        ignores the stray, and loses nothing."""
        rt = ShardRuntime(wd, 2, mode="inline")
        sb = ShardedDiskBitArray(rt, 64, name="bits", chunk_elems=16)
        # an aborted pass left a snapshot log in shard 0's local array
        # (global idx 3 -> shard 0 local 3, value 1)
        local = rt._inline_ctxs[0].objects["bits"]
        with open(local._log_path(0) + ".pass", "wb") as f:
            f.write(np.array([[3, 1]], np.int64).tobytes())
        # and a killed peer left a stray .tmp bucket
        exch = rt.driver.exchange_dir("bits")
        os.makedirs(exch, exist_ok=True)
        with open(os.path.join(exch, "s001_d000.bin.tmp"), "wb") as f:
            f.write(np.array([[5, 3]], np.int64).tobytes())
        sb.update([40], [2])                     # a fresh delayed op too
        assert sb.sync() == 0
        assert sb.get([3, 40, 5]).tolist() == [1, 2, 0]   # stray NOT applied
        # destroy() clears the exchange dir including the stray
        sb.destroy()
        assert not os.path.exists(exch)

    def test_bfs_runtime_dir_is_removable_after_search(self, wd):
        sizes, vis = breadth_first_search(
            wd, np.array([[start_code(4)]], np.uint32), GenNextNp(4),
            width=1, chunk_rows=64, nshards=2, shard_mode="inline")
        assert sum(sizes) == 24
        vis.destroy()
        exch = os.path.join(wd, "cluster", "exchange")
        # no sealed/partial bucket files survive the search
        leftovers = []
        for dirpath, _dirs, files in os.walk(exch):
            leftovers += [f for f in files if f.endswith((".bin", ".tmp"))]
        assert leftovers == []


# ------------------------------------------------------------ spawn mode

class TestSpawnMode:
    """Real worker processes (multiprocessing spawn).  Kept small — the
    ROOMY_SHARDS CI leg runs the heavier sweep below."""

    def test_spawn_list_and_worker_stats(self, wd):
        with ShardRuntime(wd, 2, mode="spawn") as rt:
            lst = ShardedDiskList(rt, width=1)
            lst.add(np.arange(32, dtype=np.uint32).reshape(-1, 1))
            assert lst.sync() == 0
            assert lst.size() == 32
            assert lst.read_all().reshape(-1).tolist() == list(range(32))
            from repro.core.disk.cluster import _w_get_stats
            stats = rt.bcast(_w_get_stats)
            assert len(stats) == 2
            assert all("extsort" in s and "bits" in s for s in stats)

    def test_spawn_worker_error_propagates(self, wd):
        with ShardRuntime(wd, 2, mode="spawn") as rt:
            with pytest.raises(RuntimeError, match="deliberate"):
                rt.bcast(_boom)
            # the runtime survives a failed collective
            from repro.core.disk.cluster import _w_noop
            assert rt.map(_w_noop) == [0, 1]

    def test_spawn_implicit_bfs_budget_per_worker(self, wd):
        """Per-SHARD budgets read from each worker process's own STATS:
        one rw pass per level, zero scans, zero sorts."""
        n_states = 256
        with ShardRuntime(os.path.join(wd, "rt"), 2, mode="spawn") as rt:
            from repro.core.disk.cluster import (_w_get_stats,
                                                 sharded_implicit_bfs)
            sizes, bits = sharded_implicit_bfs(rt, n_states, [0],
                                               RingGen(n_states),
                                               chunk_elems=64)
            assert sum(sizes) == n_states
            passes = len(sizes) + 1
            for s in rt.bcast(_w_get_stats):
                assert s["bits"]["sync_passes"] == passes
                assert s["bits"]["scan_passes"] == 0
                assert s["extsort"]["sort_passes"] == 0
            bits.destroy()


@pytest.mark.skipif(ROOMY_SHARDS < 2,
                    reason="set ROOMY_SHARDS>=2 (the CI matrix leg) to run "
                           "the spawn-mode pancake sweep")
class TestSpawnPancakeSweep:
    """The acceptance sweep under real processes — both engines, level
    counts identical to the single-process engines."""

    def test_both_engines_match_single_process(self, tmp_path):
        from repro.core import ranking as R
        n = 6
        total = math.factorial(n)
        want = _pancake_single(n, str(tmp_path / "single"))
        sizes, vis = breadth_first_search(
            str(tmp_path / "sorted"), np.array([[start_code(n)]], np.uint32),
            GenNextNp(n), width=1, chunk_rows=1 << 10,
            nshards=ROOMY_SHARDS, shard_mode="spawn")
        assert sizes == want
        vis.destroy()
        start = int(R.rank_np(np.arange(n)[None, :])[0])
        sizes, bits = implicit_bfs(
            str(tmp_path / "implicit"), total, [start], NeighborsNp(n),
            chunk_elems=256, nshards=ROOMY_SHARDS, shard_mode="spawn")
        assert sizes == want
        assert bits.count_values()[0] == 0
        bits.destroy()
