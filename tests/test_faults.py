"""Self-healing runtime (disk/faults.py + the recovery path in cluster.py).

Covers the ISSUE-6 fault-tolerance layer end to end:

  * the ``ROOMY_FAULTS`` spec grammar and the determinism contract (same
    seed + same bind → the identical firing sequence, so a failing chaos
    run replays exactly),
  * ``once`` markers persisting across plan re-installs (the cross-process
    guarantee that a recovered run does not re-fire the kill on replay),
  * zero cost when disabled: no plan installed → ``faults.ACTIVE`` is
    False, a fault-free BFS books zero fault counters,
  * retry_io / append_bytes: transient errnos heal with booked retries,
    fatal errnos give up immediately, torn appends can never leave
    partial or duplicated records,
  * the fresh=False startup sweep booking ``.tmp``/``.pass`` strays,
  * hardened teardown: a wedged (delayed) worker breaks the collective
    but neither shutdown() nor recover() ever hangs,
  * the headline contract — a worker killed at any (level, site) pair
    recovers in-run from the last coordinated checkpoint on BOTH sharded
    engines, nshards ∈ {1, 2}, with final level counts IDENTICAL to the
    fault-free run and the rollback booked under STATS['recoveries'];
    unrecoverable runs raise a structured ShardFailure, never hang.

Spawn-mode kill tests re-import the generator classes from the examples
(the test_cluster.py convention); the full spawn sweep stays behind
ROOMY_SHARDS like the rest of the spawn matrix.
"""
import errno
import math
import os
import sys

import numpy as np
import pytest

from repro.core.disk import buckets as B
from repro.core.disk import extsort, faults
from repro.core.disk import breadth_first_search, implicit_bfs
from repro.core.disk.cluster import ShardFailure, ShardRuntime, WorkerLost

from _hypothesis_compat import given, settings, st

sys.path.append(os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "examples"))
from pancake_bfs import GenNextNp, start_code         # noqa: E402
from pancake_bits import NeighborsNp                  # noqa: E402

ROOMY_SHARDS = int(os.environ.get("ROOMY_SHARDS", "0"))

# Fault-free pancake-5 flip-distance histogram (pinned by test_bfs /
# test_cluster): every recovered run below must land EXACTLY here.
PANCAKE5 = [1, 4, 12, 35, 48, 20]


@pytest.fixture(autouse=True)
def _clean_faults():
    """Every test starts with no plan, no env spec, zeroed counters."""
    saved = os.environ.pop(faults.ENV_VAR, None)
    faults.uninstall()
    extsort.reset_stats()
    yield
    faults.uninstall()
    if saved is None:
        os.environ.pop(faults.ENV_VAR, None)
    else:
        os.environ[faults.ENV_VAR] = saved


def _sorted_levels(wd: str, n: int = 5, nshards: int = 2,
                   mode: str = "inline", **kw):
    """Sharded sorted-list pancake BFS; returns level sizes."""
    rt = ShardRuntime(os.path.join(wd, "rt"), nshards, mode=mode)
    try:
        sizes, vis = breadth_first_search(
            os.path.join(wd, "bfs"), np.array([[start_code(n)]], np.uint32),
            GenNextNp(n), width=1, chunk_rows=1 << 10, runtime=rt, **kw)
        vis.destroy()
    finally:
        rt.shutdown()
    return sizes


def _implicit_levels(wd: str, n: int = 5, nshards: int = 2,
                     mode: str = "inline", **kw):
    """Sharded implicit (2-bit array) pancake BFS; returns level sizes."""
    from repro.core import ranking as R
    total = math.factorial(n)
    start = int(R.rank_np(np.arange(n)[None, :])[0])
    rt = ShardRuntime(os.path.join(wd, "rt"), nshards, mode=mode)
    try:
        sizes, bits = implicit_bfs(
            os.path.join(wd, "bfs"), total, [start], NeighborsNp(n),
            chunk_elems=1 << 5, runtime=rt, **kw)
        bits.destroy()
    finally:
        rt.shutdown()
    return sizes


_ENGINES = {"sorted": _sorted_levels, "implicit": _implicit_levels}


# ------------------------------------------------------------- spec grammar

class TestSpecParse:

    def test_grammar(self):
        plan = faults.parse(
            "seed=7;bucket_seal:transient:every=2:times=3;"
            "worker_level:kill:shard=1:level=2;"
            "oplog_append:torn:at=4:once=0;barrier:delay:secs=1.5")
        assert plan.seed == 7
        r0, r1, r2, r3 = plan.rules
        assert (r0.site, r0.kind, r0.every, r0.times) == \
            ("bucket_seal", "transient", 2, 3)
        assert not r0.once                     # transient defaults once=0
        assert (r1.site, r1.kind, r1.shard, r1.level) == \
            ("worker_level", "kill", 1, 2)
        assert r1.once                         # kill defaults once=1
        assert (r2.at, r2.once) == (4, False)  # explicit once=0 wins
        assert r3.kind == "delay" and r3.secs == 1.5 and r3.once

    def test_rejects_bad_rules(self):
        with pytest.raises(ValueError):
            faults.parse("justasite")
        with pytest.raises(ValueError):
            faults.parse("bucket_seal:transient:bogus=1")
        with pytest.raises((AssertionError, ValueError)):
            faults.parse("bucket_seal:explode")

    def test_install_from_env_noop_when_unset(self):
        os.environ.pop(faults.ENV_VAR, None)
        assert not faults.install_from_env()
        assert not faults.ACTIVE


# -------------------------------------------------------------- determinism

def _fire_trace(plan: faults.FaultPlan, hits: int = 100):
    out = []
    for _ in range(hits):
        try:
            plan.fire("chunk_flush", shard=0)
            out.append(0)
        except OSError:
            out.append(1)
    return out


class TestDeterminism:

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_same_seed_same_trace(self, seed):
        spec = f"seed={seed};chunk_flush:transient:p=0.3:once=0"
        a = faults.parse(spec).bind()
        b = faults.parse(spec).bind()
        assert _fire_trace(a) == _fire_trace(b)

    def test_shard_salt_changes_rng_stream_deterministically(self):
        spec = "seed=11;chunk_flush:transient:p=0.5:once=0"
        t3a = _fire_trace(faults.parse(spec).bind(shard=3))
        t3b = _fire_trace(faults.parse(spec).bind(shard=3))
        assert t3a == t3b
        assert sum(t3a) > 0                  # p=0.5 over 100 hits fires

    def test_at_and_every_and_times(self):
        plan = faults.parse("meta_write:transient:at=3:times=2:once=0").bind()
        trace = []
        for _ in range(6):
            try:
                plan.fire("meta_write")
                trace.append(0)
            except OSError:
                trace.append(1)
        assert trace == [0, 0, 1, 1, 0, 0]   # 3rd hit + a burst of 2


# ------------------------------------------------------------- once markers

class TestOnceMarkers:

    def test_marker_survives_reinstall(self, tmp_path):
        spec = "worker_level:kill:level=2"
        state = str(tmp_path / "faults")
        a = faults.parse(spec).bind(state_dir=state)
        with pytest.raises(faults.WorkerKilled):
            a.fire("worker_level", shard=0, level=2)
        # A fresh plan (a respawned worker) sees the marker: no re-fire.
        b = faults.parse(spec).bind(state_dir=state)
        assert b.fire("worker_level", shard=0, level=2) is None
        # ...but a different level is a different marker key.
        c = faults.parse("worker_level:kill").bind(state_dir=state)
        with pytest.raises(faults.WorkerKilled):
            c.fire("worker_level", shard=0, level=3)

    def test_in_process_fallback_without_state_dir(self):
        plan = faults.parse("ckpt_publish:fatal").bind()
        with pytest.raises(OSError):
            plan.fire("ckpt_publish")
        assert plan.fire("ckpt_publish") is None


# ---------------------------------------------------------------- zero cost

class TestZeroCost:

    def test_inactive_by_default(self):
        assert faults.ACTIVE is False
        assert faults.fire("bucket_seal", shard=0) is None

    def test_install_toggles_active(self):
        faults.install(faults.parse("bucket_seal:transient").bind())
        assert faults.ACTIVE
        faults.uninstall()
        assert not faults.ACTIVE

    def test_fault_free_run_books_nothing(self, tmp_path):
        sizes = _sorted_levels(str(tmp_path), nshards=2)
        assert sizes == PANCAKE5
        for k in ("io_retries", "io_giveups", "recoveries",
                  "replayed_levels"):
            assert extsort.STATS[k] == 0, k


# ------------------------------------------------------------ retry wrappers

class TestRetryIO:

    def test_transient_heals_with_booked_retries(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise OSError(errno.EIO, "flake")
            return "ok"

        assert faults.retry_io("meta_write", flaky) == "ok"
        assert extsort.STATS["io_retries"] == 2
        assert extsort.STATS["io_giveups"] == 0

    def test_fatal_errno_gives_up_immediately(self):
        def fatal():
            raise OSError(errno.ENOSPC, "disk full")

        with pytest.raises(OSError):
            faults.retry_io("meta_write", fatal)
        assert extsort.STATS["io_retries"] == 0
        assert extsort.STATS["io_giveups"] == 1

    def test_exhausting_the_attempt_budget_gives_up(self):
        def always():
            raise OSError(errno.EAGAIN, "never heals")

        with pytest.raises(OSError):
            faults.retry_io("meta_write", always, attempts=3,
                            base_delay=0.0001)
        assert extsort.STATS["io_retries"] == 2
        assert extsort.STATS["io_giveups"] == 1

    def test_injected_transient_burst_heals(self):
        faults.install(
            faults.parse("meta_write:transient:at=1:times=2:once=0").bind())
        assert faults.retry_io("meta_write", lambda: "ok") == "ok"
        assert extsort.STATS["io_retries"] == 2

    def test_torn_on_rewrite_site_degrades_to_transient(self):
        faults.install(
            faults.parse("chunk_flush:torn:at=1:once=0").bind())
        assert faults.retry_io("chunk_flush", lambda: "ok") == "ok"
        assert extsort.STATS["io_retries"] == 1


class TestAppendBytes:

    def test_torn_append_never_leaves_partial_records(self, tmp_path):
        path = str(tmp_path / "oplog.bin")
        faults.append_bytes("oplog_append", path, b"AAAA" * 8)
        faults.install(
            faults.parse("oplog_append:torn:at=1:once=0").bind())
        faults.append_bytes("oplog_append", path, b"BBBB" * 8)
        with open(path, "rb") as f:
            assert f.read() == b"AAAA" * 8 + b"BBBB" * 8
        assert extsort.STATS["io_retries"] == 1

    def test_creates_missing_file(self, tmp_path):
        path = str(tmp_path / "new.bin")
        faults.append_bytes("oplog_append", path, b"xyz")
        with open(path, "rb") as f:
            assert f.read() == b"xyz"


# -------------------------------------------------------------- stray sweep

class TestStraySweep:

    def test_cleanup_books_count_and_bytes(self, tmp_path):
        d = str(tmp_path)
        with open(os.path.join(d, "b_000_001.e7.tmp"), "wb") as f:
            f.write(b"x" * 100)
        with open(os.path.join(d, "log.0.pass"), "wb") as f:
            f.write(b"y" * 28)
        with open(os.path.join(d, "b_000_001.e7"), "wb") as f:
            f.write(b"sealed")                  # real data: must survive
        removed = B.cleanup_strays(d)
        assert len(removed) == 2
        assert extsort.STATS["stray_files_swept"] == 2
        assert extsort.STATS["stray_bytes_swept"] == 128
        assert os.path.exists(os.path.join(d, "b_000_001.e7"))

    def test_fresh_false_startup_sweeps_and_books(self, tmp_path):
        root = str(tmp_path / "rt")
        sub = os.path.join(root, "exchange", "bfs1")
        os.makedirs(sub)
        with open(os.path.join(sub, "dead.tmp"), "wb") as f:
            f.write(b"z" * 64)
        rt = ShardRuntime(root, 2, mode="inline", fresh=False)
        rt.shutdown()
        assert not os.path.exists(os.path.join(sub, "dead.tmp"))
        assert extsort.STATS["stray_files_swept"] == 1
        assert extsort.STATS["stray_bytes_swept"] == 64


# -------------------------------------------------- hardened teardown (spawn)

class TestTeardown:

    def test_wedged_worker_breaks_then_recovers_never_hangs(self, tmp_path):
        # The delay rule wedges shard 0 past the collective timeout: the
        # map must fail fast (WorkerLost), recover() must bring the pool
        # back (the `once` marker stops a re-fire), and shutdown must
        # return even though a worker was mid-sleep when it broke.
        os.environ[faults.ENV_VAR] = "barrier:delay:secs=4:shard=0"
        rt = ShardRuntime(str(tmp_path / "rt"), 2, mode="spawn", timeout=1.0)
        try:
            with pytest.raises(WorkerLost):
                rt.barrier()
            with pytest.raises(RuntimeError, match="recover"):
                rt.barrier()                   # poisoned, not hung
            rt.recover()
            rt.barrier()                       # healthy again
        finally:
            rt.shutdown()
        assert rt._procs == [] and rt._cmd_qs == []
        rt.shutdown()                          # idempotent

    def test_shutdown_after_worker_death(self, tmp_path):
        os.environ[faults.ENV_VAR] = "barrier:kill:shard=1"
        rt = ShardRuntime(str(tmp_path / "rt"), 2, mode="spawn", timeout=30)
        try:
            with pytest.raises(WorkerLost) as ei:
                rt.barrier()
            assert ei.value.shard == 1
        finally:
            rt.shutdown()
        assert rt._procs == []


# ------------------------------------------------- in-run recovery (inline)

def _ck(tmp_path):
    return str(tmp_path / "ck")


class TestRecoveryInline:
    """The headline contract, on the in-process runtime (same protocol,
    same on-disk state, same recovery path — kills are WorkerKilled
    raises instead of os._exit)."""

    @pytest.mark.parametrize("engine", ("sorted", "implicit"))
    @pytest.mark.parametrize("nshards", (1, 2))
    @pytest.mark.parametrize("lev", (1, 2, 3))
    def test_kill_at_every_level(self, tmp_path, engine, nshards, lev):
        shard = nshards - 1
        os.environ[faults.ENV_VAR] = \
            f"worker_level:kill:shard={shard}:level={lev}"
        sizes = _ENGINES[engine](str(tmp_path), nshards=nshards,
                                 checkpoint_dir=_ck(tmp_path),
                                 max_recoveries=2)
        assert sizes == PANCAKE5
        assert extsort.STATS["recoveries"] == 1
        assert extsort.STATS["replayed_levels"] >= 1

    @pytest.mark.parametrize("engine,site,at", [
        ("sorted", "bucket_spill", 3),
        ("sorted", "bucket_seal", 4),
        ("sorted", "chunk_flush", 3),
        ("sorted", "meta_write", 4),
        ("sorted", "ckpt_publish", 3),
        ("sorted", "barrier", 9),
        ("implicit", "bucket_spill", 8),
        ("implicit", "oplog_append", 12),
        ("implicit", "chunk_flush", 3),
        ("implicit", "ckpt_publish", 3),
        ("implicit", "barrier", 9),
    ])
    def test_kill_at_every_site(self, tmp_path, engine, site, at):
        # `at` is tuned past the seed phase so a checkpoint exists —
        # killing before the first publish is the ShardFailure test below.
        os.environ[faults.ENV_VAR] = f"{site}:kill:at={at}"
        sizes = _ENGINES[engine](str(tmp_path), nshards=2,
                                 checkpoint_dir=_ck(tmp_path),
                                 max_recoveries=3)
        assert sizes == PANCAKE5
        assert extsort.STATS["recoveries"] == 1

    @pytest.mark.parametrize("engine,site,at", [
        ("sorted", "bucket_spill", 1),
        ("sorted", "bucket_spill", 4),
        ("implicit", "oplog_append", 1),
        ("implicit", "oplog_append", 5),
    ])
    def test_torn_write_heals_without_rollback(self, tmp_path, engine,
                                               site, at):
        os.environ[faults.ENV_VAR] = f"{site}:torn:at={at}:once=0"
        sizes = _ENGINES[engine](str(tmp_path), nshards=2)
        assert sizes == PANCAKE5
        assert extsort.STATS["io_retries"] >= 1
        assert extsort.STATS["recoveries"] == 0

    @pytest.mark.parametrize("engine", ("sorted", "implicit"))
    def test_transient_storm_heals_without_rollback(self, tmp_path, engine):
        os.environ[faults.ENV_VAR] = (
            "seed=5;bucket_spill:transient:every=4:times=2:once=0;"
            "bucket_seal:transient:every=3:once=0;"
            "chunk_flush:transient:every=5:once=0;"
            "meta_write:transient:every=3:once=0;"
            "ckpt_publish:transient:every=2:once=0;"
            "oplog_append:transient:every=4:once=0")
        sizes = _ENGINES[engine](str(tmp_path), nshards=2,
                                 checkpoint_dir=_ck(tmp_path),
                                 max_recoveries=1)
        assert sizes == PANCAKE5
        assert extsort.STATS["io_retries"] > 0
        assert extsort.STATS["io_giveups"] == 0
        assert extsort.STATS["recoveries"] == 0

    @pytest.mark.parametrize("engine", ("sorted", "implicit"))
    def test_no_checkpoint_is_a_loud_shard_failure(self, tmp_path, engine):
        os.environ[faults.ENV_VAR] = "worker_level:kill:level=2"
        with pytest.raises(ShardFailure, match="no coordinated checkpoint"):
            _ENGINES[engine](str(tmp_path), nshards=2, max_recoveries=2)
        assert extsort.STATS["recoveries"] == 0

    def test_recovery_budget_exhausted_is_loud(self, tmp_path):
        os.environ[faults.ENV_VAR] = ("worker_level:kill:shard=0:level=1;"
                                      "worker_level:kill:shard=0:level=2")
        with pytest.raises(ShardFailure, match="budget is exhausted") as ei:
            _sorted_levels(str(tmp_path), nshards=2,
                           checkpoint_dir=_ck(tmp_path), max_recoveries=1)
        assert ei.value.recoveries == 1
        assert extsort.STATS["recoveries"] == 1

    def test_kill_recovers_on_pancake_6(self, tmp_path):
        want = _sorted_levels(str(tmp_path / "ref"), n=6, nshards=2)
        extsort.reset_stats()
        os.environ[faults.ENV_VAR] = "worker_level:kill:shard=1:level=3"
        sizes = _sorted_levels(str(tmp_path / "chaos"), n=6, nshards=2,
                               checkpoint_dir=_ck(tmp_path),
                               max_recoveries=2)
        assert sizes == want
        assert extsort.STATS["recoveries"] == 1


# ----------------------------------------------- in-run recovery (spawn mode)

class TestSpawnRecovery:
    """Real worker processes, real ``os._exit`` death — the acceptance
    criterion of the fault-tolerance layer."""

    def test_spawn_worker_hard_kill_recovers_sorted(self, tmp_path):
        os.environ[faults.ENV_VAR] = "worker_level:kill:shard=1:level=2"
        sizes = _sorted_levels(str(tmp_path), nshards=2, mode="spawn",
                               checkpoint_dir=_ck(tmp_path),
                               max_recoveries=2)
        assert sizes == PANCAKE5
        assert extsort.STATS["recoveries"] == 1

    @pytest.mark.skipif(ROOMY_SHARDS < 2,
                        reason="spawn implicit kill sweep runs on the "
                               "ROOMY_SHARDS CI leg")
    def test_spawn_worker_hard_kill_recovers_implicit(self, tmp_path):
        os.environ[faults.ENV_VAR] = "worker_level:kill:shard=1:level=2"
        sizes = _implicit_levels(str(tmp_path), nshards=2, mode="spawn",
                                 checkpoint_dir=_ck(tmp_path),
                                 max_recoveries=2)
        assert sizes == PANCAKE5
        assert extsort.STATS["recoveries"] == 1
