"""End-to-end behaviour tests for the whole system (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, shape_applicable
from repro.configs.inputs import input_specs
from repro.models import init_params
from repro.runtime import Request, Server, TrainSettings, train


class TestTrainingEndToEnd:
    def test_loss_decreases_minicpm(self):
        cfg = get_config("minicpm-2b", smoke=True).replace(kernels="ref")
        s = TrainSettings(batch=4, seq=32, steps=15, lr=1e-2,
                          warmup_steps=3, log_every=100)
        out = train(cfg, s, verbose=False)
        assert out["losses"][-1] < out["losses"][0]

    def test_loss_decreases_moe_and_ssm(self):
        for arch in ("granite-moe-3b-a800m", "falcon-mamba-7b"):
            cfg = get_config(arch, smoke=True).replace(kernels="ref")
            s = TrainSettings(batch=4, seq=24, steps=12, lr=5e-3,
                              warmup_steps=3, log_every=100)
            out = train(cfg, s, verbose=False)
            assert out["losses"][-1] < out["losses"][0], arch

    def test_microbatching_matches_full_batch(self):
        """grad accumulation over M microbatches == one big batch step."""
        cfg = get_config("musicgen-medium", smoke=True).replace(
            kernels="ref", dtype="float32")
        base = dict(batch=4, seq=16, steps=3, lr=1e-3, warmup_steps=0,
                    schedule="constant", log_every=100)
        out1 = train(cfg, TrainSettings(**base, num_microbatches=1),
                     verbose=False)
        out2 = train(cfg, TrainSettings(**base, num_microbatches=2),
                     verbose=False)
        np.testing.assert_allclose(out1["losses"], out2["losses"],
                                   rtol=2e-3)


class TestServing:
    def test_server_matches_reference_decode(self):
        """Continuous-batching server == hand-rolled greedy decode."""
        from repro.models import decode_step, make_cache
        cfg = get_config("minicpm-2b", smoke=True).replace(
            kernels="ref", dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompt = [5, 17, 3]
        max_new = 6

        # reference: single-sequence stepwise greedy
        caches = make_cache(cfg, 1, max_len=64)
        toks = []
        lg = None
        for t in prompt:
            lg, caches = decode_step(
                params, {"tokens": jnp.array([[t]], jnp.int32),
                         "positions": jnp.zeros((1, 1), jnp.int32)},
                caches, cfg)
        last = int(jnp.argmax(lg[0, 0]))
        toks.append(last)
        while len(toks) < max_new:
            lg, caches = decode_step(
                params, {"tokens": jnp.array([[last]], jnp.int32),
                         "positions": jnp.zeros((1, 1), jnp.int32)},
                caches, cfg)
            last = int(jnp.argmax(lg[0, 0]))
            toks.append(last)

        server = Server(cfg, params, max_batch=2, max_len=64)
        outs = server.run([Request(rid=0, prompt=prompt, max_new=max_new)])
        assert outs[0] == toks

    def test_multi_request_batching(self):
        cfg = get_config("musicgen-medium", smoke=True).replace(
            kernels="ref", dtype="float32", frontend_stub=False)
        params = init_params(cfg, jax.random.PRNGKey(1))
        server = Server(cfg, params, max_batch=2, max_len=64)
        reqs = [Request(rid=i, prompt=[1 + i, 2 + i], max_new=4)
                for i in range(4)]
        outs = server.run(reqs)
        assert len(outs) == 4
        assert all(len(v) == 4 for v in outs.values())
        assert server.stats["decode_steps"] > 0


class TestShapeMatrix:
    def test_input_specs_cover_all_cells(self):
        """Every runnable (arch × shape) produces a well-formed spec tree."""
        from repro.configs import ARCH_IDS
        n_cells = n_skips = 0
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shape in SHAPES.values():
                if not shape_applicable(shape, cfg.family):
                    n_skips += 1
                    continue
                specs = input_specs(cfg, shape)
                n_cells += 1
                if shape.kind == "train":
                    assert specs["labels"].shape == (shape.global_batch,
                                                     shape.seq_len)
                if shape.kind == "decode":
                    assert "caches" in specs
                    leaves = jax.tree.leaves(specs["caches"])
                    assert all(hasattr(l, "shape") for l in leaves)
        assert n_cells == 32 and n_skips == 8   # 40-cell matrix, 8 skips

    def test_out_of_core_dataset_feeds_training(self, tmp_path):
        """Roomy Tier-D corpus → train loop (space-limited input path)."""
        from repro.data import DiskTokenStream
        from repro.models import loss_fn
        cfg = get_config("minicpm-2b", smoke=True).replace(kernels="ref")
        d = str(tmp_path / "corpus")
        DiskTokenStream.write_corpus(d, cfg, batch=2, seq=16, n_steps=3)
        it = DiskTokenStream(d, cfg, batch=2, seq=16)
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = jax.tree.map(jnp.asarray, next(it))
        loss = loss_fn(params, batch, cfg)
        assert np.isfinite(float(loss))
