"""Pluggable bucket transports (disk/transport.py) + the consolidated
cluster/search config API (disk/config.py).

Covers the transport redesign end to end:

  * backend conformance, parametrized over ALL THREE wires (fs / tcp /
    loopback): sealed-bucket roundtrips in barrier and live mode,
    ascending-src apply order, atomic publish (unsealed traffic is
    invisible; a killed writer leaves only ignorable strays), EXACT
    overflow ``dropped`` accounting, stray cleanup, epoch isolation,
    wipe semantics, and symmetric bytes-on-wire counters,
  * wire-specific safety: torn/garbage TCP frames are discarded whole,
    node-local spool strays are swept on (re)construction, and the fs
    wire's on-disk layout stays byte-compatible in barrier mode,
  * per-key op order surviving the PIPELINED exchange (the DEL/PUT
    sequencing rule of the sharded hash table, on every backend),
  * level-count equivalence: pancake BFS on BOTH engines, for
    nshards ∈ {1, 2, 4}, across every transport × exchange discipline,
    identical to the single-process engines — plus per-shard sort/pass
    budgets unchanged from the barrier baseline,
  * the ClusterConfig/CheckpointConfig/RecoveryConfig surface: loud
    validation of conflicting settings, the warn-once deprecation shim,
    and legacy-kwarg calls producing IDENTICAL runs (level counts and
    pass ledgers) to their config-object spelling,
  * kill-one-worker recovery on the TCP wire (spawn and inline),
    recovered level counts equal to the fault-free run.

Module-level imports stay numpy-only (the test_cluster.py convention):
spawn workers re-import this module to unpickle the example generators.
"""
import math
import os
import socket
import sys

import numpy as np
import pytest

from repro.core.disk import buckets as B
from repro.core.disk import extsort, faults
from repro.core.disk import breadth_first_search, implicit_bfs
from repro.core.disk.buckets import TRANSPORT_STATS
from repro.core.disk.cluster import (ShardedDiskHashTable, ShardFailure,
                                     ShardRuntime)
from repro.core.disk.config import (CheckpointConfig, ClusterConfig,
                                    RecoveryConfig,
                                    _reset_deprecation_warnings)
from repro.core.disk.transport import (TRANSPORT_KINDS, LoopbackStore,
                                       make_transport)

sys.path.append(os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "examples"))
from pancake_bfs import GenNextNp, start_code         # noqa: E402
from pancake_bits import NeighborsNp                  # noqa: E402

ROOMY_SHARDS = int(os.environ.get("ROOMY_SHARDS", "0"))

# Pinned by test_bfs / test_cluster / test_faults: the fault-free
# pancake-5 flip-distance histogram every sweep below must land on.
PANCAKE5 = [1, 4, 12, 35, 48, 20]

EXCHANGES = ("barrier", "pipelined")


def _spec(**kw):
    spec = {"name": "x", "rec_width": 1, "rec_dtype": "int64"}
    spec.update(kw)
    return spec


def _rows(*vals):
    return np.asarray(vals, np.int64).reshape(-1, 1)


def _build_wire(kind, root, nshards=2):
    """One transport per shard, fully wired (tcp handshake included)."""
    store = LoopbackStore() if kind == "loopback" else None
    ts = [make_transport({"kind": kind, "host": "127.0.0.1"}, s, nshards,
                         root, store=store)
          for s in range(nshards)]
    if kind == "tcp":
        peers = {s: t.handshake() for s, t in enumerate(ts)}
        for t in ts:
            t.connect(peers)
    return ts


@pytest.fixture(params=TRANSPORT_KINDS)
def wire(request, tmp_path):
    ts = _build_wire(request.param, str(tmp_path))
    yield request.param, ts
    for t in ts:
        t.close()


# ========================================================= conformance

class TestTransportConformance:
    """The contracts of docs/transports.md, on every backend."""

    def test_barrier_roundtrip_ascending_src(self, wire):
        kind, (t0, t1) = wire
        spec = _spec()
        s0, s1 = t0.sender(spec), t1.sender(spec)
        s1.put([0, 0], _rows(10, 11))           # higher src seals FIRST
        s0.put([0, 1], _rows(1, 2))
        assert s1.seal(epoch=0).sum() == 0
        assert s0.seal(epoch=0).sum() == 0
        got = list(t0.recv(spec, 0, (0, 1), timeout=20))
        assert [src for src, _ in got] == [0, 1]          # ascending src
        assert got[0][1].tolist() == [[1]]
        assert got[1][1].tolist() == [[10], [11]]
        (src, rows), = t1.recv(spec, 0, (0, 1), timeout=20)
        assert src == 0 and rows.tolist() == [[2]]

    def test_live_roundtrip_and_redrain_is_empty(self, wire):
        kind, (t0, t1) = wire
        spec = _spec()
        for t in (t0, t1):
            s = t.sender(spec)
            s.put([0], _rows(100 + t.me))
            s.seal(epoch=0, publish_done=True)
        got = list(t0.recv(spec, 0, (0, 1), live=True, timeout=20))
        assert [(s, r.tolist()) for s, r in got] == [(0, [[100]]),
                                                     (1, [[101]])]
        # the epoch is consumed: a re-drain yields nothing and does NOT
        # hang (sealed/completion state outlives the payload)
        assert list(t0.recv(spec, 0, (0, 1), live=True, timeout=20)) == []

    def test_unsealed_traffic_is_invisible(self, wire):
        kind, (t0, t1) = wire
        spec = _spec()
        s1 = t1.sender(spec)
        s1.put([0], _rows(7))
        s1._spill()                 # force onto the wire's staging area
        # nothing sealed: a live recv times out instead of yielding
        with pytest.raises(TimeoutError):
            list(t0.recv(spec, 0, (1,), live=True, ordered=False,
                         timeout=0.3))

    def test_live_ordered_waits_for_ascending_src(self, wire):
        kind, (t0, t1) = wire
        spec = _spec()
        s1 = t1.sender(spec)
        s1.put([0], _rows(11))
        s1.seal(epoch=0, publish_done=True)
        # ordered: src 0 has not sealed, so src 1 must NOT be delivered
        with pytest.raises(TimeoutError):
            list(t0.recv(spec, 0, (0, 1), live=True, ordered=True,
                         timeout=0.4))
        # unordered: src 1 is available immediately
        it = t0.recv(spec, 0, (0, 1), live=True, ordered=False, timeout=20)
        src, rows = next(it)
        assert src == 1 and rows.tolist() == [[11]]
        it.close()
        s0 = t0.sender(spec)
        s0.put([0], _rows(1))
        s0.seal(epoch=0, publish_done=True)
        got = list(t0.recv(spec, 0, (0, 1), live=True, timeout=20))
        assert [(s, r.tolist()) for s, r in got] == [(0, [[1]])]

    def test_overflow_dropped_exact(self, wire):
        kind, (t0, t1) = wire
        spec = _spec(capacity=2)
        s0 = t0.sender(spec)
        # capacity is per destination per EPOCH, across multiple puts
        s0.put([0, 0, 0], _rows(1, 2, 3))
        s0.put([0, 0, 1], _rows(4, 5, 6))
        assert s0.seal(epoch=0).tolist() == [3, 0]
        (src, rows), = t0.recv(spec, 0, (0,), timeout=20)
        assert src == 0 and rows.shape[0] == 2            # exactly capacity
        (src, rows), = t1.recv(spec, 0, (0,), timeout=20)
        assert rows.tolist() == [[6]]
        # next epoch starts with a fresh budget
        s0.put([0, 0], _rows(7, 8))
        assert s0.seal(epoch=1).tolist() == [0, 0]

    def test_epoch_isolation(self, wire):
        kind, (t0, t1) = wire
        spec = _spec()
        s1 = t1.sender(spec)
        s1.put([0], _rows(1))
        s1.seal(epoch=0, publish_done=True)
        s1.put([0], _rows(2))
        s1.seal(epoch=1, publish_done=True)
        (_, rows), = t0.recv(spec, 1, (1,), live=True, timeout=20)
        assert rows.tolist() == [[2]]
        (_, rows), = t0.recv(spec, 0, (1,), live=True, timeout=20)
        assert rows.tolist() == [[1]]

    def test_killed_writer_strays_swept_sealed_survives(self, wire, tmp_path):
        kind, (t0, t1) = wire
        spec = _spec()
        dead = t1.sender(spec)
        dead.put([0], _rows(666))
        dead._spill()               # killed mid-epoch: staged, never sealed
        live = t0.sender(spec)
        live.put([0], _rows(1))
        live.seal(epoch=0)
        # a fresh transport (the restarted runtime) sweeps the strays and
        # must still deliver the sealed epoch
        if kind == "loopback":
            t1b = make_transport({"kind": kind}, 1, 2, str(tmp_path),
                                 store=t0.store)
        else:
            t1b = make_transport({"kind": kind, "host": "127.0.0.1"}, 1, 2,
                                 str(tmp_path))
        try:
            t1b.startup(fresh=False)
            (src, rows), = t0.recv(spec, 0, (0,), timeout=20)
            assert src == 0 and rows.tolist() == [[1]]
            if kind in ("fs", "tcp"):           # file-backed staging areas
                for base, _dirs, files in os.walk(str(tmp_path)):
                    assert not any(f.endswith(".tmp") for f in files), \
                        (base, files)
        finally:
            t1b.close()

    def test_wipe_discards_structure_traffic(self, wire):
        kind, (t0, t1) = wire
        spec = _spec()
        other = _spec(name="y")
        for sp in (spec, other):
            s1 = t1.sender(sp)
            s1.put([0], _rows(5))
            s1.seal(epoch=0, publish_done=True)
        for t in (t0, t1):
            t.wipe("x")
        with pytest.raises(TimeoutError):       # x's traffic is gone ...
            list(t0.recv(spec, 0, (1,), live=True, ordered=False,
                         timeout=0.3))
        (_, rows), = t0.recv(other, 0, (1,), live=True, timeout=20)
        assert rows.tolist() == [[5]]           # ... y's is untouched

    def test_bytes_on_wire_counters_symmetric(self, wire):
        kind, (t0, t1) = wire
        spec = _spec(rec_width=2)
        before = dict(TRANSPORT_STATS)
        s1 = t1.sender(spec)
        s1.put([0, 0, 1], np.arange(6, dtype=np.int64).reshape(3, 2))
        s1.seal(epoch=0, publish_done=True)
        list(t0.recv(spec, 0, (1,), live=True, timeout=20))
        list(t1.recv(spec, 0, (1,), live=True, timeout=20))
        d = {k: TRANSPORT_STATS[k] - before.get(k, 0)
             for k in TRANSPORT_STATS}
        assert d[f"{kind}_bytes_out"] == 6 * 8
        assert d[f"{kind}_bytes_out"] == d[f"{kind}_bytes_in"]
        assert d[f"{kind}_buckets_out"] == d[f"{kind}_buckets_in"] == 2
        for other in set(TRANSPORT_KINDS) - {kind}:
            assert d[f"{other}_bytes_out"] == d[f"{other}_bytes_in"] == 0


class TestMakeTransport:
    def test_loopback_needs_store(self, tmp_path):
        with pytest.raises(ValueError, match="loopback"):
            make_transport({"kind": "loopback"}, 0, 2, str(tmp_path))

    def test_unknown_kind_is_loud(self, tmp_path):
        with pytest.raises(ValueError, match="unknown transport"):
            make_transport({"kind": "carrier-pigeon"}, 0, 2, str(tmp_path))


# ==================================================== wire-specific safety

class TestTcpWire:
    def test_torn_and_garbage_frames_are_discarded_whole(self, tmp_path):
        t0, t1 = _build_wire("tcp", str(tmp_path))
        try:
            addr = t0.handshake()
            # a sender dying mid-frame: header prefix only, then the
            # connection drops — the receiver must record NOTHING
            with socket.create_connection(addr, timeout=5) as s:
                s.sendall(b"RMYB\x00")
            # a garbage stream (bad magic) is dropped whole too
            with socket.create_connection(addr, timeout=5) as s:
                s.sendall(b"NOPE" + b"\x00" * 30)
            sender = t1.sender(_spec())
            sender.put([0], _rows(42))
            sender.seal(epoch=0, publish_done=True)
            got = list(t0.recv(_spec(), 0, (1,), live=True, timeout=20))
            assert [(s_, r.tolist()) for s_, r in got] == [(1, [[42]])]
        finally:
            t0.close()
            t1.close()

    def test_spool_is_node_local_not_shared(self, tmp_path):
        t0, t1 = _build_wire("tcp", str(tmp_path))
        try:
            s0 = t0.sender(_spec())
            s0.put([1], _rows(9))
            s0._spill()
            assert os.path.isdir(os.path.join(str(tmp_path), "shard000",
                                              "_spool", "x"))
            # no shared exchange directory exists on this wire
            assert not os.path.exists(os.path.join(str(tmp_path),
                                                   "exchange"))
        finally:
            t0.close()
            t1.close()

    def test_seal_before_connect_is_loud(self, tmp_path):
        t0 = make_transport({"kind": "tcp", "host": "127.0.0.1"}, 0, 2,
                            str(tmp_path))
        try:
            s0 = t0.sender(_spec())
            s0.put([1], _rows(1))
            with pytest.raises(AssertionError, match="handshake"):
                s0.seal(epoch=0)
        finally:
            t0.close()


class TestFsWire:
    def test_barrier_layout_is_byte_compatible(self, tmp_path):
        """Barrier-mode fs transport writes EXACTLY the legacy on-disk
        protocol: epoch-stamped bucket files, no markers, readable by the
        plain buckets.py reader."""
        t0, t1 = _build_wire("fs", str(tmp_path))
        s0 = t0.sender(_spec())
        s0.put([0, 1], _rows(1, 2))
        s0.seal(epoch=3)
        exch = os.path.join(str(tmp_path), "exchange", "x")
        assert sorted(os.listdir(exch)) == ["e000003_s000_d000.bin",
                                            "e000003_s000_d001.bin"]
        (src, rows), = B.iter_incoming(exch, 1, 3, 1)
        assert src == 0 and rows.tolist() == [[2]]

    def test_pipelined_markers_land_after_data(self, tmp_path):
        t0, t1 = _build_wire("fs", str(tmp_path))
        s0 = t0.sender(_spec())
        s0.put([1], _rows(2))
        s0.seal(epoch=0, publish_done=True)
        exch = os.path.join(str(tmp_path), "exchange", "x")
        names = sorted(os.listdir(exch))
        assert "e000000_s000_d001.bin" in names
        assert "e000000_s000_d001.done" in names
        assert "e000000_s000_d000.done" in names     # empty dst: marker only


# =============================================== per-key order, pipelined

class TestPerKeyOrderPipelined:
    @pytest.mark.parametrize("transport", TRANSPORT_KINDS)
    def test_del_put_order_survives_pipelined_exchange(self, tmp_path,
                                                       transport):
        """The PR 3 sequential-op-log rule (DEL then PUT resurrects, PUT
        then DEL removes) must hold through the OVERLAPPED exchange on
        every wire — receivers consume ascending-src even while sources
        are still producing."""
        with ShardRuntime(str(tmp_path), 2, mode="inline",
                          transport=transport, exchange="pipelined") as rt:
            ht = ShardedDiskHashTable(rt, 1, 1)
            ks = np.arange(8, dtype=np.uint32).reshape(-1, 1)
            ht.insert(ks, np.full((8, 1), 10, np.int64))
            ht.sync()
            ht.remove(ks[:4])
            ht.insert(ks[:4], np.full((4, 1), 99, np.int64))
            ht.insert(ks[4:], np.full((4, 1), 77, np.int64))
            ht.remove(ks[4:])
            ht.sync()
            out, found = ht.lookup(ks)
            assert found[:4].all() and not found[4:].any()
            assert (out[:4, 0] == 99).all()
            assert ht.size() == 4


# ===================================================== engine equivalence

def _sorted_levels(wd, n=5, nshards=2, mode="inline", transport="fs",
                   exchange="barrier", **kw):
    rt = ShardRuntime(os.path.join(wd, "rt"), nshards, mode=mode,
                      transport=transport, exchange=exchange)
    try:
        sizes, vis = breadth_first_search(
            os.path.join(wd, "bfs"), np.array([[start_code(n)]], np.uint32),
            GenNextNp(n), width=1, chunk_rows=1 << 10, runtime=rt, **kw)
        vis.destroy()
    finally:
        rt.shutdown()
    return sizes


def _implicit_levels(wd, n=5, nshards=2, mode="inline", transport="fs",
                     exchange="barrier", **kw):
    from repro.core import ranking as R
    total = math.factorial(n)
    start = int(R.rank_np(np.arange(n)[None, :])[0])
    rt = ShardRuntime(os.path.join(wd, "rt"), nshards, mode=mode,
                      transport=transport, exchange=exchange)
    try:
        sizes, bits = implicit_bfs(
            os.path.join(wd, "bfs"), total, [start], NeighborsNp(n),
            chunk_elems=1 << 5, runtime=rt, **kw)
        bits.destroy()
    finally:
        rt.shutdown()
    return sizes


_ENGINES = {"sorted": _sorted_levels, "implicit": _implicit_levels}


class TestEquivalenceInline:
    """Acceptance sweep: level counts identical to single-process for
    every transport × exchange × shard count, on both engines."""

    @pytest.mark.parametrize("engine", ("sorted", "implicit"))
    @pytest.mark.parametrize("exchange", EXCHANGES)
    @pytest.mark.parametrize("transport", TRANSPORT_KINDS)
    @pytest.mark.parametrize("nshards", (1, 2, 4))
    def test_pancake5_levels_match(self, tmp_path, engine, transport,
                                   exchange, nshards):
        sizes = _ENGINES[engine](str(tmp_path), nshards=nshards,
                                 transport=transport, exchange=exchange)
        assert sizes == PANCAKE5

    @pytest.mark.parametrize("engine", ("sorted", "implicit"))
    def test_pipelined_budgets_match_barrier_baseline(self, tmp_path,
                                                      engine):
        """Overlapping the exchange must not change WHAT work is done:
        rows sorted and per-shard pass ledgers are identical to the
        barrier discipline."""
        budget_keys = ("rows_sorted", "sort_passes", "rw_passes",
                       "read_passes")
        extsort.reset_stats()
        _ENGINES[engine](os.path.join(str(tmp_path), "bar"),
                         exchange="barrier")
        barrier = {k: extsort.STATS[k] for k in budget_keys}
        extsort.reset_stats()
        _ENGINES[engine](os.path.join(str(tmp_path), "pipe"),
                         exchange="pipelined")
        pipelined = {k: extsort.STATS[k] for k in budget_keys}
        assert pipelined == barrier


class TestEquivalenceSpawn:
    """Real worker processes.  A TCP cell stays always-on (it is the
    no-shared-scratch acceptance row); the full spawn sweep rides the
    ROOMY_SHARDS CI leg like the rest of the spawn matrix."""

    def test_tcp_pipelined_spawn_sorted(self, tmp_path):
        sizes = _sorted_levels(str(tmp_path), nshards=2, mode="spawn",
                               transport="tcp", exchange="pipelined")
        assert sizes == PANCAKE5

    @pytest.mark.skipif(ROOMY_SHARDS < 2,
                        reason="full spawn sweep runs on the ROOMY_SHARDS "
                               "CI leg")
    @pytest.mark.parametrize("engine", ("sorted", "implicit"))
    @pytest.mark.parametrize("exchange", EXCHANGES)
    @pytest.mark.parametrize("transport", ("fs", "tcp"))
    def test_spawn_sweep(self, tmp_path, engine, transport, exchange):
        sizes = _ENGINES[engine](str(tmp_path), nshards=ROOMY_SHARDS,
                                 mode="spawn", transport=transport,
                                 exchange=exchange)
        assert sizes == PANCAKE5


# ========================================================== config API

def _run_sorted(wd, **kw):
    sizes, vis = breadth_first_search(
        wd, np.array([[start_code(5)]], np.uint32), GenNextNp(5),
        width=1, chunk_rows=1 << 10, **kw)
    vis.destroy()
    return sizes


class TestConfigValidation:
    """ONE shared checker: every conflicting cluster setting dies loudly
    in the config layer, not deep inside an engine."""

    def test_bad_transport_kind(self):
        with pytest.raises(ValueError, match="transport"):
            ClusterConfig(transport="smoke-signal").validate()

    def test_bad_exchange(self):
        with pytest.raises(ValueError, match="exchange"):
            ClusterConfig(exchange="vibes").validate()

    def test_bad_mode(self):
        with pytest.raises(ValueError, match="mode"):
            ClusterConfig(mode="fork").validate()

    def test_nshards_floor(self):
        with pytest.raises(ValueError, match="nshards"):
            ClusterConfig(nshards=0).validate()

    def test_loopback_needs_inline(self):
        with pytest.raises(ValueError, match="loopback"):
            ClusterConfig(transport="loopback", mode="spawn").validate()
        ClusterConfig(transport="loopback", mode="inline").validate()

    def test_adopted_runtime_shard_conflict(self, tmp_path):
        with ShardRuntime(str(tmp_path), 2, mode="inline") as rt:
            with pytest.raises(ValueError, match="nshards"):
                ClusterConfig(runtime=rt, nshards=4).validate()
            ClusterConfig(runtime=rt, nshards=2).validate()   # consistent OK

    def test_adopted_runtime_transport_conflict(self, tmp_path):
        with ShardRuntime(str(tmp_path), 2, mode="inline",
                          transport="loopback") as rt:
            with pytest.raises(ValueError, match="transport"):
                ClusterConfig(runtime=rt, transport="tcp").validate()

    def test_resume_needs_dir(self):
        with pytest.raises(ValueError, match="resume"):
            CheckpointConfig(resume=True).validate()

    def test_checkpoint_every_floor(self):
        with pytest.raises(ValueError, match="every"):
            CheckpointConfig(dir="/tmp/x", every=0).validate()

    def test_negative_recovery_budget(self):
        with pytest.raises(ValueError, match="max_recoveries"):
            RecoveryConfig(max_recoveries=-1).validate()

    def test_unfused_cannot_shard(self, tmp_path):
        with pytest.raises(ValueError, match="fused"):
            _run_sorted(str(tmp_path), fused=False,
                        cluster=ClusterConfig(nshards=2))

    def test_config_plus_legacy_kwarg_is_loud(self, tmp_path):
        with pytest.raises(ValueError, match="pick one spelling"):
            _run_sorted(str(tmp_path), cluster=ClusterConfig(nshards=2),
                        nshards=2)

    def test_default_exchange_resolves_to_barrier(self):
        assert ClusterConfig().resolved_exchange() == "barrier"
        assert not ClusterConfig().sharded
        # an explicit wire or discipline opts into the cluster runtime
        assert ClusterConfig(transport="loopback", mode="inline").sharded
        assert ClusterConfig(exchange="pipelined").sharded


class TestDeprecationShim:
    @pytest.fixture(autouse=True)
    def _fresh_warnings(self):
        _reset_deprecation_warnings()
        yield
        _reset_deprecation_warnings()

    def test_legacy_kwargs_warn_once_and_run_identically(self, tmp_path):
        import warnings
        extsort.reset_stats()
        new = _run_sorted(os.path.join(str(tmp_path), "new"),
                          cluster=ClusterConfig(nshards=2, mode="inline"))
        new_stats = dict(extsort.STATS)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            extsort.reset_stats()
            old = _run_sorted(os.path.join(str(tmp_path), "old"),
                              nshards=2, shard_mode="inline")
            old_stats = dict(extsort.STATS)
            dep = [x for x in w if issubclass(x.category,
                                              DeprecationWarning)]
        assert len(dep) == 1
        assert "nshards" in str(dep[0].message)
        assert old == new == PANCAKE5
        # identical runs, ledger for ledger — the shim maps, never changes
        assert old_stats == new_stats
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            _run_sorted(os.path.join(str(tmp_path), "old2"),
                        nshards=2, shard_mode="inline")
            assert not [x for x in w
                        if issubclass(x.category, DeprecationWarning)]

    def test_checkpoint_config_equals_legacy_kwargs(self, tmp_path):
        ck_new = os.path.join(str(tmp_path), "ck_new")
        ck_old = os.path.join(str(tmp_path), "ck_old")
        new = _run_sorted(os.path.join(str(tmp_path), "new"),
                          cluster=ClusterConfig(nshards=2, mode="inline"),
                          checkpoint=CheckpointConfig(dir=ck_new, every=2),
                          recovery=RecoveryConfig(max_recoveries=1))
        old = _run_sorted(os.path.join(str(tmp_path), "old"),
                          nshards=2, shard_mode="inline",
                          checkpoint_dir=ck_old, checkpoint_every=2,
                          max_recoveries=1)
        assert old == new == PANCAKE5
        assert sorted(os.listdir(ck_new)) == sorted(os.listdir(ck_old))

    def test_transport_rides_only_the_config_spelling(self, tmp_path):
        sizes = _run_sorted(
            str(tmp_path),
            cluster=ClusterConfig(nshards=2, mode="inline",
                                  transport="loopback",
                                  exchange="pipelined"))
        assert sizes == PANCAKE5


# =============================================== kill recovery on the wire

class TestRecoveryOnTcp:
    """The self-healing layer must survive a wire with no shared scratch:
    killed workers respawn, re-handshake, and replay to the exact
    fault-free level counts."""

    @pytest.fixture(autouse=True)
    def _clean_faults(self):
        saved = os.environ.pop(faults.ENV_VAR, None)
        faults.uninstall()
        extsort.reset_stats()
        yield
        faults.uninstall()
        if saved is None:
            os.environ.pop(faults.ENV_VAR, None)
        else:
            os.environ[faults.ENV_VAR] = saved

    def test_spawn_hard_kill_recovers_on_tcp(self, tmp_path):
        os.environ[faults.ENV_VAR] = "worker_level:kill:shard=1:level=2"
        sizes = _sorted_levels(str(tmp_path), nshards=2, mode="spawn",
                               transport="tcp",
                               checkpoint_dir=str(tmp_path / "ck"),
                               max_recoveries=2)
        assert sizes == PANCAKE5
        assert extsort.STATS["recoveries"] == 1

    @pytest.mark.parametrize("engine", ("sorted", "implicit"))
    def test_inline_kill_recovers_on_tcp_pipelined(self, tmp_path, engine):
        os.environ[faults.ENV_VAR] = "worker_level:kill:shard=1:level=2"
        sizes = _ENGINES[engine](str(tmp_path), nshards=2, mode="inline",
                                 transport="tcp", exchange="pipelined",
                                 checkpoint_dir=str(tmp_path / "ck"),
                                 max_recoveries=2)
        assert sizes == PANCAKE5
        assert extsort.STATS["recoveries"] == 1

    def test_no_checkpoint_still_fails_loud_on_tcp(self, tmp_path):
        os.environ[faults.ENV_VAR] = "worker_level:kill:level=2"
        with pytest.raises(ShardFailure, match="no coordinated checkpoint"):
            _sorted_levels(str(tmp_path), nshards=2, transport="tcp",
                           max_recoveries=2)
