"""Distance-oracle serving tier (disk/oracle.py) — PR-9 acceptance.

  * publish → serve → verify chain: a completed implicit-BFS run is
    sealed and EVERY distance and EVERY reconstructed path is checked
    against an independent in-RAM reference BFS on pancake n ≤ 7, all
    ranks, both routing modes (nshards ∈ {1, 2}); the histogram is
    anchored to the sorted-list engine at n = 6,
  * publish seals only runs it can reproduce: a wrong expected histogram
    refuses with OracleError,
  * artifact integrity: tampered chunks, rewritten METAs, manifests
    naming missing versions, and format mismatches all raise OracleError
    loudly — wrong data is never served,
  * versioning: immutable re-publish bumps the version, the manifest
    points at the newest, older sealed versions stay openable, and a
    deleted manifest crash-adopts the newest sealed version,
  * LRU chunk cache: recency eviction order, exact hit/miss/evict/byte
    counters in the ``oracle`` obs namespace, byte-budget enforcement
    (resident never above budget, oversized chunks served uncached), and
    correct results from concurrent reader threads under eviction
    pressure (fixed seed),
  * zero impact on search: an untraced implicit_bfs books nothing in the
    ``oracle`` namespace.
"""
import json
import math
import os
import sys
import threading

import numpy as np
import pytest

from repro.core.disk import implicit_bfs
from repro.core.disk.oracle import (STATS, DistanceOracle, LRUChunkCache,
                                    OracleError, ShardedOracle,
                                    publish_oracle, reset_stats)

sys.path.append(os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "examples"))

from pancake_bits import neighbors_np, sorted_list_levels


def _start_rank(n):
    import repro.core.ranking as R
    return int(R.rank_np(np.arange(n)[None, :])[0])


def _ram_distances(n, total, start):
    """Independent in-RAM reference BFS (no disk engine involved)."""
    gen = neighbors_np(n)
    dist = np.full(total, -1, np.int64)
    dist[start] = 0
    frontier = np.asarray([start], np.int64)
    d = 0
    while frontier.size:
        nb = np.unique(gen(frontier).reshape(-1))
        nb = nb[dist[nb] < 0]
        d += 1
        dist[nb] = d
        frontier = nb
    return dist


@pytest.fixture(scope="module")
def published6(tmp_path_factory):
    """Search → publish chain at n=6 (720 states, 15 chunks)."""
    n = 6
    total = math.factorial(n)
    start = _start_rank(n)
    wd = tmp_path_factory.mktemp("search6")
    sizes, bits = implicit_bfs(str(wd), total, [start], neighbors_np(n),
                               chunk_elems=256)
    bits.destroy()
    art = str(tmp_path_factory.mktemp("art6") / "oracle")
    meta = publish_oracle(art, total, [start], neighbors_np(n),
                          level_sizes=sizes, chunk_elems=48,
                          codec={"space": "pancake", "n": n})
    return {"n": n, "total": total, "start": start, "sizes": sizes,
            "art": art, "meta": meta,
            "ref": _ram_distances(n, total, start)}


class TestPublish:

    def test_meta_shape_and_manifest(self, published6):
        p = published6
        meta = p["meta"]
        assert meta["version"] == 1
        assert meta["level_sizes"] == p["sizes"]
        assert meta["n_chunks"] == -(-p["total"] // 48)
        assert len(meta["chunk_sha256"]) == meta["n_chunks"]
        with open(os.path.join(p["art"], "ORACLE")) as f:
            manifest = json.load(f)
        assert manifest["version"] == 1 and manifest["format"] == 1
        assert os.path.isdir(os.path.join(p["art"], "v000001"))

    def test_histogram_anchored_to_sorted_engine(self, published6):
        # the chain back to the paper's other engine: implicit sizes ==
        # published level_sizes == sorted-list BFS level counts
        assert published6["meta"]["level_sizes"] == sorted_list_levels(6)

    def test_refuses_wrong_histogram(self, published6, tmp_path):
        p = published6
        bad = list(p["sizes"])
        bad[2] += 1
        with pytest.raises(OracleError, match="refusing to publish"):
            publish_oracle(str(tmp_path / "bad"), p["total"], [p["start"]],
                           neighbors_np(p["n"]), level_sizes=bad,
                           chunk_elems=48)
        assert not os.path.exists(str(tmp_path / "bad" / "ORACLE"))

    def test_refuses_wrong_level_count(self, published6, tmp_path):
        p = published6
        with pytest.raises(OracleError):
            publish_oracle(str(tmp_path / "bad2"), p["total"], [p["start"]],
                           neighbors_np(p["n"]),
                           level_sizes=p["sizes"] + [5], chunk_elems=48)

    def test_republish_bumps_version_keeps_old(self, published6, tmp_path):
        p = published6
        art = str(tmp_path / "vv")
        for want in (1, 2):
            meta = publish_oracle(art, p["total"], [p["start"]],
                                  neighbors_np(p["n"]),
                                  level_sizes=p["sizes"], chunk_elems=96)
            assert meta["version"] == want
        assert os.path.isdir(os.path.join(art, "v000001"))
        with DistanceOracle(art, cache_bytes=1 << 20) as orc:
            assert orc.version == 2
        with DistanceOracle(art, cache_bytes=1 << 20, version=1) as orc:
            assert (orc.codes(np.arange(p["total"]))
                    == (p["ref"] % 3 + 1)).all()


class TestServeCorrectness:
    """Every distance and every path, all ranks, both routing modes."""

    @pytest.mark.parametrize("nshards", [1, 2])
    def test_all_distances_n6(self, published6, nshards):
        p = published6
        gen = neighbors_np(p["n"])
        if nshards == 1:
            orc = DistanceOracle(p["art"], cache_bytes=1 << 12,
                                 gen_neighbors=gen)
        else:
            orc = ShardedOracle(p["art"], nshards, cache_bytes=1 << 12,
                                gen_neighbors=gen)
        with orc:
            got = orc.lookup(np.arange(p["total"]))
            assert (got == p["ref"]).all()
            assert np.bincount(got).tolist() == p["sizes"]

    @pytest.mark.parametrize("nshards", [1, 2])
    def test_all_paths_n6(self, published6, nshards):
        p = published6
        gen = neighbors_np(p["n"])
        cls = (DistanceOracle if nshards == 1
               else lambda a, **kw: ShardedOracle(a, nshards, **kw))
        with cls(p["art"], cache_bytes=1 << 14, gen_neighbors=gen) as orc:
            ranks = np.arange(p["total"], dtype=np.int64)
            dist, chains = orc.paths(ranks)
            assert (dist == p["ref"]).all()
            for r, dv, ch in zip(ranks, dist, chains):
                assert len(ch) == dv + 1
                assert ch[0] == r and ch[-1] == p["start"]
                # each hop is a real edge one level closer to the start
                assert (p["ref"][ch] == np.arange(dv, -1, -1)).all()
                if dv > 0:
                    nbrs = gen(ch[:-1])
                    assert (nbrs == ch[1:, None]).any(axis=1).all()

    def test_all_distances_n7_both_modes(self, tmp_path):
        # the acceptance bound: n = 7, all 5040 ranks, nshards ∈ {1, 2}
        n, total = 7, math.factorial(7)
        start = _start_rank(n)
        gen = neighbors_np(n)
        ref = _ram_distances(n, total, start)
        art = str(tmp_path / "art7")
        meta = publish_oracle(art, total, [start], gen,
                              level_sizes=np.bincount(ref).tolist(),
                              chunk_elems=256)
        assert len(meta["level_sizes"]) - 1 == 8  # pancake number P7
        for nshards in (1, 2):
            cls = (DistanceOracle if nshards == 1
                   else lambda a, **kw: ShardedOracle(a, nshards, **kw))
            with cls(art, cache_bytes=1 << 12, gen_neighbors=gen) as orc:
                dist, chains = orc.paths(np.arange(total, dtype=np.int64))
                assert (dist == ref).all()
                for r, dv, ch in zip(range(total), dist, chains):
                    assert len(ch) == dv + 1 and ch[0] == r
                    assert (ref[ch] == np.arange(dv, -1, -1)).all()

    def test_unreached_states_get_minus_one(self, tmp_path):
        # a 2-regular ring with an unreachable tail half
        ring = 16

        def gen(idx):
            idx = np.asarray(idx, np.int64)
            return np.stack([(idx - 1) % ring, (idx + 1) % ring], axis=1)
        total = 32                     # states ring..31 are unreachable
        sizes = [1] + [2] * 7 + [1]
        art = str(tmp_path / "ring")
        publish_oracle(art, total, [0], gen, level_sizes=sizes,
                       chunk_elems=8)
        with DistanceOracle(art, cache_bytes=1 << 12,
                            gen_neighbors=gen) as orc:
            got = orc.lookup(np.arange(total))
            want = np.minimum(np.arange(ring), ring - np.arange(ring))
            assert (got[:ring] == want).all()
            assert (got[ring:] == -1).all()
            d, chains = orc.paths(np.asarray([ring + 3]))
            assert d[0] == -1 and list(chains[0]) == [ring + 3]

    def test_rank_out_of_range_raises(self, published6):
        with DistanceOracle(published6["art"], cache_bytes=1 << 12) as orc:
            with pytest.raises(ValueError):
                orc.codes(np.asarray([published6["total"]]))
            with pytest.raises(ValueError):
                orc.codes(np.asarray([-1]))


class TestIntegrity:
    """Tamper / version-mismatch → loud OracleError, never wrong data."""

    def _republish(self, p, tmp_path, name="t"):
        art = str(tmp_path / name)
        publish_oracle(art, p["total"], [p["start"]],
                       neighbors_np(p["n"]), level_sizes=p["sizes"],
                       chunk_elems=48)
        return art

    def test_tampered_chunk_never_serves(self, published6, tmp_path):
        art = self._republish(published6, tmp_path)
        chunk = os.path.join(art, "v000001", "b000003.npy")
        raw = bytearray(open(chunk, "rb").read())
        raw[-1] ^= 0xFF
        open(chunk, "wb").write(bytes(raw))
        orc = DistanceOracle(art, cache_bytes=1 << 20)
        with pytest.raises(OracleError, match="sha256"):
            orc.codes(np.arange(published6["total"]))

    def test_rewritten_meta_detected(self, published6, tmp_path):
        art = self._republish(published6, tmp_path)
        mpath = os.path.join(art, "v000001", "META.json")
        meta = json.load(open(mpath))
        meta["level_sizes"][0] = 7
        json.dump(meta, open(mpath, "w"), sort_keys=True)
        with pytest.raises(OracleError, match="fingerprint"):
            DistanceOracle(art, cache_bytes=1 << 20)

    def test_manifest_names_missing_version(self, published6, tmp_path):
        art = self._republish(published6, tmp_path)
        with open(os.path.join(art, "ORACLE"), "w") as f:
            json.dump({"format": 1, "version": 9, "meta_sha256": "x"}, f)
        with pytest.raises(OracleError, match="no such sealed"):
            DistanceOracle(art)

    def test_format_mismatch(self, published6, tmp_path):
        art = self._republish(published6, tmp_path)
        with open(os.path.join(art, "ORACLE"), "w") as f:
            json.dump({"format": 99, "version": 1}, f)
        with pytest.raises(OracleError, match="format"):
            DistanceOracle(art)
        # ... and a future META format is refused even via fallback
        os.remove(os.path.join(art, "ORACLE"))
        mpath = os.path.join(art, "v000001", "META.json")
        meta = json.load(open(mpath))
        meta["format"] = 99
        json.dump(meta, open(mpath, "w"), sort_keys=True)
        with pytest.raises(OracleError, match="format"):
            DistanceOracle(art)

    def test_corrupt_manifest_raises(self, published6, tmp_path):
        art = self._republish(published6, tmp_path)
        open(os.path.join(art, "ORACLE"), "w").write("{truncated")
        with pytest.raises(OracleError, match="corrupt"):
            DistanceOracle(art)

    def test_missing_manifest_adopts_newest_sealed(self, published6,
                                                   tmp_path):
        # crash between seal and manifest write: newest sealed wins
        p = published6
        art = self._republish(p, tmp_path)
        os.remove(os.path.join(art, "ORACLE"))
        with DistanceOracle(art, cache_bytes=1 << 20) as orc:
            assert orc.version == 1
            assert (orc.codes(np.arange(p["total"]))
                    == (p["ref"] % 3 + 1)).all()

    def test_empty_root_raises(self, tmp_path):
        with pytest.raises(OracleError):
            DistanceOracle(str(tmp_path / "nothing"))
        os.makedirs(str(tmp_path / "empty"))
        with pytest.raises(OracleError, match="no sealed"):
            DistanceOracle(str(tmp_path / "empty"))


class TestLRUCache:

    @staticmethod
    def _loader(nbytes=10):
        def load(key):
            return np.full(nbytes, key % 251, np.uint8)
        return load

    def test_eviction_order_is_recency(self):
        reset_stats()
        cache = LRUChunkCache(30, self._loader(10))     # holds 3 chunks
        for k in (0, 1, 2):
            cache.get(k)
        assert cache.keys() == [0, 1, 2]
        cache.get(0)                                     # refresh 0
        assert cache.keys() == [1, 2, 0]
        cache.get(3)                                     # evicts LRU = 1
        assert cache.keys() == [2, 0, 3]
        cache.get(1)                                     # evicts LRU = 2
        assert cache.keys() == [0, 3, 1]

    def test_exact_counters(self):
        reset_stats()
        cache = LRUChunkCache(30, self._loader(10))
        for k in (0, 1, 2):                              # 3 cold misses
            cache.get(k)
        for k in (0, 1, 2):                              # 3 hits
            cache.get(k)
        cache.get(3)                                     # miss + eviction
        cache.get(0)                                     # miss (was evicted)
        assert STATS["hits"] == 3
        assert STATS["misses"] == 5
        assert STATS["chunk_loads"] == 5
        assert STATS["evictions"] == 2
        assert STATS["bytes_read"] == 50
        assert STATS["resident_bytes"] == 30
        assert STATS["resident_peak"] == 30

    def test_budget_enforced_and_oversized_uncached(self):
        reset_stats()
        cache = LRUChunkCache(25, self._loader(10))      # holds 2 of 10B
        for k in range(7):
            arr = cache.get(k)
            assert arr.nbytes == 10
            assert cache.resident <= 25
            assert STATS["resident_bytes"] <= 25
        big_cache = LRUChunkCache(5, self._loader(10))   # chunk > budget
        arr = big_cache.get(0)
        assert arr.nbytes == 10 and big_cache.resident == 0
        assert big_cache.keys() == []                    # served uncached
        assert STATS["resident_peak"] <= 25

    def test_threaded_readers_under_eviction_pressure(self, published6):
        # fixed-seed stress: 8 threads hammer a cache holding ~2 of 15
        # chunks; every returned distance code must still be exact, and
        # the counters must balance exactly when the dust settles.
        p = published6
        reset_stats()
        orc = DistanceOracle(p["art"], cache_bytes=40)   # 48-elem chunks
        want_codes = (p["ref"] % 3 + 1).astype(np.uint8)
        errors = []

        def reader(seed):
            rng = np.random.default_rng(seed)
            try:
                for _ in range(60):
                    ranks = rng.integers(0, p["total"], 64).astype(np.int64)
                    got = orc.codes(ranks)
                    if not (got == want_codes[ranks]).all():
                        raise AssertionError("wrong code under pressure")
            except BaseException as e:
                errors.append(e)
        threads = [threading.Thread(target=reader, args=(s,))
                   for s in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        # exact accounting even under contention: every miss loaded,
        # every lookup/batch booked, residency inside the budget
        assert STATS["lookups"] == 8 * 60 * 64
        assert STATS["batches"] == 8 * 60
        assert STATS["misses"] == STATS["chunk_loads"]
        assert STATS["misses"] > 0 and STATS["evictions"] > 0
        assert STATS["resident_peak"] <= 40
        assert STATS["resident_bytes"] == orc.cache.resident <= 40
        orc.close()
        assert STATS["resident_bytes"] == 0

    def test_untraced_search_books_nothing(self, tmp_path):
        reset_stats()
        sizes, bits = implicit_bfs(str(tmp_path), 24, [0], neighbors_np(4),
                                   chunk_elems=8)
        bits.destroy()
        assert sum(sizes) == 24
        assert all(v == 0 for v in STATS.values()), STATS
