"""Optimizer substrate: AdamW math, schedules, compression error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Shim: @given tests skip individually when hypothesis is absent; the
# plain oracle tests in this module still run (see _hypothesis_compat).
from _hypothesis_compat import given, settings, st

from repro import optim
from repro.optim import compress, schedule


class TestAdamW:
    def test_matches_reference_math(self):
        """One step against a hand-rolled numpy AdamW."""
        p = {"w": jnp.array([1.0, -2.0, 3.0])}
        g = {"w": jnp.array([0.1, 0.2, -0.3])}
        st_ = optim.init(p)
        lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
        new_p, new_st, gnorm = optim.update(
            g, st_, p, lr=lr, b1=b1, b2=b2, eps=eps, weight_decay=wd,
            clip_norm=None)
        gn = np.array([0.1, 0.2, -0.3])
        m = (1 - b1) * gn
        v = (1 - b2) * gn ** 2
        mh = m / (1 - b1)
        vh = v / (1 - b2)
        want = np.array([1.0, -2.0, 3.0]) - lr * (
            mh / (np.sqrt(vh) + eps) + wd * np.array([1.0, -2.0, 3.0]))
        np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-6)
        assert int(new_st.step) == 1

    def test_clipping(self):
        p = {"w": jnp.zeros(4)}
        g = {"w": jnp.full((4,), 10.0)}        # norm 20
        _, _, gnorm = optim.update(g, optim.init(p), p, lr=0.0,
                                   clip_norm=1.0, weight_decay=0.0)
        assert abs(float(gnorm) - 20.0) < 1e-4

    def test_quadratic_convergence(self):
        p = {"w": jnp.array([5.0])}
        st_ = optim.init(p)
        for _ in range(200):
            g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
            p, st_, _ = optim.update(g, st_, p, lr=0.1, weight_decay=0.0)
        assert abs(float(p["w"][0])) < 0.1


class TestSchedules:
    def test_wsd_phases(self):
        lr = schedule.wsd(1.0, warmup_steps=10, stable_steps=20,
                          decay_steps=10, final_ratio=0.1)
        assert float(lr(0)) == 0.0
        assert abs(float(lr(5)) - 0.5) < 1e-6           # warmup
        assert abs(float(lr(15)) - 1.0) < 1e-6          # stable
        assert abs(float(lr(25)) - 1.0) < 1e-6
        assert abs(float(lr(40)) - 0.1) < 1e-6          # decayed
        assert abs(float(lr(100)) - 0.1) < 1e-6         # floor

    def test_cosine_endpoints(self):
        lr = schedule.cosine(1.0, warmup_steps=10, total_steps=110,
                             final_ratio=0.1)
        assert abs(float(lr(10)) - 1.0) < 1e-5
        assert abs(float(lr(110)) - 0.1) < 1e-5


class TestCompression:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_int8_error_feedback_closes(self, seed):
        """codec(x) + residual == x exactly (the error-feedback identity)."""
        g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (300,)) * 3}
        msg, res = compress.int8_compress(g, None)
        deq = compress.int8_decompress(msg, g)
        np.testing.assert_allclose(
            np.asarray(deq["w"] + res["w"]), np.asarray(g["w"]),
            rtol=1e-5, atol=1e-6)

    def test_int8_residual_accumulates_to_exact(self):
        """Constant grad: mean of k compressed steps → true grad (EF)."""
        g = {"w": jnp.array([0.001, 1.0, -0.5, 0.0003] * 64)}
        res = None
        total = jnp.zeros_like(g["w"])
        k = 50
        for _ in range(k):
            msg, res = compress.int8_compress(g, res)
            total = total + compress.int8_decompress(msg, g)["w"]
        np.testing.assert_allclose(np.asarray(total / k),
                                   np.asarray(g["w"]), atol=1e-4)

    def test_topk_keeps_largest(self):
        g = {"w": jnp.array([0.1, -5.0, 0.2, 4.0, 0.01] * 20)}
        msg, res = compress.topk_compress(g, None, density=0.4)
        deq = compress.topk_decompress(msg, g)
        # top-40% = the ±5/±4 entries
        kept = np.asarray(deq["w"]) != 0
        assert kept.sum() == 40
        np.testing.assert_allclose(
            np.asarray(deq["w"] + res["w"]), np.asarray(g["w"]),
            rtol=1e-5, atol=1e-6)

    def test_wire_bytes_reduction(self):
        g = {"w": jnp.zeros((1024,), jnp.float32)}
        msg, _ = compress.int8_compress(g, None)
        assert compress.wire_bytes(msg) < 1024 * 4 / 3   # >3× reduction
