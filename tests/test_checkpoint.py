"""Checkpoint manager: atomic roundtrip, async writer, GC, elastic restore
across device counts (the 1000-node elasticity story, DESIGN.md §8)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": jnp.asarray(3.5)}}


class TestSaveRestore:
    def test_roundtrip(self, tmp_path):
        t = tree()
        save(str(tmp_path), 7, t)
        assert latest_step(str(tmp_path)) == 7
        got = restore(str(tmp_path), 7, t)
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_no_tmp_left(self, tmp_path):
        save(str(tmp_path), 1, tree())
        entries = os.listdir(tmp_path)
        assert "step_00000001" in entries
        assert not any(e.endswith(".tmp") for e in entries)

    def test_gc_keeps_last_three(self, tmp_path):
        for s in range(6):
            save(str(tmp_path), s, tree())
        steps = sorted(d for d in os.listdir(tmp_path)
                       if d.startswith("step_"))
        assert len(steps) == 3
        assert latest_step(str(tmp_path)) == 5

    def test_async_checkpointer(self, tmp_path):
        ck = AsyncCheckpointer(str(tmp_path))
        for s in (1, 2):
            ck.save(s, tree())
        ck.wait()
        assert latest_step(str(tmp_path)) == 2
        ck.close()


class TestElasticRestore:
    """Save under one device count, restore under another (subprocess with
    8 fake devices writes; this 1-device process restores — and the other
    direction via sharded placement in the subprocess)."""

    def test_restore_from_8dev_shards(self, tmp_path, multidev):
        multidev(f"""
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.checkpoint import save
            mesh = jax.make_mesh((8,), ("data",),
                                 axis_types=(jax.sharding.AxisType.Auto,))
            x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                               NamedSharding(mesh, P("data")))
            save({str(tmp_path)!r}, 3, {{"x": x}})
        """)
        got = restore(str(tmp_path), 3,
                      {"x": jnp.zeros((8, 8))})
        np.testing.assert_array_equal(np.asarray(got["x"]),
                                      np.arange(64.0).reshape(8, 8))

    def test_restore_onto_different_mesh(self, tmp_path, multidev):
        save(str(tmp_path), 1, {"x": jnp.arange(32.0).reshape(8, 4)})
        multidev(f"""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.checkpoint import restore
            mesh = jax.make_mesh((4,), ("data",),
                                 axis_types=(jax.sharding.AxisType.Auto,))
            sh = {{"x": NamedSharding(mesh, P("data"))}}
            got = restore({str(tmp_path)!r}, 1,
                          {{"x": jnp.zeros((8, 4))}}, shardings=sh)
            assert got["x"].sharding.num_devices == 4
            np.testing.assert_array_equal(np.asarray(got["x"]),
                                          np.arange(32.0).reshape(8, 4))
            print("elastic ok")
        """, n_devices=4)


class TestFaultToleranceLoop:
    def test_crash_restore_replay_matches_uninterrupted(self, tmp_path):
        """Train 10 steps with an injected crash at step 7 + checkpoint
        every 3 → final losses must match an uninterrupted run (replay
        determinism, DESIGN.md §8)."""
        from repro.configs import get_config
        from repro.runtime import FaultInjector, TrainSettings, train

        cfg = get_config("musicgen-medium", smoke=True).replace(
            kernels="ref")
        base = dict(batch=2, seq=16, steps=10, lr=1e-3, warmup_steps=2,
                    log_every=100)
        s1 = TrainSettings(**base, ckpt_every=3,
                           ckpt_dir=str(tmp_path / "a"))
        out1 = train(cfg, s1, fault=FaultInjector(fault_step=7),
                     verbose=False)
        assert out1["restarts"] == 1
        s2 = TrainSettings(**base, ckpt_every=0,
                           ckpt_dir=str(tmp_path / "b"))
        out2 = train(cfg, s2, verbose=False)
        np.testing.assert_allclose(out1["losses"][-1], out2["losses"][-1],
                                   rtol=1e-5)


class TestWatchdog:
    def test_straggler_detection_and_evict(self):
        from repro.runtime import StragglerWatchdog
        wd = StragglerWatchdog(warmup_steps=2, strikes_to_evict=2,
                               threshold=2.0)
        verdicts = [wd.observe(i, 0.1) for i in range(5)]     # settle
        assert verdicts[-1] == "ok"
        assert wd.observe(5, 0.5) == "slow"
        assert wd.observe(6, 0.5) == "evict"
        assert wd.events                                       # logged
        # slow steps must not poison the EWMA
        assert abs(wd.ewma - 0.1) < 0.02
