"""Hypothesis import shim: degrade @given tests to individual skips.

A module-level ``pytest.importorskip("hypothesis")`` would skip entire
modules — dropping the plain oracle tests that live alongside the
property tests. Importing ``given``/``settings``/``st`` from here keeps
those running: with hypothesis installed this re-exports the real thing;
without it, @given-decorated tests skip one by one and everything else
collects and runs normally.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                       # minimal CI image
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Accepts any strategy expression at decoration time."""

        def __getattr__(self, _name):
            def strategy(*_args, **_kwargs):
                return None
            return strategy

    st = _Strategies()
