"""Pass-planner invariants: the fused one-pass-per-level budget, both tiers.

Hypothesis-free (seeded numpy randomness) like test_sort_once.py — these
guard the streaming pass planner (disk/passes.py) and the fused BFS levels
built on it, and must run in the minimal CI image.

Covers:
  * PassPlan stage composition (producer/consumer order, write-back rules)
    and the extsort.STATS pass ledger (rw/read passes, piggybacked stages)
  * DiskBitArray.run_pass snapshot isolation: updates queued by a consumer
    stage mid-pass apply in the NEXT pass, never the current one — and the
    aborted-pass re-adoption rule extended over the sharded runtime's
    bucket dirs (cluster.py)
  * Tier D implicit BFS: exactly ONE fused read-write pass per level
    (sync/scan/rw counters), array bytes touched == one traversal per
    level to the byte, fused ≡ unfused levels AND final bit array
  * Tier J: the fused mark+rotate+count kernel ≡ the two-kernel reference,
    implicit BFS fused ≡ unfused, and the sorted engine's level budget of
    ONE lexsort + ONE scatter (the staging scatter folded into the sort)
  * fused ≡ unfused level counts on pancake n=7 for both engines
"""
import math
import os
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitarray as BA
from repro.core import constructs as C
from repro.core import ranking as R
from repro.core import rlist as RL
from repro.core import types as T
from repro.core.disk import DiskBitArray, PassPlan, implicit_bfs
from repro.core.disk import bitarray as DBA
from repro.core.disk import extsort
from repro.core.disk.passes import record_pass

sys.path.append(os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "examples"))
from pancake_bits import (neighbor_jnp as _pancake_neighbor_jnp,        # noqa: E402
                          neighbors_np as _pancake_neighbors_np)


@pytest.fixture
def wd(tmp_path):
    return str(tmp_path)


# -------------------------------------------------------------- PassPlan

class TestPassPlan:
    def test_stage_order_and_write_composition(self):
        seen = []
        plan = (PassPlan("p")
                .writes(lambda s, v: v + 1)
                .reads(lambda s, v: seen.append(("r1", s, v.copy())))
                .writes(lambda s, v: v * 2)
                .reads(lambda s, v: seen.append(("r2", s, v.copy()))))
        out = plan.apply_chunk(32, np.array([1, 2], np.uint8))
        # consumers observe the values produced by the stages BEFORE them
        assert np.array_equal(seen[0][2], [2, 3])
        assert np.array_equal(seen[1][2], [4, 6])
        assert seen[0][1] == seen[1][1] == 32
        assert np.array_equal(out, [4, 6])
        assert plan.writes_chunks and plan.forces_full_traversal

    def test_read_only_plan_does_not_write(self):
        plan = PassPlan().reads(lambda s, v: None)
        assert not plan.writes_chunks
        assert plan.forces_full_traversal
        assert PassPlan().n_stages == 0 and not PassPlan().forces_full_traversal

    def test_dirty_only_plan_visits_only_logged_chunks(self, wd):
        ba = DiskBitArray(wd, 64, chunk_elems=16)      # 4 chunks
        ba.update([17], [1])                           # only chunk 1 dirty
        seen = []
        DBA.reset_stats()
        ba.run_pass(PassPlan("seed", dirty_only=True)
                    .reads(lambda s, v: seen.append(s)))
        assert seen == [16]
        # exactly one 4-byte packed chunk read, nothing else
        assert (DBA.STATS["bytes_read"] - DBA.STATS["log_bytes_read"]) == 4
        assert ba.get([17])[0] == 1
        ba.destroy()

    def test_record_pass_ledger(self):
        extsort.reset_stats()
        record_pass(3, writes=True)
        record_pass(1, writes=False)
        assert extsort.STATS["rw_passes"] == 1
        assert extsort.STATS["read_passes"] == 1
        # 2 of the 3 fused stages rode the first traversal for free
        assert extsort.STATS["piggybacked_stages"] == 2


class TestRunPassSnapshotIsolation:
    def test_mid_pass_updates_defer_to_next_pass(self, wd):
        ba = DiskBitArray(wd, 64, chunk_elems=16)      # 4 chunks
        ba.update([0], [1])                            # chunk 0 dirty

        def echo_mark(start, vals):
            # consumer on chunk 0 queues a mark into chunk 3 (ahead of the
            # traversal) — it must NOT land in this pass
            if start == 0:
                ba.update([60], [3])

        ba.run_pass(PassPlan("iso").reads(echo_mark))
        assert ba.get([0])[0] == 1                     # this pass's op applied
        assert ba.get([60])[0] == 0                    # deferred mark absent
        ba.sync()
        assert ba.get([60])[0] == 3                    # applied by the NEXT pass
        ba.destroy()

    def test_mid_pass_update_to_earlier_chunk_defers_too(self, wd):
        ba = DiskBitArray(wd, 64, chunk_elems=16)

        def mark_back(start, vals):
            if start == 48:                            # last chunk marks chunk 0
                ba.update([1], [2])

        ba.run_pass(PassPlan().reads(mark_back))
        assert ba.get([1])[0] == 0
        ba.sync()
        assert ba.get([1])[0] == 2
        ba.destroy()

    def test_aborted_pass_snapshot_is_readopted(self, wd):
        ba = DiskBitArray(wd, 32, chunk_elems=16)
        ba.update([2], [1])

        class Boom(Exception):
            pass

        def blow_up(start, vals):
            raise Boom

        with pytest.raises(Boom):
            ba.run_pass(PassPlan().reads(blow_up))
        ba.update([3], [2])                            # newer op, same chunk
        ba.sync()                                      # must apply BOTH
        assert ba.get([2])[0] == 1 and ba.get([3])[0] == 2
        ba.destroy()


class TestShardedSnapshotReadoption:
    """The ``.pass`` re-adoption guarantee above, extended over the
    sharded runtime's bucket dirs (ISSUE 4): a sync that dies mid-pass on
    a WORKER leaves its shard-local snapshot plus (possibly) in-flight
    ``.tmp`` bucket files — the next sharded sync re-adopts the snapshot,
    ignores the strays, and loses no queued op."""

    def test_aborted_sharded_sync_loses_no_ops(self, wd):
        from repro.core.disk.cluster import (ShardRuntime,
                                             ShardedDiskBitArray)

        class Boom(Exception):
            pass

        def exploding_apply(old, agg):
            raise Boom

        rt = ShardRuntime(wd, 2, mode="inline")
        sb = ShardedDiskBitArray(rt, 64, name="bits", chunk_elems=16)
        sb.update([3], [1])                  # global idx 3 -> shard 0
        with pytest.raises(Boom):
            sb.sync(apply=exploding_apply)   # dies AFTER log promotion
        # a "killed peer" also left an in-flight .tmp bucket behind
        exch = rt.driver.exchange_dir("bits")
        with open(os.path.join(exch, "s001_d000.bin.tmp"), "wb") as f:
            f.write(np.array([[5, 3]], np.int64).tobytes())
        sb.update([40], [2])                 # global idx 40 -> shard 1
        assert sb.sync() == 0                # re-adopts, ignores the .tmp
        assert sb.get([3, 40, 5]).tolist() == [1, 2, 0]
        sb.destroy()
        assert not os.path.exists(exch)      # cleanup removed the stray


# ------------------------------------------- Tier D fused implicit BFS

def _ring_neighbors(n_states):
    def gen(idx):
        return np.stack([(idx + 1) % n_states, (idx - 1) % n_states], axis=1)
    return gen


class TestTierDFusedImplicitBFS:
    def test_one_rw_pass_per_level_exact_counters(self, wd):
        n_states = 256                                  # 4 chunks of 64
        DBA.reset_stats()
        extsort.reset_stats()
        sizes, bits = implicit_bfs(wd, n_states, [0],
                                   _ring_neighbors(n_states), chunk_elems=64)
        nbytes = bits.nbytes
        assert sum(sizes) == n_states
        passes = len(sizes) + 1        # seed pass + one per level transition
        # THE budget: one fused read-write pass per level, zero scan passes
        assert DBA.STATS["sync_passes"] == passes
        assert DBA.STATS["scan_passes"] == 0
        assert extsort.STATS["rw_passes"] == passes
        # expand+count rode every pass: ≥2 piggybacked stages per level
        assert extsort.STATS["piggybacked_stages"] >= 2 * passes
        # array bytes: exactly ONE traversal of the packed array per
        # rotate pass; the seed pass is dirty-only and touches just the
        # seed's chunk (16 packed bytes of the 64-byte array)
        arr_read = DBA.STATS["bytes_read"] - DBA.STATS["log_bytes_read"]
        assert arr_read == (passes - 1) * nbytes + 16
        arr_written = DBA.STATS["bytes_written"] - DBA.STATS["log_bytes_written"]
        assert arr_written == (passes - 1) * nbytes + 16
        bits.destroy()

    def test_unfused_pays_the_extra_scan_pass(self, wd):
        n_states = 256
        DBA.reset_stats()
        sizes, bits = implicit_bfs(wd, n_states, [0],
                                   _ring_neighbors(n_states), chunk_elems=64,
                                   fused=False)
        bits.destroy()
        # reference composition: a separate expand read pass per level
        assert DBA.STATS["scan_passes"] == len(sizes)
        assert DBA.STATS["sync_passes"] == len(sizes) + 1

    def test_fused_equals_unfused_bits_and_levels(self, wd):
        n = 6
        total = math.factorial(n)
        start = int(R.rank_np(np.arange(n)[None, :])[0])
        sizes_f, bits_f = implicit_bfs(
            os.path.join(wd, "f"), total, [start], _pancake_neighbors_np(n),
            chunk_elems=256)
        sizes_u, bits_u = implicit_bfs(
            os.path.join(wd, "u"), total, [start], _pancake_neighbors_np(n),
            chunk_elems=256, fused=False)
        assert sizes_f == sizes_u
        assert np.array_equal(bits_f.read_all(), bits_u.read_all())
        hist = bits_f.count_values()
        assert hist[0] == 0 and hist[3] == total
        bits_f.destroy()
        bits_u.destroy()

    def test_pancake_n7_level_counts(self, wd):
        # OEIS A058986: pancake diameter of n=7 is 8; fused engine must
        # reproduce the full flip-distance histogram.
        n = 7
        total = math.factorial(n)
        start = int(R.rank_np(np.arange(n)[None, :])[0])
        sizes, bits = implicit_bfs(wd, total, [start],
                                   _pancake_neighbors_np(n),
                                   chunk_elems=1 << 10)
        bits.destroy()
        assert sum(sizes) == total
        assert len(sizes) - 1 == 8
        assert sizes == [1, 6, 30, 149, 543, 1357, 1903, 1016, 35]


# ------------------------------------------- Tier J fused implicit BFS

class TestTierJFusedImplicit:
    def test_mark_rotate_count_matches_two_kernel_reference(self):
        rng = np.random.default_rng(10)
        for case in range(10):
            w = int(rng.integers(1, 12))
            packed = jnp.asarray(rng.integers(0, 1 << 32, w, dtype=np.uint64)
                                 .astype(np.uint32))
            m = int(rng.integers(1, 64))
            idx = jnp.asarray(rng.integers(-4, w * 16 + 8, m).astype(np.int32))
            n = int(rng.integers(1, w * 16 + 1))
            got, gcnt = BA.mark_rotate_count(packed, idx, n, impl="ref")
            marked = BA.mark_packed(packed, idx, impl="ref")
            want, wcnt = BA.rotate_count(marked, n, impl="ref")
            assert np.array_equal(np.asarray(got), np.asarray(want)), case
            assert int(gcnt) == int(wcnt), case

    def test_implicit_bfs_fused_equals_unfused(self):
        n = 5
        total = math.factorial(n)
        start = int(R.rank_np(np.arange(n)[None, :])[0])
        sf, bf = C.implicit_bfs(total, [start], _pancake_neighbor_jnp(n))
        su, bu = C.implicit_bfs(total, [start], _pancake_neighbor_jnp(n),
                                fused=False)
        assert sf == su
        assert np.array_equal(np.asarray(bf.data), np.asarray(bu.data))

    def test_pancake_n7_level_counts_both_engines_agree(self, wd):
        n = 7
        total = math.factorial(n)
        start = int(R.rank_np(np.arange(n)[None, :])[0])
        j_sizes, _ = C.implicit_bfs(total, [start], _pancake_neighbor_jnp(n))
        d_sizes, bits = implicit_bfs(wd, total, [start],
                                     _pancake_neighbors_np(n),
                                     chunk_elems=1 << 11)
        bits.destroy()
        assert j_sizes == d_sizes
        assert sum(j_sizes) == total


# --------------------------------------- Tier J sorted-engine level budget

def _tiny_gen_next(n):
    def gen(row):
        code = row[0]
        perm = jnp.stack([(code >> jnp.uint32(4 * i)) & jnp.uint32(0xF)
                          for i in range(n)]).astype(jnp.int32)
        outs = []
        for k in range(2, n + 1):
            flipped = jnp.concatenate([perm[:k][::-1], perm[k:]])
            acc = jnp.uint32(0)
            for i in range(n):
                acc = acc | (flipped[i].astype(jnp.uint32)
                             << jnp.uint32(4 * i))
            outs.append(acc)
        return jnp.stack(outs)[:, None], jnp.ones((n - 1,), bool)
    return gen


class TestTierJLevelBudget:
    def test_fused_level_is_one_lexsort_one_scatter(self):
        # The expansion-scatter staging is folded into the fused lexsort:
        # a whole level traces ONE lexsort + ONE scatter (the fold into
        # the visited list).  The reference composition pays 2 + 2.
        n = 4
        cur = RL.from_rows(jnp.array([[0x3210]], jnp.uint32), capacity=4)
        all_lst = RL.from_rows(jnp.array([[0x3210]], jnp.uint32), capacity=32)
        T.reset_sort_stats()
        C._bfs_level(cur, all_lst, _tiny_gen_next(n), n - 1, 16)
        assert T.SORT_STATS == {"lexsorts": 1, "scatters": 1}
        T.reset_sort_stats()
        C._bfs_level_reference(cur, all_lst, _tiny_gen_next(n), n - 1, 16)
        assert T.SORT_STATS["lexsorts"] >= 2
        assert T.SORT_STATS["scatters"] >= 2

    def test_fused_bfs_equals_reference_pancake_n7(self):
        n = 7
        start = np.array([[sum(i << (4 * i) for i in range(n))]], np.uint32)
        total = math.factorial(n)
        res_f = C.breadth_first_search(start, _tiny_gen_next(n), fanout=n - 1,
                                       width=1, all_capacity=total + 8,
                                       level_capacity=total + 8)
        res_u = C.breadth_first_search(start, _tiny_gen_next(n), fanout=n - 1,
                                       width=1, all_capacity=total + 8,
                                       level_capacity=total + 8, fused=False)
        assert res_f.level_sizes == res_u.level_sizes
        assert sum(res_f.level_sizes) == total
