"""Tier J Roomy structures vs in-RAM oracles, incl. hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Shim: @given tests skip individually when hypothesis is absent; the
# plain oracle tests in this module still run (see _hypothesis_compat).
from _hypothesis_compat import given, settings, st

from repro.core import array as RA
from repro.core import hashtable as HT
from repro.core import rlist as RL
from repro.core import types as T

SENT = 0xFFFFFFFF


def rows_strategy(width=2, max_n=40, max_val=30):
    # max_val small → plenty of duplicates; sentinel excluded by bound
    return st.lists(
        st.tuples(*([st.integers(0, max_val)] * width)),
        min_size=0, max_size=max_n)


def as_np(rows, width=2):
    if not rows:
        return np.zeros((0, width), np.uint32)
    return np.array(rows, np.uint32)


class TestRoomyList:
    @settings(max_examples=30, deadline=None)
    @given(rows_strategy())
    def test_remove_dupes_matches_set(self, rows):
        arr = as_np(rows)
        rl = RL.from_rows(jnp.asarray(arr), capacity=64)
        rd = RL.remove_dupes(rl)
        got = sorted(map(tuple, RL.to_numpy(rd).tolist()))
        assert got == sorted(set(map(tuple, arr.tolist())))

    @settings(max_examples=30, deadline=None)
    @given(rows_strategy(), rows_strategy())
    def test_remove_all_multiset(self, a_rows, b_rows):
        a, b = as_np(a_rows), as_np(b_rows)
        rl_a = RL.from_rows(jnp.asarray(a), capacity=64)
        rl_b = RL.from_rows(jnp.asarray(b), capacity=64)
        out = RL.remove_all(rl_a, rl_b)
        bset = set(map(tuple, b.tolist()))
        want = sorted(t for t in map(tuple, a.tolist()) if t not in bset)
        assert sorted(map(tuple, RL.to_numpy(out).tolist())) == want

    @settings(max_examples=20, deadline=None)
    @given(rows_strategy(), rows_strategy())
    def test_member_mask(self, a_rows, q_rows):
        a, q = as_np(a_rows), as_np(q_rows)
        if q.shape[0] == 0:
            return
        rl = RL.from_rows(jnp.asarray(a), capacity=64)
        got = np.asarray(RL.member_mask(rl, jnp.asarray(q)))
        aset = set(map(tuple, a.tolist()))
        want = np.array([tuple(r) in aset for r in q.tolist()])
        assert np.array_equal(got, want)

    def test_add_overflow_flag(self):
        rl = RL.make(4, 1)
        rl, ov = RL.add(rl, jnp.arange(3, dtype=jnp.uint32)[:, None])
        assert not bool(ov)
        rl, ov = RL.add(rl, jnp.arange(3, dtype=jnp.uint32)[:, None])
        assert bool(ov)
        assert int(rl.count) == 4          # clamped, no corruption

    def test_reduce_and_predicate(self):
        vals = np.array([[1], [2], [2], [5]], np.uint32)
        rl = RL.from_rows(jnp.asarray(vals), capacity=8)
        s = RL.reduce(rl, lambda r: r[0].astype(jnp.uint32),
                      lambda a, b: a + b, jnp.uint32(0))
        assert int(s) == 10
        assert int(RL.predicate_count(rl, lambda r: r[0] == 2)) == 2


class TestRoomyArray:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 15), st.integers(-50, 50)),
                    min_size=0, max_size=30))
    def test_scatter_add_sync_matches_numpy(self, updates):
        base = np.arange(16, dtype=np.int32)
        ra = RA.make(jnp.asarray(base), queue_capacity=32,
                     payload_dtype=jnp.int32)
        if updates:
            idx = jnp.array([u[0] for u in updates], jnp.int32)
            pay = jnp.array([u[1] for u in updates], jnp.int32)
            ra, ov = RA.update(ra, idx, pay)
            assert not bool(ov)
        ra = RA.sync(ra, combine=lambda a, b: a + b,
                     apply=lambda old, agg: old + agg)
        want = base.copy()
        for i, v in updates:
            want[i] += v
        assert np.array_equal(np.asarray(ra.data), want)

    def test_queue_order_independence(self):
        """combine is assoc+comm → any issue order gives the same sync."""
        base = jnp.zeros(8, jnp.int32)
        idx = jnp.array([3, 1, 3, 3, 1], jnp.int32)
        pay = jnp.array([1, 10, 2, 3, 20], jnp.int32)
        ra1 = RA.make(base, 8, payload_dtype=jnp.int32)
        ra1, _ = RA.update(ra1, idx, pay)
        perm = jnp.array([4, 2, 0, 1, 3])
        ra2 = RA.make(base, 8, payload_dtype=jnp.int32)
        ra2, _ = RA.update(ra2, idx[perm], pay[perm])
        f = lambda ra: RA.sync(ra, lambda a, b: a + b,
                               lambda o, g: o + g).data
        assert np.array_equal(np.asarray(f(ra1)), np.asarray(f(ra2)))

    def test_incremental_predicate_count(self):
        pred = lambda x: x > 5
        ra = RA.make(jnp.arange(8, dtype=jnp.int32), 8,
                     payload_dtype=jnp.int32, pred=pred)
        assert int(ra.pcount) == 2             # 6, 7
        ra, _ = RA.update(ra, jnp.array([0, 7], jnp.int32),
                          jnp.array([100, -100], jnp.int32))
        ra = RA.sync(ra, lambda a, b: a + b, lambda o, g: o + g, pred=pred)
        assert int(ra.pcount) == 2             # 0→100 in, 7→-93 out


class TestRoomyHashTable:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 20), st.integers(0, 100)),
                    min_size=0, max_size=30))
    def test_insert_sum_matches_dict(self, pairs):
        ht = HT.make(capacity=64, key_width=1, queue_capacity=64,
                     val_dtype=jnp.int32)
        want = {}
        for k, v in pairs:
            want[k] = want.get(k, 0) + v
        if pairs:
            keys = jnp.array([[k] for k, _ in pairs], jnp.uint32)
            vals = jnp.array([v for _, v in pairs], jnp.int32)
            ht, _ = HT.insert(ht, keys, vals)
        ht, ov = HT.sync(ht, combine=lambda a, b: a + b,
                         apply=lambda old, agg, p: jnp.where(p, old + agg,
                                                             agg))
        assert not bool(ov)
        assert int(ht.count) == len(want)
        if want:
            q = jnp.array([[k] for k in want], jnp.uint32)
            got_v, got_f = HT.lookup(ht, q)
            assert bool(jnp.all(got_f))
            for (k, v), gv in zip(want.items(), np.asarray(got_v)):
                assert v == gv
        # absent key
        _, f = HT.lookup(ht, jnp.array([[999]], jnp.uint32))
        assert not bool(f[0])

    def test_remove_tombstone_wins(self):
        ht = HT.make(16, 1, 16, val_dtype=jnp.int32)
        ht, _ = HT.insert(ht, jnp.array([[7]], jnp.uint32),
                          jnp.array([1], jnp.int32))
        ht, _ = HT.remove(ht, jnp.array([[7]], jnp.uint32))
        ht, _ = HT.sync(ht)
        _, f = HT.lookup(ht, jnp.array([[7]], jnp.uint32))
        assert not bool(f[0])
        assert int(ht.count) == 0


class TestRoomyHashTableOpOrder:
    """Tier J mirror of TestDiskHashTableOpOrder (test_disk_tier.py): the
    op log executes sequentially per key within one sync window — DEL then
    PUT resurrects, PUT then DEL removes — matching Tier D's
    DiskHashTable.sync rule exactly (the ROADMAP alignment item)."""

    @staticmethod
    def _sum_sync(ht):
        return HT.sync(ht, combine=lambda a, b: a + b,
                       apply=lambda o, a, p: jnp.where(p, o + a, a))

    def test_del_then_put_resurrects(self):
        ht = HT.make(16, 1, 16, val_dtype=jnp.int32)
        ht, _ = HT.insert(ht, jnp.array([[7]], jnp.uint32),
                          jnp.array([1], jnp.int32))
        ht, _ = HT.sync(ht)
        ht, _ = HT.remove(ht, jnp.array([[7]], jnp.uint32))
        ht, _ = HT.insert(ht, jnp.array([[7]], jnp.uint32),
                          jnp.array([5], jnp.int32))
        ht, _ = self._sum_sync(ht)
        v, f = HT.lookup(ht, jnp.array([[7]], jnp.uint32))
        assert bool(f[0])
        # the DEL wiped the stored 1: the PUT applies as a fresh insert
        assert int(v[0]) == 5
        assert int(ht.count) == 1

    def test_put_then_del_removes(self):
        ht = HT.make(16, 1, 16, val_dtype=jnp.int32)
        ht, _ = HT.insert(ht, jnp.array([[7]], jnp.uint32),
                          jnp.array([1], jnp.int32))
        ht, _ = HT.sync(ht)
        ht, _ = HT.insert(ht, jnp.array([[7]], jnp.uint32),
                          jnp.array([9], jnp.int32))
        ht, _ = HT.remove(ht, jnp.array([[7]], jnp.uint32))
        ht, _ = HT.sync(ht)
        _, f = HT.lookup(ht, jnp.array([[7]], jnp.uint32))
        assert not bool(f[0])
        assert int(ht.count) == 0

    def test_puts_after_del_combine_fresh(self):
        ht = HT.make(16, 1, 16, val_dtype=jnp.int32)
        ht, _ = HT.insert(ht, jnp.array([[3]], jnp.uint32),
                          jnp.array([100], jnp.int32))
        ht, _ = HT.sync(ht)
        ht, _ = HT.remove(ht, jnp.array([[3]], jnp.uint32))
        ht, _ = HT.insert(ht, jnp.array([[3], [3]], jnp.uint32),
                          jnp.array([2, 3], jnp.int32))
        ht, _ = self._sum_sync(ht)
        v, f = HT.lookup(ht, jnp.array([[3]], jnp.uint32))
        assert bool(f[0]) and int(v[0]) == 5    # 2+3, NOT 105: the 100 is gone
        assert int(ht.count) == 1

    def test_del_of_absent_key_is_noop(self):
        ht = HT.make(16, 1, 16, val_dtype=jnp.int32)
        ht, _ = HT.remove(ht, jnp.array([[42]], jnp.uint32))
        ht, _ = HT.sync(ht)
        _, f = HT.lookup(ht, jnp.array([[42]], jnp.uint32))
        assert not bool(f[0]) and int(ht.count) == 0

    def test_matches_tier_d_sequential_dict(self):
        # seeded mixed PUT/DEL streams over 3 sync windows vs the
        # sequential-per-key dict oracle (Tier D's documented semantics)
        rng = np.random.default_rng(11)
        for _ in range(3):
            ht = HT.make(64, 1, 128, val_dtype=jnp.int32)
            want = {}
            for _wnd in range(3):
                ops = [(int(rng.integers(0, 12)), int(rng.integers(0, 50)),
                        bool(rng.random() < 0.3)) for _ in range(25)]
                for k, v, d in ops:
                    if d:
                        ht, _ = HT.remove(ht, jnp.array([[k]], jnp.uint32))
                        want.pop(k, None)
                    else:
                        ht, _ = HT.insert(ht, jnp.array([[k]], jnp.uint32),
                                          jnp.array([v], jnp.int32))
                        want[k] = want.get(k, 0) + v
                ht, ov = self._sum_sync(ht)
                assert not bool(ov)
            assert int(ht.count) == len(want)
            if want:
                q = jnp.array([[k] for k in sorted(want)], jnp.uint32)
                gv, gf = HT.lookup(ht, q)
                assert bool(jnp.all(gf))
                assert ([int(x) for x in np.asarray(gv)]
                        == [want[k] for k in sorted(want)])


class TestHelpers:
    @settings(max_examples=20, deadline=None)
    @given(rows_strategy(width=3, max_n=20))
    def test_lexsort_rows(self, rows):
        arr = as_np(rows, 3)
        if arr.shape[0] == 0:
            return
        perm = T.lexsort_rows(jnp.asarray(arr))
        got = arr[np.asarray(perm)]
        want = np.array(sorted(map(tuple, arr.tolist())), np.uint32)
        assert np.array_equal(got, want)

    def test_tree_reduce_identity_law(self):
        vals = jnp.arange(7, dtype=jnp.int32)
        assert int(T.tree_reduce(vals, jnp.maximum, -2**31)) == 6
        assert int(T.tree_reduce(vals, lambda a, b: a + b, 0)) == 21


class TestRoomySet:
    """Native RoomySet — the paper's named future work, as a primitive.

    One-pass union/intersection/difference must match python sets AND the
    paper's 3-temporary RoomyList recipes (cross-validated)."""

    @settings(max_examples=25, deadline=None)
    @given(st.sets(st.integers(0, 40)), st.sets(st.integers(0, 40)))
    def test_native_ops_match_python_sets(self, a, b):
        from repro.core import rset as RS

        def mk(s):
            rows = (jnp.array(sorted(s), jnp.uint32)[:, None]
                    if s else jnp.zeros((0, 1), jnp.uint32))
            return RS.from_rows(rows, capacity=max(len(s), 1))
        A, B = mk(a), mk(b)
        got_u = sorted(x[0] for x in RS.to_numpy(RS.union(A, B)).tolist())
        got_i = sorted(x[0] for x in
                       RS.to_numpy(RS.intersection(A, B)).tolist())
        got_d = sorted(x[0] for x in
                       RS.to_numpy(RS.difference(A, B)).tolist())
        assert got_u == sorted(a | b)
        assert got_i == sorted(a & b)
        assert got_d == sorted(a - b)

    def test_matches_list_recipe(self):
        """Native intersection == the paper's (A+B)−(A−B)−(B−A) recipe."""
        from repro.core import constructs as C
        from repro.core import rset as RS
        import numpy as np
        rng = np.random.default_rng(0)
        a = rng.integers(0, 60, 40).astype(np.uint32)
        b = rng.integers(0, 60, 30).astype(np.uint32)
        A_l = RL.remove_dupes(RL.from_rows(jnp.asarray(a)[:, None], 64))
        B_l = RL.remove_dupes(RL.from_rows(jnp.asarray(b)[:, None], 64))
        recipe = sorted(x[0] for x in
                        RL.to_numpy(C.set_intersection(A_l, B_l)).tolist())
        A_s = RS.from_rows(jnp.asarray(a)[:, None], 64)
        B_s = RS.from_rows(jnp.asarray(b)[:, None], 64)
        native = sorted(x[0] for x in
                        RS.to_numpy(RS.intersection(A_s, B_s)).tolist())
        assert native == recipe

    def test_dedup_on_build(self):
        from repro.core import rset as RS
        s = RS.from_rows(jnp.array([[7], [7], [7]], jnp.uint32), capacity=4)
        assert int(s.count) == 1


class TestBinByDestOverflow:
    """delayed.bin_by_dest drop accounting: ``dropped`` must equal EXACTLY
    the number of valid items beyond per-bucket capacity."""

    def _oracle_dropped(self, dest, valid, nbuckets, capacity):
        counts = np.zeros(nbuckets, np.int64)
        for d, v in zip(np.asarray(dest).tolist(), np.asarray(valid).tolist()):
            if v and 0 <= d < nbuckets:
                counts[d] += 1
        return int(np.maximum(counts - capacity, 0).sum())

    def test_dropped_matches_per_bucket_overflow(self):
        from repro.core import delayed as D
        rng = np.random.default_rng(0)
        for seed in range(5):
            rng = np.random.default_rng(seed)
            m, nb, cap = 64, 4, 5
            dest = jnp.asarray(rng.integers(0, nb, m).astype(np.int32))
            valid = jnp.asarray(rng.random(m) < 0.7)
            pay = jnp.asarray(rng.integers(0, 100, (m, 2)).astype(np.int32))
            b = D.bin_by_dest(dest, pay, valid, nb, cap)
            want = self._oracle_dropped(dest, valid, nb, cap)
            assert int(b.dropped) == want
            # and the kept count is consistent: valid slots == valid - dropped
            nvalid = int(jnp.sum(valid.astype(jnp.int32)))
            assert int(jnp.sum(b.valid.astype(jnp.int32))) == nvalid - want

    def test_single_bucket_hotspot(self):
        from repro.core import delayed as D
        m, nb, cap = 32, 4, 3
        dest = jnp.zeros((m,), jnp.int32)             # everyone → bucket 0
        valid = jnp.ones((m,), bool)
        pay = jnp.ones((m, 1), jnp.int32)
        b = D.bin_by_dest(dest, pay, valid, nb, cap)
        assert int(b.dropped) == m - cap

    def test_all_invalid_drops_nothing(self):
        from repro.core import delayed as D
        m, nb, cap = 16, 4, 2
        dest = jnp.zeros((m,), jnp.int32)
        valid = jnp.zeros((m,), bool)
        pay = jnp.ones((m, 1), jnp.int32)
        b = D.bin_by_dest(dest, pay, valid, nb, cap)
        assert int(b.dropped) == 0
        assert int(jnp.sum(b.valid.astype(jnp.int32))) == 0

    def test_zero_capacity_drops_all_valid(self):
        from repro.core import delayed as D
        m, nb = 10, 3
        dest = jnp.asarray(np.arange(m) % nb, jnp.int32)
        valid = jnp.asarray(np.arange(m) % 2 == 0)    # 5 valid
        pay = jnp.ones((m, 1), jnp.int32)
        b = D.bin_by_dest(dest, pay, valid, nb, 0)
        assert int(b.dropped) == 5
        assert b.payload.shape == (nb, 0, 1)
