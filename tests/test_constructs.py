"""Paper §3 programming constructs (Tier J): map, reduce, set ops, chain
reduction, parallel prefix, pair reduction, BFS — each against an
independent oracle, plus the paper's own examples."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Shim: @given tests skip individually when hypothesis is absent; the
# plain oracle tests in this module still run (see _hypothesis_compat).
from _hypothesis_compat import given, settings, st

from repro.core import array as RA
from repro.core import constructs as C
from repro.core import hashtable as HT
from repro.core import rlist as RL


class TestPaperMapExample:
    def test_array_to_hashtable(self):
        """Paper's map example: RoomyArray → RoomyHashTable (index as key)."""
        data = jnp.array([5, 9, 5, 7], jnp.int32)
        ra = RA.make(data, queue_capacity=4)
        ht = HT.make(16, 1, 8, val_dtype=jnp.int32)
        keys = jnp.arange(4, dtype=jnp.uint32)[:, None]
        ht, _ = HT.insert(ht, keys, ra.data)
        ht, _ = HT.sync(ht)
        vals, found = HT.lookup(ht, keys)
        assert bool(jnp.all(found))
        assert np.array_equal(np.asarray(vals), np.asarray(data))


class TestPaperReduceExample:
    def test_sum_of_squares(self):
        """Paper's reduce example over a RoomyList."""
        rl = RL.from_rows(jnp.arange(10, dtype=jnp.uint32)[:, None], 16)
        s = RL.reduce(rl, lambda r: (r[0] * r[0]).astype(jnp.uint32),
                      lambda a, b: a + b, jnp.uint32(0))
        assert int(s) == sum(i * i for i in range(10))


class TestSetOps:
    @settings(max_examples=25, deadline=None)
    @given(st.sets(st.integers(0, 30)), st.sets(st.integers(0, 30)))
    def test_union_difference_intersection(self, a, b):
        def mk(s):
            rows = (jnp.array(sorted(s), jnp.uint32)[:, None]
                    if s else jnp.zeros((0, 1), jnp.uint32))
            return RL.from_rows(rows, capacity=64)
        A, B = mk(a), mk(b)
        got_u = sorted(x[0] for x in RL.to_numpy(C.set_union(A, B)).tolist())
        assert got_u == sorted(a | b)
        got_d = sorted(x[0] for x in
                       RL.to_numpy(C.set_difference(A, B)).tolist())
        assert got_d == sorted(a - b)
        got_i = sorted(x[0] for x in
                       RL.to_numpy(C.set_intersection(A, B)).tolist())
        assert got_i == sorted(a & b)


class TestChainAndPrefix:
    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(-100, 100), min_size=1, max_size=32))
    def test_chain_reduction(self, vals):
        """a[i] += a[i-1], all reads before writes (paper §3)."""
        a = jnp.array(vals, jnp.int32)
        ra = RA.make(a, queue_capacity=len(vals), payload_dtype=jnp.int32)
        out = C.chain_reduce(ra, lambda old, prev: old + prev)
        want = np.array(vals, np.int64)
        want[1:] += np.array(vals[:-1], np.int64)
        assert np.array_equal(np.asarray(out.data), want.astype(np.int32))

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(-50, 50), min_size=1, max_size=40))
    def test_parallel_prefix_is_cumsum(self, vals):
        a = jnp.array(vals, jnp.int32)
        ra = RA.make(a, queue_capacity=len(vals), payload_dtype=jnp.int32)
        out = C.parallel_prefix(ra, lambda o, p: o + p)
        assert np.array_equal(np.asarray(out.data),
                              np.cumsum(vals).astype(np.int32))


class TestPairReduction:
    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.integers(-10, 10), min_size=1, max_size=20),
           st.integers(2, 8))
    def test_sum_over_pairs(self, vals, block):
        a = jnp.array(vals, jnp.int32)
        ra = RA.make(a, queue_capacity=1)
        got = C.pair_reduce(ra, lambda x, y: (x * y).astype(jnp.int32),
                            lambda p, q: p + q, jnp.int32(0), block=block)
        assert int(got) == sum(vals) ** 2       # Σᵢⱼ xᵢxⱼ = (Σx)²


class TestBFS:
    def test_pancake_diameters(self):
        """Paper's flagship app. Diameters from OEIS A058986."""
        for n, want_diam in [(4, 4), (5, 5), (6, 7)]:
            def encode_start(n):
                return np.uint32(sum(i << (4 * i) for i in range(n)))

            def gen_next(row, n=n):
                code = row[0]
                perm = jnp.stack(
                    [(code >> jnp.uint32(4 * i)) & jnp.uint32(0xF)
                     for i in range(n)]).astype(jnp.int32)
                outs = []
                for k in range(2, n + 1):
                    flipped = jnp.concatenate([perm[:k][::-1], perm[k:]])
                    acc = jnp.uint32(0)
                    for i in range(n):
                        acc = acc | (flipped[i].astype(jnp.uint32)
                                     << jnp.uint32(4 * i))
                    outs.append(acc)
                return jnp.stack(outs)[:, None], jnp.ones((n - 1,), bool)

            total = math.factorial(n)
            res = C.breadth_first_search(
                np.array([[encode_start(n)]], np.uint32), gen_next,
                fanout=n - 1, width=1,
                all_capacity=total + 8, level_capacity=total + 8)
            assert sum(res.level_sizes) == total, (n, res.level_sizes)
            assert len(res.level_sizes) - 1 == want_diam

    def test_capacity_growth_path(self):
        """Start with a too-small 'all' capacity; BFS must grow and finish."""
        n = 5

        def gen_next(row):
            code = row[0]
            perm = jnp.stack(
                [(code >> jnp.uint32(4 * i)) & jnp.uint32(0xF)
                 for i in range(n)]).astype(jnp.int32)
            outs = []
            for k in range(2, n + 1):
                flipped = jnp.concatenate([perm[:k][::-1], perm[k:]])
                acc = jnp.uint32(0)
                for i in range(n):
                    acc = acc | (flipped[i].astype(jnp.uint32)
                                 << jnp.uint32(4 * i))
                outs.append(acc)
            return jnp.stack(outs)[:, None], jnp.ones((n - 1,), bool)

        start = np.uint32(sum(i << (4 * i) for i in range(n)))
        res = C.breadth_first_search(
            np.array([[start]], np.uint32), gen_next, fanout=n - 1, width=1,
            all_capacity=16, level_capacity=64)   # 120 states won't fit 16
        assert sum(res.level_sizes) == math.factorial(n)


class TestCayleyBFS:
    def test_mahonian_profile_s5(self):
        """Second BFS app: S_5 bubble-sort Cayley graph — level sizes must
        equal the Mahonian numbers and diameter n(n-1)/2 (exact oracle)."""
        import sys, os
        sys.path.insert(0, os.path.join(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), "examples"))
        from cayley_bfs import gen_next_jnp, mahonian
        n = 5
        start = np.uint32(sum(i << (4 * i) for i in range(n)))
        res = C.breadth_first_search(
            np.array([[start]], np.uint32), gen_next_jnp(n), fanout=n - 1,
            width=1, all_capacity=128, level_capacity=128)
        assert res.level_sizes == mahonian(n)
        assert len(res.level_sizes) - 1 == n * (n - 1) // 2
