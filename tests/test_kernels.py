"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes and dtypes (deliverable (c) of the brief)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def rand(seed, shape, dtype=jnp.float32, scale=1.0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return (x * scale).astype(dtype)


ATTN_CASES = [
    # b, hq, hkv, sq, skv, d, causal, window, softcap
    (1, 4, 4, 64, 64, 32, True, None, None),
    (2, 8, 2, 128, 128, 64, True, None, None),      # GQA 4:1
    (1, 4, 1, 96, 96, 32, True, None, None),        # MQA, unaligned seq
    (1, 4, 2, 96, 96, 32, True, 32, None),          # sliding window
    (1, 2, 2, 64, 64, 32, True, None, 50.0),        # softcap (gemma2)
    (1, 4, 2, 64, 64, 32, True, 16, 30.0),          # window+softcap
    (1, 4, 1, 48, 80, 32, False, None, None),       # cross-length, bidir
    (2, 2, 2, 33, 65, 16, True, None, None),        # odd sizes → padding
]


class TestFlashAttention:
    @pytest.mark.parametrize("case", ATTN_CASES)
    def test_kernel_matches_naive(self, case):
        b, hq, hkv, sq, skv, d, causal, window, softcap = case
        q = rand(1, (b, hq, sq, d))
        k = rand(2, (b, hkv, skv, d))
        v = rand(3, (b, hkv, skv, d))
        got = ops.flash_attention(q, k, v, causal=causal, window=window,
                                  softcap=softcap, impl="interpret",
                                  block_q=32, block_k=32)
        want = ref.attention_naive(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("case", ATTN_CASES[:4])
    def test_blocked_ref_matches_naive(self, case):
        b, hq, hkv, sq, skv, d, causal, window, softcap = case
        q = rand(4, (b, hq, sq, d))
        k = rand(5, (b, hkv, skv, d))
        v = rand(6, (b, hkv, skv, d))
        got = ref.attention_ref(q, k, v, causal=causal, window=window,
                                softcap=softcap, block_k=48)
        want = ref.attention_naive(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                            (jnp.bfloat16, 2e-2)])
    def test_dtypes(self, dtype, atol):
        q = rand(7, (1, 4, 64, 32), dtype)
        k = rand(8, (1, 2, 64, 32), dtype)
        v = rand(9, (1, 2, 64, 32), dtype)
        got = ops.flash_attention(q, k, v, impl="interpret",
                                  block_q=32, block_k=32)
        want = ref.attention_naive(q, k, v)
        assert got.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=atol, rtol=atol)

    def test_decode_ref_matches_naive_last_row(self):
        b, hq, hkv, s, d = 2, 4, 2, 48, 32
        q = rand(10, (b, hq, 1, d))
        k = rand(11, (b, hkv, s, d))
        v = rand(12, (b, hkv, s, d))
        full = ref.attention_naive(q, k, v, causal=False)
        mask = jnp.ones((b, s), bool)
        dec = ref.decode_attention_ref(
            q[:, :, 0], k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            mask)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, :, 0]),
                                   atol=2e-5, rtol=2e-5)


MAMBA_CASES = [
    (2, 64, 32, 16, 16, 32),     # b, l, di, n, bd, bt
    (1, 100, 16, 8, 16, 32),     # unaligned length → padding
    (1, 128, 64, 4, 32, 64),
    (3, 32, 8, 16, 8, 16),
]


class TestMambaScan:
    @pytest.mark.parametrize("case", MAMBA_CASES)
    def test_kernel_matches_refs(self, case):
        b, l, di, n, bd, bt = case
        x = rand(1, (b, l, di))
        dt = jnp.abs(rand(2, (b, l, di))) * 0.1
        a = -jnp.abs(rand(3, (di, n)))
        bb = rand(4, (b, l, n))
        cc = rand(5, (b, l, n))
        d = rand(6, (di,))
        got = ops.mamba_scan(x, dt, a, bb, cc, d, impl="interpret",
                             block_d=bd, block_t=bt)
        want_assoc = ref.mamba_scan_ref(x, dt, a, bb, cc, d)
        want_seq = ref.mamba_scan_seq_ref(x, dt, a, bb, cc, d)
        np.testing.assert_allclose(np.asarray(want_assoc),
                                   np.asarray(want_seq), atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want_assoc),
                                   atol=1e-4, rtol=1e-4)

    def test_bfloat16(self):
        b, l, di, n = 1, 64, 16, 8
        x = rand(7, (b, l, di), jnp.bfloat16)
        dt = jnp.abs(rand(8, (b, l, di), jnp.bfloat16)) * 0.1
        a = -jnp.abs(rand(9, (di, n)))
        bb = rand(10, (b, l, n), jnp.bfloat16)
        cc = rand(11, (b, l, n), jnp.bfloat16)
        d = rand(12, (di,))
        got = ops.mamba_scan(x, dt, a, bb, cc, d, impl="interpret",
                             block_d=16, block_t=32)
        want = ref.mamba_scan_ref(x, dt, a, bb, cc, d)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=5e-2, rtol=5e-2)


SCATTER_CASES = [
    (16, 100, 8, 16),    # n_rows, m, d, block_m
    (64, 37, 4, 16),     # unaligned m → padding
    (8, 256, 16, 64),
    (32, 5, 8, 8),       # fewer ops than one block
]


class TestBucketScatter:
    @pytest.mark.parametrize("case", SCATTER_CASES)
    @pytest.mark.parametrize("sorted_idx", [True, False])
    def test_kernel_matches_ref(self, case, sorted_idx):
        n, m, d, bm = case
        tab = rand(1, (n, d))
        idx = jax.random.randint(jax.random.PRNGKey(2), (m,), 0, n + 3)
        if sorted_idx:
            idx = jnp.sort(idx)
        pay = rand(3, (m, d))
        got = ops.bucket_scatter_add(tab, idx, pay, impl="interpret",
                                     block_m=bm)
        want = ref.bucket_scatter_add_ref(tab, idx, pay)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)

    def test_out_of_range_dropped(self):
        tab = jnp.zeros((4, 2))
        idx = jnp.array([0, 4, 5, 3], jnp.int32)    # 4, 5 dropped
        pay = jnp.ones((4, 2))
        got = ops.bucket_scatter_add(tab, idx, pay, impl="interpret",
                                     block_m=4)
        want = jnp.zeros((4, 2)).at[jnp.array([0, 3])].add(1.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))


BITPACK_CASES = [
    (100, 8),            # W words, block_w
    (1024, 8),           # whole tiles
    (1300, 16),          # ragged tail
]


class TestBitpack:
    """2-bit packed-array kernels (the implicit-BFS hot paths) vs oracles."""

    @pytest.mark.parametrize("case", BITPACK_CASES)
    def test_lut_count_matches_ref(self, case):
        w, bw = case
        packed = jax.random.randint(jax.random.PRNGKey(0), (w,), 0,
                                    1 << 30, dtype=jnp.int32).astype(jnp.uint32)
        lut = 0 | (3 << 2) | (1 << 4) | (3 << 6)    # the BFS rotate LUT
        got, gcnt = ops.bitpack_lut_count(packed, lut, 1, impl="interpret",
                                          block_w=bw)
        want, wcnt = ref.bitpack_lut_count_ref(packed, lut, 1)
        assert np.array_equal(np.asarray(got), np.asarray(want))
        assert int(gcnt) == int(wcnt)

    def test_lut_count_pad_collision(self):
        # count_val == lut[0]: the kernel's tile padding maps to the counted
        # value and must be corrected away.
        packed = jnp.asarray([0, 0xFFFFFFFF, 5], jnp.uint32)
        lut = 0 | (0 << 2) | (2 << 4) | (1 << 6)
        got, gcnt = ops.bitpack_lut_count(packed, lut, 0, impl="interpret")
        want, wcnt = ref.bitpack_lut_count_ref(packed, lut, 0)
        assert np.array_equal(np.asarray(got), np.asarray(want))
        assert int(gcnt) == int(wcnt)

    @pytest.mark.parametrize("bm", [4, 64])
    def test_scatter_mark_matches_ref(self, bm):
        w, m = 40, 200
        packed = jax.random.randint(jax.random.PRNGKey(1), (w,), 0,
                                    1 << 30, dtype=jnp.int32).astype(jnp.uint32)
        # duplicates, OOB high, negative — all must behave
        idx = jax.random.randint(jax.random.PRNGKey(2), (m,), -8,
                                 w * 16 + 32, dtype=jnp.int32)
        got = ops.bitpack_scatter_mark(packed, idx, impl="interpret",
                                       block_m=bm)
        want = ref.bitpack_scatter_mark_ref(packed, idx, 2, 0)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("bm", [4, 64])
    def test_mark_rotate_count_matches_ref(self, bm):
        # the fused per-level kernel ≡ scatter_mark ∘ lut_count, including
        # duplicate / OOB / negative indices landing in the trash row
        w, m = 40, 200
        packed = jax.random.randint(jax.random.PRNGKey(3), (w,), 0,
                                    1 << 30, dtype=jnp.int32).astype(jnp.uint32)
        idx = jax.random.randint(jax.random.PRNGKey(4), (m,), -8,
                                 w * 16 + 32, dtype=jnp.int32)
        lut = 0 | (3 << 2) | (1 << 4) | (3 << 6)    # the BFS rotate LUT
        got, gcnt = ops.bitpack_mark_rotate_count(
            packed, idx, lut, 1, impl="interpret", block_m=bm)
        want, wcnt = ref.bitpack_mark_rotate_count_ref(packed, idx, lut, 1,
                                                       2, 0)
        assert np.array_equal(np.asarray(got), np.asarray(want))
        assert int(gcnt) == int(wcnt)

    def test_mark_rotate_count_pad_collision(self):
        # count_val == lut[0]: the trash row must stay out of the count and
        # the wrapper's tail-field correction must still hold
        packed = jnp.asarray([0, 0xFFFFFFFF, 5], jnp.uint32)
        lut = 0 | (0 << 2) | (2 << 4) | (1 << 6)
        idx = jnp.asarray([0, 7, 7, -1, 3 * 16 + 5], jnp.int32)
        got, gcnt = ops.bitpack_mark_rotate_count(packed, idx, lut, 0,
                                                  impl="interpret")
        want, wcnt = ref.bitpack_mark_rotate_count_ref(packed, idx, lut, 0,
                                                       2, 0)
        assert np.array_equal(np.asarray(got), np.asarray(want))
        assert int(gcnt) == int(wcnt)

    # ------------------------------------------ serving-tier gather path

    @pytest.mark.parametrize("case", [
        (1000, 4096, 128, 64),   # W, M, page_words, block_m
        (64, 7, 32, 4),          # tiny, ragged page tail
        (4096, 20000, 512, 256), # defaults-shaped
    ])
    def test_gather2_matches_ref(self, case):
        # The Tier J batched-lookup acceptance pin: the paged gather
        # kernel must match the unpack-everything oracle BIT FOR BIT,
        # including OOB/negative queries (→ 0) and duplicate ranks.
        w, m, pw, bm = case
        rng = np.random.default_rng(w + m)
        packed = jnp.asarray(
            rng.integers(0, 1 << 32, w, dtype=np.uint64).astype(np.uint32))
        idx = rng.integers(-50, w * 16 + 50, m).astype(np.int64)
        got = ops.bitpack_gather2(packed, idx, impl="interpret",
                                  page_words=pw, block_m=bm)
        want = ops.bitpack_gather2(packed, idx, impl="ref")
        assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_gather2_empty_and_all_oob(self):
        packed = jnp.asarray(np.arange(10, dtype=np.uint32))
        for idx in (np.asarray([], np.int64), np.full(5, -3, np.int64),
                    np.full(3, 10 * 16 + 7, np.int64)):
            got = ops.bitpack_gather2(packed, idx, impl="interpret")
            want = ops.bitpack_gather2(packed, idx, impl="ref")
            assert np.array_equal(np.asarray(got), np.asarray(want))
            assert np.asarray(got).shape == idx.shape

    def test_gather2_matches_disk_packing(self):
        # Layout bridge: bytes packed by the DISK tier (4 fields/uint8,
        # field j at bits 2j), viewed little-endian as uint32 words, must
        # gather to the same fields the disk-side random read extracts —
        # the contract that lets a served oracle chunk feed the kernel.
        from repro.core.disk.bitarray import pack2
        rng = np.random.default_rng(5)
        vals = rng.integers(0, 4, 1000).astype(np.uint8)
        raw = pack2(vals)
        pad = (-raw.size) % 4
        words = jnp.asarray(np.frombuffer(
            np.concatenate([raw, np.zeros(pad, np.uint8)]).tobytes(),
            dtype="<u4"))
        idx = rng.integers(0, 1000, 500).astype(np.int64)
        got = ops.bitpack_gather2(words, idx, impl="interpret",
                                  page_words=8, block_m=16)
        assert np.array_equal(np.asarray(got), vals[idx].astype(np.int32))


class TestMamba2SSD:
    """Chunked SSD (matmul) form vs the recurrence oracles (§Perf cell C)."""

    @pytest.mark.parametrize("chunk", [8, 16, 13])
    def test_matches_mamba1_form(self, chunk):
        B, L, H, P, N = 2, 50, 3, 8, 16
        x4 = rand(1, (B, L, H, P))
        dt = jnp.abs(rand(2, (B, L, H))) * 0.1
        a = -jnp.abs(rand(3, (H,)))
        bm = rand(4, (B, L, N))
        cm = rand(5, (B, L, N))
        d = rand(6, (H,))
        y_ssd, h_ssd = ref.mamba2_ssd(x4, dt, a, bm, cm, d, chunk=chunk)
        di = H * P
        y_ref, h_ref = ref.mamba_scan_seq_stateful(
            x4.reshape(B, L, di), jnp.repeat(dt, P, axis=-1),
            jnp.broadcast_to(jnp.repeat(a, P)[:, None], (di, N)),
            bm, cm, jnp.repeat(d, P))
        np.testing.assert_allclose(np.asarray(y_ssd.reshape(B, L, di)),
                                   np.asarray(y_ref), atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(h_ssd.reshape(B, H, P, N)),
                                   np.asarray(h_ref.reshape(B, H, P, N)),
                                   atol=2e-4, rtol=2e-4)

    def test_h0_carry(self):
        """Running two halves with a state hand-off == one full pass."""
        B, L, H, P, N = 1, 64, 2, 4, 8
        x4 = rand(7, (B, L, H, P))
        dt = jnp.abs(rand(8, (B, L, H))) * 0.1
        a = -jnp.abs(rand(9, (H,)))
        bm = rand(10, (B, L, N))
        cm = rand(11, (B, L, N))
        d = rand(12, (H,))
        y_full, h_full = ref.mamba2_ssd(x4, dt, a, bm, cm, d, chunk=16)
        y1, h1 = ref.mamba2_ssd(x4[:, :32], dt[:, :32], a, bm[:, :32],
                                cm[:, :32], d, chunk=16)
        y2, h2 = ref.mamba2_ssd(x4[:, 32:], dt[:, 32:], a, bm[:, 32:],
                                cm[:, 32:], d, chunk=16, h0=h1)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y1, y2], axis=1)),
            np.asarray(y_full), atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                                   atol=2e-4, rtol=2e-4)


BWD_CASES = [
    # b, hq, hkv, sq, skv, d, causal, window, softcap
    (1, 2, 2, 64, 64, 32, True, None, None),
    (1, 4, 2, 64, 64, 32, True, None, None),       # GQA group-sum
    (1, 2, 2, 96, 96, 16, True, 32, None),         # window, unaligned
    (1, 2, 2, 64, 64, 32, True, None, 30.0),       # softcap derivative
    (1, 2, 1, 48, 80, 32, False, None, None),      # cross-len bidir MQA
]


class TestFlashAttentionBackward:
    """Pallas backward kernels (dkdv + dq) vs jax.grad of the naive oracle,
    plus the custom_vjp wiring in ops.flash_attention."""

    @pytest.mark.parametrize("case", BWD_CASES)
    def test_bwd_kernels_match_autograd(self, case):
        from repro.kernels.flash_attention import flash_attention as fa
        from repro.kernels.flash_attention_bwd import flash_attention_bwd
        b, hq, hkv, sq, skv, d, causal, window, softcap = case
        q = rand(1, (b, hq, sq, d))
        k = rand(2, (b, hkv, skv, d))
        v = rand(3, (b, hkv, skv, d))
        do = rand(4, (b, hq, sq, d))

        def f(q, k, v):
            o = ref.attention_naive(q, k, v, causal=causal, window=window,
                                    softcap=softcap)
            return jnp.sum(o.astype(jnp.float32) * do)
        dq_r, dk_r, dv_r = jax.grad(f, argnums=(0, 1, 2))(q, k, v)

        o, lse = fa(q, k, v, causal=causal, window=window, softcap=softcap,
                    block_q=32, block_k=32, interpret=True, return_lse=True)
        dq, dk, dv = flash_attention_bwd(
            q, k, v, o, lse, do, causal=causal, window=window,
            softcap=softcap, block_q=32, block_k=32, interpret=True)
        g = hq // hkv
        dk = dk.reshape(b, hkv, g, skv, d).sum(2)
        dv = dv.reshape(b, hkv, g, skv, d).sum(2)
        for a_, b_ in [(dq, dq_r), (dk, dk_r), (dv, dv_r)]:
            np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                       atol=3e-4, rtol=3e-4)

    def test_custom_vjp_end_to_end(self):
        b, hq, hkv, sq, skv, d = 1, 4, 2, 64, 64, 32
        q = rand(5, (b, hq, sq, d))
        k = rand(6, (b, hkv, skv, d))
        v = rand(7, (b, hkv, skv, d))
        do = rand(8, (b, hq, sq, d))

        def f_kernel(q, k, v):
            o = ops.flash_attention(q, k, v, impl="interpret",
                                    block_q=32, block_k=32)
            return jnp.sum(o.astype(jnp.float32) * do)

        def f_ref(q, k, v):
            return jnp.sum(ref.attention_naive(q, k, v).astype(jnp.float32)
                           * do)
        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a_, b_ in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                       atol=3e-4, rtol=3e-4)


class TestPagedDecodeKernel:
    """Flash-decoding over Roomy pages: scalar-prefetch page-table DMA
    indexing vs the contiguous-gather oracle, with SHUFFLED physical
    placement (proves the table is honored, not assumed identity)."""

    @pytest.mark.parametrize("case", [
        (2, 4, 2, 16, 4, 32, None),
        (3, 6, 2, 8, 5, 16, 30.0),      # GQA 3:1 + softcap
        (1, 4, 4, 16, 3, 32, None),     # MHA
    ])
    def test_matches_gather_oracle(self, case):
        from repro.kernels.paged_decode import paged_decode_attention
        b, hq, kvh, ps, pps, hd, softcap = case
        rng = np.random.default_rng(0)
        num_pages = b * pps + 3
        kp = jnp.asarray(rng.standard_normal((num_pages, ps, kvh, hd)),
                         jnp.float32)
        vp = jnp.asarray(rng.standard_normal((num_pages, ps, kvh, hd)),
                         jnp.float32)
        q = jnp.asarray(rng.standard_normal((b, hq, hd)), jnp.float32)
        perm = rng.permutation(num_pages)[: b * pps]
        table = jnp.asarray(perm.reshape(b, pps), jnp.int32)
        lengths = jnp.asarray(rng.integers(1, pps * ps + 1, (b,)),
                              jnp.int32)
        got = paged_decode_attention(q, kp, vp, table, lengths,
                                     softcap=softcap, interpret=True)
        kf = kp[table].reshape(b, pps * ps, kvh, hd)
        vf = vp[table].reshape(b, pps * ps, kvh, hd)
        mask = jnp.arange(pps * ps)[None] < lengths[:, None]
        want = ref.decode_attention_ref(q, kf, vf, mask, softcap=softcap)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)
