"""Distribution: sharding rules, bucket exchange, Roomy-vs-einsum parity on
a real (fake-device) mesh — the multi-device correctness core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding_rules import ShardingRules
from repro.models import lm
from repro import optim


class FakeMesh:
    """Minimal mesh stand-in for spec construction (no devices touched)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_all_leaves(arch):
    cfg = get_config(arch)
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(cfg, mesh)
    params_shape = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    specs = rules.param_specs(params_shape)
    flat_p = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for (kp, leaf), spec in zip(flat_p, flat_s):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim
        # every named axis must divide its dim
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (kp, leaf.shape, spec)


def test_fallbacks_reported_for_gemma2():
    cfg = get_config("gemma2-2b")
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(cfg, mesh)
    params_shape = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    rules.param_specs(params_shape)
    assert any("tp_q" in f for f in rules.fallbacks)   # 8 heads vs tp=16


def test_cache_specs_shard_pages():
    cfg = get_config("nemotron-4-15b")
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(cfg, mesh)
    caches = jax.eval_shape(lambda: lm.make_cache(cfg, 128, max_len=1024))
    specs = rules.cache_specs(caches, batch=128)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    by_name = {"".join(str(k) for k in kp): v for kp, v in flat}
    k_spec = [v for k, v in by_name.items() if "k_pages" in k][0]
    assert k_spec[1] is not None        # num_pages dim sharded


class TestMultiDevice:
    def test_bucket_exchange_roundtrip(self, multidev):
        multidev("""
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.core import delayed as D
            mesh = jax.make_mesh((8,), ("x",),
                                 axis_types=(jax.sharding.AxisType.Auto,))
            S, m, C = 8, 32, 64
            dest = jax.random.randint(jax.random.PRNGKey(0), (S*m,), 0, S)
            pay = jax.random.normal(jax.random.PRNGKey(1), (S*m, 4))
            valid = jnp.ones((S*m,), bool)
            def f(dest, pay, valid):
                return D.bucket_sync_access(
                    dest.astype(jnp.int32), pay, valid, "x", S, C,
                    lambda r, v: r * 2.0)
            fs = jax.shard_map(f, mesh=mesh,
                               in_specs=(P("x"), P("x"), P("x")),
                               out_specs=(P("x"), P("x"), P()))
            out, ok, dropped = fs(dest, pay, valid)
            assert int(dropped) == 0
            np.testing.assert_allclose(np.asarray(out),
                                       np.asarray(pay) * 2.0, rtol=1e-6)
            print("exchange ok")
        """)

    def test_moe_roomy_matches_einsum(self, multidev):
        """The paper-technique dispatch must equal the baseline (up to
        capacity drops, which this sizing avoids)."""
        multidev("""
            import numpy as np, jax, jax.numpy as jnp
            from repro.configs import get_config
            from repro.models.moe import init_moe, moe_einsum, moe_roomy
            cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True).replace(
                kernels="ref", dtype="float32", capacity_factor=8.0,
                n_experts=8, top_k=2)
            mesh = jax.make_mesh((2, 4), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            p = init_moe(jax.random.PRNGKey(0), cfg)
            x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, cfg.d_model))
            base = moe_einsum(p, x, cfg)
            got = moe_roomy(p, x, cfg, mesh)
            np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                       atol=2e-4, rtol=2e-4)
            print("moe parity ok")
        """)

    def test_roomy_embed_matches_gather(self, multidev):
        multidev("""
            import numpy as np, jax, jax.numpy as jnp
            from repro.configs import get_config
            from repro.models.layers import init_embedding, embed_tokens
            cfg = get_config("minicpm-2b", smoke=True).replace(
                dtype="float32", embedding_dispatch="roomy")
            mesh = jax.make_mesh((2, 4), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            p = init_embedding(jax.random.PRNGKey(0), cfg)
            ids = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                     cfg.vocab_size)
            roomy = embed_tokens(p, ids, cfg, mesh)
            plain = embed_tokens(p, ids, cfg.replace(
                embedding_dispatch="gspmd"), None)
            np.testing.assert_allclose(np.asarray(roomy), np.asarray(plain),
                                       atol=1e-6)
            print("embed parity ok")
        """)

    def test_paged_decode_sharded_matches_host(self, multidev):
        """decode_step on a (2,4) mesh == decode_step with no mesh."""
        multidev("""
            import numpy as np, jax, jax.numpy as jnp
            from repro.configs import get_config
            from repro.models import init_params, make_cache, decode_step
            cfg = get_config("granite-34b", smoke=True).replace(
                kernels="ref", dtype="float32")
            params = init_params(cfg, jax.random.PRNGKey(0))
            mesh = jax.make_mesh((4, 2), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            b = 8
            toks = jax.random.randint(jax.random.PRNGKey(1), (b, 1), 0,
                                      cfg.vocab_size)
            pos = jnp.zeros((b, 1), jnp.int32)
            for t in range(3):
                caches_h = make_cache(cfg, b, max_len=32)
                caches_m = make_cache(cfg, b, max_len=32)
                l_h, _ = decode_step(params, {"tokens": toks,
                                              "positions": pos},
                                     caches_h, cfg, None)
                l_m, _ = decode_step(params, {"tokens": toks,
                                              "positions": pos},
                                     caches_m, cfg, mesh)
                np.testing.assert_allclose(np.asarray(l_h), np.asarray(l_m),
                                           atol=2e-4, rtol=2e-4)
            print("paged decode parity ok")
        """)

    def test_cp_decode_batch1_matches_host(self, multidev):
        multidev("""
            import numpy as np, jax, jax.numpy as jnp
            from repro.configs import get_config
            from repro.models import init_params, make_cache, decode_step
            cfg = get_config("minicpm-2b", smoke=True).replace(
                kernels="ref", dtype="float32")
            params = init_params(cfg, jax.random.PRNGKey(0))
            mesh = jax.make_mesh((4, 2), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            toks = jnp.array([[3]], jnp.int32)
            pos = jnp.zeros((1, 1), jnp.int32)
            ch = make_cache(cfg, 1, max_len=512)
            cm = make_cache(cfg, 1, max_len=512)
            for t in range(3):
                l_h, ch = decode_step(params, {"tokens": toks,
                                               "positions": pos}, ch, cfg,
                                      None)
                l_m, cm = decode_step(params, {"tokens": toks,
                                               "positions": pos}, cm, cfg,
                                      mesh)
                np.testing.assert_allclose(np.asarray(l_h), np.asarray(l_m),
                                           atol=2e-4, rtol=2e-4)
            print("cp decode parity ok")
        """)

    def test_sharded_train_step_matches_host(self, multidev):
        """One jitted train step on an (2,4) mesh == single-device step."""
        multidev("""
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.configs import get_config
            from repro.distributed.sharding_rules import ShardingRules, named
            from repro.models import init_params, loss_fn
            from repro import optim
            cfg = get_config("musicgen-medium", smoke=True).replace(
                kernels="ref", dtype="float32")
            mesh = jax.make_mesh((2, 4), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            params = init_params(cfg, jax.random.PRNGKey(0))
            rng = np.random.default_rng(0)
            b, s = 4, 16
            batch = {"inputs": {"embeds": jnp.asarray(
                         rng.standard_normal((b, s, cfg.d_model)),
                         jnp.float32),
                     "positions": jnp.tile(jnp.arange(s)[None], (b, 1))},
                     "labels": jnp.asarray(
                         rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
            loss_host = loss_fn(params, batch, cfg, None)
            rules = ShardingRules(cfg, mesh)
            pspecs = rules.param_specs(jax.eval_shape(lambda: params))
            p_sh = jax.tree.map(jax.device_put, params, named(mesh, pspecs))
            loss_mesh = jax.jit(
                lambda p, b_: loss_fn(p, b_, cfg, mesh))(p_sh, batch)
            np.testing.assert_allclose(float(loss_host), float(loss_mesh),
                                       rtol=2e-5)
            print("train parity ok", float(loss_host))
        """)


class TestCrossPodCompression:
    def test_int8_wire_exchange(self, multidev):
        """Wire-level int8 cross-pod gradient exchange: matches f32 within
        quantization error AND the compiled schedule carries s8 all-gathers
        on the pod axis (DESIGN.md §8; EXPERIMENTS §Perf D)."""
        multidev("""
            import re
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.configs import get_config
            from repro.models import init_params, loss_fn
            from repro.distributed.collectives import (crosspod_int8_mean,
                                                       crosspod_f32_mean)
            mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*3)
            cfg = get_config("musicgen-medium", smoke=True).replace(
                kernels="ref", dtype="float32")
            params = init_params(cfg, jax.random.PRNGKey(0))
            rng = np.random.default_rng(0)
            b, s = 8, 16
            batch = {"inputs": {"embeds": jnp.asarray(
                         rng.standard_normal((b, s, cfg.d_model)),
                         jnp.float32),
                     "positions": jnp.tile(jnp.arange(s)[None], (b, 1))},
                     "labels": jnp.asarray(
                         rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
            def make_step(reducer):
                def per_pod(params, batch_pod):
                    loss, grads = jax.value_and_grad(
                        lambda p: loss_fn(p, batch_pod, cfg, None))(params)
                    grads, _ = reducer(grads, "pod")
                    return jax.lax.pmean(loss, "pod"), grads
                return jax.shard_map(
                    per_pod, mesh=mesh,
                    in_specs=(jax.tree.map(lambda _: P(), params),
                              jax.tree.map(lambda x: P("pod"), batch)),
                    out_specs=(P(), jax.tree.map(lambda _: P(), params)),
                    axis_names={"pod"}, check_vma=False)
            step_i8 = jax.jit(make_step(crosspod_int8_mean))
            l8, g8 = step_i8(params, batch)
            l32, g32 = jax.jit(make_step(crosspod_f32_mean))(params, batch)
            assert abs(float(l8) - float(l32)) < 1e-5
            err = max(float(jnp.max(jnp.abs(a - b_))
                            / (jnp.max(jnp.abs(b_)) + 1e-9))
                      for a, b_ in zip(jax.tree.leaves(g8),
                                       jax.tree.leaves(g32)))
            assert err < 0.02, err
            hlo = step_i8.lower(params, batch).compile().as_text()
            assert re.search(r"s8\\[[\\d,]*\\][^\\n]*all-gather", hlo), \\
                "no int8 wire traffic in the schedule"
            print("int8 wire ok", err)
        """)
