"""Sort-once engine invariants (both tiers).

Deliberately hypothesis-free (seeded numpy randomness) so these run even in
the minimal CI image: they are the guard rails for the fused BFS paths.

Covers:
  * ChunkStore sortedness invariant + manifest key ranges + meta-on-flush
  * extsort: heapq k-way merge, duplicates spanning run boundaries under
    dedupe=True, sorted-input sort skip, membership-probe chunk pruning
  * Tier D fused level_step ≡ remove_dupes → remove_all composition, and
    the pass-counter contract (ONE sort pass over the frontier, visited
    set never sorted)
  * Tier J dedupe_subtract_fold ≡ remove_dupes → remove_all → add_all,
    and its one-lexsort trace
  * fused vs unfused BFS end-to-end equivalence on both tiers
"""
import json
import math
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import constructs as C
from repro.core import rlist as RL
from repro.core import types as T
from repro.core.disk import (ChunkStore, DiskList, MembershipProbe,
                             SortedRunSet, breadth_first_search, extsort,
                             level_step, row_keys)


@pytest.fixture
def wd(tmp_path):
    return str(tmp_path)


def _rand_rows(rng, n, width=2, lo=0, hi=50):
    return rng.integers(lo, hi, size=(n, width)).astype(np.uint32)


def _as_sorted_tuples(arr):
    return sorted(map(tuple, np.asarray(arr).tolist()))


def _pancake_gen_next(n):
    """4-bit-packed pancake expansion (the sorted-list engines' encoding)."""
    def gen(chunk):
        codes = chunk[:, 0]
        perms = np.stack([(codes >> (4 * i)) & 0xF for i in range(n)],
                         axis=1).astype(np.int64)
        outs = []
        for k in range(2, n + 1):
            flipped = np.concatenate(
                [perms[:, :k][:, ::-1], perms[:, k:]], axis=1)
            code = np.zeros(chunk.shape[0], np.uint32)
            for i in range(n):
                code |= flipped[:, i].astype(np.uint32) << np.uint32(4 * i)
            outs.append(code)
        return np.concatenate(outs)[:, None]
    return gen


def _pancake_start(n):
    return np.uint32(sum(i << (4 * i) for i in range(n)))


# ------------------------------------------------------------ ChunkStore

class TestSortednessInvariant:
    def test_external_sort_marks_and_append_clears(self, wd):
        rng = np.random.default_rng(0)
        src = ChunkStore(f"{wd}/src", width=2, chunk_rows=16)
        src.append(_rand_rows(rng, 100))
        src.flush()
        assert not src.sorted
        out = ChunkStore(f"{wd}/out", width=2, chunk_rows=16)
        extsort.external_sort(src, out, f"{wd}/tmp", run_rows=32)
        assert out.sorted
        out.append(_rand_rows(rng, 4))
        assert not out.sorted            # any append invalidates the claim

    def test_sorted_flag_and_ranges_persist_on_reopen(self, wd):
        rng = np.random.default_rng(1)
        src = ChunkStore(f"{wd}/src", width=1, chunk_rows=8)
        src.append(_rand_rows(rng, 60, width=1))
        src.flush()
        out = ChunkStore(f"{wd}/s", width=1, chunk_rows=8)
        extsort.external_sort(src, out, f"{wd}/tmp", run_rows=16)
        re = ChunkStore(f"{wd}/s", width=1, chunk_rows=8)
        assert re.sorted
        assert re.n_chunks == out.n_chunks
        for i in range(re.n_chunks):
            lo, hi = re.chunk_range(i)
            keys = row_keys(np.asarray(re.load_chunk(i)))
            assert lo == bytes(keys[0]) and hi == bytes(keys[-1])

    def test_mark_sorted_rejects_unsorted_chunks(self, wd):
        s = ChunkStore(f"{wd}/u", width=1, chunk_rows=4)
        s.append(np.arange(10, 20, dtype=np.uint32)[:, None])
        s.append(np.arange(0, 4, dtype=np.uint32)[:, None])   # below chunk 0
        s.flush()
        with pytest.raises(ValueError):
            s.mark_sorted()

    def test_meta_written_only_on_flush(self, wd):
        s = ChunkStore(f"{wd}/m", width=1, chunk_rows=8)
        s.append(np.arange(100, dtype=np.uint32)[:, None])    # 12 chunk files
        assert s.n_chunks == 12
        # Meta is lazy: nothing persisted until flush() despite 12 chunk
        # writes (in-memory state is authoritative between flushes).
        assert not os.path.exists(os.path.join(s.path, "meta.json"))
        s.flush()
        with open(os.path.join(s.path, "meta.json")) as f:
            meta = json.load(f)
        assert meta["n_chunks"] == 13 and meta["total_rows"] == 100


# --------------------------------------------------------------- extsort

class TestExtsortEdges:
    def test_dupes_spanning_run_boundaries_dedupe(self, wd):
        # 3 distinct values, each repeated far beyond run_rows, so every
        # run boundary splits a duplicate group — the dedupe carry must
        # hold across runs, not just across blocks.
        vals = np.repeat(np.array([7, 3, 9], np.uint32), 40)[:, None]
        src = ChunkStore(f"{wd}/src", width=1, chunk_rows=8)
        src.append(vals)
        src.flush()
        out = ChunkStore(f"{wd}/out", width=1, chunk_rows=8)
        extsort.external_sort(src, out, f"{wd}/tmp", run_rows=16, dedupe=True)
        assert out.read_all()[:, 0].tolist() == [3, 7, 9]
        assert out.sorted

    def test_heap_merge_matches_oracle(self, wd):
        rng = np.random.default_rng(2)
        data = _rand_rows(rng, 500, width=2, hi=40)
        src = ChunkStore(f"{wd}/src", width=2, chunk_rows=32)
        src.append(data)
        src.flush()
        out = ChunkStore(f"{wd}/out", width=2, chunk_rows=32)
        extsort.external_sort(src, out, f"{wd}/tmp", run_rows=64)
        got = out.read_all()
        want = data[np.argsort(row_keys(data), kind="stable")]
        assert np.array_equal(got, want)

    def test_sorted_input_skips_sort(self, wd):
        rng = np.random.default_rng(3)
        src = ChunkStore(f"{wd}/src", width=1, chunk_rows=16)
        src.append(_rand_rows(rng, 200, width=1))
        src.flush()
        mid = ChunkStore(f"{wd}/mid", width=1, chunk_rows=16)
        extsort.external_sort(src, mid, f"{wd}/t1", run_rows=64)
        extsort.reset_stats()
        out = ChunkStore(f"{wd}/out", width=1, chunk_rows=16)
        extsort.external_sort(mid, out, f"{wd}/t2", run_rows=64, dedupe=True)
        assert extsort.STATS["sort_passes"] == 0
        assert extsort.STATS["sorts_skipped"] == 1
        assert out.read_all()[:, 0].tolist() == sorted(
            set(mid.read_all()[:, 0].tolist()))

    def test_membership_probe_prunes_disjoint_chunks(self, wd):
        lo_rows = np.arange(0, 64, dtype=np.uint32)[:, None]
        hi_rows = np.arange(10_000, 10_064, dtype=np.uint32)[:, None]
        src = ChunkStore(f"{wd}/src", width=1, chunk_rows=8)
        src.append(np.concatenate([lo_rows, hi_rows]))
        src.flush()
        b = ChunkStore(f"{wd}/b", width=1, chunk_rows=8)
        extsort.external_sort(src, b, f"{wd}/t", run_rows=256)
        extsort.reset_stats()
        probe = MembershipProbe(b)
        q = np.arange(10_000, 10_032, dtype=np.uint32)[:, None]
        member = probe.contains(row_keys(q))
        assert member.all()
        assert extsort.STATS["chunks_pruned"] >= 8   # low chunks never loaded


# -------------------------------------------------- Tier D fused level

def _build_frontier_and_visited(wd, rng, n_raw=300, n_visited=200, width=2):
    raw = ChunkStore(f"{wd}/raw", width=width, chunk_rows=16)
    raw.append(_rand_rows(rng, n_raw, width=width))
    raw.flush()
    run_set = SortedRunSet(wd, width, chunk_rows=16, name="vis")
    visited = _rand_rows(rng, n_visited, width=width)
    for i, part in enumerate(np.array_split(visited, 3)):
        src = ChunkStore(f"{wd}/vsrc{i}", width=width, chunk_rows=16)
        src.append(part)
        src.flush()
        run = ChunkStore(f"{wd}/vrun{i}", width=width, chunk_rows=16)
        extsort.external_sort(src, run, f"{wd}/vt{i}", run_rows=64,
                              dedupe=True)
        src.destroy()
        run_set.add_run(run)
    return raw, run_set, visited


class TestLevelStepFusion:
    def test_matches_reference_composition(self, wd):
        rng = np.random.default_rng(4)
        raw, run_set, visited = _build_frontier_and_visited(wd, rng)
        raw_rows = raw.read_all()
        out = ChunkStore(f"{wd}/out", width=2, chunk_rows=16)
        level_step(raw, run_set.runs, out, f"{wd}/lt", run_rows=64)
        got = _as_sorted_tuples(out.read_all())

        # Reference: the paper's literal composition on DiskList.
        ref = DiskList(wd, width=2, chunk_rows=16)
        ref.add(raw_rows)
        ref.remove_dupes(run_rows=64)
        vis = DiskList(wd, width=2, chunk_rows=16)
        vis.add(visited)
        ref.remove_all(vis, run_rows=64)
        want = _as_sorted_tuples(ref.read_all())
        assert got == want

        # Oracle for good measure.
        vis_set = set(map(tuple, visited.tolist()))
        oracle = sorted({tuple(r) for r in raw_rows.tolist()} - vis_set)
        assert got == oracle
        assert out.sorted                 # ready to fold into the run set

    def test_one_sort_pass_never_sorts_visited(self, wd):
        rng = np.random.default_rng(5)
        raw, run_set, _ = _build_frontier_and_visited(
            wd, rng, n_raw=400, n_visited=600)
        extsort.reset_stats()
        out = ChunkStore(f"{wd}/out", width=2, chunk_rows=16)
        level_step(raw, run_set.runs, out, f"{wd}/lt", run_rows=64)
        # Exactly ONE sort pass, covering exactly the raw frontier rows;
        # the visited runs are only read (merge/probe), never sorted.
        assert extsort.STATS["sort_passes"] == 1
        assert extsort.STATS["rows_sorted"] == 400

    def test_runset_compaction_is_merge_not_sort(self, wd):
        rng = np.random.default_rng(6)
        rs = SortedRunSet(wd, 1, chunk_rows=16, max_runs=2, name="rs")
        for i in range(3):
            src = ChunkStore(f"{wd}/s{i}", width=1, chunk_rows=16)
            src.append(_rand_rows(rng, 50, width=1, hi=1000))
            src.flush()
            run = ChunkStore(f"{wd}/r{i}", width=1, chunk_rows=16)
            extsort.external_sort(src, run, f"{wd}/t{i}", run_rows=32,
                                  dedupe=True)
            src.destroy()
            rs.add_run(run)
        union = sorted({int(x) for r in rs.runs for x in r.read_all()[:, 0]})
        extsort.reset_stats()
        assert rs.maybe_compact()
        assert len(rs.runs) == 1
        assert extsort.STATS["sort_passes"] == 0      # merge pass only
        assert rs.runs[0].read_all()[:, 0].tolist() == union
        rs.destroy()


class TestTieredCompaction:
    """SortedRunSet policy knob: default 'full' behaviour is unchanged;
    'tiered' merges only comparable-size runs."""

    def _sorted_run(self, wd, rng, name, nrows):
        src = ChunkStore(f"{wd}/{name}_src", width=1, chunk_rows=16)
        src.append(_rand_rows(rng, nrows, width=1, hi=100_000))
        src.flush()
        run = ChunkStore(f"{wd}/{name}", width=1, chunk_rows=16)
        extsort.external_sort(src, run, f"{wd}/{name}_t", run_rows=64,
                              dedupe=True)
        src.destroy()
        return run

    def test_default_policy_is_full_merge(self, wd):
        rng = np.random.default_rng(7)
        rs = SortedRunSet(wd, 1, chunk_rows=16, max_runs=2, name="rs")
        assert rs.policy == "full"                  # default preserved
        for i in range(4):
            rs.add_run(self._sorted_run(wd, rng, f"r{i}", 40))
        union = sorted({int(x) for r in rs.runs for x in r.read_all()[:, 0]})
        assert rs.maybe_compact()
        assert len(rs.runs) == 1                    # everything re-merged
        assert rs.runs[0].read_all()[:, 0].tolist() == union
        rs.destroy()

    def test_tiered_leaves_big_runs_untouched(self, wd):
        rng = np.random.default_rng(8)
        rs = SortedRunSet(wd, 1, chunk_rows=16, max_runs=2, name="rs",
                          policy="tiered", size_ratio=2)
        big = self._sorted_run(wd, rng, "big", 2000)
        rs.add_run(big)
        for i in range(3):
            rs.add_run(self._sorted_run(wd, rng, f"small{i}", 30))
        union = sorted({int(x) for r in rs.runs for x in r.read_all()[:, 0]})
        extsort.reset_stats()
        assert rs.maybe_compact()
        # the big settled run must survive identical; the smalls merged
        assert any(r is big for r in rs.runs)
        assert len(rs.runs) == 2
        assert extsort.STATS["sort_passes"] == 0    # still merge, not sort
        got = sorted({int(x) for r in rs.runs for x in r.read_all()[:, 0]})
        assert got == union
        rs.destroy()

    def test_tiered_absorbs_comparable_sizes(self, wd):
        rng = np.random.default_rng(9)
        rs = SortedRunSet(wd, 1, chunk_rows=16, max_runs=2, name="rs",
                          policy="tiered", size_ratio=2)
        # all comparable → one merge collapses them all
        for i in range(4):
            rs.add_run(self._sorted_run(wd, rng, f"r{i}", 50))
        assert rs.maybe_compact()
        assert len(rs.runs) == 1
        rs.destroy()

    def test_bfs_tiered_knob_equivalent_levels(self, wd):
        n = 5
        gen_next = _pancake_gen_next(n)
        start = _pancake_start(n)
        sizes_full, all_full = breadth_first_search(
            f"{wd}/full", np.array([[start]], np.uint32), gen_next,
            width=1, chunk_rows=256, max_runs=2)
        sizes_tier, all_tier = breadth_first_search(
            f"{wd}/tier", np.array([[start]], np.uint32), gen_next,
            width=1, chunk_rows=256, max_runs=2, compaction="tiered")
        assert sizes_tier == sizes_full
        assert np.array_equal(all_tier.read_all(), all_full.read_all())
        all_full.destroy()
        all_tier.destroy()


class TestDiskBFSFusedVsUnfused:
    def test_pancake_n5_equivalent(self, wd):
        n = 5
        gen_next = _pancake_gen_next(n)
        start = np.array([[_pancake_start(n)]], np.uint32)
        sizes_f, all_f = breadth_first_search(
            f"{wd}/f", start, gen_next, width=1, chunk_rows=32, max_runs=2)
        sizes_u, all_u = breadth_first_search(
            f"{wd}/u", start, gen_next, width=1, chunk_rows=32, fused=False)
        assert sizes_f == sizes_u
        assert sum(sizes_f) == math.factorial(n)
        got_f = _as_sorted_tuples(all_f.read_all())
        got_u = _as_sorted_tuples(all_u.read_all())
        assert got_f == got_u
        all_f.destroy()
        all_u.destroy()


# -------------------------------------------------- Tier J fused level

def _reference_dsf(nxt_rows, nxt_valid, all_lst, next_cap):
    nxt = RL.make(next_cap, nxt_rows.shape[1])
    nxt, overflow = RL.add(nxt, nxt_rows, nxt_valid)
    nxt = RL.remove_dupes(nxt)
    nxt = RL.remove_all(nxt, all_lst)
    all2, ov2 = RL.add_all(all_lst, nxt)
    return nxt, all2, overflow | ov2


class TestTierJFusedLevel:
    def test_dedupe_subtract_fold_matches_reference(self):
        rng = np.random.default_rng(7)
        for case in range(20):
            m = int(rng.integers(1, 40))
            na = int(rng.integers(1, 30))
            width = int(rng.integers(1, 3))
            nxt_rows = jnp.asarray(_rand_rows(rng, m, width=width, hi=20))
            nxt_valid = jnp.asarray(rng.random(m) < 0.8)
            all_rows = np.unique(_rand_rows(rng, na, width=width, hi=20),
                                 axis=0)
            all_lst = RL.from_rows(jnp.asarray(all_rows),
                                   capacity=na + 8)
            next_cap = m + 4
            got_n, got_a, got_ov = C.dedupe_subtract_fold(
                nxt_rows, nxt_valid, all_lst, next_cap)
            want_n, want_a, want_ov = _reference_dsf(
                nxt_rows, nxt_valid, all_lst, next_cap)
            assert (_as_sorted_tuples(RL.to_numpy(got_n))
                    == _as_sorted_tuples(RL.to_numpy(want_n))), case
            assert (_as_sorted_tuples(RL.to_numpy(got_a))
                    == _as_sorted_tuples(RL.to_numpy(want_a))), case
            assert bool(got_ov) == bool(want_ov), case

    def test_fused_level_traces_one_lexsort(self):
        all_lst = RL.from_rows(jnp.array([[1], [2]], jnp.uint32), capacity=16)
        rows = jnp.array([[2], [3], [3], [4]], jnp.uint32)
        valid = jnp.ones((4,), bool)
        T.reset_sort_stats()
        C.dedupe_subtract_fold(rows, valid, all_lst, 8)
        assert T.SORT_STATS["lexsorts"] == 1
        T.reset_sort_stats()
        _reference_dsf(rows, valid, all_lst, 8)
        assert T.SORT_STATS["lexsorts"] >= 2      # the fusion's savings

    def test_bfs_fused_matches_reference_pancake(self):
        n = 5

        def gen_next(row):
            code = row[0]
            perm = jnp.stack(
                [(code >> jnp.uint32(4 * i)) & jnp.uint32(0xF)
                 for i in range(n)]).astype(jnp.int32)
            outs = []
            for k in range(2, n + 1):
                flipped = jnp.concatenate([perm[:k][::-1], perm[k:]])
                acc = jnp.uint32(0)
                for i in range(n):
                    acc = acc | (flipped[i].astype(jnp.uint32)
                                 << jnp.uint32(4 * i))
                outs.append(acc)
            return jnp.stack(outs)[:, None], jnp.ones((n - 1,), bool)

        start = np.array([[sum(i << (4 * i) for i in range(n))]], np.uint32)
        total = math.factorial(n)
        res_f = C.breadth_first_search(start, gen_next, fanout=n - 1, width=1,
                                       all_capacity=total + 8,
                                       level_capacity=total + 8)
        res_r = C.breadth_first_search(start, gen_next, fanout=n - 1, width=1,
                                       all_capacity=total + 8,
                                       level_capacity=total + 8, fused=False)
        assert res_f.level_sizes == res_r.level_sizes
        assert sum(res_f.level_sizes) == total
        assert (_as_sorted_tuples(RL.to_numpy(res_f.all))
                == _as_sorted_tuples(RL.to_numpy(res_r.all)))
