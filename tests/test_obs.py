"""Observability layer (core/obs.py + disk/trace.py).

Covers the PR-7 contracts end to end:

  * zero cost when disabled: ``obs.ACTIVE`` is False by default, every
    ``span()`` call returns the shared no-op, an untraced run writes no
    trace file and mutates no tracing state,
  * span mechanics: nesting (parent/depth), wall-time monotonicity,
    counter-delta metrics, shard tagging, out-of-LIFO close tolerance,
  * the registry absorbing the legacy STATS dicts (same live objects),
    snapshot/merge associativity (hypothesis property) with the empty
    snapshot as identity,
  * ``obs.scope()`` delta windows — live while open, frozen at close,
    never resetting the module globals (the bench best-of fix),
  * JSONL trace round-trip + per-level report + Chrome export schema,
  * the sharded-totals contract (ISSUE-7 satellite): spawn == inline ==
    single-process byte counters on pancake n=5, even with tracing off,
  * the acceptance pin: a traced spawn run's per-shard ``pass.rw`` byte
    metrics sum EXACTLY to the single-process run's byte counters,
  * recovery tracing: a killed-and-recovered run books one
    ``recovery.rollback`` span and tags the replayed level.

Module-level imports stay numpy-only (the test_cluster.py convention):
spawn workers re-import this module's generator imports.
"""
import json
import math
import os
import sys

import numpy as np
import pytest

from repro.core import obs
from repro.core.disk import extsort, faults, trace
from repro.core.disk import implicit_bfs

from _hypothesis_compat import given, settings, st

sys.path.append(os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "examples"))
from pancake_bits import NeighborsNp                  # noqa: E402

# Fault-free pancake-5 flip-distance histogram (pinned by test_cluster).
PANCAKE5 = [1, 4, 12, 35, 48, 20]


@pytest.fixture(autouse=True)
def _clean_obs():
    """Tracing is off on entry and exit; a failing test can't leak an
    open session, the env hook, or buffered spans into its neighbours."""
    assert trace._SESSION is None, "a previous test leaked a trace session"
    yield
    if trace._SESSION is not None:
        trace.stop()
    os.environ.pop(obs.ENV_VAR, None)
    obs.disable()


def _implicit_levels(wd, n=5, nshards=1, mode="spawn", **kw):
    """Pancake-n implicit (2-bit array) BFS; returns level sizes.

    chunk_elems=20 (a multiple of the 4 packed values per byte) divides
    both the single-process array (120 elements, n=5) and the 60-element
    shard blocks, so chunk boundaries — and therefore partial-pass byte
    counts — line up exactly across layouts (what the byte-total
    equality tests below compare)."""
    from repro.core import ranking as R
    total = math.factorial(n)
    start = int(R.rank_np(np.arange(n)[None, :])[0])
    sizes, bits = implicit_bfs(
        os.path.join(wd, "b"), total, [start], NeighborsNp(n),
        chunk_elems=20, nshards=nshards, shard_mode=mode, **kw)
    bits.destroy()
    return sizes


# ----------------------------------------------------------- zero-cost off

class TestZeroCost:

    def test_off_by_default(self):
        assert obs.ACTIVE is False
        assert obs.ENV_VAR not in os.environ
        s = obs.span("bfs.level", level=1)
        assert s is obs._NULL                 # the shared no-op, no alloc
        with s:
            s.set(extra=1)
        assert obs.drain_spans() == []

    def test_gauge_and_observe_are_noops_when_off(self):
        obs.gauge("g", 1.5)
        obs.observe("h", 42)
        assert obs._GAUGES == {} and obs._HISTS == {}

    def test_untraced_run_books_nothing(self, tmp_path):
        sizes = _implicit_levels(str(tmp_path), n=4, nshards=1)
        assert sum(sizes) == 24 and len(sizes) - 1 == 4
        assert obs.ACTIVE is False
        assert obs.drain_spans() == []
        assert obs._GAUGES == {} and obs._HISTS == {}
        assert obs.ENV_VAR not in os.environ
        assert not [p for p in tmp_path.rglob("*.jsonl")]


# ------------------------------------------------------------- percentile

class TestHistogramPercentile:
    """Histogram.percentile(q) — the serve bench's p50/p99 columns."""

    def test_single_bucket_interpolates(self):
        h = obs.Histogram()
        for _ in range(10):
            h.observe(3)                      # all land in (2, 4]
        assert h.percentile(0) == pytest.approx(2.0)
        assert h.percentile(50) == pytest.approx(3.0)
        assert h.percentile(100) == pytest.approx(4.0)

    def test_multi_bucket_walk(self):
        h = obs.Histogram()
        for v in (1, 1, 1, 10, 100):          # buckets 0 (x3), 4, 7
            h.observe(v)
        assert h.percentile(50) <= 1.0        # rank 2.5 inside bucket 0
        assert 8 < h.percentile(75) <= 16     # rank 3.75 → bucket 4
        assert 64 < h.percentile(100) <= 128  # top of bucket 7

    def test_bucket_edge_exact(self):
        # q at a bucket boundary must return that bucket's upper edge
        h = obs.Histogram()
        for v in (1, 4):
            h.observe(v)
        assert h.percentile(50) == pytest.approx(1.0)
        assert h.percentile(100) == pytest.approx(4.0)

    def test_monotone_in_q(self):
        h = obs.Histogram()
        rng = np.random.default_rng(0)
        for v in rng.uniform(0.5, 5000.0, 300):
            h.observe(v)
        qs = [0, 1, 10, 25, 50, 75, 90, 99, 100]
        ps = [h.percentile(q) for q in qs]
        assert ps == sorted(ps)

    def test_bounded_by_bucket_resolution(self):
        # the estimate never strays beyond the covering power-of-2 bucket
        h = obs.Histogram()
        for _ in range(1000):
            h.observe(777)                    # bucket (512, 1024]
        for q in (1, 50, 99):
            assert 512 < h.percentile(q) <= 1024

    def test_empty_and_bad_q(self):
        h = obs.Histogram()
        assert h.percentile(50) == 0.0
        h.observe(2)
        with pytest.raises(ValueError):
            h.percentile(-1)
        with pytest.raises(ValueError):
            h.percentile(101)


# ---------------------------------------------------------- span mechanics

class TestSpanMechanics:

    def test_nesting_parent_depth_and_timing(self):
        obs.enable()
        with obs.span("outer", level=1):
            with obs.span("inner"):
                pass
        inner, outer = obs.drain_spans()      # inner closes (emits) first
        assert inner["sid"] == "inner" and outer["sid"] == "outer"
        assert inner["parent"] == "outer" and inner["depth"] == 1
        assert outer["parent"] is None and outer["depth"] == 0
        assert inner["ts_us"] >= outer["ts_us"]
        assert 0 <= inner["dur_us"] <= outer["dur_us"]
        assert outer["attrs"] == {"level": 1}

    def test_sequential_spans_monotonic(self):
        obs.enable()
        for i in range(5):
            with obs.span("step", i=i):
                pass
        recs = obs.drain_spans()
        ts = [r["ts_us"] for r in recs]
        assert ts == sorted(ts)
        assert [r["attrs"]["i"] for r in recs] == list(range(5))

    def test_metric_deltas(self):
        d = obs.counters("obstest", {"x": 0})
        obs.enable()
        with obs.span("work"):
            d["x"] += 3
        with obs.span("idle"):
            pass
        work, idle = obs.drain_spans()
        assert work["metrics"] == {"obstest.x": 3}
        assert "metrics" not in idle           # zero deltas are omitted

    def test_shard_tagging(self):
        obs.enable(shard=7)
        with obs.span("a"):
            pass
        with obs.span("b", shard=2):          # explicit tag wins
            pass
        a, b = obs.drain_spans()
        assert a["shard"] == 7 and b["shard"] == 2
        assert "attrs" not in b               # shard= is split out

    def test_out_of_lifo_close_is_tolerated(self):
        obs.enable()
        s1 = obs.span("gen_held").__enter__()
        s2 = obs.span("other").__enter__()
        s1.__exit__(None, None, None)         # generator-held span first
        s2.__exit__(None, None, None)
        recs = obs.drain_spans()
        assert [r["sid"] for r in recs] == ["gen_held", "other"]
        assert obs._STACK == []

    def test_span_duration_histogram(self):
        obs.enable()
        with obs.span("timed"):
            pass
        assert obs._HISTS["span.timed.us"].count == 1

    def test_histogram_pow2_buckets(self):
        h = obs.Histogram()
        for v in (0, 1, 2, 3, 4, 5, 1024):
            h.observe(v)
        assert h.buckets == {0: 2, 1: 1, 2: 2, 3: 1, 10: 1}
        assert h.count == 7 and h.total == 1039.0


# ------------------------------------------------------- registry + merge

_INTS = st.integers(min_value=0, max_value=1 << 40)
_SNAP = st.fixed_dictionaries({
    "counters": st.dictionaries(
        st.sampled_from(["extsort", "bits", "tierj"]),
        st.dictionaries(st.sampled_from(["x", "y", "z"]), _INTS, max_size=3),
        max_size=3),
    "gauges": st.dictionaries(st.sampled_from(["g1", "g2"]),
                              st.integers(min_value=0, max_value=99),
                              max_size=2),
    "hists": st.dictionaries(
        st.sampled_from(["h1", "h2"]),
        st.fixed_dictionaries({
            "buckets": st.dictionaries(st.integers(min_value=0, max_value=8),
                                       st.integers(min_value=1, max_value=99),
                                       max_size=3),
            "count": st.integers(min_value=0, max_value=300),
            "total": st.integers(min_value=0, max_value=1000)}),
        max_size=2),
})


class TestRegistryMerge:

    def test_absorbs_legacy_stats_dicts(self):
        """The compatibility keystone: the legacy module dicts ARE the
        registry namespaces — the very same mutable objects."""
        from repro.core.disk import bitarray as DBA
        assert obs.counters("extsort", {}) is extsort.STATS
        assert obs.counters("bits", {}) is DBA.STATS

    def test_counters_live_dict_visible_in_snapshot(self):
        d = obs.counters("obstest2", {"n": 0})
        d["n"] += 5
        assert obs.snapshot()["counters"]["obstest2"]["n"] == d["n"]

    def test_merge_empty_identity(self):
        a = {"counters": {"ns": {"k": 3}}, "gauges": {"g": 1.0},
             "hists": {"h": {"buckets": {0: 2}, "count": 2, "total": 2.0}}}
        empty = {"counters": {}, "gauges": {}, "hists": {}}
        assert obs.merge(a, empty) == obs.merge(empty, a)

    @settings(max_examples=60, deadline=None)
    @given(_SNAP, _SNAP, _SNAP)
    def test_merge_associative(self, a, b, c):
        # Integer-valued totals keep float addition exact, so this is
        # true equality, not approximate: fold order can't matter.
        assert obs.merge(obs.merge(a, b), c) == obs.merge(a, obs.merge(b, c))

    def test_counter_deltas_flat_nonzero(self):
        before = {"counters": {"ns": {"a": 1, "b": 2}}}
        after = {"counters": {"ns": {"a": 4, "b": 2}, "new": {"c": 7}}}
        assert obs.counter_deltas(after, before) == {"ns.a": 3, "new.c": 7}


class TestScope:

    def test_live_then_frozen(self):
        d = obs.counters("scopetest", {"n": 0})
        with obs.scope() as sc:
            d["n"] += 2
            assert sc.delta()["scopetest"]["n"] == 2    # live while open
            d["n"] += 3
        frozen = sc.delta()["scopetest"]["n"]
        assert frozen == 5
        d["n"] += 10
        assert sc.delta()["scopetest"]["n"] == 5        # frozen at close

    def test_overlapping_scopes_independent(self):
        """No global reset: two observers each get their own window —
        exactly what reset_stats() between bench repeats broke."""
        d = obs.counters("scopetest2", {"n": 0})
        s1 = obs.Scope()
        d["n"] += 1
        s2 = obs.Scope()
        d["n"] += 1
        assert s1.delta()["scopetest2"]["n"] == 2
        assert s2.delta()["scopetest2"]["n"] == 1


# ------------------------------------------------------- trace round-trip

class TestTraceRoundTrip:

    def _traced_run(self, tmp_path):
        p = str(tmp_path / "run.jsonl")
        trace.start(p, meta={"example": "unit", "n": 4})
        assert os.environ[obs.ENV_VAR] == "1"
        sizes = _implicit_levels(str(tmp_path), n=4, nshards=1)
        assert trace.stop() == p
        return p, sizes

    def test_round_trip_and_report(self, tmp_path, capsys):
        p, sizes = self._traced_run(tmp_path)
        assert obs.ACTIVE is False and obs.ENV_VAR not in os.environ
        meta, spans, summary = trace.read(p)
        assert meta["example"] == "unit" and meta["version"] == 1
        sids = {s["sid"] for s in spans}
        assert "bfs.level" in sids and "pass.rw" in sids
        assert "bits" in summary["counters"]
        rows = trace.report(p)
        out = capsys.readouterr().out
        assert "level" in out and "skew%" in out and "total" in out
        assert rows and sum(r["passes"] for r in rows) > 0
        assert sum(r["bytes"] for r in rows) > 0
        assert not any(r["replay"] for r in rows)       # fault-free run

    def test_chrome_export_schema(self, tmp_path):
        p, _ = self._traced_run(tmp_path)
        out = trace.export_chrome(p)
        assert out == str(tmp_path / "run.chrome.json")
        cj = json.load(open(out))
        evs = cj["traceEvents"]
        assert evs
        for e in evs:
            assert e["ph"] in ("X", "M")
            assert {"name", "ts", "pid", "tid"} <= set(e)
        xs = [e for e in evs if e["ph"] == "X"]
        assert xs
        assert all(e["ts"] >= 0 and e["dur"] >= 0 and e["cat"] == "roomy"
                   for e in xs)
        assert any(e["ph"] == "M" and e["args"]["name"] == "coordinator"
                   for e in evs)
        assert cj["otherData"]["example"] == "unit"

    def test_cli(self, tmp_path, capsys):
        p, _ = self._traced_run(tmp_path)
        assert trace.main(["report", p]) == 0
        out2 = str(tmp_path / "alt.json")
        assert trace.main(["export-chrome", p, "-o", out2]) == 0
        assert json.load(open(out2))["traceEvents"]

    def test_start_twice_raises_stop_idempotent(self, tmp_path):
        assert trace.stop() is None            # nothing active: a no-op
        trace.start(str(tmp_path / "a.jsonl"))
        with pytest.raises(RuntimeError, match="already active"):
            trace.start(str(tmp_path / "b.jsonl"))
        trace.stop()
        assert trace.stop() is None


# ------------------------------------------ sharded totals + acceptance

def _bits_delta(wd, **kw):
    with obs.scope() as sc:
        sizes = _implicit_levels(wd, n=5, **kw)
    assert sizes == PANCAKE5
    return sc.delta()["bits"]


class TestShardedTotals:

    def test_spawn_inline_single_totals_agree(self, tmp_path):
        """The satellite-2 contract: spawn workers' counters are folded
        back to the coordinator at every level barrier even with tracing
        OFF, so the three execution modes book identical byte totals."""
        assert obs.ACTIVE is False
        single = _bits_delta(str(tmp_path / "s1"), nshards=1)
        inline = _bits_delta(str(tmp_path / "s2"), nshards=2, mode="inline")
        spawn = _bits_delta(str(tmp_path / "s3"), nshards=2, mode="spawn")
        for k in ("bytes_read", "bytes_written"):
            assert single[k] == inline[k] == spawn[k] > 0, k
        # Per-shard pass counters agree between the two sharded modes.
        assert inline["sync_passes"] == spawn["sync_passes"] > 0

    def test_spawn_trace_per_shard_bytes_sum_to_single_process(self,
                                                               tmp_path):
        """The PR acceptance pin: the merged trace's per-shard pass.rw
        byte metrics sum EXACTLY to the single-process byte counters."""
        with obs.scope() as sc:
            assert _implicit_levels(str(tmp_path / "ref"),
                                    n=5, nshards=1) == PANCAKE5
        ref = sc.delta()["bits"]
        ref_bytes = ref["bytes_read"] + ref["bytes_written"]

        p = str(tmp_path / "run.jsonl")
        trace.start(p, meta={"example": "unit-sharded"})
        assert _implicit_levels(str(tmp_path / "sh"), n=5, nshards=2,
                                mode="spawn") == PANCAKE5
        trace.stop()

        _, spans, _ = trace.read(p)
        per_shard = {}
        for s in spans:
            if s["sid"] == "pass.rw" and s.get("shard") is not None:
                m = s.get("metrics") or {}
                per_shard[s["shard"]] = (per_shard.get(s["shard"], 0)
                                         + m.get("bits.bytes_read", 0)
                                         + m.get("bits.bytes_written", 0))
        assert set(per_shard) == {0, 1}
        assert all(v > 0 for v in per_shard.values())
        assert sum(per_shard.values()) == ref_bytes


# ------------------------------------------------------ recovery tracing

class TestRecoveryTrace:

    def test_rollback_span_and_replay_tags(self, tmp_path):
        """Kill shard 1 mid-search (spawn mode): the merged trace books
        exactly one recovery.rollback span and the replayed coordinator
        level carries replay=True — what the report marks with ``*``."""
        saved = os.environ.pop(faults.ENV_VAR, None)
        faults.uninstall()
        extsort.reset_stats()
        os.environ[faults.ENV_VAR] = "worker_level:kill:shard=1:level=2"
        p = str(tmp_path / "chaos.jsonl")
        trace.start(p, meta={"example": "unit-chaos"})
        try:
            sizes = _implicit_levels(str(tmp_path), n=5, nshards=2,
                                     mode="spawn",
                                     checkpoint_dir=str(tmp_path / "ck"),
                                     max_recoveries=2)
        finally:
            trace.stop()
            os.environ.pop(faults.ENV_VAR, None)
            faults.uninstall()
            if saved is not None:
                os.environ[faults.ENV_VAR] = saved
        assert sizes == PANCAKE5
        assert extsort.STATS["recoveries"] == 1

        _, spans, _ = trace.read(p)
        rollbacks = [s for s in spans if s["sid"] == "recovery.rollback"]
        assert len(rollbacks) == 1
        assert rollbacks[0]["attrs"]["shard_lost"] == 1
        assert rollbacks[0]["shard"] is None       # coordinator-side span
        replayed = [s for s in spans if s["sid"] == "bfs.level"
                    and (s.get("attrs") or {}).get("replay")]
        assert replayed
        assert all(s["shard"] is None for s in replayed)
        rows = trace.level_rows(spans)
        assert any(r["replay"] for r in rows)
        assert sum(r["recoveries"] for r in rows) == 1
