"""Data pipeline: (seed, step) determinism, streams, disk-backed corpus."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DiskTokenStream, SyntheticStream, make_batch, synth_tokens


class TestDeterminism:
    def test_same_seed_step_same_batch(self):
        a = synth_tokens(1, 5, 4, 16, 1000)
        b = synth_tokens(1, 5, 4, 16, 1000)
        assert np.array_equal(a, b)
        c = synth_tokens(1, 6, 4, 16, 1000)
        assert not np.array_equal(a, c)

    def test_labels_are_shifted_tokens(self):
        cfg = get_config("minicpm-2b", smoke=True)
        batch = make_batch(cfg, seed=0, step=0, batch=2, seq=8)
        toks = np.asarray(batch["inputs"]["tokens"])
        labels = np.asarray(batch["labels"])
        assert np.array_equal(toks[:, 1:], labels[:, :-1])

    def test_mrope_positions_three_rows(self):
        cfg = get_config("qwen2-vl-2b", smoke=True)
        batch = make_batch(cfg, 0, 0, 2, 8)
        assert batch["inputs"]["positions"].shape == (2, 8, 3)

    def test_frontend_stub_embeds(self):
        cfg = get_config("musicgen-medium", smoke=True)
        batch = make_batch(cfg, 0, 0, 2, 8)
        assert "embeds" in batch["inputs"]
        assert batch["inputs"]["embeds"].shape == (2, 8, cfg.d_model)


class TestStreams:
    def test_synthetic_stream_prefetch(self):
        cfg = get_config("minicpm-2b", smoke=True)
        it = SyntheticStream(cfg, batch=2, seq=8, seed=3)
        b0 = next(it)
        b1 = next(it)
        assert not np.array_equal(b0["inputs"]["tokens"],
                                  b1["inputs"]["tokens"])
        # replay from step 0 gives the same first batch
        it2 = SyntheticStream(cfg, batch=2, seq=8, seed=3)
        b0r = next(it2)
        assert np.array_equal(b0["inputs"]["tokens"],
                              b0r["inputs"]["tokens"])
        it.close(); it2.close()

    def test_disk_corpus_roundtrip(self, tmp_path):
        cfg = get_config("minicpm-2b", smoke=True)
        d = str(tmp_path / "corpus")
        DiskTokenStream.write_corpus(d, cfg, batch=2, seq=8, n_steps=4,
                                     seed=1)
        it = DiskTokenStream(d, cfg, batch=2, seq=8)
        b0 = next(it)
        want = synth_tokens(1, 0, 2, 9, cfg.vocab_size)
        assert np.array_equal(np.asarray(b0["inputs"]["tokens"]),
                              want[:, :8])
        assert np.array_equal(np.asarray(b0["labels"]), want[:, 1:])
        # step 4 wraps to chunk 0
        for _ in range(3):
            next(it)
        b4 = next(it)
        assert np.array_equal(np.asarray(b4["inputs"]["tokens"]),
                              want[:, :8])
