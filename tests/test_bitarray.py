"""Implicit-BFS subsystem invariants: rank/unrank bijection, the 2-bit
delayed-update arrays on both tiers, and engine equivalence.

Hypothesis-free (seeded numpy randomness) like test_sort_once.py — these
guard the second BFS engine and must run in the minimal CI image.

Covers:
  * ranking: Myrvold–Ruskey roundtrip + bijectivity, NumPy ≡ jnp (double-
    word uint32 arithmetic), multi-word ranks for n > 12, row codec order
  * DiskBitArray: pack codec, log/sync contract vs a dict oracle, combine
    semantics, fused transform, byte-histogram counts, log spill to disk
  * RoomyBitArray: queue/sync vs oracle, packed write disjointness,
    mark_packed duplicate/OOB safety, rotate_count
  * implicit BFS ≡ sorted-list BFS level profiles on both tiers (pancake)
  * sharded_mark_sync through the bucket exchange on a fake-device mesh
"""
import math
import os
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitarray as BA
from repro.core import constructs as C
from repro.core import ranking as R
from repro.core.disk import DiskBitArray, implicit_bfs
from repro.core.disk import bitarray as DBA

# The pancake neighbor generators and the sorted-list oracle live with the
# example CLI (benchmarks/bfs.py imports them the same way) — one copy.
sys.path.append(os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "examples"))
from pancake_bits import (neighbor_jnp as _pancake_neighbor_jnp,        # noqa: E402
                          neighbors_np as _pancake_neighbors_np,
                          sorted_list_levels as _sorted_list_levels)


@pytest.fixture
def wd(tmp_path):
    return str(tmp_path)


# ------------------------------------------------------------- ranking

class TestRanking:
    def test_unrank_is_bijective_and_rank_inverts(self):
        for n in range(1, 7):
            f = math.factorial(n)
            ranks = np.arange(f, dtype=np.uint64)
            perms = R.unrank_np(n, ranks)
            assert np.all(np.sort(perms, axis=1) == np.arange(n))
            assert len({tuple(p) for p in perms.tolist()}) == f
            assert np.array_equal(R.rank_np(perms), ranks)

    def test_jnp_matches_numpy_single_word(self):
        n = 6
        ranks = np.arange(math.factorial(n), dtype=np.uint64)
        perms = R.unrank_np(n, ranks)
        rows = R.ranks_to_rows(ranks, n)
        assert rows.shape[1] == 1
        got_p = np.asarray(R.unrank_jnp(n, jnp.asarray(rows)))
        assert np.array_equal(got_p, perms)
        got_r = np.asarray(R.rank_jnp(jnp.asarray(perms)))
        assert np.array_equal(R.rows_to_ranks(got_r), ranks)

    def test_multiword_n13_and_boundary_n20(self):
        rng = np.random.default_rng(0)
        for n in (13, 20):
            f = math.factorial(n)
            ranks = (rng.integers(0, f, size=300, dtype=np.uint64)
                     if n == 20 else
                     rng.integers(0, f, size=300).astype(np.uint64))
            perms = R.unrank_np(n, ranks)
            assert np.array_equal(R.rank_np(perms), ranks)
            rows = R.ranks_to_rows(ranks, n)
            assert rows.shape[1] == 2
            assert np.array_equal(R.rows_to_ranks(rows), ranks)
            got_p = np.asarray(R.unrank_jnp(n, jnp.asarray(rows)))
            assert np.array_equal(got_p, perms)
            got_r = np.asarray(R.rank_jnp(jnp.asarray(perms)))
            assert np.array_equal(R.rows_to_ranks(got_r), ranks)

    def test_rank_rows_sort_in_rank_order(self):
        # word 0 is the high word: lexicographic (word-0-first) row order
        # must equal numeric rank order — the property the sorted-list
        # engine needs to consume rank rows directly.
        rng = np.random.default_rng(1)
        ranks = rng.integers(0, math.factorial(14), size=500).astype(np.uint64)
        rows = R.ranks_to_rows(ranks, 14)
        order = np.lexsort((rows[:, 1], rows[:, 0]))
        assert np.array_equal(R.rows_to_ranks(rows[order]), np.sort(ranks))


# -------------------------------------------------------- DiskBitArray

class TestDiskBitArray:
    def test_pack_unpack_roundtrip(self):
        rng = np.random.default_rng(0)
        vals = rng.integers(0, 4, 1001).astype(np.uint8)
        packed = DBA.pack2(vals)
        assert packed.shape[0] == -(-1001 // 4)
        assert np.array_equal(DBA.unpack2(packed, 1001), vals)

    def test_update_sync_matches_dict(self, wd):
        rng = np.random.default_rng(1)
        n = 1000
        ba = DiskBitArray(wd, n, chunk_elems=256)
        want = np.zeros(n, np.uint8)
        for _ in range(3):
            idx = rng.integers(0, n, 200)
            vals = rng.integers(0, 4, 200).astype(np.uint8)
            ba.update(idx, vals)
            for i, v in zip(idx, vals):
                want[i] |= v                 # default combine=OR …
        ba.sync(apply=lambda old, agg: old | agg)   # … apply=merge
        assert np.array_equal(ba.read_all(), want)
        assert np.array_equal(ba.get(np.arange(n)), want)
        hist = ba.count_values()
        assert hist.sum() == n
        assert np.array_equal(hist, np.bincount(want, minlength=4))
        ba.destroy()

    def test_sync_default_overwrites_with_last_combine(self, wd):
        ba = DiskBitArray(wd, 16, chunk_elems=8)
        ba.update([3, 3], [1, 2])
        # default combine=OR over both payloads, default apply=overwrite
        ba.sync()
        assert ba.get([3])[0] == 3
        ba.destroy()

    def test_transform_runs_on_logless_chunks(self, wd):
        ba = DiskBitArray(wd, 64, chunk_elems=16)   # 4 chunks
        ba.update([0], [1])                          # only chunk 0 logged
        seen = []
        ba.sync(transform=lambda start, vals: (seen.append(start), vals + 0)[1])
        assert seen == [0, 16, 32, 48]
        assert ba.get([0])[0] == 1
        ba.destroy()

    def test_log_spill_bounds_ram(self, wd):
        ba = DiskBitArray(wd, 256, chunk_elems=64, log_buf_rows=8)
        ba.update(np.arange(16) * 16 % 256, np.ones(16, np.uint8))
        # past log_buf_rows the buffered ops must hit per-chunk log files
        logs = [f for f in os.listdir(ba.path) if f.startswith("log")]
        assert logs, "expected spilled op-log files"
        ba.sync(apply=lambda old, agg: old | agg)
        assert ba.count_values()[1] == np.unique(np.arange(16) * 16 % 256).size
        ba.destroy()

    def test_stats_count_bytes(self, wd):
        DBA.reset_stats()
        ba = DiskBitArray(wd, 128, chunk_elems=64)
        ba.update([1], [2])
        ba.sync()
        assert DBA.STATS["sync_passes"] == 1
        assert DBA.STATS["bytes_read"] > 0
        assert DBA.STATS["bytes_written"] > 0
        ba.destroy()


# ------------------------------------------------------- RoomyBitArray

class TestRoomyBitArray:
    def test_pack_unpack_roundtrip(self):
        rng = np.random.default_rng(2)
        vals = jnp.asarray(rng.integers(0, 4, 250).astype(np.uint32))
        packed = BA.pack_values(vals)
        assert packed.shape[0] == BA.n_words(250)
        assert np.array_equal(np.asarray(BA.unpack_values(packed))[:250],
                              np.asarray(vals))

    def test_update_sync_matches_dict(self):
        rng = np.random.default_rng(3)
        n = 200
        ba = BA.make(n, queue_capacity=128)
        idx = rng.integers(0, n, 100)
        vals = rng.integers(0, 4, 100)
        ba, ov = BA.update(ba, jnp.asarray(idx), jnp.asarray(vals))
        assert not bool(ov)
        ba = BA.sync(ba)        # combine=OR, apply=overwrite-with-aggregate
        want = np.zeros(n, np.uint32)
        for i, v in zip(idx, vals):
            want[i] |= v
        assert np.array_equal(np.asarray(BA.get(ba, jnp.arange(n))), want)

    def test_sync_on_empty_queue_capacity_is_noop(self):
        ba = BA.make(32)                    # default queue_capacity=0
        out = BA.sync(ba)
        assert np.array_equal(np.asarray(out.data), np.asarray(ba.data))

    def test_update_queue_overflow_flag(self):
        ba = BA.make(64, queue_capacity=4)
        ba, ov = BA.update(ba, jnp.arange(3), jnp.ones(3))
        assert not bool(ov)
        ba, ov = BA.update(ba, jnp.arange(3), jnp.ones(3))
        assert bool(ov)

    def test_mark_packed_duplicates_and_oob(self):
        data = jnp.zeros((4,), jnp.uint32)          # 64 elements
        idx = jnp.asarray([5, 5, 5, 63, 64, 9999, -1], jnp.int32)
        out = BA.mark_packed(data, idx, impl="ref")
        vals = np.asarray(BA.unpack_values(out))
        want = np.zeros(64, np.uint32)
        want[[5, 63]] = BA.NEXT
        assert np.array_equal(vals, want)
        # non-UNSEEN targets absorb the mark
        out2 = BA.mark_packed(out, jnp.asarray([5], jnp.int32), impl="ref")
        assert np.array_equal(np.asarray(out2), np.asarray(out))

    def test_rotate_count(self):
        vals = jnp.asarray([BA.UNSEEN, BA.CUR, BA.NEXT, BA.DONE, BA.NEXT],
                           jnp.uint32)
        data = BA.pack_values(vals)
        new, cnt = BA.rotate_count(data, 5, impl="ref")
        got = np.asarray(BA.unpack_values(new))[:5]
        assert list(got) == [BA.UNSEEN, BA.DONE, BA.CUR, BA.DONE, BA.CUR]
        assert int(cnt) == 2

    def test_packed_write_shares_words(self):
        # two elements of the same uint32 word must update independently
        ba = BA.make(32, queue_capacity=8)
        ba, _ = BA.update(ba, jnp.asarray([0, 1, 15]), jnp.asarray([1, 2, 3]))
        ba = BA.sync(ba)
        got = np.asarray(BA.get(ba, jnp.asarray([0, 1, 2, 15])))
        assert list(got) == [1, 2, 0, 3]


# ------------------------------------------------- implicit BFS engines

class TestImplicitBFS:
    def test_tier_d_matches_sorted_list_engine(self, wd):
        n = 5
        total = math.factorial(n)
        start = int(R.rank_np(np.arange(n)[None, :])[0])
        sizes, bits = implicit_bfs(os.path.join(wd, "imp"), total, [start],
                                   _pancake_neighbors_np(n),
                                   chunk_elems=256)
        hist = bits.count_values()
        bits.destroy()
        want = _sorted_list_levels(n)
        assert sizes == want
        assert sum(sizes) == total
        assert hist[0] == 0                  # no UNSEEN left
        assert hist[3] == total              # every state ended DONE

    def test_tier_j_matches_tier_d(self, wd):
        n = 5
        total = math.factorial(n)
        start = int(R.rank_np(np.arange(n)[None, :])[0])
        d_sizes, bits = implicit_bfs(wd, total, [start],
                                     _pancake_neighbors_np(n),
                                     chunk_elems=64)
        bits.destroy()
        j_sizes, jbits = C.implicit_bfs(total, [start],
                                        _pancake_neighbor_jnp(n))
        assert j_sizes == d_sizes
        vals = np.asarray(BA.unpack_values(jbits.data))[:total]
        assert (vals == BA.DONE).all()

    def test_duplicate_seeds_collapse(self, wd):
        n = 4
        total = math.factorial(n)
        start = int(R.rank_np(np.arange(n)[None, :])[0])
        sizes, bits = implicit_bfs(wd, total, [start, start, start],
                                   _pancake_neighbors_np(n), chunk_elems=16)
        bits.destroy()
        assert sizes[0] == 1 and sum(sizes) == total


# ---------------------------------------------------------- sharded sync

class TestShardedMarkSync:
    def test_bucket_exchange_mark(self, multidev):
        multidev("""
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            shard_map = getattr(jax, "shard_map", None)
            if shard_map is None:
                from jax.experimental.shard_map import shard_map
            from repro.core import bitarray as BA
            S, nw_local, m = 4, 2, 16          # 32 elements per shard
            mesh = jax.make_mesh((S,), ("x",))
            data = jnp.zeros((S * nw_local,), jnp.uint32)
            rng = np.random.default_rng(0)
            idx = jnp.asarray(rng.integers(0, 128, S * m).astype(np.int32))
            valid = jnp.ones((S * m,), bool)
            def f(data, idx, valid):
                return BA.sharded_mark_sync(data, idx, valid, "x", S,
                                            capacity=m)
            fs = shard_map(f, mesh=mesh,
                           in_specs=(P("x"), P("x"), P("x")),
                           out_specs=(P("x"), P()))
            out, dropped = fs(data, idx, valid)
            assert int(dropped) == 0
            got = np.asarray(BA.unpack_values(out))
            want = np.zeros(128, np.uint32)
            want[np.unique(np.asarray(idx))] = BA.NEXT
            assert np.array_equal(got, want)
            print("sharded mark ok")
        """, n_devices=4)
