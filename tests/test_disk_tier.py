"""Tier D (out-of-core) vs oracles + cross-tier equivalence with Tier J.

Chunk sizes are deliberately tiny so every operation genuinely crosses
chunk boundaries (multi-file external sorts, merge joins, etc.)."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

# Shim: @given tests skip individually when hypothesis is absent; the
# plain oracle tests in this module still run (see _hypothesis_compat).
from _hypothesis_compat import given, settings, st

from repro.core import rlist as RL
from repro.core.disk import (ChunkStore, DiskArray, DiskHashTable, DiskList,
                             breadth_first_search, sort_rows)


@pytest.fixture
def wd(tmp_path):
    return str(tmp_path)


class TestChunkStore:
    def test_append_flush_roundtrip(self, wd):
        s = ChunkStore(f"{wd}/s", width=2, chunk_rows=8)
        data = np.arange(50, dtype=np.uint32).reshape(25, 2)
        s.append(data[:10]); s.append(data[10:])
        s.flush()
        assert s.n_chunks == math.ceil(25 / 8)
        assert np.array_equal(s.read_all(), data)

    def test_reopen_persists(self, wd):
        s = ChunkStore(f"{wd}/p", width=1, chunk_rows=4)
        s.append(np.arange(10, dtype=np.uint32)[:, None])
        s.flush()
        s2 = ChunkStore(f"{wd}/p", width=1, chunk_rows=4)
        assert s2.size == 10
        assert np.array_equal(s2.read_all()[:, 0], np.arange(10))


class TestDiskList:
    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 25), st.integers(0, 25)),
                    min_size=0, max_size=60))
    def test_dedup_matches_tier_j(self, rows):
        arr = (np.array(rows, np.uint32).reshape(-1, 2)
               if rows else np.zeros((0, 2), np.uint32))
        dl = DiskList(str(pytest.wd) if hasattr(pytest, "wd") else "/tmp/roomy_hyp",
                      width=2, chunk_rows=16)
        dl.add(arr)
        dl.remove_dupes(run_rows=16)
        got = sorted(map(tuple, dl.read_all().tolist()))
        rl = RL.remove_dupes(RL.from_rows(jnp.asarray(arr.reshape(-1, 2)),
                                          capacity=128))
        want = sorted(map(tuple, RL.to_numpy(rl).tolist()))
        assert got == want
        dl.destroy()

    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.integers(0, 30), max_size=50),
           st.lists(st.integers(0, 30), max_size=30))
    def test_remove_all_matches_tier_j(self, a, b):
        a_arr = np.array(a, np.uint32).reshape(-1, 1)
        b_arr = np.array(b, np.uint32).reshape(-1, 1)
        da = DiskList("/tmp/roomy_hyp2", width=1, chunk_rows=8)
        db = DiskList("/tmp/roomy_hyp2", width=1, chunk_rows=8)
        da.add(a_arr); db.add(b_arr)
        da.remove_all(db, run_rows=16)
        got = sorted(x[0] for x in da.read_all().tolist())
        bset = set(b)
        assert got == sorted(x for x in a if x not in bset)
        da.destroy(); db.destroy()

    def test_reduce_streaming(self, wd):
        dl = DiskList(wd, width=1, chunk_rows=7)
        dl.add(np.arange(100, dtype=np.uint32)[:, None])
        tot = dl.reduce(lambda c: int((c[:, 0].astype(np.int64) ** 2).sum()),
                        lambda a, b: a + b, 0)
        assert tot == sum(i * i for i in range(100))


class TestDiskArray:
    def test_chain_reduction_out_of_core(self, wd):
        da = DiskArray(wd, n=200, width=1, chunk_rows=16)
        da.write_all(np.arange(200, dtype=np.int64)[:, None])
        vals = da.read_all()
        da.update(np.arange(1, 200), vals[:-1])
        da.sync(combine=lambda p, q: p + q, apply=lambda o, a: o + a)
        got = da.read_all()[:, 0]
        want = np.arange(200, dtype=np.int64)
        want[1:] += np.arange(199)
        assert np.array_equal(got, want)

    def test_duplicate_index_combine(self, wd):
        da = DiskArray(wd, n=10, width=1, chunk_rows=4)
        da.update(np.array([3, 3, 7, 3]),
                  np.array([[1], [2], [5], [4]], np.int64))
        da.sync(combine=lambda p, q: p + q, apply=lambda o, a: o + a)
        got = da.read_all()[:, 0]
        assert got[3] == 7 and got[7] == 5


class TestDiskHashTable:
    def test_matches_dict(self, wd):
        rng = np.random.default_rng(0)
        keys = rng.integers(0, 40, 200).astype(np.uint32)
        vals = rng.integers(0, 100, 200).astype(np.int64)
        ht = DiskHashTable(wd, key_width=1, val_width=1, nbuckets=8)
        ht.insert(keys[:, None], vals[:, None])
        ht.sync(combine=lambda a, b: a + b,
                apply=lambda o, a, p: np.where(p[:, None], o + a, a))
        want = {}
        for k, v in zip(keys.tolist(), vals.tolist()):
            want[k] = want.get(k, 0) + v
        assert ht.size() == len(want)
        q = np.array(sorted(want), np.uint32)[:, None]
        got_v, got_f = ht.lookup(q)
        assert got_f.all()
        assert np.array_equal(got_v[:, 0],
                              np.array([want[k] for k in sorted(want)]))


class TestDiskHashTableOpOrder:
    """Op-log ORDER within one sync window (dhash.py's merge order): the
    log executes sequentially per key — DEL then PUT resurrects, PUT then
    DEL removes."""

    def test_del_then_put_resurrects(self, wd):
        ht = DiskHashTable(wd, 1, 1, nbuckets=4)
        ht.insert(np.array([[7]], np.uint32), np.array([[1]], np.int64))
        ht.sync()
        ht.remove(np.array([[7]], np.uint32))
        ht.insert(np.array([[7]], np.uint32), np.array([[5]], np.int64))
        ht.sync(combine=lambda a, b: a + b,
                apply=lambda o, a, p: np.where(p[:, None], o + a, a))
        v, f = ht.lookup(np.array([[7]], np.uint32))
        assert f[0]
        # the DEL wiped the stored 1: the PUT applies as a fresh insert
        assert v[0, 0] == 5
        assert ht.size() == 1
        ht.destroy()

    def test_put_then_del_removes(self, wd):
        ht = DiskHashTable(wd, 1, 1, nbuckets=4)
        ht.insert(np.array([[7]], np.uint32), np.array([[1]], np.int64))
        ht.sync()
        ht.insert(np.array([[7]], np.uint32), np.array([[9]], np.int64))
        ht.remove(np.array([[7]], np.uint32))
        ht.sync()
        _, f = ht.lookup(np.array([[7]], np.uint32))
        assert not f[0]
        assert ht.size() == 0
        ht.destroy()

    def test_puts_after_del_combine_fresh(self, wd):
        ht = DiskHashTable(wd, 1, 1, nbuckets=4)
        ht.insert(np.array([[3]], np.uint32), np.array([[100]], np.int64))
        ht.sync()
        ht.remove(np.array([[3]], np.uint32))
        ht.insert(np.array([[3], [3]], np.uint32),
                  np.array([[2], [3]], np.int64))
        ht.sync(combine=lambda a, b: a + b,
                apply=lambda o, a, p: np.where(p[:, None], o + a, a))
        v, f = ht.lookup(np.array([[3]], np.uint32))
        assert f[0] and v[0, 0] == 5        # 2+3, NOT 105: the 100 is gone
        ht.destroy()

    def test_del_of_absent_key_is_noop(self, wd):
        ht = DiskHashTable(wd, 1, 1, nbuckets=4)
        ht.remove(np.array([[42]], np.uint32))
        ht.sync()
        _, f = ht.lookup(np.array([[42]], np.uint32))
        assert not f[0] and ht.size() == 0
        ht.destroy()


class TestDiskBFS:
    def test_pancake_n6_matches_tier_j_and_oeis(self, wd):
        n = 6
        def gen_next(chunk):
            codes = chunk[:, 0]
            perms = np.stack([(codes >> (4 * i)) & 0xF for i in range(n)],
                             axis=1).astype(np.int64)
            outs = []
            for k in range(2, n + 1):
                flipped = np.concatenate(
                    [perms[:, :k][:, ::-1], perms[:, k:]], axis=1)
                code = np.zeros(chunk.shape[0], np.uint32)
                for i in range(n):
                    code |= flipped[:, i].astype(np.uint32) << np.uint32(4 * i)
                outs.append(code)
            return np.concatenate(outs)[:, None]

        start = np.uint32(sum(i << (4 * i) for i in range(n)))
        sizes, all_lst = breadth_first_search(
            wd, np.array([[start]], np.uint32), gen_next, width=1,
            chunk_rows=128)
        assert sum(sizes) == math.factorial(n)
        # pancake diameter P(6) = 7 (OEIS A058986); level profile fixed
        assert sizes == [1, 5, 20, 79, 199, 281, 133, 2]
        all_lst.destroy()
