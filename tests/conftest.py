import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_multidev(code: str, n_devices: int = 8, timeout: int = 300):
    """Run a snippet in a subprocess with N fake devices (the dry-run flag
    must never leak into this process — see the brief)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, (
        f"multidev subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
        f"STDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def multidev():
    return run_multidev
