"""Compressed runs + bytes-budget v2, pinned differentially (disk/codec.py).

Four contracts, per docs/compression.md:

* Codec correctness — varint-delta ``keys`` chunks and ``rle2`` 2-bit
  chunks round-trip exactly (property-based via the hypothesis shim,
  plus deterministic edge cases), the skip index agrees with a plain
  binary search, and every adversarial input — truncation, bit flips,
  overlong varints, unknown codec ids — raises a loud
  :class:`CodecError`, never wrong data.
* Differential equivalence — compressed ≡ uncompressed on pancake
  n ≤ 7 for BOTH engines × nshards {1, 2} × {spawn, inline}: identical
  level counts and identical sort/merge/pass budgets (codec I/O is
  booked separately, like ``ckpt_*``).  Kill-and-resume crosses the
  compressed/uncompressed boundary in BOTH directions.
* Backward compatibility — the committed pre-compression fixture
  (sealed FORMAT-1 oracle artifact + mid-search checkpoint, generated
  by the pre-codec tree) opens byte-identically; a format-version
  mismatch is a loud structured error, not a KeyError.
* Bytes actually drop — sorted-engine stored bytes per level at
  pancake n = 7 shrink ≥ 2x with compression on (the acceptance pin).
"""
import hashlib
import json
import math
import os
import shutil
import sys

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core import ranking as R
from repro.core.disk import (ChunkStore, CodecError, DistanceOracle,
                             OracleError, breadth_first_search, codec,
                             implicit_bfs)
from repro.core.disk import bitarray as DBA
from repro.core.disk import extsort
from repro.core.disk.bitarray import DiskBitArray
from repro.core.disk.config import CheckpointConfig, ClusterConfig

sys.path.append(os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "examples"))
from pancake_bfs import GenNextNp, start_code          # noqa: E402
from pancake_bits import NeighborsNp                   # noqa: E402

FIXTURE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "fixtures", "pre_compression")

N = 5
TOTAL = math.factorial(N)
START_ROWS = np.array([[start_code(N)]], np.uint32)
START_RANK = int(R.rank_np(np.arange(N)[None, :])[0])


# ============================================================ codec unit

class TestKeysRoundTrip:
    @pytest.mark.parametrize("n", [0, 1, 2, 7, codec.BLOCK_ROWS,
                                   codec.BLOCK_ROWS + 1,
                                   3 * codec.BLOCK_ROWS + 17])
    def test_width1_round_trip(self, n):
        rng = np.random.default_rng(n)
        rows = np.sort(rng.integers(0, 1 << 32, size=n,
                                    dtype=np.uint64)).astype(np.uint32)
        rows = rows.reshape(-1, 1)
        buf = codec.encode_keys(rows)
        assert (codec.decode_keys(buf) == rows).all()

    def test_width2_preserves_lex_order(self):
        rng = np.random.default_rng(7)
        rows = rng.integers(0, 1 << 32, size=(4096, 2), dtype=np.uint64)
        rows = rows.astype(np.uint32)
        order = np.lexsort((rows[:, 1], rows[:, 0]))
        rows = rows[order]
        assert (codec.decode_keys(codec.encode_keys(rows)) == rows).all()

    def test_duplicates_survive(self):
        rows = np.array([[3], [3], [3], [9], [9]], np.uint32)
        assert (codec.decode_keys(codec.encode_keys(rows)) == rows).all()

    def test_extreme_keys(self):
        rows = np.array([[0, 0], [0, 1], [0xFFFFFFFF, 0xFFFFFFFF]],
                        np.uint32)
        assert (codec.decode_keys(codec.encode_keys(rows)) == rows).all()

    def test_unsorted_input_raises(self):
        rows = np.array([[5], [4]], np.uint32)
        with pytest.raises(CodecError, match="not sorted"):
            codec.encode_keys(rows)

    def test_width3_has_no_packing(self):
        with pytest.raises(CodecError, match="width"):
            codec.encode_keys(np.zeros((4, 3), np.uint32))


class TestRle2RoundTrip:
    @pytest.mark.parametrize("packed", [
        np.zeros(0, np.uint8),
        np.zeros(1, np.uint8),
        np.full(10_000, 0xFF, np.uint8),
        np.arange(256, dtype=np.uint8),
        np.repeat(np.array([0, 0xFF, 0, 0x55], np.uint8), [5000, 3, 1, 900]),
    ])
    def test_round_trip(self, packed):
        assert (codec.decode_rle2(codec.encode_rle2(packed)) == packed).all()

    def test_sparse_array_compresses_hard(self):
        packed = np.zeros(1 << 16, np.uint8)
        packed[123] = 0x40
        buf = codec.encode_rle2(packed)
        assert len(buf) < 64
        assert (codec.decode_rle2(buf) == packed).all()


class TestSkipIndex:
    def _reader(self, keys):
        rows = np.asarray(keys, np.uint64).astype(np.uint32).reshape(-1, 1)
        return codec.CompressedKeyReader(
            codec.encode_keys(rows, block_rows=16)), rows[:, 0]

    def test_block_span_matches_binary_search(self):
        rng = np.random.default_rng(3)
        keys = np.sort(rng.integers(0, 1 << 20, size=500, dtype=np.uint64))
        rdr, flat = self._reader(keys)
        for lo, hi in [(0, 1 << 20), (5, 5), (100, 5000),
                       (int(flat[0]), int(flat[0])),
                       (int(flat[-1]), 1 << 20), (1 << 21, 1 << 22)]:
            got = rdr.keys_between(lo, hi)
            # Every key inside [lo, hi] must appear in the decoded span.
            want = flat[(flat >= lo) & (flat <= hi)]
            inside = got[(got >= lo) & (got <= hi)]
            assert (inside == want.astype(np.uint64)).all(), (lo, hi)

    def test_narrow_probe_skips_blocks(self):
        rdr, _ = self._reader(np.arange(0, 4096, dtype=np.uint64))
        before = codec.STATS["blocks_decoded"]
        rdr.keys_between(17, 30)        # inside block 1 of 256
        assert codec.STATS["blocks_decoded"] - before == 1

    def test_all_rows_equals_input(self):
        keys = np.sort(np.random.default_rng(9).integers(
            0, 1 << 30, size=1000, dtype=np.uint64))
        rdr, flat = self._reader(keys)
        assert (rdr.all_keys() == flat.astype(np.uint64)).all()


class TestAdversarial:
    """Corrupt data always raises CodecError — never returns wrong rows."""

    def _enc(self):
        rows = np.arange(10_000, dtype=np.uint32).reshape(-1, 1)
        return bytearray(codec.encode_keys(rows))

    def test_truncated_stream(self):
        buf = self._enc()
        for cut in (3, 8, len(buf) // 2, len(buf) - 1):
            with pytest.raises(CodecError):
                codec.decode_keys(bytes(buf[:cut]))

    def test_every_region_bit_flip_fails_loudly(self):
        buf = self._enc()
        # Flip a bit in each structural region: magic, codec id, header,
        # skip index, payload, crc trailer.
        for pos in (0, 4, 7, 40, len(buf) // 2, len(buf) - 2):
            bad = bytearray(buf)
            bad[pos] ^= 0x10
            with pytest.raises(CodecError):
                codec.decode_keys(bytes(bad))

    def test_wrong_codec_id(self):
        rows = np.arange(16, dtype=np.uint32).reshape(-1, 1)
        buf = codec.encode_keys(rows)
        with pytest.raises(CodecError, match="codec id"):
            codec.decode_rle2(buf)

    def test_overlong_varint_rejected(self):
        # 11 continuation bytes: longer than any uint64 encoding.
        stream = np.array([0x80] * 11 + [0x01], np.uint8)
        with pytest.raises(CodecError, match="[Oo]verlong"):
            codec._varint_decode(stream)

    def test_redundant_zero_terminal_rejected(self):
        # 0x80 0x00 re-encodes 0 in two bytes — non-canonical.
        with pytest.raises(CodecError, match="overlong"):
            codec._varint_decode(np.array([0x80, 0x00], np.uint8))

    def test_uint64_overflow_rejected(self):
        stream = np.array([0xFF] * 9 + [0x02], np.uint8)
        with pytest.raises(CodecError, match="overflow"):
            codec._varint_decode(stream)

    def test_truncated_varint_rejected(self):
        with pytest.raises(CodecError, match="truncated"):
            codec._varint_decode(np.array([0x80], np.uint8))

    def test_rle2_bit_flip(self):
        buf = bytearray(codec.encode_rle2(np.full(4096, 0xFF, np.uint8)))
        buf[len(buf) // 2] ^= 0x04
        with pytest.raises(CodecError):
            codec.decode_rle2(bytes(buf))

    def test_wire_corrupt(self):
        framed = bytearray(codec.wire_encode(b"x" * 1000))
        framed[10] ^= 0xFF
        with pytest.raises(CodecError, match="wire"):
            codec.wire_decode(bytes(framed))

    def test_wire_passthrough(self):
        assert codec.wire_decode(b"plain payload") == b"plain payload"

    def test_unknown_store_codec_fails_loudly(self, tmp_path):
        st_ = ChunkStore(str(tmp_path / "s"), 1, codec="keys")
        st_.append(np.arange(8, dtype=np.uint32).reshape(-1, 1))
        st_.flush(mark_sorted=True)
        meta = json.load(open(st_._meta_path))
        meta["codec"] = "zstd-future"
        json.dump(meta, open(st_._meta_path, "w"))
        with pytest.raises(CodecError, match="format version"):
            ChunkStore(str(tmp_path / "s"), 1)


# =================================================== property-based (shim)

@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=(1 << 64) - 1),
                max_size=300))
def test_prop_keys_round_trip_u64(vals):
    keys = np.sort(np.array(vals, np.uint64))
    rows = codec.u64_to_rows(keys, 2)
    assert (codec.decode_keys(codec.encode_keys(rows)) == rows).all()


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=255), max_size=400))
def test_prop_rle2_round_trip(byte_vals):
    packed = np.array(byte_vals, np.uint8)
    assert (codec.decode_rle2(codec.encode_rle2(packed)) == packed).all()


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=(1 << 32) - 1),
                min_size=1, max_size=300),
       st.integers(min_value=0, max_value=(1 << 32) - 1),
       st.integers(min_value=0, max_value=(1 << 32) - 1))
def test_prop_skip_index_consistent(vals, a, b):
    lo, hi = min(a, b), max(a, b)
    flat = np.sort(np.array(vals, np.uint64))
    rdr = codec.CompressedKeyReader(
        codec.encode_keys(flat.astype(np.uint32).reshape(-1, 1),
                          block_rows=8))
    got = rdr.keys_between(lo, hi)
    want = flat[(flat >= lo) & (flat <= hi)]
    inside = got[(got >= lo) & (got <= hi)]
    assert (inside == want).all()


@settings(max_examples=60, deadline=None)
@given(st.binary(max_size=300))
def test_prop_garbage_never_decodes_silently(blob):
    """Arbitrary bytes either raise CodecError or (vanishingly unlikely)
    carry a valid crc32 container — they never crash with a non-codec
    error or return silently wrong shapes."""
    for dec in (codec.decode_keys, codec.decode_rle2):
        try:
            dec(blob)
        except CodecError:
            pass


# ============================================= differential BFS equivalence

def run_sorted(wd, nshards=1, mode="inline", compress=False, **kw):
    cc = (ClusterConfig(nshards=nshards, mode=mode) if nshards > 1
          else None)
    sizes, handle = breadth_first_search(
        str(wd), START_ROWS, GenNextNp(N), width=1, chunk_rows=1 << 8,
        cluster=cc, compress=compress, **kw)
    handle.destroy()
    return sizes


def run_implicit(wd, nshards=1, mode="inline", compress=False, **kw):
    cc = (ClusterConfig(nshards=nshards, mode=mode) if nshards > 1
          else None)
    sizes, bits = implicit_bfs(
        str(wd), TOTAL, [START_RANK], NeighborsNp(N), chunk_elems=1 << 6,
        cluster=cc, compress=compress, **kw)
    bits.destroy()
    return sizes


ENGINES = {"sorted": run_sorted, "implicit": run_implicit}

# The pass/row budgets that must be codec-blind.  Byte counters (which
# legitimately shrink with compression) are deliberately absent.
BUDGET_KEYS = {
    "sorted": ("sort_passes", "rows_sorted", "merge_passes",
               "sorts_skipped", "chunks_probed", "chunks_pruned"),
    "implicit": ("rw_passes", "read_passes", "piggybacked_stages"),
}


@pytest.fixture(scope="module")
def want():
    import tempfile
    with tempfile.TemporaryDirectory() as wd:
        s = run_sorted(os.path.join(wd, "s"))
        i = run_implicit(os.path.join(wd, "i"))
    assert s == i and sum(s) == TOTAL
    return s


class TestDifferentialEquivalence:
    @pytest.mark.parametrize("engine", ["sorted", "implicit"])
    @pytest.mark.parametrize("nshards,mode", [(1, "inline"), (2, "inline"),
                                              (1, "spawn"), (2, "spawn")])
    def test_compressed_equals_uncompressed(self, tmp_path, want, engine,
                                            nshards, mode):
        run = ENGINES[engine]

        def measure(sub, compress):
            extsort.reset_stats()
            DBA.reset_stats()
            sizes = run(tmp_path / sub, nshards=nshards, mode=mode,
                        compress=compress)
            return sizes, dict(extsort.STATS), dict(DBA.STATS)

        s_raw, ext_raw, _ = measure("raw", False)
        s_cmp, ext_cmp, _ = measure("cmp", True)
        assert s_raw == s_cmp == want
        for key in BUDGET_KEYS[engine]:
            assert ext_raw[key] == ext_cmp[key], key

    def test_implicit_array_pass_budget_codec_blind(self, tmp_path):
        """sync/scan pass counts (not bytes) identical either way."""
        DBA.reset_stats()
        run_implicit(tmp_path / "raw", compress=False)
        raw = dict(DBA.STATS)
        DBA.reset_stats()
        run_implicit(tmp_path / "cmp", compress=True)
        cmp_ = dict(DBA.STATS)
        for key in ("sync_passes", "scan_passes", "ops_applied"):
            assert raw[key] == cmp_[key], key

    @pytest.mark.parametrize("engine", ["sorted", "implicit"])
    @pytest.mark.parametrize("first,second", [(False, True), (True, False)])
    def test_kill_resume_crosses_codec_boundary(self, tmp_path, want,
                                                engine, first, second):
        """Checkpoint written by one format, resumed by the other —
        both directions, level counts identical to uninterrupted."""
        run = ENGINES[engine]
        ckdir = str(tmp_path / "ck")
        partial = run(tmp_path / "w1", compress=first,
                      checkpoint=CheckpointConfig(dir=ckdir, every=1),
                      max_levels=2)
        assert partial == want[:3]
        got = run(tmp_path / "w2", compress=second,
                  checkpoint=CheckpointConfig(dir=ckdir, resume=True))
        assert got == want

    def test_sharded_kill_resume_crosses_boundary(self, tmp_path, want):
        ckdir = str(tmp_path / "ck")
        run_sorted(tmp_path / "w1", nshards=2, compress=False,
                   checkpoint=CheckpointConfig(dir=ckdir, every=1),
                   max_levels=2)
        got = run_sorted(tmp_path / "w2", nshards=2, compress=True,
                         checkpoint=CheckpointConfig(dir=ckdir, resume=True))
        assert got == want


class TestBytesActuallyDrop:
    def test_sorted_n7_bytes_per_level_halve(self, tmp_path):
        """The acceptance pin: pancake n=7 sorted-engine stored bytes
        drop >= 2x with compression on (same levels, same budgets)."""
        n = 7
        start = np.array([[start_code(n)]], np.uint32)

        def stored_bytes(sub, compress):
            codec.reset_stats()
            store_dir = tmp_path / sub
            sizes, handle = breadth_first_search(
                str(store_dir), start, GenNextNp(n), width=1,
                chunk_rows=1 << 8, compress=compress)
            total = 0
            for root, _d, files in os.walk(store_dir):
                for fn in files:
                    if fn.endswith((".npy", ".rmz")):
                        total += os.path.getsize(os.path.join(root, fn))
            handle.destroy()
            return sizes, total

        sizes_raw, raw = stored_bytes("raw", False)
        sizes_cmp, cmp_ = stored_bytes("cmp", True)
        assert sizes_raw == sizes_cmp and sum(sizes_raw) == math.factorial(n)
        ratio = raw / cmp_
        assert ratio >= 2.0, f"compression ratio {ratio:.2f} < 2x"
        # And the codec ledger agrees: raw >= 2x stored for extsort writes.
        led_raw = codec.STATS.get("extsort_raw_bytes", 0)
        led_st = codec.STATS.get("extsort_stored_bytes", 0)
        assert led_raw >= 2 * led_st > 0

    def test_rle2_snapshot_bytes_drop(self, tmp_path):
        def chunk_bytes(root):
            return sum(os.path.getsize(os.path.join(r, f))
                       for r, _d, fs in os.walk(root) for f in fs
                       if f.endswith((".npy", ".rmz")))

        bits = DiskBitArray(str(tmp_path / "b"), 1 << 14, compress=True)
        raw_bits = DiskBitArray(str(tmp_path / "r"), 1 << 14)
        sz = chunk_bytes(tmp_path / "b")
        raw_sz = chunk_bytes(tmp_path / "r")
        assert 0 < sz * 10 < raw_sz  # all-UNSEEN: RLE collapses to ~nothing
        bits.destroy()
        raw_bits.destroy()


# ================================================= backward-compat fixture

def _fixture_sha():
    with open(os.path.join(FIXTURE, "expected_sha256.json")) as f:
        return json.load(f)


def _walk_sha(root):
    out = {}
    for r, _d, files in os.walk(root):
        for fn in sorted(files):
            p = os.path.join(r, fn)
            rel = os.path.relpath(p, root)
            if rel == "expected_sha256.json":
                continue
            with open(p, "rb") as f:
                out[rel] = hashlib.sha256(f.read()).hexdigest()
    return out


class Pancake4Gen:
    """Raw-permutation pancake expansion, width 4 (the fixture's coding)."""

    def __call__(self, rows):
        rows = np.asarray(rows, np.uint32)
        out = []
        for r in rows:
            for k in range(2, 5):
                s = r.copy()
                s[:k] = s[:k][::-1]
                out.append(s)
        return np.asarray(out, np.uint32)


def _gen4_idx(idx):
    import itertools
    perms = np.array(list(itertools.permutations(range(4))), np.uint32)
    rank = {tuple(p): i for i, p in enumerate(perms)}
    idx = np.asarray(idx, np.int64)
    out = np.empty((len(idx), 3), np.int64)
    for i, r in enumerate(perms[idx]):
        for j, k in enumerate(range(2, 5)):
            s = r.copy()
            s[:k] = s[:k][::-1]
            out[i, j] = rank[tuple(s)]
    return out


class TestBackwardCompat:
    def test_fixture_is_byte_identical(self):
        """The committed artifact matches the sha manifest sealed at
        generation time — git hasn't mangled it, and nothing in the
        current tree rewrote it."""
        assert _walk_sha(FIXTURE) == _fixture_sha()

    def test_format1_oracle_opens_and_serves(self):
        with DistanceOracle(os.path.join(FIXTURE, "oracle"),
                            gen_neighbors=_gen4_idx) as oracle:
            assert oracle.meta["format"] == 1
            assert "chunk_codec" not in oracle.meta
            q = np.arange(24, dtype=np.int64)
            dist = oracle.distance(q)
            assert dist.min() == 0 and int(dist[0]) == 0
            counts = np.bincount(dist)
            assert counts.tolist() == oracle.level_sizes
        # Opening is read-only: every fixture byte unchanged.
        assert _walk_sha(FIXTURE) == _fixture_sha()

    def test_pre_compression_checkpoint_resumes_compressed(self, tmp_path):
        """The fixture's mid-search FORMAT-raw checkpoint resumes under
        compress=True — the cross-version boundary of docs/compression.md."""
        ckdir = str(tmp_path / "ck")
        shutil.copytree(os.path.join(FIXTURE, "ckpt"), ckdir)
        start = np.arange(4, dtype=np.uint32).reshape(1, -1)
        sizes, visited = breadth_first_search(
            str(tmp_path / "w"), start, Pancake4Gen(), width=4,
            compress=True,
            checkpoint=CheckpointConfig(dir=ckdir, resume=True))
        got = visited.read_all()
        visited.destroy()
        assert sum(sizes) == 24 and got.shape == (24, 4)
        assert sizes[:3] == [1, 3, 6]      # the fixture's sealed prefix

    def test_oracle_format_mismatch_is_structured(self, tmp_path):
        src = os.path.join(FIXTURE, "oracle")
        dst = str(tmp_path / "oracle")
        shutil.copytree(src, dst)
        man = json.load(open(os.path.join(dst, "ORACLE")))
        man["format"] = 99
        json.dump(man, open(os.path.join(dst, "ORACLE"), "w"))
        with pytest.raises(OracleError, match="supported formats"):
            DistanceOracle(dst)

    def test_oracle_meta_format_mismatch_is_structured(self, tmp_path):
        src = os.path.join(FIXTURE, "oracle")
        dst = str(tmp_path / "oracle")
        shutil.copytree(src, dst)
        os.remove(os.path.join(dst, "ORACLE"))    # force crash-adoption
        mp = os.path.join(dst, "v000001", "META.json")
        meta = json.load(open(mp))
        meta["format"] = 99
        json.dump(meta, open(mp, "w"))
        with pytest.raises(OracleError, match="supported formats"):
            DistanceOracle(dst)
