"""Per-arch smoke tests (deliverable (f)): reduced config, one train step +
one decode step on CPU, asserting shapes and finiteness; plus decode-vs-
forward consistency and variant-specific behaviors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward_hidden, init_params, loss_fn,
                          make_cache, prefill)
from repro.models.lm import logits_fn

KEY = jax.random.PRNGKey(0)


def make_smoke_batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (b, s + 1)).astype(np.int32)
    pos = np.tile(np.arange(s, dtype=np.int32)[None], (b, 1))
    if cfg.mrope:
        pos = np.tile(pos[:, :, None], (1, 1, 3))
    inputs = {"positions": jnp.asarray(pos)}
    if cfg.frontend_stub:
        inputs["embeds"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)).astype(np.float32))
    else:
        inputs["tokens"] = jnp.asarray(toks[:, :s])
    return {"inputs": inputs, "labels": jnp.asarray(toks[:, 1:])}


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_train_step(self, arch):
        cfg = get_config(arch, smoke=True).replace(kernels="ref")
        params = init_params(cfg, KEY)
        batch = make_smoke_batch(cfg)
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg))(params)
        assert np.isfinite(float(loss))
        for leaf in jax.tree.leaves(grads):
            assert np.isfinite(np.asarray(leaf, np.float32)).all()
        hidden = forward_hidden(params, batch["inputs"], cfg)
        b, s = batch["labels"].shape
        assert hidden.shape == (b, s, cfg.d_model)

    def test_decode_step(self, arch):
        cfg = get_config(arch, smoke=True).replace(kernels="ref")
        params = init_params(cfg, KEY)
        b = 2
        caches = make_cache(cfg, b, max_len=32)
        batch = make_smoke_batch(cfg, b=b, s=1)
        logits, caches2 = decode_step(params, batch["inputs"], caches, cfg)
        assert logits.shape == (b, 1, cfg.vocab_padded)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        # cache advanced exactly one position
        if "kv" in caches2:
            assert int(jax.tree.leaves(caches2["kv"].lengths)[0].reshape(-1)[0]) == 1


class TestDecodeForwardConsistency:
    """Greedy decode over t steps must equal the t-th column of the full
    forward logits (teacher forcing) — exercises paged KV end to end."""

    @pytest.mark.parametrize("arch", ["minicpm-2b", "gemma2-2b",
                                      "falcon-mamba-7b", "zamba2-1.2b",
                                      "granite-34b"])
    def test_stepwise_equals_forward(self, arch):
        cfg = get_config(arch, smoke=True).replace(
            kernels="ref", dtype="float32")
        params = init_params(cfg, KEY)
        b, s = 2, 12
        rng = np.random.default_rng(3)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                           jnp.int32)
        pos = jnp.tile(jnp.arange(s)[None], (b, 1))
        if cfg.mrope:
            pos = jnp.tile(pos[:, :, None], (1, 1, 3))
        hidden = forward_hidden(params, {"tokens": toks, "positions": pos},
                                cfg)
        full_logits = logits_fn(params, hidden, cfg)

        caches = make_cache(cfg, b, max_len=32)
        step_logits = []
        for t in range(s):
            inp = {"tokens": toks[:, t:t + 1],
                   "positions": (pos[:, t:t + 1]
                                 if not cfg.mrope else pos[:, t:t + 1])}
            lg, caches = decode_step(params, inp, caches, cfg)
            step_logits.append(lg[:, 0])
        step_logits = jnp.stack(step_logits, axis=1)
        np.testing.assert_allclose(
            np.asarray(step_logits, np.float32),
            np.asarray(full_logits, np.float32), atol=2e-3, rtol=2e-3)


class TestVariantBehaviors:
    def test_gemma2_softcap_bounds_logits(self):
        cfg = get_config("gemma2-2b", smoke=True).replace(
            kernels="ref", dtype="float32")
        params = init_params(cfg, KEY)
        batch = make_smoke_batch(cfg)
        hidden = forward_hidden(params, batch["inputs"], cfg)
        logits = logits_fn(params, hidden, cfg)
        real = np.asarray(logits[..., :cfg.vocab_size], np.float32)
        assert np.abs(real).max() <= cfg.logit_softcap + 1e-3

    def test_local_window_masks_past(self):
        """With window w, token t must be independent of tokens < t-w."""
        cfg = get_config("gemma2-2b", smoke=True).replace(
            kernels="ref", dtype="float32", local_window=4)
        params = init_params(cfg, KEY)
        b, s = 1, 14
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)
        toks2 = toks.copy()
        toks2[0, 0] = (toks2[0, 0] + 1) % cfg.vocab_size   # perturb far past
        pos = jnp.tile(jnp.arange(s)[None], (b, 1))
        h1 = forward_hidden(params, {"tokens": jnp.asarray(toks),
                                     "positions": pos}, cfg)
        h2 = forward_hidden(params, {"tokens": jnp.asarray(toks2),
                                     "positions": pos}, cfg)
        # not equal globally (token 0 itself changed)...
        assert not np.allclose(np.asarray(h1), np.asarray(h2))
        # gemma2 alternates local/global so full independence needs all-local;
        # check a pure-local config: global layers removed via window on both
        cfg_local = cfg.replace(local_global_pattern=False, n_layers=2)
        params_l = init_params(cfg_local, KEY)
        def fh(t):
            return forward_hidden(
                params_l, {"tokens": jnp.asarray(t), "positions": pos},
                cfg_local, None)
        # run every layer with the window by monkey-level: family dense,
        # local_global off → global layers; emulate locality via attention
        # window arg exercised in kernel tests instead. Here assert causality:
        toks3 = toks.copy()
        toks3[0, -1] = (toks3[0, -1] + 1) % cfg.vocab_size  # perturb future
        h3 = fh(toks3)
        h0 = fh(toks)
        np.testing.assert_allclose(np.asarray(h0[0, :-1]),
                                   np.asarray(h3[0, :-1]), atol=1e-5)

    def test_mrope_equals_rope_on_text(self):
        """Equal (t,h,w) position rows collapse M-RoPE to standard RoPE for
        sections covering head_dim/2 — sanity on the vlm backbone."""
        from repro.models.rope import mrope, rope
        x = jax.random.normal(KEY, (1, 8, 2, 16))
        pos = jnp.arange(8)[None]
        pos3 = jnp.tile(pos[..., None], (1, 1, 3))
        a = rope(x, pos, 10_000.0)
        b = mrope(x, pos3, 10_000.0, (3, 3, 2))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    def test_moe_einsum_vs_roomy_needs_mesh(self):
        """Without a mesh the roomy dispatch must fall back to einsum."""
        from repro.models.moe import init_moe, moe, moe_einsum
        cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True).replace(
            kernels="ref", dtype="float32")
        p = init_moe(KEY, cfg)
        x = jax.random.normal(KEY, (2, 8, cfg.d_model))
        np.testing.assert_allclose(np.asarray(moe(p, x, cfg, None)),
                                   np.asarray(moe_einsum(p, x, cfg)))

    def test_nemotron_relu2(self):
        from repro.models.layers import _act
        x = jnp.array([-1.0, 0.5, 2.0])
        np.testing.assert_allclose(np.asarray(_act("relu2")(x)),
                                   [0.0, 0.25, 4.0])


class TestPrefillDecodeConsistency:
    """prefill(s tokens) then decode == stepwise decode from scratch —
    exercises SSM state extraction, hybrid segment caches, paged bulk_fill
    with partial pages, and gemma2's local/global pair caches."""

    @pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-1.2b",
                                      "minicpm-2b", "gemma2-2b"])
    def test_prefill_then_decode(self, arch):
        from repro.models import prefill
        cfg = get_config(arch, smoke=True).replace(kernels="ref",
                                                   dtype="float32")
        params = init_params(cfg, KEY)
        b, s = 2, 10
        rng = np.random.default_rng(1)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 1)),
                           jnp.int32)
        pos = jnp.tile(jnp.arange(s)[None], (b, 1))
        if cfg.mrope:
            pos = jnp.tile(pos[:, :, None], (1, 1, 3))
        _, caches = prefill(params, {"tokens": toks[:, :s],
                                     "positions": pos}, cfg, max_len=32)
        lg_a, _ = decode_step(params, {"tokens": toks[:, s:s + 1],
                                       "positions": pos[:, :1]}, caches, cfg)
        caches2 = make_cache(cfg, b, max_len=32)
        for t in range(s + 1):
            lg_b, caches2 = decode_step(
                params, {"tokens": toks[:, t:t + 1],
                         "positions": pos[:, :1]}, caches2, cfg)
        np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                                   atol=2e-3, rtol=2e-3)
