"""Pallas TPU kernels for the 2-bit packed arrays (core/bitarray.py).

The implicit BFS engine stores 16 two-bit elements per uint32 word; its two
per-level hot paths are pure bit manipulation over the packed words, which
is exactly VPU-shaped work:

  bitpack_lut_count     the fused rotate+count pass: unpack each word's 16
                        fields, map them through a 4-entry LUT (encoded in
                        one uint32 scalar), repack, and count fields that
                        map to a target value — one streaming read-write
                        pass over the packed array, no unpacked (8× larger)
                        intermediate ever hits HBM.

  bitpack_scatter_mark  the sync apply phase: a batch of element indices
                        whose 2-bit field must become ``mark`` iff it
                        currently holds ``only_if`` (the OR-style visited
                        test of the BFS — marks on non-UNSEEN states are
                        absorbed).  Sequential read-modify-write per op,
                        same trash-row convention as bucket_scatter.py; the
                        packed table must fit VMEM (callers tile by shard,
                        which the Roomy layout already provides).

  bitpack_mark_rotate_count
                        the two fused into ONE kernel — the whole per-level
                        array pass of the implicit BFS: scatter the marks,
                        then LUT-rotate and count in the same VMEM
                        residency, so the packed table crosses HBM once per
                        level instead of twice (the Tier J twin of the disk
                        pass planner's fused read-write pass).

  bitpack_gather2       the serving tier's Tier J lookup path: gather the
                        2-bit fields for a vector of element indices out of
                        page-resident packed words.  Queries are binned to
                        pages HOST-side (gather2_plan — the oracle server's
                        chunk binning, numpy) and the kernel walks a
                        scalar-prefetched page table (the paged.py /
                        paged_decode.py idiom) so each grid step streams
                        exactly one page of packed words into VMEM.

All have pure-jnp oracles in ref.py and interpret-mode CPU validation in
tests/test_kernels.py; ops.py hosts the dispatching wrappers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells it TPUCompilerParams; keep both working.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

FIELDS_PER_WORD = 16
LANES = 128
DEFAULT_BW = 8           # words-per-block rows (uint32 tile is (8, 128))
DEFAULT_BM = 256         # scatter ops per block


def make_lut(table) -> int:
    """Encode a 4-entry value map [new0, new1, new2, new3] into one uint32
    scalar: entry v occupies bits [2v, 2v+2)."""
    assert len(table) == 4 and all(0 <= v <= 3 for v in table)
    return sum(int(v) << (2 * i) for i, v in enumerate(table))


# ---------------------------------------------------------- lut + count

def _lut_count_kernel(p_ref, o_ref, cnt_ref, *, lut: int, count_val: int):
    blk = pl.program_id(0)
    w = p_ref[...]
    acc = jnp.zeros_like(w)
    total = jnp.zeros((), jnp.int32)
    for j in range(FIELDS_PER_WORD):
        f = (w >> (2 * j)) & 3
        nf = (jnp.uint32(lut) >> (2 * f)) & 3
        acc = acc | (nf << (2 * j))
        total = total + jnp.sum((nf == count_val).astype(jnp.int32))
    o_ref[...] = acc

    @pl.when(blk == 0)
    def _init():
        cnt_ref[0, 0] = jnp.int32(0)

    cnt_ref[0, 0] = cnt_ref[0, 0] + total


def bitpack_lut_count(
    packed: jax.Array,       # (W,) uint32
    lut: int,                # make_lut(...) scalar (static)
    count_val: int,          # field value to count after mapping (static)
    *,
    block_w: int = DEFAULT_BW,
    interpret: bool = False,
):
    """Map every 2-bit field through ``lut`` and count resulting fields ==
    ``count_val``.  Returns (new_packed (W,) uint32, count () int32).

    Padding note: the grid pads W up to whole (block_w, 128) tiles with
    zero words; that tile padding is corrected below, so the count covers
    exactly the W·16 fields of the input words.  Callers owning fewer than
    W·16 logical elements correct for THEIR tail fields themselves (see
    core/bitarray.py rotate_count).
    """
    w = packed.shape[0]
    rows = -(-w // LANES)
    rows_pad = -(-rows // block_w) * block_w
    p2 = jnp.zeros((rows_pad * LANES,), jnp.uint32).at[:w].set(packed)
    p2 = p2.reshape(rows_pad, LANES)

    kernel = functools.partial(_lut_count_kernel, lut=lut,
                               count_val=count_val)
    out, cnt = pl.pallas_call(
        kernel,
        grid=(rows_pad // block_w,),
        in_specs=[pl.BlockSpec((block_w, LANES), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_w, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows_pad, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="roomy_bitpack_lut_count",
    )(p2)
    pad_fields = (rows_pad * LANES - w) * FIELDS_PER_WORD
    lut0 = lut & 3
    cnt_corr = cnt[0, 0] - (pad_fields if lut0 == count_val else 0)
    return out.reshape(-1)[:w], cnt_corr


# -------------------------------------------------------- scatter mark

def _scatter_mark_kernel(idx_ref, tab_ref, out_ref, *, bm: int, n_words: int,
                         mark: int, only_if: int):
    blk = pl.program_id(0)

    @pl.when(blk == 0)
    def _init():
        out_ref[...] = tab_ref[...]

    def body(i, _):
        elt = idx_ref[i, 0]
        word = jnp.where(elt >= 0, elt // FIELDS_PER_WORD, n_words)
        word = jnp.minimum(word, n_words)            # trash row for drops
        sh = (2 * jnp.maximum(elt % FIELDS_PER_WORD, 0)).astype(jnp.uint32)
        w = pl.load(out_ref, (pl.ds(word, 1), slice(None)))
        field = (w >> sh) & jnp.uint32(3)
        new_w = jnp.where(field == jnp.uint32(only_if),
                          (w & ~(jnp.uint32(3) << sh))
                          | (jnp.uint32(mark) << sh),
                          w).astype(jnp.uint32)
        pl.store(out_ref, (pl.ds(word, 1), slice(None)), new_w)
        return 0

    jax.lax.fori_loop(0, bm, body, 0)


def _scatter_prep(packed: jax.Array, idx: jax.Array, block_m: int):
    """Shared op-index padding/clipping + table staging for the scatter
    kernels: OOB/negative indices retarget the trash row ``n_words``."""
    n_words = packed.shape[0]
    m = idx.shape[0]
    bm = min(block_m, max(m, 1))
    m_pad = -(-max(m, 1) // bm) * bm
    cap = n_words * FIELDS_PER_WORD
    idx = jnp.where((idx >= 0) & (idx < cap), idx, cap)
    if m_pad != m:
        idx = jnp.pad(idx, (0, m_pad - m), constant_values=cap)
    idx = idx.astype(jnp.int32).reshape(m_pad, 1)
    tab = jnp.concatenate([packed.astype(jnp.uint32),
                           jnp.zeros((1,), jnp.uint32)]).reshape(-1, 1)
    return idx, tab, bm, m_pad


def bitpack_scatter_mark(
    packed: jax.Array,       # (W,) uint32 — must fit VMEM as (W+1, 1)
    idx: jax.Array,          # (M,) int32 element indices; OOB/negative drop
    *,
    mark: int = 2,           # value to write (static)
    only_if: int = 0,        # write only where the field currently == this
    block_m: int = DEFAULT_BM,
    interpret: bool = False,
) -> jax.Array:
    """packed[idx] ← mark where the 2-bit field holds ``only_if`` (the
    delayed-mark apply of the implicit BFS).  Duplicate indices are safe —
    the first mark wins and later ones see ``mark`` ≠ ``only_if``."""
    n_words = packed.shape[0]
    idx, tab, bm, m_pad = _scatter_prep(packed, idx, block_m)

    kernel = functools.partial(_scatter_mark_kernel, bm=bm, n_words=n_words,
                               mark=mark, only_if=only_if)
    out = pl.pallas_call(
        kernel,
        grid=(m_pad // bm,),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((n_words + 1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n_words + 1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_words + 1, 1), jnp.uint32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="roomy_bitpack_scatter_mark",
    )(idx, tab)
    return out[:n_words, 0]


# ------------------------------------------- fused mark + rotate + count

def _mark_rotate_count_kernel(idx_ref, tab_ref, out_ref, cnt_ref, *, bm: int,
                              n_words: int, mark: int, only_if: int,
                              lut: int, count_val: int, nblocks: int):
    blk = pl.program_id(0)

    @pl.when(blk == 0)
    def _init():
        out_ref[...] = tab_ref[...]
        cnt_ref[0, 0] = jnp.int32(0)

    def body(i, _):
        elt = idx_ref[i, 0]
        word = jnp.where(elt >= 0, elt // FIELDS_PER_WORD, n_words)
        word = jnp.minimum(word, n_words)            # trash row for drops
        sh = (2 * jnp.maximum(elt % FIELDS_PER_WORD, 0)).astype(jnp.uint32)
        w = pl.load(out_ref, (pl.ds(word, 1), slice(None)))
        field = (w >> sh) & jnp.uint32(3)
        new_w = jnp.where(field == jnp.uint32(only_if),
                          (w & ~(jnp.uint32(3) << sh))
                          | (jnp.uint32(mark) << sh),
                          w).astype(jnp.uint32)
        pl.store(out_ref, (pl.ds(word, 1), slice(None)), new_w)
        return 0

    jax.lax.fori_loop(0, bm, body, 0)

    # Last op block: the fully marked table is still resident in VMEM —
    # rotate it through the LUT and count in place, saving the second HBM
    # round trip a separate bitpack_lut_count pass would pay.
    @pl.when(blk == nblocks - 1)
    def _rotate_count():
        w = out_ref[...]                             # (n_words + 1, 1)
        live = jax.lax.broadcasted_iota(jnp.int32, w.shape, 0) < n_words
        acc = jnp.zeros_like(w)
        total = jnp.zeros((), jnp.int32)
        for j in range(FIELDS_PER_WORD):
            f = (w >> (2 * j)) & 3
            nf = (jnp.uint32(lut) >> (2 * f)) & 3
            acc = acc | (nf << (2 * j))
            total = total + jnp.sum(
                jnp.where(live, (nf == count_val).astype(jnp.int32), 0))
        # The trash row soaked up dropped marks; leave it un-rotated (it is
        # sliced away by the wrapper) and keep it out of the count.
        out_ref[...] = jnp.where(live, acc, w)
        cnt_ref[0, 0] = total


def bitpack_mark_rotate_count(
    packed: jax.Array,       # (W,) uint32 — must fit VMEM as (W+1, 1)
    idx: jax.Array,          # (M,) int32 element indices; OOB/negative drop
    lut: int,                # make_lut(...) scalar (static)
    count_val: int,          # field value to count after mapping (static)
    *,
    mark: int = 2,
    only_if: int = 0,
    block_m: int = DEFAULT_BM,
    interpret: bool = False,
):
    """The implicit BFS's whole per-level array pass as ONE kernel:
    ``packed[idx] ← mark`` where the field holds ``only_if`` (delayed-mark
    apply, duplicates/OOB safe as in bitpack_scatter_mark), then every
    field maps through ``lut`` and fields mapping to ``count_val`` are
    counted — over ALL W·16 fields; callers owning fewer logical elements
    correct for their tail fields (core/bitarray.py mark_rotate_count).
    Returns (new_packed (W,) uint32, count () int32).

    Equivalent to bitpack_scatter_mark followed by bitpack_lut_count, but
    the packed table crosses HBM once instead of twice per level.
    """
    n_words = packed.shape[0]
    idx, tab, bm, m_pad = _scatter_prep(packed, idx, block_m)

    kernel = functools.partial(_mark_rotate_count_kernel, bm=bm,
                               n_words=n_words, mark=mark, only_if=only_if,
                               lut=lut, count_val=count_val,
                               nblocks=m_pad // bm)
    out, cnt = pl.pallas_call(
        kernel,
        grid=(m_pad // bm,),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((n_words + 1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_words + 1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_words + 1, 1), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="roomy_bitpack_mark_rotate_count",
    )(idx, tab)
    return out[:n_words, 0], cnt[0, 0]


# ------------------------------------------------- paged gather (serving)

DEFAULT_PAGE_WORDS = 512     # packed words per page block (2 KiB / page)


def _gather2_kernel(tbl_ref, idx_ref, page_ref, out_ref, *, bm: int):
    """One grid step = one block of ``bm`` page-LOCAL element indices
    against the one page the scalar-prefetched table routed in.  Negative
    indices are padding → 0 (same convention as the ref oracle's OOB)."""
    def body(i, _):
        elt = idx_ref[i, 0]
        ok = elt >= 0
        ee = jnp.maximum(elt, 0)
        word = ee // FIELDS_PER_WORD
        sh = (2 * (ee % FIELDS_PER_WORD)).astype(jnp.uint32)
        w = pl.load(page_ref, (pl.ds(word, 1), slice(None)))
        f = ((w >> sh) & jnp.uint32(3)).astype(jnp.int32)
        pl.store(out_ref, (pl.ds(i, 1), slice(None)),
                 jnp.where(ok, f, 0))
        return 0

    jax.lax.fori_loop(0, bm, body, 0)


def gather2_plan(idx, n_words: int, *,
                 page_words: int = DEFAULT_PAGE_WORDS,
                 block_m: int = DEFAULT_BM):
    """Host-side (numpy) page binning for :func:`bitpack_gather2`.

    Bins the element indices by owning page (stable argsort + contiguous
    slices — the disk tier's bin-by-dest idiom), pads each page's run to
    whole ``block_m`` blocks with -1, and returns

        (local (n_blocks·bm,) int32 page-LOCAL indices,
         page_table (n_blocks,) int32,
         out_pos (n_blocks·bm,) int64 original query position, -1 = pad)

    OOB/negative queries are excluded here (they never reach the kernel)
    and read back as 0 through ``out_pos``.  Binning is data-dependent
    host work — the same reason the oracle server bins by chunk outside
    any jit.
    """
    idx = np.asarray(idx).astype(np.int64).reshape(-1)
    cap = n_words * FIELDS_PER_WORD
    fpp = page_words * FIELDS_PER_WORD
    (pos,) = np.nonzero((idx >= 0) & (idx < cap))
    page_of = idx[pos] // fpp
    order = pos[np.argsort(page_of, kind="stable")]
    pages, starts = np.unique(idx[order] // fpp, return_index=True)
    bounds = np.append(starts, order.size)
    locs, outpos, tbl = [], [], []
    for pi, page in enumerate(pages):
        sel = order[bounds[pi]:bounds[pi + 1]]
        pad = -(-sel.size // block_m) * block_m - sel.size
        locs.append(np.concatenate(
            [(idx[sel] - page * fpp).astype(np.int32),
             np.full(pad, -1, np.int32)]))
        outpos.append(np.concatenate([sel, np.full(pad, -1, np.int64)]))
        tbl.extend([int(page)] * ((sel.size + pad) // block_m))
    if not tbl:                 # no valid query: one dummy all-pad block
        locs = [np.full(block_m, -1, np.int32)]
        outpos = [np.full(block_m, -1, np.int64)]
        tbl = [0]
    return (np.concatenate(locs), np.asarray(tbl, np.int32),
            np.concatenate(outpos))


def bitpack_gather2(
    packed: jax.Array,       # (W,) uint32 packed 2-bit fields
    idx,                     # (M,) int element indices; OOB/negative → 0
    *,
    page_words: int = DEFAULT_PAGE_WORDS,
    block_m: int = DEFAULT_BM,
    interpret: bool = False,
) -> jax.Array:
    """Gather the 2-bit field for each element index: (M,) int32 in 0..3.

    The packed words are padded to whole pages of ``page_words`` and the
    grid runs one step per query block; a PrefetchScalarGridSpec page
    table (built by :func:`gather2_plan`) picks which page each block's
    BlockSpec streams into VMEM — so a batch touching k pages moves
    k·page_words·4 bytes regardless of W, the serving tier's cache-miss
    cost model on device.
    """
    n_words = packed.shape[0]
    m = int(np.asarray(idx).reshape(-1).shape[0])
    n_pages = max(1, -(-n_words // page_words))
    local, tbl, out_pos = gather2_plan(idx, n_words,
                                       page_words=page_words,
                                       block_m=block_m)
    bm = min(block_m, local.shape[0])
    paged = (jnp.zeros((n_pages * page_words,), jnp.uint32)
             .at[:n_words].set(packed.astype(jnp.uint32))
             .reshape(n_pages * page_words, 1))
    n_blocks = tbl.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, tbl: (i, 0)),
            pl.BlockSpec((page_words, 1), lambda i, tbl: (tbl[i], 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, tbl: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_gather2_kernel, bm=bm),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks * bm, 1), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="roomy_bitpack_gather2",
    )(jnp.asarray(tbl), jnp.asarray(local).reshape(-1, 1), paged)
    flat = np.asarray(out).reshape(-1)
    res = np.zeros(m, np.int32)
    (live,) = np.nonzero(out_pos >= 0)
    res[out_pos[live]] = flat[live]
    return jnp.asarray(res)
