"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are also the implementations the model stack uses on non-TPU backends
and under the dry-run (kernels lower to XLA HLO there — see DESIGN.md §7).
``attention_ref`` is written in the *blocked online-softmax* form (a scan
over kv chunks) so its HLO memory profile matches the flash kernel rather
than materializing S×S logits; ``attention_naive`` is the O(S²)-memory
textbook form used only as the oracle-of-the-oracle in tests.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ------------------------------------------------------------- attention

def _mask(q_pos, k_pos, seq_kv, causal, window):
    m = k_pos < seq_kv
    if causal:
        m = m & (k_pos <= q_pos)
    if window is not None:
        m = m & (k_pos >= q_pos - window)
    return m


def attention_naive(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None):
    """(B, Hq, Sq, D) x (B, Hkv, Skv, D) — materializes full logits."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    s = jnp.where(_mask(q_pos, k_pos, skv, causal, window), s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                  scale=None, block_k: int = 512):
    """Blocked online-softmax attention (flash semantics, pure jnp).

    Scans kv in chunks of block_k carrying (acc, m, l) — O(Sq·D) live
    memory. This is the model-stack attention on every non-TPU backend.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bk = min(block_k, skv)
    skv_p = -(-skv // bk) * bk
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    nk = skv_p // bk
    kb = k.reshape(b, hkv, nk, bk, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nk, bk, d).transpose(2, 0, 1, 3, 4)
    qf = q.astype(jnp.float32)
    q_pos = jnp.arange(sq)[:, None]

    def step(carry, inp):
        acc, m_prev, l_prev, ki = carry[0], carry[1], carry[2], carry[3]
        kc, vc = inp
        kc = jnp.repeat(kc.astype(jnp.float32), g, axis=1)   # (b, hq, bk, d)
        vc = jnp.repeat(vc.astype(jnp.float32), g, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kc) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = ki * bk + jnp.arange(bk)[None, :]
        msk = _mask(q_pos, k_pos, skv, causal, window)
        s = jnp.where(msk[None, None], s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        p = jnp.where(msk[None, None], p, 0.0)
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vc)
        return (acc, m_cur, l_cur, ki + 1), None

    init = (jnp.zeros((b, hq, sq, d), jnp.float32),
            jnp.full((b, hq, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, hq, sq), jnp.float32),
            jnp.zeros((), jnp.int32))
    (acc, m, l, _), _ = jax.lax.scan(step, init, (kb, vb))
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).astype(q.dtype)


def decode_attention_ref(q, k, v, mask, *, softcap=None, scale=None):
    """Single-position decode attention over a (paged) cache.

    q: (B, Hq, D); k, v: (B, S, Hkv, D); mask: (B, S) validity.
    GQA via grouped einsum — K/V are never repeated or upcast in HBM
    (the f32+repeat form peaked at g·2× the cache size; §Perf note)."""
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, d)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, d).astype(q.dtype)


# ------------------------------------------------------------ mamba scan

def mamba_scan_ref(x, dt, a, b, c, d):
    """Associative-scan oracle of kernels/mamba_scan.py (same signature)."""
    bsz, seq, di = x.shape
    n = a.shape[1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    af, bf, cf = a.astype(jnp.float32), b.astype(jnp.float32), c.astype(jnp.float32)
    da = jnp.exp(dtf[..., None] * af[None, None])            # (B, L, Di, N)
    dbx = (dtf * xf)[..., None] * bf[:, :, None, :]          # (B, L, Di, N)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (da, dbx), axis=1)
    y = jnp.einsum("blin,bln->bli", h, cf) + xf * d.astype(jnp.float32)[None, None]
    return y.astype(x.dtype)


def mamba_scan_seq_stateful(x, dt, a, b, c, d, h0=None):
    """Sequential scan returning (y, final_state) — the prefill form."""
    bsz, seq, di = x.shape
    n = a.shape[1]

    def step(h, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt[..., None] * a[None])               # (B, Di, N)
        h = h * da + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bin,bn->bi", h, ct) + xt * d[None]
        return h, y

    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)
    xs = (x.astype(jnp.float32).swapaxes(0, 1),
          dt.astype(jnp.float32).swapaxes(0, 1),
          b.astype(jnp.float32).swapaxes(0, 1),
          c.astype(jnp.float32).swapaxes(0, 1))
    h_last, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), h_last


def mamba_scan_seq_ref(x, dt, a, b, c, d):
    """Sequential-scan second oracle (independent of associative form)."""
    return mamba_scan_seq_stateful(x, dt, a, b, c, d)[0]


# ------------------------------------------------------- mamba2 SSD form

def mamba2_ssd(x, dt, a, b, c, d, *, chunk: int = 128, h0=None):
    """Chunked state-space-dual (matmul) form of mamba2 — beyond-paper
    optimization for the memory-bound sequential scan (§Perf cell C).

    Valid when the decay is scalar-per-head (mamba2). Within a chunk of Q
    steps everything is dense matmuls (MXU work, no per-step state in HBM);
    one (H, P, N) state hand-off crosses chunks.

    x: (B, L, H, P); dt: (B, L, H) (post-softplus); a: (H,) negative;
    b, c: (B, L, N) (single group); d: (H,).
    Returns (y (B, L, H, P), h_last (B, H, P, N)).
    """
    bs, l, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q

    xf = x.astype(jnp.float32).reshape(bs, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(bs, nc, q, h)
    bf = b.astype(jnp.float32).reshape(bs, nc, q, n)
    cf = c.astype(jnp.float32).reshape(bs, nc, q, n)
    af = a.astype(jnp.float32)

    # per-chunk log-decay prefix: cum[t] = Σ_{r≤t} dt_r·a   (≤ 0)
    log_a = dtf * af[None, None, None, :]              # (B, NC, Q, H)
    cum = jnp.cumsum(log_a, axis=2)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,NC,Qt,Qs,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    w = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    g = jnp.einsum("bktn,bksn->bkts", cf, bf)          # (B,NC,Qt,Qs)
    m = g[..., None] * w * dtf[:, :, None, :, :]       # (B,NC,Qt,Qs,H)
    y_intra = jnp.einsum("bktsh,bkshp->bkthp", m, xf)

    # inter-chunk: scan the (H, P, N) hand-off
    decay_full = jnp.exp(cum[:, :, -1])                # (B, NC, H)
    # state injected by chunk k: Σ_s exp(cum_last-cum_s)·dt_s·x_s ⊗ B_s
    wsrc = jnp.exp(cum[:, :, -1:, :] - cum) * dtf      # (B,NC,Q,H)
    inj = jnp.einsum("bkqh,bkqhp,bkqn->bkhpn", wsrc, xf, bf)

    def step(hprev, inp):
        dk, ik = inp                                   # (B,H), (B,H,P,N)
        hnew = hprev * dk[..., None, None] + ik
        return hnew, hprev                             # emit PRE-chunk state

    if h0 is None:
        h0 = jnp.zeros((bs, h, p, n), jnp.float32)
    h_last, h_in = jax.lax.scan(
        step, h0, (decay_full.swapaxes(0, 1), inj.swapaxes(0, 1)))
    h_in = h_in.swapaxes(0, 1)                         # (B,NC,H,P,N)

    y_inter = jnp.einsum("bkqh,bkqn,bkhpn->bkqhp",
                         jnp.exp(cum), cf, h_in)
    y = (y_intra + y_inter).reshape(bs, nc * q, h, p)[:, :l]
    y = y + x.astype(jnp.float32)[:, :l] * d.astype(jnp.float32)[None, None, :, None]
    return y, h_last


# --------------------------------------------------------- bucket scatter

def bucket_scatter_add_ref(table, idx, payload):
    """Oracle of kernels/bucket_scatter.py: dropped out-of-range indices."""
    n = table.shape[0]
    idx = jnp.where(idx < n, idx, n)
    acc = table.astype(jnp.float32).at[idx].add(
        payload.astype(jnp.float32), mode="drop")
    return acc.astype(table.dtype)


# --------------------------------------------------------------- bitpack

def bitpack_lut_count_ref(packed, lut, count_val):
    """Oracle of kernels/bitpack.py lut+count: unpack all 16 fields, map
    through the scalar-encoded LUT, repack, count — over ALL W·16 fields."""
    shifts = (jnp.arange(16, dtype=jnp.uint32) * 2)[None, :]
    f = (packed.astype(jnp.uint32)[:, None] >> shifts) & 3
    nf = (jnp.uint32(lut) >> (2 * f)) & 3
    new = jnp.sum(nf << shifts, axis=1).astype(jnp.uint32)  # disjoint bits
    cnt = jnp.sum((nf == count_val).astype(jnp.int32))
    return new, cnt


def bitpack_scatter_mark_ref(packed, idx, mark, only_if):
    """Oracle of bitpack_scatter_mark: order-independent because a field is
    marked iff it *initially* holds only_if (later duplicates no-op)."""
    w = packed.shape[0]
    cap = w * 16
    shifts = (jnp.arange(16, dtype=jnp.uint32) * 2)[None, :]
    fields = ((packed.astype(jnp.uint32)[:, None] >> shifts) & 3).reshape(-1)
    idx = jnp.where((idx >= 0) & (idx < cap), idx, cap)
    tgt_val = fields[jnp.minimum(idx, cap - 1)]
    new_val = jnp.where(tgt_val == only_if, jnp.uint32(mark), tgt_val)
    fields = fields.at[idx].set(new_val, mode="drop")
    return jnp.sum(fields.reshape(w, 16) << shifts, axis=1).astype(jnp.uint32)


def bitpack_mark_rotate_count_ref(packed, idx, lut, count_val, mark, only_if):
    """Oracle of the fused bitpack_mark_rotate_count: the scatter-mark
    oracle followed by the lut+count oracle (the two passes the fused
    kernel collapses into one table residency)."""
    marked = bitpack_scatter_mark_ref(packed, idx, mark, only_if)
    return bitpack_lut_count_ref(marked, lut, count_val)


def bitpack_gather2_ref(packed, idx):
    """Oracle of bitpack_gather2: unpack every field, gather, OOB → 0."""
    w = packed.shape[0]
    cap = w * 16
    shifts = (jnp.arange(16, dtype=jnp.uint32) * 2)[None, :]
    fields = ((packed.astype(jnp.uint32)[:, None] >> shifts) & 3).reshape(-1)
    idx = jnp.asarray(idx).reshape(-1)
    ok = (idx >= 0) & (idx < cap)
    safe = jnp.clip(idx, 0, cap - 1).astype(jnp.int32)
    return jnp.where(ok, fields[safe], 0).astype(jnp.int32)
