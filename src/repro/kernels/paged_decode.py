"""Pallas TPU paged-decode attention — flash-decoding over the Roomy pages.

The serving hot loop: one query token per sequence attends over a paged KV
cache WITHOUT materializing the contiguous (B, S, kvh, hd) gather that the
jnp path builds (paged.gather). The page table is a *scalar-prefetch*
operand, so each grid step's BlockSpec index_map dereferences the table and
DMAs exactly one physical page — random page placement costs nothing (the
Roomy access pattern, resolved at the DMA level).

Grid: (batch, kv_heads, pages_per_seq←sequential). Per step: one (ps, hd)
K/V page against the query group's (g, hd) rows, online-softmax merged in
VMEM scratch. HBM traffic = the live cache bytes, once.

GQA: the q heads of one kv head's group ride along in the block (g = Hq/Hkv
rows) — one MXU matmul per page per group.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells it TPUCompilerParams; keep both working.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, ps: int, softcap, scale: float):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    npages = pl.num_programs(2)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = len_ref[b]
    page_start = pi * ps

    @pl.when(page_start < seq_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (g, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (ps, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        pos = page_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < seq_len
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_cur

    @pl.when(pi == npages - 1)
    def _finish():
        l = l_ref[:, 0]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(
    q: jax.Array,           # (B, Hq, hd)
    k_pages: jax.Array,     # (num_pages, ps, kvh, hd)
    v_pages: jax.Array,
    page_table: jax.Array,  # (B, pps) int32 physical page ids
    lengths: jax.Array,     # (B,) int32
    *,
    softcap: float | None = None,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, Hq, hd) in q.dtype."""
    b, hq, hd = q.shape
    _, ps, kvh, _ = k_pages.shape
    pps = page_table.shape[1]
    g = hq // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, kvh, g, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, pps),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda bb, h, pi, tbl, ln: (bb, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda bb, h, pi, tbl, ln: (tbl[bb, pi], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda bb, h, pi, tbl, ln: (tbl[bb, pi], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bb, h, pi, tbl, ln: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, ps=ps, softcap=softcap, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="roomy_paged_decode",
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(b, hq, hd)
