"""Pallas TPU kernels for the framework's compute hot spots.

  flash_attention      blockwise online-softmax attention
                       (causal/local/softcap/GQA, optional LSE residual)
  flash_attention_bwd  the matching backward pair (dkdv + dq kernels),
                       wired through ops.flash_attention's custom_vjp
  mamba_scan           chunked selective scan for mamba1/mamba2 archs
  paged_decode         flash-decoding over Roomy KV pages (scalar-prefetch
                       page-table DMA indexing — the serving hot loop)
  bucket_scatter       segment scatter-add — the Roomy sync apply phase
  bitpack              2-bit packed-array LUT-rotate/count + masked mark
                       scatter — the implicit-BFS per-level hot paths

ref.py also hosts the mamba2 SSD (chunked matmul) form — pure-jnp but
MXU-shaped, the §Perf cell-C optimization. Each kernel has a pure-jnp
oracle; ops.py holds the jit'd backend-dispatching wrappers. Kernels are
TPU-target and validated in interpret mode on CPU (tests/test_kernels.py
sweeps shapes × dtypes; backward vs jax.grad of the naive oracle).
"""
from . import ops, ref
from .ops import (bitpack_lut_count, bitpack_scatter_mark,
                  bucket_scatter_add, flash_attention, mamba_scan)

__all__ = ["ops", "ref", "bitpack_lut_count", "bitpack_scatter_mark",
           "bucket_scatter_add", "flash_attention", "mamba_scan"]
