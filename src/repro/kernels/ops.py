"""Public jit'd entry points for the Pallas kernels.

Backend dispatch policy (DESIGN.md §7):
  * TPU backend → pl.pallas_call (compiled Mosaic kernel)
  * anything else (CPU CI, the 512-device dry-run) → interpret mode for
    explicitly-requested kernel validation, otherwise the blocked jnp
    reference, whose HLO has the same FLOP count and a matching streaming
    memory profile (what cost_analysis reads).

``impl`` arg: "auto" | "pallas" | "interpret" | "ref".
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import bitpack as _bp
from . import bucket_scatter as _bs
from . import flash_attention as _fa
from . import mamba_scan as _ms
from . import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if _on_tpu() else "ref"


# ------------------------------------------------------------- attention

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash_kernel_vjp(q, k, v, causal, window, softcap, scale, block_q,
                      block_k, interpret):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=block_q, block_k=block_k, interpret=interpret)


def _flash_fwd(q, k, v, causal, window, softcap, scale, block_q, block_k,
               interpret):
    o, lse = _fa.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
        return_lse=True)
    return o, (q, k, v, o, lse)


def _flash_bwd_impl(causal, window, softcap, scale, block_q, block_k,
                    interpret, res, do):
    from .flash_attention_bwd import flash_attention_bwd
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd(
        q, k, v, o, lse, do, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=block_q, block_k=block_k, interpret=interpret)
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    if g > 1:                                 # GQA: sum the query group
        skv = k.shape[2]
        dk = dk.reshape(b, hkv, g, skv, d).sum(2)
        dv = dv.reshape(b, hkv, g, skv, d).sum(2)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_kernel_vjp.defvjp(_flash_fwd, _flash_bwd_impl)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "impl", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, impl="auto", block_q=128, block_k=128):
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        return _flash_kernel_vjp(q, k, v, causal, window, softcap, scale,
                                 block_q, block_k, mode == "interpret")
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale, block_k=block_k)


# ------------------------------------------------------------ mamba scan

@functools.partial(jax.jit, static_argnames=("impl", "block_d", "block_t"))
def mamba_scan(x, dt, a, b, c, d, *, impl="auto", block_d=256, block_t=128):
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        bsz, seq, di = x.shape
        bt = min(block_t, seq)
        pad = (-seq) % bt
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
            c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        bd = block_d
        while di % bd:
            bd //= 2
        y = _ms.mamba_scan(x, dt, a, b, c, d, block_d=bd, block_t=bt,
                           interpret=(mode == "interpret"))
        return y[:, :seq]
    # ref path: the associative form materializes (B, L, Di, N) — fine for
    # tests, ruinous at dry-run scale. Long sequences use the sequential
    # scan, whose live state matches the Pallas kernel's VMEM footprint.
    if x.shape[1] > 512:
        return _ref.mamba_scan_seq_ref(x, dt, a, b, c, d)
    return _ref.mamba_scan_ref(x, dt, a, b, c, d)


# --------------------------------------------------------- bucket scatter

@functools.partial(jax.jit, static_argnames=("impl", "block_m"))
def bucket_scatter_add(table, idx, payload, *, impl="auto", block_m=256):
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        return _bs.bucket_scatter_add(table, idx, payload, block_m=block_m,
                                      interpret=(mode == "interpret"))
    return _ref.bucket_scatter_add_ref(table, idx, payload)


# --------------------------------------------------------------- bitpack

@functools.partial(jax.jit, static_argnames=("lut", "count_val", "impl",
                                             "block_w"))
def bitpack_lut_count(packed, lut, count_val, *, impl="auto", block_w=8):
    """Map each 2-bit field of the packed words through the 4-entry LUT and
    count fields that map to ``count_val`` (over ALL W·16 fields — callers
    with fewer logical elements correct for their padding fields)."""
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        return _bp.bitpack_lut_count(packed, lut, count_val, block_w=block_w,
                                     interpret=(mode == "interpret"))
    return _ref.bitpack_lut_count_ref(packed, lut, count_val)


@functools.partial(jax.jit, static_argnames=("mark", "only_if", "impl",
                                             "block_m"))
def bitpack_scatter_mark(packed, idx, *, mark=2, only_if=0, impl="auto",
                         block_m=256):
    """packed[idx]'s 2-bit field ← mark where it currently holds only_if;
    out-of-range indices dropped, duplicates safe (first mark wins)."""
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        return _bp.bitpack_scatter_mark(packed, idx, mark=mark,
                                        only_if=only_if, block_m=block_m,
                                        interpret=(mode == "interpret"))
    return _ref.bitpack_scatter_mark_ref(packed, idx, mark, only_if)


@functools.partial(jax.jit, static_argnames=("lut", "count_val", "mark",
                                             "only_if", "impl", "block_m"))
def bitpack_mark_rotate_count(packed, idx, lut, count_val, *, mark=2,
                              only_if=0, impl="auto", block_m=256):
    """Fused scatter-mark + lut-rotate + count — the implicit BFS's whole
    per-level array pass in one kernel (one HBM traversal of the packed
    words instead of two).  Semantics are exactly bitpack_scatter_mark
    followed by bitpack_lut_count; the count covers ALL W·16 fields."""
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        return _bp.bitpack_mark_rotate_count(
            packed, idx, lut, count_val, mark=mark, only_if=only_if,
            block_m=block_m, interpret=(mode == "interpret"))
    return _ref.bitpack_mark_rotate_count_ref(packed, idx, lut, count_val,
                                              mark, only_if)


def bitpack_gather2(packed, idx, *, impl="auto", page_words=512,
                    block_m=256):
    """Gather the 2-bit field for each element index (OOB/negative → 0) —
    the serving tier's Tier J batched-lookup path.  NOT jit-wrapped as a
    whole: the kernel path bins queries to pages host-side (numpy in
    bitpack.gather2_plan, data-dependent shapes), exactly like the oracle
    server bins queries to chunks; the pallas_call itself compiles."""
    mode = _resolve(impl)
    if mode in ("pallas", "interpret"):
        return _bp.bitpack_gather2(packed, idx, page_words=page_words,
                                   block_m=block_m,
                                   interpret=(mode == "interpret"))
    return _ref.bitpack_gather2_ref(packed, idx)
