"""Pallas TPU flash-attention backward — completes the kernel pair.

Standard two-kernel decomposition (FlashAttention-2 style):

  dkdv kernel   grid (B, Hq, KVb, Qb←sequential): per kv block, accumulate
                dK/dV over all visible q blocks in VMEM scratch
  dq kernel     grid (B, Hq, Qb, KVb←sequential): per q block, accumulate
                dQ over all visible kv blocks

Recomputation uses the forward's LSE residual (one f32 row per query —
flash_attention(…, return_lse=True)), plus D = rowsum(dO ∘ O) computed in
plain jnp by the wrapper (elementwise; not worth a kernel).

GQA: gradients are produced per *query* head — the ops.py wrapper sums
dK/dV over each kv head's group (exactly what the math says).

Softcap: s = c·tanh(u/c) ⇒ ds/du = 1 − (s/c)², applied inside both
kernels. Causal/window masking matches the forward block-skip logic.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .flash_attention import NEG_INF


def _logits(q, k, scale, softcap):
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s_capped = jnp.tanh(s / softcap) * softcap
        dcap = 1.0 - jnp.square(s_capped / softcap)
        return s_capped, dcap
    return s, None


def _mask(q_start, k_start, bq, bk, seq_kv, causal, window):
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = k_pos < seq_kv
    if causal:
        m &= k_pos <= q_pos
    if window is not None:
        m &= k_pos >= q_pos - window
    return m


def _run(q_start, k_start, bq, bk, causal, window):
    run = jnp.asarray(True)
    if causal:
        run = run & (k_start <= q_start + bq - 1)
    if window is not None:
        run = run & (k_start + bk - 1 >= q_start - window)
    return run


def _dkdv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dvec_ref,
                 dk_ref, dv_ref, dk_acc, dv_acc, *,
                 scale, causal, window, softcap, bq, bk, seq_kv):
    kvi = pl.program_id(2)
    qi = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start, k_start = qi * bq, kvi * bk

    @pl.when(_run(q_start, k_start, bq, bk, causal, window))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]                           # (bq,)
        dvec = dvec_ref[0, 0]                         # (bq,) rowsum(dO·O)
        s, dcap = _logits(q, k, scale, softcap)
        msk = _mask(q_start, k_start, bq, bk, seq_kv, causal, window)
        p = jnp.exp(jnp.where(msk, s, NEG_INF) - lse[:, None])
        p = jnp.where(msk, p, 0.0)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # pᵀ dO (bk, d)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # (bq, bk)
        ds = p * (dp - dvec[:, None])
        if softcap is not None:
            ds = ds * dcap
        ds = ds * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # dsᵀ q (bk, d)

    @pl.when(qi == nq - 1)
    def _emit():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dvec_ref, dq_ref,
               dq_acc, *, scale, causal, window, softcap, bq, bk, seq_kv):
    qi = pl.program_id(2)
    kvi = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kvi == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_start, k_start = qi * bq, kvi * bk

    @pl.when(_run(q_start, k_start, bq, bk, causal, window))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        dvec = dvec_ref[0, 0]
        s, dcap = _logits(q, k, scale, softcap)
        msk = _mask(q_start, k_start, bq, bk, seq_kv, causal, window)
        p = jnp.exp(jnp.where(msk, s, NEG_INF) - lse[:, None])
        p = jnp.where(msk, p, 0.0)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        ds = p * (dp - dvec[:, None])
        if softcap is not None:
            ds = ds * dcap
        ds = ds * scale
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)       # ds k (bq, d)

    @pl.when(kvi == nk - 1)
    def _emit():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def flash_attention_bwd(
    q, k, v, o, lse, do, *,
    causal=True, window=None, softcap=None, scale=None,
    block_q=128, block_k=128, interpret=False,
):
    """Returns (dq (B,Hq,Sq,D), dk (B,Hq,Skv,D), dv (B,Hq,Skv,D)).

    dk/dv are per *query* head; sum groups for GQA (ops wrapper).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    groups = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bq = min(block_q, max(sq, 8))
    bk = min(block_k, max(skv, 8))
    sq_p = -(-sq // bq) * bq
    skv_p = -(-skv // bk) * bk
    pad_q = [(0, 0), (0, 0), (0, sq_p - sq), (0, 0)]
    pad_k = [(0, 0), (0, 0), (0, skv_p - skv), (0, 0)]
    if sq_p != sq:
        q, o, do = (jnp.pad(x, pad_q) for x in (q, o, do))
        lse = jnp.pad(lse, [(0, 0), (0, 0), (0, sq_p - sq)],
                      constant_values=0.0)
    if skv_p != skv:
        k, v = (jnp.pad(x, pad_k) for x in (k, v))

    dvec = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                   axis=-1)                             # (b, hq, sq_p)

    kw = dict(scale=scale, causal=causal, window=window, softcap=softcap,
              bq=bq, bk=bk, seq_kv=skv)
    g = groups
    q_spec = pl.BlockSpec((1, 1, bq, d), lambda bb, h, x, y: (bb, h, y, 0))
    k_spec = pl.BlockSpec((1, 1, bk, d),
                          lambda bb, h, x, y: (bb, h // g, x, 0))
    row_spec = pl.BlockSpec((1, 1, bq), lambda bb, h, x, y: (bb, h, y))
    params = pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "parallel",
                             "arbitrary"))

    dk, dv = pl.pallas_call(
        functools.partial(_dkdv_kernel, **kw),
        grid=(b, hq, skv_p // bk, sq_p // bq),
        in_specs=[q_spec, k_spec, k_spec, q_spec, row_spec, row_spec],
        out_specs=(pl.BlockSpec((1, 1, bk, d),
                                lambda bb, h, x, y: (bb, h, x, 0)),
                   pl.BlockSpec((1, 1, bk, d),
                                lambda bb, h, x, y: (bb, h, x, 0))),
        out_shape=(jax.ShapeDtypeStruct((b, hq, skv_p, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, hq, skv_p, d), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        compiler_params=params, interpret=interpret,
        name="roomy_flash_attention_dkdv",
    )(q, k, v, do, lse, dvec)

    q_spec2 = pl.BlockSpec((1, 1, bq, d), lambda bb, h, x, y: (bb, h, x, 0))
    k_spec2 = pl.BlockSpec((1, 1, bk, d),
                           lambda bb, h, x, y: (bb, h // g, y, 0))
    row_spec2 = pl.BlockSpec((1, 1, bq), lambda bb, h, x, y: (bb, h, x))
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **kw),
        grid=(b, hq, sq_p // bq, skv_p // bk),
        in_specs=[q_spec2, k_spec2, k_spec2, q_spec2, row_spec2, row_spec2],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bb, h, x, y: (bb, h, x, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=params, interpret=interpret,
        name="roomy_flash_attention_dq",
    )(q, k, v, do, lse, dvec)

    return (dq[:, :, :sq].astype(q.dtype),
            dk[:, :, :skv].astype(q.dtype),
            dv[:, :, :skv].astype(q.dtype))
