"""Pallas TPU segment scatter-add — the apply phase of a Roomy sync.

After the bucket exchange (core/delayed.py) every shard holds a batch of
(index, payload) update ops destined for its local table slice (embedding
gradients, hashtable values, KV pages). The sync sorts ops by index, so the
kernel sees *runs* of equal indices and can accumulate each run in VMEM,
touching the table once per run instead of once per op — the random-write →
streaming-write conversion that is the heart of the paper.

Correctness does not depend on sortedness (every index change just flushes
the run accumulator through a read-modify-write), so the oracle can be
plain segment_sum; sorted input is purely a performance property.

Mechanics: one sequential grid axis over op blocks; scratch carries the
current run (index in SMEM, (1, D) accumulator in VMEM) across blocks. The
table block must fit VMEM — callers tile big tables into bucket slices
first (which the Roomy layout already provides). Masked flushes go to a
trash row appended at table index N, avoiding data-dependent control flow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 256


def _scatter_kernel(idx_ref, pay_ref, tab_ref, out_ref, cur_ref, acc_ref, *,
                    bm: int, n_rows: int):
    blk = pl.program_id(0)
    nblk = pl.num_programs(0)

    @pl.when(blk == 0)
    def _init():
        out_ref[...] = tab_ref[...]
        cur_ref[0] = n_rows                      # trash row
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(i, _):
        row_idx = idx_ref[i, 0]
        cur = cur_ref[0]
        boundary = row_idx != cur
        # Flush the finished run to its row (or to trash if mid-run).
        tgt = jnp.where(boundary, jnp.minimum(cur, n_rows), n_rows)
        old = pl.load(out_ref, (pl.ds(tgt, 1), slice(None)))
        pl.store(out_ref, (pl.ds(tgt, 1), slice(None)), old + acc_ref[...])
        pay = pay_ref[i].astype(jnp.float32)[None, :]
        acc_ref[...] = jnp.where(boundary, pay, acc_ref[...] + pay)
        cur_ref[0] = row_idx
        return 0

    jax.lax.fori_loop(0, bm, body, 0)

    @pl.when(blk == nblk - 1)
    def _final_flush():
        tgt = jnp.minimum(cur_ref[0], n_rows)
        old = pl.load(out_ref, (pl.ds(tgt, 1), slice(None)))
        pl.store(out_ref, (pl.ds(tgt, 1), slice(None)), old + acc_ref[...])


def bucket_scatter_add(
    table: jax.Array,    # (N, D) f32 — the owner's table slice
    idx: jax.Array,      # (M,) int32; idx >= N (or == N) means "drop"
    payload: jax.Array,  # (M, D)
    *,
    block_m: int = DEFAULT_BM,
    interpret: bool = False,
) -> jax.Array:
    """table[idx[i]] += payload[i] for all i; out-of-range indices dropped.

    Returns the updated (N, D) table. Sorted idx is faster (fewer RMWs) but
    not required.
    """
    n, d = table.shape
    m = idx.shape[0]
    bm = min(block_m, m)
    m_pad = -(-m // bm) * bm
    if m_pad != m:
        idx = jnp.pad(idx, (0, m_pad - m), constant_values=n)
        payload = jnp.pad(payload, ((0, m_pad - m), (0, 0)))
    idx = jnp.minimum(idx.astype(jnp.int32), n).reshape(m_pad, 1)
    tab_p = jnp.concatenate([table.astype(jnp.float32),
                             jnp.zeros((1, d), jnp.float32)], axis=0)

    kernel = functools.partial(_scatter_kernel, bm=bm, n_rows=n)
    out = pl.pallas_call(
        kernel,
        grid=(m_pad // bm,),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),          # idx
            pl.BlockSpec((bm, d), lambda i: (i, 0)),          # payload
            pl.BlockSpec((n + 1, d), lambda i: (0, 0)),       # table
        ],
        out_specs=pl.BlockSpec((n + 1, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n + 1, d), jnp.float32),
        scratch_shapes=[
            pltpu.SMEM((1,), jnp.int32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="roomy_bucket_scatter",
    )(idx, payload, tab_p)
    return out[:n].astype(table.dtype)
