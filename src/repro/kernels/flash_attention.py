"""Pallas TPU flash attention — the compute hot spot of every attention arch.

TPU-native design (DESIGN.md §7): online-softmax blockwise attention with
q/kv tiles sized for the 128×128 MXU and all accumulators resident in VMEM.
The kv-block grid axis is the innermost ("arbitrary" = sequential) axis, so
the (BQ, D) f32 accumulator + (BQ,) m/l statistics persist in VMEM scratch
across kv steps — the HBM traffic is exactly one read of Q/K/V and one
write of O (the flash property).

Variants needed by the assigned archs (all compile-time flags):
  causal         decoder LMs
  local window   gemma2 alternating local layers (sliding window)
  logit softcap  gemma2 (tanh soft-capping)
  GQA            q-head groups share one kv head (phi3.5/minicpm/…)

Grid: (batch, q_heads, q_blocks, kv_blocks); kv innermost-sequential.
Block shapes: q (1, 1, BQ, D), k/v (1, 1, BK, D), out (1, 1, BQ, D).
Scratch: acc (BQ, D) f32, m (BQ, 1) f32, l (BQ, 1) f32 — ~BQ·(D+2)·4 bytes
≈ 66 KB at BQ=128, D=128: comfortably inside one core's VMEM next to the
~128 KB of q/k/v tiles.

Causal skipping: fully-masked kv blocks are skipped with @pl.when (no MXU
work issued), giving the ~2× causal saving.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int | None,
                 softcap: float | None, bq: int, bk: int, seq_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk

    # Static-shape block skip decisions are data-independent → pl.when.
    run = jnp.asarray(True)
    if causal:
        run = run & (k_start <= q_start + bq - 1)
    if window is not None:
        run = run & (k_start + bk - 1 >= q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < seq_kv
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos >= q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                           # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_cur

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[:, 0]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


def _attn_kernel_lse(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref,
                     l_ref, **kw):
    """Forward variant that also emits the row log-sum-exp (bwd residual)."""
    _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, **kw)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == nk - 1)
    def _emit_lse():
        l = l_ref[:, 0]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        lse_ref[0, 0] = (m_ref[:, 0] + jnp.log(safe_l)).astype(lse_ref.dtype)


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK,
    interpret: bool = False,
    return_lse: bool = False,
):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); Hq % Hkv == 0.

    Returns (B, Hq, Sq, D) in q.dtype (and the (B, Hq, Sq) f32 row
    log-sum-exp when return_lse — the backward residual). Sequences are
    padded to block multiples internally; `window` is the number of
    *previous* positions visible (exclusive of self), matching gemma2's
    sliding window.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    groups = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    bq = min(block_q, max(sq, 8))
    bk = min(block_k, max(skv, 8))
    sq_p = -(-sq // bq) * bq
    skv_p = -(-skv // bk) * bk
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))

    grid = (b, hq, sq_p // bq, skv_p // bk)
    kw = dict(scale=scale, causal=causal, window=window,
              softcap=softcap, bq=bq, bk=bk, seq_kv=skv)
    in_specs = [
        pl.BlockSpec((1, 1, bq, d), lambda bb, h, qi, ki: (bb, h, qi, 0)),
        pl.BlockSpec((1, 1, bk, d),
                     lambda bb, h, qi, ki, g=groups: (bb, h // g, ki, 0)),
        pl.BlockSpec((1, 1, bk, d),
                     lambda bb, h, qi, ki, g=groups: (bb, h // g, ki, 0)),
    ]
    o_spec = pl.BlockSpec((1, 1, bq, d), lambda bb, h, qi, ki: (bb, h, qi, 0))
    scratch = [
        pltpu.VMEM((bq, d), jnp.float32),
        pltpu.VMEM((bq, 1), jnp.float32),
        pltpu.VMEM((bq, 1), jnp.float32),
    ]
    params = pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "parallel",
                             "arbitrary"))
    if return_lse:
        out, lse = pl.pallas_call(
            functools.partial(_attn_kernel_lse, **kw),
            grid=grid, in_specs=in_specs,
            out_specs=(o_spec,
                       pl.BlockSpec((1, 1, bq),
                                    lambda bb, h, qi, ki: (bb, h, qi))),
            out_shape=(jax.ShapeDtypeStruct((b, hq, sq_p, d), q.dtype),
                       jax.ShapeDtypeStruct((b, hq, sq_p), jnp.float32)),
            scratch_shapes=scratch, compiler_params=params,
            interpret=interpret, name="roomy_flash_attention_fwd",
        )(q, k, v)
        return out[:, :, :sq, :], lse[:, :, :sq]
    out = pl.pallas_call(
        functools.partial(_attn_kernel, **kw),
        grid=grid, in_specs=in_specs, out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, d), q.dtype),
        scratch_shapes=scratch, compiler_params=params,
        interpret=interpret, name="roomy_flash_attention",
    )(q, k, v)
    return out[:, :, :sq, :]
