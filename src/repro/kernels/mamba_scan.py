"""Pallas TPU selective-scan (Mamba) kernel — the SSM archs' dominant op.

The recurrence per (channel i, state j):

    h[i,j] ← exp(Δ_t·A[i,j])·h[i,j] + (Δ_t·x_t[i])·B_t[j]
    y_t[i]  = Σ_j C_t[j]·h[i,j] + D[i]·x_t[i]

TPU adaptation (DESIGN.md §7): time is *chunked* — the grid's innermost
(sequential) axis walks time chunks while the (BD, N) state block lives in
VMEM scratch across steps, so HBM traffic is one streaming read of
x/Δ/B/C and one write of y per chunk: the Roomy streaming discipline
applied to the time dimension. batch × channel-blocks are the parallel
grid axes (channel blocks are independent, unlike attention rows).

d_state N is small (16 for falcon-mamba / 64-128 for mamba2's head form),
so the per-step work is VPU-heavy outer products; the MXU matmul form
(chunked SSD) is a possible further optimization, noted in EXPERIMENTS.md.

mamba2 reduces to this kernel with A[i,j] = a_head(i) broadcast and x/Δ in
(heads·head_dim) channel layout (see models/ssm.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BD = 256    # channel block
DEFAULT_BT = 128    # time chunk


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_ref, *,
                 bt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)                 # (bd, n)
    d = d_ref[...].astype(jnp.float32)                 # (1, bd)

    def step(t, h):
        xt = x_ref[0, t].astype(jnp.float32)           # (bd,)
        dtt = dt_ref[0, t].astype(jnp.float32)         # (bd,)
        bt_ = b_ref[0, t].astype(jnp.float32)          # (n,)
        ct = c_ref[0, t].astype(jnp.float32)           # (n,)
        da = jnp.exp(dtt[:, None] * a)                 # (bd, n)
        h = h * da + (dtt * xt)[:, None] * bt_[None, :]
        y = jnp.sum(h * ct[None, :], axis=1) + d[0] * xt
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bt, step, h_ref[...])
    h_ref[...] = h


def mamba_scan(
    x: jax.Array,       # (B, L, Di)
    dt: jax.Array,      # (B, L, Di)  — already softplus'd Δ
    a: jax.Array,       # (Di, N)     — negative decay rates
    b: jax.Array,       # (B, L, N)
    c: jax.Array,       # (B, L, N)
    d: jax.Array,       # (Di,)
    *,
    block_d: int = DEFAULT_BD,
    block_t: int = DEFAULT_BT,
    interpret: bool = False,
) -> jax.Array:
    """Returns y: (B, L, Di) in x.dtype. L must be a multiple of block_t
    (callers pad); Di a multiple of block_d (block shrinks if Di small)."""
    bsz, seq, di = x.shape
    n = a.shape[1]
    bd = min(block_d, di)
    bt = min(block_t, seq)
    assert di % bd == 0 and seq % bt == 0, (di, bd, seq, bt)

    grid = (bsz, di // bd, seq // bt)
    kernel = functools.partial(_scan_kernel, bt=bt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda bb, dd, tt: (bb, tt, dd)),   # x
            pl.BlockSpec((1, bt, bd), lambda bb, dd, tt: (bb, tt, dd)),   # dt
            pl.BlockSpec((bd, n), lambda bb, dd, tt: (dd, 0)),            # a
            pl.BlockSpec((1, bt, n), lambda bb, dd, tt: (bb, tt, 0)),     # b
            pl.BlockSpec((1, bt, n), lambda bb, dd, tt: (bb, tt, 0)),     # c
            pl.BlockSpec((1, bd), lambda bb, dd, tt: (0, dd)),            # d
        ],
        out_specs=pl.BlockSpec((1, bt, bd), lambda bb, dd, tt: (bb, tt, dd)),
        out_shape=jax.ShapeDtypeStruct((bsz, seq, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="roomy_mamba_scan",
    )(x, dt, a, b, c, d.reshape(1, -1))
