"""RoomyHashTable — capacity-bounded key→value map with delayed ops.

The paper's RoomyHashTable buckets (key, value) pairs by key hash so that a
sync never needs a global sort.  Functionally we keep the table as rows
sorted by (hash(key), key): a sync is then a sorted merge of the queued
batch against the table — a pure streaming pass, and precisely the per-
bucket merge Roomy performs on disk (the Tier-D twin in disk/dhash.py
executes the same merge per bucket file).

Operations (Table 1):
  insert/update  delayed   -> queued, executed by ``sync``
  remove         delayed   -> queued with a tombstone flag
  access         delayed   -> ``lookup`` (batched sorted-merge probe)
  sync/size/map/reduce/predicateCount -> immediate

Keys are (key_width,) uint32 rows; values any dtype/shape. The all-ones key
is reserved (sentinel), as in types.py.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import types as T


class RoomyHashTable(NamedTuple):
    keys: jax.Array    # (cap, kw) uint32 — sorted by (hash, key); sentinel-padded
    vals: jax.Array    # (cap, *vshape)
    count: jax.Array   # () int32
    q_keys: jax.Array  # (qcap, kw) uint32
    q_vals: jax.Array  # (qcap, *vshape)
    q_del: jax.Array   # (qcap,) bool — tombstone flags
    q_n: jax.Array     # () int32

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    @property
    def key_width(self) -> int:
        return self.keys.shape[1]

    @property
    def queue_capacity(self) -> int:
        return self.q_keys.shape[0]


def _sort_key(keys: jax.Array) -> jax.Array:
    """Lexsort permutation by (hash(key), key words...)."""
    h = T.hash_rows(keys)
    # Sentinel keys must sort last: force their hash to max.
    h = jnp.where(T.is_sentinel(keys), T.UINT32_MAX, h)
    cols = [keys[:, j] for j in range(keys.shape[1] - 1, -1, -1)] + [h]
    return jnp.lexsort(tuple(cols))


def make(capacity: int, key_width: int, queue_capacity: int,
         val_shape: tuple = (), val_dtype=jnp.uint32) -> RoomyHashTable:
    return RoomyHashTable(
        keys=T.sentinel_rows(capacity, key_width),
        vals=jnp.zeros((capacity,) + val_shape, val_dtype),
        count=jnp.zeros((), jnp.int32),
        q_keys=T.sentinel_rows(queue_capacity, key_width),
        q_vals=jnp.zeros((queue_capacity,) + val_shape, val_dtype),
        q_del=jnp.zeros((queue_capacity,), bool),
        q_n=jnp.zeros((), jnp.int32),
    )


def _queue(ht: RoomyHashTable, keys, vals, deletes, valid):
    qcap = ht.queue_capacity
    dest = ht.q_n + jnp.cumsum(valid.astype(jnp.int32)) - 1
    dest = jnp.where(valid, dest, qcap)
    q_keys = ht.q_keys.at[dest].set(keys.astype(jnp.uint32), mode="drop")
    q_vals = ht.q_vals.at[dest].set(vals.astype(ht.q_vals.dtype), mode="drop")
    q_del = ht.q_del.at[dest].set(deletes, mode="drop")
    nvalid = jnp.sum(valid.astype(jnp.int32))
    overflow = ht.q_n + nvalid > qcap
    q_n = jnp.minimum(ht.q_n + nvalid, qcap)
    return ht._replace(q_keys=q_keys, q_vals=q_vals, q_del=q_del, q_n=q_n), overflow


def insert(ht: RoomyHashTable, keys: jax.Array, vals: jax.Array,
           valid: jax.Array | None = None):
    """Queue delayed inserts/updates for a batch of (key, value) pairs."""
    if valid is None:
        valid = jnp.ones((keys.shape[0],), bool)
    valid = valid & ~T.is_sentinel(keys)
    return _queue(ht, keys, vals, jnp.zeros((keys.shape[0],), bool), valid)


def remove(ht: RoomyHashTable, keys: jax.Array, valid: jax.Array | None = None):
    """Queue delayed removals."""
    if valid is None:
        valid = jnp.ones((keys.shape[0],), bool)
    valid = valid & ~T.is_sentinel(keys)
    vals = jnp.zeros((keys.shape[0],) + ht.q_vals.shape[1:], ht.q_vals.dtype)
    return _queue(ht, keys, vals, jnp.ones((keys.shape[0],), bool), valid)


def sync(
    ht: RoomyHashTable,
    combine: Callable = None,
    apply: Callable = None,
) -> RoomyHashTable:
    """Execute all queued ops as one sorted merge (streaming pass).

    combine(v1, v2): merges two queued payloads for the same key, folded in
        ISSUE ORDER (default: last-wins — the stable sort keeps each key's
        queue rows in the order they were queued).
    apply(old_val, agg, present): vectorized; present is a bool mask saying
        whether the key already existed. Default: insert/overwrite with agg.

    Op-log ORDER is honoured per key, matching Tier D's DiskHashTable.sync
    exactly (the ROADMAP alignment item): a DEL wipes the key *and every
    earlier queued PUT*, and PUTs after the last DEL resurrect the key —
    their combine-fold applies against ``present=False`` (the old value is
    gone, ``old`` reads as zeros).  A key whose last op is DEL is removed.
    This is sequential execution of the log, pinned by
    TestRoomyHashTableOpOrder next to Tier D's TestDiskHashTableOpOrder.
    """
    if combine is None:
        combine = lambda a, b: b
    if apply is None:
        apply = lambda old, agg, present: agg

    cap, qcap = ht.capacity, ht.queue_capacity
    in_q = jnp.arange(qcap) < ht.q_n
    qk = jnp.where(in_q[:, None], ht.q_keys, T.UINT32_MAX)

    all_keys = jnp.concatenate([ht.keys, qk], axis=0)
    all_vals = jnp.concatenate([ht.vals, ht.q_vals], axis=0)
    from_tab = jnp.concatenate([jnp.arange(cap) < ht.count,
                                jnp.zeros((qcap,), bool)])
    is_del = jnp.concatenate([jnp.zeros((cap,), bool), ht.q_del & in_q])

    perm = _sort_key(all_keys)
    k_s, v_s = all_keys[perm], all_vals[perm]
    tab_s, del_s = from_tab[perm], is_del[perm]
    valid_s = ~T.is_sentinel(k_s)

    rid = T.run_ids(k_s)
    nseg = cap + qcap
    starts = T.first_of_run(k_s)
    pos = jnp.arange(nseg)
    qrow = valid_s & ~tab_s

    # Sequential per-key semantics: within a run the stable sort yields
    # [table row?, queue rows in issue order].  Everything at or before a
    # key's LAST DEL is wiped; the PUTs strictly after it are "live".
    run_pos = pos - jax.lax.associative_scan(
        jnp.maximum, jnp.where(starts, pos, 0))
    last_del = jax.ops.segment_max(
        jnp.where(del_s & qrow, run_pos, -1), rid, num_segments=nseg)
    live_s = qrow & ~del_s & (run_pos > last_del[rid])

    # Combine-fold over the live PUTs only, in issue order: every non-live
    # row restarts a segment (isolating itself), and so does the first live
    # row after one — live rows are a contiguous run suffix, so the run's
    # last row then carries the fold of exactly the live PUTs.
    prev_live = jnp.concatenate([jnp.zeros((1,), bool), live_s[:-1]])
    seg_starts = starts | ~live_s | ~prev_live
    agg = T.segmented_reduce_last(v_s, seg_starts, combine)

    run_has_tab = jax.ops.segment_max(tab_s.astype(jnp.int32), rid, num_segments=nseg)
    run_had_del = jax.ops.segment_max((del_s & qrow).astype(jnp.int32), rid,
                                      num_segments=nseg)
    run_has_live = jax.ops.segment_max(live_s.astype(jnp.int32), rid,
                                       num_segments=nseg)
    # Sorted position of the table row within each run (or -1): stable sort
    # puts the (unique) table row first in its run.
    run_tab_idx = jax.ops.segment_max(
        jnp.where(tab_s, pos, -1), rid, num_segments=nseg
    )

    # A DEL wiped the stored value: resurrecting PUTs apply as inserts
    # (present=False, old=0), exactly like Tier D's present_eff.
    present = (run_has_tab[rid] == 1) & (run_had_del[rid] == 0)
    deleted = (run_had_del[rid] == 1) & (run_has_live[rid] == 0)
    pmask = present.reshape((-1,) + (1,) * (v_s.ndim - 1))
    old = jnp.where(pmask, v_s[jnp.maximum(run_tab_idx[rid], 0)],
                    jnp.zeros_like(v_s))
    new_val = apply(old, agg, present)

    # Survivors: one row per run — the run's last row when it is live (it
    # carries the fold of the live PUTs); pure-table runs keep their table
    # row unless their key was deleted.
    run_last = jnp.concatenate([rid[1:] != rid[:-1], jnp.ones((1,), bool)])
    keep_tab_row = tab_s & (run_has_live[rid] == 0) & ~deleted
    keep_q_row = live_s & run_last
    keep = (keep_tab_row | keep_q_row) & valid_s

    qmask = keep_q_row.reshape((-1,) + (1,) * (new_val.ndim - 1))
    out_val = jnp.where(qmask, new_val, v_s)

    # Compact survivors (stable: preserves (hash, key) sort order).
    cperm = jnp.argsort(~keep, stable=True)
    k_c, v_c = k_s[cperm], out_val[cperm]
    kept = keep[cperm]
    k_c = jnp.where(kept[:, None], k_c, T.UINT32_MAX)
    count = jnp.sum(keep.astype(jnp.int32))
    overflow = count > cap

    new_ht = RoomyHashTable(
        keys=k_c[:cap],
        vals=v_c[:cap],
        count=jnp.minimum(count, cap),
        q_keys=T.sentinel_rows(qcap, ht.key_width),
        q_vals=jnp.zeros_like(ht.q_vals),
        q_del=jnp.zeros((qcap,), bool),
        q_n=jnp.zeros((), jnp.int32),
    )
    return new_ht, overflow


def lookup(ht: RoomyHashTable, queries: jax.Array):
    """Batched access: returns (vals, found). Streaming sorted-merge probe."""
    m = queries.shape[0]
    cap = ht.capacity
    all_keys = jnp.concatenate([ht.keys, queries.astype(jnp.uint32)], axis=0)
    from_tab = jnp.concatenate([jnp.arange(cap) < ht.count, jnp.zeros((m,), bool)])
    perm = _sort_key(all_keys)
    k_s, tab_s = all_keys[perm], from_tab[perm]
    rid = T.run_ids(k_s)
    nseg = cap + m
    run_tab_idx = jax.ops.segment_max(
        jnp.where(tab_s, perm, -1), rid, num_segments=nseg
    )
    hit_idx_s = run_tab_idx[rid]                      # original table index or -1
    hit_idx = jnp.full((nseg,), -1, jnp.int32).at[perm].set(hit_idx_s)
    hit_idx_q = hit_idx[cap:]
    found = (hit_idx_q >= 0) & ~T.is_sentinel(queries)
    vals = ht.vals[jnp.maximum(hit_idx_q, 0)]
    return vals, found


def size(ht: RoomyHashTable) -> jax.Array:
    return ht.count


def map_items(ht: RoomyHashTable, fn: Callable):
    """fn(key_row, val) vectorized over the table (invalid slots included —
    mask with arange<count on the caller side)."""
    return jax.vmap(fn)(ht.keys, ht.vals)


def reduce(ht: RoomyHashTable, elt_fn: Callable, merge_fn: Callable, identity):
    vals = jax.vmap(elt_fn)(ht.keys, ht.vals)
    mask = (jnp.arange(ht.capacity) < ht.count)
    mask = mask.reshape((-1,) + (1,) * (vals.ndim - 1))
    vals = jnp.where(mask, vals, jnp.asarray(identity, vals.dtype))
    return T.tree_reduce(vals, merge_fn, identity)


def predicate_count(ht: RoomyHashTable, pred: Callable) -> jax.Array:
    hits = jax.vmap(pred)(ht.keys, ht.vals) & (jnp.arange(ht.capacity) < ht.count)
    return jnp.sum(hits.astype(jnp.int32))
