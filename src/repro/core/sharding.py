"""Owner maps and mesh helpers for sharded Roomy structures.

Roomy distributes each structure across "disks" by a static owner function;
here the disks are mesh shards. Two owner maps, matching the paper:

* arrays: block distribution — owner(i) = i // (n / nshards)
* hash tables / lists: hash distribution — owner(x) = hash(x) % nshards
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import types as T


def block_owner(idx: jax.Array, n: int, nshards: int) -> jax.Array:
    """Owner shard of array index idx under block distribution.

    MUST stay bit-identical to the jax-free Tier D mirror
    ``disk.buckets.block_owner_np`` — the multiprocess ShardRuntime routes
    with the numpy version, and an ownership disagreement between
    processes silently corrupts a sharded structure.  Golden-value tests
    in tests/test_cluster.py pin both sides.
    """
    per = -(-n // nshards)  # ceil
    return (idx // per).astype(jnp.int32)


def hash_owner(rows: jax.Array, nshards: int) -> jax.Array:
    """Owner shard of an element/key row under hash distribution.

    Mirrored by ``disk.buckets.hash_owner_np`` (same constraint as
    :func:`block_owner`: pinned cross-process by golden-value tests).
    """
    return (T.hash_rows(rows) % jnp.uint32(nshards)).astype(jnp.int32)


def shard_leading(x: jax.Array, mesh: Mesh, axis: str) -> jax.Array:
    """Place x with its leading dim sharded over ``axis``."""
    spec = P(axis, *([None] * (x.ndim - 1)))
    return jax.device_put(x, NamedSharding(mesh, spec))


def replicated(x, mesh: Mesh):
    return jax.device_put(x, NamedSharding(mesh, P()))


def axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis]
