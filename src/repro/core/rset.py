"""RoomySet — the native set the paper names as future work (§3):

  "Some of these operations (particularly intersection) are sub-optimal
   when built using the current set of primitives. Future work is planned
   to add a native RoomySet data structure. … Set intersection may become
   a Roomy primitive in the future."

Representation: rows kept **sorted-unique** (sentinel-padded), so every
set operation is ONE merge pass — no 3-temporary intersection dance:

  union         merge + dedup                 O((n+m)·log)
  intersection  rows present in both runs     O((n+m)·log)
  difference    rows present only in A        O((n+m)·log)
  member_mask   sorted-merge probe            O((n+m)·log)

vs the RoomyList recipes: union 2 passes, intersection 7 passes over
3 temporaries (benchmarked in benchmarks/constructs.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import rlist as RL
from . import types as T


class RoomySet(NamedTuple):
    data: jax.Array   # (capacity, width) uint32, sorted-unique then sentinel
    count: jax.Array  # () int32

    @property
    def capacity(self) -> int:
        return self.data.shape[0]

    @property
    def width(self) -> int:
        return self.data.shape[1]


def _normalize(rows: jax.Array, valid: jax.Array) -> RoomySet:
    """Sort, dedup, compact — establish the invariant (ONE lexsort).

    The kept rows are already in sorted order, so the compaction is a
    stable boolean argsort (compact_valid_first), not a second lexsort —
    the sortedness invariant at work.
    """
    rows = jnp.where(valid[:, None], rows, T.sentinel_rows(*rows.shape))
    perm = T.lexsort_rows(rows)
    rows_s = rows[perm]
    keep = T.first_of_run(rows_s) & T.rows_valid(rows_s)
    data, count = T.compact_valid_first(rows_s, keep)
    return RoomySet(data, count)


def make(capacity: int, width: int) -> RoomySet:
    return RoomySet(T.sentinel_rows(capacity, width), jnp.zeros((), jnp.int32))


def from_rows(rows: jax.Array, capacity: int | None = None) -> RoomySet:
    n, w = rows.shape
    capacity = capacity or n
    pad = capacity - n
    rows = jnp.concatenate(
        [rows.astype(jnp.uint32), T.sentinel_rows(pad, w)], axis=0) \
        if pad else rows.astype(jnp.uint32)
    return _normalize(rows, jnp.arange(capacity) < n)


def from_list(rl: RL.RoomyList) -> RoomySet:
    return _normalize(rl.data, RL.valid_mask(rl))


def _merge(a: RoomySet, b: RoomySet, keep_rule: str) -> RoomySet:
    """One sorted-merge pass implementing union/intersection/difference.

    keep_rule: 'any' (union) | 'both' (intersection) | 'a_only' (difference)
    """
    na, nb = a.capacity, b.capacity
    rows = jnp.concatenate([a.data, b.data], axis=0)
    from_a = jnp.concatenate([jnp.ones((na,), bool), jnp.zeros((nb,), bool)])
    perm = T.lexsort_rows(rows)
    rows_s, from_a_s = rows[perm], from_a[perm]
    valid_s = T.rows_valid(rows_s)
    rid = T.run_ids(rows_s)
    nseg = na + nb
    in_a = jax.ops.segment_max((from_a_s & valid_s).astype(jnp.int32), rid,
                               num_segments=nseg)
    in_b = jax.ops.segment_max((~from_a_s & valid_s).astype(jnp.int32), rid,
                               num_segments=nseg)
    first = T.first_of_run(rows_s) & valid_s
    if keep_rule == "any":
        keep = first
    elif keep_rule == "both":
        keep = first & (in_a[rid] == 1) & (in_b[rid] == 1)
    elif keep_rule == "a_only":
        keep = first & (in_a[rid] == 1) & (in_b[rid] == 0)
    else:
        raise ValueError(keep_rule)
    # Kept rows are sorted already: compact with a boolean argsort instead
    # of a second lexsort (sort-once — every set op is ONE lexsort pass).
    data, count = T.compact_valid_first(rows_s, keep)
    return RoomySet(data[:max(na, nb) if keep_rule != "any" else nseg], count)


def union(a: RoomySet, b: RoomySet) -> RoomySet:
    """Native |: one pass (capacity grows to na+nb)."""
    return _merge(a, b, "any")


def intersection(a: RoomySet, b: RoomySet) -> RoomySet:
    """Native &: ONE pass — the primitive the paper planned."""
    return _merge(a, b, "both")


def difference(a: RoomySet, b: RoomySet) -> RoomySet:
    """Native −: one pass."""
    return _merge(a, b, "a_only")


def member_mask(s: RoomySet, queries: jax.Array) -> jax.Array:
    return RL.member_mask(RL.RoomyList(s.data, s.count), queries)


def size(s: RoomySet) -> jax.Array:
    return s.count


def to_numpy(s: RoomySet):
    import numpy as np
    data = np.asarray(jax.device_get(s.data))
    return data[: int(jax.device_get(s.count))]
