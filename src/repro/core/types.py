"""Element codecs and low-level helpers shared by the Roomy data structures.

Roomy elements are fixed-width records. We represent every element as a row
of ``width`` uint32 words (JAX runs with x64 disabled, so uint32 is the
natural machine word).  The all-ones row is reserved as the *sentinel*
("empty slot") — the same reservation Roomy's disk format makes for chunk
padding.  Rows compare lexicographically word-0-first, so sentinel rows sort
last, which every compaction routine below relies on.
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from . import obs

UINT32_MAX = jnp.uint32(0xFFFFFFFF)

# Trace-time sort accounting for the sort-once engine. Because the heavy
# paths run under jit, the counters measure how many multi-key lexsort OPS
# (incremented when lexsort_rows is traced) and append-scatter OPS
# (append_block) a traced computation contains, not per-step executions —
# which is exactly the pass-count the paper's cost model cares about.
# Tests call the un-jitted functions and assert deltas; the Tier J BFS
# level budget is 1 lexsort + 1 scatter (constructs._bfs_level).
SORT_STATS = obs.counters("tierj", {"lexsorts": 0, "scatters": 0})


def reset_sort_stats() -> None:
    for k in SORT_STATS:
        SORT_STATS[k] = 0


def sentinel_rows(n: int, width: int) -> jax.Array:
    """(n, width) block of sentinel (all-ones) rows."""
    return jnp.full((n, width), UINT32_MAX, dtype=jnp.uint32)


def is_sentinel(rows: jax.Array) -> jax.Array:
    """(n,) bool — True where the row is the reserved empty marker."""
    return jnp.all(rows == UINT32_MAX, axis=-1)


def rows_valid(rows: jax.Array) -> jax.Array:
    return ~is_sentinel(rows)


def lexsort_rows(rows: jax.Array) -> jax.Array:
    """Permutation sorting rows lexicographically (word 0 most significant).

    ``jnp.lexsort`` treats the *last* key as primary, so feed words in
    reverse order.  Stable, so equal rows keep their relative order.
    """
    SORT_STATS["lexsorts"] += 1
    w = rows.shape[-1]
    return jnp.lexsort(tuple(rows[:, j] for j in range(w - 1, -1, -1)))


def rows_equal(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.all(a == b, axis=-1)


def run_ids(sorted_rows: jax.Array) -> jax.Array:
    """Segment ids of equal-runs in lexicographically sorted rows.

    run_ids[i] == run_ids[j] iff rows i and j are equal. ids are dense,
    starting at 0.
    """
    neq = jnp.any(sorted_rows[1:] != sorted_rows[:-1], axis=-1)
    new_run = jnp.concatenate([jnp.ones((1,), dtype=bool), neq])
    return jnp.cumsum(new_run.astype(jnp.int32)) - 1


def first_of_run(sorted_rows: jax.Array) -> jax.Array:
    """(n,) bool — True at the first element of each equal-run."""
    neq = jnp.any(sorted_rows[1:] != sorted_rows[:-1], axis=-1)
    return jnp.concatenate([jnp.ones((1,), dtype=bool), neq])


def hash_rows(rows: jax.Array, seed: int = 0x9E3779B9) -> jax.Array:
    """Deterministic 32-bit mix hash of each row (for bucket assignment).

    FNV-ish multiply/xor fold over the words; good enough dispersion for
    bucketing (we never rely on it for adversarial inputs).
    """
    h = jnp.full(rows.shape[:-1], jnp.uint32(seed), dtype=jnp.uint32)
    for j in range(rows.shape[-1]):
        w = rows[..., j]
        h = (h ^ w) * jnp.uint32(0x01000193)
        h = h ^ (h >> 15)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    return h


def compact_valid_first(rows: jax.Array, valid: jax.Array):
    """Stable-partition rows so valid ones come first; invalid→sentinel.

    Returns (rows, count). Order of the valid rows is preserved — in
    particular, compacting already-lexsorted rows keeps them sorted, so this
    single-key boolean argsort replaces a second full lexsort everywhere the
    sort-once engine holds the sortedness invariant (rset/rlist/constructs).
    """
    perm = jnp.argsort(~valid, stable=True)
    rows = rows[perm]
    valid = valid[perm]
    rows = jnp.where(valid[:, None], rows, sentinel_rows(rows.shape[0], rows.shape[1]))
    return rows, jnp.sum(valid.astype(jnp.int32))


def segmented_reduce_last(
    vals: jax.Array,
    starts: jax.Array,
    combine: Callable,
):
    """Segmented inclusive scan; position i holds the combine of its segment
    prefix. The *last* position of each segment therefore holds the segment
    total.

    vals: (n, ...) payloads in segment order.
    starts: (n,) bool, True at segment starts.
    combine(a, b): associative payload combiner.
    """

    def op(left, right):
        fl, vl = left
        fr, vr = right
        v = jnp.where(
            fr if fr.ndim == vl.ndim else fr.reshape(fr.shape + (1,) * (vl.ndim - fr.ndim)),
            vr,
            combine(vl, vr),
        )
        return (fl | fr, v)

    flags = starts
    _, out = jax.lax.associative_scan(op, (flags, vals))
    return out


def tree_reduce(vals: jax.Array, merge: Callable, identity) -> jax.Array:
    """Log-depth reduction of vals (leading axis) with a user monoid.

    Pads to a power of two with ``identity``; merge must satisfy
    merge(identity, x) == x.
    """
    n = vals.shape[0]
    pow2 = 1
    while pow2 < n:
        pow2 *= 2
    ident_row = jnp.broadcast_to(jnp.asarray(identity, dtype=vals.dtype), vals.shape[1:])
    pad = jnp.broadcast_to(ident_row, (pow2 - n,) + vals.shape[1:])
    x = jnp.concatenate([vals, pad], axis=0) if pow2 != n else vals
    while x.shape[0] > 1:
        half = x.shape[0] // 2
        x = merge(x[:half], x[half:])
    return x[0]


def append_block(buf: jax.Array, count: jax.Array, block: jax.Array, valid: jax.Array):
    """Append the valid rows of ``block`` to ``buf`` starting at ``count``.

    buf: (cap, ...) with sentinel/garbage beyond count.
    block: (m, ...); valid: (m,) bool.
    Returns (buf, new_count, overflow). Valid rows are scattered to
    positions [count, count+nvalid); writes past capacity are dropped and
    ``overflow`` is set so callers can re-run with a larger capacity (the
    Python-level "growth" path; see DESIGN.md §2 static-shape note).
    """
    SORT_STATS["scatters"] += 1
    cap = buf.shape[0]
    nvalid = jnp.sum(valid.astype(jnp.int32))
    # Destination of each valid row; invalid rows target ``cap`` → dropped.
    dest = count + jnp.cumsum(valid.astype(jnp.int32)) - 1
    dest = jnp.where(valid, dest, cap)
    new_buf = buf.at[dest].set(block.astype(buf.dtype), mode="drop")
    new_count = jnp.minimum(count + nvalid, cap)
    overflow = count + nvalid > cap
    return new_buf, new_count, overflow


def pad_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p
