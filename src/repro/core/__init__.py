"""repro.core — the Roomy programming model in JAX (Tier J).

See DESIGN.md. Submodules:

  types       element codecs, sentinels, sort/segment helpers
  obs         metrics registry + span tracer (stdlib-only; docs/observability.md)
  rlist       RoomyList        (unordered multiset)
  rset        RoomySet         (native sorted-unique set — paper's §3 roadmap)
  array       RoomyArray       (delayed access/update + sync)
  bitarray    RoomyBitArray    (packed 2-bit elements, delayed marks —
                                the implicit-BFS representation)
  hashtable   RoomyHashTable   (delayed insert/remove/update + sync)
  ranking     Myrvold–Ruskey permutation rank/unrank (state ↔ index)
  delayed     BucketExchange — delayed-op engine over a mesh axis
  constructs  map/reduce/set-ops/chain/prefix/pair/BFS (paper §3)
  sharding    owner maps + mesh placement helpers
  paged       Roomy paged-KV store for long-context decode
  disk        Tier D — the paper-faithful out-of-core implementation

``repro.core.disk`` is itself a documented facade: structures, search
engines, the ClusterConfig/CheckpointConfig/RecoveryConfig API and the
pluggable bucket Transport all surface there (see its ``__all__``);
worker-command internals (``_w_*``) and owner-map helpers do not.

Submodules load lazily (PEP 562): the Tier J modules pull in jax, and the
multiprocess shard workers of ``disk/cluster.py`` import this package only
to reach the pure-numpy disk tier — an eager jax import would tax every
worker spawn (and every ``spawn``-pickled function they unpickle) for
modules the worker never touches.
"""
import importlib

__all__ = [
    "array", "bitarray", "constructs", "delayed", "disk", "hashtable",
    "obs", "paged", "ranking", "rlist", "rset", "sharding", "types",
]


def __getattr__(name):
    if name in __all__:
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
