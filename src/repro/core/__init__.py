"""repro.core — the Roomy programming model in JAX (Tier J).

See DESIGN.md. Submodules:

  types       element codecs, sentinels, sort/segment helpers
  rlist       RoomyList        (unordered multiset)
  rset        RoomySet         (native sorted-unique set — paper's §3 roadmap)
  array       RoomyArray       (delayed access/update + sync)
  bitarray    RoomyBitArray    (packed 2-bit elements, delayed marks —
                                the implicit-BFS representation)
  hashtable   RoomyHashTable   (delayed insert/remove/update + sync)
  ranking     Myrvold–Ruskey permutation rank/unrank (state ↔ index)
  delayed     BucketExchange — delayed-op engine over a mesh axis
  constructs  map/reduce/set-ops/chain/prefix/pair/BFS (paper §3)
  sharding    owner maps + mesh placement helpers
  paged       Roomy paged-KV store for long-context decode
  disk        Tier D — the paper-faithful out-of-core implementation
"""
from . import (array, bitarray, constructs, delayed, hashtable, paged,
               ranking, rlist, rset, sharding, types)

__all__ = [
    "array", "bitarray", "constructs", "delayed", "hashtable", "paged",
    "ranking", "rlist", "rset", "sharding", "types",
]
