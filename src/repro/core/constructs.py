"""The paper's §3 programming constructs, built on the Roomy primitives.

map / reduce are primitives (rlist.py, array.py); here we provide:

  set operations    union / difference / intersection (paper's recipes,
                    including the 3-temporary intersection)
  chain reduction   a[i] = f(a[i], a[i-1]) via delayed updates — reads all
                    old values before any write (deterministic, §3)
  parallel prefix   log-round chain reductions with stride doubling
  pair reduction    blocked streaming over all N² pairs
  BFS               level-synchronous frontier expansion with the paper's
                    exact dedup loop, plus Python-level capacity growth
                    (the static-shape adaptation of "dynamically sized")
  implicit BFS      the paper's second engine: rank-indexed 2-bit array
                    with delayed marks — no frontier lists, no sorting
                    (bitarray.py + ranking.py; the pancake construction)

Everything below is jit-compatible except the BFS driver loop, which is a
Python loop over levels (level count is data-dependent) — the same
structure as the paper's ``while (RoomyList_size(cur))``.
"""
from __future__ import annotations

import functools
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from . import array as RA
from . import bitarray as BA
from . import obs
from . import rlist as RL
from . import types as T


# ---------------------------------------------------------------- set ops

def set_union(a: RL.RoomyList, b: RL.RoomyList) -> RL.RoomyList:
    """A = A ∪ B   (paper: addAll + removeDupes)."""
    out, _ = RL.add_all(a, b)
    return RL.remove_dupes(out)


def set_difference(a: RL.RoomyList, b: RL.RoomyList) -> RL.RoomyList:
    """A = A − B   (paper: removeAll; assumes a, b are sets)."""
    return RL.remove_all(a, b)


def set_intersection(a: RL.RoomyList, b: RL.RoomyList,
                     capacity: int | None = None) -> RL.RoomyList:
    """C = A ∩ B via the paper's recipe: (A+B) − (A−B) − (B−A)."""
    cap = capacity or (a.capacity + b.capacity)
    a_and_b = RL.make(cap, a.width)
    a_and_b, _ = RL.add_all(a_and_b, a)
    a_and_b, _ = RL.add_all(a_and_b, b)
    a_and_b = RL.remove_dupes(a_and_b)
    a_minus_b = RL.remove_all(a, b)
    b_minus_a = RL.remove_all(b, a)
    c = RL.make(cap, a.width)
    c, _ = RL.add_all(c, a_and_b)
    c = RL.remove_all(c, a_minus_b)
    c = RL.remove_all(c, b_minus_a)
    return c


# ------------------------------------------------------- chain reduction

def chain_reduce(ra: RA.RoomyArray, combine: Callable) -> RA.RoomyArray:
    """a[i] = combine(a[i], a[i-1]) for i in 1..N-1, old values throughout.

    Paper §3: map over the array issues update(i+1, val_i); sync applies
    them against the old state (scatter-gather).
    """
    n = ra.size
    idx = jnp.arange(n, dtype=jnp.int32) + 1          # i-1 → i
    valid = idx < n
    ra, _ = RA.update(ra, idx, ra.data, valid)
    return RA.sync(ra, combine=lambda p, q: p, apply=lambda old, pay: combine(old, pay))


def parallel_prefix(ra: RA.RoomyArray, combine: Callable) -> RA.RoomyArray:
    """Inclusive scan via log₂N chain reductions with stride doubling."""
    n = ra.size
    k = 1
    while k < n:
        idx = jnp.arange(n, dtype=jnp.int32) + k
        valid = idx < n
        ra, _ = RA.update(ra, idx, ra.data, valid)
        ra = RA.sync(ra, combine=lambda p, q: p,
                     apply=lambda old, pay: combine(old, pay))
        k *= 2
    return ra


# -------------------------------------------------------- pair reduction

def pair_reduce(ra: RA.RoomyArray, pair_fn: Callable, merge_fn: Callable,
                identity, block: int = 256):
    """Fold pair_fn(a[i], a[j]) over all N² ordered pairs.

    Streaming block×block evaluation — the batched form of the paper's
    map-issuing-accesses pattern (each outer block's delayed accesses to the
    whole array are served one inner block at a time).
    """
    n = ra.size
    nblocks = -(-n // block)
    pad = nblocks * block - n
    data = jnp.concatenate([ra.data, jnp.zeros((pad,) + ra.data.shape[1:],
                                               ra.data.dtype)], axis=0)
    valid = jnp.arange(nblocks * block) < n
    data_b = data.reshape((nblocks, block) + ra.data.shape[1:])
    valid_b = valid.reshape(nblocks, block)

    def outer(acc, ob):
        o_dat, o_val = ob

        def inner(acc2, ib):
            i_dat, i_val = ib
            vals = jax.vmap(lambda x: jax.vmap(lambda y: pair_fn(x, y))(i_dat))(o_dat)
            mask = (o_val[:, None] & i_val[None, :])
            mask = mask.reshape(mask.shape + (1,) * (vals.ndim - 2))
            vals = jnp.where(mask, vals, jnp.asarray(identity, vals.dtype))
            flat = vals.reshape((-1,) + vals.shape[2:])
            return merge_fn(acc2, T.tree_reduce(flat, merge_fn, identity)), None

        acc, _ = jax.lax.scan(inner, acc, (data_b, valid_b))
        return acc, None

    init = jnp.asarray(identity)
    acc, _ = jax.lax.scan(outer, init, (data_b, valid_b))
    return acc


# ------------------------------------------------------------------- BFS

class BFSResult:
    def __init__(self):
        self.level_sizes: List[int] = []
        self.all: RL.RoomyList | None = None
        self.levels_run: int = 0


def dedupe_subtract_fold(nxt_rows: jax.Array, nxt_valid: jax.Array,
                         all_lst: RL.RoomyList, next_cap: int):
    """Fused removeDupes ∘ removeAll ∘ addAll — ONE lexsort, ONE scatter
    (sort-once, Tier J).

    One lexsort over the tagged concatenation ``[nxt_raw; all]`` decides all
    three at once: within an equal-run, any member tagged "old" kills the run
    (visited-set subtraction), otherwise the first member survives
    (intra-level dedup); survivors — already in sorted order — are compacted
    with a boolean argsort and folded into ``all`` with one scatter.

    ``nxt_rows`` may be the RAW expansion (invalid slots included): invalid
    rows are masked to sentinel and sort last, so the same lexsort also does
    the staging compaction that used to cost a separate ``RL.add`` scatter
    before the fold (_bfs_level) — the whole level is 1 lexsort + 1 scatter.

    The reference composition (staged add → remove_dupes → remove_all →
    add_all) costs 2 lexsorts + 2 scatters over the same data; property
    tests assert element-wise equivalence (tests/test_sort_once.py).

    Returns (nxt, all2, overflow) like the composition it replaces.
    """
    m, w = nxt_rows.shape
    na = all_lst.capacity
    all_valid = RL.valid_mask(all_lst)
    # Mask BOTH sides to sentinel outside their valid ranges: append_block's
    # contract allows garbage (not just sentinel) beyond count, and unmasked
    # garbage rows would be resurrected as phantom frontier states.
    rows = jnp.concatenate(
        [jnp.where(nxt_valid[:, None], nxt_rows.astype(jnp.uint32),
                   T.sentinel_rows(m, w)),
         jnp.where(all_valid[:, None], all_lst.data,
                   T.sentinel_rows(na, w))], axis=0)
    is_old = jnp.concatenate([jnp.zeros((m,), bool), all_valid])
    perm = T.lexsort_rows(rows)
    rows_s = rows[perm]
    old_s = is_old[perm]
    rid = T.run_ids(rows_s)
    run_has_old = jax.ops.segment_max(old_s.astype(jnp.int32), rid,
                                      num_segments=m + na)
    keep = (T.first_of_run(rows_s) & T.rows_valid(rows_s)
            & (run_has_old[rid] == 0))
    rows_c, count = T.compact_valid_first(rows_s, keep)   # stays sorted
    if next_cap <= m + na:
        nxt_data = rows_c[:next_cap]
    else:
        nxt_data = jnp.concatenate(
            [rows_c, T.sentinel_rows(next_cap - (m + na), w)], axis=0)
    nxt = RL.RoomyList(nxt_data, jnp.minimum(count, next_cap))
    all2, ov2 = RL.add(all_lst, nxt_data, jnp.arange(next_cap) < count)
    return nxt, all2, (count > next_cap) | ov2


def _bfs_level(cur: RL.RoomyList, all_lst: RL.RoomyList, gen_next: Callable,
               fanout: int, next_cap: int):
    """One level: expand cur, then one fused dedupe/subtract/fold pass.

    gen_next(row) -> (rows (fanout, w), valid (fanout,)). Jitted per shape.

    The raw expansion feeds dedupe_subtract_fold directly: its lexsort
    masks invalid slots to sentinel (they sort last and drop), so the
    staging scatter that used to compact the expansion into a next_cap
    buffer first is folded into the sort the level already pays — one
    lexsort + one scatter per level, asserted by the SORT_STATS trace
    tests.  (The lexsort covers capacity·fanout + all_cap rows instead of
    next_cap + all_cap; sorting the dead slots is cheaper than the extra
    full-width scatter pass they used to cost.)
    """
    nbr_rows, nbr_valid = jax.vmap(gen_next)(cur.data)
    nbr_valid = nbr_valid & RL.valid_mask(cur)[:, None]
    return dedupe_subtract_fold(nbr_rows.reshape(-1, cur.width),
                                nbr_valid.reshape(-1), all_lst, next_cap)


def _bfs_level_reference(cur: RL.RoomyList, all_lst: RL.RoomyList,
                         gen_next: Callable, fanout: int, next_cap: int):
    """Unfused reference level (2 lexsorts + 2 boolean compactions) — kept
    for equivalence tests and the sorts-per-level benchmark; semantics
    identical to _bfs_level."""
    nbr_rows, nbr_valid = jax.vmap(gen_next)(cur.data)
    nbr_valid = nbr_valid & RL.valid_mask(cur)[:, None]
    nxt = RL.make(next_cap, cur.width)
    nxt, overflow = RL.add(nxt, nbr_rows.reshape(-1, cur.width),
                           nbr_valid.reshape(-1))
    nxt = RL.remove_dupes(nxt)                 # dedup within level
    nxt = RL.remove_all(nxt, all_lst)          # dedup against previous levels
    all2, ov2 = RL.add_all(all_lst, nxt)       # record new elements
    return nxt, all2, overflow | ov2


def _implicit_level(data, *, n_states: int, neighbor_fn: Callable,
                    impl: str, fused: bool = True):
    """One implicit-BFS level over the packed 2-bit array: mark every
    neighbor of a CUR state NEXT-if-UNSEEN (the delayed-update batch — a
    masked scatter, duplicates and visited states absorb silently), then
    rotate CUR→DONE / NEXT→CUR and count the new frontier.  With
    ``fused=True`` the mark scatter and the LUT rotate+count run as ONE
    kernel over the packed words (kernels/bitpack.py
    bitpack_mark_rotate_count) — one HBM read-write traversal of the
    array per level instead of two, the Tier J twin of the disk pass
    planner's fused level.  No sort of any kind either way."""
    cap = data.shape[0] * BA.FIELDS_PER_WORD
    vals = BA.unpack_values(data)[:n_states]
    cur = vals == BA.CUR
    nbr = jax.vmap(neighbor_fn)(jnp.arange(n_states, dtype=jnp.int32))
    tgt = jnp.where(cur[:, None], nbr.astype(jnp.int32), cap).reshape(-1)
    if fused:
        return BA.mark_rotate_count(data, tgt, n_states, impl=impl)
    data = BA.mark_packed(data, tgt, impl=impl)
    return BA.rotate_count(data, n_states, impl=impl)


def implicit_bfs(
    n_states: int,
    start_idx,
    neighbor_fn: Callable,
    max_levels: int = 1_000,
    impl: str = "auto",
    fused: bool = True,
):
    """The paper's *second* BFS engine on Tier J: implicit search over a
    2-bit RoomyBitArray indexed by state rank (ranking.py), the device twin
    of ``disk.implicit_bfs``.

    neighbor_fn(i int32) -> (fanout,) int32 neighbor indices; it is vmapped
    over the whole index space each level — the static-shape adaptation of
    "expand the CUR states" (non-CUR rows are masked out of the mark), so a
    level costs O(n_states) regardless of frontier size but needs no
    frontier list, no sorting and no duplicate elimination.

    Returns (level_sizes, bits: RoomyBitArray) — all reached states end
    DONE in ``bits``.  ``fused=False`` keeps the two-kernel reference
    composition (mark scatter, then rotate+count) for equivalence tests.
    """
    ba = BA.make(n_states)
    start = jnp.asarray(start_idx, jnp.int32).reshape(-1)
    data = BA.mark_packed(ba.data, start, mark=BA.CUR, only_if=BA.UNSEEN,
                          impl=impl)
    level_sizes: List[int] = [int(jnp.sum(
        (BA.unpack_values(data)[:n_states] == BA.CUR).astype(jnp.int32)))]
    step = jax.jit(functools.partial(_implicit_level, n_states=n_states,
                                     neighbor_fn=neighbor_fn, impl=impl,
                                     fused=fused))
    for _ in range(max_levels):
        with obs.span("bfs.level", level=len(level_sizes), tier="j",
                      engine="implicit"):
            data, cnt = step(data)
            c = int(cnt)
        if c == 0:
            break
        level_sizes.append(c)
    return level_sizes, ba._replace(data=data)


def breadth_first_search(
    start_rows,
    gen_next: Callable,
    fanout: int,
    width: int,
    all_capacity: int,
    level_capacity: int,
    max_levels: int = 1_000,
    fused: bool = True,
) -> BFSResult:
    """Paper §3 BFS over an implicit graph, with capacity growth on overflow.

    The per-level step is jitted; capacities double (Python level) whenever
    a level overflows — the static-shape equivalent of Roomy's dynamically
    sized lists. fused=True (default) runs the one-lexsort
    dedupe_subtract_fold level; fused=False the 3-lexsort reference
    composition (for equivalence tests and benchmarks).
    """
    start_rows = jnp.asarray(start_rows, jnp.uint32).reshape(-1, width)
    all_lst = RL.make(all_capacity, width)
    all_lst, _ = RL.add(all_lst, start_rows)
    cur = RL.make(level_capacity, width)
    cur, _ = RL.add(cur, start_rows)

    level_fn = _bfs_level if fused else _bfs_level_reference
    step = jax.jit(functools.partial(level_fn, gen_next=gen_next,
                                     fanout=fanout),
                   static_argnames=("next_cap",))

    res = BFSResult()
    res.level_sizes.append(int(cur.count))
    for _ in range(max_levels):
        if int(cur.count) == 0:
            res.level_sizes.pop()              # last level was empty
            break
        with obs.span("bfs.level", level=res.levels_run + 1, tier="j",
                      engine="sorted", frontier=int(cur.count)):
            next_cap = max(level_capacity, int(cur.count) * fanout)
            nxt, all2, overflow = step(cur, all_lst, next_cap=next_cap)
            if bool(overflow):
                # Grow the 'all' list and redo this level (pure functional
                # state means the failed attempt had no side effects).
                all_capacity *= 2
                grown = RL.make(all_capacity, width)
                grown, _ = RL.add_all(grown, all_lst)
                all_lst = grown
                nxt, all2, overflow = step(cur, all_lst, next_cap=next_cap)
                if bool(overflow):
                    raise MemoryError("BFS capacity growth failed twice")
            cur, all_lst = nxt, all2
            res.levels_run += 1
            res.level_sizes.append(int(cur.count))
        if int(cur.count) == 0:
            res.level_sizes.pop()
            break
    res.all = all_lst
    return res
