"""Process-local metrics registry and structured span tracer.

Roomy's performance argument is that disk-based computation is priced
in a handful of countable quantities — passes over data, bytes
streamed, exchange volume (paper §2–3).  This module is the one home
for those counts plus wall-time:

* a **registry** of counters / gauges / histograms that ABSORBS the
  legacy module dicts (``extsort.STATS``, ``bitarray.STATS``,
  ``types.SORT_STATS`` stay the very same mutable dict objects —
  every existing ``STATS[k] += n`` keeps working unchanged and is
  automatically visible to snapshots/scopes/spans), and
* a **span tracer**: nested, wall-clock-timed phases with stable ids
  (``bfs.level``, ``pass.rw``, ``sort.run_build``, ``merge``,
  ``bucket.seal``/``bucket.apply``, ``ckpt.snapshot``/``ckpt.restore``,
  ``recovery.rollback``) that record the counter deltas which occurred
  inside them.  Finished spans go to a sink (disk/trace.py's JSONL
  writer) or, in shard workers, to a buffer drained over the result
  queue at each level barrier.

Zero-cost contract (same standard as disk/faults.py): ``ACTIVE`` is
False by default, every tracing hook starts with that single attribute
test (``span()`` returns a shared no-op immediately), counters behave
exactly as before, and the committed bench baseline stays
byte-identical with tracing off — CI enforces it.

stdlib-only on purpose: spawn-mode shard workers import this module
and must never import jax (see repro/core/__init__'s lazy-import
contract).
"""
from __future__ import annotations

import contextlib
import math
import time
from typing import Callable, Dict, List, Optional

ACTIVE = False

#: Presence of this env var in a freshly spawned (or recovery-respawned)
#: shard worker turns on buffered tracing there — disk/trace.py sets it.
ENV_VAR = "ROOMY_TRACE"

# ----------------------------------------------------------------- registry

_COUNTERS: Dict[str, Dict[str, int]] = {}
_GAUGES: Dict[str, float] = {}
_HISTS: Dict[str, "Histogram"] = {}


def counters(namespace: str, defaults: Dict[str, int]) -> Dict[str, int]:
    """Register (or re-attach to) a counter namespace.

    Returns the LIVE dict: callers keep mutating it with plain
    ``d[k] += n`` and the registry holds the same object, so snapshots
    and scopes see every update with zero per-increment overhead.  This
    is how the legacy ``STATS`` dicts are absorbed backward-compatibly.
    """
    d = _COUNTERS.setdefault(namespace, {})
    for k, v in defaults.items():
        d.setdefault(k, v)
    return d


def gauge(name: str, value) -> None:
    """Record a point-in-time value (last write wins).  ACTIVE-gated so
    an untraced run never touches the registry."""
    if ACTIVE:
        _GAUGES[name] = float(value)


class Histogram:
    """Exact-count histogram with power-of-two buckets.

    Bucket ``b`` counts observations ``v`` with ``2**(b-1) < v <= 2**b``
    (bucket 0 counts ``v <= 1``).  Counts are exact, not sampled;
    merging two histograms is elementwise addition, hence associative.
    """

    __slots__ = ("buckets", "count", "total")

    def __init__(self):
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0

    def observe(self, v) -> None:
        v = float(v)
        if v <= 1.0:
            b = 0
        else:
            m, e = math.frexp(v)            # v = m * 2**e, 0.5 <= m < 1
            b = e - 1 if m == 0.5 else e    # ceil(log2(v))
        self.buckets[b] = self.buckets.get(b, 0) + 1
        self.count += 1
        self.total += v

    def percentile(self, q: float) -> float:
        """Estimate the ``q``-th percentile (0 <= q <= 100) from buckets.

        The target rank is walked through the sorted bucket keys; inside
        the covering bucket ``(2**(b-1), 2**b]`` the value is linearly
        interpolated by the rank's fractional position among that
        bucket's observations, so the estimate is exact at bucket edges
        and never off by more than one power-of-two bucket's width — the
        resolution p50/p99 latency columns need.  Empty histogram → 0.0.
        """
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile q={q} outside [0, 100]")
        if self.count == 0:
            return 0.0
        target = q / 100.0 * self.count
        seen = 0
        for b in sorted(self.buckets):
            n = self.buckets[b]
            if seen + n >= target:
                lo = 0.0 if b == 0 else float(2 ** (b - 1))
                hi = float(2 ** b)
                frac = (target - seen) / n
                return lo + frac * (hi - lo)
            seen += n
        return float(2 ** max(self.buckets))


def histogram(name: str) -> Histogram:
    h = _HISTS.get(name)
    if h is None:
        h = _HISTS[name] = Histogram()
    return h


def observe(name: str, value) -> None:
    """Book one histogram observation (latency, bytes...).  ACTIVE-gated."""
    if ACTIVE:
        histogram(name).observe(value)


def snapshot() -> dict:
    """Picklable point-in-time copy of the whole registry — what spawn
    workers ship to the coordinator at each level barrier."""
    return {
        "counters": {ns: dict(d) for ns, d in _COUNTERS.items()},
        "gauges": dict(_GAUGES),
        "hists": {n: {"buckets": dict(h.buckets), "count": h.count,
                      "total": h.total} for n, h in _HISTS.items()},
    }


def merge(a: dict, b: dict) -> dict:
    """Combine two snapshots: counters and histograms add, ``b``'s
    gauges win.  Associative with the empty snapshot as identity — the
    property the coordinator relies on when folding per-shard snapshots
    in whatever order the result queue delivers them."""
    out = {"counters": {}, "gauges": {}, "hists": {}}
    for src in (a, b):
        for ns, d in src.get("counters", {}).items():
            od = out["counters"].setdefault(ns, {})
            for k, v in d.items():
                od[k] = od.get(k, 0) + v
        for n, h in src.get("hists", {}).items():
            oh = out["hists"].setdefault(
                n, {"buckets": {}, "count": 0, "total": 0.0})
            for bkt, c in h["buckets"].items():
                oh["buckets"][bkt] = oh["buckets"].get(bkt, 0) + c
            oh["count"] += h["count"]
            oh["total"] += h["total"]
    out["gauges"].update(a.get("gauges", {}))
    out["gauges"].update(b.get("gauges", {}))
    return out


def counter_deltas(after: dict, before: dict) -> Dict[str, int]:
    """Flat non-zero counter deltas between two snapshots, keyed
    ``namespace.counter`` — the span metric format."""
    out: Dict[str, int] = {}
    for ns, d in after.get("counters", {}).items():
        base = before.get("counters", {}).get(ns, {})
        for k, v in d.items():
            dv = v - base.get(k, 0)
            if dv:
                out[ns + "." + k] = dv
    return out


# ------------------------------------------------------------------- scopes

class Scope:
    """Counter snapshot/delta window — per-block deltas WITHOUT resetting
    the module globals (a mid-run ``reset_stats()`` corrupts every other
    observer, which is exactly the bench best-of bug this fixes)."""

    __slots__ = ("_begin", "_end")

    def __init__(self):
        self._begin = {ns: dict(d) for ns, d in _COUNTERS.items()}
        self._end = None

    def delta(self) -> Dict[str, Dict[str, int]]:
        """Per-namespace counter deltas since the scope opened (live
        while the scope is open, frozen at its close)."""
        cur = self._end or {ns: dict(d) for ns, d in _COUNTERS.items()}
        out: Dict[str, Dict[str, int]] = {}
        for ns, d in cur.items():
            base = self._begin.get(ns, {})
            out[ns] = {k: v - base.get(k, 0) for k, v in d.items()}
        return out


@contextlib.contextmanager
def scope():
    s = Scope()
    try:
        yield s
    finally:
        s._end = {ns: dict(d) for ns, d in _COUNTERS.items()}


# -------------------------------------------------------------------- spans

_SHARD: Optional[int] = None          # default shard tag for new spans
_STACK: List["Span"] = []             # open spans (runtime is 1 thread/proc)
_SPANS: List[dict] = []               # finished spans awaiting drain/sink
_SINK: Optional[Callable[[dict], None]] = None


class _NullSpan:
    """Shared no-op for the ACTIVE=False fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        pass


_NULL = _NullSpan()


class Span:
    __slots__ = ("sid", "attrs", "shard", "ts_us", "parent", "depth",
                 "_t0", "_base")

    def __init__(self, sid: str, attrs: dict):
        self.sid = sid
        self.shard = attrs.pop("shard", _SHARD)
        self.attrs = attrs

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        self.parent = _STACK[-1].sid if _STACK else None
        self.depth = len(_STACK)
        _STACK.append(self)
        self._base = {ns: dict(d) for ns, d in _COUNTERS.items()}
        self.ts_us = int(time.time() * 1e6)   # epoch µs: cross-process order
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur_us = int((time.perf_counter() - self._t0) * 1e6)
        # Generator-held spans (merge streams, bucket application) can
        # close out of LIFO order — remove by identity, top down.
        for i in range(len(_STACK) - 1, -1, -1):
            if _STACK[i] is self:
                del _STACK[i]
                break
        metrics: Dict[str, int] = {}
        for ns, d in _COUNTERS.items():
            base = self._base.get(ns, {})
            for k, v in d.items():
                dv = v - base.get(k, 0)
                if dv:
                    metrics[ns + "." + k] = dv
        rec = {"type": "span", "sid": self.sid, "ts_us": self.ts_us,
               "dur_us": dur_us, "shard": self.shard,
               "parent": self.parent, "depth": self.depth}
        if self.attrs:
            rec["attrs"] = self.attrs
        if metrics:
            rec["metrics"] = metrics
        if ACTIVE:
            histogram("span." + self.sid + ".us").observe(dur_us)
        _emit(rec)
        return False


def span(sid: str, **attrs):
    """Open a traced span (context manager).  The hook cost when tracing
    is off is this single attribute test.  ``shard=`` is split out as
    the span's shard tag (inline-mode worker fns pass it explicitly;
    spawn workers inherit it from ``enable(shard=...)``)."""
    if not ACTIVE:
        return _NULL
    return Span(sid, attrs)


def _emit(rec: dict) -> None:
    if _SINK is not None:
        _SINK(rec)
    else:
        _SPANS.append(rec)


def drain_spans() -> List[dict]:
    """Pop and return buffered finished spans (plain picklable dicts) —
    what a spawn worker returns over the result queue at a barrier."""
    out = _SPANS[:]
    del _SPANS[:]
    return out


def ingest(spans: List[dict], shard: Optional[int] = None) -> None:
    """Coordinator side: file spans collected from a worker, tagging
    untagged ones with that worker's shard id."""
    for rec in spans:
        if shard is not None and rec.get("shard") is None:
            rec["shard"] = shard
        _emit(rec)


def enable(shard: Optional[int] = None,
           sink: Optional[Callable[[dict], None]] = None) -> None:
    """Turn tracing on.  ``sink`` (the coordinator's JSONL writer)
    receives finished spans immediately; without one (shard workers)
    spans buffer for ``drain_spans()``."""
    global ACTIVE, _SHARD, _SINK
    _SHARD = shard
    _SINK = sink
    ACTIVE = True


def disable() -> None:
    """Turn tracing off and drop all tracing state.  Counters are NOT
    touched — they belong to their owning modules (``reset_stats()``)."""
    global ACTIVE, _SHARD, _SINK
    ACTIVE = False
    _SHARD = None
    _SINK = None
    del _STACK[:]
    del _SPANS[:]
    _GAUGES.clear()
    _HISTS.clear()
