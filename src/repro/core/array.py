"""RoomyArray — fixed-size indexed array with *delayed* access/update ops.

This is the paper's workhorse structure: random-access ``update(i, payload,
fn)`` / ``access(i, ctx, fn)`` operations are queued, and ``sync`` executes
the whole batch as one streaming pass:

    sort queue by index  →  segment-combine payloads per index
                         →  apply(old, aggregate) at each touched index.

That sort+segment+scatter pipeline is exactly Roomy's scatter-gather; on the
sharded path the sort is replaced by the bucket exchange in ``delayed.py``
and the apply phase is the ``bucket_scatter`` Pallas kernel.

Unlike RoomyList, elements here can be any dtype/shape (the LM framework
stores embedding rows and KV pages in RoomyArrays).

Determinism note (paper §3 "chain reduction"): sync applies updates against
the *old* array state only — queued updates never observe each other's
writes, so constructs like chain reduction are deterministic.  Multiple
updates hitting one index are merged with ``combine``, which therefore must
be associative+commutative (the paper's reduce-style contract).

``predicateCount`` is maintained *incrementally* during sync (the paper
stresses it needs no separate scan): sync adjusts the count by
Σ pred(new) − Σ pred(old) over touched slots.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import types as T


class RoomyArray(NamedTuple):
    data: jax.Array      # (n, *elt_shape)
    q_idx: jax.Array     # (qcap,) int32 — target index, ==n for empty slots
    q_pay: jax.Array     # (qcap, *pay_shape)
    q_n: jax.Array       # () int32
    pcount: jax.Array    # () int32 — live predicate count (0 if unused)

    @property
    def size(self) -> int:
        return self.data.shape[0]

    @property
    def queue_capacity(self) -> int:
        return self.q_idx.shape[0]


def make(
    data: jax.Array,
    queue_capacity: int,
    payload_shape: tuple = (),
    payload_dtype=jnp.uint32,
    pred: Optional[Callable] = None,
) -> RoomyArray:
    n = data.shape[0]
    q_idx = jnp.full((queue_capacity,), n, jnp.int32)
    q_pay = jnp.zeros((queue_capacity,) + payload_shape, payload_dtype)
    if pred is not None:
        pcount = jnp.sum(jax.vmap(pred)(data).astype(jnp.int32))
    else:
        pcount = jnp.zeros((), jnp.int32)
    return RoomyArray(data, q_idx, q_pay, jnp.zeros((), jnp.int32), pcount)


def update(ra: RoomyArray, idx: jax.Array, payload: jax.Array,
           valid: jax.Array | None = None):
    """Queue a batch of delayed updates. Returns (array, overflow)."""
    if valid is None:
        valid = jnp.ones(idx.shape, bool)
    qcap = ra.queue_capacity
    dest = ra.q_n + jnp.cumsum(valid.astype(jnp.int32)) - 1
    dest = jnp.where(valid, dest, qcap)
    q_idx = ra.q_idx.at[dest].set(idx.astype(jnp.int32), mode="drop")
    q_pay = ra.q_pay.at[dest].set(payload.astype(ra.q_pay.dtype), mode="drop")
    nvalid = jnp.sum(valid.astype(jnp.int32))
    overflow = ra.q_n + nvalid > qcap
    q_n = jnp.minimum(ra.q_n + nvalid, qcap)
    return ra._replace(q_idx=q_idx, q_pay=q_pay, q_n=q_n), overflow


def access(ra: RoomyArray, idx: jax.Array) -> jax.Array:
    """Batched random read (the resolved form of delayed access ops)."""
    return ra.data[idx]


def sync(
    ra: RoomyArray,
    combine: Callable,
    apply: Callable,
    pred: Optional[Callable] = None,
) -> RoomyArray:
    """Execute all queued updates in one streaming batch.

    combine(p1, p2): associative+commutative merge of two payloads aimed at
        the same index (both vectorized over a leading axis).
    apply(old_elt, agg_payload) -> new_elt: applied once per touched index.
    pred: if given, the live predicate count is maintained incrementally.
    """
    n = ra.size
    qcap = ra.queue_capacity
    in_q = jnp.arange(qcap) < ra.q_n
    idx = jnp.where(in_q, ra.q_idx, n)            # park empties at n
    order = jnp.argsort(idx, stable=True)
    idx_s = idx[order]
    pay_s = ra.q_pay[order]
    starts = jnp.concatenate(
        [jnp.ones((1,), bool), idx_s[1:] != idx_s[:-1]]
    )
    agg = T.segmented_reduce_last(pay_s, starts, combine)
    # Segment totals live at the *last* slot of each segment.
    last = jnp.concatenate([idx_s[1:] != idx_s[:-1], jnp.ones((1,), bool)])
    target = jnp.where(last & (idx_s < n), idx_s, n)
    old = ra.data[jnp.minimum(target, n - 1)]
    new = apply(old, agg)
    data = ra.data.at[target].set(new.astype(ra.data.dtype), mode="drop")
    pcount = ra.pcount
    if pred is not None:
        touched = target < n
        po = jax.vmap(pred)(old) & touched
        pn = jax.vmap(pred)(new) & touched
        pcount = pcount + jnp.sum(pn.astype(jnp.int32)) - jnp.sum(po.astype(jnp.int32))
    q_idx = jnp.full((qcap,), n, jnp.int32)
    q_pay = jnp.zeros_like(ra.q_pay)
    return RoomyArray(data, q_idx, q_pay, jnp.zeros((), jnp.int32), pcount)


def map_elements(ra: RoomyArray, fn: Callable) -> jax.Array:
    """Paper's map: fn(index, element) vectorized over the whole array."""
    return jax.vmap(fn)(jnp.arange(ra.size), ra.data)


def map_update(ra: RoomyArray, fn: Callable) -> RoomyArray:
    """In-place streaming transform: data[i] = fn(i, data[i])."""
    new = jax.vmap(fn)(jnp.arange(ra.size), ra.data)
    return ra._replace(data=new.astype(ra.data.dtype))


def reduce(ra: RoomyArray, elt_fn: Callable, merge_fn: Callable, identity):
    vals = jax.vmap(elt_fn)(jnp.arange(ra.size), ra.data)
    return T.tree_reduce(vals, merge_fn, identity)


def predicate_count(ra: RoomyArray) -> jax.Array:
    """The incrementally-maintained count (see module docstring)."""
    return ra.pcount
