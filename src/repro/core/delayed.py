"""BucketExchange — the Roomy delayed-op engine on a device mesh.

This module is the paper's central mechanism, adapted to TPU (DESIGN.md §2):
random-access operations are *delayed*, binned by the shard that owns their
target, exchanged in fixed-capacity buckets with ONE ``all_to_all`` per
direction, then applied as a streaming batch on the owner. Latency-bound
random access becomes two bandwidth-bound collectives — exactly Roomy's
disk-seek → streaming conversion, with ICI links playing the role of disk
spindles.

Layout convention: everything here operates on *per-shard local* arrays,
i.e. it is meant to be called INSIDE ``jax.shard_map``.  ``S`` is the size
of the exchange axis, ``C`` the per-(src,dst) bucket capacity (the same
fixed-size-bucket scheme Roomy uses for its disk files; overflowing items
are dropped and counted, like MoE token dropping — callers size C for their
tolerance, and the returned ``dropped`` count feeds tests/monitoring).

The three phases:

  bin_by_dest   local sort-by-owner + scatter into (S, C, ·) buckets
  exchange      jax.lax.all_to_all over the named axis
  unbin         route per-item results back to their issue order

``bucket_sync_update`` / ``bucket_sync_access`` compose them into the two
delayed-op flavours of the paper (update: fire-and-forget scatter; access:
full round trip).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Binned(NamedTuple):
    payload: jax.Array   # (S, C, *d) bucketed payloads
    valid: jax.Array     # (S, C) bool
    src_idx: jax.Array   # (S, C) int32 — originating local item index (or m)
    dropped: jax.Array   # () int32 — items that overflowed their bucket


def bin_by_dest(dest: jax.Array, payload: jax.Array, valid: jax.Array,
                nbuckets: int, capacity: int) -> Binned:
    """Bin m local items into per-destination buckets of fixed capacity.

    dest: (m,) int32 in [0, nbuckets); payload: (m, *d); valid: (m,).
    """
    m = dest.shape[0]
    d_eff = jnp.where(valid, dest, nbuckets).astype(jnp.int32)
    order = jnp.argsort(d_eff, stable=True)
    d_s = d_eff[order]
    pay_s = payload[order]
    pos = jnp.arange(m, dtype=jnp.int32)
    starts = jnp.concatenate([jnp.ones((1,), bool), d_s[1:] != d_s[:-1]])
    run_start = jax.lax.cummax(jnp.where(starts, pos, 0))
    rank = pos - run_start
    ok = (rank < capacity) & (d_s < nbuckets)
    flat = jnp.where(ok, d_s * capacity + rank, nbuckets * capacity)

    buf = jnp.zeros((nbuckets * capacity,) + payload.shape[1:], payload.dtype)
    buf = buf.at[flat].set(pay_s, mode="drop")
    vbuf = jnp.zeros((nbuckets * capacity,), bool).at[flat].set(ok, mode="drop")
    sbuf = jnp.full((nbuckets * capacity,), m, jnp.int32).at[flat].set(
        order.astype(jnp.int32), mode="drop")

    nvalid = jnp.sum((d_s < nbuckets).astype(jnp.int32))
    dropped = nvalid - jnp.sum(ok.astype(jnp.int32))
    return Binned(
        payload=buf.reshape((nbuckets, capacity) + payload.shape[1:]),
        valid=vbuf.reshape(nbuckets, capacity),
        src_idx=sbuf.reshape(nbuckets, capacity),
        dropped=dropped,
    )


def exchange(x: jax.Array, axis_name: str) -> jax.Array:
    """All-to-all the leading (destination) axis. x: (S, C, *d) per shard.

    After the call, row j holds what shard j sent to this shard.
    """
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                              tiled=True)


def unbin(results: jax.Array, src_idx: jax.Array, m: int) -> jax.Array:
    """Scatter per-bucket results back to issue order. results: (S, C, *e)."""
    flat_res = results.reshape((-1,) + results.shape[2:])
    flat_idx = src_idx.reshape(-1)
    out = jnp.zeros((m,) + results.shape[2:], results.dtype)
    return out.at[flat_idx].set(flat_res, mode="drop")


def bucket_sync_update(
    dest: jax.Array,
    payload: jax.Array,
    valid: jax.Array,
    axis_name: str,
    nshards: int,
    capacity: int,
    owner_apply: Callable,
    owner_state,
):
    """Delayed *update* sync: route payloads to owners, apply, no reply.

    owner_apply(state, payload (S*C, *d), valid (S*C,)) -> new state.
    Returns (new_state, dropped). Call inside shard_map.
    """
    binned = bin_by_dest(dest, payload, valid, nshards, capacity)
    recv = exchange(binned.payload, axis_name)
    recv_valid = exchange(binned.valid, axis_name)
    flat = recv.reshape((-1,) + recv.shape[2:])
    flat_valid = recv_valid.reshape(-1)
    new_state = owner_apply(owner_state, flat, flat_valid)
    dropped = jax.lax.psum(binned.dropped, axis_name)
    return new_state, dropped


def bucket_sync_access(
    dest: jax.Array,
    payload: jax.Array,
    valid: jax.Array,
    axis_name: str,
    nshards: int,
    capacity: int,
    owner_fn: Callable,
):
    """Delayed *access* sync: route to owners, compute, route replies back.

    owner_fn(payload (S, C, *d), valid (S, C)) -> results (S, C, *e).
    Returns (results_in_issue_order (m, *e), valid_out (m,), dropped).
    Call inside shard_map.
    """
    m = dest.shape[0]
    binned = bin_by_dest(dest, payload, valid, nshards, capacity)
    recv = exchange(binned.payload, axis_name)
    recv_valid = exchange(binned.valid, axis_name)
    results = owner_fn(recv, recv_valid)
    back = exchange(results, axis_name)
    out = unbin(back, binned.src_idx, m)
    ok = unbin(binned.valid.astype(jnp.int32), binned.src_idx, m) > 0
    dropped = jax.lax.psum(binned.dropped, axis_name)
    return out, ok, dropped
