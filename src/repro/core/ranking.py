"""Permutation rank/unrank — the index space of the implicit bit-array BFS.

Invariant: ``rank`` and ``unrank`` are exact inverses forming a bijection
{permutations of n} ↔ [0, n!), identical bit-for-bit between the numpy
(Tier D) and jax (Tier J) implementations, and rank *rows* sort
lexicographically in rank order (word 0 most significant).  The implicit
BFS engines index 2-bit state arrays with these ranks, so any deviation
silently conflates distinct states.

The paper's pancake computation never stores permutations as row keys: a
permutation IS its index into a RoomyArray of 2-bit elements, via a
rank/unrank bijection {permutations of n} ↔ [0, n!).  We use the
Myrvold–Ruskey ordering (linear-time, non-lexicographic — the ordering is
irrelevant, only bijectivity matters), which vectorizes over batches as n
rounds of fancy-indexed swaps:

    unrank(r):  pi = identity; for i = n..1: swap(pi[i-1], pi[r % i]); r //= i
    rank(pi):   for i = n..2: emit s = pi[i-1]; swap pi so value i-1 lands at
                slot i-1 (and fix pi⁻¹); fold r = r·i + s  (i ascending)

Two parallel implementations share that algorithm:

  *_np    NumPy, uint64 ranks (Tier D — disk BFS drives millions of states
          through these per level; every step is a batched gather/scatter)
  *_jnp   jax.numpy, and — because JAX runs with x64 disabled — ranks are
          **two-word (hi, lo) uint32 pairs** with schoolbook base-2¹⁶
          multiply-add / long division by the (≤ n) loop constant.  Word 0
          is the high word, so rank rows sort lexicographically in rank
          order under the repo's word-0-most-significant row convention.

One uint32 word holds n ≤ 12 (12! < 2³²); two words hold n ≤ 20
(20! < 2⁶⁴).  ``RANK_WIDTH[n]`` gives the row width the BFS encodings use.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

MAX_N = 20          # 20! < 2^64: two uint32 words per rank
MAX_N_1WORD = 12    # 12! < 2^32: single-word ranks


def rank_width(n: int) -> int:
    """Row width (uint32 words) needed to hold ranks in [0, n!)."""
    assert 1 <= n <= MAX_N, f"rank/unrank supports n <= {MAX_N}"
    return 1 if n <= MAX_N_1WORD else 2


# ======================================================================
# NumPy (Tier D)
# ======================================================================

def unrank_np(n: int, ranks: np.ndarray) -> np.ndarray:
    """Myrvold–Ruskey unrank, batched: (m,) uint64 → (m, n) int64 perms."""
    assert 1 <= n <= MAX_N
    r = np.asarray(ranks, np.uint64).copy().reshape(-1)
    m = r.shape[0]
    pi = np.broadcast_to(np.arange(n, dtype=np.int64), (m, n)).copy()
    rows = np.arange(m)
    for i in range(n, 0, -1):
        s = (r % np.uint64(i)).astype(np.int64)
        r //= np.uint64(i)
        a = pi[rows, i - 1].copy()
        pi[rows, i - 1] = pi[rows, s]
        pi[rows, s] = a
    return pi


def rank_np(perms: np.ndarray) -> np.ndarray:
    """Myrvold–Ruskey rank, batched: (m, n) perms → (m,) uint64 ranks."""
    pi = np.array(perms, np.int64, copy=True)
    m, n = pi.shape
    assert 1 <= n <= MAX_N
    pinv = np.argsort(pi, axis=1)
    rows = np.arange(m)
    s_seq = []
    for i in range(n, 1, -1):
        s = pi[rows, i - 1].copy()
        j = pinv[rows, i - 1].copy()
        # swap pi[i-1] ↔ pi[j] (value i-1 moves to its home slot) …
        pi[rows, i - 1] = pi[rows, j]
        pi[rows, j] = s
        # … and the matching swap in the inverse.
        t = pinv[rows, s].copy()
        pinv[rows, s] = pinv[rows, i - 1]
        pinv[rows, i - 1] = t
        s_seq.append(s)
    r = np.zeros(m, np.uint64)
    for i, s in zip(range(2, n + 1), reversed(s_seq)):
        r = r * np.uint64(i) + s.astype(np.uint64)
    return r


def ranks_to_rows(ranks: np.ndarray, n: int) -> np.ndarray:
    """uint64 ranks → (m, rank_width(n)) uint32 rows, word 0 most significant
    (so lexicographic row order == numeric rank order)."""
    r = np.asarray(ranks, np.uint64).reshape(-1)
    if rank_width(n) == 1:
        return r.astype(np.uint32)[:, None]
    hi = (r >> np.uint64(32)).astype(np.uint32)
    lo = (r & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return np.stack([hi, lo], axis=1)


def rows_to_ranks(rows: np.ndarray) -> np.ndarray:
    """(m, 1|2) uint32 rows → (m,) uint64 ranks (inverse of ranks_to_rows)."""
    rows = np.asarray(rows, np.uint32)
    if rows.shape[1] == 1:
        return rows[:, 0].astype(np.uint64)
    return (rows[:, 0].astype(np.uint64) << np.uint64(32)) | rows[:, 1].astype(np.uint64)


# ======================================================================
# jax.numpy (Tier J) — double-word uint32 arithmetic (x64 is disabled)
# ======================================================================

def _muladd_u64(hi: jax.Array, lo: jax.Array, i: int, s: jax.Array):
    """(hi, lo)·i + s for small i ≤ MAX_N, s < i.  Base-2¹⁶ carries keep
    every intermediate under 32 bits."""
    s = s.astype(jnp.uint32)
    t0 = (lo & 0xFFFF) * i + s
    t1 = (lo >> 16) * i + (t0 >> 16)
    new_lo = (t0 & 0xFFFF) | ((t1 & 0xFFFF) << 16)
    new_hi = hi * i + (t1 >> 16)
    return new_hi.astype(jnp.uint32), new_lo.astype(jnp.uint32)


def _divmod_u64(hi: jax.Array, lo: jax.Array, i: int):
    """(hi, lo) divmod small i: schoolbook base-2¹⁶ long division.
    Returns (q_hi, q_lo, rem); rem < i fits one word trivially."""
    digits = (hi >> 16, hi & 0xFFFF, lo >> 16, lo & 0xFFFF)
    rem = jnp.zeros_like(hi)
    q = []
    for d in digits:
        cur = (rem << 16) | d          # rem < i ≤ 20 → cur < 2²¹
        q.append(cur // i)
        rem = cur % i
    q_hi = ((q[0] << 16) | q[1]).astype(jnp.uint32)
    q_lo = ((q[2] << 16) | q[3]).astype(jnp.uint32)
    return q_hi, q_lo, rem.astype(jnp.uint32)


def unrank_jnp(n: int, rank_rows: jax.Array) -> jax.Array:
    """Batched unrank: (m, rank_width(n)) uint32 rows → (m, n) int32 perms.

    Accepts width-1 rows for n ≤ 12 and width-2 (hi, lo) rows for any n.
    """
    assert 1 <= n <= MAX_N
    rank_rows = rank_rows.astype(jnp.uint32)
    if rank_rows.shape[1] == 1:
        hi = jnp.zeros_like(rank_rows[:, 0])
        lo = rank_rows[:, 0]
    else:
        hi, lo = rank_rows[:, 0], rank_rows[:, 1]
    m = lo.shape[0]
    pi = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (m, n))
    rows = jnp.arange(m)
    for i in range(n, 0, -1):
        hi, lo, s = _divmod_u64(hi, lo, i)
        s = s.astype(jnp.int32)
        a = pi[:, i - 1]
        b = pi[rows, s]
        pi = pi.at[:, i - 1].set(b)
        pi = pi.at[rows, s].set(a)
    return pi


def rank_jnp(perms: jax.Array, width: int | None = None) -> jax.Array:
    """Batched rank: (m, n) perms → (m, width) uint32 rank rows.

    width defaults to rank_width(n); word 0 is the high word.
    """
    pi = perms.astype(jnp.int32)
    m, n = pi.shape
    assert 1 <= n <= MAX_N
    width = width or rank_width(n)
    pinv = jnp.argsort(pi, axis=1).astype(jnp.int32)
    rows = jnp.arange(m)
    s_seq = []
    for i in range(n, 1, -1):
        s = pi[:, i - 1]
        j = pinv[:, i - 1]
        pj = pi[rows, j]
        pi = pi.at[:, i - 1].set(pj)
        pi = pi.at[rows, j].set(s)
        t = pinv[rows, s]
        u = pinv[:, i - 1]
        pinv = pinv.at[rows, s].set(u)
        pinv = pinv.at[:, i - 1].set(t)
        s_seq.append(s)
    hi = jnp.zeros((m,), jnp.uint32)
    lo = jnp.zeros((m,), jnp.uint32)
    for i, s in zip(range(2, n + 1), reversed(s_seq)):
        hi, lo = _muladd_u64(hi, lo, i, s)
    if width == 1:
        return lo[:, None]
    return jnp.stack([hi, lo], axis=1)


def n_states(n: int) -> int:
    return math.factorial(n)
