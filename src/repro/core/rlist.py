"""RoomyList — capacity-bounded, unordered multiset of fixed-width elements.

Faithful port of the paper's RoomyList (Table 1):

  add          delayed   -> ``add`` (batched append; the caller's batch is
                            the delay unit — see DESIGN.md §2)
  remove       delayed   -> ``remove`` (batched)
  addAll       immediate -> ``add_all``
  removeAll    immediate -> ``remove_all`` (multiset: removes *all*
                            occurrences of every element present in other)
  removeDupes  immediate -> ``remove_dupes``
  sync         immediate -> no-op here (adds apply eagerly in the functional
                            encoding; kept for API parity)
  size         immediate -> ``.count``
  map / reduce / predicateCount -> ``map_rows`` / ``reduce`` / ``predicate_count``

Representation: ``data`` is (capacity, width) uint32 with the logical
content in rows [0, count); rows beyond are the sentinel. The list is
unordered, so every operation is free to permute rows.

The paper notes RoomyList operations are dominated by sorting — that is by
construction true here too (lexsort is the workhorse), which is why the LM
integration prefers RoomyArray/RoomyHashTable bucketing (see delayed.py).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import types as T


class RoomyList(NamedTuple):
    data: jax.Array   # (capacity, width) uint32
    count: jax.Array  # () int32

    @property
    def capacity(self) -> int:
        return self.data.shape[0]

    @property
    def width(self) -> int:
        return self.data.shape[1]


def make(capacity: int, width: int) -> RoomyList:
    return RoomyList(T.sentinel_rows(capacity, width), jnp.zeros((), jnp.int32))


def from_rows(rows: jax.Array, capacity: int | None = None) -> RoomyList:
    n, w = rows.shape
    capacity = capacity or n
    rl = make(capacity, w)
    rl, _ = add(rl, rows.astype(jnp.uint32), jnp.ones((n,), bool))
    return rl


def valid_mask(rl: RoomyList) -> jax.Array:
    return jnp.arange(rl.capacity) < rl.count


def add(rl: RoomyList, rows: jax.Array, valid: jax.Array | None = None):
    """Append a batch of rows. Returns (list, overflow)."""
    if valid is None:
        valid = jnp.ones((rows.shape[0],), bool)
    data, count, overflow = T.append_block(rl.data, rl.count, rows, valid)
    return RoomyList(data, count), overflow


def add_all(dst: RoomyList, src: RoomyList):
    """dst += src (multiset union, keeps duplicates) — paper's addAll."""
    return add(dst, src.data, valid_mask(src))


def remove(rl: RoomyList, rows: jax.Array, valid: jax.Array | None = None) -> RoomyList:
    """Remove all occurrences of each given row — paper's delayed remove."""
    if valid is None:
        valid = jnp.ones((rows.shape[0],), bool)
    other = make(rows.shape[0], rows.shape[1])
    other, _ = add(other, rows.astype(jnp.uint32), valid)
    return remove_all(rl, other)


def remove_all(a: RoomyList, b: RoomyList) -> RoomyList:
    """a -= b: drop every a-row that occurs (at least once) in b.

    Sort-once: the survivors are compacted directly in sorted order
    (boolean argsort) instead of being scattered back to a's slot order and
    re-partitioned — the list is unordered, so no information is lost.
    """
    na, nb = a.capacity, b.capacity
    rows = jnp.concatenate([a.data, b.data], axis=0)
    tag_b = jnp.concatenate([jnp.zeros((na,), bool), valid_mask(b)])
    from_a = jnp.concatenate([valid_mask(a), jnp.zeros((nb,), bool)])
    perm = T.lexsort_rows(rows)
    rows_s, tag_s, from_a_s = rows[perm], tag_b[perm], from_a[perm]
    rid = T.run_ids(rows_s)
    # A run contains a b-row iff segment-max of tag_b is 1.
    run_has_b = jax.ops.segment_max(
        tag_s.astype(jnp.int32), rid, num_segments=na + nb
    )
    keep_s = from_a_s & (run_has_b[rid] == 0)
    data, count = T.compact_valid_first(rows_s, keep_s)
    return RoomyList(data[:na], count)


def remove_dupes(rl: RoomyList) -> RoomyList:
    """Collapse the multiset to a set — paper's removeDupes.

    Sort-once: one lexsort, then a boolean-argsort compaction of the
    already-sorted survivors (no scatter-back + re-partition round trip).
    Slots beyond count are masked to sentinel first: append_block's
    contract permits garbage there, which must not surface as elements.
    """
    rows = jnp.where(valid_mask(rl)[:, None], rl.data,
                     T.sentinel_rows(rl.capacity, rl.width))
    perm = T.lexsort_rows(rows)
    rows_s = rows[perm]
    keep_s = T.first_of_run(rows_s) & T.rows_valid(rows_s)
    data, count = T.compact_valid_first(rows_s, keep_s)
    return RoomyList(data, count)


def member_mask(rl: RoomyList, queries: jax.Array) -> jax.Array:
    """(m,) bool — which query rows occur in the list."""
    m = queries.shape[0]
    rows = jnp.concatenate([rl.data, queries.astype(jnp.uint32)], axis=0)
    tag_list = jnp.concatenate([valid_mask(rl), jnp.zeros((m,), bool)])
    perm = T.lexsort_rows(rows)
    rid = T.run_ids(rows[perm])
    run_has = jax.ops.segment_max(
        tag_list[perm].astype(jnp.int32), rid, num_segments=rows.shape[0]
    )
    hit_s = run_has[rid] == 1
    hits = jnp.zeros((rows.shape[0],), bool).at[perm].set(hit_s)
    return hits[rl.capacity:]


def map_rows(rl: RoomyList, fn: Callable) -> jax.Array:
    """Apply fn to every element (vectorized); returns fn's batched output.

    fn: (width,) uint32 -> pytree. Invalid slots still flow through fn;
    mask with ``valid_mask`` on the caller side when it matters.
    """
    return jax.vmap(fn)(rl.data)


def reduce(rl: RoomyList, elt_fn: Callable, merge_fn: Callable, identity) -> jax.Array:
    """Paper's reduce: merge_fn must be associative+commutative with
    ``identity`` as its unit (undefined order, as the paper warns)."""
    vals = jax.vmap(elt_fn)(rl.data)
    ident = jnp.asarray(identity, dtype=vals.dtype)
    mask = valid_mask(rl).reshape((-1,) + (1,) * (vals.ndim - 1))
    vals = jnp.where(mask, vals, ident)
    return T.tree_reduce(vals, merge_fn, identity)


def predicate_count(rl: RoomyList, pred: Callable) -> jax.Array:
    hits = jax.vmap(pred)(rl.data) & valid_mask(rl)
    return jnp.sum(hits.astype(jnp.int32))


def to_numpy(rl: RoomyList):
    """Materialize the logical content (host-side; test/debug helper)."""
    import numpy as np

    data = np.asarray(jax.device_get(rl.data))
    n = int(jax.device_get(rl.count))
    return data[:n]
