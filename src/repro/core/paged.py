"""Roomy paged-KV store — the RoomyArray access pattern applied to KV cache.

Long-context decode is a space-limited computation: the KV cache of one
524 288-token sequence does not fit one chip's HBM.  We treat the cache as a
RoomyArray of fixed-size *pages* distributed over the mesh ("many disks"),
and a decode step's reads as delayed accesses resolved by one batched
gather per layer — never per-token random access.

Functional layout (a pytree, friendly to scan-over-layers):

  k_pages, v_pages : (num_pages, page_size, kv_heads, head_dim)
  page_table       : (batch, pages_per_seq) int32 — logical→physical map
  lengths          : (batch,) int32 current sequence lengths

Sharding: ``num_pages`` shards over the mesh's data axis for batch=1
long-context (context parallelism); for batched decode the batch dim of
``page_table``/``lengths`` shards over data instead and pages replicate the
same way the model does. The dry-run exercises both.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class PagedKV(NamedTuple):
    k_pages: jax.Array     # (num_pages, page, kvh, hd)
    v_pages: jax.Array     # (num_pages, page, kvh, hd)
    page_table: jax.Array  # (batch, pages_per_seq) int32
    lengths: jax.Array     # (batch,) int32

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[1]

    @property
    def pages_per_seq(self) -> int:
        return self.page_table.shape[1]


def make(batch: int, max_len: int, kv_heads: int, head_dim: int,
         page_size: int = 128, dtype=jnp.bfloat16) -> PagedKV:
    pages_per_seq = -(-max_len // page_size)
    num_pages = batch * pages_per_seq
    # Identity page table: page p of sequence b is physical b*pps + p.
    table = (jnp.arange(batch)[:, None] * pages_per_seq
             + jnp.arange(pages_per_seq)[None, :]).astype(jnp.int32)
    shape = (num_pages, page_size, kv_heads, head_dim)
    return PagedKV(
        k_pages=jnp.zeros(shape, dtype),
        v_pages=jnp.zeros(shape, dtype),
        page_table=table,
        lengths=jnp.zeros((batch,), jnp.int32),
    )


def append(cache: PagedKV, k_new: jax.Array, v_new: jax.Array) -> PagedKV:
    """Append one token's K/V per sequence (decode step).

    k_new, v_new: (batch, kv_heads, head_dim). Delayed-update semantics:
    the whole batch of writes lands as one scatter (Roomy update+sync).
    """
    b = cache.lengths.shape[0]
    page_logical = cache.lengths // cache.page_size
    offset = cache.lengths % cache.page_size
    phys = jnp.take_along_axis(cache.page_table, page_logical[:, None],
                               axis=1)[:, 0]
    k_pages = cache.k_pages.at[phys, offset].set(k_new.astype(cache.k_pages.dtype))
    v_pages = cache.v_pages.at[phys, offset].set(v_new.astype(cache.v_pages.dtype))
    return cache._replace(k_pages=k_pages, v_pages=v_pages,
                          lengths=cache.lengths + 1)


def bulk_fill(cache: PagedKV, k: jax.Array, v: jax.Array,
              lengths: jax.Array) -> PagedKV:
    """Prefill: write (batch, seq, kvh, hd) K/V into pages in one pass.

    Partial final pages are zero-padded (lengths marks validity)."""
    b, s, kvh, hd = k.shape
    ps = cache.page_size
    npage = -(-s // ps)
    if npage * ps != s:
        pad = npage * ps - s
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k_r = k.reshape(b * npage, ps, kvh, hd)
    v_r = v.reshape(b * npage, ps, kvh, hd)
    phys = cache.page_table[:, :npage].reshape(-1)
    k_pages = cache.k_pages.at[phys].set(k_r.astype(cache.k_pages.dtype))
    v_pages = cache.v_pages.at[phys].set(v_r.astype(cache.v_pages.dtype))
    return cache._replace(k_pages=k_pages, v_pages=v_pages, lengths=lengths)


def gather(cache: PagedKV) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Resolve the delayed page accesses for a decode step.

    Returns (k, v, mask): (batch, pages_per_seq*page, kvh, hd) and a
    validity mask (batch, pages_per_seq*page). One batched gather — the
    RoomyArray access/sync pair with the page table as the op queue.
    """
    b, pps = cache.page_table.shape
    k = cache.k_pages[cache.page_table]      # (b, pps, page, kvh, hd)
    v = cache.v_pages[cache.page_table]
    ps = cache.page_size
    k = k.reshape(b, pps * ps, *k.shape[3:])
    v = v.reshape(b, pps * ps, *v.shape[3:])
    mask = jnp.arange(pps * ps)[None, :] < cache.lengths[:, None]
    return k, v, mask
