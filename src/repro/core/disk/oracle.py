"""Distance-oracle serving tier: sealed artifacts + batched query server.

The paper's flagship computations end with a perfect distance table — the
finished 2-bit array — which the search machinery then throws away.  This
module turns "search completes" into "queries served": a completed
implicit-BFS run is *published* as an immutable, versioned, checksummed
artifact, and a read-only :class:`DistanceOracle` serves batched
``rank → distance`` lookups (and path reconstruction) over it through an
LRU chunk cache whose budget can be a small fraction of the artifact.

Why publishing re-labels
------------------------
``implicit_bfs`` finishes with every reached state at ``DONE`` — distance
parity is not recoverable from the search array; only ``level_sizes``
survives.  ``publish_oracle`` therefore runs a **mod-3 labeling pass**
(the classic two-bit BFS encoding from Cooperman–Finkelstein / Korf used
by the frontier searches the paper cites): code 0 = unreached, code
``(d % 3) + 1`` = reached at distance ``d``.  Marks only ever land on
UNSEEN cells (the ``apply`` hook), so labels are exact; the per-level
newly-marked counts are compared against the completed search's
``level_sizes`` — publishing *seals a finished run*, it never invents
one.  Because three codes cycle, level ``d`` expansion also re-expands
distance ``d-3`` states whose chunks carry fresh marks; their neighbors
are all labeled already, so the duplicate marks absorb harmlessly — a
bounded CPU tax on the one-time publish, never a correctness issue.

Artifact layout (mirrors ``checkpoint.py``'s publish discipline)::

    <root>/ORACLE              manifest: {"format", "version", "meta_sha256"}
    <root>/v000001/META.json   format, n_states, chunking, start ranks,
                               level_sizes, codec params, owner-function
                               goldens, per-chunk sha256 fingerprints
    <root>/v000001/b000000.npy packed 2-bit code chunks (DiskBitArray layout)

Staging (``v*.tmp`` → ``os.rename`` seal → manifest ``.tmp`` +
``os.replace``) makes every step atomic; a crash leaves either the old
version adoptable or the new one sealed.  Versions are IMMUTABLE:
re-publishing bumps the version and repoints the manifest; older sealed
versions remain readable until manually removed.  Adoption rules match
``SearchCheckpoint.latest``: a missing manifest falls back to the newest
sealed version with a valid META; a manifest naming a missing/torn
version, a META whose sha256 disagrees with the manifest, a format
mismatch, or a chunk whose sha256 disagrees with META all raise
:class:`OracleError` — the oracle fails loudly, it never serves wrong
data.

Exact distances from mod-3 codes: **greedy descent**.  A walker at code
``c`` holding distance ``d ≡ c-1 (mod 3)`` moves to any neighbor with
code ``((c - 2) % 3) + 1`` — neighbor distances differ from ``d`` by at
most 1 (this requires the neighbor relation to be SYMMETRIC, true for
the involutive pancake/Cayley generators), so a neighbor at ``d-1 mod 3``
is at exactly ``d - 1``.  Steps until a start state = the distance; the
visited ranks = the path.  Descent is batched: one ``gen_neighbors`` call
and one batched code gather advance every active walker per step.

This module must stay importable without jax (the disk tier's spawn
workers import it); neighbor generators are caller-supplied callables
``(m,) int64 ranks → (m, deg) int64`` — e.g. ``examples/pancake_bits
.neighbors_np(n)``.  Cache accounting lives in the ``oracle`` obs
namespace (exact, thread-locked); a search that never touches this
module books nothing there.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import tempfile
import threading
from collections import OrderedDict
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from . import faults
from . import codec as _codec
from .bitarray import UNSEEN, VALS_PER_BYTE, DiskBitArray
from .buckets import block_owner_np
from .passes import PassPlan

__all__ = ["OracleError", "DistanceOracle", "ShardedOracle",
           "publish_oracle", "label_distances_mod3", "reset_stats", "STATS"]

MANIFEST = "ORACLE"
META = "META.json"
FORMAT = 1                    # raw .npy chunk payloads (the original layout)
FORMAT_COMPRESSED = 2         # RLE-coded .rmz chunk payloads (disk/codec.py)
SUPPORTED_FORMATS = (FORMAT, FORMAT_COMPRESSED)
_VDIR_RE = re.compile(r"^v(\d{6,})$")
# Owner-function golden fingerprints are pinned for these shard counts at
# publish time; ShardedOracle recomputes and compares at open (an
# ownership disagreement between publisher and server is silent
# misrouting — same rule as checkpoint resume).
_GOLDEN_NSHARDS = (1, 2, 4, 8)

# Exact serving-side accounting (docs/serving.md "Cache contract").
# resident_bytes is a live gauge summed over every open cache; the rest
# are monotonic.  All mutations hold _STATS_LOCK so concurrent readers
# keep the counts exact — the serve bench pins resident_peak <= budget.
STATS = obs.counters("oracle", {
    "lookups": 0, "batches": 0, "hits": 0, "misses": 0,
    "chunk_loads": 0, "evictions": 0, "bytes_read": 0,
    "resident_bytes": 0, "resident_peak": 0,
})
_STATS_LOCK = threading.Lock()


def reset_stats() -> None:
    for k in STATS:
        STATS[k] = 0


class OracleError(RuntimeError):
    """Artifact missing, torn, tampered, or structurally incompatible."""


def _code_of(level: int) -> int:
    return (level % 3) + 1


def _sha256_bytes(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


# ===================================================== mod-3 labeling pass
def label_distances_mod3(bits: DiskBitArray, start: np.ndarray,
                         gen_neighbors: Callable[[np.ndarray], np.ndarray],
                         expand_batch: int = 1 << 15,
                         expect_level_sizes: Optional[Sequence[int]] = None,
                         ) -> List[int]:
    """BFS over ``bits`` writing code ``(d % 3) + 1`` at every reached
    state; returns the per-level newly-labeled counts.

    One fused read-write pass per level, same machinery as
    ``implicit_bfs``: the pass applies the queued level-``d`` marks (the
    ``apply`` hook counts how many landed on UNSEEN — states at ``d-3``
    share the code, so scanning codes could not recover the count) and
    its piggybacked read stage expands the freshly-coded states, queueing
    level-``d+1`` marks for the next pass.  ``dirty_only`` passes visit
    only chunks holding queued marks: every distance-``d`` state lives in
    such a chunk (its mark is in the log), and skipped chunks can only
    contain already-labeled states whose re-expansion would be wasted.

    ``expect_level_sizes``: the completed search's histogram; any
    per-level disagreement raises :class:`OracleError` — publishing only
    seals runs it can reproduce exactly.
    """
    start = np.asarray(start, np.int64).reshape(-1)
    if start.size == 0:
        raise OracleError("empty start set")
    newly = 0

    def counting_apply(old: np.ndarray, agg: np.ndarray) -> np.ndarray:
        nonlocal newly
        fresh = old == UNSEEN
        newly += int(np.count_nonzero(fresh))
        return np.where(fresh, agg, old)

    def make_expand(code_cur: int, code_next: int):
        def expand(chunk_start: int, vals: np.ndarray) -> None:
            (pos,) = np.nonzero(vals == code_cur)
            for lo in range(0, pos.shape[0], expand_batch):
                idx = chunk_start + pos[lo:lo + expand_batch].astype(np.int64)
                nbrs = np.asarray(gen_neighbors(idx), np.int64).reshape(-1)
                bits.update(nbrs, np.full(nbrs.shape, code_next, np.uint8))
        return expand

    level_sizes: List[int] = []
    bits.update(start, np.full(start.shape, _code_of(0), np.uint8))
    level = 0
    while True:
        newly = 0
        plan = PassPlan("oracle-label", dirty_only=True).reads(
            make_expand(_code_of(level), _code_of(level + 1)))
        # All marks queued for one pass carry the same code — first wins.
        bits.run_pass(plan, combine=lambda p, q: p, apply=counting_apply)
        if newly == 0:
            break
        if expect_level_sizes is not None:
            if (level >= len(expect_level_sizes)
                    or newly != int(expect_level_sizes[level])):
                want = (int(expect_level_sizes[level])
                        if level < len(expect_level_sizes) else "<end>")
                raise OracleError(
                    f"labeling level {level} marked {newly} states but the "
                    f"completed search recorded {want} — refusing to "
                    "publish a run the labeler cannot reproduce")
        level_sizes.append(newly)
        level += 1
        if level > bits.n:
            raise OracleError("labeling did not terminate (neighbor "
                              "function not symmetric/closed?)")
    if (expect_level_sizes is not None
            and len(level_sizes) != len(expect_level_sizes)):
        raise OracleError(
            f"labeling found {len(level_sizes)} levels but the completed "
            f"search recorded {len(expect_level_sizes)}")
    return level_sizes


# ================================================================ publish
def _sealed_versions(root: str) -> List[int]:
    out = []
    for fn in os.listdir(root):
        m = _VDIR_RE.match(fn)
        if m and os.path.isdir(os.path.join(root, fn)):
            out.append(int(m.group(1)))
    return sorted(out)


def publish_oracle(dst: str, n_states: int, start: np.ndarray,
                   gen_neighbors: Callable[[np.ndarray], np.ndarray], *,
                   level_sizes: Optional[Sequence[int]] = None,
                   chunk_elems: int = 1 << 22,
                   codec: Optional[dict] = None,
                   workdir: Optional[str] = None,
                   expand_batch: int = 1 << 15,
                   log_buf_rows: int = 1 << 20,
                   compress: bool = False) -> dict:
    """Seal a completed search as an immutable versioned oracle artifact.

    Runs the mod-3 labeling BFS in a scratch :class:`DiskBitArray`
    (``workdir`` or a temp dir), validates per-level counts against
    ``level_sizes`` (pass the completed run's histogram — e.g. the
    return of ``implicit_bfs`` or a checkpoint META's ``sizes``), then
    publishes under ``dst`` with the checkpoint layer's atomic-rename
    discipline.  Returns the sealed META dict (includes ``version``).

    ``codec`` is an opaque dict recorded in META describing the rank
    codec (e.g. ``{"space": "pancake", "n": 9, "ranking":
    "myrvold-ruskey"}``) so a consumer can reconstruct the right
    ``gen_neighbors`` / unrank for path queries.

    ``compress=True`` seals the chunk payloads through the RLE codec of
    ``disk/codec.py`` (``b*.rmz`` instead of ``b*.npy``) and bumps the
    artifact format to :data:`FORMAT_COMPRESSED`.  The per-chunk sha256
    fingerprints are always taken over the RAW packed bytes, so a
    compressed and an uncompressed publish of the same run carry
    identical fingerprints; a tampered compressed stream fails the codec
    CRC before the fingerprint is even consulted.  FORMAT-1 artifacts are
    byte-for-byte unaffected by this option existing.
    """
    n_states = int(n_states)
    start = np.asarray(start, np.int64).reshape(-1)
    os.makedirs(dst, exist_ok=True)
    scratch = workdir or tempfile.mkdtemp(prefix="oracle_label_")
    own_scratch = workdir is None
    try:
        bits = DiskBitArray(scratch, n_states, chunk_elems=chunk_elems,
                            name="oracle_label", log_buf_rows=log_buf_rows)
        sizes = label_distances_mod3(
            bits, start, gen_neighbors, expand_batch=expand_batch,
            expect_level_sizes=level_sizes)

        version = (_sealed_versions(dst) or [0])[-1] + 1
        vdir = os.path.join(dst, f"v{version:06d}")
        stage = vdir + ".tmp"
        shutil.rmtree(stage, ignore_errors=True)
        os.makedirs(stage)
        fmt = FORMAT_COMPRESSED if compress else FORMAT
        chunk_sha = {}
        for c in range(bits.n_chunks):
            packed = np.load(bits._chunk_path(c))
            chunk_sha[str(c)] = _sha256_bytes(packed.tobytes())
            if compress:
                enc = _codec.encode_rle2(packed, tag="oracle")
                with open(os.path.join(stage, f"b{c:06d}.rmz"), "wb") as f:
                    f.write(enc)
            else:
                np.save(os.path.join(stage, f"b{c:06d}.npy"), packed)
        probe = np.linspace(0, n_states - 1,
                            num=min(9, n_states)).astype(np.int64)
        meta = {
            "format": fmt,
            "kind": "distance_oracle_mod3",
            "version": version,
            "n_states": n_states,
            "chunk_elems": int(chunk_elems),
            "n_chunks": bits.n_chunks,
            "start": start.tolist(),
            "level_sizes": [int(s) for s in sizes],
            "codec": dict(codec or {}),
            "chunk_sha256": chunk_sha,
            "owner_probe": probe.tolist(),
            "owner_golden": {
                str(ns): block_owner_np(probe, n_states, ns).tolist()
                for ns in _GOLDEN_NSHARDS},
        }
        if compress:        # FORMAT-1 METAs never carry the key
            meta["chunk_codec"] = "rle2"
        meta_blob = json.dumps(meta, sort_keys=True).encode()
        # META lands last inside the stage: a sealed dir always carries it.
        with open(os.path.join(stage, META), "wb") as f:
            f.write(meta_blob)
        faults.retry_io(
            "oracle_publish",
            lambda: os.path.isdir(stage) and os.rename(stage, vdir),
            version=version)                            # atomic seal

        def _point_manifest() -> None:
            tmp = os.path.join(dst, MANIFEST + ".tmp")
            with open(tmp, "w") as f:
                json.dump({"format": fmt, "version": version,
                           "meta_sha256": _sha256_bytes(meta_blob)}, f)
            os.replace(tmp, os.path.join(dst, MANIFEST))
        faults.retry_io("oracle_publish", _point_manifest, version=version)
        # Versions are immutable — only stray staging dirs are GC'd.
        for fn in os.listdir(dst):
            if fn.endswith(".tmp") and fn != MANIFEST + ".tmp":
                shutil.rmtree(os.path.join(dst, fn), ignore_errors=True)
        return meta
    finally:
        if own_scratch:
            shutil.rmtree(scratch, ignore_errors=True)
        else:
            shutil.rmtree(os.path.join(scratch, "oracle_label"),
                          ignore_errors=True)


# ============================================================== LRU cache
class LRUChunkCache:
    """Byte-budgeted LRU over loaded chunk arrays, exact accounting.

    ``get`` serves hits by reference (eviction only drops the cache's
    reference — a reader holding the array keeps it alive, so concurrent
    readers under eviction pressure never see freed memory).  A chunk
    larger than the whole budget is served UNCACHED rather than evicting
    everything for a doomed insert.  The loader runs outside the entry
    lock so distinct chunks load in parallel; a lost race books its load
    but keeps the winner's entry.
    """

    def __init__(self, budget_bytes: int,
                 loader: Callable[[int], np.ndarray]):
        self.budget = int(budget_bytes)
        self._loader = loader
        self._lock = threading.Lock()
        self._entries: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self.resident = 0

    def keys(self) -> List[int]:
        """Cached chunk ids, LRU first (test hook)."""
        with self._lock:
            return list(self._entries)

    def get(self, key: int) -> np.ndarray:
        with self._lock:
            arr = self._entries.get(key)
            if arr is not None:
                self._entries.move_to_end(key)
                with _STATS_LOCK:
                    STATS["hits"] += 1
                return arr
        with _STATS_LOCK:
            STATS["misses"] += 1
        arr = self._loader(key)
        with self._lock:
            with _STATS_LOCK:
                STATS["chunk_loads"] += 1
                STATS["bytes_read"] += arr.nbytes
            have = self._entries.get(key)
            if have is not None:
                self._entries.move_to_end(key)
                return have
            while self._entries and self.resident + arr.nbytes > self.budget:
                _, old = self._entries.popitem(last=False)
                self.resident -= old.nbytes
                with _STATS_LOCK:
                    STATS["evictions"] += 1
                    STATS["resident_bytes"] -= old.nbytes
            if arr.nbytes <= self.budget:
                self._entries[key] = arr
                self.resident += arr.nbytes
                with _STATS_LOCK:
                    STATS["resident_bytes"] += arr.nbytes
                    STATS["resident_peak"] = max(STATS["resident_peak"],
                                                 STATS["resident_bytes"])
            return arr

    def close(self) -> None:
        with self._lock:
            freed = self.resident
            self._entries.clear()
            self.resident = 0
        if freed:
            with _STATS_LOCK:
                STATS["resident_bytes"] -= freed


# ======================================================== batched descent
def _descend(codes_fn: Callable[[np.ndarray], np.ndarray],
             gen_neighbors: Callable[[np.ndarray], np.ndarray],
             ranks: np.ndarray, start: np.ndarray, max_dist: int,
             record: bool) -> Tuple[np.ndarray, Optional[List[List[int]]]]:
    """Batched greedy descent: exact distances (and optionally paths).

    Every iteration advances ALL active walkers one step toward the start
    set with one ``gen_neighbors`` call and one batched code gather —
    total gathers = max distance in the batch, not sum of distances.
    Unreached ranks (code 0) get distance -1 and a path of [rank].
    """
    ranks = np.asarray(ranks, np.int64).reshape(-1)
    dist = np.full(ranks.shape, -1, np.int64)
    chains: Optional[List[List[int]]] = (
        [[int(r)] for r in ranks] if record else None)
    cur = ranks.copy()
    code = codes_fn(cur)
    active = code != 0
    at_start = active & np.isin(cur, start)
    dist[at_start] = 0
    active &= ~at_start
    steps = 0
    while active.any():
        steps += 1
        if steps > max_dist:
            raise OracleError(
                f"greedy descent exceeded the published diameter "
                f"{max_dist} — artifact corrupt or neighbor function "
                "mismatched")
        (pos,) = np.nonzero(active)
        want = ((code[pos].astype(np.int64) - 2) % 3 + 1).astype(np.uint8)
        nb = np.asarray(gen_neighbors(cur[pos]), np.int64)
        nb = nb.reshape(pos.shape[0], -1)
        ncode = codes_fn(nb.reshape(-1)).reshape(nb.shape)
        hit = ncode == want[:, None]
        if not hit.any(axis=1).all():
            raise OracleError(
                "greedy descent found a state with no neighbor one level "
                "closer — artifact corrupt or neighbor function mismatched")
        pick = np.argmax(hit, axis=1)
        rows = np.arange(pos.shape[0])
        cur[pos] = nb[rows, pick]
        code[pos] = ncode[rows, pick]
        if chains is not None:
            for p in pos:
                chains[p].append(int(cur[p]))
        arrived = np.isin(cur[pos], start)
        dist[pos[arrived]] = steps
        active[pos[arrived]] = False
    return dist, chains


# ========================================================== DistanceOracle
class DistanceOracle:
    """Read-only batched ``rank → distance`` server over a sealed artifact.

    Opens the manifest-designated version (crash-adopting the newest
    sealed version when the manifest is missing, exactly like
    ``SearchCheckpoint.latest``), verifies the META fingerprint, and
    serves through an :class:`LRUChunkCache` of ``cache_bytes``.  Chunks
    are adopted ``DiskBitArray(init_chunks=False)``-style: opened
    ``np.load(mmap_mode="r")``, materialized once, sha256-verified
    against META on first load — a tampered chunk raises
    :class:`OracleError` before a single value is served.

    ``gen_neighbors`` (``(m,) → (m, deg)`` ranks, symmetric relation) is
    only needed for :meth:`distance` / :meth:`paths`; :meth:`codes`
    serves raw mod-3 codes without it.
    """

    def __init__(self, root: str, cache_bytes: int = 1 << 20,
                 version: Optional[int] = None,
                 gen_neighbors: Optional[Callable] = None):
        self.root = root
        self.gen_neighbors = gen_neighbors
        if not os.path.isdir(root):
            raise OracleError(f"no oracle artifact at {root}")
        version, want_sha = self._resolve_version(version)
        self.version = version
        self._vdir = os.path.join(root, f"v{version:06d}")
        meta_path = os.path.join(self._vdir, META)
        try:
            with open(meta_path, "rb") as f:
                blob = f.read()
            meta = json.loads(blob)
        except (OSError, ValueError) as e:
            raise OracleError(f"unreadable oracle META {meta_path}: {e}"
                              ) from None
        if want_sha is not None and _sha256_bytes(blob) != want_sha:
            raise OracleError(
                f"META fingerprint mismatch for v{version:06d} — manifest "
                "says someone rewrote the sealed META (tamper?)")
        if meta.get("format") not in SUPPORTED_FORMATS:
            raise OracleError(
                f"oracle format {meta.get('format')!r} is not one of the "
                f"supported formats {SUPPORTED_FORMATS} — refusing to "
                "guess at the layout (was this artifact published by a "
                "newer release?)")
        self._chunk_codec = meta.get("chunk_codec")
        if meta["format"] == FORMAT_COMPRESSED:
            if self._chunk_codec != "rle2":
                raise OracleError(
                    f"format-{FORMAT_COMPRESSED} oracle META names chunk "
                    f"codec {self._chunk_codec!r}; this build only decodes "
                    "'rle2'")
        elif self._chunk_codec is not None:
            raise OracleError(
                f"format-{FORMAT} oracle META unexpectedly names a chunk "
                f"codec ({self._chunk_codec!r}) — artifact inconsistent")
        if int(meta.get("version", -1)) != version:
            raise OracleError(
                f"sealed dir v{version:06d} carries META version "
                f"{meta.get('version')} — manifest/artifact mismatch")
        self.meta = meta
        self.n_states = int(meta["n_states"])
        self.chunk_elems = int(meta["chunk_elems"])
        self.n_chunks = int(meta["n_chunks"])
        self.level_sizes = [int(s) for s in meta["level_sizes"]]
        self.max_dist = len(self.level_sizes) - 1
        self.start = np.asarray(meta["start"], np.int64)
        self.cache = LRUChunkCache(cache_bytes, self._load_chunk)

    # --------------------------------------------------------- open rules
    def _resolve_version(self, version: Optional[int]
                         ) -> Tuple[int, Optional[str]]:
        sealed = _sealed_versions(self.root)
        mpath = os.path.join(self.root, MANIFEST)
        manifest = None
        if os.path.exists(mpath):
            try:
                with open(mpath) as f:
                    manifest = json.load(f)
                int(manifest["version"])
            except (OSError, ValueError, KeyError, TypeError):
                raise OracleError(
                    f"corrupt oracle manifest {mpath}") from None
            if manifest.get("format") not in SUPPORTED_FORMATS:
                raise OracleError(
                    f"oracle manifest format {manifest.get('format')!r} is "
                    f"not one of the supported formats {SUPPORTED_FORMATS}")
        if version is None:
            if manifest is not None:
                version = int(manifest["version"])
                if version not in sealed:
                    raise OracleError(
                        f"manifest names v{version:06d} but no such sealed "
                        "version exists (torn publish / rollback?) — "
                        "refusing to guess")
            elif sealed:
                version = sealed[-1]    # crash between seal and manifest
            else:
                raise OracleError(f"no sealed oracle version under "
                                  f"{self.root}")
        elif version not in sealed:
            raise OracleError(f"requested v{version:06d} is not sealed "
                              f"under {self.root} (have {sealed})")
        want_sha = None
        if manifest is not None and int(manifest["version"]) == version:
            want_sha = manifest.get("meta_sha256")
        return version, want_sha

    def _chunk_rows(self, c: int) -> int:
        return min(self.chunk_elems, self.n_states - c * self.chunk_elems)

    def _load_chunk(self, c: int) -> np.ndarray:
        if self._chunk_codec == "rle2":
            path = os.path.join(self._vdir, f"b{c:06d}.rmz")
            try:
                with open(path, "rb") as f:
                    buf = f.read()
                packed = _codec.decode_rle2(buf, tag="oracle")
            except OSError as e:
                raise OracleError(f"unreadable oracle chunk {path}: {e}"
                                  ) from None
            except _codec.CodecError as e:
                raise OracleError(
                    f"oracle chunk {path} fails to decode ({e}) — "
                    "tampered or torn; refusing to serve from it") from None
        else:
            path = os.path.join(self._vdir, f"b{c:06d}.npy")
            try:
                packed = np.ascontiguousarray(np.load(path, mmap_mode="r"))
            except (OSError, ValueError) as e:
                raise OracleError(f"unreadable oracle chunk {path}: {e}"
                                  ) from None
        rows = -(-self._chunk_rows(c) // VALS_PER_BYTE)
        if packed.dtype != np.uint8 or packed.shape != (rows,):
            raise OracleError(
                f"oracle chunk {path} has shape {packed.shape} "
                f"{packed.dtype}, expected ({rows},) uint8")
        want = self.meta["chunk_sha256"].get(str(c))
        if _sha256_bytes(packed.tobytes()) != want:
            raise OracleError(
                f"oracle chunk {path} fails its sha256 fingerprint — "
                "tampered or torn; refusing to serve from it")
        return packed

    @property
    def artifact_bytes(self) -> int:
        """Total packed chunk bytes of the open version."""
        return sum(-(-self._chunk_rows(c) // VALS_PER_BYTE)
                   for c in range(self.n_chunks))

    # ------------------------------------------------------------ serving
    def codes(self, ranks: np.ndarray) -> np.ndarray:
        """Batched raw mod-3 codes (0 = unreached) for int64 ranks."""
        idx = np.asarray(ranks, np.int64).reshape(-1)
        with _STATS_LOCK:
            STATS["lookups"] += int(idx.size)
            STATS["batches"] += 1
        if idx.size == 0:
            return np.zeros(0, np.uint8)
        if idx.min() < 0 or idx.max() >= self.n_states:
            raise ValueError(
                f"rank out of range [0, {self.n_states}) in oracle query")
        out = np.empty(idx.shape, np.uint8)
        chunk_of = idx // self.chunk_elems
        order = np.argsort(chunk_of, kind="stable")
        bounds = np.searchsorted(chunk_of[order],
                                 np.arange(self.n_chunks + 1))
        for c in np.unique(chunk_of):
            sel = order[bounds[c]:bounds[c + 1]]
            local = idx[sel] - c * self.chunk_elems
            packed = self.cache.get(int(c))
            out[sel] = ((packed[local // VALS_PER_BYTE]
                         >> (2 * (local % VALS_PER_BYTE)).astype(np.uint8))
                        & 3)
        return out

    def distance(self, ranks: np.ndarray,
                 gen_neighbors: Optional[Callable] = None) -> np.ndarray:
        """Batched EXACT distances via greedy descent (-1 = unreached)."""
        gen = gen_neighbors or self.gen_neighbors
        if gen is None:
            raise ValueError("distance queries need gen_neighbors "
                             "(constructor or argument)")
        dist, _ = _descend(self.codes, gen, ranks, self.start,
                           self.max_dist, record=False)
        return dist

    # The serving-tier entry point name; distance IS the lookup product.
    lookup = distance

    def paths(self, ranks: np.ndarray,
              gen_neighbors: Optional[Callable] = None
              ) -> Tuple[np.ndarray, List[np.ndarray]]:
        """Batched path reconstruction: ``(distances, [rank chains])``.

        Each chain runs query rank → ... → a start rank, consecutive
        entries neighbors, length ``distance + 1``; unreached ranks get
        distance -1 and the single-entry chain ``[rank]``.
        """
        gen = gen_neighbors or self.gen_neighbors
        if gen is None:
            raise ValueError("path queries need gen_neighbors")
        dist, chains = _descend(self.codes, gen, ranks, self.start,
                                self.max_dist, record=True)
        return dist, [np.asarray(ch, np.int64) for ch in chains]

    def path(self, rank: int,
             gen_neighbors: Optional[Callable] = None) -> np.ndarray:
        return self.paths(np.asarray([rank]), gen_neighbors)[1][0]

    def close(self) -> None:
        self.cache.close()

    def __enter__(self) -> "DistanceOracle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# =========================================================== ShardedOracle
class ShardedOracle:
    """Shard-aware front: bins query batches by ``block_owner_np`` and
    fans them to per-shard :class:`DistanceOracle` caches.

    Every shard opens the same sealed artifact; sharding partitions CACHE
    LOCALITY, not data — shard ``s``'s cache warms only the chunks of its
    block range (a chunk straddling a shard boundary may warm in two
    caches; block ranges and chunks are both contiguous so at most two).
    The per-shard budget is ``cache_bytes // nshards``, so total resident
    bytes stay under ``cache_bytes``.  Opening validates the published
    owner-function goldens for ``nshards`` when META pinned them —
    publisher/server ownership drift is misrouting, and fails loudly.
    """

    def __init__(self, root: str, nshards: int, cache_bytes: int = 1 << 20,
                 version: Optional[int] = None,
                 gen_neighbors: Optional[Callable] = None):
        if nshards < 1:
            raise ValueError("nshards must be >= 1")
        self.nshards = int(nshards)
        self.gen_neighbors = gen_neighbors
        per = max(1, int(cache_bytes) // self.nshards)
        self.shards = [DistanceOracle(root, cache_bytes=per, version=version,
                                      gen_neighbors=gen_neighbors)
                       for _ in range(self.nshards)]
        meta = self.shards[0].meta
        self.n_states = int(meta["n_states"])
        self.start = self.shards[0].start
        self.max_dist = self.shards[0].max_dist
        self.level_sizes = self.shards[0].level_sizes
        golden = meta.get("owner_golden", {}).get(str(self.nshards))
        if golden is not None:
            probe = np.asarray(meta["owner_probe"], np.int64)
            got = block_owner_np(probe, self.n_states,
                                 self.nshards).tolist()
            if got != golden:
                raise OracleError(
                    f"block owner function for nshards={self.nshards} "
                    f"disagrees with the published golden values "
                    f"({got} != {golden}) — routing would silently "
                    "misdirect queries")

    def codes(self, ranks: np.ndarray) -> np.ndarray:
        idx = np.asarray(ranks, np.int64).reshape(-1)
        if idx.size == 0:
            return np.zeros(0, np.uint8)
        # buckets.py bin-by-dest: stable argsort by owner, contiguous
        # slices per shard, scatter results back in input order.
        own = block_owner_np(idx, self.n_states, self.nshards)
        order = np.argsort(own, kind="stable")
        bounds = np.searchsorted(own[order], np.arange(self.nshards + 1))
        out = np.empty(idx.shape, np.uint8)
        for s in range(self.nshards):
            sel = order[bounds[s]:bounds[s + 1]]
            if sel.size:
                out[sel] = self.shards[s].codes(idx[sel])
        return out

    def distance(self, ranks: np.ndarray,
                 gen_neighbors: Optional[Callable] = None) -> np.ndarray:
        gen = gen_neighbors or self.gen_neighbors
        if gen is None:
            raise ValueError("distance queries need gen_neighbors")
        dist, _ = _descend(self.codes, gen, ranks, self.start,
                           self.max_dist, record=False)
        return dist

    lookup = distance

    def paths(self, ranks: np.ndarray,
              gen_neighbors: Optional[Callable] = None
              ) -> Tuple[np.ndarray, List[np.ndarray]]:
        gen = gen_neighbors or self.gen_neighbors
        if gen is None:
            raise ValueError("path queries need gen_neighbors")
        dist, chains = _descend(self.codes, gen, ranks, self.start,
                                self.max_dist, record=True)
        return dist, [np.asarray(ch, np.int64) for ch in chains]

    def close(self) -> None:
        for sh in self.shards:
            sh.close()

    def __enter__(self) -> "ShardedOracle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
