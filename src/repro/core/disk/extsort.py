"""External merge sort for chunked row stores (Tier D workhorse).

Roomy's removeDupes/removeAll are "dominated by the time to sort the list"
(paper §2); this module is that sort: chunk-sized in-RAM runs followed by a
blocked k-way merge whose unit of work is a numpy slice, never a Python row
loop over the whole data.

Rows are compared lexicographically. For streaming comparisons we view each
row as a big-endian byte string (``void`` scalar): bytewise order of
big-endian unsigned words == numeric lexicographic order, so np.searchsorted
on the void keys gives us merge boundaries for free.
"""
from __future__ import annotations

from typing import Callable, Iterator, List, Optional

import numpy as np

from .store import ChunkStore


def row_keys(rows: np.ndarray) -> np.ndarray:
    """(n,) fixed-length byte keys whose order == lexicographic row order.

    Big-endian unsigned words compared bytewise == numeric lexicographic
    order; numpy's 'S' dtype is ordered and searchsorted/isin-compatible.
    """
    w = rows.shape[1]
    be = np.ascontiguousarray(rows, dtype=">u4")
    return be.view(np.dtype(("S", 4 * w))).reshape(-1)


def sort_rows(rows: np.ndarray) -> np.ndarray:
    return rows[np.argsort(row_keys(rows), kind="stable")]


class _RunCursor:
    """Streaming cursor over the chunks of one sorted run."""

    def __init__(self, store: ChunkStore):
        self._it = store.iter_chunks()
        self.block: Optional[np.ndarray] = None
        self.keys: Optional[np.ndarray] = None
        self.pos = 0
        self._advance_block()

    def _advance_block(self) -> None:
        for blk in self._it:
            if blk.shape[0]:
                self.block = np.asarray(blk)
                self.keys = row_keys(self.block)
                self.pos = 0
                return
        self.block = None

    @property
    def alive(self) -> bool:
        return self.block is not None

    @property
    def head(self):
        return self.keys[self.pos]

    def take_until(self, bound) -> np.ndarray:
        """Pop and return rows with key <= bound (at least one row)."""
        j = int(np.searchsorted(self.keys[self.pos:], bound, side="right"))
        j = max(j, 1)                       # guarantee progress
        out = self.block[self.pos:self.pos + j]
        self.pos += j
        if self.pos >= self.block.shape[0]:
            self._advance_block()
        return out


def make_runs(src: ChunkStore, tmp_dir: str, run_rows: int) -> List[ChunkStore]:
    """Phase 1: cut src into sorted runs of ≤ run_rows rows each."""
    runs: List[ChunkStore] = []
    buf: List[np.ndarray] = []
    nbuf = 0

    def emit():
        nonlocal buf, nbuf
        if not nbuf:
            return
        rows = np.concatenate(buf, axis=0) if len(buf) > 1 else buf[0]
        run = ChunkStore(f"{tmp_dir}/run{len(runs):04d}", src.width,
                         src.dtype, src.chunk_rows, fresh=True)
        run.append(sort_rows(np.asarray(rows)))
        run.flush()
        runs.append(run)
        buf, nbuf = [], 0

    for chunk in src.iter_chunks():
        start = 0
        while start < chunk.shape[0]:
            take = min(run_rows - nbuf, chunk.shape[0] - start)
            buf.append(np.asarray(chunk[start:start + take]))
            nbuf += take
            start += take
            if nbuf >= run_rows:
                emit()
    emit()
    return runs


def merge_runs(runs: List[ChunkStore], out: ChunkStore,
               dedupe: bool = False) -> None:
    """Phase 2: blocked k-way merge of sorted runs into ``out``.

    With dedupe=True, equal rows collapse to one (needs a carry of the last
    emitted key across block boundaries).
    """
    cursors = [_RunCursor(r) for r in runs]
    last_key = None
    while True:
        alive = [c for c in cursors if c.alive]
        if not alive:
            break
        i = int(np.argmin([c.head for c in alive])) if len(alive) > 1 else 0
        src = alive[i]
        others = [c.head for j, c in enumerate(alive) if j != i]
        bound = min(others) if others else src.keys[-1]
        block = src.take_until(bound)
        if dedupe:
            keys = row_keys(block)
            keep = np.ones(block.shape[0], bool)
            keep[1:] = keys[1:] != keys[:-1]
            if last_key is not None and block.shape[0]:
                keep[0] &= keys[0] != last_key
            if block.shape[0]:
                last_key = keys[-1]
            block = block[keep]
        out.append(block)
    out.flush()


def external_sort(src: ChunkStore, out: ChunkStore, tmp_dir: str,
                  run_rows: int = 1 << 18, dedupe: bool = False) -> None:
    runs = make_runs(src, tmp_dir, run_rows)
    try:
        merge_runs(runs, out, dedupe=dedupe)
    finally:
        for r in runs:
            r.destroy()


def merge_difference(a_sorted: ChunkStore, b_sorted: ChunkStore,
                     out: ChunkStore) -> None:
    """out = rows of a not present in b (multiset removeAll; inputs sorted).

    Blocked merge-join: for each a-block, membership against the b-stream is
    decided with two searchsorted calls per overlapping b-block.
    """
    b_cur = _RunCursor(b_sorted)
    b_tail_keys: Optional[np.ndarray] = None

    for a_block in a_sorted.iter_chunks():
        a_block = np.asarray(a_block)
        if not a_block.shape[0]:
            continue
        a_keys = row_keys(a_block)
        member = np.zeros(a_block.shape[0], bool)
        # Pull b blocks while they can still overlap this a block.
        while True:
            if b_tail_keys is not None:
                member |= np.isin(a_keys, b_tail_keys)
                if b_tail_keys.size and b_tail_keys[-1] >= a_keys[-1]:
                    break
                b_tail_keys = None
            if not b_cur.alive:
                break
            blk = b_cur.take_until(b_cur.keys[-1])   # whole current block
            b_tail_keys = row_keys(np.asarray(blk))
        out.append(a_block[~member])
    out.flush()
