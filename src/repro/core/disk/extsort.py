"""External merge sort for chunked row stores (Tier D workhorse).

Roomy's removeDupes/removeAll are "dominated by the time to sort the list"
(paper §2); this module is that sort: chunk-sized in-RAM runs followed by a
blocked k-way merge whose unit of work is a numpy slice, never a Python row
loop over the whole data.

Rows are compared lexicographically. For streaming comparisons we view each
row as a big-endian byte string (``void`` scalar): bytewise order of
big-endian unsigned words == numeric lexicographic order, so np.searchsorted
on the void keys gives us merge boundaries for free.

Sort-once engine
----------------
Every full sort pass is counted in :data:`STATS`, and every function that
emits sorted output records the fact on the destination store
(``mark_sorted``).  Consumers honour the invariant: :func:`external_sort`
degrades to a copy (or a one-pass :func:`stream_dedupe`) when its input is
already sorted, and :class:`MembershipProbe` answers sorted-membership
queries against a sorted store while pruning chunks whose manifest key
range cannot intersect the query window.  The k-way merge itself is a
``heapq`` of ``(head_key, run_index)`` entries — O(log k) per block
selection instead of the O(k) argmin scan over all run heads.
"""
from __future__ import annotations

import heapq
from typing import Iterator, List, Optional

import numpy as np

from .. import obs
from .store import ChunkStore, row_keys

__all__ = [
    "STATS", "reset_stats", "row_keys", "sort_rows", "RunBuilder",
    "make_runs", "iter_merged", "merge_runs", "external_sort",
    "stream_dedupe", "MembershipProbe", "merge_difference",
    "segment_combine_ordered",
]


# Pass counters for the sort-once engine. ``sort_passes`` counts full
# sort passes (each make_runs / in-RAM sort of a dataset is one pass);
# ``rows_sorted`` the rows that went through them — the invariant tests
# assert a fused BFS level sorts exactly the raw frontier, once, and never
# the visited set. ``merge_passes`` counts streaming merges (reads, not
# sorts); ``sorts_skipped`` counts sorts avoided via the sorted invariant;
# ``chunks_pruned`` counts visited-set chunks skipped via manifest ranges.
# The pass planner (passes.py) books its fused traversals here too:
# ``rw_passes``/``read_passes`` per planned traversal of a chunked store,
# ``piggybacked_stages`` for every consumer stage that rode a producer's
# traversal instead of paying its own pass (the planner's savings, and the
# budget the implicit-BFS tests pin: ONE rw pass per level, zero extra).
# Checkpoint/restart I/O (disk/checkpoint.py) is booked ONLY under the
# ``ckpt_*`` counters — snapshot copies must never inflate the sort/merge/
# pass ledgers, so the per-level budgets hold with checkpointing on and a
# resumed run provably pays only the remaining levels' passes.  The
# fault-tolerance layer (disk/faults.py, cluster recovery) follows the same
# segregation rule: ``io_retries``/``io_giveups`` book transient-I/O retry
# outcomes, ``recoveries``/``replayed_levels`` book in-run rollbacks and the
# BFS levels re-run because of them, and ``stray_files_swept``/
# ``stray_bytes_swept`` book what the fresh=False startup sweep cleaned —
# none of which touch the sort/merge/pass ledgers, so the per-level pass
# budgets the CI gate pins hold for the non-replayed work.
STATS = obs.counters("extsort", {
    "sort_passes": 0, "rows_sorted": 0, "merge_passes": 0,
    "sorts_skipped": 0, "chunks_pruned": 0, "chunks_probed": 0,
    "rw_passes": 0, "read_passes": 0, "piggybacked_stages": 0,
    "ckpt_bytes_read": 0, "ckpt_bytes_written": 0,
    "ckpt_snapshots": 0, "ckpt_restores": 0,
    "io_retries": 0, "io_giveups": 0,
    "recoveries": 0, "replayed_levels": 0,
    "stray_files_swept": 0, "stray_bytes_swept": 0})


def reset_stats() -> None:
    for k in STATS:
        STATS[k] = 0


def sort_rows(rows: np.ndarray) -> np.ndarray:
    return rows[np.argsort(row_keys(rows), kind="stable")]


def segment_combine_ordered(ids: np.ndarray, vals: np.ndarray, combine):
    """Ordered combine-fold over runs of equal ids (ids non-decreasing).

    Returns (uniq_ids, agg) with agg[j] = the in-row-order fold of the vals
    whose id == uniq_ids[j] — the shared op-log merge kernel of the delayed
    syncs (darray/dhash/bitarray).  Runs are short in practice: the loop is
    over the longest run, each step a vectorized combine of every run's
    k-th element.
    """
    n = ids.shape[0]
    if n == 0:
        return ids[:0], vals[:0]
    starts = np.ones(n, bool)
    starts[1:] = ids[1:] != ids[:-1]
    seg = np.cumsum(starts) - 1
    uniq = ids[starts]
    agg = vals[starts].copy()
    pos = np.arange(n)
    run_pos = pos - np.maximum.accumulate(np.where(starts, pos, 0))
    for k in range(1, int(run_pos.max()) + 1):
        sel = run_pos == k
        if not sel.any():       # no gaps: run lengths only shrink with k
            break
        agg[seg[sel]] = combine(agg[seg[sel]], vals[sel])
    return uniq, agg


class _RunCursor:
    """Streaming cursor over the chunks of one sorted run."""

    def __init__(self, store: ChunkStore):
        self._it = store.iter_chunks()
        self.block: Optional[np.ndarray] = None
        self.keys: Optional[np.ndarray] = None
        self.pos = 0
        self._advance_block()

    def _advance_block(self) -> None:
        for blk in self._it:
            if blk.shape[0]:
                self.block = np.asarray(blk)
                self.keys = row_keys(self.block)
                self.pos = 0
                return
        self.block = None

    @property
    def alive(self) -> bool:
        return self.block is not None

    @property
    def head(self):
        return self.keys[self.pos]

    def take_until(self, bound) -> np.ndarray:
        """Pop and return rows with key <= bound (at least one row)."""
        j = int(np.searchsorted(self.keys[self.pos:], bound, side="right"))
        j = max(j, 1)                       # guarantee progress
        out = self.block[self.pos:self.pos + j]
        self.pos += j
        if self.pos >= self.block.shape[0]:
            self._advance_block()
        return out


class RunBuilder:
    """Phase 1 as a sink: feed rows in, get sorted runs of ≤ run_rows out.

    Streaming producers (e.g. the fused BFS expansion) push rows directly —
    the frontier is sorted run-at-a-time *as it is generated*, never
    written unsorted to disk and read back. This whole builder accounts as
    ONE sort pass over the rows it saw (counted at finish()).
    """

    def __init__(self, tmp_dir: str, width: int, dtype="uint32",
                 chunk_rows: int = 1 << 16, run_rows: int = 1 << 18,
                 codec: Optional[str] = None):
        self.tmp_dir = tmp_dir
        self.width = width
        self.dtype = dtype
        self.chunk_rows = chunk_rows
        self.run_rows = run_rows
        self.codec = codec
        self.runs: List[ChunkStore] = []
        self._buf: List[np.ndarray] = []
        self._nbuf = 0
        self._total = 0

    def add(self, rows: np.ndarray) -> None:
        rows = np.asarray(rows).reshape(-1, self.width)
        self._buf.append(rows)
        self._nbuf += rows.shape[0]
        self._total += rows.shape[0]
        while self._nbuf >= self.run_rows:
            self._emit(self.run_rows)

    def _emit(self, nrows: int) -> None:
        with obs.span("sort.run_build", rows=nrows, run=len(self.runs)):
            buf = (np.concatenate(self._buf, axis=0)
                   if len(self._buf) > 1 else self._buf[0])
            take, rest = buf[:nrows], buf[nrows:]
            run = ChunkStore(f"{self.tmp_dir}/run{len(self.runs):04d}",
                             self.width, self.dtype, self.chunk_rows,
                             fresh=True, codec=self.codec)
            run.append(sort_rows(np.asarray(take)))
            run.flush(mark_sorted=True)
            self.runs.append(run)
            self._buf = [rest] if rest.shape[0] else []
            self._nbuf = rest.shape[0]

    def finish(self) -> List[ChunkStore]:
        if self._nbuf:
            self._emit(self._nbuf)
        if self._total:                 # an empty pass sorted nothing
            STATS["sort_passes"] += 1
            STATS["rows_sorted"] += self._total
        return self.runs


def make_runs(src: ChunkStore, tmp_dir: str, run_rows: int) -> List[ChunkStore]:
    """Phase 1: cut src into sorted runs of ≤ run_rows rows each.

    This is the ONE sort pass the sort-once engine allows per dataset;
    it is counted in STATS and each emitted run is marked sorted.
    """
    builder = RunBuilder(tmp_dir, src.width, src.dtype, src.chunk_rows,
                         run_rows, codec=src.codec)
    for chunk in src.iter_chunks():
        builder.add(np.asarray(chunk))
    return builder.finish()


def iter_merged(runs: List[ChunkStore],
                dedupe: bool = False) -> Iterator[np.ndarray]:
    """Blocked k-way merge of sorted runs, yielding globally sorted blocks.

    A heap of (head_key, run_index) picks the cursor with the globally
    smallest head; that cursor's current *block max* becomes the batch
    bound. Every cursor whose head is ≤ the bound contributes its ≤-bound
    prefix (one searchsorted slice each), and the concatenated batch is
    sorted in RAM. Batches are therefore chunk-sized — heavily interleaved
    runs cost one vectorized sort per chunk, not one Python iteration per
    row (the naive emit-up-to-next-head merge degenerates to ~1-row blocks
    on uniformly interleaved runs). RAM stays O(k · chunk).

    With dedupe=True, equal rows collapse to one (a carry of the last
    emitted key crosses batch boundaries).
    """
    # The span covers the whole streaming merge; a consumer that abandons
    # the generator closes it via GeneratorExit, which still unwinds the
    # ``with`` (obs tolerates the resulting out-of-LIFO span ends).
    with obs.span("merge", runs=len(runs), dedupe=dedupe):
        STATS["merge_passes"] += 1
        cursors = [_RunCursor(r) for r in runs]
        heap = [(c.head, i) for i, c in enumerate(cursors) if c.alive]
        heapq.heapify(heap)
        last_key = None
        while heap:
            # Candidates: every cursor whose head could fall in this batch.
            _, i0 = heapq.heappop(heap)
            cand = [i0]
            while heap and heap[0][0] <= cursors[i0].keys[-1]:
                cand.append(heapq.heappop(heap)[1])
            # The batch bound is the smallest candidate block-max: each
            # candidate's ≤-bound prefix then lies entirely inside its
            # current block, so nothing below the bound can surface in a
            # later batch, and the min-block-max cursor drains a whole
            # block (progress).
            bound = min(cursors[i].keys[-1] for i in cand)
            parts = [cursors[i].take_until(bound)
                     for i in cand if cursors[i].head <= bound]
            for i in cand:
                if cursors[i].alive:
                    heapq.heappush(heap, (cursors[i].head, i))
            block = (np.concatenate(parts, axis=0)
                     if len(parts) > 1 else parts[0])
            if len(parts) > 1:
                block = sort_rows(block)
            if dedupe:
                keys = row_keys(block)
                keep = np.ones(block.shape[0], bool)
                keep[1:] = keys[1:] != keys[:-1]
                if last_key is not None and block.shape[0]:
                    keep[0] &= keys[0] != last_key
                if block.shape[0]:
                    last_key = keys[-1]
                block = block[keep]
            if block.shape[0]:
                yield block


def merge_runs(runs: List[ChunkStore], out: ChunkStore,
               dedupe: bool = False) -> None:
    """Phase 2: k-way merge of sorted runs into ``out`` (marked sorted)."""
    for block in iter_merged(runs, dedupe=dedupe):
        out.append(block)
    out.flush(mark_sorted=True)


def stream_dedupe(src_sorted: ChunkStore, out: ChunkStore) -> None:
    """One streaming pass collapsing equal adjacent rows of a sorted store.

    A 1-run merge: iter_merged already owns the dedupe carry logic, and
    routing through it keeps the STATS merge-pass accounting uniform.
    """
    merge_runs([src_sorted], out, dedupe=True)


def external_sort(src: ChunkStore, out: ChunkStore, tmp_dir: str,
                  run_rows: int = 1 << 18, dedupe: bool = False) -> None:
    """Sort src into out — skipped entirely when src already claims sorted.

    The sorted-input path is a streaming copy (or one dedupe pass), no
    comparison sort at all; the skip is counted in STATS["sorts_skipped"].
    """
    if src.sorted:
        STATS["sorts_skipped"] += 1
        if dedupe:
            stream_dedupe(src, out)
        else:
            for chunk in src.iter_chunks():
                out.append(np.asarray(chunk))
            out.flush(mark_sorted=True)
        return
    runs = make_runs(src, tmp_dir, run_rows)
    try:
        merge_runs(runs, out, dedupe=dedupe)
    finally:
        for r in runs:
            r.destroy()


class MembershipProbe:
    """Streaming membership tester against one sorted store.

    ``contains(qkeys)`` answers which of the (ascending) query keys occur
    in the store. Successive calls must present *disjoint, ascending*
    key windows: every key of call N+1 must be ≥ every key of call N —
    exactly the batches a merge pass emits. (Merely non-decreasing window
    *starts* are NOT enough: once a chunk falls wholly below a window it
    is skipped forever, so a later query reaching back below the previous
    window's end would silently miss.) The store is walked strictly
    forward and each chunk is loaded at most once per pass. Chunks whose
    manifest ``[min, max]`` range cannot intersect the current window are
    skipped without touching disk (STATS["chunks_pruned"]).

    Compressed stores get one level finer: a chunk's skip index
    (disk/codec.py) is binary-searched and only the blocks intersecting
    the query window are decoded.  The ``chunks_probed``/
    ``chunks_pruned`` ledgers count identically either way — the
    compressed ≡ uncompressed budget contract; block-level savings book
    under the separate ``codec`` namespace.
    """

    def __init__(self, store: ChunkStore):
        assert store.sorted, "MembershipProbe requires a sorted store"
        assert store._buf_rows == 0, "flush the store before probing"
        # row_keys views rows as big-endian uint32 words; any other dtype
        # would get silently truncated/misordered keys, so reject it.
        assert store.dtype.kind == "u" and store.dtype.itemsize == 4, \
            "MembershipProbe requires a 4-byte unsigned (keyed) store"
        self.store = store
        self._i = 0
        self._cached_i = -1
        self._cached_keys: Optional[np.ndarray] = None
        self._cached_reader = None

    def _keys(self, i: int) -> np.ndarray:
        if self._cached_i != i:
            self._cached_keys = row_keys(np.asarray(self.store.load_chunk(i)))
            self._cached_i = i
            STATS["chunks_probed"] += 1
        return self._cached_keys

    def _reader(self, i: int):
        if self._cached_i != i:
            self._cached_reader = self.store.key_reader(i)
            self._cached_i = i
            STATS["chunks_probed"] += 1
        return self._cached_reader

    def _range(self, i: int):
        return self.store.chunk_range(i)    # always present: keyed store

    @staticmethod
    def _q64(qkeys: np.ndarray) -> np.ndarray:
        """Byte keys → the uint64 key space of the compressed skip index
        (same order: big-endian bytes compare like the packed integer)."""
        w = qkeys.dtype.itemsize
        return np.frombuffer(qkeys.tobytes(),
                             ">u4" if w == 4 else ">u8").astype(np.uint64)

    def contains(self, qkeys: np.ndarray) -> np.ndarray:
        member = np.zeros(qkeys.shape[0], bool)
        if not qkeys.shape[0]:
            return member
        lo, hi = bytes(qkeys[0]), bytes(qkeys[-1])
        compressed = self.store.codec == "keys"
        q64 = self._q64(qkeys) if compressed else None
        n = self.store.n_chunks
        while self._i < n:
            rmin, rmax = self._range(self._i)
            if rmax < lo:                   # chunk wholly below the window:
                if self._cached_i != self._i:
                    STATS["chunks_pruned"] += 1
                self._i += 1                # queries only ascend — done with it
                continue
            if rmin > hi:                   # chunk wholly above: later windows
                break
            # Both sides are sorted: binary-search membership, no re-sorting
            # (np.isin would sort both arrays on every call).
            if compressed:
                # Decode only the skip-index blocks the window touches;
                # every stored key in [lo, hi] lives in one of them, so
                # membership over the decoded span is exact.
                rdr = self._reader(self._i)
                ck = rdr.keys_between(int(q64[0]), int(q64[-1]))
                pos = np.searchsorted(ck, q64)
                inb = pos < ck.shape[0]
                member[inb] |= ck[pos[inb]] == q64[inb]
            else:
                ck = self._keys(self._i)
                pos = np.searchsorted(ck, qkeys)
                inb = pos < ck.shape[0]
                member[inb] |= ck[pos[inb]] == qkeys[inb]
            if rmax >= hi:                  # chunk may overlap the next window
                break
            self._i += 1
        return member


def merge_difference(a_sorted: ChunkStore, b_sorted: ChunkStore,
                     out: ChunkStore) -> None:
    """out = rows of a not present in b (multiset removeAll; inputs sorted).

    One streaming pass over a; b is walked forward once via MembershipProbe,
    loading only b-chunks whose key range intersects a's. Output inherits
    a's sorted order.
    """
    with obs.span("merge", kind="difference"):
        STATS["merge_passes"] += 1
        probe = MembershipProbe(b_sorted)
        for a_block in a_sorted.iter_chunks():
            a_block = np.asarray(a_block)
            if not a_block.shape[0]:
                continue
            member = probe.contains(row_keys(a_block))
            out.append(a_block[~member])
        out.flush(mark_sorted=a_sorted.sorted)
