"""Out-of-core breadth-first search (Tier D) — the paper's flagship loop.

Same structure as the paper's §3 listing — expand, removeDupes, removeAll,
addAll, rotate — but run through the sort-once engine: the three per-level
list operations are fused into :func:`level_step`, a single streaming pass
that sorts the raw frontier ONCE (chunk-sized in-RAM runs), k-way merges
the runs with dedupe, subtracts the visited set via forward-walking
membership probes (manifest key ranges prune non-overlapping chunks), and
emits the surviving rows as a sorted run. That output *is* the new visited
run — fold-in is free — so the visited set (an LSM-style
:class:`~repro.core.disk.lsm.SortedRunSet`) is never re-sorted; it is only
geometrically re-merged every ``max_runs`` levels.

Pass accounting per level (asserted in tests/test_sort_once.py):
  sort passes            1      (the raw frontier, once)
  visited rows sorted    0      (probes read, never sort)

The unfused reference composition (``fused=False``) is retained for
equivalence tests and benchmarking; with the DiskList sortedness
invariant it pays 2 external sort passes per level, one of which
re-sorts the entire visited set.

A second, rank-indexed engine lives in :func:`implicit_bfs`: states are
indices into a 2-bit :class:`~repro.core.disk.bitarray.DiskBitArray`
(UNSEEN/CUR/NEXT/DONE) and a level is ONE fused read-write pass with no
sorting at all — the expand read piggybacks on the mark/rotate write via
the pass planner (passes.py) — the paper's actual pancake construction.
See docs/architecture.md "Two BFS representations" for when each
engine wins.
"""
from __future__ import annotations

import os
import shutil
from typing import Callable, List

import numpy as np

from .. import obs
from . import checkpoint as ckpt
from . import extsort
from .bitarray import CUR, DONE, NEXT, UNSEEN, DiskBitArray
from .checkpoint import SearchCheckpoint
from .config import _UNSET, resolve_configs
from .dlist import DiskList
from .lsm import SortedRunSet
from .passes import PassPlan
from .store import ChunkStore, row_keys


def level_step(raw: ChunkStore, all_runs: List[ChunkStore], out: ChunkStore,
               tmp_dir: str, run_rows: int = 1 << 18,
               probe_rows: int = 1 << 14) -> None:
    """Fused removeDupes → removeAll → addAll: one sort pass over ``raw``.

    raw:      unsorted frontier expansion (consumed read-only).
    all_runs: sorted visited-set runs (read forward once each, with
              chunk-range pruning; never sorted).
    out:      receives the deduped, unvisited frontier — sorted and marked
              so, ready to be add_run() into the visited SortedRunSet.

    Merged blocks are accumulated to ~probe_rows before the visited-set
    probes run: the k-way merge can emit tiny blocks when runs interleave
    heavily, and probing per tiny block would swamp the fusion win with
    per-call overhead. Batching keeps the probes' windows non-decreasing,
    so the forward-only walk still holds.
    """
    runs = extsort.make_runs(raw, tmp_dir, run_rows)
    try:
        _merge_subtract(runs, all_runs, out, probe_rows)
    finally:
        for r in runs:
            r.destroy()


def _merge_subtract(frontier_runs: List[ChunkStore],
                    all_runs: List[ChunkStore], out: ChunkStore,
                    probe_rows: int = 1 << 14) -> None:
    """Merge+dedupe the frontier runs, subtracting the visited runs in
    stream; emits sorted unique unvisited rows into ``out``."""
    probes = [extsort.MembershipProbe(r) for r in all_runs]
    batch: List[np.ndarray] = []
    batch_rows = 0

    def subtract_emit():
        nonlocal batch, batch_rows
        if not batch_rows:
            return
        rows = np.concatenate(batch, axis=0) if len(batch) > 1 else batch[0]
        batch, batch_rows = [], 0
        member = np.zeros(rows.shape[0], bool)
        if probes:
            keys = row_keys(rows)
            for p in probes:
                member |= p.contains(keys)
        out.append(rows[~member])

    for block in extsort.iter_merged(frontier_runs, dedupe=True):
        batch.append(block)
        batch_rows += block.shape[0]
        if batch_rows >= probe_rows:
            subtract_emit()
    subtract_emit()
    out.flush(mark_sorted=True)


def _sharded_runtime(workdir: str, cluster):
    """Resolve the (runtime, owns_it) pair for a sharded engine call from
    a validated :class:`~.config.ClusterConfig` (conflict checking lives
    in config.resolve_configs, the one shared checker)."""
    return cluster.build_runtime(workdir)


def _ckpt_sorted(ck: SearchCheckpoint, all_runs: SortedRunSet,
                 cur: ChunkStore, level_sizes: List[int], width: int,
                 prev: dict) -> None:
    """Publish one single-process sorted-engine checkpoint (end of level).

    ``prev`` carries {dir, names} of THIS search's previous published
    snapshot so unchanged runs hard-link instead of re-copying
    (checkpoint.snapshot_sorted_state's incremental rule); it is updated
    in place after a successful publish."""
    version = ck.next_version()
    stage = ck.begin(version)
    state = ckpt.snapshot_sorted_state(stage, all_runs, cur,
                                       prev_dir=prev.get("dir"),
                                       prev_names=prev.get("names"))
    sealed = ck.publish(
        version, {"engine": "sorted", "sharded": False, "nshards": 1,
                  "width": width, "n_states": 0,
                  "level_sizes": list(level_sizes),
                  "golden": ckpt.golden_owner_values(1, width, 0),
                  # Optional codec marker (format negotiation,
                  # docs/compression.md): absent/None == raw, so
                  # pre-compression checkpoints keep opening unchanged.
                  "codec": cur.codec,
                  "state": state})
    prev["dir"], prev["names"] = sealed, set(state["runs"])


def _ckpt_implicit(ck: SearchCheckpoint, bits: DiskBitArray,
                   level_sizes: List[int], n_states: int) -> None:
    """Publish one single-process implicit-engine checkpoint: the rotated
    array plus the op logs holding the NEXT level's queued marks."""
    version = ck.next_version()
    stage = ck.begin(version)
    state = ckpt.snapshot_implicit_state(stage, bits)
    ck.publish(version, {"engine": "implicit", "sharded": False,
                         "nshards": 1, "width": 1, "n_states": n_states,
                         "level_sizes": list(level_sizes),
                         "golden": ckpt.golden_owner_values(1, 1, n_states),
                         "codec": "rle2" if bits.compress else None,
                         "state": state})


def breadth_first_search(
    workdir: str,
    start_rows: np.ndarray,
    gen_next: Callable[[np.ndarray], np.ndarray],
    width: int,
    chunk_rows: int = 1 << 16,
    max_levels: int = 10_000,
    fused: bool = True,
    run_rows: int = 1 << 18,
    max_runs: int = 8,
    compaction: str = "full",
    size_ratio: int = 2,
    compress: bool = False,
    cluster=None,
    checkpoint=None,
    recovery=None,
    nshards=_UNSET,
    runtime=_UNSET,
    shard_mode=_UNSET,
    bucket_capacity=_UNSET,
    checkpoint_dir=_UNSET,
    checkpoint_every=_UNSET,
    resume=_UNSET,
    max_recoveries=_UNSET,
):
    """gen_next(chunk (m, width)) -> neighbor rows (m*fanout, width).

    Cluster shape, checkpointing, and recovery are configured with the
    consolidated config objects (disk/config.py)::

        disk.breadth_first_search(wd, start, gen, width,
            cluster=ClusterConfig(nshards=4, transport="tcp",
                                  exchange="pipelined"),
            checkpoint=CheckpointConfig(dir=ck, every=2),
            recovery=RecoveryConfig(max_recoveries=3))

    The pre-config keyword spellings (``nshards=``, ``shard_mode=``,
    ``bucket_capacity=``, ``runtime=``, ``checkpoint_dir=``,
    ``checkpoint_every=``, ``resume=``, ``max_recoveries=``) keep working
    for one release via a deprecation shim that maps them onto the same
    configs and warns once.

    Returns (level_sizes, all). With fused=True (default), ``all`` is the
    visited SortedRunSet; with fused=False (the reference composition used
    by equivalence tests/benchmarks), a DiskList. Both expose
    size/read_all/destroy. start_rows are treated as a set (duplicate
    seeds collapse) on both paths. ``compaction``/``size_ratio`` select the
    visited-set compaction policy (lsm.py: "full" re-merges everything,
    "tiered" only comparable-size runs).

    ``compress=True`` stores every sorted run varint-delta-compressed
    (disk/codec.py, docs/compression.md): identical level counts and
    sort/pass budgets, fewer stored bytes per level.  Resume works
    across the compressed/uncompressed boundary in both directions —
    restored runs keep their checkpointed format (per-run manifests),
    new runs use this flag.  Fused engine only.

    With ``nshards > 1`` (or an explicit cluster.ShardRuntime via
    ``runtime=``) the search runs distributed: states partition by
    ``hash_owner``, every shard pays the fused per-level budget (one sort
    pass over ITS raw frontier) on its own partition, and cross-shard
    expansion rows travel through the disk bucket exchange.  Level counts
    are identical to the single-process engine for any nshards.  In
    spawn mode ``gen_next`` must be picklable; ``shard_mode="inline"``
    runs the same protocol in-process (closure-friendly).

    ``checkpoint_dir=`` enables durable checkpoint/restart
    (disk/checkpoint.py, format in docs/checkpointing.md): every
    ``checkpoint_every`` completed levels the visited run set and the
    frontier are snapshotted with the atomic-publish discipline, so a
    killed search resumes (``resume=True``) from its last checkpoint with
    level counts identical to an uninterrupted run, paying only the
    remaining levels' sort passes (checkpoint I/O is booked under the
    separate ``ckpt_*`` STATS counters).  ``resume=True`` with no
    published checkpoint starts fresh; a corrupt or structurally
    mismatched checkpoint raises
    :class:`~repro.core.disk.checkpoint.CheckpointError`.  Checkpointing
    requires the fused engine.

    ``max_recoveries=`` > 0 (sharded runs only) arms in-run self-healing:
    worker death, collective timeout, or a fatal I/O error rolls every
    shard back to the last coordinated checkpoint and replays, up to the
    budget; an unrecoverable failure raises a structured
    :class:`~repro.core.disk.cluster.ShardFailure` (docs/fault-tolerance.md).
    """
    cl, cp, rec = resolve_configs(
        "breadth_first_search", cluster=cluster, checkpoint=checkpoint,
        recovery=recovery, fused=fused, nshards=nshards, runtime=runtime,
        shard_mode=shard_mode, bucket_capacity=bucket_capacity,
        checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
        resume=resume, max_recoveries=max_recoveries)
    checkpoint_dir, checkpoint_every, resume = cp.dir, cp.every, cp.resume
    if cl.sharded:
        from .cluster import sharded_bfs
        rt, own = _sharded_runtime(workdir, cl)
        sizes, handle = sharded_bfs(
            rt, start_rows, gen_next, width, chunk_rows=chunk_rows,
            max_levels=max_levels, run_rows=run_rows, max_runs=max_runs,
            compaction=compaction, size_ratio=size_ratio, compress=compress,
            bucket_capacity=cl.bucket_capacity, checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every, resume=resume,
            max_recoveries=rec.max_recoveries)
        handle._own_runtime = own
        return sizes, handle
    if not fused:
        assert not compress, "compress=True requires the fused engine"
        return _breadth_first_search_unfused(
            workdir, start_rows, gen_next, width, chunk_rows, max_levels)

    # One scratch dir for every level's sort runs (run stores are destroyed
    # each level; reusing the parent avoids leaking one empty dir per level).
    tmp_dir = os.path.join(workdir, "bfs_tmp")
    codec = "keys" if compress else None
    all_runs = SortedRunSet(workdir, width, chunk_rows, max_runs=max_runs,
                            name="bfs_all", policy=compaction,
                            size_ratio=size_ratio, codec=codec)
    ck = SearchCheckpoint(checkpoint_dir) if checkpoint_dir else None
    ck_prev: dict = {}
    state = ck.latest() if (ck is not None and resume) else None
    if state is not None:
        ckpt.validate_resume(state, "sorted", 1, width, 0, sharded=False)
        cur = ckpt.restore_sorted_state(ck.snapshot_dir(state),
                                        state["state"], all_runs, workdir,
                                        width, chunk_rows)
        assert cur is not None, "single-process checkpoint lost its frontier"
        level_sizes: List[int] = [int(x) for x in state["level_sizes"]]
        start_lev = len(level_sizes)
    else:
        start_rows = np.asarray(start_rows, np.uint32).reshape(-1, width)
        seed = ChunkStore(os.path.join(workdir, "bfs_seed"), width,
                          chunk_rows=chunk_rows, fresh=True)
        seed.append(start_rows)
        seed.flush()
        cur = ChunkStore(os.path.join(workdir, "bfs_lev0"), width,
                         chunk_rows=chunk_rows, fresh=True, codec=codec)
        extsort.external_sort(seed, cur, tmp_dir, run_rows=run_rows,
                              dedupe=True)
        seed.destroy()
        all_runs.add_run(cur)
        level_sizes = [cur.size]
        if cur.size == 0:
            shutil.rmtree(tmp_dir, ignore_errors=True)
            return [], all_runs
        start_lev = 1
        if ck is not None:      # level-0 snapshot: any kill is resumable
            _ckpt_sorted(ck, all_runs, cur, level_sizes, width, ck_prev)
    for lev in range(start_lev, max_levels + 1):
        with obs.span("bfs.level", level=lev, engine="sorted",
                      frontier=cur.size):
            # Expansion streams straight into sorted run construction: the
            # raw frontier is never written unsorted to disk and read back
            # (the one sort pass happens as the neighbours are generated).
            builder = extsort.RunBuilder(tmp_dir, width,
                                         chunk_rows=chunk_rows,
                                         run_rows=run_rows, codec=codec)
            for chunk in cur.iter_chunks():
                builder.add(gen_next(np.asarray(chunk)))
            runs = builder.finish()
            # cur is fully consumed; compaction may now merge (and destroy)
            # it.
            all_runs.maybe_compact()
            nxt = ChunkStore(os.path.join(workdir, f"bfs_lev{lev}"), width,
                             chunk_rows=chunk_rows, fresh=True, codec=codec)
            try:
                _merge_subtract(runs, all_runs.runs, nxt)
            finally:
                for r in runs:
                    r.destroy()
            if nxt.size == 0:
                nxt.destroy()
                empty = True
            else:
                empty = False
                all_runs.add_run(nxt)
                cur = nxt
                level_sizes.append(cur.size)
                if ck is not None and lev % checkpoint_every == 0:
                    _ckpt_sorted(ck, all_runs, cur, level_sizes, width,
                                 ck_prev)
        if empty:
            break
    shutil.rmtree(tmp_dir, ignore_errors=True)
    return level_sizes, all_runs


def implicit_bfs(
    workdir: str,
    n_states: int,
    start_idx,
    gen_neighbors: Callable[[np.ndarray], np.ndarray],
    chunk_elems: int = 1 << 22,
    max_levels: int = 10_000,
    expand_batch: int = 1 << 16,
    log_buf_rows: int = 1 << 20,
    fused: bool = True,
    compress: bool = False,
    cluster=None,
    checkpoint=None,
    recovery=None,
    nshards=_UNSET,
    runtime=_UNSET,
    shard_mode=_UNSET,
    bucket_capacity=_UNSET,
    checkpoint_dir=_UNSET,
    checkpoint_every=_UNSET,
    resume=_UNSET,
    max_recoveries=_UNSET,
):
    """The paper's *second* BFS engine: implicit search over a 2-bit array.

    Cluster shape, checkpointing, and recovery ride the same consolidated
    config objects as :func:`breadth_first_search` (``cluster=``,
    ``checkpoint=``, ``recovery=`` — disk/config.py); the pre-config
    keyword spellings keep working for one release via the warn-once
    deprecation shim.

    Instead of sorted frontier lists keyed by state rows, every state is an
    index into a :class:`DiskBitArray` of ``n_states`` 2-bit elements
    (UNSEEN/CUR/NEXT/DONE) — for permutation state spaces the index is the
    Myrvold–Ruskey rank (core/ranking.py).  With ``fused=True`` (default) a
    level is ONE fused read-write pass, planned through passes.PassPlan,
    and ZERO sorts or duplicate-elimination passes:

      level pass   per chunk: apply the previous level's queued marks
                   (UNSEEN→NEXT — any other state absorbs the mark, which
                   *is* the duplicate / visited elimination), rotate
                   CUR→DONE, NEXT→CUR, count the new frontier, and expand
                   the freshly rotated CUR states — the expand read
                   piggybacks on the mark/rotate write, so the array is
                   traversed once per level instead of twice.  Marks the
                   expansion queues are snapshot-isolated to the NEXT pass
                   (batched to owner chunks by the bit array, spilled to
                   disk past ``log_buf_rows``).

    ``fused=False`` keeps the two-pass reference composition (a separate
    expand read pass before each mark/rotate read-write pass) for
    equivalence tests and benchmarking.

    gen_neighbors(idx (m,) int64) -> (m, fanout) int64 neighbor indices.

    Memory is O(chunk + expand_batch·fanout) regardless of frontier size;
    disk is n_states/4 bytes + queued marks.  Wins over the sorted-list
    engine when levels are a large fraction of the state space (see
    docs/architecture.md "Two BFS representations"); completes 9! states
    where the single-word sorted encodings stop at 8!.

    Returns (level_sizes, bits) — ``bits`` holds the final DONE marks
    (distance parity is not recoverable; level_sizes is the histogram).

    With ``nshards > 1`` (or ``runtime=``) the 2-bit array is
    block-distributed over shard workers (``sharding.block_owner``); each
    shard still pays exactly ONE fused read-write pass over ITS block per
    level, and cross-shard marks ride the disk bucket exchange into the
    owner's snapshot-isolated op log.  Level counts match the
    single-process engine for any nshards.  In spawn mode
    ``gen_neighbors`` must be picklable; ``shard_mode="inline"`` runs the
    protocol in-process.

    ``checkpoint_dir=`` / ``checkpoint_every=`` / ``resume=`` enable
    durable checkpoint/restart exactly as in
    :func:`breadth_first_search`: a snapshot captures the rotated 2-bit
    array AND the op logs holding the next level's queued marks, so a
    resumed run continues mid-search with identical level counts and only
    the remaining levels' array passes (fused engine only; the chunk
    layout is pinned by the checkpoint — on resume the snapshot's
    ``chunk_elems`` wins over the argument).

    ``max_recoveries=`` > 0 (sharded runs only) arms in-run self-healing
    from the coordinated checkpoints, exactly as in
    :func:`breadth_first_search`.
    """
    cl, cp, rec = resolve_configs(
        "implicit_bfs", cluster=cluster, checkpoint=checkpoint,
        recovery=recovery, fused=fused, nshards=nshards, runtime=runtime,
        shard_mode=shard_mode, bucket_capacity=bucket_capacity,
        checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
        resume=resume, max_recoveries=max_recoveries)
    checkpoint_dir, checkpoint_every, resume = cp.dir, cp.every, cp.resume
    if cl.sharded:
        from .cluster import sharded_implicit_bfs
        rt, own = _sharded_runtime(workdir, cl)
        sizes, handle = sharded_implicit_bfs(
            rt, n_states, start_idx, gen_neighbors, chunk_elems=chunk_elems,
            max_levels=max_levels, expand_batch=expand_batch,
            log_buf_rows=log_buf_rows, compress=compress,
            bucket_capacity=cl.bucket_capacity,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            resume=resume, max_recoveries=rec.max_recoveries)
        handle._own_runtime = own
        return sizes, handle
    ck = SearchCheckpoint(checkpoint_dir) if checkpoint_dir else None
    state = ck.latest() if (ck is not None and resume) else None
    if state is not None:
        ckpt.validate_resume(state, "implicit", 1, 1, n_states,
                             sharded=False)
        # The snapshot pins the chunk layout: adopt with ITS chunk_elems.
        chunk_elems = int(state["state"]["chunk_elems"])
    # On resume every chunk arrives from the snapshot: skip the zero-fill
    # (writing n/4 bytes of zeros just to overwrite them).
    bits = DiskBitArray(workdir, n_states, chunk_elems=chunk_elems,
                        name="bfs_bits", log_buf_rows=log_buf_rows,
                        init_chunks=state is None, compress=compress)

    def expand(chunk_start: int, vals: np.ndarray) -> None:
        (cur_pos,) = np.nonzero(vals == CUR)
        for lo in range(0, cur_pos.size, expand_batch):
            idx = chunk_start + cur_pos[lo:lo + expand_batch].astype(np.int64)
            nbrs = np.asarray(gen_neighbors(idx), np.int64).reshape(-1)
            bits.update(nbrs, np.full(nbrs.shape, NEXT, np.uint8))

    if not fused:
        start = np.unique(np.asarray(start_idx, np.int64).reshape(-1))
        assert start.size and start.min() >= 0 and start.max() < n_states
        bits.update(start, np.full(start.shape, CUR, np.uint8))
        return _implicit_bfs_unfused(bits, start, expand, max_levels)

    nxt_count = 0

    def count_cur(chunk_start: int, vals: np.ndarray) -> None:
        nonlocal nxt_count
        nxt_count += int(np.count_nonzero(vals == CUR))

    def rotate(chunk_start: int, vals: np.ndarray) -> np.ndarray:
        vals = np.where(vals == CUR, np.uint8(DONE), vals)
        return np.where(vals == NEXT, np.uint8(CUR), vals)

    if state is not None:
        ckpt.restore_implicit_state(ck.snapshot_dir(state), bits)
        level_sizes: List[int] = [int(x) for x in state["level_sizes"]]
    else:
        start = np.unique(np.asarray(start_idx, np.int64).reshape(-1))
        assert start.size and start.min() >= 0 and start.max() < n_states
        bits.update(start, np.full(start.shape, CUR, np.uint8))
        # Pass 0: apply the seed marks (overwrite), count them, and expand
        # them — the level-1 expand read already rides the seed write pass.
        # The array is freshly zeroed, so CUR can only exist in the seeds'
        # (dirty) chunks: dirty_only skips the guaranteed-no-op read of the
        # rest.
        bits.run_pass(PassPlan("bfs-seed", dirty_only=True)
                      .reads(count_cur).reads(expand))
        level_sizes = [nxt_count]
        if ck is not None:      # level-0 snapshot: any kill is resumable
            _ckpt_implicit(ck, bits, level_sizes, n_states)
    lev = len(level_sizes) - 1          # highest level already counted
    while lev < max_levels:
        with obs.span("bfs.level", level=lev + 1, engine="implicit"):
            nxt_count = 0
            # One fused read-write pass: marks from the previous expansion
            # apply (UNSEEN→NEXT), the chunk rotates, the new frontier is
            # counted, and its expansion queues marks for the NEXT pass.
            bits.run_pass(
                PassPlan("bfs-level").writes(rotate).reads(count_cur)
                .reads(expand),
                combine=lambda p, q: p,        # every mark payload == NEXT
                apply=lambda old, agg: np.where(old == UNSEEN, agg, old))
            if nxt_count:
                level_sizes.append(nxt_count)
                lev += 1
                if ck is not None and lev % checkpoint_every == 0:
                    _ckpt_implicit(ck, bits, level_sizes, n_states)
        if nxt_count == 0:
            break
    return level_sizes, bits


def _implicit_bfs_unfused(bits: DiskBitArray, start: np.ndarray,
                          expand: Callable, max_levels: int):
    """Reference composition: separate expand read pass + mark/rotate
    read-write pass per level (the pre-planner two-pass structure, kept
    for equivalence tests and the passes-per-level benchmark)."""
    bits.sync()                                   # overwrite: seeds → CUR
    level_sizes: List[int] = [int(start.size)]
    for _ in range(max_levels):
        bits.map_chunks(expand)
        nxt_count = 0

        def mark_rotate(chunk_start: int, vals: np.ndarray) -> np.ndarray:
            nonlocal nxt_count
            vals = np.where(vals == CUR, np.uint8(DONE), vals)
            vals = np.where(vals == NEXT, np.uint8(CUR), vals)
            nxt_count += int(np.count_nonzero(vals == CUR))
            return vals

        bits.sync(combine=lambda p, q: p,          # every mark payload == NEXT
                  apply=lambda old, agg: np.where(old == UNSEEN, agg, old),
                  transform=mark_rotate)
        if nxt_count == 0:
            break
        level_sizes.append(nxt_count)
    return level_sizes, bits


def _breadth_first_search_unfused(
    workdir: str,
    start_rows: np.ndarray,
    gen_next: Callable[[np.ndarray], np.ndarray],
    width: int,
    chunk_rows: int = 1 << 16,
    max_levels: int = 10_000,
):
    """Reference path: the paper's literal removeDupes/removeAll/addAll
    composition (2 sort passes per level, visited set re-sorted each
    level)."""
    start_rows = np.asarray(start_rows, np.uint32).reshape(-1, width)
    # Seed treated as a set, matching the fused path (which dedupes via its
    # initial external sort) so the two are element-wise equivalent.
    start_rows = np.unique(start_rows, axis=0)
    all_lst = DiskList(workdir, width, chunk_rows, name="bfs_all")
    cur = DiskList(workdir, width, chunk_rows, name="bfs_lev0")
    all_lst.add(start_rows)
    cur.add(start_rows)

    level_sizes: List[int] = [cur.size()]
    for lev in range(1, max_levels + 1):
        if cur.size() == 0:
            level_sizes.pop()
            break
        nxt = DiskList(workdir, width, chunk_rows, name=f"bfs_lev{lev}")
        cur.map_chunks(lambda chunk: nxt.add(gen_next(chunk)))
        nxt.remove_dupes()
        nxt.remove_all(all_lst)
        all_lst.add_all(nxt)
        cur.destroy()
        cur = nxt
        level_sizes.append(cur.size())
        if cur.size() == 0:
            level_sizes.pop()
            break
    cur.destroy()
    return level_sizes, all_lst
