"""Out-of-core breadth-first search (Tier D) — the paper's flagship loop.

Identical structure to the paper's §3 listing: expand the current level
into ``next`` via a user generator, removeDupes within the level, removeAll
against ``all``, addAll into ``all``, rotate. Every phase is a streaming
disk pass; RAM stays O(chunk) regardless of frontier size.
"""
from __future__ import annotations

from typing import Callable, List

import numpy as np

from .dlist import DiskList


def breadth_first_search(
    workdir: str,
    start_rows: np.ndarray,
    gen_next: Callable[[np.ndarray], np.ndarray],
    width: int,
    chunk_rows: int = 1 << 16,
    max_levels: int = 10_000,
):
    """gen_next(chunk (m, width)) -> neighbor rows (m*fanout, width).

    Returns (level_sizes, all_list).
    """
    start_rows = np.asarray(start_rows, np.uint32).reshape(-1, width)
    all_lst = DiskList(workdir, width, chunk_rows, name="bfs_all")
    cur = DiskList(workdir, width, chunk_rows, name="bfs_lev0")
    all_lst.add(start_rows)
    cur.add(start_rows)

    level_sizes: List[int] = [cur.size()]
    for lev in range(1, max_levels + 1):
        if cur.size() == 0:
            level_sizes.pop()
            break
        nxt = DiskList(workdir, width, chunk_rows, name=f"bfs_lev{lev}")
        cur.map_chunks(lambda chunk: nxt.add(gen_next(chunk)))
        nxt.remove_dupes()
        nxt.remove_all(all_lst)
        all_lst.add_all(nxt)
        cur.destroy()
        cur = nxt
        level_sizes.append(cur.size())
        if cur.size() == 0:
            level_sizes.pop()
            break
    cur.destroy()
    return level_sizes, all_lst
