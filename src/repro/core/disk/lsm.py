"""LSM-style collection of sorted runs — the sort-once visited set.

The naive BFS loop re-sorts the entire visited set ``all`` on every level
(``remove_all`` externally sorts both operands), paying O(levels × |all|)
redundant sort work. A :class:`SortedRunSet` instead keeps ``all`` as a
stack of sorted, mutually disjoint runs — one per BFS level — and only
merges them *geometrically*: when the run count exceeds ``max_runs`` the
runs are k-way merged (a read pass, never a comparison sort) into a single
run. Amortized, each element is merged O(levels / max_runs) times instead
of being re-sorted every level.

Runs are appended via :meth:`add_run` and must individually satisfy the
ChunkStore sortedness invariant (``store.sorted``); ownership transfers to
the run set (compaction and :meth:`destroy` will destroy them).
"""
from __future__ import annotations

import os
import uuid
from typing import Iterator, List

import numpy as np

from . import extsort
from .store import ChunkStore


class SortedRunSet:
    def __init__(self, workdir: str, width: int, chunk_rows: int = 1 << 16,
                 max_runs: int = 8, name: str | None = None):
        self.workdir = workdir
        self.width = width
        self.chunk_rows = chunk_rows
        self.max_runs = max_runs
        self.name = name or f"runset_{uuid.uuid4().hex[:8]}"
        self.runs: List[ChunkStore] = []
        self._seq = 0

    # ---------------------------------------------------------- mutation
    def add_run(self, store: ChunkStore) -> None:
        """Fold a sorted run in (ownership moves here). O(1) — no merge."""
        assert store.sorted, "SortedRunSet.add_run requires a sorted store"
        self.runs.append(store)

    def maybe_compact(self) -> bool:
        """Geometric merge: collapse all runs into one when count > max_runs.

        A k-way merge pass (dedupe=True — runs are sets), not a sort; the
        invariant tests assert STATS["sort_passes"] stays 0 here. Returns
        True if a compaction happened (callers holding references to member
        runs must re-read self.runs afterwards).
        """
        if len(self.runs) <= self.max_runs:
            return False
        merged = ChunkStore(
            os.path.join(self.workdir, f"{self.name}.compact{self._seq}"),
            self.width, chunk_rows=self.chunk_rows, fresh=True)
        self._seq += 1
        extsort.merge_runs(self.runs, merged, dedupe=True)
        for r in self.runs:
            r.destroy()
        self.runs = [merged]
        return True

    # -------------------------------------------------------------- read
    def size(self) -> int:
        """Total rows across runs (exact when runs are disjoint, as in BFS)."""
        return sum(r.size for r in self.runs)

    def iter_sorted(self) -> Iterator[np.ndarray]:
        """Globally sorted, deduped blocks across all runs (one merge pass)."""
        return extsort.iter_merged(self.runs, dedupe=True)

    def read_all(self) -> np.ndarray:
        """Materialize the merged unique rows (tests/small data only)."""
        blocks = list(self.iter_sorted())
        if not blocks:
            return np.zeros((0, self.width), np.uint32)
        return np.concatenate(blocks, axis=0)

    def destroy(self) -> None:
        for r in self.runs:
            r.destroy()
        self.runs = []
