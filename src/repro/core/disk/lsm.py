"""LSM-style collection of sorted runs — the sort-once visited set.

The naive BFS loop re-sorts the entire visited set ``all`` on every level
(``remove_all`` externally sorts both operands), paying O(levels × |all|)
redundant sort work. A :class:`SortedRunSet` instead keeps ``all`` as a
stack of sorted, mutually disjoint runs — one per BFS level — and only
merges them *geometrically*: when the run count exceeds ``max_runs`` the
runs are k-way merged (a read pass, never a comparison sort) into a single
run. Amortized, each element is merged O(levels / max_runs) times instead
of being re-sorted every level.

Runs are appended via :meth:`add_run` and must individually satisfy the
ChunkStore sortedness invariant (``store.sorted``); ownership transfers to
the run set (compaction and :meth:`destroy` will destroy them).

Compaction policies (the ROADMAP follow-up):

  ``full``    (default) collapse ALL runs into one — every element pays
              one merge per compaction, including the big old runs.
  ``tiered``  size-ratio compaction: merge only the smallest runs — at
              least enough to get back under ``max_runs``, then keep
              absorbing the next-smallest run while it is no bigger than
              ``size_ratio`` × the accumulated merge. Large settled runs
              are left untouched, cutting re-merge write amplification
              from O(levels/max_runs) per element toward O(log levels).
"""
from __future__ import annotations

import os
import uuid
from typing import Iterator, List

import numpy as np

from .. import obs
from . import extsort
from .store import ChunkStore


class SortedRunSet:
    def __init__(self, workdir: str, width: int, chunk_rows: int = 1 << 16,
                 max_runs: int = 8, name: str | None = None,
                 policy: str = "full", size_ratio: int = 2,
                 codec: str | None = None):
        assert policy in ("full", "tiered"), policy
        self.workdir = workdir
        self.width = width
        self.chunk_rows = chunk_rows
        self.max_runs = max_runs
        self.policy = policy
        self.size_ratio = size_ratio
        # Compaction OUTPUT format.  Adopted/added runs keep whatever
        # format their manifest claims (checkpoint-restored runs may
        # differ — mixed run sets are fine, load_chunk decodes), but
        # every merge this set performs re-encodes into ``codec``.
        self.codec = codec
        self.name = name or f"runset_{uuid.uuid4().hex[:8]}"
        self.runs: List[ChunkStore] = []
        self._seq = 0

    # ---------------------------------------------------------- mutation
    def adopt_runs(self, runs: List[ChunkStore], seq: int) -> None:
        """Adopt a restored run stack wholesale (checkpoint/restart path).

        ``seq`` must be the compaction sequence recorded at snapshot time:
        compaction output dirs are named ``{name}.compact{seq}`` with
        ``fresh=True``, so replaying from a smaller seq could wipe a live
        run directory.  Every adopted run must hold the sortedness claim.
        """
        assert not self.runs, "adopt_runs on a non-empty run set"
        for r in runs:
            assert r.sorted, "adopt_runs requires sorted stores"
        self.runs = list(runs)
        self._seq = max(self._seq, int(seq))

    def add_run(self, store: ChunkStore) -> None:
        """Fold a sorted run in (ownership moves here). O(1) — no merge."""
        assert store.sorted, "SortedRunSet.add_run requires a sorted store"
        self.runs.append(store)

    def maybe_compact(self) -> bool:
        """Geometric merge past max_runs, per the configured policy.

        Always a k-way merge pass (dedupe=True — runs are sets), never a
        sort; the invariant tests assert STATS["sort_passes"] stays 0 here.
        Returns True if a compaction happened (callers holding references
        to member runs must re-read self.runs afterwards).
        """
        if len(self.runs) <= self.max_runs:
            return False
        if self.policy == "full":
            victims = list(self.runs)
        else:
            # Tiered: merge the smallest runs — at least enough to drop back
            # to max_runs, then absorb the next while it is ≤ size_ratio ×
            # the accumulated merge (runs of comparable size merge together;
            # settled big runs stay put).
            by_size = sorted(self.runs, key=lambda r: r.size)
            k = len(self.runs) - self.max_runs + 1
            acc = sum(r.size for r in by_size[:k])
            while (k < len(by_size)
                   and by_size[k].size <= self.size_ratio * max(acc, 1)):
                acc += by_size[k].size
                k += 1
            victims = by_size[:k]
        # Parent span over the k-way merge pass: the nested "merge" span
        # (iter_merged) carries the pass itself; this one tags it as
        # compaction work with the victim count and policy.
        with obs.span("merge", kind="compact", policy=self.policy,
                      victims=len(victims)):
            merged = ChunkStore(
                os.path.join(self.workdir, f"{self.name}.compact{self._seq}"),
                self.width, chunk_rows=self.chunk_rows, fresh=True,
                codec=self.codec)
            self._seq += 1
            extsort.merge_runs(victims, merged, dedupe=True)
        victim_ids = {id(r) for r in victims}
        survivors = [r for r in self.runs if id(r) not in victim_ids]
        for r in victims:
            r.destroy()
        self.runs = survivors + [merged]
        return True

    # -------------------------------------------------------------- read
    def size(self) -> int:
        """Total rows across runs (exact when runs are disjoint, as in BFS)."""
        return sum(r.size for r in self.runs)

    def iter_sorted(self) -> Iterator[np.ndarray]:
        """Globally sorted, deduped blocks across all runs (one merge pass)."""
        return extsort.iter_merged(self.runs, dedupe=True)

    def read_all(self) -> np.ndarray:
        """Materialize the merged unique rows (tests/small data only)."""
        blocks = list(self.iter_sorted())
        if not blocks:
            return np.zeros((0, self.width), np.uint32)
        return np.concatenate(blocks, axis=0)

    def destroy(self) -> None:
        for r in self.runs:
            r.destroy()
        self.runs = []
