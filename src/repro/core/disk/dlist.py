"""DiskList — the paper's RoomyList, genuinely out-of-core (Tier D).

All operations stream chunk-at-a-time; RAM held at any instant is O(chunk).
Semantics mirror Tier J (rlist.py) exactly, and the cross-tier equivalence
is property-tested in tests/test_disk_tier.py.
"""
from __future__ import annotations

import os
import uuid
from typing import Callable, List

import numpy as np

from . import extsort
from .store import ChunkStore


class DiskList:
    _seq = 0

    def __init__(self, workdir: str, width: int, chunk_rows: int = 1 << 16,
                 name: str | None = None):
        self.workdir = workdir
        self.width = width
        self.chunk_rows = chunk_rows
        name = name or f"dlist_{DiskList._seq}_{uuid.uuid4().hex[:8]}"
        DiskList._seq += 1
        self.name = name
        self.store = ChunkStore(os.path.join(workdir, name), width,
                                chunk_rows=chunk_rows, fresh=True)

    # ------------------------------------------------------------ basics
    def add(self, rows: np.ndarray) -> None:
        """Delayed add — buffered by the store, lands at chunk granularity."""
        self.store.append(rows)

    def add_all(self, other: "DiskList") -> None:
        other.store.flush()
        for chunk in other.store.iter_chunks():
            self.store.append(np.asarray(chunk))

    def size(self) -> int:
        return self.store.size

    def _fresh(self, tag: str) -> ChunkStore:
        return ChunkStore(os.path.join(self.workdir,
                                       f"{self.name}.{tag}.{uuid.uuid4().hex[:8]}"),
                          self.width, chunk_rows=self.chunk_rows, fresh=True)

    def _swap(self, new_store: ChunkStore) -> None:
        self.store.destroy()
        self.store = new_store

    # --------------------------------------------------------- mutators
    #
    # Sort-once: every mutator records sorted output on its result store
    # (via extsort) and consults the invariant on its inputs — a second
    # remove_dupes, or a remove_all after a remove_dupes, performs zero
    # comparison sorts (streaming passes only).

    def remove_dupes(self, run_rows: int = 1 << 18) -> None:
        self.store.flush()
        out = self._fresh("dedup")
        tmp = os.path.join(self.workdir, f"{self.name}.sorttmp")
        # external_sort degrades to a one-pass stream_dedupe when the store
        # already claims sorted.
        extsort.external_sort(self.store, out, tmp, run_rows=run_rows,
                              dedupe=True)
        self._swap(out)

    def remove_all(self, other: "DiskList", run_rows: int = 1 << 18) -> None:
        """Remove every occurrence of each element of other (multiset)."""
        self.store.flush()
        other.store.flush()
        if self.store.sorted:                 # invariant: skip the a-sort
            a_sorted = self.store
        else:
            a_sorted = self._fresh("asort")
            extsort.external_sort(self.store, a_sorted,
                                  os.path.join(self.workdir, f"{self.name}.t1"),
                                  run_rows=run_rows)
        if other.store.sorted:                # invariant: skip the b-sort
            b_sorted = other.store
        else:
            b_sorted = self._fresh("bsort")
            extsort.external_sort(other.store, b_sorted,
                                  os.path.join(self.workdir, f"{self.name}.t2"),
                                  run_rows=run_rows, dedupe=True)
        out = self._fresh("diff")
        extsort.merge_difference(a_sorted, b_sorted, out)
        if a_sorted is not self.store:
            a_sorted.destroy()
        if b_sorted is not other.store:
            b_sorted.destroy()
        self._swap(out)

    def remove(self, rows: np.ndarray) -> None:
        tmp = DiskList(self.workdir, self.width, self.chunk_rows)
        tmp.add(rows)
        self.remove_all(tmp)
        tmp.destroy()

    # -------------------------------------------------------- streaming
    def map_chunks(self, fn: Callable[[np.ndarray], None]) -> None:
        """Paper's map: fn applied to each chunk (vectorized numpy)."""
        self.store.flush()
        for chunk in self.store.iter_chunks():
            fn(np.asarray(chunk))

    def reduce(self, elt_fn: Callable, merge_fn: Callable, init):
        """elt_fn(chunk)->partial, merge_fn(partial, partial)->partial."""
        self.store.flush()
        acc = init
        for chunk in self.store.iter_chunks():
            acc = merge_fn(acc, elt_fn(np.asarray(chunk)))
        return acc

    def predicate_count(self, pred: Callable[[np.ndarray], np.ndarray]) -> int:
        return self.reduce(lambda c: int(pred(c).sum()), lambda a, b: a + b, 0)

    def read_all(self) -> np.ndarray:
        self.store.flush()
        return self.store.read_all()

    def destroy(self) -> None:
        self.store.destroy()
