"""DiskBitArray — the paper's 2-bit RoomyArray on real disk (Tier D).

This is the structure behind the paper's flagship pancake result: a packed
array of 2-bit elements indexed by permutation rank (core/ranking.py), with
*delayed* random-access updates batched into streaming passes.  Four
elements pack into each uint8, so N states cost N/4 bytes on disk — the
4·N/16-byte budget the paper quotes for its two 2-bit arrays.

The log/sync contract mirrors darray.py exactly: ``update(idx, vals)``
appends (idx, val) to the op log of the chunk that owns idx (bucketed
immediately, spilled to disk past ``log_buf_rows`` so queued updates never
outgrow RAM), and ``sync(combine, apply)`` streams each chunk once:

    load packed chunk, unpack → load its op log, sort ops by index,
    segment-combine, vals[uniq] = apply(old, agg) → [transform] → pack,
    write back, clear log.

``sync`` is sugar over ``run_pass(plan)`` — the pass-planner entry point
(passes.py): a plan's producer stage rewrites each chunk after its ops
apply (the mark-then-rotate step) and consumer stages read the result in
the SAME traversal, with snapshot-isolated logs so updates queued mid-pass
defer to the next pass.  The implicit BFS (disk/bfs.py:implicit_bfs) rides
this to run ONE fused read-write pass per level — the next level's expand
read piggybacks on the pass applying and rotating this level's marks —
and never a sort.

STATS counts bytes streamed so benchmarks can report bytes-touched-per-
level next to the sorted-list engine's rows-sorted numbers; the shared
pass ledger (extsort.STATS rw_passes/read_passes/piggybacked_stages) books
each planned traversal.

Compressed arrays (docs/compression.md): ``compress=True`` stores cold
chunks RLE-encoded (disk/codec.py — long UNSEEN/DONE stretches collapse
to a few bytes), and snapshots inherit the format for free since they
copy chunk files verbatim.  The chunk loader auto-detects the format
PER FILE, so adopting a snapshot from the other side of the
compressed/uncompressed boundary just works — each chunk transcodes to
the local format lazily, on its next write.  ``bytes_read``/
``bytes_written`` book STORED bytes (what actually crossed the disk);
raw-vs-stored ratios live in the ``codec`` namespace under the ``bits``
tag.  Pass counters are codec-blind — compressed ≡ uncompressed.
"""
from __future__ import annotations

import os
import shutil
import uuid
from typing import Callable, List, Optional

import numpy as np

from .. import obs
from . import codec as _codec
from . import faults
from .extsort import segment_combine_ordered
from .passes import PassPlan, record_pass
from .store import _write_bytes

VALS_PER_BYTE = 4

# The 2-bit BFS mark encoding — canonical definition for BOTH tiers
# (disk/bfs.py and core/bitarray.py import these; UNSEEN must be 0 so a
# fresh zeroed array is all-unseen for free).
UNSEEN, CUR, NEXT, DONE = 0, 1, 2, 3

# Pass/byte accounting (benchmarks/bfs.py reports bytes touched per level).
# bytes_read/bytes_written are totals; log_bytes_read/log_bytes_written are
# the op-log subset, so packed-ARRAY traversal bytes — the planner's unit
# of saving — are exactly bytes_read - log_bytes_read (ditto written), and
# tests can pin "one array traversal per fused BFS level" to the byte.
STATS = obs.counters("bits", {
    "bytes_read": 0, "bytes_written": 0, "log_bytes_read": 0,
    "log_bytes_written": 0, "sync_passes": 0, "scan_passes": 0,
    "ops_applied": 0})


def reset_stats() -> None:
    for k in STATS:
        STATS[k] = 0


# (256, 4) lookup: _BYTE_COUNTS[b, v] = how many of byte b's four 2-bit
# fields equal v — turns count_values into one np.bincount + matmul.
_BYTE_COUNTS = np.zeros((256, 4), np.int64)
for _b in range(256):
    for _j in range(4):
        _BYTE_COUNTS[_b, (_b >> (2 * _j)) & 3] += 1


def pack2(vals: np.ndarray) -> np.ndarray:
    """(k,) values in 0..3 → (ceil(k/4),) uint8; tail fields padded with 0."""
    vals = np.asarray(vals, np.uint8).reshape(-1)
    pad = (-vals.shape[0]) % VALS_PER_BYTE
    if pad:
        vals = np.concatenate([vals, np.zeros(pad, np.uint8)])
    v = vals.reshape(-1, VALS_PER_BYTE)
    return (v[:, 0] | (v[:, 1] << 2) | (v[:, 2] << 4) | (v[:, 3] << 6)).astype(np.uint8)


def unpack2(packed: np.ndarray, count: int) -> np.ndarray:
    """(b,) uint8 → (count,) uint8 values in 0..3."""
    packed = np.asarray(packed, np.uint8)
    out = np.empty((packed.shape[0], VALS_PER_BYTE), np.uint8)
    for j in range(VALS_PER_BYTE):
        out[:, j] = (packed >> (2 * j)) & 3
    return out.reshape(-1)[:count]


class DiskBitArray:
    """Chunked packed 2-bit array with per-chunk delayed-update op logs."""

    def __init__(self, workdir: str, n: int, chunk_elems: int = 1 << 22,
                 name: str | None = None, log_buf_rows: int = 1 << 20,
                 init_chunks: bool = True, compress: bool = False):
        """``init_chunks=False`` skips writing the zeroed chunk files —
        ONLY for a caller about to :meth:`adopt_snapshot` (which supplies
        every chunk): resuming a large search must not write n/4 bytes of
        zeros just to overwrite them.  The array is unusable until the
        adoption lands."""
        assert chunk_elems % VALS_PER_BYTE == 0
        self.n = int(n)
        self.chunk_elems = int(chunk_elems)
        self.n_chunks = -(-self.n // self.chunk_elems)
        self.log_buf_rows = int(log_buf_rows)
        self.compress = bool(compress)
        name = name or f"dbits_{uuid.uuid4().hex[:8]}"
        self.path = os.path.join(workdir, name)
        if os.path.isdir(self.path):
            shutil.rmtree(self.path)
        os.makedirs(self.path)
        if init_chunks:
            for c in range(self.n_chunks):
                rows = self._chunk_rows(c)
                self._store_packed(
                    c, np.zeros(-(-rows // VALS_PER_BYTE), np.uint8),
                    book=False, retry=False)
        self._log_bufs: List[List[np.ndarray]] = [[] for _ in range(self.n_chunks)]
        self._log_buffered = 0

    # ----------------------------------------------------------- layout
    def _chunk_rows(self, c: int) -> int:
        return min(self.chunk_elems, self.n - c * self.chunk_elems)

    def _chunk_path(self, c: int, rmz: bool = False) -> str:
        return os.path.join(self.path,
                            f"b{c:06d}.{'rmz' if rmz else 'npy'}")

    # -------------------------------------------------- chunk file codec
    def _load_packed(self, c: int, book: bool = True) -> np.ndarray:
        """Load chunk ``c``'s packed bytes, auto-detecting the file's own
        format — an adopted snapshot may carry the other side of the
        compressed/uncompressed boundary.  Books STORED bytes read."""
        pz = self._chunk_path(c, rmz=True)
        if os.path.exists(pz):
            with open(pz, "rb") as f:
                buf = f.read()
            if book:
                STATS["bytes_read"] += len(buf)
            return _codec.decode_rle2(buf, tag="bits")
        packed = np.load(self._chunk_path(c))
        if book:
            STATS["bytes_read"] += packed.nbytes
        return packed

    def _store_packed(self, c: int, packed: np.ndarray, book: bool = True,
                      retry: bool = True) -> None:
        """Write chunk ``c`` in the LOCAL format (transcoding away any
        other-format file a snapshot adoption left), booking stored
        bytes written."""
        if self.compress:
            enc = _codec.encode_rle2(packed, tag="bits")
            path, stale = (self._chunk_path(c, rmz=True),
                           self._chunk_path(c))
            write = lambda: _write_bytes(path, enc)
            stored = len(enc)
        else:
            path, stale = (self._chunk_path(c),
                           self._chunk_path(c, rmz=True))
            write = lambda: np.save(path, packed)
            stored = packed.nbytes
        if retry:
            faults.retry_io("chunk_flush", write, chunk=c)
        else:
            write()
        if os.path.exists(stale):
            os.remove(stale)
        if book:
            STATS["bytes_written"] += stored

    def _log_path(self, c: int) -> str:
        # Raw append-mode int64 (idx, val) pairs — NOT .npy: spills append
        # O(spill) bytes instead of rewriting the whole accumulated log.
        return os.path.join(self.path, f"log{c:06d}.bin")

    @property
    def nbytes(self) -> int:
        """Total packed bytes on disk (the 2·N-bit budget)."""
        return sum(-(-self._chunk_rows(c) // VALS_PER_BYTE)
                   for c in range(self.n_chunks))

    # ------------------------------------------------------ delayed ops
    def update(self, idx: np.ndarray, vals: np.ndarray) -> None:
        """Queue delayed writes vals∈0..3 at idx (bucketed to owner chunks).

        Like darray.update, ops are binned to their owner chunk immediately;
        unlike darray the in-RAM log is bounded: once ``log_buf_rows`` ops
        are buffered they spill to the per-chunk log files, so a BFS level
        whose expansion exceeds RAM still works (the whole point).
        """
        idx = np.asarray(idx, np.int64).reshape(-1)
        vals = np.asarray(vals, np.uint8).reshape(-1)
        assert idx.shape == vals.shape
        ok = (idx >= 0) & (idx < self.n)
        if not ok.all():        # drop out-of-range, like the Tier J mark
            idx, vals = idx[ok], vals[ok]
        if not idx.shape[0]:
            return
        chunk_of = idx // self.chunk_elems
        order = np.argsort(chunk_of, kind="stable")
        idx, vals, chunk_of = idx[order], vals[order], chunk_of[order]
        bounds = np.searchsorted(chunk_of, np.arange(self.n_chunks + 1))
        for c in range(self.n_chunks):
            lo, hi = bounds[c], bounds[c + 1]
            if hi > lo:
                rec = np.empty((hi - lo, 2), np.int64)
                rec[:, 0] = idx[lo:hi]
                rec[:, 1] = vals[lo:hi]
                self._log_bufs[c].append(rec)
        self._log_buffered += idx.shape[0]
        if self._log_buffered >= self.log_buf_rows:
            self._flush_logs()

    def _flush_logs(self) -> None:
        for c, buf in enumerate(self._log_bufs):
            if not buf:
                continue
            rec = np.concatenate(buf, axis=0) if len(buf) > 1 else buf[0]
            # Positioned truncate-on-retry append: a torn spill attempt can
            # never leave a partial (idx, val) record in the op log.
            faults.append_bytes(
                "oplog_append", self._log_path(c),
                np.ascontiguousarray(rec, np.int64).tobytes(), chunk=c)
            STATS["bytes_written"] += rec.nbytes
            STATS["log_bytes_written"] += rec.nbytes
            self._log_bufs[c] = []
        self._log_buffered = 0

    # -------------------------------------------------------------- sync
    def sync(self, combine: Optional[Callable] = None,
             apply: Optional[Callable] = None,
             transform: Optional[Callable] = None) -> None:
        """Execute all queued updates in one streaming pass (darray contract).

        combine(p1, p2): associative merge of two values aimed at one index
            (default: bitwise OR — the natural monoid of mark bits).
        apply(old_vals, agg_vals) -> new_vals at the touched indices
            (default: overwrite with the aggregate).
        transform(start, vals) -> vals: if given, runs on EVERY chunk after
            its updates apply (forcing a full read-write pass even over
            log-less chunks) — the fusion hook for mark-then-rotate steps.

        Sugar over :meth:`run_pass` with a single-producer plan; callers
        that want consumer stages riding the same traversal (the implicit
        BFS's fused expand read) build a :class:`PassPlan` directly.
        """
        plan = PassPlan("sync")
        if transform is not None:
            plan.writes(transform)
        self.run_pass(plan, combine=combine, apply=apply)

    def run_pass(self, plan: PassPlan, combine: Optional[Callable] = None,
                 apply: Optional[Callable] = None) -> None:
        """Apply all queued updates AND the plan's stages in ONE traversal.

        The pass-planner entry point (passes.py): each chunk is loaded
        once, its snapshot ops applied (combine/apply as in :meth:`sync`),
        then threaded through the plan's stages in order, and written back
        only if it was dirtied (ops applied or a write stage ran).

        Snapshot isolation: the op logs existing when the pass OPENS are
        the only updates it applies.  Updates queued by plan stages during
        the traversal — e.g. the piggybacked expand read of the implicit
        BFS marking next-level states — accumulate in fresh logs for the
        NEXT pass, even when they target chunks this pass has not reached
        yet.  That is the paper's delayed-update batching rule made
        structural, and what makes the producer/consumer fusion sound.
        """
        if combine is None:
            combine = np.bitwise_or
        if apply is None:
            apply = lambda old, agg: agg
        any_log = any(
            bool(self._log_bufs[c]) or os.path.exists(self._log_path(c))
            or os.path.exists(self._log_path(c) + ".pass")
            for c in range(self.n_chunks))
        writes = plan.writes_chunks or any_log
        # The span opens BEFORE the log flush/promotion so the queued-op
        # spill bytes land in this pass's metrics — a shard's pass spans
        # then carry its complete byte ledger (the trace acceptance pin).
        with obs.span("pass.rw" if writes else "pass.read", plan=plan.name,
                      chunks=self.n_chunks):
            self._flush_logs()
            # Promote current logs to a read-only snapshot (.pass); mid-pass
            # updates re-open fresh .bin logs this traversal never reads. A
            # leftover snapshot from an aborted pass is re-adopted in front
            # of the newer records so no queued op is ever lost.
            for c in range(self.n_chunks):
                lp, sp = self._log_path(c), self._log_path(c) + ".pass"
                if os.path.exists(sp):
                    if os.path.exists(lp):
                        with open(sp, "ab") as dst, open(lp, "rb") as src:
                            dst.write(src.read())
                        os.remove(lp)
                elif os.path.exists(lp):
                    os.replace(lp, sp)
            STATS["sync_passes"] += 1
            record_pass(plan.n_stages + (1 if any_log else 0), writes=writes)
            for c in range(self.n_chunks):
                sp = self._log_path(c) + ".pass"
                has_log = os.path.exists(sp)
                if not has_log and not plan.forces_full_traversal:
                    continue
                rows = self._chunk_rows(c)
                packed = self._load_packed(c)
                vals = unpack2(packed, rows)
                if has_log:
                    log = np.fromfile(sp, dtype=np.int64).reshape(-1, 2)
                    STATS["bytes_read"] += log.nbytes
                    STATS["log_bytes_read"] += log.nbytes
                    if log.shape[0]:
                        local = log[:, 0] - c * self.chunk_elems
                        pay = log[:, 1].astype(np.uint8)
                        order = np.argsort(local, kind="stable")
                        uniq, agg = segment_combine_ordered(
                            local[order], pay[order], combine)
                        vals[uniq] = apply(vals[uniq], agg)
                        STATS["ops_applied"] += int(log.shape[0])
                vals = plan.apply_chunk(c * self.chunk_elems, vals)
                assert vals.shape[0] == rows
                if has_log or plan.writes_chunks:
                    self._store_packed(c, pack2(vals))
                if has_log:
                    # Consumed only after the chunk lands: a stage raising
                    # mid-pass leaves the snapshot for the next pass to
                    # re-adopt instead of silently dropping this chunk's
                    # queued ops.
                    os.remove(sp)

    # ------------------------------------------------------- checkpoint
    def snapshot_to(self, dst: str) -> int:
        """Copy the array's durable state — packed chunks, spilled op logs,
        and any ``.pass`` snapshot an aborted pass left behind — into
        ``dst``.  RAM-buffered ops are flushed first so the snapshot is
        self-contained: adopting it replays exactly the marks that were
        queued here.  Bytes are booked under the checkpoint counters
        (``extsort.STATS['ckpt_bytes_written']``), never the array/log
        ledgers, so pass budgets are unchanged.  Returns bytes copied.
        """
        from .checkpoint import copy_dir_booked
        self._flush_logs()
        return copy_dir_booked(self.path, dst, "ckpt_bytes_written")

    def adopt_snapshot(self, src: str) -> int:
        """Replace this array's on-disk state with a snapshot taken by
        :meth:`snapshot_to` (same ``n`` / ``chunk_elems`` layout — the
        checkpoint layer validates that before calling).  Clears RAM log
        buffers and any local log files first so nothing of the pre-adopt
        life leaks into the restored state.  Returns bytes copied
        (booked under ``ckpt_bytes_read``).
        """
        from .checkpoint import copy_dir_booked
        self._log_bufs = [[] for _ in range(self.n_chunks)]
        self._log_buffered = 0
        for fn in os.listdir(self.path):
            p = os.path.join(self.path, fn)
            if os.path.isfile(p):
                # Everything goes: stale op logs / .pass snapshots, AND
                # chunk files — a pre-adopt chunk in the OTHER codec
                # format would otherwise shadow the adopted one (the
                # loader auto-detects per file, preferring compressed).
                os.remove(p)
        total = copy_dir_booked(src, self.path, "ckpt_bytes_read")
        for c in range(self.n_chunks):
            assert (os.path.isfile(self._chunk_path(c))
                    or os.path.isfile(self._chunk_path(c, rmz=True))), \
                f"snapshot is missing chunk {c} — torn checkpoint payload"
        return total

    # -------------------------------------------------------- streaming
    def map_chunks(self, fn: Callable[[int, np.ndarray], None]) -> None:
        """Read-only streaming scan: fn(start_index, values)."""
        STATS["scan_passes"] += 1
        for c in range(self.n_chunks):
            packed = self._load_packed(c)
            fn(c * self.chunk_elems, unpack2(packed, self._chunk_rows(c)))

    def map_update(self, fn: Callable[[int, np.ndarray], np.ndarray]) -> None:
        """In-place streaming transform: vals = fn(start, vals)."""
        STATS["scan_passes"] += 1
        for c in range(self.n_chunks):
            rows = self._chunk_rows(c)
            packed = self._load_packed(c)
            vals = np.asarray(fn(c * self.chunk_elems,
                                 unpack2(packed, rows)), np.uint8)
            assert vals.shape[0] == rows
            self._store_packed(c, pack2(vals), retry=False)

    def count_values(self) -> np.ndarray:
        """(4,) histogram of element values — one byte-histogram pass, no
        unpacking (the paper's predicateCount for 2-bit arrays)."""
        counts = np.zeros(4, np.int64)
        pad = 0
        for c in range(self.n_chunks):
            packed = self._load_packed(c)
            counts += np.bincount(packed, minlength=256) @ _BYTE_COUNTS
            pad += packed.shape[0] * VALS_PER_BYTE - self._chunk_rows(c)
        counts[0] -= pad            # pack2 pads tail fields with value 0
        return counts

    # ------------------------------------------------------------- read
    def get(self, idx: np.ndarray) -> np.ndarray:
        """Random read (tests/debug — production access is via sync/map)."""
        idx = np.asarray(idx, np.int64).reshape(-1)
        out = np.empty(idx.shape[0], np.uint8)
        chunk_of = idx // self.chunk_elems
        for c in np.unique(chunk_of):
            sel = chunk_of == c
            packed = self._load_packed(int(c), book=False)
            local = idx[sel] - int(c) * self.chunk_elems
            byte = np.asarray(packed[local // VALS_PER_BYTE])
            out[sel] = (byte >> (2 * (local % VALS_PER_BYTE)).astype(np.uint8)) & 3
        return out

    def read_all(self) -> np.ndarray:
        """(n,) values — tests/small data only."""
        parts = []
        for c in range(self.n_chunks):
            parts.append(unpack2(self._load_packed(c, book=False),
                                 self._chunk_rows(c)))
        return (np.concatenate(parts) if parts else np.zeros(0, np.uint8))

    def destroy(self) -> None:
        self._log_bufs = [[] for _ in range(self.n_chunks)]
        shutil.rmtree(self.path, ignore_errors=True)
