"""Deterministic fault injection + transient-I/O retry (Tier D stack).

Invariant: with no plan installed the fault hooks are a single module
attribute test (``faults.ACTIVE``) — no allocation, no call — so the
pass/byte budgets and the bench baseline are untouched by this layer
(the CI bench gate pins that); with a plan installed, every injection is
a deterministic function of the ``ROOMY_FAULTS`` spec, the seed, and the
per-site hit sequence, so a failing chaos run replays exactly.

Roomy's target computations run for days to months on clusters where
disk and worker failures are expected, not exceptional (paper §2–3).
This module gives the runtime two things:

  1. **Named fault sites.**  The I/O hot spots (bucket spill/seal, chunk
     flush, op-log append, checkpoint publish, worker per-level entry,
     worker command barrier) call :func:`fire` with their site name and
     context.  An installed :class:`FaultPlan` decides — deterministically
     — whether that hit raises a transient ``OSError``, a fatal
     ``OSError``, kills the process (``os._exit`` in spawn workers, a
     :class:`WorkerKilled` raise in-process), sleeps past a collective
     timeout, or tears the write in progress.

  2. **Transient-I/O retry.**  :func:`retry_io` wraps an idempotent I/O
     operation: transient errnos (EIO, EAGAIN, EBUSY, EINTR, ETIMEDOUT,
     ESTALE — the shared-filesystem flake set) retry with bounded
     exponential backoff, fatal errnos re-raise immediately, and both
     outcomes are booked in ``extsort.STATS`` (``io_retries`` /
     ``io_giveups``).  :func:`append_bytes` makes file *appends*
     retry-safe: the pre-append size is recorded and every attempt
     truncates back to it first, so a torn write from a failed attempt
     can never leave duplicate or partial records.

``ROOMY_FAULTS`` spec grammar (rules separated by ``;``)::

    seed=42;bucket_seal:transient:every=2:times=2;worker_level:kill:shard=1:level=2

Each rule is ``site:kind[:key=val]*`` with

  kind   transient | fatal | kill | delay | torn
  shard  only fire in the worker with this shard id
  level  only fire when the site reports this BFS level
  at     fire on the Nth matching hit of the site (1-based)
  every  fire on every Nth matching hit
  p      fire with this probability (seeded, per-rule RNG)
  times  consecutive hits that fail once triggered (transient bursts)
  once   fire at most once per (site, rule, level) — persisted via
         marker files in the bound state dir so a respawned worker does
         not re-fire on replay; defaults ON for kill/fatal/delay
  secs   sleep length for ``delay`` rules

Spawn workers install the plan from the environment at startup
(:func:`install_from_env`, called by ``cluster._worker_main``), bound to
the runtime root's ``_faults/`` marker dir and ``allow_exit=True`` so
``kill`` is a real ``os._exit``.  The coordinator (and inline mode)
installs the same spec with ``allow_exit=False`` so ``kill`` becomes a
:class:`WorkerKilled` raise the recovery path catches.
"""
from __future__ import annotations

import errno
import os
import time
from typing import Dict, List, Optional

import numpy as np

__all__ = [
    "ACTIVE", "FaultPlan", "FaultRule", "WorkerKilled", "append_bytes",
    "default_chaos_spec", "fire", "install", "install_from_env", "parse",
    "retry_io", "uninstall",
]

ENV_VAR = "ROOMY_FAULTS"

# Errnos worth retrying: the transient flake set of a shared filesystem.
# Everything else (ENOSPC, EROFS, EACCES, ...) is fatal — retrying cannot
# help and would only hide a real operational problem.
TRANSIENT_ERRNOS = frozenset({
    errno.EIO, errno.EAGAIN, errno.EBUSY, errno.EINTR, errno.ETIMEDOUT,
    errno.ESTALE,
})

KINDS = ("transient", "fatal", "kill", "delay", "torn")

# Module-level switch the hot sites test BEFORE calling anything: with no
# plan installed a fault hook costs one attribute read and a branch.
ACTIVE = False
_PLAN: Optional["FaultPlan"] = None


class WorkerKilled(RuntimeError):
    """In-process stand-in for hard worker death (inline mode / tests):
    ``kill`` rules raise this instead of ``os._exit`` when the plan was
    installed with ``allow_exit=False``."""


def _stats() -> Dict[str, int]:
    from . import extsort          # lazy: extsort imports store imports us
    return extsort.STATS


# ---------------------------------------------------------------- the plan

class FaultRule:
    """One ``site:kind:...`` rule of a :class:`FaultPlan` (see module
    docstring for the selector/trigger semantics)."""

    def __init__(self, site: str, kind: str, *, shard: Optional[int] = None,
                 level: Optional[int] = None, at: Optional[int] = None,
                 every: Optional[int] = None, p: Optional[float] = None,
                 times: int = 1, once: Optional[bool] = None,
                 secs: float = 30.0):
        assert kind in KINDS, f"unknown fault kind {kind!r}"
        self.site = site
        self.kind = kind
        self.shard = shard
        self.level = level
        self.at = at
        self.every = every
        self.p = p
        self.times = max(1, int(times))
        # kill/fatal/delay default to once-per-(site,level): without the
        # marker a recovered run would re-fire on replay and never converge.
        self.once = (kind in ("kill", "fatal", "delay")
                     if once is None else bool(once))
        self.secs = float(secs)
        # Bound at plan bind time.
        self.idx = 0
        self._rng: Optional[np.random.Generator] = None
        self._hits = 0
        self._burst = 0
        self._fired_keys: set = set()   # in-process `once` fallback

    def _matches_ctx(self, ctx: dict) -> bool:
        if self.shard is not None and ctx.get("shard") != self.shard:
            return False
        if self.level is not None and ctx.get("level") != self.level:
            return False
        return True

    def _triggered(self) -> bool:
        if self.at is not None:
            return self._hits == self.at
        if self.every is not None:
            return self._hits % self.every == 0
        if self.p is not None:
            return bool(self._rng.random() < self.p)
        return True

    def _marker_key(self, ctx: dict) -> str:
        key = f"{self.site}.{self.idx}"
        if "level" in ctx:
            key += f".l{int(ctx['level'])}"
        return key


class FaultPlan:
    """A seeded, deterministic set of :class:`FaultRule`\\ s.

    ``fire(site, **ctx)`` is the single entry point: it walks the rules
    registered for the site, and the first one that matches acts —
    raising, killing, sleeping, or returning an action dict
    (``{"torn": True}``) the call site interprets.  Hit counters are
    per-process; ``once`` rules persist marker files under ``state_dir``
    so they stay fired across worker respawns and coordinator restarts.
    """

    def __init__(self, rules: List[FaultRule], seed: int = 0):
        self.rules = list(rules)
        self.seed = int(seed)
        self.state_dir: Optional[str] = None
        self.allow_exit = False
        self._by_site: Dict[str, List[FaultRule]] = {}
        for i, r in enumerate(self.rules):
            r.idx = i
            self._by_site.setdefault(r.site, []).append(r)

    def bind(self, state_dir: Optional[str] = None,
             shard: Optional[int] = None, allow_exit: bool = False
             ) -> "FaultPlan":
        """Attach per-process identity: the cross-process marker dir, the
        shard id salt for the per-rule RNGs, and whether ``kill`` may
        really ``os._exit``."""
        self.state_dir = state_dir
        self.allow_exit = bool(allow_exit)
        if state_dir:
            os.makedirs(state_dir, exist_ok=True)
        salt = 0 if shard is None else (int(shard) + 1)
        for r in self.rules:
            r._rng = np.random.default_rng(
                (self.seed * 1_000_003 + r.idx * 9_176 + salt) & 0xFFFFFFFF)
        return self

    # ------------------------------------------------------------- firing
    def _fired_before(self, rule: FaultRule, key: str) -> bool:
        if self.state_dir:
            return os.path.exists(os.path.join(self.state_dir, key))
        return key in rule._fired_keys

    def _mark_fired(self, rule: FaultRule, key: str) -> None:
        if self.state_dir:
            with open(os.path.join(self.state_dir, key), "w"):
                pass
        rule._fired_keys.add(key)

    def _act(self, rule: FaultRule, site: str, ctx: dict) -> Optional[dict]:
        where = f"injected at {site}" + (
            f" (shard={ctx['shard']})" if "shard" in ctx else "")
        if rule.kind == "transient":
            raise OSError(errno.EIO, f"transient fault {where}")
        if rule.kind == "fatal":
            raise OSError(errno.ENOSPC, f"fatal fault {where}")
        if rule.kind == "kill":
            if self.allow_exit:
                # Marker already written by fire(); die without cleanup —
                # the hard-death shape recovery must survive.
                os._exit(17)
            raise WorkerKilled(f"worker killed {where}")
        if rule.kind == "delay":
            time.sleep(rule.secs)
            return None
        return {"torn": True}          # interpreted by append_bytes

    def fire(self, site: str, **ctx) -> Optional[dict]:
        """One hit at ``site``.  May raise (transient/fatal/kill), sleep
        (delay), or return an action dict (torn); returns None when no
        rule acts."""
        for rule in self._by_site.get(site, ()):
            if not rule._matches_ctx(ctx):
                continue
            if rule._burst > 0:        # mid-burst: keep failing
                rule._burst -= 1
                return self._act(rule, site, ctx)
            rule._hits += 1
            if not rule._triggered():
                continue
            if rule.once:
                key = rule._marker_key(ctx)
                if self._fired_before(rule, key):
                    continue
                self._mark_fired(rule, key)
            rule._burst = rule.times - 1
            return self._act(rule, site, ctx)
        return None


# --------------------------------------------------------------- (un)install

def parse(spec: str) -> FaultPlan:
    """Parse a ``ROOMY_FAULTS`` spec string (grammar in module docstring)."""
    seed = 0
    rules: List[FaultRule] = []
    for token in spec.split(";"):
        token = token.strip()
        if not token:
            continue
        if token.startswith("seed="):
            seed = int(token[len("seed="):])
            continue
        parts = token.split(":")
        if len(parts) < 2:
            raise ValueError(f"fault rule {token!r} needs site:kind")
        site, kind, kv = parts[0], parts[1], parts[2:]
        kwargs: dict = {}
        for item in kv:
            k, _, v = item.partition("=")
            if k in ("shard", "level", "at", "every", "times"):
                kwargs[k] = int(v)
            elif k in ("p", "secs"):
                kwargs[k] = float(v)
            elif k == "once":
                kwargs[k] = v not in ("0", "false", "no")
            else:
                raise ValueError(f"unknown fault rule key {k!r} in {token!r}")
        rules.append(FaultRule(site, kind, **kwargs))
    return FaultPlan(rules, seed=seed)


def default_chaos_spec(seed: int, shards: int = 1) -> str:
    """The examples' ``--chaos SEED`` storm (also the CI chaos job):
    torn appends plus transient flakes on every retry-wrapped site, and —
    when sharded — one real worker kill mid-search, so the run exercises
    both the retry layer and the checkpoint-rollback recovery path."""
    spec = (f"seed={int(seed)};"
            "bucket_spill:torn:every=7:once=0;"
            "oplog_append:torn:every=9:once=0;"
            "bucket_seal:transient:every=5:times=2:once=0;"
            "chunk_flush:transient:every=6:once=0;"
            "meta_write:transient:every=4:once=0;"
            "ckpt_publish:transient:every=3:once=0")
    if shards > 1:
        spec += ";worker_level:kill:shard=1:level=2"
    return spec


def install(plan: Optional[FaultPlan]) -> None:
    global _PLAN, ACTIVE
    _PLAN = plan
    ACTIVE = plan is not None


def uninstall() -> None:
    install(None)


def install_from_env(state_dir: Optional[str] = None,
                     shard: Optional[int] = None,
                     allow_exit: bool = False) -> bool:
    """Install the plan named by ``$ROOMY_FAULTS`` (binding it to this
    process's identity); a missing/empty variable leaves the current
    installation untouched.  Returns True if a plan was installed."""
    spec = os.environ.get(ENV_VAR, "").strip()
    if not spec:
        return False
    install(parse(spec).bind(state_dir=state_dir, shard=shard,
                             allow_exit=allow_exit))
    return True


def fire(site: str, **ctx) -> Optional[dict]:
    """Module-level dispatch to the installed plan (no-op when none)."""
    plan = _PLAN
    if plan is None:
        return None
    return plan.fire(site, **ctx)


# ------------------------------------------------------------ retry wrappers

def retry_io(site: str, fn, attempts: int = 6, base_delay: float = 0.002,
             max_delay: float = 0.1, fire_site: bool = True, **ctx):
    """Run an *idempotent* I/O operation with transient-errno retry.

    Transient OSErrors (:data:`TRANSIENT_ERRNOS`) — whether injected at
    ``site`` or raised by the real filesystem — retry up to ``attempts``
    total tries with bounded exponential backoff, booking each retry in
    ``extsort.STATS['io_retries']``.  A fatal errno, or exhaustion of the
    attempt budget, books ``io_giveups`` and re-raises: the caller (BFS
    recovery, or the user) decides what dies.  ``fn`` must be safe to
    re-execute — whole-file rewrites and atomic renames are; bare appends
    are not (use :func:`append_bytes`)."""
    attempt = 0
    while True:
        try:
            if fire_site and ACTIVE:
                act = _PLAN.fire(site, **ctx)
                if act:                # torn rule on a non-append site:
                    raise OSError(     # degrade to a transient failure
                        errno.EIO, f"torn fault at {site} (as transient)")
            return fn()
        except OSError as exc:
            attempt += 1
            if exc.errno not in TRANSIENT_ERRNOS or attempt >= attempts:
                _stats()["io_giveups"] += 1
                raise
            _stats()["io_retries"] += 1
            time.sleep(min(base_delay * (2 ** (attempt - 1)), max_delay))


def append_bytes(site: str, path: str, data: bytes, **ctx) -> None:
    """Retry-safe append: record the pre-append size, and have EVERY
    attempt truncate back to it before writing — so a torn write from a
    failed attempt (transient error, injected tear) can never leave
    partial or duplicated records behind.  This is what makes the op-log
    and bucket-spill appends idempotent under :func:`retry_io`."""
    try:
        pos = os.path.getsize(path)
    except OSError:
        pos = 0

    def _do() -> None:
        with open(path, "r+b" if os.path.exists(path) else "wb") as f:
            f.truncate(pos)
            f.seek(pos)
            act = _PLAN.fire(site, **ctx) if ACTIVE else None
            if act and act.get("torn"):
                f.write(data[:max(1, len(data) // 2)])
                f.flush()
                raise OSError(errno.EIO, f"torn write injected at {site}")
            f.write(data)

    retry_io(site, _do, fire_site=False, **ctx)
