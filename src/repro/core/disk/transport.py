"""Pluggable bucket transports — how sealed buckets travel between shards.

The paper's cluster model (§2–3) promises that "all aspects of
parallelism and remote I/O are hidden within the library": a delayed
operation is routed to the shard that owns its target and applied there
at sync, and the *wire* those operations ride is an implementation
detail.  This module makes the wire a real interface.  The contracts
every backend preserves (pinned by tests/test_transport.py):

  atomic publish     a receiver sees an epoch's bucket either complete or
                     not at all; a sender killed mid-epoch leaves only
                     ignorable strays (``.tmp`` files, half-written
                     socket frames, unpublished in-memory buffers).
  exact overflow     rows past a destination's per-epoch capacity are
                     dropped AND counted, never silently
                     (:class:`~.buckets.BucketSender`).
  ascending-src apply in barrier mode (and ordered pipelined mode) a
                     destination consumes sources in ascending id order —
                     the deterministic sequencing the sharded hash
                     table's per-key op order relies on.
  stray cleanup      a fresh runtime can always sweep what a killed run
                     left behind, and books what it swept.

Backends (selected via ``ClusterConfig(transport=...)``):

  fs        the shared-filesystem layout of ``buckets.py`` — the default,
            byte-compatible on disk with the pre-transport protocol in
            barrier mode (pipelined mode adds ``.done`` markers).
  tcp       length-prefixed frames over sockets, one receiver thread per
            shard: spawn workers exchange buckets with NO shared exchange
            directory (the real multi-host shape).  Spills spool to the
            worker's private node-local scratch, never a shared path.
  loopback  an in-process mailbox for the thread-parallel ``inline``
            mode: zero file I/O on the exchange path, senders publish
            byte payloads straight into the shared store.

Pipelined exchange (``ClusterConfig(exchange="pipelined")``) overlaps
produce and apply: a worker seals with completion markers and its peers
begin absorbing its buckets while slower shards are still expanding —
the only barrier left is the level boundary.  ``recv(..., live=True)``
is that incremental consumption; ``ordered=True`` preserves the
ascending-src apply order where per-key sequencing demands it.

See docs/transports.md for the backend matrix and the full contract.
"""
from __future__ import annotations

import os
import shutil
import socket
import struct
import threading
import time
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from .. import obs
from . import faults
from . import codec as _codec
from .buckets import (TRANSPORT_STATS, BucketSender, BucketWriter,
                      _bucket_name, _done_name, cleanup_strays,
                      iter_incoming)

__all__ = ["Transport", "TransportAborted", "FsTransport", "TcpTransport",
           "LoopbackTransport", "LoopbackStore", "make_transport",
           "TRANSPORT_KINDS"]

TRANSPORT_KINDS = ("fs", "tcp", "loopback")

_POLL = 0.02              # seconds between stray polls / cond waits


class TransportAborted(RuntimeError):
    """A live recv was unblocked by the runtime's abort flag — a PEER
    failed, not this shard.  Distinct so the threaded map can prefer the
    original failure (which carries shard/site attribution) over the
    secondary aborts it caused."""


class Transport:
    """One shard's view of the bucket wire.

    Every process (each worker plus the coordinator, which sends as
    source id ``nshards``) holds exactly one instance per runtime.  The
    surface the runtime drives:

      sender(spec)     a fresh :class:`~.buckets.BucketSender` for one
                       structure (the runtime caches it per name).
      recv(spec, epoch, srcs, live=, ordered=)
                       stream (src, rows) pairs addressed to this shard.
                       Barrier mode (``live=False``) yields only after
                       every source in ``srcs`` sealed, ascending src.
                       Pipelined mode (``live=True``) yields each source
                       as soon as its completion marker lands;
                       ``ordered=True`` still consumes ascending.
      handshake()/connect(peers)
                       address exchange for backends with real endpoints
                       (tcp); no-ops elsewhere.
      startup(fresh)/wipe(name)/wipe_all()/close()
                       lifecycle: stray sweep or full wipe at runtime
                       construction, per-structure wipe at destroy and
                       rollback (in-flight buckets of a failed epoch are
                       dead traffic), teardown.
    """

    kind = "abstract"

    #: True when receivers on this wire WAIT for every source's sealed
    #: flag (mailbox semantics) — every source must then seal every
    #: epoch, even an empty one.  False for the fs wire's barrier mode,
    #: where absence of a bucket file IS the empty bucket (and where an
    #: unforced seal would adopt a killed peer's stray ``.tmp``).
    explicit_completion = True

    def __init__(self, root: str, me: int, nshards: int,
                 abort: Optional[threading.Event] = None,
                 timeout: float = 600.0, wire_compress: bool = False):
        self.root = root
        self.me = int(me)
        self.nshards = int(nshards)
        self.abort = abort
        self.timeout = timeout
        # Mailbox wires only: zlib-frame sealed payloads at publish.
        # Receivers ALWAYS auto-detect (wire_decode passes plain payloads
        # through), so the flag is a sender-side choice and mixed
        # sender/receiver configurations interoperate.
        self.wire_compress = bool(wire_compress)

    # ------------------------------------------------------------- sending
    def sender(self, spec: dict) -> BucketSender:
        raise NotImplementedError

    # ----------------------------------------------------------- receiving
    def recv(self, spec: dict, epoch: int,
             srcs: Optional[Tuple[int, ...]] = None, *, live: bool = False,
             ordered: bool = True, timeout: Optional[float] = None
             ) -> Iterator[Tuple[int, np.ndarray]]:
        raise NotImplementedError

    # ------------------------------------------------------------ topology
    def handshake(self):
        """This shard's receive endpoint, or None for endpoint-free
        backends.  Called once per (re)spawn, before any seal."""
        return None

    def connect(self, peers: dict) -> None:
        """Install the peer endpoint map from the coordinator's
        handshake round (``{shard: endpoint}``)."""

    # ----------------------------------------------------------- lifecycle
    def startup(self, fresh: bool) -> None:
        """Coordinator-side stray policy at runtime construction:
        ``fresh=True`` discards ALL queued exchange traffic, otherwise
        only ignorable strays are swept (and booked)."""

    def wipe(self, name: str) -> None:
        """Discard every queued/sealed bucket of one structure."""

    def wipe_all(self) -> None:
        raise NotImplementedError

    def close(self) -> None:
        """Release sockets/threads.  Idempotent."""

    # ------------------------------------------------------------- helpers
    def _check_abort(self) -> None:
        if self.abort is not None and self.abort.is_set():
            raise TransportAborted(
                f"{self.kind} transport: recv aborted (runtime recovering)")


# =============================================================== shared FS

class FsTransport(Transport):
    """The paper-original shared-filesystem wire (buckets.py).

    Barrier mode is byte-identical on disk to the pre-transport protocol:
    ``.tmp`` in-flight files, epoch-stamped sealed files, absence = empty
    bucket.  Pipelined mode adds per-(src,dst) ``.done`` markers written
    strictly after the data rename, so a receiver polls markers and
    consumes sources incrementally."""

    kind = "fs"
    explicit_completion = False

    def _dir(self, name: str) -> str:
        return os.path.join(self.root, "exchange", name)

    def sender(self, spec: dict) -> BucketWriter:
        return BucketWriter(self._dir(spec["name"]), src=self.me,
                            nshards=self.nshards, width=spec["rec_width"],
                            dtype=spec["rec_dtype"],
                            capacity=spec.get("capacity"))

    def recv(self, spec, epoch, srcs=None, *, live=False, ordered=True,
             timeout=None):
        root = self._dir(spec["name"])
        if not live:
            return self._recv_barrier(spec, root, epoch)
        assert srcs is not None, "pipelined recv needs explicit sources"
        return self._recv_live(spec, root, epoch, srcs, ordered,
                               timeout or self.timeout)

    def _recv_barrier(self, spec, root, epoch):
        # Exactly the legacy scan: whatever is sealed for this epoch IS
        # the epoch's traffic (the completed seal map was the barrier).
        with obs.span("bucket.recv", epoch=epoch, dst=self.me,
                      transport="fs"):
            for src, rows in iter_incoming(root, self.me, epoch,
                                           spec["rec_width"],
                                           spec["rec_dtype"]):
                TRANSPORT_STATS["fs_bytes_in"] += rows.nbytes
                TRANSPORT_STATS["fs_buckets_in"] += 1
                yield src, rows

    def _recv_live(self, spec, root, epoch, srcs, ordered, timeout):
        dt = np.dtype(spec["rec_dtype"])
        width = spec["rec_width"]
        pending = sorted(set(srcs))
        deadline = time.monotonic() + timeout
        with obs.span("bucket.recv", epoch=epoch, dst=self.me,
                      transport="fs", live=True):
            while pending:
                ready: List[int] = []
                for src in list(pending):
                    marker = os.path.join(root,
                                          _done_name(epoch, src, self.me))
                    if os.path.exists(marker):
                        ready.append(src)
                    elif ordered:
                        break      # ascending-src order: wait for this one
                for src in ready:
                    path = os.path.join(root,
                                        _bucket_name(epoch, src, self.me))
                    if os.path.exists(path):
                        raw = np.fromfile(path, dtype=dt)
                        assert raw.size % width == 0, \
                            f"torn bucket file {path}"
                        # Consume BEFORE yielding (matching the mailbox
                        # wires' take-then-yield): an abandoned receiver
                        # must not leave the payload re-deliverable.
                        os.remove(path)
                        TRANSPORT_STATS["fs_bytes_in"] += raw.nbytes
                        TRANSPORT_STATS["fs_buckets_in"] += 1
                        yield src, raw.reshape(-1, width)
                    pending.remove(src)
                if not pending:
                    break
                if ready:          # progress resets the straggler clock
                    deadline = time.monotonic() + timeout
                    continue
                self._check_abort()
                if time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"fs transport: shard {self.me} timed out waiting "
                        f"for sources {pending} (epoch {epoch}, "
                        f"{spec['name']})")
                time.sleep(_POLL)

    def startup(self, fresh: bool) -> None:
        exch = os.path.join(self.root, "exchange")
        if fresh and os.path.isdir(exch):
            shutil.rmtree(exch)
        os.makedirs(exch, exist_ok=True)
        for sub in sorted(os.listdir(exch)):
            cleanup_strays(os.path.join(exch, sub))

    def wipe(self, name: str) -> None:
        shutil.rmtree(self._dir(name), ignore_errors=True)

    def wipe_all(self) -> None:
        exch = os.path.join(self.root, "exchange")
        shutil.rmtree(exch, ignore_errors=True)
        os.makedirs(exch, exist_ok=True)


# ================================================================= mailbox

class _Mailbox:
    """Sealed-bucket store shared by the socket and loopback wires:
    payload bytes plus per-(structure, epoch, dst) sealed-source flags,
    guarded by one condition variable.  Payloads are consumed exactly
    once; sealed flags persist until the structure is wiped, so a second
    recv of a drained epoch yields nothing instead of hanging."""

    def __init__(self):
        self.cond = threading.Condition()
        self._payloads: Dict[tuple, List[Tuple[int, bytes]]] = {}
        self._sealed: Dict[tuple, set] = {}

    def publish(self, name: str, epoch: int, src: int,
                dst_payloads: Dict[int, bytes], dsts) -> None:
        with self.cond:
            for dst, data in dst_payloads.items():
                # Replace, don't append: a sender retry re-publishes the
                # same bytes, and last-write-wins keeps that idempotent.
                lst = self._payloads.setdefault((name, epoch, dst), [])
                lst[:] = [(s, d) for s, d in lst if s != src]
                lst.append((src, data))
            for dst in dsts:
                self._sealed.setdefault((name, epoch, dst), set()).add(src)
            self.cond.notify_all()

    def sealed_set(self, name: str, epoch: int, dst: int) -> set:
        return self._sealed.get((name, epoch, dst), set())

    def take(self, name: str, epoch: int, dst: int, src: int) -> List[bytes]:
        lst = self._payloads.get((name, epoch, dst))
        if not lst:
            return []
        out = [data for s, data in lst if s == src]
        lst[:] = [(s, data) for s, data in lst if s != src]
        return out

    def wipe(self, name: Optional[str] = None) -> None:
        with self.cond:
            for d in (self._payloads, self._sealed):
                for k in [k for k in d if name is None or k[0] == name]:
                    del d[k]
            self.cond.notify_all()


def _mailbox_recv(box: _Mailbox, kind: str, spec: dict, epoch: int, dst: int,
                  srcs, live: bool, ordered: bool, timeout: float,
                  check_abort) -> Iterator[Tuple[int, np.ndarray]]:
    """The shared consumption loop over a :class:`_Mailbox`: barrier mode
    waits for every source's sealed flag then yields ascending; live mode
    yields each source as its flag lands (ascending when ``ordered``)."""
    dt = np.dtype(spec["rec_dtype"])
    width = spec["rec_width"]
    name = spec["name"]
    pending = sorted(set(srcs))
    deadline = time.monotonic() + timeout
    with obs.span("bucket.recv", epoch=epoch, dst=dst, transport=kind,
                  live=live):
        while pending:
            got: List[Tuple[int, List[bytes]]] = []
            with box.cond:
                while True:
                    check_abort()
                    sealed = box.sealed_set(name, epoch, dst)
                    if live and not ordered:
                        avail = [s for s in pending if s in sealed]
                    elif live:
                        avail = []
                        for s in pending:
                            if s not in sealed:
                                break
                            avail.append(s)
                    else:
                        avail = (list(pending)
                                 if all(s in sealed for s in pending)
                                 else [])
                    if avail:
                        for s in avail:
                            got.append((s, box.take(name, epoch, dst, s)))
                            pending.remove(s)
                        break
                    if time.monotonic() >= deadline:
                        raise TimeoutError(
                            f"{kind} transport: shard {dst} timed out "
                            f"waiting for sources {pending} (epoch "
                            f"{epoch}, {name})")
                    box.cond.wait(_POLL)
            for s, payloads in got:
                for data in payloads:
                    # Wire bytes are what traveled (possibly compressed);
                    # wire_decode auto-detects and books the raw/stored
                    # ratio in the codec ledger under tag "transport".
                    wire_len = len(data)
                    data = _codec.wire_decode(data)
                    raw = np.frombuffer(data, dtype=dt)
                    assert raw.size % width == 0, "torn bucket payload"
                    TRANSPORT_STATS[f"{kind}_bytes_in"] += wire_len
                    TRANSPORT_STATS[f"{kind}_buckets_in"] += 1
                    yield s, raw.reshape(-1, width)
            deadline = time.monotonic() + timeout


# ================================================================ loopback

class LoopbackStore(_Mailbox):
    """The shared in-process mailbox of a loopback runtime — one instance
    per :class:`~.cluster.ShardRuntime`, handed to every inline context's
    transport.  Lives entirely in RAM: the thread-parallel inline mode's
    exchange path does zero file I/O."""


class _LoopbackSender(BucketSender):
    """Sender half of the loopback wire: spills accumulate in per-dst
    byte buffers (truncate-on-retry, so the ``bucket_spill`` fault site
    keeps its idempotence contract), seal publishes them into the shared
    store in one atomic (lock-held) step."""

    kind = "loopback"

    def __init__(self, store: LoopbackStore, name: str, src: int,
                 nshards: int, width: int, dtype="int64",
                 capacity: Optional[int] = None, buf_rows: int = 1 << 15,
                 wire_compress: bool = False):
        super().__init__(src, nshards, width, dtype=dtype,
                         capacity=capacity, buf_rows=buf_rows)
        self._store = store
        self._name = name
        self._wire_compress = wire_compress
        self._pend: List[bytearray] = [bytearray() for _ in range(nshards)]

    def _append(self, dst: int, data: bytes) -> None:
        buf = self._pend[dst]
        pre = len(buf)

        def _do(buf=buf, pre=pre, data=data):
            del buf[pre:]          # truncate-on-retry: never duplicates
            buf.extend(data)
        faults.retry_io("bucket_spill", _do, shard=self.src, dst=dst)

    def _publish(self, epoch: int, publish_done: bool) -> None:
        # The sealed flag IS the completion marker on this wire, published
        # in both modes (a mailbox receiver cannot scan for absence).
        payloads = {d: (_codec.wire_encode(bytes(b)) if self._wire_compress
                        else bytes(b))
                    for d, b in enumerate(self._pend) if b}

        def _do():
            self._store.publish(self._name, epoch, self.src, payloads,
                                range(self.nshards))
        faults.retry_io("bucket_seal", _do, shard=self.src)
        self._pend = [bytearray() for _ in range(self.nshards)]


class LoopbackTransport(Transport):
    """In-process mailbox wire for thread-parallel ``inline`` mode.

    Requires every shard to live in one process (the store is a shared
    Python object): ``ClusterConfig`` validation rejects
    ``transport="loopback"`` with ``mode="spawn"`` loudly."""

    kind = "loopback"

    def __init__(self, root, me, nshards, store: LoopbackStore,
                 abort=None, timeout: float = 600.0,
                 wire_compress: bool = False):
        super().__init__(root, me, nshards, abort=abort, timeout=timeout,
                         wire_compress=wire_compress)
        self.store = store

    def sender(self, spec: dict) -> _LoopbackSender:
        return _LoopbackSender(self.store, spec["name"], src=self.me,
                               nshards=self.nshards,
                               width=spec["rec_width"],
                               dtype=spec["rec_dtype"],
                               capacity=spec.get("capacity"),
                               wire_compress=self.wire_compress)

    def recv(self, spec, epoch, srcs=None, *, live=False, ordered=True,
             timeout=None):
        assert srcs is not None, \
            "loopback recv needs explicit sources (nothing to scan)"
        return _mailbox_recv(self.store, "loopback", spec, epoch, self.me,
                             srcs, live, ordered, timeout or self.timeout,
                             self._check_abort)

    def startup(self, fresh: bool) -> None:
        if fresh:
            self.store.wipe()

    def wipe(self, name: str) -> None:
        self.store.wipe(name)

    def wipe_all(self) -> None:
        self.store.wipe()


# ===================================================================== tcp

# Frame header: magic | kind | src | epoch | name-length | payload-length.
# DATA frames carry one destination's complete sealed bucket; a SEALED
# frame is the epoch completion marker (payload-length 0).  A connection
# that dies mid-frame is discarded whole — the receiver records nothing
# for a partial frame, which is exactly the killed-writer guarantee the
# ``.tmp`` discipline gives the fs wire.
_MAGIC = b"RMYB"
_DATA, _SEALED = 0, 1
_HEADER = struct.Struct("<4sBiqHQ")


def _frame(kind: int, src: int, epoch: int, name: str,
           payload: bytes) -> bytes:
    nb = name.encode()
    return _HEADER.pack(_MAGIC, kind, src, epoch, len(nb),
                        len(payload)) + nb + payload


def _recv_exact(conn: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly n bytes, or None on a short read (dead sender)."""
    chunks = []
    while n:
        try:
            b = conn.recv(min(n, 1 << 20))
        except OSError:
            return None
        if not b:
            return None
        chunks.append(b)
        n -= len(b)
    return b"".join(chunks)


class _TcpReceiver(threading.Thread):
    """One listening socket per shard; every inbound connection is framed
    into the shard's mailbox.  Partial/garbage frames are dropped with
    the connection (killed-writer safety); daemon threads, so a killed
    worker process takes its receiver with it."""

    def __init__(self, host: str, me: int):
        super().__init__(daemon=True, name="bucket-tcp-recv")
        self.me = int(me)
        self.box = _Mailbox()
        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind((host, 0))
        self._lsock.listen(64)
        self.addr = self._lsock.getsockname()
        self._closed = False
        self.start()

    def run(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._lsock.accept()
            except OSError:
                return                    # listener closed: shut down
            threading.Thread(target=self._serve, args=(conn,),
                             daemon=True).start()

    def _serve(self, conn: socket.socket) -> None:
        with conn:
            while True:
                head = _recv_exact(conn, _HEADER.size)
                if head is None:
                    return
                magic, kind, src, epoch, nlen, plen = _HEADER.unpack(head)
                if magic != _MAGIC:
                    return                # garbage stream: drop it whole
                name_b = _recv_exact(conn, nlen)
                if name_b is None:
                    return
                payload = b""
                if plen:
                    payload = _recv_exact(conn, plen)
                    if payload is None:
                        return            # torn frame: record NOTHING
                name = name_b.decode()
                if kind == _DATA:
                    self.box.publish(name, epoch, src,
                                     {self.me: payload}, ())
                elif kind == _SEALED:
                    self.box.publish(name, epoch, src, {}, (self.me,))

    def close(self) -> None:
        self._closed = True
        try:
            self._lsock.close()
        except OSError:
            pass


class _TcpSender(BucketSender):
    """Sender half of the socket wire.  Spills spool to the worker's
    private node-local scratch (same truncate-on-retry append as the fs
    ``.tmp`` files — ``bucket_spill`` keeps its fault semantics); seal
    streams each destination's spool as ONE framed message followed by
    the SEALED marker, over a fresh connection per destination.  A retry
    reconnects, so a partial earlier attempt is discarded by the receiver
    with its dead connection — never duplicated."""

    kind = "tcp"

    def __init__(self, transport: "TcpTransport", name: str, src: int,
                 nshards: int, width: int, dtype="int64",
                 capacity: Optional[int] = None, buf_rows: int = 1 << 15):
        super().__init__(src, nshards, width, dtype=dtype,
                         capacity=capacity, buf_rows=buf_rows)
        self._transport = transport
        self._name = name
        self._scratch = os.path.join(transport.scratch, name)
        os.makedirs(self._scratch, exist_ok=True)

    def _tmp_path(self, dst: int) -> str:
        return os.path.join(self._scratch,
                            f"s{self.src:03d}_d{dst:03d}.bin.tmp")

    def _append(self, dst: int, data: bytes) -> None:
        faults.append_bytes("bucket_spill", self._tmp_path(dst), data,
                            shard=self.src, dst=dst)

    def _publish(self, epoch: int, publish_done: bool) -> None:
        # The SEALED frame is this wire's completion marker, sent to every
        # destination in both modes (a socket receiver cannot scan for
        # absence the way the fs reader does).
        peers = self._transport.peers
        assert peers is not None, \
            "tcp transport: seal before the handshake/connect round"
        for d in range(self.nshards):
            tmp = self._tmp_path(d)
            payload = b""
            if os.path.exists(tmp):
                with open(tmp, "rb") as f:
                    payload = f.read()
            if payload and self._transport.wire_compress:
                payload = _codec.wire_encode(payload)

            def _send(d=d, payload=payload, epoch=epoch):
                with socket.create_connection(
                        tuple(peers[d]), timeout=30.0) as s:
                    if payload:
                        s.sendall(_frame(_DATA, self.src, epoch,
                                         self._name, payload))
                    s.sendall(_frame(_SEALED, self.src, epoch,
                                     self._name, b""))
            faults.retry_io("bucket_seal", _send, shard=self.src, dst=d)
            if payload:
                os.remove(tmp)


class TcpTransport(Transport):
    """Socket wire: spawn workers exchange buckets over TCP streams with
    no shared exchange directory.  Each shard runs one receiver thread
    bound to ``(host, 0)``; the coordinator collects the addresses in a
    handshake round after every (re)spawn and broadcasts the peer map
    before any seal."""

    kind = "tcp"

    def __init__(self, root, me, nshards, host: str = "127.0.0.1",
                 abort=None, timeout: float = 600.0,
                 wire_compress: bool = False):
        super().__init__(root, me, nshards, abort=abort, timeout=timeout,
                         wire_compress=wire_compress)
        self.host = host
        self.peers: Optional[Dict[int, tuple]] = None
        # Node-local spool for pre-seal spills: under THIS shard's private
        # directory, never a shared exchange path.
        self.scratch = os.path.join(root, f"shard{me:03d}", "_spool")
        if os.path.isdir(self.scratch):
            for sub in sorted(os.listdir(self.scratch)):
                cleanup_strays(os.path.join(self.scratch, sub))
        self._receiver = _TcpReceiver(host, me)

    def sender(self, spec: dict) -> _TcpSender:
        return _TcpSender(self, spec["name"], src=self.me,
                          nshards=self.nshards, width=spec["rec_width"],
                          dtype=spec["rec_dtype"],
                          capacity=spec.get("capacity"))

    def recv(self, spec, epoch, srcs=None, *, live=False, ordered=True,
             timeout=None):
        assert srcs is not None, \
            "tcp recv needs explicit sources (nothing to scan)"
        return _mailbox_recv(self._receiver.box, "tcp", spec, epoch,
                             self.me, srcs, live, ordered,
                             timeout or self.timeout, self._check_abort)

    def handshake(self):
        return self._receiver.addr

    def connect(self, peers: dict) -> None:
        self.peers = {int(k): tuple(v) for k, v in peers.items()}

    def startup(self, fresh: bool) -> None:
        if fresh:
            self._receiver.box.wipe()

    def wipe(self, name: str) -> None:
        self._receiver.box.wipe(name)
        shutil.rmtree(os.path.join(self.scratch, name), ignore_errors=True)

    def wipe_all(self) -> None:
        self._receiver.box.wipe()
        shutil.rmtree(self.scratch, ignore_errors=True)

    def close(self) -> None:
        self._receiver.close()


# ================================================================= factory

def make_transport(tspec: dict, me: int, nshards: int, root: str,
                   abort: Optional[threading.Event] = None,
                   store: Optional[LoopbackStore] = None,
                   timeout: float = 600.0) -> Transport:
    """Build one shard's transport from its picklable spec
    (``{"kind": ..., "host": ...}`` — what crosses the spawn queue)."""
    kind = tspec.get("kind", "fs")
    wire_compress = bool(tspec.get("wire_compress", False))
    if kind == "fs":
        if wire_compress:
            raise ValueError(
                "wire_compress=True needs a mailbox wire (tcp/loopback) — "
                "the fs bucket layout is a byte-compatibility contract")
        return FsTransport(root, me, nshards, abort=abort, timeout=timeout)
    if kind == "tcp":
        return TcpTransport(root, me, nshards,
                            host=tspec.get("host", "127.0.0.1"),
                            abort=abort, timeout=timeout,
                            wire_compress=wire_compress)
    if kind == "loopback":
        if store is None:
            raise ValueError(
                "transport='loopback' needs the runtime's shared in-process "
                "store — it only works with mode='inline' (spawn workers "
                "live in other processes)")
        return LoopbackTransport(root, me, nshards, store, abort=abort,
                                 timeout=timeout,
                                 wire_compress=wire_compress)
    raise ValueError(
        f"unknown transport kind {kind!r} (choose from {TRANSPORT_KINDS})")
