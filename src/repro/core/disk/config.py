"""Consolidated cluster / checkpoint / recovery configuration.

The engine entrypoints (`breadth_first_search`, `implicit_bfs`,
`sharded_bfs`, `sharded_implicit_bfs`) grew ~20 keyword arguments across
PRs 4–7; this module collapses the cluster-shaped ones into three small
frozen dataclasses and gives the old kwargs a one-release deprecation
shim.  It is also where conflicting cluster settings are rejected loudly
— ONE shared checker instead of per-engine ad-hoc ``ValueError``s.

    cfg = ClusterConfig(nshards=4, transport="tcp", exchange="pipelined")
    disk.breadth_first_search(wd, start, gen, cluster=cfg,
                              checkpoint=CheckpointConfig(dir=ck, every=2),
                              recovery=RecoveryConfig(max_recoveries=3))

Legacy spellings (``nshards=4, shard_mode="spawn", checkpoint_dir=ck,
...``) keep working and warn once per entrypoint per process.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

__all__ = ["ClusterConfig", "CheckpointConfig", "RecoveryConfig",
           "resolve_configs"]

_UNSET = object()          # distinguishes "not passed" from explicit None/0

#: transports a ClusterConfig will accept (mirrors transport.TRANSPORT_KINDS
#: without importing it — config must stay importable in spawn workers
#: before heavy modules load).
_KINDS = ("fs", "tcp", "loopback")
_EXCHANGES = ("barrier", "pipelined")
_MODES = ("spawn", "inline")


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """How the search is sharded and how buckets travel between shards.

    exchange=None resolves to "barrier" — the legacy discipline, kept
    the default so existing runs stay byte-identical on disk and in
    STATS.  exchange="pipelined" opts into overlapped produce/apply (and
    thread-parallel workers in inline mode).

    wire_compress=True zlib-frames each sealed bucket payload on the
    mailbox wires (tcp/loopback) — order-preserving, receiver
    auto-detects, so a compressing sender interoperates with any
    receiver.  The fs wire rejects it: its on-disk bucket layout is a
    byte-compatibility contract (docs/transports.md).
    """

    nshards: int = 1
    mode: str = "spawn"
    transport: str = "fs"
    exchange: Optional[str] = None
    bucket_capacity: Optional[int] = None
    runtime: Optional[object] = None       # adopt an existing ShardRuntime
    timeout: float = 600.0
    host: str = "127.0.0.1"
    wire_compress: bool = False

    def resolved_exchange(self) -> str:
        return self.exchange if self.exchange is not None else "barrier"

    def validate(self) -> "ClusterConfig":
        if self.transport not in _KINDS:
            raise ValueError(
                f"ClusterConfig.transport={self.transport!r}: choose from "
                f"{_KINDS}")
        if self.exchange is not None and self.exchange not in _EXCHANGES:
            raise ValueError(
                f"ClusterConfig.exchange={self.exchange!r}: choose from "
                f"{_EXCHANGES} (or None to resolve per mode)")
        if self.mode not in _MODES:
            raise ValueError(
                f"ClusterConfig.mode={self.mode!r}: choose from {_MODES}")
        if self.nshards < 1:
            raise ValueError(f"ClusterConfig.nshards={self.nshards} < 1")
        if self.wire_compress and self.transport == "fs":
            raise ValueError(
                "ClusterConfig: wire_compress=True needs a mailbox wire "
                "(transport='tcp' or 'loopback') — the fs wire's on-disk "
                "bucket layout is a byte-compatibility contract")
        if self.transport == "loopback" and self.mode == "spawn":
            raise ValueError(
                "ClusterConfig: transport='loopback' is the in-process wire "
                "for mode='inline'; spawn workers live in other processes "
                "and cannot share its store — use transport='tcp' or 'fs'")
        if self.runtime is not None:
            rt_n = getattr(self.runtime, "nshards", None)
            if self.nshards not in (1, rt_n):
                raise ValueError(
                    f"ClusterConfig: runtime= has nshards={rt_n} but "
                    f"nshards={self.nshards} was also passed — drop one "
                    "(an adopted runtime brings its own shard count)")
            rt_kind = getattr(getattr(self.runtime, "transport", None),
                              "kind", "fs")
            if self.transport != "fs" and self.transport != rt_kind:
                raise ValueError(
                    f"ClusterConfig: runtime= runs transport={rt_kind!r} "
                    f"but transport={self.transport!r} was also passed — "
                    "an adopted runtime brings its own wire")
        return self

    @property
    def sharded(self) -> bool:
        # An explicit non-default wire or exchange discipline opts into
        # the sharded runtime even at nshards=1 (a one-shard cluster is a
        # real cluster: same protocol, same transport).
        return (self.runtime is not None or self.nshards > 1
                or self.transport != "fs" or self.exchange is not None)

    def build_runtime(self, workdir: str):
        """Adopt ``runtime=`` or build a fresh ShardRuntime under
        ``workdir/cluster``.  Returns ``(runtime, owns)`` — the engine
        destroys the runtime only when it owns it."""
        if self.runtime is not None:
            return self.runtime, False
        import os

        from .cluster import ShardRuntime
        rt = ShardRuntime(os.path.join(workdir, "cluster"), self.nshards,
                          mode=self.mode, timeout=self.timeout,
                          transport=self.transport,
                          exchange=self.resolved_exchange(),
                          host=self.host,
                          wire_compress=self.wire_compress)
        return rt, True


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    """Where and how often level snapshots publish (docs/checkpointing.md)."""

    dir: Optional[str] = None
    every: int = 1
    resume: bool = False

    def validate(self) -> "CheckpointConfig":
        if self.every < 1:
            raise ValueError(f"CheckpointConfig.every={self.every} < 1")
        if self.dir is None and self.resume:
            raise ValueError(
                "CheckpointConfig: resume=True needs dir= (nowhere to "
                "resume from)")
        return self

    @property
    def enabled(self) -> bool:
        return self.dir is not None


@dataclasses.dataclass(frozen=True)
class RecoveryConfig:
    """In-run self-healing budget (docs/fault-tolerance.md)."""

    max_recoveries: int = 0

    def validate(self) -> "RecoveryConfig":
        if self.max_recoveries < 0:
            raise ValueError(
                f"RecoveryConfig.max_recoveries={self.max_recoveries} < 0")
        return self


_warned: set = set()


def _warn_once(entry: str, names) -> None:
    if entry in _warned:
        return
    _warned.add(entry)
    warnings.warn(
        f"{entry}: keyword(s) {sorted(names)} are deprecated — pass "
        "cluster=ClusterConfig(...), checkpoint=CheckpointConfig(...), "
        "recovery=RecoveryConfig(...) instead (see docs/transports.md)",
        DeprecationWarning, stacklevel=4)


def resolve_configs(entry: str, *,
                    cluster: Optional[ClusterConfig] = None,
                    checkpoint: Optional[CheckpointConfig] = None,
                    recovery: Optional[RecoveryConfig] = None,
                    fused: bool = True,
                    # ---- legacy kwargs (deprecation shim) ----
                    nshards=_UNSET, runtime=_UNSET, shard_mode=_UNSET,
                    bucket_capacity=_UNSET, checkpoint_dir=_UNSET,
                    checkpoint_every=_UNSET, resume=_UNSET,
                    max_recoveries=_UNSET):
    """The one shared checker behind every engine entrypoint.

    Maps legacy kwargs onto the config objects (warning once per
    entrypoint), validates each config, and rejects the cross-cutting
    conflicts: legacy kwargs alongside their config object, and
    ``fused=False`` with any sharding (the unfused reference paths are
    single-process by design).  Returns the validated
    ``(ClusterConfig, CheckpointConfig, RecoveryConfig)`` triple.
    """
    legacy_cluster = {k: v for k, v in
                      [("nshards", nshards), ("runtime", runtime),
                       ("shard_mode", shard_mode),
                       ("bucket_capacity", bucket_capacity)]
                      if v is not _UNSET}
    legacy_ckpt = {k: v for k, v in
                   [("checkpoint_dir", checkpoint_dir),
                    ("checkpoint_every", checkpoint_every),
                    ("resume", resume)] if v is not _UNSET}
    legacy_rec = {k: v for k, v in [("max_recoveries", max_recoveries)]
                  if v is not _UNSET}
    legacy = {**legacy_cluster, **legacy_ckpt, **legacy_rec}

    for cfg, keys, what in ((cluster, legacy_cluster, "cluster="),
                            (checkpoint, legacy_ckpt, "checkpoint="),
                            (recovery, legacy_rec, "recovery=")):
        if cfg is not None and keys:
            raise ValueError(
                f"{entry}: {what} was passed together with legacy "
                f"keyword(s) {sorted(keys)} — pick one spelling")
    if legacy:
        _warn_once(entry, legacy)

    if cluster is None:
        cluster = ClusterConfig(
            nshards=legacy_cluster.get("nshards", 1) or 1,
            mode=legacy_cluster.get("shard_mode", "spawn"),
            bucket_capacity=legacy_cluster.get("bucket_capacity"),
            runtime=legacy_cluster.get("runtime"))
    if checkpoint is None:
        checkpoint = CheckpointConfig(
            dir=legacy_ckpt.get("checkpoint_dir"),
            every=legacy_ckpt.get("checkpoint_every", 1),
            resume=legacy_ckpt.get("resume", False))
    if recovery is None:
        recovery = RecoveryConfig(
            max_recoveries=legacy_rec.get("max_recoveries", 0))

    cluster = cluster.validate()
    checkpoint = checkpoint.validate()
    recovery = recovery.validate()

    if not fused:
        if cluster.sharded:
            raise ValueError(
                f"{entry}: fused=False is the single-process reference "
                "path — it cannot run sharded (drop cluster config or "
                "set fused=True)")
        if checkpoint.enabled:
            raise ValueError(
                f"{entry}: checkpointing requires the fused pass "
                "(fused=False has no level snapshot points)")
    # NOTE: max_recoveries > 0 without a checkpoint dir is deliberately NOT
    # a config error — rolling back with no adoptable checkpoint is a loud
    # runtime ShardFailure ("no coordinated checkpoint"), and tests pin
    # that behaviour.
    return cluster, checkpoint, recovery


def _reset_deprecation_warnings() -> None:
    """Test hook: make the next legacy-kwarg call warn again."""
    _warned.clear()
