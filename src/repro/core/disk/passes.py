"""Streaming pass planner (Tier D) — one traversal, many stages.

Invariant: a pass applies exactly the updates queued strictly BEFORE it
opened (op logs are promoted to a read-only snapshot at open; stages'
mid-pass updates land in the next pass's log), and every planned
traversal is booked once in ``extsort.STATS`` — so "one fused read-write
pass per BFS level" is countable and CI-enforced
(docs/architecture.md §"Pass-budget contract").

Roomy prices every operation in streaming passes over chunked storage
(paper §2), so the cheapest pass is the one that never runs.  A
:class:`PassPlan` names the stages that want to see each chunk of ONE
storage object during ONE traversal and fuses them:

  * a **write** stage rewrites the chunk values (the producer — e.g. the
    implicit BFS's mark-then-rotate step);
  * a **read** stage only observes the values flowing past (a consumer —
    e.g. the next level's expand read, or a frontier count).

Stages run in registration order, each seeing the output of the stages
before it, so a consumer registered after a producer reads the
producer's freshly written values without a second trip to disk.  That
is exactly how ``disk/bfs.py:implicit_bfs`` collapses its per-level
expand-read-then-sync-read-write pair into ONE fused read-write pass:
the level-k expand rides the pass that applies and rotates the
level-(k-1) marks.

Delayed-update discipline: updates a stage queues against the *same*
storage mid-pass are snapshot-isolated — the storage promotes its op
logs to a read-only snapshot when the pass opens
(:meth:`DiskBitArray.run_pass`), so marks generated inside the pass land
in the NEXT pass's log, never this one's.  This is the paper's batching
rule made structural: a pass only ever applies updates issued strictly
before it started.

Accounting lands in :data:`extsort.STATS`, the Tier-D pass ledger
(``rw_passes`` / ``read_passes`` per traversal, ``piggybacked_stages``
for every stage beyond the first that shared one — each of those is a
whole pass the planner deleted; tests assert the budgets).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import numpy as np

from . import extsort

__all__ = ["PassPlan", "record_pass"]

# Per-chunk stage: fn(chunk_start, vals). Write stages return the
# replacement values; read stages' return value is ignored.
Stage = Tuple[Callable[[int, np.ndarray], Optional[np.ndarray]], bool]


def record_pass(n_stages: int, writes: bool) -> None:
    """Book one fused traversal into the shared pass ledger."""
    extsort.STATS["rw_passes" if writes else "read_passes"] += 1
    extsort.STATS["piggybacked_stages"] += max(0, n_stages - 1)


class PassPlan:
    """An ordered bundle of stages to fuse into a single streaming pass.

    Build with the chainable :meth:`writes` / :meth:`reads`, then hand to
    a storage object's pass runner (``DiskBitArray.run_pass``).  The plan
    itself is storage-agnostic: it only knows how to thread one chunk's
    values through its stages (:meth:`apply_chunk`) and what the fused
    traversal costs (:attr:`writes_chunks` decides read vs read-write).
    """

    def __init__(self, name: str = "pass", dirty_only: bool = False):
        """``dirty_only=True`` restricts the traversal to chunks with
        queued ops — for stages whose work provably lives only where
        updates land (e.g. the implicit BFS seed pass: a fresh array is
        all-UNSEEN, so counting/expanding CUR outside the seeds' chunks
        is a guaranteed no-op and the read would be pure waste)."""
        self.name = name
        self.dirty_only = dirty_only
        self._stages: List[Stage] = []

    # ------------------------------------------------------------ build
    def writes(self, fn: Callable[[int, np.ndarray], np.ndarray]) -> "PassPlan":
        """Add a producer stage: vals = fn(chunk_start, vals)."""
        self._stages.append((fn, True))
        return self

    def reads(self, fn: Callable[[int, np.ndarray], None]) -> "PassPlan":
        """Add a consumer stage: fn(chunk_start, vals), observation only."""
        self._stages.append((fn, False))
        return self

    # ---------------------------------------------------------- queries
    @property
    def n_stages(self) -> int:
        return len(self._stages)

    @property
    def writes_chunks(self) -> bool:
        """True if any stage rewrites chunk values (forces a write-back)."""
        return any(w for _, w in self._stages)

    @property
    def forces_full_traversal(self) -> bool:
        """A non-empty plan must see EVERY chunk, not just dirty ones —
        unless it opted into ``dirty_only``."""
        return bool(self._stages) and not self.dirty_only

    # --------------------------------------------------------- execution
    def apply_chunk(self, chunk_start: int, vals: np.ndarray) -> np.ndarray:
        """Thread one chunk's values through the stages, in order."""
        for fn, writes in self._stages:
            if writes:
                vals = np.asarray(fn(chunk_start, vals), vals.dtype)
            else:
                fn(chunk_start, vals)
        return vals
