"""SearchCheckpoint — durable checkpoint/restart of an in-progress BFS.

Roomy's premise is that the authoritative state of a computation lives on
disk, which makes long-running searches restartable "for free" — this
module is that promise made real for both Tier D BFS engines.  A
checkpoint directory holds monotonically versioned snapshot directories::

    <checkpoint_dir>/
        CHECKPOINT            # JSON manifest: the one adoptable version
        v000007/              # a sealed (complete, immutable) snapshot
            META.json         # copy of the manifest payload for v7
            ...engine state...
        v000008.tmp/          # in-flight snapshot of a killed writer: GARBAGE

Publish discipline (the same ``.tmp``-then-atomic-rename rule the bucket
exchange and ChunkStore manifests use):

  1. stage everything into ``v{k}.tmp/`` (including ``META.json``, last),
  2. ``os.rename`` the directory to ``v{k}`` — the atomic seal,
  3. rewrite ``CHECKPOINT`` via its own tmp + ``os.replace``,
  4. best-effort GC of older ``v*`` dirs and stray ``.tmp`` dirs.

A crash at ANY point leaves the previous checkpoint adoptable: before (2)
only a ``.tmp`` stray exists; between (2) and (3) a sealed-but-unpublished
``v{k}`` exists which adoption ignores (the manifest rules); after (3) the
new version is live.  Adoption (:meth:`SearchCheckpoint.latest`):

  * no manifest and no sealed snapshots → ``None`` (nothing to resume);
  * unreadable/truncated manifest → fall back to the highest sealed
    snapshot with a valid ``META.json`` (adopt the previous checkpoint);
    if none exists either, raise :class:`CheckpointError` (fail loudly);
  * manifest names a version whose directory is missing or torn (a
    version rollback / tampering) → raise :class:`CheckpointError` —
    NEVER silently resume from some other state.

Resume re-validates the engine kind, the structural parameters (row
width / state count / chunk layout), the shard count, and the owner-
function golden values recorded at snapshot time — a resumed sharded run
whose owner function disagrees with the checkpointing run would silently
corrupt every partition, so that mismatch is an error, not a warning.

Checkpoint I/O is booked in ``extsort.STATS`` under the dedicated
``ckpt_bytes_read`` / ``ckpt_bytes_written`` / ``ckpt_snapshots`` /
``ckpt_restores`` counters — NEVER in the sort/merge/pass ledgers — so
the per-level pass budgets (docs/architecture.md) are unchanged by
checkpointing, and a resumed run pays exactly the remaining levels'
budgets (asserted in tests/test_checkpoint_bfs.py).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import List, Optional

import numpy as np

from .. import obs
from . import extsort, faults
from .buckets import block_owner_np, hash_owner_np
from .lsm import SortedRunSet
from .store import ChunkStore

__all__ = ["CheckpointError", "SearchCheckpoint", "golden_owner_values",
           "validate_resume"]

MANIFEST = "CHECKPOINT"
META = "META.json"
_VDIR_RE = re.compile(r"^v(\d{6,})$")   # {:06d} grows past 6 digits


class CheckpointError(RuntimeError):
    """An unadoptable or inconsistent checkpoint — resuming would either
    lose the search or corrupt it, so we fail loudly instead."""


# ----------------------------------------------------------- owner goldens

def golden_owner_values(nshards: int, width: int, n_states: int) -> dict:
    """Owner-function fingerprints pinned into every checkpoint manifest.

    A resumed run must route rows/indices to the SAME shards the
    checkpointing run did; these fixed-input golden values are recomputed
    at resume and compared (see docs/architecture.md "Sharded Tier D
    runtime" for why an ownership disagreement is silent corruption).
    """
    rows = (np.arange(1, 8 * max(width, 1) + 1, dtype=np.uint32)
            .reshape(8, max(width, 1)))
    golden = {"hash": hash_owner_np(rows, nshards).tolist()}
    if n_states > 0:
        idx = np.linspace(0, n_states - 1, num=min(9, n_states)).astype(np.int64)
        golden["block"] = block_owner_np(idx, n_states, nshards).tolist()
    return golden


def validate_resume(meta: dict, engine: str, nshards: int, width: int,
                    n_states: int, sharded: bool) -> None:
    """Fail loudly on any structural mismatch between the checkpoint and
    the resuming call: engine kind, snapshot format (single-process vs
    sharded — their payload layouts differ), shard count, row width /
    state count, and the owner-function golden values.  A manifest
    MISSING one of the structural keys is corruption, not a pass —
    defaulting a missing key to the caller's own value would vacuously
    validate it."""
    for key in ("engine", "sharded", "nshards", "width", "n_states",
                "golden", "level_sizes"):
        if key not in meta:
            raise CheckpointError(
                f"checkpoint manifest is missing the structural key "
                f"{key!r} — corrupt or foreign META, refusing to resume")
    if meta["engine"] != engine:
        raise CheckpointError(
            f"checkpoint is for engine {meta['engine']!r}, "
            f"resume requested {engine!r}")
    if bool(meta["sharded"]) != sharded:
        want = "sharded" if meta["sharded"] else "single-process"
        got = "sharded" if sharded else "single-process"
        raise CheckpointError(
            f"checkpoint was written by the {want} runtime, resume is "
            f"{got} — the snapshot layouts are not interchangeable "
            "(even at nshards=1)")
    if int(meta["nshards"]) != nshards:
        raise CheckpointError(
            f"checkpoint was taken with nshards={meta['nshards']}, "
            f"resume runs nshards={nshards} — repartitioning a mid-search "
            "checkpoint is not supported")
    if int(meta["width"]) != width:
        raise CheckpointError(
            f"checkpoint row width {meta['width']} != {width}")
    if int(meta["n_states"]) != n_states:
        raise CheckpointError(
            f"checkpoint n_states {meta['n_states']} != {n_states}")
    want = golden_owner_values(nshards, width, n_states)
    got = meta["golden"]
    for key, vals in want.items():
        if got.get(key) != vals:
            raise CheckpointError(
                f"owner-function golden values diverged ({key}: checkpoint "
                f"{got.get(key)} vs resume {vals}) — the owner maps changed "
                "since this checkpoint was written")


# ------------------------------------------------------------ booked copies

def _copy_file_booked(src: str, dst: str, counter: str) -> int:
    shutil.copyfile(src, dst)
    n = os.path.getsize(dst)
    extsort.STATS[counter] += n
    return n


def copy_dir_booked(src: str, dst: str, counter: str) -> int:
    """Copy every regular file of ``src`` into ``dst`` (flat), booking the
    bytes under the given ckpt counter.  Returns bytes copied."""
    os.makedirs(dst, exist_ok=True)
    total = 0
    for fn in sorted(os.listdir(src)):
        p = os.path.join(src, fn)
        if os.path.isfile(p):
            total += _copy_file_booked(p, os.path.join(dst, fn), counter)
    return total


def _link_or_copy_dir(src: str, dst: str) -> int:
    """Populate ``dst`` with hard links to ``src``'s files — both live
    under the same checkpoint root, so linking normally succeeds and costs
    no data I/O (sealed snapshots are immutable, and GC's rmtree just
    drops link counts).  Falls back to copying per file; returns the bytes
    physically copied (0 when every link landed)."""
    os.makedirs(dst, exist_ok=True)
    copied = 0
    for fn in sorted(os.listdir(src)):
        p = os.path.join(src, fn)
        if not os.path.isfile(p):
            continue
        q = os.path.join(dst, fn)
        try:
            os.link(p, q)
        except OSError:
            shutil.copyfile(p, q)
            copied += os.path.getsize(q)
    return copied


# ---------------------------------------------------------------- the layer

class SearchCheckpoint:
    """Versioned snapshot directory with atomic publish and crash adoption
    (module docstring has the full format and rules)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._next = None       # lazily derived from latest()

    # ------------------------------------------------------------ layout
    def _vdir(self, version: int) -> str:
        return os.path.join(self.root, f"v{version:06d}")

    def _manifest_path(self) -> str:
        return os.path.join(self.root, MANIFEST)

    def _sealed_versions(self) -> List[int]:
        out = []
        for fn in os.listdir(self.root):
            m = _VDIR_RE.match(fn)
            if m and os.path.isdir(os.path.join(self.root, fn)):
                out.append(int(m.group(1)))
        return sorted(out)

    def _read_meta(self, version: int) -> Optional[dict]:
        try:
            with open(os.path.join(self._vdir(version), META)) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    # ---------------------------------------------------------- adoption
    def latest(self) -> Optional[dict]:
        """The adoptable checkpoint's manifest payload, or None if no
        checkpoint has ever been published.  Raises CheckpointError when
        state exists but none of it is safely adoptable (see module
        docstring for the exact rules)."""
        sealed = self._sealed_versions()
        mpath = self._manifest_path()
        if not os.path.exists(mpath):
            if not sealed:
                return None
            # Crash between seal and first manifest write: the highest
            # sealed snapshot is complete by construction — adopt it.
            return self._adopt_fallback(sealed)
        try:
            with open(mpath) as f:
                manifest = json.load(f)
            version = int(manifest["version"])
        except (OSError, ValueError, KeyError, TypeError):
            # Truncated/garbled manifest: the snapshots themselves carry
            # META.json, so fall back to the newest sealed one.
            if sealed:
                return self._adopt_fallback(sealed)
            raise CheckpointError(
                f"corrupt checkpoint manifest {mpath} and no sealed "
                "snapshot to fall back to") from None
        meta = self._read_meta(version)
        if meta is None:
            raise CheckpointError(
                f"checkpoint manifest names version {version} but "
                f"{self._vdir(version)} is missing or torn (version "
                "rollback?) — refusing to guess")
        if int(meta.get("version", version)) != version:
            raise CheckpointError(
                f"snapshot v{version} carries META version "
                f"{meta.get('version')} — manifest/snapshot mismatch")
        return meta

    def _adopt_fallback(self, sealed: List[int]) -> dict:
        for version in reversed(sealed):
            meta = self._read_meta(version)
            if meta is not None and int(meta.get("version", -1)) == version:
                return meta
        raise CheckpointError(
            f"no adoptable snapshot under {self.root}: manifest unreadable "
            f"and sealed dirs {sealed} all lack a valid {META}")

    def snapshot_dir(self, meta: dict) -> str:
        """The sealed directory holding an adopted checkpoint's payload."""
        return self._vdir(int(meta["version"]))

    # ----------------------------------------------------------- publish
    def next_version(self) -> int:
        if self._next is None:
            sealed = self._sealed_versions()
            base = sealed[-1] if sealed else 0
            try:
                published = self.latest()
            except CheckpointError:
                published = None
            if published is not None:
                base = max(base, int(published["version"]))
            self._next = base + 1
        v, self._next = self._next, self._next + 1
        return v

    def begin(self, version: int) -> str:
        """Open a staging directory for ``version`` (clearing any stale
        seal or stray .tmp of the same version from a previous life)."""
        stage = self._vdir(version) + ".tmp"
        shutil.rmtree(stage, ignore_errors=True)
        shutil.rmtree(self._vdir(version), ignore_errors=True)
        os.makedirs(stage)
        return stage

    def publish(self, version: int, meta: dict) -> str:
        """Seal ``v{version}.tmp`` and move the manifest forward, atomically
        at every step; GC older snapshots only after the manifest points at
        the new one.  Returns the sealed snapshot directory (callers
        thread it as ``prev_dir`` for the next incremental snapshot)."""
        meta = dict(meta)
        meta["version"] = version
        stage = self._vdir(version) + ".tmp"
        with open(os.path.join(stage, META), "w") as f:
            json.dump(meta, f)
        # Both steps are atomic renames (idempotent: re-running a rename
        # whose source already moved is caught by the exists() guard in the
        # closure), so transient-errno retry is safe; a giveup here leaves
        # the previous checkpoint adoptable per the crash rules above.
        faults.retry_io(
            "ckpt_publish",
            lambda: (os.path.isdir(stage)
                     and os.rename(stage, self._vdir(version))),
            version=version)                           # atomic seal

        def _point_manifest() -> None:
            tmp = self._manifest_path() + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"version": version}, f)
            os.replace(tmp, self._manifest_path())     # atomic publish
        faults.retry_io("ckpt_publish", _point_manifest, version=version)
        extsort.STATS["ckpt_snapshots"] += 1
        for fn in os.listdir(self.root):               # best-effort GC
            m = _VDIR_RE.match(fn)
            if (m and int(m.group(1)) < version) or fn.endswith(".tmp"):
                if fn != MANIFEST + ".tmp":
                    shutil.rmtree(os.path.join(self.root, fn),
                                  ignore_errors=True)
        return self._vdir(version)


# ================================================== sorted-list engine state
#
# Snapshot payload: one directory per visited run, keyed by the run's
# directory basename (ChunkStore chunks + meta.json manifest copied
# verbatim), plus which run is the current frontier.  Restore copies the
# runs back under the resuming workdir and rebuilds the SortedRunSet
# around them.

def snapshot_sorted_state(stage_dir: str, all_runs: SortedRunSet,
                          cur: Optional[ChunkStore],
                          prev_dir: Optional[str] = None,
                          prev_names=None) -> dict:
    """Stage the visited run set (and frontier identity) into
    ``stage_dir``; returns the engine-state meta to embed in the manifest.

    Incremental rule: a run whose basename appears in ``prev_names`` —
    the runs THIS live search exported into the previous published
    snapshot (``prev_dir``) — is hard-linked from there instead of
    re-copied.  Runs are immutable once added (only compaction replaces
    them, under a fresh name), so total checkpoint I/O across a search is
    O(|visited| + compaction output), not O(levels × |visited|).
    ``prev_names`` must be threaded by the caller from its OWN previous
    snapshot, never read out of an adopted manifest: linking against a
    foreign snapshot could resurrect stale bytes under a recycled run
    name (e.g. a restarted-without-resume search in a reused checkpoint
    directory).
    """
    with obs.span("ckpt.snapshot", engine="sorted", runs=len(all_runs.runs)):
        names: List[str] = []
        cur_name = None
        os.makedirs(stage_dir, exist_ok=True)
        reuse = prev_names if (prev_dir is not None and prev_names) else ()
        for run in all_runs.runs:
            dname = os.path.basename(run.path)
            assert dname not in names, f"duplicate run basename {dname}"
            dst = os.path.join(stage_dir, dname)
            if dname in reuse and os.path.isdir(os.path.join(prev_dir,
                                                             dname)):
                extsort.STATS["ckpt_bytes_written"] += _link_or_copy_dir(
                    os.path.join(prev_dir, dname), dst)
            else:
                extsort.STATS["ckpt_bytes_written"] += run.export_to(dst)
            names.append(dname)
            if cur is not None and run is cur:
                cur_name = dname
        return {"runs": names, "cur": cur_name, "runset_seq": all_runs._seq}


def restore_sorted_state(snap_dir: str, state: dict, all_runs: SortedRunSet,
                         workdir: str, width: int, chunk_rows: int):
    """Rebuild the visited runs under ``workdir`` from a sealed snapshot;
    returns the current-frontier store (None when the shard's frontier was
    empty at snapshot time).  Restored run directories get a fresh
    ``{runset}.ckpt.`` prefix so they can never collide with (or be wiped
    by) the level/compaction stores the resumed loop will create."""
    with obs.span("ckpt.restore", engine="sorted", runs=len(state["runs"])):
        extsort.STATS["ckpt_restores"] += 1
        runs: List[ChunkStore] = []
        cur = None
        for dname in state["runs"]:
            dst = os.path.join(workdir, f"{all_runs.name}.ckpt.{dname}")
            shutil.rmtree(dst, ignore_errors=True)
            copy_dir_booked(os.path.join(snap_dir, dname), dst,
                            "ckpt_bytes_read")
            run = ChunkStore(dst, width, chunk_rows=chunk_rows)
            assert run.sorted, \
                f"restored run {dname} lost its sortedness claim"
            runs.append(run)
            if state.get("cur") == dname:
                cur = run
        all_runs.adopt_runs(runs, seq=int(state["runset_seq"]))
        return cur


# ==================================================== implicit engine state

def snapshot_implicit_state(stage_dir: str, bits) -> dict:
    """Snapshot a DiskBitArray (packed chunks + pending op logs) into
    ``stage_dir/bits``; returns the engine-state meta."""
    with obs.span("ckpt.snapshot", engine="implicit"):
        nbytes = bits.snapshot_to(os.path.join(stage_dir, "bits"))
        return {"bits_bytes": nbytes, "chunk_elems": bits.chunk_elems}


def restore_implicit_state(snap_dir: str, bits) -> None:
    with obs.span("ckpt.restore", engine="implicit"):
        extsort.STATS["ckpt_restores"] += 1
        bits.adopt_snapshot(os.path.join(snap_dir, "bits"))
