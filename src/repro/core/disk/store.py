"""Chunked on-disk row store — Tier D's backing file format.

A store is a directory of fixed-size ``.npy`` chunks plus a small JSON
manifest. Appends are RAM-buffered up to one chunk (Roomy's write buffer);
reads are streaming, chunk at a time. Rows are (width,) unsigned words,
matching Tier J's element codec, but any numpy dtype works.

Sortedness invariant (the sort-once engine's contract)
------------------------------------------------------
A store may claim ``sorted == True`` only when the concatenation of its
chunks, in chunk order, is lexicographically non-decreasing row-wise.  The
flag is never inferred: a producer that emitted sorted output (external
sort, merge pass, streaming dedupe) asserts it via :meth:`mark_sorted`,
which validates chunk-boundary monotonicity against the recorded per-chunk
key ranges and persists the claim in the manifest.  Any subsequent
:meth:`append` clears the flag — unsorted data may then follow.

For 4-byte unsigned stores the manifest also records each chunk's
``[min, max]`` row key (big-endian byte key, see :func:`row_keys`), whether
or not the store is sorted.  Consumers use the ranges to prune chunks that
cannot intersect a query window (``MembershipProbe`` in extsort.py), so a
BFS level never reads visited-set chunks outside the frontier's key range.

The manifest is written only on :meth:`flush` — in-memory state is
authoritative between flushes. A crash between flushes therefore loses
*everything appended since the last flush()*, not just the RAM buffer:
chunk files past the manifest's ``n_chunks`` are invisible on reopen and
will be overwritten. Producers call flush() at their durability points
(end of an operation); mid-stream crash-recovery is explicitly not a
goal of this scratch tier.

Compressed stores (docs/compression.md)
---------------------------------------
``codec="keys"`` stores chunks varint-delta-compressed (disk/codec.py)
instead of raw ``.npy`` — each chunk's rows must be internally sorted
(run producers guarantee this; the encoder raises ``CodecError``
otherwise).  The codec is a *store* property persisted in the manifest,
so a reopened or checkpoint-restored store keeps its own format and a
run set may mix compressed and uncompressed runs freely — ``load_chunk``
decodes transparently.  Rows without a lossless uint64 key packing
(width > 2, or non-4-byte-unsigned dtypes) silently degrade to raw —
the when-not-to-compress rule.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Iterator, List, Optional, Tuple

import numpy as np

from . import codec as _codec
from . import faults


def _write_bytes(path: str, buf: bytes) -> None:
    with open(path, "wb") as f:
        f.write(buf)


def _lex_extreme_key(rows: np.ndarray, mode: str) -> bytes:
    """Byte key of the lexicographic min/max row — O(width) column passes
    (numpy can't reduce min/max over 'S' dtype directly)."""
    sel = np.arange(rows.shape[0])
    for j in range(rows.shape[1]):
        col = np.asarray(rows[sel, j])
        ext = col.min() if mode == "min" else col.max()
        sel = sel[col == ext]
        if sel.size == 1:
            break
    return bytes(row_keys(np.asarray(rows[sel[:1]]))[0])


def row_keys(rows: np.ndarray) -> np.ndarray:
    """(n,) fixed-length byte keys whose order == lexicographic row order.

    Big-endian unsigned words compared bytewise == numeric lexicographic
    order; numpy's 'S' dtype is ordered and searchsorted/isin-compatible.
    """
    w = rows.shape[1]
    be = np.ascontiguousarray(rows, dtype=">u4")
    return be.view(np.dtype(("S", 4 * w))).reshape(-1)


class ChunkStore:
    def __init__(self, path: str, width: int, dtype="uint32",
                 chunk_rows: int = 1 << 16, fresh: bool = False,
                 codec: Optional[str] = None):
        self.path = path
        self.width = width
        self.dtype = np.dtype(dtype)
        self.chunk_rows = int(chunk_rows)
        if fresh and os.path.isdir(path):
            shutil.rmtree(path)
        os.makedirs(path, exist_ok=True)
        self._meta_path = os.path.join(path, "meta.json")
        self.sorted = False
        assert codec in (None, "keys"), f"unknown store codec {codec!r}"
        if codec == "keys" and not (
                self.dtype.kind == "u" and self.dtype.itemsize == 4
                and width <= _codec.max_packable_width()):
            codec = None               # no lossless packing: raw fallback
        self.codec = codec
        # Per-chunk (min_key, max_key) byte pairs; None entries for dtypes
        # without a defined byte-key order (anything but 4-byte unsigned).
        self._chunk_ranges: List[Optional[Tuple[bytes, bytes]]] = []
        if os.path.exists(self._meta_path):
            with open(self._meta_path) as f:
                meta = json.load(f)
            assert meta["width"] == width, "store width mismatch"
            self.n_chunks = meta["n_chunks"]
            self.total_rows = meta["total_rows"]
            self.chunk_rows = meta["chunk_rows"]
            self.sorted = bool(meta.get("sorted", False))
            # The manifest's codec is authoritative for existing chunks
            # (a checkpoint-restored run keeps its own format regardless
            # of what the resuming search would create fresh).  An
            # unknown name is a format-version mismatch — fail loudly
            # before a chunk is misread, not with a numpy parse error.
            self.codec = meta.get("codec")
            if self.codec not in (None, "keys"):
                raise _codec.CodecError(
                    f"store manifest {self._meta_path} names chunk codec "
                    f"{self.codec!r}; this build only decodes 'keys' — "
                    "artifact written by a newer format version?")
            self._chunk_ranges = [
                (bytes.fromhex(r[0]), bytes.fromhex(r[1])) if r else None
                for r in meta.get("chunk_ranges", [None] * self.n_chunks)]
        else:
            # Meta is written lazily (first flush): store directories live on
            # scratch filesystems where every extra file op costs real time.
            self.n_chunks = 0
            self.total_rows = 0
        self._buf: List[np.ndarray] = []
        self._buf_rows = 0
        # True whenever the on-disk manifest lags the in-memory state
        # (chunks flushed since the last _write_meta).
        self._meta_dirty = not os.path.exists(self._meta_path)

    # ------------------------------------------------------------- write
    def append(self, rows: np.ndarray) -> None:
        rows = np.ascontiguousarray(rows, dtype=self.dtype).reshape(-1, self.width)
        self.sorted = False            # producers re-assert via mark_sorted()
        self._buf.append(rows)
        self._buf_rows += rows.shape[0]
        while self._buf_rows >= self.chunk_rows:
            self._flush_chunk(self.chunk_rows)

    def flush(self, mark_sorted: bool = False) -> None:
        """Persist buffered rows + manifest. mark_sorted=True additionally
        claims the sortedness invariant in the same (single) meta write —
        the common producer epilogue ``flush(); mark_sorted()`` would pay
        two manifest writes."""
        while self._buf_rows > 0:
            self._flush_chunk(min(self._buf_rows, self.chunk_rows))
        if mark_sorted:
            self._validate_sorted_ranges()
            self.sorted = True
        self._write_meta()

    def _keyed(self) -> bool:
        return self.dtype.kind == "u" and self.dtype.itemsize == 4

    def _flush_chunk(self, nrows: int) -> None:
        buf = np.concatenate(self._buf, axis=0) if len(self._buf) > 1 else self._buf[0]
        chunk, rest = buf[:nrows], buf[nrows:]
        # Whole-file rewrite → idempotent → safe under transient retry.
        if self.codec == "keys":
            enc = _codec.encode_keys(np.asarray(chunk), tag="extsort")
            faults.retry_io(
                "chunk_flush",
                lambda: _write_bytes(self._chunk_path(self.n_chunks), enc))
        else:
            faults.retry_io(
                "chunk_flush",
                lambda: np.save(self._chunk_path(self.n_chunks), chunk))
        if self._keyed():
            self._chunk_ranges.append((_lex_extreme_key(chunk, "min"),
                                       _lex_extreme_key(chunk, "max")))
        else:
            self._chunk_ranges.append(None)
        self.n_chunks += 1
        self.total_rows += chunk.shape[0]
        self._meta_dirty = True
        self._buf = [rest] if rest.shape[0] else []
        self._buf_rows = rest.shape[0]
        # Meta is deliberately NOT rewritten here: one JSON serialization +
        # atomic rename per chunk turns long append streams into O(n_chunks)
        # meta churn. flush() persists; in-memory state rules in between.

    def _write_meta(self) -> None:
        def _do() -> None:
            tmp = self._meta_path + ".tmp"
            with open(tmp, "w") as f:
                meta = {"width": self.width, "dtype": self.dtype.name,
                        "chunk_rows": self.chunk_rows,
                        "n_chunks": self.n_chunks,
                        "total_rows": self.total_rows,
                        "sorted": self.sorted,
                        "chunk_ranges": [
                            [r[0].hex(), r[1].hex()] if r else None
                            for r in self._chunk_ranges]}
                if self.codec:      # absent == raw: old manifests unchanged
                    meta["codec"] = self.codec
                json.dump(meta, f)
            os.replace(tmp, self._meta_path)       # atomic
        faults.retry_io("meta_write", _do)
        self._meta_dirty = False

    def _validate_sorted_ranges(self) -> None:
        for i in range(1, self.n_chunks):
            cur, prev = self._chunk_ranges[i], self._chunk_ranges[i - 1]
            if cur is not None and prev is not None and cur[0] < prev[1]:
                raise ValueError(
                    f"mark_sorted: chunk {i} starts below chunk {i-1}'s max")

    def mark_sorted(self) -> None:
        """Producer's claim that rows (in chunk order) are globally sorted.

        Requires a flushed store; validates chunk-boundary monotonicity
        against recorded key ranges and persists the flag. (Producers that
        are about to flush anyway should use ``flush(mark_sorted=True)`` —
        one manifest write instead of two.)
        """
        assert self._buf_rows == 0, "flush() before mark_sorted()"
        self._validate_sorted_ranges()
        self.sorted = True
        self._write_meta()

    # ------------------------------------------------------------ export
    def export_to(self, dst: str) -> int:
        """Copy this store (chunks + manifest) to ``dst``, byte-identical.

        Requires a flushed store — the manifest is the durable contract,
        and exporting unflushed RAM state would seal a store whose manifest
        disagrees with its chunk files.  A store whose chunks auto-flushed
        without a manifest write (append of an exact chunk multiple) gets
        its manifest synced here first, so the export can never undercount
        chunks.  Used by the checkpoint layer (disk/checkpoint.py), which
        books the returned byte count under the dedicated ``ckpt_*`` STATS
        counters.  Returns bytes copied.
        """
        assert self._buf_rows == 0, "flush() before export_to()"
        if self._meta_dirty:
            self._write_meta()
        os.makedirs(dst, exist_ok=True)
        total = 0
        for fn in sorted(os.listdir(self.path)):
            p = os.path.join(self.path, fn)
            if os.path.isfile(p):
                shutil.copyfile(p, os.path.join(dst, fn))
                total += os.path.getsize(p)
        return total

    # -------------------------------------------------------------- read
    def _chunk_path(self, i: int) -> str:
        ext = "rmz" if self.codec else "npy"
        return os.path.join(self.path, f"c{i:06d}.{ext}")

    def load_chunk(self, i: int) -> np.ndarray:
        if self.codec == "keys":
            with open(self._chunk_path(i), "rb") as f:
                return _codec.decode_keys(f.read(), tag="extsort")
        return np.load(self._chunk_path(i), mmap_mode="r")

    def key_reader(self, i: int) -> Optional["_codec.CompressedKeyReader"]:
        """Skip-indexed lazy reader for a compressed chunk (None for raw
        stores — callers fall back to :meth:`load_chunk`).  Lets probes
        decode only the blocks a query window intersects."""
        if self.codec != "keys":
            return None
        with open(self._chunk_path(i), "rb") as f:
            return _codec.CompressedKeyReader(f.read(), tag="extsort")

    def chunk_range(self, i: int) -> Optional[Tuple[bytes, bytes]]:
        """(min_key, max_key) of chunk i, or None if the dtype is unkeyed."""
        return self._chunk_ranges[i]

    def iter_chunks(self) -> Iterator[np.ndarray]:
        """Stream chunks (memory-mapped — only touched pages load)."""
        for i in range(self.n_chunks):
            yield self.load_chunk(i)
        if self._buf_rows:
            yield (np.concatenate(self._buf, axis=0)
                   if len(self._buf) > 1 else self._buf[0])

    def read_all(self) -> np.ndarray:
        """Materialize everything (tests/small data only)."""
        parts = list(self.iter_chunks())
        if not parts:
            return np.zeros((0, self.width), self.dtype)
        return np.concatenate([np.asarray(p) for p in parts], axis=0)

    @property
    def size(self) -> int:
        return self.total_rows + self._buf_rows

    def destroy(self) -> None:
        self._buf, self._buf_rows = [], 0
        shutil.rmtree(self.path, ignore_errors=True)
