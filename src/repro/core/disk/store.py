"""Chunked on-disk row store — Tier D's backing file format.

A store is a directory of fixed-size ``.npy`` chunks plus a small JSON
manifest. Appends are RAM-buffered up to one chunk (Roomy's write buffer);
reads are streaming, chunk at a time. Rows are (width,) unsigned words,
matching Tier J's element codec, but any numpy dtype works.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Iterator, List

import numpy as np


class ChunkStore:
    def __init__(self, path: str, width: int, dtype="uint32",
                 chunk_rows: int = 1 << 16, fresh: bool = False):
        self.path = path
        self.width = width
        self.dtype = np.dtype(dtype)
        self.chunk_rows = int(chunk_rows)
        if fresh and os.path.isdir(path):
            shutil.rmtree(path)
        os.makedirs(path, exist_ok=True)
        self._meta_path = os.path.join(path, "meta.json")
        if os.path.exists(self._meta_path):
            with open(self._meta_path) as f:
                meta = json.load(f)
            assert meta["width"] == width, "store width mismatch"
            self.n_chunks = meta["n_chunks"]
            self.total_rows = meta["total_rows"]
            self.chunk_rows = meta["chunk_rows"]
        else:
            self.n_chunks = 0
            self.total_rows = 0
            self._write_meta()
        self._buf: List[np.ndarray] = []
        self._buf_rows = 0

    # ------------------------------------------------------------- write
    def append(self, rows: np.ndarray) -> None:
        rows = np.ascontiguousarray(rows, dtype=self.dtype).reshape(-1, self.width)
        self._buf.append(rows)
        self._buf_rows += rows.shape[0]
        while self._buf_rows >= self.chunk_rows:
            self._flush_chunk(self.chunk_rows)

    def flush(self) -> None:
        while self._buf_rows > 0:
            self._flush_chunk(min(self._buf_rows, self.chunk_rows))
        self._write_meta()

    def _flush_chunk(self, nrows: int) -> None:
        buf = np.concatenate(self._buf, axis=0) if len(self._buf) > 1 else self._buf[0]
        chunk, rest = buf[:nrows], buf[nrows:]
        np.save(self._chunk_path(self.n_chunks), chunk)
        self.n_chunks += 1
        self.total_rows += chunk.shape[0]
        self._buf = [rest] if rest.shape[0] else []
        self._buf_rows = rest.shape[0]
        self._write_meta()

    def _write_meta(self) -> None:
        tmp = self._meta_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"width": self.width, "dtype": self.dtype.name,
                       "chunk_rows": self.chunk_rows,
                       "n_chunks": self.n_chunks,
                       "total_rows": self.total_rows}, f)
        os.replace(tmp, self._meta_path)       # atomic

    # -------------------------------------------------------------- read
    def _chunk_path(self, i: int) -> str:
        return os.path.join(self.path, f"c{i:06d}.npy")

    def iter_chunks(self) -> Iterator[np.ndarray]:
        """Stream chunks (memory-mapped — only touched pages load)."""
        for i in range(self.n_chunks):
            yield np.load(self._chunk_path(i), mmap_mode="r")
        if self._buf_rows:
            yield (np.concatenate(self._buf, axis=0)
                   if len(self._buf) > 1 else self._buf[0])

    def read_all(self) -> np.ndarray:
        """Materialize everything (tests/small data only)."""
        parts = list(self.iter_chunks())
        if not parts:
            return np.zeros((0, self.width), self.dtype)
        return np.concatenate([np.asarray(p) for p in parts], axis=0)

    @property
    def size(self) -> int:
        return self.total_rows + self._buf_rows

    def destroy(self) -> None:
        self._buf, self._buf_rows = [], 0
        shutil.rmtree(self.path, ignore_errors=True)
