"""DiskArray — the paper's RoomyArray on real disk (Tier D).

The array lives as fixed-size chunks on disk; a delayed ``update(i, pay)``
appends (i, pay) to the *op log of the chunk that owns i* — Roomy's
bucketing trick, so a sync streams each chunk exactly once and never seeks:

    for each chunk:  load chunk,  load its op log,  sort ops by index,
                     segment-combine, apply, write back, clear log.

This is the scatter-gather the paper describes for chain reduction; the
Tier-J twin (array.py) runs the same algorithm on device.
"""
from __future__ import annotations

import json
import os
import shutil
import uuid
from typing import Callable

import numpy as np

from .extsort import segment_combine_ordered


class DiskArray:
    def __init__(self, workdir: str, n: int, width: int = 1,
                 dtype="int64", chunk_rows: int = 1 << 16,
                 name: str | None = None):
        self.n = n
        self.width = width
        self.dtype = np.dtype(dtype)
        self.chunk_rows = chunk_rows
        self.n_chunks = -(-n // chunk_rows)
        name = name or f"darray_{uuid.uuid4().hex[:8]}"
        self.path = os.path.join(workdir, name)
        if os.path.isdir(self.path):
            shutil.rmtree(self.path)
        os.makedirs(self.path)
        for c in range(self.n_chunks):
            rows = min(chunk_rows, n - c * chunk_rows)
            np.save(self._chunk_path(c),
                    np.zeros((rows, width), self.dtype))
        self._log_bufs = [[] for _ in range(self.n_chunks)]

    def _chunk_path(self, c: int) -> str:
        return os.path.join(self.path, f"a{c:06d}.npy")

    def _log_path(self, c: int) -> str:
        return os.path.join(self.path, f"log{c:06d}.npy")

    # ------------------------------------------------------ delayed ops
    def update(self, idx: np.ndarray, payload: np.ndarray) -> None:
        """Queue delayed updates (bucketed to owner chunks immediately)."""
        idx = np.asarray(idx, np.int64).reshape(-1)
        payload = np.asarray(payload, self.dtype).reshape(idx.shape[0], -1)
        chunk_of = idx // self.chunk_rows
        order = np.argsort(chunk_of, kind="stable")
        idx, payload, chunk_of = idx[order], payload[order], chunk_of[order]
        bounds = np.searchsorted(chunk_of, np.arange(self.n_chunks + 1))
        for c in range(self.n_chunks):
            lo, hi = bounds[c], bounds[c + 1]
            if hi > lo:
                rec = np.concatenate(
                    [idx[lo:hi, None].astype(np.int64),
                     payload[lo:hi].astype(np.int64)], axis=1)
                self._log_bufs[c].append(rec)

    def _flush_logs(self) -> None:
        for c, buf in enumerate(self._log_bufs):
            if not buf:
                continue
            rec = np.concatenate(buf, axis=0)
            if os.path.exists(self._log_path(c)):
                old = np.load(self._log_path(c))
                rec = np.concatenate([old, rec], axis=0)
            np.save(self._log_path(c), rec)
            self._log_bufs[c] = []

    def sync(self, combine: Callable, apply: Callable) -> None:
        """Execute all queued updates; one streaming pass over the array.

        combine(p1, p2): associative merge of payloads for one index.
        apply(old_rows, agg_rows) -> new_rows (vectorized).
        """
        self._flush_logs()
        for c in range(self.n_chunks):
            lp = self._log_path(c)
            if not os.path.exists(lp):
                continue
            log = np.load(lp)
            os.remove(lp)
            if not log.shape[0]:
                continue
            chunk = np.load(self._chunk_path(c))
            local = (log[:, 0] - c * self.chunk_rows).astype(np.int64)
            pay = log[:, 1:].astype(self.dtype)
            order = np.argsort(local, kind="stable")
            uniq, agg = segment_combine_ordered(local[order], pay[order],
                                                combine)
            chunk[uniq] = apply(chunk[uniq], agg)
            np.save(self._chunk_path(c), chunk)

    # -------------------------------------------------------- streaming
    def map_chunks(self, fn: Callable[[int, np.ndarray], None]) -> None:
        for c in range(self.n_chunks):
            fn(c * self.chunk_rows, np.load(self._chunk_path(c),
                                            mmap_mode="r"))

    def map_update(self, fn: Callable[[int, np.ndarray], np.ndarray]) -> None:
        for c in range(self.n_chunks):
            chunk = np.load(self._chunk_path(c))
            np.save(self._chunk_path(c), fn(c * self.chunk_rows, chunk))

    def reduce(self, elt_fn: Callable, merge_fn: Callable, init):
        acc = init
        for c in range(self.n_chunks):
            acc = merge_fn(acc, elt_fn(np.load(self._chunk_path(c),
                                               mmap_mode="r")))
        return acc

    def read_all(self) -> np.ndarray:
        return np.concatenate([np.load(self._chunk_path(c))
                               for c in range(self.n_chunks)], axis=0)

    def write_all(self, rows: np.ndarray) -> None:
        rows = np.asarray(rows, self.dtype).reshape(self.n, self.width)
        for c in range(self.n_chunks):
            lo = c * self.chunk_rows
            np.save(self._chunk_path(c), rows[lo:lo + self.chunk_rows])

    def destroy(self) -> None:
        shutil.rmtree(self.path, ignore_errors=True)
