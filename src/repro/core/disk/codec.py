"""Compressed chunk codecs — varint-delta sorted keys, RLE 2-bit bytes.

Roomy's binding resource is disk bandwidth (paper §2): both engines are
I/O-bound at the sizes that matter, so bytes saved on scratch are passes
saved on the wall clock.  This module is the one home for the on-disk
compressed formats and their integrity rules:

* ``keys`` codec (id 1) — sorted-run rows.  Ranks within a sorted run
  are non-decreasing integers; the encoder packs each row into a uint64
  key (width ≤ 2 uint32 words — big-endian lexicographic row order ==
  numeric key order), delta-encodes within fixed-size blocks, and
  LEB128-varints the deltas.  A **skip index** of
  ``(first_key, last_key, byte_offset, n_rows)`` per block lets
  ``MembershipProbe`` range-pruning and ``PassPlan`` chunk traversal
  decode only the blocks a query window touches
  (:class:`CompressedKeyReader`).  Width > 2 has no lossless uint64
  packing — stores silently fall back to raw ``.npy`` (the
  when-not-to-compress rule, docs/compression.md).

* ``rle2`` codec (id 2) — the 2-bit array's packed bytes.  A BFS
  array is dominated by long ``UNSEEN`` (0x00) then ``DONE`` (0xFF)
  stretches; runs are stored columnar (values, then varint lengths) so
  both encode and decode are single vectorized numpy passes.

* ``wire`` framing — optional zlib compression of transport bucket
  payloads (docs/transports.md).  Bucket bytes carry *ordered* op logs
  (per-key op order is a correctness contract), so the wire codec is a
  byte-transparent wrapper, never a re-sort.

Integrity is loud by construction: every container ends in a crc32 of
everything before it, varint streams reject truncation / overlong /
overflowing encodings, and block payloads must reproduce their skip
index exactly.  Corrupt data raises :class:`CodecError` — wrong bytes
are never returned.

Accounting: raw vs stored byte counts book into the ``codec`` obs
namespace per caller tag (``{tag}_raw_bytes`` / ``{tag}_stored_bytes``
on encode, ``*_read`` on decode) plus skip-index effectiveness
(``blocks_decoded`` / ``blocks_skipped``).  Codec I/O is segregated
from the sort/merge/pass ledgers the CI gate pins — same discipline as
the ``ckpt_*`` counters — so compressed ≡ uncompressed holds for every
pass budget, by the byte.
"""
from __future__ import annotations

import struct
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from .. import obs

__all__ = [
    "CodecError", "STATS", "MAGIC", "CODEC_KEYS", "CODEC_RLE2",
    "encode_keys", "decode_keys", "CompressedKeyReader",
    "encode_rle2", "decode_rle2", "sniff",
    "wire_encode", "wire_decode",
    "rows_to_u64", "u64_to_rows", "max_packable_width",
]

MAGIC = b"RMZ1"
WIRE_MAGIC = b"RMZW"
CODEC_KEYS = 1
CODEC_RLE2 = 2

#: Rows wider than this have no lossless uint64 key packing → raw fallback.
_MAX_KEY_WIDTH = 2

#: Rows per skip-index block (last block may be short).  Small enough
#: that a narrow probe window decodes a fraction of a chunk, large
#: enough that the 28-byte index entry amortizes to < 0.1 bit/row.
BLOCK_ROWS = 4096

_VARINT_MAX_LEN = 10          # ceil(64 / 7)

# Raw-vs-stored byte ledgers, keyed by caller tag at runtime
# (``extsort_raw_bytes``, ``bits_stored_bytes``, ...).  Lives in its own
# namespace so the sort/merge/pass budgets stay codec-blind.
STATS = obs.counters("codec", {
    "blocks_decoded": 0, "blocks_skipped": 0, "codec_errors": 0})


class CodecError(Exception):
    """Compressed data failed validation (truncated, corrupt, overlong,
    unknown codec/version).  Loud by contract: decoders raise this and
    never return wrong data."""


def _err(msg: str) -> "CodecError":
    STATS["codec_errors"] += 1
    return CodecError(msg)


def book(tag: str, raw: int, stored: int, read: bool = False) -> None:
    """Book one encode (or decode, ``read=True``) into the codec ledger."""
    sfx = "_read" if read else ""
    for key, n in ((f"{tag}_raw_bytes{sfx}", raw),
                   (f"{tag}_stored_bytes{sfx}", stored)):
        STATS[key] = STATS.get(key, 0) + int(n)


def reset_stats() -> None:
    for k in STATS:
        STATS[k] = 0


# ------------------------------------------------------------------ varints

def _varint_encode(vals: np.ndarray) -> bytes:
    """LEB128-encode a uint64 array (vectorized, ≤ 10 byte-lane passes)."""
    vals = np.ascontiguousarray(vals, np.uint64)
    n = vals.shape[0]
    if n == 0:
        return b""
    nb = np.ones(n, np.int64)
    rem = vals >> np.uint64(7)
    while rem.any():
        nb[rem > 0] += 1
        rem >>= np.uint64(7)
    offs = np.zeros(n, np.int64)
    np.cumsum(nb[:-1], out=offs[1:])
    out = np.zeros(int(offs[-1] + nb[-1]), np.uint8)
    for k in range(int(nb.max())):
        sel = nb > k
        byte = ((vals[sel] >> np.uint64(7 * k)) & np.uint64(0x7F)).astype(np.uint8)
        byte[nb[sel] > k + 1] |= 0x80          # continuation bit
        out[offs[sel] + k] = byte
    return out.tobytes()


def _varint_decode(buf: np.ndarray) -> np.ndarray:
    """Decode a whole LEB128 stream to uint64 (vectorized).

    Rejects truncation (trailing continuation bit), overlong encodings
    (> 10 bytes, or a redundant 0x00 terminal byte), and 64-bit overflow.
    """
    if buf.shape[0] == 0:
        return np.zeros(0, np.uint64)
    cont = (buf & 0x80) != 0
    ends = np.flatnonzero(~cont)
    if ends.size == 0 or ends[-1] != buf.shape[0] - 1:
        raise _err("varint stream truncated mid-value")
    starts = np.empty(ends.shape[0], np.int64)
    starts[0] = 0
    starts[1:] = ends[:-1] + 1
    lens = ends - starts + 1
    maxlen = int(lens.max())
    if maxlen > _VARINT_MAX_LEN:
        raise _err(f"overlong varint ({maxlen} bytes > {_VARINT_MAX_LEN})")
    long10 = lens == _VARINT_MAX_LEN
    if long10.any() and (buf[starts[long10] + 9] > 1).any():
        raise _err("varint overflows uint64")
    if ((lens > 1) & (buf[ends] == 0)).any():
        raise _err("overlong varint (redundant zero terminal byte)")
    vals = np.zeros(ends.shape[0], np.uint64)
    for k in range(maxlen):
        sel = lens > k
        vals[sel] |= ((buf[starts[sel] + k] & np.uint64(0x7F)).astype(np.uint64)
                      << np.uint64(7 * k))
    return vals


# --------------------------------------------------------- key <-> row pack

def max_packable_width() -> int:
    return _MAX_KEY_WIDTH


def rows_to_u64(rows: np.ndarray) -> np.ndarray:
    """(n, w≤2) uint32 rows → (n,) uint64 keys; numeric key order ==
    lexicographic row order (== the store's big-endian byte-key order)."""
    rows = np.ascontiguousarray(rows, np.uint32)
    w = rows.shape[1]
    if w == 1:
        return rows[:, 0].astype(np.uint64)
    if w == 2:
        return ((rows[:, 0].astype(np.uint64) << np.uint64(32))
                | rows[:, 1].astype(np.uint64))
    raise _err(f"keys codec packs width <= {_MAX_KEY_WIDTH}, got {w}")


def u64_to_rows(keys: np.ndarray, width: int) -> np.ndarray:
    if width == 1:
        return keys.astype(np.uint32).reshape(-1, 1)
    if width == 2:
        return np.stack(
            [(keys >> np.uint64(32)).astype(np.uint32),
             (keys & np.uint64(0xFFFFFFFF)).astype(np.uint32)], axis=1)
    raise _err(f"keys codec packs width <= {_MAX_KEY_WIDTH}, got {width}")


# ------------------------------------------------------------- keys codec

_KEYS_HDR = struct.Struct("<BIII")       # width, n_rows, n_blocks, block_rows
_SKIP_ENT = struct.Struct("<QQQI")       # first_key, last_key, offset, n_rows


def encode_keys(rows: np.ndarray, tag: str = "codec",
                block_rows: int = BLOCK_ROWS) -> bytes:
    """Compress one sorted chunk of (n, w≤2) uint32 rows.

    Layout: MAGIC, codec id, header, skip index, per-block varint
    payload (absolute first key + deltas), crc32 trailer.  Raises
    CodecError if the rows are not non-decreasing — compression never
    silently reorders.
    """
    rows = np.ascontiguousarray(rows, np.uint32).reshape(-1, rows.shape[-1])
    keys = rows_to_u64(rows)
    n = keys.shape[0]
    if n > 1 and (keys[1:] < keys[:-1]).any():
        raise _err("encode_keys: rows are not sorted (delta would wrap)")
    nblocks = -(-n // block_rows) if n else 0
    index: List[bytes] = []
    payload: List[bytes] = []
    off = 0
    for b in range(nblocks):
        blk = keys[b * block_rows:(b + 1) * block_rows]
        deltas = blk.copy()
        deltas[1:] = blk[1:] - blk[:-1]
        enc = _varint_encode(deltas)
        index.append(_SKIP_ENT.pack(int(blk[0]), int(blk[-1]), off,
                                    blk.shape[0]))
        payload.append(enc)
        off += len(enc)
    body = (MAGIC + bytes([CODEC_KEYS])
            + _KEYS_HDR.pack(rows.shape[1], n, nblocks, block_rows)
            + b"".join(index) + b"".join(payload))
    out = body + struct.pack("<I", zlib.crc32(body))
    book(tag, rows.nbytes, len(out))
    return out


def _check_container(buf: bytes, want_codec: int) -> memoryview:
    """Common magic/codec/crc validation; returns the view after the id
    byte (header onward)."""
    if len(buf) < len(MAGIC) + 1 + 4:
        raise _err("compressed chunk truncated (shorter than any header)")
    if bytes(buf[:4]) != MAGIC:
        raise _err(f"bad magic {bytes(buf[:4])!r} (not a compressed chunk)")
    if buf[4] != want_codec:
        raise _err(f"codec id {buf[4]} != expected {want_codec}")
    (crc,) = struct.unpack("<I", buf[-4:])
    if zlib.crc32(memoryview(buf)[:-4]) != crc:
        raise _err("crc32 mismatch: compressed chunk corrupt")
    return memoryview(buf)[5:-4]


class CompressedKeyReader:
    """Skip-indexed view over one ``keys``-codec chunk.

    Decodes blocks lazily and caches them, so a probe whose query window
    touches a fraction of the chunk pays a fraction of the decode —
    the compressed analogue of manifest-range chunk pruning, one level
    finer.  ``keys_between`` returns the (sorted, contiguous) keys of
    every block intersecting ``[lo, hi]``; membership searchsorted over
    that span is exact for any query inside the window.
    """

    def __init__(self, buf: bytes, tag: str = "codec"):
        body = _check_container(buf, CODEC_KEYS)
        self._tag = tag
        self.width, self.n_rows, self.n_blocks, self.block_rows = \
            _KEYS_HDR.unpack_from(body, 0)
        isz = self.n_blocks * _SKIP_ENT.size
        if len(body) < _KEYS_HDR.size + isz:
            raise _err("skip index truncated")
        self.first = np.empty(self.n_blocks, np.uint64)
        self.last = np.empty(self.n_blocks, np.uint64)
        self._offs = np.empty(self.n_blocks + 1, np.int64)
        self._rows = np.empty(self.n_blocks, np.int64)
        for b in range(self.n_blocks):
            fk, lk, off, nr = _SKIP_ENT.unpack_from(
                body, _KEYS_HDR.size + b * _SKIP_ENT.size)
            self.first[b], self.last[b], self._offs[b], self._rows[b] = \
                fk, lk, off, nr
        self._payload = np.frombuffer(
            body, np.uint8, offset=_KEYS_HDR.size + isz)
        self._offs[-1] = self._payload.shape[0]
        if int(self._rows.sum()) != self.n_rows or (self._rows <= 0).any():
            raise _err("skip index row counts disagree with header")
        if self.n_blocks and ((self.first[1:] < self.last[:-1]).any()
                              or (self.last < self.first).any()):
            raise _err("skip index not sorted")
        self._cache: Dict[int, np.ndarray] = {}

    def _decode_block(self, b: int) -> np.ndarray:
        blk = self._cache.get(b)
        if blk is not None:
            return blk
        lo, hi = int(self._offs[b]), int(self._offs[b + 1])
        if hi > self._payload.shape[0] or lo > hi:
            raise _err("block payload truncated")
        deltas = _varint_decode(self._payload[lo:hi])
        if deltas.shape[0] != self._rows[b]:
            raise _err(f"block {b}: {deltas.shape[0]} values, "
                       f"skip index says {self._rows[b]}")
        keys = np.cumsum(deltas, dtype=np.uint64)
        if keys[0] != self.first[b] or keys[-1] != self.last[b]:
            raise _err(f"block {b}: decoded ends disagree with skip index")
        self._cache[b] = keys
        STATS["blocks_decoded"] += 1
        book(self._tag, keys.nbytes // 2 * self.width, hi - lo, read=True)
        return keys

    def block_span(self, lo: int, hi: int) -> Tuple[int, int]:
        """[b0, b1) of blocks whose key range intersects [lo, hi] —
        binary search over the skip index, no payload touched."""
        b0 = int(np.searchsorted(self.last, np.uint64(lo), side="left"))
        b1 = int(np.searchsorted(self.first, np.uint64(hi), side="right"))
        return b0, max(b0, b1)

    def keys_between(self, lo: int, hi: int) -> np.ndarray:
        b0, b1 = self.block_span(lo, hi)
        STATS["blocks_skipped"] += self.n_blocks - (b1 - b0)
        parts = [self._decode_block(b) for b in range(b0, b1)]
        if not parts:
            return np.zeros(0, np.uint64)
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def all_keys(self) -> np.ndarray:
        parts = [self._decode_block(b) for b in range(self.n_blocks)]
        if not parts:
            return np.zeros(0, np.uint64)
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def all_rows(self) -> np.ndarray:
        return u64_to_rows(self.all_keys(), self.width)


def decode_keys(buf: bytes, tag: str = "codec") -> np.ndarray:
    """Full decode: compressed chunk → (n, w) uint32 rows."""
    return CompressedKeyReader(buf, tag=tag).all_rows()


# -------------------------------------------------------------- rle2 codec

_RLE_HDR = struct.Struct("<QI")          # n_bytes, n_runs


def encode_rle2(packed: np.ndarray, tag: str = "codec") -> bytes:
    """RLE a packed 2-bit chunk (uint8 bytes, 4 elements each).

    Columnar layout — run values as raw bytes, run lengths as one varint
    stream — so decode is a single np.repeat.  Long UNSEEN/DONE
    stretches (0x00 / 0xFF) collapse to a few bytes each.
    """
    packed = np.ascontiguousarray(packed, np.uint8).reshape(-1)
    n = packed.shape[0]
    if n == 0:
        starts = np.zeros(0, np.int64)
    else:
        starts = np.flatnonzero(np.concatenate(
            [[True], packed[1:] != packed[:-1]]))
    lens = np.diff(np.concatenate([starts, [n]])).astype(np.uint64)
    body = (MAGIC + bytes([CODEC_RLE2])
            + _RLE_HDR.pack(n, starts.shape[0])
            + packed[starts].tobytes() + _varint_encode(lens))
    out = body + struct.pack("<I", zlib.crc32(body))
    book(tag, n, len(out))
    return out


def decode_rle2(buf: bytes, tag: str = "codec") -> np.ndarray:
    """Compressed 2-bit chunk → packed uint8 array, validated end to end."""
    body = _check_container(buf, CODEC_RLE2)
    n_bytes, n_runs = _RLE_HDR.unpack_from(body, 0)
    if len(body) < _RLE_HDR.size + n_runs:
        raise _err("rle2 values truncated")
    vals = np.frombuffer(body, np.uint8, count=n_runs,
                         offset=_RLE_HDR.size)
    lens = _varint_decode(np.frombuffer(
        body, np.uint8, offset=_RLE_HDR.size + n_runs))
    if lens.shape[0] != n_runs:
        raise _err(f"rle2: {lens.shape[0]} run lengths for {n_runs} runs")
    if n_runs and ((lens == 0).any() or (vals[1:] == vals[:-1]).any()):
        raise _err("rle2: zero-length or unmerged runs (non-canonical)")
    if int(lens.sum()) != n_bytes:
        raise _err("rle2: run lengths do not sum to the declared size")
    out = np.repeat(vals, lens.astype(np.int64))
    book(tag, n_bytes, len(buf), read=True)
    return out


# ----------------------------------------------------------------- sniffing

def sniff(buf: bytes) -> Optional[int]:
    """Codec id of a compressed chunk, or None for anything else (e.g. a
    raw ``.npy``).  Only looks at the magic — validation happens on
    decode."""
    if len(buf) >= 5 and bytes(buf[:4]) == MAGIC:
        return buf[4]
    return None


# ------------------------------------------------------------- wire framing

def wire_encode(payload: bytes, tag: str = "transport") -> bytes:
    """zlib-frame one transport bucket payload (order-preserving: bucket
    bytes are ordered op logs, so the wire codec never re-sorts)."""
    out = WIRE_MAGIC + zlib.compress(payload, 6)
    book(tag, len(payload), len(out))
    return out


def wire_decode(buf: bytes, tag: str = "transport") -> bytes:
    """Inverse of :func:`wire_encode`; plain payloads pass through, so a
    compressing sender interoperates with an agnostic receiver."""
    if buf[:4] != WIRE_MAGIC:
        return buf
    try:
        payload = zlib.decompress(bytes(buf[4:]))
    except zlib.error as e:
        raise _err(f"wire payload corrupt: {e}") from None
    book(tag, len(payload), len(buf), read=True)
    return payload
