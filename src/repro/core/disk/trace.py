"""JSONL trace sessions for obs.py spans, with a report/export CLI.

A trace file is JSON Lines: a ``meta`` record, then one record per
finished span (coordinator spans plus worker spans merged in at every
level barrier, tagged ``shard=k``), then a final ``summary`` record
holding the merged registry snapshot.  One distributed run — one file.

    from repro.core.disk import trace
    trace.start("run.jsonl")
    ... search ...
    trace.stop()

CLI (PYTHONPATH=src):

    python -m repro.core.disk.trace report run.jsonl
    python -m repro.core.disk.trace export-chrome run.jsonl -o run.json

``report`` prints the per-level table (wall time, passes, bytes,
bytes/s, retries, recoveries, per-shard skew); ``export-chrome`` writes
Chrome trace-event JSON loadable in Perfetto (ui.perfetto.dev) or
chrome://tracing, one track per shard.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional

from .. import obs


class TraceSession:
    """Line-buffered JSONL writer wired in as the obs span sink."""

    def __init__(self, path: str, meta: Optional[dict] = None):
        self.path = path
        self._f = open(path, "w", buffering=1)
        rec = {"type": "meta", "version": 1, "pid": os.getpid(),
               "unix_time": time.time()}
        if meta:
            rec.update(meta)
        self.write(rec)

    def write(self, rec: dict) -> None:
        self._f.write(json.dumps(rec, separators=(",", ":"),
                                 sort_keys=True) + "\n")

    def close(self) -> None:
        self.write({"type": "summary", **obs.snapshot()})
        self._f.close()


_SESSION: Optional[TraceSession] = None


def start(path: str, meta: Optional[dict] = None) -> TraceSession:
    """Begin tracing this process into ``path`` and export
    ``ROOMY_TRACE=1`` so shard workers spawned (or recovery-respawned)
    after this call turn on buffered tracing and ship their spans back
    at each level barrier."""
    global _SESSION
    if _SESSION is not None:
        raise RuntimeError(f"trace already active: {_SESSION.path}")
    _SESSION = TraceSession(path, meta=meta)
    os.environ[obs.ENV_VAR] = "1"
    obs.enable(sink=_SESSION.write)
    return _SESSION


def stop() -> Optional[str]:
    """Finish the active session: flush, write the summary record, turn
    tracing off.  Returns the trace path (None if nothing was active)."""
    global _SESSION
    if _SESSION is None:
        return None
    for rec in obs.drain_spans():      # belt and braces: sink mode buffers 0
        _SESSION.write(rec)
    path = _SESSION.path
    _SESSION.close()
    _SESSION = None
    os.environ.pop(obs.ENV_VAR, None)
    obs.disable()
    return path


# ------------------------------------------------------------------ reading

def read(path: str):
    """Parse a trace file -> (meta, spans, summary)."""
    meta, spans, summary = {}, [], {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.get("type")
            if kind == "meta":
                meta = rec
            elif kind == "span":
                spans.append(rec)
            elif kind == "summary":
                summary = rec
    return meta, spans, summary


def _metric(rec: dict, *keys: str) -> int:
    m = rec.get("metrics") or {}
    return sum(m.get(k, 0) for k in keys)


_PASS_KEYS = ("extsort.sort_passes", "extsort.merge_passes",
              "extsort.rw_passes", "extsort.read_passes")
_BYTE_KEYS = ("bits.bytes_read", "bits.bytes_written")


def level_rows(spans: List[dict]) -> List[dict]:
    """Aggregate ``bfs.level`` spans into one row per level.

    Counter metrics come from the coordinator span only (``shard`` is
    None there): in spawn mode the coordinator folds worker counter
    deltas inside the level barrier, and in inline mode workers share
    the coordinator's registry — either way the coordinator span's
    deltas already include the workers', so adding worker spans on top
    would double-count.  Worker spans contribute the per-shard wall
    times the skew column is computed from.

    ``recovery.rollback`` spans fold into their level's retries /
    recoveries columns: a rollback happens OUTSIDE any ``bfs.level``
    span (the failed level's span already closed when its collective
    raised), so its counters would otherwise be invisible here.
    """
    levels: Dict[int, dict] = {}
    for s in spans:
        if s.get("sid") not in ("bfs.level", "recovery.rollback"):
            continue
        attrs = s.get("attrs") or {}
        lev = attrs.get("level")
        if lev is None:
            continue
        row = levels.setdefault(int(lev), {
            "level": int(lev), "wall_us": 0, "shard_us": {}, "passes": 0,
            "bytes": 0, "retries": 0, "recoveries": 0, "replay": False})
        if s.get("sid") == "recovery.rollback":
            row["retries"] += _metric(s, "extsort.io_retries")
            row["recoveries"] += max(1, _metric(s, "extsort.recoveries"))
            continue
        if s.get("shard") is None:
            row["wall_us"] += s.get("dur_us", 0)
            row["passes"] += _metric(s, *_PASS_KEYS)
            row["bytes"] += _metric(s, *_BYTE_KEYS)
            row["retries"] += _metric(s, "extsort.io_retries")
            row["recoveries"] += _metric(s, "extsort.recoveries")
        else:
            sh = row["shard_us"]
            k = int(s["shard"])
            sh[k] = sh.get(k, 0) + s.get("dur_us", 0)
        if attrs.get("replay"):
            row["replay"] = True
    out = []
    for lev in sorted(levels):
        row = levels[lev]
        walls = list(row["shard_us"].values())
        row["skew_pct"] = (100.0 * (max(walls) - min(walls)) / max(walls)
                          if len(walls) >= 2 and max(walls) > 0 else 0.0)
        # single-process runs have no coordinator/worker split: the one
        # bfs.level span per level carries both the wall time and metrics
        if row["wall_us"] == 0 and walls:
            row["wall_us"] = max(walls)
        out.append(row)
    return out


def _human_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024.0 or unit == "TB":
            return f"{n:.0f}{unit}" if unit == "B" else f"{n:.1f}{unit}"
        n /= 1024.0
    return f"{n:.1f}TB"


def report_json(path: str) -> dict:
    """Machine-readable form of :func:`report`: the same per-level rows
    plus totals, as one JSON-serializable dict.  The serve bench and CI
    assertions consume this instead of scraping the printed table
    (``shard_us`` keys become strings in transit — JSON has no int keys).
    """
    meta, spans, summary = read(path)
    rows = level_rows(spans)
    tot = {k: sum(r[k] for r in rows)
           for k in ("wall_us", "passes", "bytes", "retries", "recoveries")}
    return {
        "trace": path,
        "meta": meta,
        "levels": rows,
        "totals": tot,
        "replayed_levels": [r["level"] for r in rows if r["replay"]],
        "rollback_spans": sum(1 for s in spans
                              if s.get("sid") == "recovery.rollback"),
    }


def report(path: str, out=None) -> List[dict]:
    """Print the per-level table for a trace file; returns the rows."""
    out = out or sys.stdout
    meta, spans, summary = read(path)
    rows = level_rows(spans)
    shards = sorted({s["shard"] for s in spans if s.get("shard") is not None})
    src = meta.get("example") or meta.get("argv") or path
    line = (f"trace: {src}  spans={len(spans)}"
            + (f"  shards={len(shards)}" if shards else ""))
    print(line, file=out)
    hdr = (f"{'level':>6} {'wall_s':>8} {'passes':>7} {'bytes':>10} "
           f"{'bytes/s':>10} {'retries':>8} {'recov':>6} {'skew%':>6}")
    print(hdr, file=out)
    tot = {"wall_us": 0, "passes": 0, "bytes": 0, "retries": 0,
           "recoveries": 0}
    replay_seen = False
    for r in rows:
        wall_s = r["wall_us"] / 1e6
        bps = r["bytes"] / wall_s if wall_s > 0 else 0.0
        mark = "*" if r["replay"] else " "
        replay_seen = replay_seen or r["replay"]
        print(f"{r['level']:>5}{mark} {wall_s:>8.3f} {r['passes']:>7} "
              f"{_human_bytes(r['bytes']):>10} {_human_bytes(bps):>9}/s "
              f"{r['retries']:>8} {r['recoveries']:>6} "
              f"{r['skew_pct']:>6.1f}", file=out)
        for k in tot:
            tot[k] += r[k]
    wall_s = tot["wall_us"] / 1e6
    bps = tot["bytes"] / wall_s if wall_s > 0 else 0.0
    print(f"{'total':>6} {wall_s:>8.3f} {tot['passes']:>7} "
          f"{_human_bytes(tot['bytes']):>10} {_human_bytes(bps):>9}/s "
          f"{tot['retries']:>8} {tot['recoveries']:>6} {'':>6}", file=out)
    if replay_seen:
        print("(* = level replayed by rollback-and-replay recovery)",
              file=out)
    n_rollbacks = sum(1 for s in spans if s.get("sid") == "recovery.rollback")
    if n_rollbacks:
        print(f"recovery.rollback spans: {n_rollbacks}", file=out)
    return rows


# ----------------------------------------------------------- chrome export

def export_chrome(path: str, out_path: Optional[str] = None) -> str:
    """Write Chrome trace-event JSON (Perfetto-loadable).  Spans map to
    complete ("X") events; each shard gets its own pid track (pid 0 is
    the coordinator), nesting is recovered from ts/dur containment."""
    meta, spans, summary = read(path)
    t0 = min((s["ts_us"] for s in spans), default=0)
    events = []
    pids = set()
    for s in spans:
        pid = 0 if s.get("shard") is None else int(s["shard"]) + 1
        pids.add(pid)
        args = dict(s.get("attrs") or {})
        args.update(s.get("metrics") or {})
        events.append({"ph": "X", "name": s["sid"], "cat": "roomy",
                       "ts": s["ts_us"] - t0, "dur": s.get("dur_us", 0),
                       "pid": pid, "tid": 0, "args": args})
    for pid in sorted(pids):
        name = "coordinator" if pid == 0 else f"shard {pid - 1}"
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "tid": 0, "ts": 0, "args": {"name": name}})
    out_path = out_path or (os.path.splitext(path)[0] + ".chrome.json")
    with open(out_path, "w") as f:
        json.dump({"traceEvents": events,
                   "displayTimeUnit": "ms",
                   "otherData": {k: v for k, v in meta.items()
                                 if k != "type"}}, f)
    return out_path


# ---------------------------------------------------------------------- CLI

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.disk.trace",
        description="Inspect Roomy JSONL trace files (docs/observability.md)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rp = sub.add_parser("report", help="per-level wall/pass/byte table")
    rp.add_argument("trace")
    rp.add_argument("--json", action="store_true",
                    help="emit the report as one JSON object (levels + "
                         "totals) instead of the human table")
    ep = sub.add_parser("export-chrome",
                        help="write Chrome trace-event JSON for Perfetto")
    ep.add_argument("trace")
    ep.add_argument("-o", "--out", default=None,
                    help="output path (default: <trace>.chrome.json)")
    args = ap.parse_args(argv)
    if args.cmd == "report":
        if args.json:
            json.dump(report_json(args.trace), sys.stdout)
            print()
        else:
            report(args.trace)
    else:
        out = export_chrome(args.trace, args.out)
        print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
