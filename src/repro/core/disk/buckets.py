"""Disk-backed delayed-op buckets — the paper's per-(src,dst) bucket files.

Invariant: readers only ever see *sealed* (atomically renamed) bucket
files — a writer killed mid-epoch leaves nothing but ignorable ``.tmp``
strays — and the numpy owner maps here are bit-identical to Tier J's
``core/sharding.py`` maps (golden-pinned in tests/test_cluster.py),
since an ownership disagreement silently corrupts a sharded structure.
Overflow past a bucket's per-epoch capacity is dropped AND counted
exactly, never silently.

Roomy ships every delayed operation to the disk that owns its target in
fixed-capacity bucket files, one per (source, destination) pair, and applies
them in a streaming batch at sync (paper §2–3).  Tier J already has the
device-mesh analogue (``core/delayed.bin_by_dest`` + ``all_to_all``); this
module is the Tier D original: real files on a filesystem shared by the
shard workers (``cluster.py``), with the same conventions —

  * a bucket holds at most ``capacity`` rows per exchange epoch; overflow
    rows are *dropped and counted* exactly like ``bin_by_dest`` (callers
    size the capacity for their tolerance, and ``ShardRuntime.sync()``
    surfaces the exact totals),
  * rows are fixed-width records of one numpy dtype, appended raw (no
    header) so spills cost O(spill) bytes,
  * a writer accumulates into ``*.tmp`` files during the epoch and
    *seals* them (atomic rename) at sync: a worker killed mid-epoch
    leaves only ``.tmp`` strays, which readers ignore and
    :func:`cleanup_strays` removes.  A sealed file is immutable; the
    destination deletes it after applying.

Owner functions
---------------
The numpy owner maps live here (this package is jax-free — worker
processes must not pay a jax import to route rows).  They are mirrors of
the Tier J maps in ``core/sharding.py`` and MUST stay bit-identical to
them: a worker disagreeing with the coordinator about ownership silently
corrupts a sharded structure.  ``tests/test_cluster.py`` pins both sides
to golden values.
"""
from __future__ import annotations

import os
from typing import Iterator, List, Optional, Tuple

import numpy as np

from .. import obs
from . import faults

__all__ = [
    "hash_rows_np", "hash_owner_np", "block_owner_np", "block_size",
    "BucketSender", "BucketWriter", "iter_incoming", "incoming_files",
    "cleanup_strays",
]


# The per-backend bytes-on-wire ledger (docs/observability.md).  One flat
# namespace, keys prefixed by backend kind: a sharded run reports exactly
# which wire its buckets rode and how many bytes crossed it.  Registered
# eagerly so scopes/snapshots always see every key.
TRANSPORT_STATS = obs.counters("transport", {
    f"{kind}_{which}": 0
    for kind in ("fs", "tcp", "loopback")
    for which in ("bytes_out", "bytes_in", "buckets_out", "buckets_in")
})


# ------------------------------------------------------------- owner maps

def hash_rows_np(rows: np.ndarray, seed: int = 0x9E3779B9) -> np.ndarray:
    """Numpy mirror of ``types.hash_rows`` — same FNV-ish mix, bit for bit."""
    rows = np.asarray(rows)
    h = np.full(rows.shape[:-1], np.uint32(seed), np.uint32)
    with np.errstate(over="ignore"):
        for j in range(rows.shape[-1]):
            w = rows[..., j].astype(np.uint32)
            h = (h ^ w) * np.uint32(0x01000193)
            h = h ^ (h >> np.uint32(15))
        h = h * np.uint32(0x85EBCA6B)
    return h ^ (h >> np.uint32(13))


def hash_owner_np(rows: np.ndarray, nshards: int) -> np.ndarray:
    """Owner shard of an element/key row under hash distribution."""
    return (hash_rows_np(rows) % np.uint32(nshards)).astype(np.int32)


def block_size(n: int, nshards: int) -> int:
    """Rows per shard under block distribution (ceil — last shard short)."""
    return -(-n // nshards)


def block_owner_np(idx: np.ndarray, n: int, nshards: int) -> np.ndarray:
    """Owner shard of array index idx under block distribution."""
    per = block_size(n, nshards)
    return (np.asarray(idx, np.int64) // per).astype(np.int32)


# ---------------------------------------------------------- file protocol
#
# Final (sealed) bucket: e{epoch:06d}_s{src:03d}_d{dst:03d}.bin
# In-flight bucket:      the same + ".tmp"  (ignorable garbage if orphaned)
# Seal marker:           e{epoch:06d}_s{src:03d}_d{dst:03d}.done
#                        (pipelined exchange only — written AFTER the data
#                        rename, so a marker guarantees the bucket, if any,
#                        is already published; absence of a marker in
#                        barrier mode keeps the on-disk layout byte
#                        identical to the pre-transport protocol)

def _bucket_name(epoch: int, src: int, dst: int) -> str:
    return f"e{epoch:06d}_s{src:03d}_d{dst:03d}.bin"


def _done_name(epoch: int, src: int, dst: int) -> str:
    return f"e{epoch:06d}_s{src:03d}_d{dst:03d}.done"


class BucketSender:
    """Backend-independent half of the bucket protocol: routing rows to
    destinations, per-epoch capacity enforcement with EXACT dropped
    counts, and RAM-bounded buffering.  This is the interface contract
    every transport backend must preserve (docs/transports.md):

      * ``put(dest, rows)`` buffers rows toward their destination shard,
        spilling through ``_append`` past ``buf_rows`` buffered rows so
        an epoch's traffic never outgrows RAM.  Rows past a destination's
        per-epoch ``capacity`` are dropped AND counted, never silently.
      * ``seal(epoch)`` flushes, atomically publishes every destination's
        bucket through ``_publish`` and returns the exact per-destination
        dropped counts.  Until seal, a reader must see NOTHING of the
        epoch's traffic; a sender killed mid-epoch leaves only ignorable
        strays.

    Subclasses supply the wire: ``_append(dst, data)`` persists one spill
    (idempotent under the transient-retry discipline — ``faults``' torn/
    retry semantics) and ``_publish(epoch, publish_done)`` makes every
    non-empty destination bucket visible atomically.  ``kind`` names the
    backend in the ``transport`` counter namespace."""

    kind = "abstract"

    def __init__(self, src: int, nshards: int, width: int,
                 dtype="int64", capacity: Optional[int] = None,
                 buf_rows: int = 1 << 15):
        self.src = int(src)
        self.nshards = int(nshards)
        self.width = int(width)
        self.dtype = np.dtype(dtype)
        self.capacity = None if capacity is None else int(capacity)
        self.buf_rows = int(buf_rows)
        self._bufs: List[List[np.ndarray]] = [[] for _ in range(nshards)]
        self._nbuf = 0
        # Rows accepted / dropped / bytes appended per destination THIS
        # epoch (bytes feed the per-backend bytes-on-wire counters).
        self._accepted = np.zeros(nshards, np.int64)
        self._dropped = np.zeros(nshards, np.int64)
        self._bytes = np.zeros(nshards, np.int64)

    def put(self, dest: np.ndarray, rows: np.ndarray) -> None:
        """Route rows to their destination buckets.  dest: (m,) shard ids in
        [0, nshards); rows: (m, width).  Rows past a destination's epoch
        capacity are dropped and counted (the bin_by_dest convention)."""
        dest = np.asarray(dest, np.int64).reshape(-1)
        rows = np.ascontiguousarray(rows, self.dtype).reshape(-1, self.width)
        assert dest.shape[0] == rows.shape[0]
        if not dest.shape[0]:
            return
        order = np.argsort(dest, kind="stable")
        dest, rows = dest[order], rows[order]
        bounds = np.searchsorted(dest, np.arange(self.nshards + 1))
        for d in range(self.nshards):
            lo, hi = bounds[d], bounds[d + 1]
            if hi <= lo:
                continue
            take = hi - lo
            if self.capacity is not None:
                room = max(0, self.capacity - int(self._accepted[d]))
                if take > room:
                    self._dropped[d] += take - room
                    take = room
            if take:
                self._bufs[d].append(rows[lo:lo + take])
                self._accepted[d] += take
                self._nbuf += take
        if self._nbuf >= self.buf_rows:
            self._spill()

    def _spill(self) -> None:
        for d, buf in enumerate(self._bufs):
            if not buf:
                continue
            rec = np.concatenate(buf, axis=0) if len(buf) > 1 else buf[0]
            data = np.ascontiguousarray(rec, self.dtype).tobytes()
            self._append(d, data)
            self._bytes[d] += len(data)
            self._bufs[d] = []
        self._nbuf = 0

    def seal(self, epoch: int, publish_done: bool = False) -> np.ndarray:
        """Publish this epoch's buckets atomically and reset.

        Returns the (nshards,) per-destination dropped counts for the
        epoch.  Destinations that received no rows publish no bucket — the
        reader treats absence as an empty bucket.  With ``publish_done``
        (the pipelined exchange) every destination additionally gets a
        completion marker AFTER its data is published, so a receiver can
        consume this source incrementally without waiting for the level
        barrier."""
        with obs.span("bucket.seal", epoch=epoch, src=self.src,
                      rows=int(self._accepted.sum())):
            self._spill()
            with obs.span("bucket.send", epoch=epoch, src=self.src,
                          transport=self.kind, bytes=int(self._bytes.sum())):
                self._publish(epoch, publish_done)
            TRANSPORT_STATS[f"{self.kind}_bytes_out"] += int(
                self._bytes.sum())
            TRANSPORT_STATS[f"{self.kind}_buckets_out"] += int(
                np.count_nonzero(self._bytes))
            dropped = self._dropped.copy()
            self._accepted[:] = 0
            self._dropped[:] = 0
            self._bytes[:] = 0
            return dropped

    # ------------------------------------------------ backend hooks
    def _append(self, dst: int, data: bytes) -> None:
        raise NotImplementedError

    def _publish(self, epoch: int, publish_done: bool) -> None:
        raise NotImplementedError


class BucketWriter(BucketSender):
    """The shared-filesystem bucket backend — the paper's original shape.

    One source's outgoing per-destination buckets accumulate in ``.tmp``
    files under the structure's exchange directory; ``seal(epoch)``
    renames every ``.tmp`` to its final epoch-stamped name (the atomic
    publish the destination's reader looks for).  The on-disk layout in
    barrier mode is byte-identical to the pre-transport protocol."""

    kind = "fs"

    def __init__(self, root: str, src: int, nshards: int, width: int,
                 dtype="int64", capacity: Optional[int] = None,
                 buf_rows: int = 1 << 15):
        os.makedirs(root, exist_ok=True)
        self.root = root
        super().__init__(src, nshards, width, dtype=dtype,
                         capacity=capacity, buf_rows=buf_rows)

    def _tmp_path(self, dst: int) -> str:
        # The epoch is stamped at seal time; one in-flight file per dst.
        return os.path.join(self.root, f"s{self.src:03d}_d{dst:03d}.bin.tmp")

    def _append(self, dst: int, data: bytes) -> None:
        # Positioned, truncate-on-retry append: a torn or transiently
        # failed spill can never leave partial records in the bucket.
        faults.append_bytes("bucket_spill", self._tmp_path(dst), data,
                            shard=self.src, dst=dst)

    def _publish(self, epoch: int, publish_done: bool) -> None:
        for d in range(self.nshards):
            tmp = self._tmp_path(d)
            if os.path.exists(tmp):
                final = os.path.join(
                    self.root, _bucket_name(epoch, self.src, d))
                faults.retry_io("bucket_seal",
                                lambda t=tmp, f=final: os.replace(t, f),
                                shard=self.src, dst=d)
        if publish_done:
            # Markers land strictly after the data renames: a marker's
            # existence means this source's bucket for that destination
            # (if any) is already readable.
            for d in range(self.nshards):
                marker = os.path.join(
                    self.root, _done_name(epoch, self.src, d))
                faults.retry_io("bucket_seal",
                                lambda m=marker: open(m, "wb").close(),
                                shard=self.src, dst=d)


# ----------------------------------------------------------------- reader

def incoming_files(root: str, dst: int, epoch: int) -> List[Tuple[int, str]]:
    """Sealed bucket files destined to ``dst`` for ``epoch``, as sorted
    (src, path) pairs — ascending src, the deterministic apply order the
    sharded hash table's per-key sequencing relies on."""
    if not os.path.isdir(root):
        return []
    suffix = f"_d{dst:03d}.bin"
    prefix = f"e{epoch:06d}_s"
    out = []
    for fn in os.listdir(root):
        if fn.startswith(prefix) and fn.endswith(suffix):
            out.append((int(fn[len(prefix):len(prefix) + 3]),
                        os.path.join(root, fn)))
    return sorted(out)


def iter_incoming(root: str, dst: int, epoch: int, width: int,
                  dtype="int64", consume: bool = True
                  ) -> Iterator[Tuple[int, np.ndarray]]:
    """Stream (src, rows) for every sealed bucket aimed at ``dst`` this
    epoch, ascending src.  With ``consume=True`` each file is deleted
    after it is yielded (the destination owns sealed files)."""
    # Generator span: opens at first advance, closes when the stream is
    # exhausted or the consumer abandons it (GeneratorExit unwinds the
    # ``with``; obs tolerates the out-of-LIFO end).
    with obs.span("bucket.apply", epoch=epoch, dst=dst):
        dt = np.dtype(dtype)
        for src, path in incoming_files(root, dst, epoch):
            rows = np.fromfile(path, dtype=dt)
            assert rows.size % width == 0, f"torn bucket file {path}"
            yield src, rows.reshape(-1, width)
            if consume:
                os.remove(path)


# ---------------------------------------------------------------- cleanup

def cleanup_strays(root: str) -> List[str]:
    """Remove in-flight strays orphaned by a killed worker: ``.tmp``
    buckets, plus any foreign ``.pass`` files (op-log pass snapshots
    belong under structure dirs, never in an exchange dir — one here is
    wreckage).  What gets swept is booked, not silently discarded:
    ``extsort.STATS['stray_files_swept'/'stray_bytes_swept']`` report the
    count and bytes so a fresh=False startup says what it cleaned.

    Sealed files are NOT touched — an epoch sealed but not yet applied is
    real queued data; only the runtime's ``fresh`` wipe discards those.
    Returns the removed paths (tests assert on them)."""
    from . import extsort          # lazy: extsort is downstream of us
    removed = []
    if not os.path.isdir(root):
        return removed
    for fn in sorted(os.listdir(root)):
        if fn.endswith(".tmp") or fn.endswith(".pass"):
            path = os.path.join(root, fn)
            try:
                extsort.STATS["stray_bytes_swept"] += os.path.getsize(path)
            except OSError:
                pass
            os.remove(path)
            extsort.STATS["stray_files_swept"] += 1
            removed.append(path)
    return removed
