"""ShardRuntime — the multiprocess sharded Tier D runtime.

Invariant: partitions are disjoint under the static owner functions and
every delayed op reaches its owner exactly once through sealed bucket
files, so for ANY nshards the sharded structures and both sharded BFS
engines are element-wise equivalent to their single-process forms, and
the per-level pass budgets hold PER SHARD (the exchange adds bucket I/O,
never a sort or an extra traversal).  A completed ``map`` is the
collective barrier; checkpoint epochs snapshot every shard at that
barrier before the coordinator publishes (docs/checkpointing.md).

The paper's promise is that "all aspects of parallelism and remote I/O are
hidden within the library": a structure is partitioned over workers by a
static owner function, delayed operations are buffered into per-(src,dst)
bucket files (buckets.py), and a ``sync`` ships and applies them on the
owner.  This module is that runtime for the disk tier:

  * :class:`ShardRuntime` — N workers, each with its own shard root
    directory, driven by a coordinator over command queues.  Two worker
    modes: ``"spawn"`` (real processes, the production shape — spawn
    start method, so every function and argument crossing the queue must
    be picklable) and ``"inline"`` (the same code run sequentially in the
    coordinator process — deterministic, closure-friendly, what the
    equivalence tests sweep over nshards ∈ {1, 2, 4}).

  * Sharded wrappers — :class:`ShardedDiskList` (hash-distributed),
    :class:`ShardedDiskHashTable` (hash-distributed),
    :class:`ShardedDiskBitArray` (block-distributed) — coordinator-side
    handles whose delayed ops route through disk bucket files and apply
    at sync via the existing op-log machinery (``dlist``/``dhash``/
    ``bitarray``).  Bucket overflow is dropped-and-counted exactly like
    Tier J's ``delayed.bin_by_dest``; :meth:`ShardRuntime.sync` surfaces
    the exact totals per structure.

  * Distributed BFS on both engines — :func:`sharded_bfs` (sorted-list)
    and :func:`sharded_implicit_bfs` (2-bit array), reached through
    ``disk.breadth_first_search(..., nshards=)`` / ``disk.implicit_bfs``.
    Each shard sorts/traverses only its partition; frontier expansion is
    bucket-exchanged to owners at the level barrier.  The PR 3 per-level
    pass budgets hold PER SHARD: one sort pass over the shard's raw
    frontier (sorted-list), one fused read-write array pass (implicit) —
    the exchange adds bucket-file I/O, never an extra sort or traversal.

Sync protocol (one structure, one epoch): the coordinator seals its own
outgoing buckets, then runs two collective phases over the workers —
*seal* (every worker publishes its outgoing buckets for the epoch; the
phase completion is the barrier) and *apply* (every worker streams the
sealed buckets addressed to it into its local structure's op log and
syncs).  A worker killed mid-epoch leaves only ``.tmp`` bucket files,
which readers ignore and a fresh runtime sweeps away.

Ownership must be identical in every process: the owner maps live in
buckets.py (numpy, jax-free) and are pinned to Tier J's
``sharding.hash_owner`` / ``sharding.block_owner`` by golden-value tests.
"""
from __future__ import annotations

import os
import shutil
import threading
import traceback
from typing import Callable, List, Optional, Sequence

import numpy as np

from .. import obs
from . import checkpoint as ckpt
from . import extsort, faults
from .bitarray import CUR, DONE, NEXT, UNSEEN, DiskBitArray
from .bitarray import STATS as BITS_STATS
from .buckets import (BucketSender, block_owner_np, block_size,
                      hash_owner_np)
from .checkpoint import SearchCheckpoint
from .transport import (LoopbackStore, Transport, TransportAborted,
                        make_transport)
from .dhash import DiskHashTable
from .dlist import DiskList
from .lsm import SortedRunSet
from .passes import PassPlan
from .store import ChunkStore

__all__ = [
    "ShardContext", "ShardRuntime", "ShardFailure", "WorkerLost",
    "ShardedDiskList", "ShardedDiskHashTable",
    "ShardedDiskBitArray", "sharded_bfs", "sharded_implicit_bfs",
]

_MAP_TIMEOUT = 600.0          # seconds a collective phase may take


class WorkerLost(RuntimeError):
    """A shard worker died or timed out mid-collective.  Carries the shard
    id and the collective's name so the recovery path (and a human reading
    the log) knows exactly where the pool broke."""

    def __init__(self, msg: str, shard: Optional[int] = None,
                 phase: Optional[str] = None):
        super().__init__(msg)
        self.shard = shard
        self.phase = phase


class ShardFailure(RuntimeError):
    """Unrecoverable sharded-run failure — the loud, structured end state.

    Raised when in-run recovery is impossible (no adoptable coordinated
    checkpoint, ``max_recoveries`` budget exhausted, or a fatal errno
    survived the retry layer): the run stops HERE, naming the shard, the
    fault site/phase, the exchange epoch and the BFS level, instead of
    hanging on a dead queue or silently desynchronizing partitions."""

    def __init__(self, reason: str, *, shard=None, site=None, epoch=None,
                 level=None, recoveries: int = 0):
        self.shard = shard
        self.site = site
        self.epoch = epoch
        self.level = level
        self.recoveries = recoveries
        detail = ", ".join(
            f"{k}={v}" for k, v in (("shard", shard), ("site", site),
                                    ("epoch", epoch), ("level", level),
                                    ("recoveries", recoveries))
            if v is not None)
        super().__init__(f"{reason} [{detail}]")


# ============================================================== worker side

class ShardContext:
    """One worker's view of the runtime: its shard id, its private root
    directory (every local ChunkStore/op-log lives under it), its
    transport endpoint with its cached outgoing :class:`BucketSender` per
    structure, and the registry of local structure shards built up by
    coordinator commands."""

    def __init__(self, shard: int, nshards: int, root: str,
                 tspec: Optional[dict] = None, exchange: str = "barrier",
                 timeout: float = _MAP_TIMEOUT, store=None, abort=None):
        self.shard = int(shard)
        self.nshards = int(nshards)
        self.root = root
        self.exchange = exchange
        self.dir = os.path.join(root, f"shard{shard:03d}")
        os.makedirs(self.dir, exist_ok=True)
        self.objects: dict = {}
        self._writers: dict = {}
        self.transport: Transport = make_transport(
            tspec or {"kind": "fs"}, shard, nshards, root,
            abort=abort, store=store, timeout=timeout)

    @property
    def pipelined(self) -> bool:
        return self.exchange == "pipelined"

    def exchange_dir(self, name: str) -> str:
        return os.path.join(self.root, "exchange", name)

    def writer(self, spec: dict) -> BucketSender:
        """The (cached) outgoing bucket sender for one structure."""
        name = spec["name"]
        if name not in self._writers:
            self._writers[name] = self.transport.sender(spec)
        return self._writers[name]

    def recv(self, spec: dict, epoch: int, srcs, ordered: bool = True):
        """Stream (src, rows) addressed to this shard for one epoch,
        through the runtime's exchange discipline: barrier mode consumes
        a completed epoch, pipelined mode consumes each source as its
        completion marker lands."""
        return self.transport.recv(spec, epoch, tuple(srcs),
                                   live=self.pipelined, ordered=ordered)


def _worker_main(shard: int, nshards: int, root: str, cmd_q, res_q,
                 tspec: Optional[dict] = None,
                 exchange: str = "barrier",
                 timeout: float = _MAP_TIMEOUT) -> None:
    """Command loop of one spawned worker.  Every command is a picklable
    ``(fn, args)`` executed against the persistent :class:`ShardContext`;
    exceptions travel back as formatted strings (tracebacks don't
    pickle).  The fault plan (if ``$ROOMY_FAULTS`` is set) is installed
    with ``allow_exit=True``: ``kill`` rules here are a real ``os._exit``,
    the hard-death shape the coordinator's recovery must survive."""
    ctx = ShardContext(shard, nshards, root, tspec=tspec, exchange=exchange,
                       timeout=timeout)
    faults.install_from_env(state_dir=os.path.join(root, "_faults"),
                            shard=shard, allow_exit=True)
    # Tracing rides the environment exactly like the fault plan: trace.start
    # exports $ROOMY_TRACE before the pool spawns (and before recovery
    # respawns), so every worker buffers shard-tagged spans for the
    # coordinator to collect at the level barrier (_w_obs_collect).
    if os.environ.get(obs.ENV_VAR):
        obs.enable(shard=shard)
    while True:
        msg = cmd_q.get()
        if msg is None:
            ctx.transport.close()
            return
        fn, args = msg
        try:
            if faults.ACTIVE:     # barrier site: delay/kill before dispatch
                faults.fire("barrier", shard=shard,
                            fn=getattr(fn, "__name__", str(fn)))
            res_q.put((True, fn(ctx, *args)))
        except BaseException:
            res_q.put((False, traceback.format_exc()))


def _w_noop(ctx: ShardContext) -> int:
    return ctx.shard


def _w_seal(ctx: ShardContext, spec: dict, epoch: int) -> int:
    """Publish this worker's outgoing buckets for one structure/epoch.

    On wires with explicit completion (tcp, loopback — and the fs wire's
    pipelined markers) this seals even with nothing queued: an empty seal
    is cheap, a missing one hangs the receiver.  In fs barrier mode a
    shard that never wrote skips instead — absence IS the empty bucket
    there, and an unforced seal would adopt a killed peer's stray
    ``.tmp`` as real traffic (pinned by the abort-safety tests)."""
    if (spec["name"] not in ctx._writers and not ctx.pipelined
            and not ctx.transport.explicit_completion):
        return 0
    return int(ctx.writer(spec).seal(epoch,
                                     publish_done=ctx.pipelined).sum())


def _w_transport_addr(ctx: ShardContext):
    """This worker's receive endpoint (handshake round, tcp)."""
    return ctx.transport.handshake()


def _w_transport_connect(ctx: ShardContext, peers: dict) -> int:
    ctx.transport.connect(peers)
    return ctx.shard


def _w_exchange(ctx: ShardContext, spec: dict, epoch: int, apply_fn,
                *apply_args) -> tuple:
    """Pipelined sync of one structure on one worker: seal the outgoing
    buckets with completion markers, then apply inbound as each peer's
    marker lands (the apply_fn's ``ctx.recv`` is live here) — producing
    and applying overlap across shards, the barrier is only the map
    completing.  Returns (dropped, applied)."""
    dropped = int(ctx.writer(spec).seal(epoch, publish_done=True).sum())
    return dropped, apply_fn(ctx, spec, epoch, *apply_args)


def _w_get_stats(ctx: ShardContext) -> dict:
    """This worker's pass/byte ledgers (per-shard budget assertions)."""
    return {"extsort": dict(extsort.STATS), "bits": dict(BITS_STATS)}


def _w_reset_stats(ctx: ShardContext) -> None:
    extsort.reset_stats()
    for k in BITS_STATS:
        BITS_STATS[k] = 0


def _w_obs_collect(ctx: ShardContext) -> tuple:
    """This worker's registry snapshot plus its buffered spans, for the
    coordinator's telemetry fold (:meth:`ShardRuntime.collect_obs`).
    Counters are NOT reset — the coordinator folds deltas against its
    last collection, so ``_w_get_stats`` budget assertions keep seeing
    the worker's cumulative totals."""
    return obs.snapshot(), obs.drain_spans()


def _w_destroy(ctx: ShardContext, name: str) -> None:
    obj = ctx.objects.pop(name, None)
    if obj is not None:
        obj.destroy()
    ctx._writers.pop(name, None)


# ========================================================== coordinator side

class ShardRuntime:
    """N shard workers plus the coordinator-side bucket plumbing.

    mode="spawn"   real worker processes (multiprocessing spawn start
                   method — safe under jax/threads).  Functions, specs
                   and payloads crossing the queues must be picklable.
    mode="inline"  the same worker functions run sequentially in this
                   process — zero startup cost, closure-friendly; shard
                   state still lives in per-shard directories and all
                   exchange traffic still goes through bucket files, so
                   it exercises the identical on-disk protocol.

    The runtime owns ``root``: per-shard directories ``shard{k:03d}/``
    and the transport's exchange area (a shared ``exchange/`` directory
    for the fs wire; sockets/in-process mailboxes elsewhere).
    ``fresh=True`` (default) wipes leftovers from a previous (possibly
    killed) run; otherwise only ignorable ``.tmp``/``.pass`` strays are
    swept — and what the sweep cleaned is booked in ``extsort.STATS``
    (``stray_files_swept`` / ``stray_bytes_swept``), never silently
    discarded.

    ``transport=`` picks the wire (docs/transports.md): ``"fs"``
    (default, shared filesystem, byte-compatible layout), ``"tcp"``
    (sockets, no shared exchange dir), ``"loopback"`` (in-process
    mailbox, inline only).  ``exchange=`` picks the sync discipline:
    ``"barrier"`` (default, the legacy two-phase seal-all-then-apply-all)
    or ``"pipelined"`` (workers apply inbound buckets while peers are
    still producing; inline mode then runs its workers in a thread pool —
    the GIL-releasing numpy passes overlap).
    """

    def __init__(self, root: str, nshards: int, mode: str = "spawn",
                 fresh: bool = True, timeout: float = _MAP_TIMEOUT,
                 transport: str = "fs", exchange: Optional[str] = None,
                 host: str = "127.0.0.1", wire_compress: bool = False):
        assert nshards >= 1
        assert mode in ("spawn", "inline"), mode
        assert exchange in (None, "barrier", "pipelined"), exchange
        if transport == "loopback" and mode != "inline":
            raise ValueError(
                "transport='loopback' is the in-process wire for "
                "mode='inline' — spawn workers cannot share its store")
        if wire_compress and transport == "fs":
            raise ValueError(
                "wire_compress=True needs a mailbox wire (tcp/loopback) — "
                "the fs bucket layout is a byte-compatibility contract")
        self.root = root
        self.nshards = int(nshards)
        self.mode = mode
        self.timeout = timeout
        self.exchange_mode = exchange or "barrier"
        self.tspec = {"kind": transport, "host": host,
                      "wire_compress": bool(wire_compress)}
        self._broken = False     # set when a collective desynchronizes
        self.epoch = 0
        self._seq = 0
        self._structs: dict = {}
        # Per-shard last-seen counter values (ns -> {key: value}), the
        # baselines collect_obs folds deltas against.  Spawn mode only:
        # inline workers mutate this process's registry directly.
        self._obs_base: List[dict] = [dict() for _ in range(self.nshards)]
        # The coordinator runs the same fault plan as the workers (if any)
        # but never exits the process: kill rules become WorkerKilled
        # raises, which inline mode and the BFS recovery path catch.
        faults.install_from_env(state_dir=os.path.join(root, "_faults"),
                                allow_exit=False)
        self._store = LoopbackStore() if transport == "loopback" else None
        # Inline workers share one abort flag: the first thread to fail a
        # pipelined level unblocks every peer's live recv.
        self._abort = threading.Event()
        # The coordinator acts as bucket source ``nshards`` (one past the
        # worker ids) — its delayed ops ride the same wire.
        self.driver = self._make_ctx(self.nshards)
        self.driver.transport.startup(fresh)
        self._procs: List = []
        self._cmd_qs: List = []
        self._res_qs: List = []
        self._inline_ctxs: List[ShardContext] = []
        if mode == "inline":
            self._inline_ctxs = [self._make_ctx(s)
                                 for s in range(self.nshards)]
        else:
            self._spawn_workers()
        self._handshake()

    @property
    def pipelined(self) -> bool:
        return self.exchange_mode == "pipelined"

    def _make_ctx(self, shard: int) -> ShardContext:
        return ShardContext(shard, self.nshards, self.root,
                            tspec=self.tspec, exchange=self.exchange_mode,
                            timeout=self.timeout, store=self._store,
                            abort=self._abort)

    def _spawn_workers(self) -> None:
        import multiprocessing as mp
        mpctx = mp.get_context("spawn")
        for s in range(self.nshards):
            cq, rq = mpctx.Queue(), mpctx.Queue()
            p = mpctx.Process(target=_worker_main,
                              args=(s, self.nshards, self.root, cq, rq,
                                    self.tspec, self.exchange_mode,
                                    self.timeout),
                              daemon=True)
            p.start()
            self._procs.append(p)
            self._cmd_qs.append(cq)
            self._res_qs.append(rq)

    def _handshake(self) -> None:
        """Endpoint-exchange round for transports with real addresses
        (tcp): collect every worker's receive endpoint, broadcast the
        peer map, and wire the coordinator's own sender.  Runs after
        every (re)spawn, before any seal."""
        if self.tspec["kind"] != "tcp":
            return
        if self.mode == "inline":
            peers = {c.shard: c.transport.handshake()
                     for c in self._inline_ctxs}
            for c in self._inline_ctxs:
                c.transport.connect(peers)
        else:
            addrs = self.bcast(_w_transport_addr)
            peers = {s: a for s, a in enumerate(addrs)}
            self.bcast(_w_transport_connect, peers)
        self.driver.transport.connect(peers)

    # ------------------------------------------------------------ plumbing
    def next_epoch(self) -> int:
        self.epoch += 1
        return self.epoch

    def next_name(self, prefix: str) -> str:
        self._seq += 1
        return f"{prefix}{self._seq}"

    def _get_result(self, s: int, fn_name: str):
        """Blocking result read from shard s, polling in short slices so a
        dead worker is reported within seconds, not after the full
        collective timeout."""
        import queue as _queue
        import time as _time
        deadline = _time.monotonic() + self.timeout
        while True:
            try:
                return self._res_qs[s].get(timeout=2.0)
            except _queue.Empty:
                # Check the WHOLE pool, not just shard s: in a pipelined
                # exchange a live worker blocks on a dead peer's buckets,
                # so the stall surfaces on the wrong queue first.
                for i, p in enumerate(self._procs):
                    if not p.is_alive():
                        raise WorkerLost(
                            f"shard {i} died during {fn_name}",
                            shard=i, phase=fn_name) from None
                if _time.monotonic() >= deadline:
                    raise WorkerLost(
                        f"shard {s} timed out during {fn_name}",
                        shard=s, phase=fn_name) from None

    def map(self, fn: Callable, args: Optional[Sequence[tuple]] = None
            ) -> list:
        """Run ``fn(ctx, *args[s])`` on every shard; a completed map is the
        runtime's collective barrier.  ``args`` is one tuple per shard
        (or None for no arguments)."""
        argl = list(args) if args is not None else [()] * self.nshards
        assert len(argl) == self.nshards
        if self.mode == "inline":
            if self.pipelined and self.nshards > 1:
                return self._map_threaded(fn, argl)
            outs = []
            for ctx, a in zip(self._inline_ctxs, argl):
                if faults.ACTIVE:     # same barrier site the workers fire
                    faults.fire("barrier", shard=ctx.shard,
                                fn=getattr(fn, "__name__", str(fn)))
                outs.append(fn(ctx, *a))
            return outs
        if self._broken:
            raise RuntimeError(
                "ShardRuntime is desynchronized (a previous collective "
                "timed out or lost a worker) — recover() or build a "
                "fresh runtime")
        fn_name = getattr(fn, "__name__", str(fn))
        for q, a in zip(self._cmd_qs, argl):
            q.put((fn, tuple(a)))
        outs, errors = [], []
        for s in range(self.nshards):
            try:
                ok, val = self._get_result(s, fn_name)
            except RuntimeError:
                # Results may still be in flight: any further command
                # would pair stale replies with new requests, so poison
                # the runtime instead of silently desynchronizing.
                self._broken = True
                raise
            if ok:
                outs.append(val)
            else:
                errors.append(f"shard {s}:\n{val}")
        if errors:
            # Every shard answered — queues are still aligned, the
            # runtime stays usable.
            raise RuntimeError(f"worker failure in {fn_name}:\n"
                               + "\n".join(errors))
        return outs

    def _map_threaded(self, fn: Callable, argl: list) -> list:
        """Pipelined inline map: every shard's worker function runs in
        its own thread (the carried ROADMAP item — the numpy passes and
        file I/O release the GIL, so inline mode finally overlaps).
        Necessary for correctness too: a pipelined level blocks on peer
        buckets, which a sequential loop would deadlock on.  The FIRST
        failure sets the shared abort flag immediately (waiting for
        earlier futures first would stall every live peer until its recv
        timeout); the lowest failing shard's ORIGINAL exception
        propagates — abort-induced :class:`~.transport.TransportAborted`
        secondaries are only raised when nothing better exists."""
        from concurrent.futures import (FIRST_EXCEPTION, ThreadPoolExecutor,
                                        wait as _futwait)

        def run(ctx, a):
            if faults.ACTIVE:     # same barrier site the workers fire
                faults.fire("barrier", shard=ctx.shard,
                            fn=getattr(fn, "__name__", str(fn)))
            return fn(ctx, *a)

        self._abort.clear()
        outs: list = [None] * self.nshards
        errs: list = [None] * self.nshards
        with ThreadPoolExecutor(max_workers=self.nshards,
                                thread_name_prefix="shard") as pool:
            futs = [pool.submit(run, ctx, a)
                    for ctx, a in zip(self._inline_ctxs, argl)]
            done, _pending = _futwait(futs, return_when=FIRST_EXCEPTION)
            if any(f.exception() is not None for f in done):
                self._abort.set()        # unblock peers' live recvs NOW
            _futwait(futs)
            for s, fut in enumerate(futs):
                exc = fut.exception()
                if exc is not None:
                    errs[s] = exc
                    self._abort.set()
                else:
                    outs[s] = fut.result()
        real = [e for e in errs
                if e is not None and not isinstance(e, TransportAborted)]
        for exc in real or [e for e in errs if e is not None]:
            raise exc
        return outs

    def bcast(self, fn: Callable, *args) -> list:
        """map() with the same (picklable) arguments on every shard."""
        return self.map(fn, [tuple(args)] * self.nshards)

    def barrier(self) -> None:
        self.bcast(_w_noop)

    # ------------------------------------------------------------ exchange
    def seal_driver(self, spec: dict, epoch: int) -> int:
        """Seal the coordinator's outgoing buckets for one epoch
        (publishing completion markers in pipelined mode); returns the
        exact overflow-drop count."""
        return int(self.driver.writer(spec)
                   .seal(epoch, publish_done=self.pipelined).sum())

    def exchange(self, spec: dict, apply_fn: Callable, *apply_args) -> dict:
        """One delayed-op sync of one structure.  Barrier mode: seal
        everywhere (the completed seal map IS the barrier), then apply
        everywhere.  Pipelined mode: one collective in which each worker
        seals with completion markers and applies peers' buckets as they
        land — produce and apply overlap, the barrier is only the map
        completing.  Both return {"dropped": n, "applied": [...]} with
        the EXACT count of rows lost to bucket-capacity overflow
        (coordinator + all workers), mirroring ``bin_by_dest``."""
        epoch = self.next_epoch()
        dropped = self.seal_driver(spec, epoch)
        if self.pipelined:
            res = self.bcast(_w_exchange, spec, epoch, apply_fn,
                             *apply_args)
            dropped += sum(d for d, _a in res)
            return {"dropped": dropped, "applied": [a for _d, a in res]}
        dropped += sum(self.bcast(_w_seal, spec, epoch))
        applied = self.bcast(apply_fn, spec, epoch, *apply_args)
        return {"dropped": dropped, "applied": applied}

    def wipe_exchange(self, name: str) -> None:
        """Discard every queued/sealed bucket of one structure, on
        whatever wire this runtime runs (rollback and destroy: in-flight
        buckets of a failed epoch are dead traffic)."""
        self.driver.transport.wipe(name)
        for ctx in self._inline_ctxs:
            ctx.transport.wipe(name)

    def register(self, struct) -> None:
        self._structs[struct.name] = struct

    def sync(self) -> dict:
        """Sync every registered sharded structure (default combine/apply);
        returns {structure_name: exact_dropped_count}."""
        out = {name: s.sync() for name, s in self._structs.items()}
        self.collect_obs()
        return out

    # ------------------------------------------------------------ telemetry
    def collect_obs(self) -> None:
        """Fold the spawn workers' counter deltas (and, when tracing,
        their buffered spans) into the coordinator's obs registry, so
        pass/byte totals survive worker process exit and a distributed
        run produces ONE coherent trace.

        Spawn mode only: inline workers run in this process and mutate
        the shared module registries directly — folding would double
        count.  Deltas are taken against the last collection per shard
        (``_obs_base``); :meth:`recover` resets the baselines because
        respawned workers restart their counters at zero.  Never raises:
        a dying pool must not turn telemetry into the crash."""
        if self.mode != "spawn" or self._broken or not self._procs:
            return
        try:
            snaps = self.bcast(_w_obs_collect)
        except (RuntimeError, OSError):
            return
        for shard, (snap, spans) in enumerate(snaps):
            base = self._obs_base[shard]
            for ns, vals in snap["counters"].items():
                prev = base.setdefault(ns, {})
                live = obs.counters(ns, {})
                for k, v in vals.items():
                    d = v - prev.get(k, 0)
                    if d:
                        live[k] = live.get(k, 0) + d
                    prev[k] = v
            if obs.ACTIVE and spans:
                obs.ingest(spans, shard=shard)

    # ------------------------------------------------------------ lifecycle
    def shutdown(self) -> None:
        """Stop the workers (spawn mode).  Shard directories stay on disk.
        Always returns, even for a broken pool: see _teardown_workers.
        Final telemetry sweep first — pass/byte totals booked since the
        last barrier would otherwise die with the worker processes."""
        self.collect_obs()
        self._teardown_workers()
        for ctx in self._inline_ctxs:
            ctx.transport.close()
        self.driver.transport.close()

    def _teardown_workers(self) -> None:
        """Tear the worker pool down without ever hanging.

        A worker blocked writing a large result cannot exit until its
        result queue drains, and a Queue's feeder thread will block
        interpreter exit unless cancelled — so the order is: send stop
        sentinels (non-blocking), drain every result queue, escalate
        join → terminate → kill, then close and ``cancel_join_thread()``
        every queue.  Safe on an already-dead or desynchronized pool."""
        if not self._procs and not self._cmd_qs:
            return
        import queue as _queue
        for q in self._cmd_qs:
            try:
                q.put_nowait(None)
            except Exception:
                pass
        for rq in self._res_qs:
            while True:
                try:
                    rq.get_nowait()
                except (_queue.Empty, OSError, ValueError):
                    break
        for p in self._procs:
            p.join(timeout=10)
            if p.is_alive():
                p.terminate()
                p.join(timeout=10)
            if p.is_alive():
                p.kill()
                p.join(timeout=10)
        for q in list(self._cmd_qs) + list(self._res_qs):
            try:
                q.close()
                q.cancel_join_thread()
            except Exception:
                pass
        self._procs, self._cmd_qs, self._res_qs = [], [], []

    def recover(self) -> None:
        """Return a broken runtime to a usable state after a failed
        collective: tear down the (dead, wedged, or desynchronized) worker
        pool, respawn it, and drop coordinator-side buffered bucket
        writers.  Shard directories are NOT touched — the caller is
        expected to re-adopt a coordinated checkpoint (the BFS recovery
        path) or rebuild its structures before issuing new collectives:
        respawned workers start with empty object registries."""
        self.driver._writers = {}
        self._abort.clear()
        if self.mode == "inline":
            for ctx in self._inline_ctxs:
                ctx.transport.close()     # tcp receiver threads would leak
            self._inline_ctxs = [self._make_ctx(s)
                                 for s in range(self.nshards)]
        else:
            self._teardown_workers()
            self._spawn_workers()
        # Respawned workers restart their counters at zero: reset the
        # delta baselines or the next collect_obs would fold negatives.
        self._obs_base = [dict() for _ in range(self.nshards)]
        self._broken = False
        self._handshake()                 # fresh pool, fresh endpoints

    def destroy(self) -> None:
        """Shutdown and remove every shard/exchange directory."""
        self.shutdown()
        shutil.rmtree(self.root, ignore_errors=True)

    def __enter__(self) -> "ShardRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()


# =============================================================== make/apply

def _w_make(ctx: ShardContext, spec: dict) -> None:
    kind, name = spec["kind"], spec["name"]
    if kind == "list":
        ctx.objects[name] = DiskList(ctx.dir, spec["width"],
                                     spec["chunk_rows"], name=name)
    elif kind == "hash":
        ctx.objects[name] = DiskHashTable(ctx.dir, spec["key_width"],
                                          spec["val_width"],
                                          nbuckets=spec["nbuckets"], name=name)
    elif kind == "bits":
        per = spec["per"]
        n_local = max(0, min(per, spec["n"] - ctx.shard * per))
        ctx.objects[name] = DiskBitArray(
            ctx.dir, n_local, chunk_elems=spec["chunk_elems"], name=name,
            log_buf_rows=spec["log_buf_rows"],
            init_chunks=spec.get("init_chunks", True),
            compress=spec.get("compress", False))
    else:
        raise ValueError(f"unknown structure kind {kind!r}")


class _ShardedBase:
    """Coordinator-side handle: a name, a picklable spec, and the routing
    of driver-issued delayed ops into the driver's bucket writer."""

    def __init__(self, runtime: ShardRuntime, spec: dict):
        self.runtime = runtime
        self.spec = spec
        self.name = spec["name"]
        self._own_runtime = False     # set by the bfs.py wrappers: destroy()
        runtime.bcast(_w_make, spec)  # then also shuts the runtime down
        runtime.register(self)

    def _put(self, dest: np.ndarray, rows: np.ndarray) -> None:
        self.runtime.driver.writer(self.spec).put(dest, rows)

    def destroy(self) -> None:
        self.runtime.bcast(_w_destroy, self.name)
        self.runtime._structs.pop(self.name, None)
        self.runtime.driver._writers.pop(self.name, None)
        self.runtime.wipe_exchange(self.name)
        if self._own_runtime:
            self.runtime.shutdown()


# ------------------------------------------------------------- DiskList

def _w_list_apply(ctx: ShardContext, spec: dict, epoch: int) -> int:
    obj = ctx.objects[spec["name"]]
    got = 0
    for _src, rows in ctx.recv(spec, epoch, range(ctx.nshards + 1)):
        obj.add(rows)
        got += rows.shape[0]
    obj.store.flush()
    return got


def _w_list_size(ctx: ShardContext, name: str) -> int:
    return ctx.objects[name].size()


def _w_list_read(ctx: ShardContext, name: str) -> np.ndarray:
    return ctx.objects[name].read_all()


def _w_list_remove_dupes(ctx: ShardContext, name: str) -> None:
    ctx.objects[name].remove_dupes()


def _w_list_remove_all(ctx: ShardContext, name: str, other: str) -> None:
    ctx.objects[name].remove_all(ctx.objects[other])


def _w_list_add_all(ctx: ShardContext, name: str, other: str) -> None:
    ctx.objects[name].add_all(ctx.objects[other])


class ShardedDiskList(_ShardedBase):
    """RoomyList partitioned by ``hash_owner`` across the shard workers.

    ``add`` is delayed: rows land in per-destination bucket files and
    reach their owner's DiskList at :meth:`sync`.  Set algebra
    (remove_dupes / remove_all / add_all between equally-sharded lists)
    is purely shard-local — the owner function makes the partitions
    disjoint, so local ops compose to the global op."""

    def __init__(self, runtime: ShardRuntime, width: int,
                 name: str | None = None, chunk_rows: int = 1 << 16,
                 capacity: Optional[int] = None):
        spec = {"kind": "list", "name": name or runtime.next_name("slist"),
                "width": width, "chunk_rows": chunk_rows,
                "rec_width": width, "rec_dtype": "uint32",
                "capacity": capacity}
        super().__init__(runtime, spec)
        self.width = width

    def add(self, rows: np.ndarray) -> None:
        rows = np.ascontiguousarray(rows, np.uint32).reshape(-1, self.width)
        self._put(hash_owner_np(rows, self.runtime.nshards), rows)

    def sync(self) -> int:
        return self.runtime.exchange(self.spec, _w_list_apply)["dropped"]

    def size(self) -> int:
        return sum(self.runtime.bcast(_w_list_size, self.name))

    def remove_dupes(self) -> None:
        self.runtime.bcast(_w_list_remove_dupes, self.name)

    def remove_all(self, other: "ShardedDiskList") -> None:
        assert other.runtime is self.runtime
        self.runtime.bcast(_w_list_remove_all, self.name, other.name)

    def add_all(self, other: "ShardedDiskList") -> None:
        assert other.runtime is self.runtime
        self.runtime.bcast(_w_list_add_all, self.name, other.name)

    def read_all(self) -> np.ndarray:
        """Gathered rows, sorted for comparability (tests/small data)."""
        parts = self.runtime.bcast(_w_list_read, self.name)
        rows = np.concatenate(parts, axis=0) if parts else \
            np.zeros((0, self.width), np.uint32)
        return extsort.sort_rows(rows) if rows.shape[0] else rows


# --------------------------------------------------------- DiskHashTable

def _w_hash_apply(ctx: ShardContext, spec: dict, epoch: int,
                  combine, apply) -> int:
    kw, vw = spec["key_width"], spec["val_width"]
    obj = ctx.objects[spec["name"]]
    got = 0
    # Ascending-src consumption (ordered even when pipelined) keeps each
    # key's PUT/DEL interleaving deterministic across sources.
    for _src, rec in ctx.recv(spec, epoch, range(ctx.nshards + 1)):
        got += rec.shape[0]
        ops = rec[:, 0]
        keys = rec[:, 1:1 + kw].astype(np.uint32)
        vals = rec[:, 1 + kw:]
        # Replay in record order, splitting at op changes so each key's
        # PUT/DEL interleaving reaches the table's sequential op log
        # exactly as issued.
        bnd = np.flatnonzero(np.diff(ops)) + 1
        for lo, hi in zip(np.r_[0, bnd], np.r_[bnd, ops.shape[0]]):
            if ops[lo] == DiskHashTable.OP_PUT:
                obj.insert(keys[lo:hi], vals[lo:hi])
            else:
                obj.remove(keys[lo:hi])
    obj.sync(combine=combine, apply=apply)
    return got


def _w_hash_lookup(ctx: ShardContext, name: str, keys: np.ndarray):
    return ctx.objects[name].lookup(keys)


def _w_hash_size(ctx: ShardContext, name: str) -> int:
    return ctx.objects[name].size()


def _w_hash_items(ctx: ShardContext, name: str):
    return list(ctx.objects[name].items())


class ShardedDiskHashTable(_ShardedBase):
    """RoomyHashTable partitioned by ``hash_owner`` of the key row.

    Delayed inserts/removes are encoded as int64 records
    ``[op, key_words..., val_words...]`` in the bucket files and replayed
    on the owner in deterministic order (ascending source id, issue order
    within a source), feeding DiskHashTable's sequential per-key op log —
    so DEL→PUT resurrects and PUT→DEL removes exactly as in the
    single-process table.  ``lookup`` is the delayed-access round trip:
    queries scatter to owners, results gather back in issue order."""

    def __init__(self, runtime: ShardRuntime, key_width: int, val_width: int,
                 name: str | None = None, nbuckets: int = 16,
                 capacity: Optional[int] = None):
        spec = {"kind": "hash", "name": name or runtime.next_name("shash"),
                "key_width": key_width, "val_width": val_width,
                "nbuckets": nbuckets,
                "rec_width": 1 + key_width + val_width, "rec_dtype": "int64",
                "capacity": capacity}
        super().__init__(runtime, spec)
        self.kw, self.vw = key_width, val_width

    def _queue(self, keys, vals, op: int) -> None:
        keys = np.ascontiguousarray(keys, np.uint32).reshape(-1, self.kw)
        vals = np.ascontiguousarray(vals, np.int64).reshape(keys.shape[0],
                                                            self.vw)
        rec = np.empty((keys.shape[0], 1 + self.kw + self.vw), np.int64)
        rec[:, 0] = op
        rec[:, 1:1 + self.kw] = keys
        rec[:, 1 + self.kw:] = vals
        self._put(hash_owner_np(keys, self.runtime.nshards), rec)

    def insert(self, keys, vals) -> None:
        self._queue(keys, vals, DiskHashTable.OP_PUT)

    def remove(self, keys) -> None:
        keys = np.asarray(keys, np.uint32).reshape(-1, self.kw)
        self._queue(keys, np.zeros((keys.shape[0], self.vw), np.int64),
                    DiskHashTable.OP_DEL)

    def sync(self, combine=None, apply=None) -> int:
        """In spawn mode ``combine``/``apply`` must be picklable."""
        return self.runtime.exchange(self.spec, _w_hash_apply,
                                     combine, apply)["dropped"]

    def lookup(self, keys):
        keys = np.asarray(keys, np.uint32).reshape(-1, self.kw)
        owner = hash_owner_np(keys, self.runtime.nshards)
        args = [(self.name, keys[owner == s])
                for s in range(self.runtime.nshards)]
        res = self.runtime.map(_w_hash_lookup, args)
        out = np.zeros((keys.shape[0], self.vw), np.int64)
        found = np.zeros(keys.shape[0], bool)
        for s, (vals, ok) in enumerate(res):
            sel = np.flatnonzero(owner == s)
            out[sel], found[sel] = vals, ok
        return out, found

    def size(self) -> int:
        return sum(self.runtime.bcast(_w_hash_size, self.name))

    def items(self):
        for shard_items in self.runtime.bcast(_w_hash_items, self.name):
            for tk, tv in shard_items:
                yield tk, tv


# --------------------------------------------------------- DiskBitArray

def _mark_first(p, q):
    return p


def _apply_unseen(old, agg):
    return np.where(old == UNSEEN, agg, old)


def _w_bits_apply(ctx: ShardContext, spec: dict, epoch: int,
                  combine, apply) -> int:
    obj = ctx.objects[spec["name"]]
    base = ctx.shard * spec["per"]
    got = 0
    for _src, rec in ctx.recv(spec, epoch, range(ctx.nshards + 1)):
        obj.update(rec[:, 0] - base, rec[:, 1].astype(np.uint8))
        got += rec.shape[0]
    obj.sync(combine=combine, apply=apply)
    return got


def _w_bits_count(ctx: ShardContext, name: str) -> np.ndarray:
    return ctx.objects[name].count_values()


def _w_bits_read(ctx: ShardContext, name: str) -> np.ndarray:
    return ctx.objects[name].read_all()


def _w_bits_get(ctx: ShardContext, name: str, base: int,
                idx: np.ndarray) -> np.ndarray:
    return ctx.objects[name].get(np.asarray(idx, np.int64) - base)


class ShardedDiskBitArray(_ShardedBase):
    """2-bit RoomyArray block-distributed over the shard workers.

    Shard s owns global indices [s·per, (s+1)·per) with
    per = ceil(n / nshards) (``buckets.block_owner_np``, pinned to Tier
    J's ``sharding.block_owner``).  Delayed ``update`` records are
    (global_idx, val) int64 pairs in the bucket files; sync applies them
    through each local DiskBitArray's snapshot-isolated op log."""

    def __init__(self, runtime: ShardRuntime, n: int,
                 name: str | None = None, chunk_elems: int = 1 << 22,
                 log_buf_rows: int = 1 << 20,
                 capacity: Optional[int] = None, init_chunks: bool = True,
                 compress: bool = False):
        spec = {"kind": "bits", "name": name or runtime.next_name("sbits"),
                "n": int(n), "per": block_size(int(n), runtime.nshards),
                "chunk_elems": chunk_elems, "log_buf_rows": log_buf_rows,
                "rec_width": 2, "rec_dtype": "int64", "capacity": capacity,
                "init_chunks": init_chunks, "compress": compress}
        super().__init__(runtime, spec)
        self.n = int(n)
        self.per = spec["per"]

    def update(self, idx: np.ndarray, vals: np.ndarray) -> None:
        idx = np.asarray(idx, np.int64).reshape(-1)
        vals = np.asarray(vals, np.uint8).reshape(-1)
        ok = (idx >= 0) & (idx < self.n)       # drop out-of-range, like the tiers
        idx, vals = idx[ok], vals[ok]
        rec = np.empty((idx.shape[0], 2), np.int64)
        rec[:, 0] = idx
        rec[:, 1] = vals
        self._put(block_owner_np(idx, self.n, self.runtime.nshards), rec)

    def sync(self, combine=None, apply=None) -> int:
        """In spawn mode ``combine``/``apply`` must be picklable."""
        return self.runtime.exchange(self.spec, _w_bits_apply,
                                     combine, apply)["dropped"]

    def count_values(self) -> np.ndarray:
        counts = self.runtime.bcast(_w_bits_count, self.name)
        return np.sum(np.stack(counts, axis=0), axis=0)

    def get(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx, np.int64).reshape(-1)
        if idx.size:
            assert idx.min() >= 0 and idx.max() < self.n, \
                "get: index out of range"
        owner = block_owner_np(idx, self.n, self.runtime.nshards)
        args = [(self.name, s * self.per, idx[owner == s])
                for s in range(self.runtime.nshards)]
        out = np.empty(idx.shape[0], np.uint8)
        for s, vals in enumerate(self.runtime.map(_w_bits_get, args)):
            out[owner == s] = vals
        return out

    def read_all(self) -> np.ndarray:
        """(n,) values — shard order IS global order (block layout)."""
        parts = self.runtime.bcast(_w_bits_read, self.name)
        return (np.concatenate(parts) if parts else np.zeros(0, np.uint8))


# ==================================================== distributed BFS (sorted)

def _w_bfs_init(ctx: ShardContext, spec: dict) -> None:
    name = spec["name"]
    ctx.objects[name] = {
        "all": SortedRunSet(ctx.dir, spec["width"], spec["chunk_rows"],
                            max_runs=spec["max_runs"], name=f"{name}_all",
                            policy=spec["compaction"],
                            size_ratio=spec["size_ratio"],
                            codec=spec.get("codec")),
        "cur": None, "builder": None, "lev": 0,
    }


def _w_bfs_seed(ctx: ShardContext, spec: dict, epoch: int) -> int:
    """Sort+dedupe the seed rows routed to this shard into level 0."""
    st = ctx.objects[spec["name"]]
    builder = extsort.RunBuilder(os.path.join(ctx.dir, f"{spec['name']}_tmp"),
                                 spec["width"], chunk_rows=spec["chunk_rows"],
                                 run_rows=spec["run_rows"],
                                 codec=spec.get("codec"))
    # Seed rows come from the coordinator alone (source id nshards).
    for _src, rows in ctx.recv(spec, epoch, (ctx.nshards,)):
        builder.add(rows)
    runs = builder.finish()
    lev0 = ChunkStore(os.path.join(ctx.dir, f"{spec['name']}_lev0"),
                      spec["width"], chunk_rows=spec["chunk_rows"], fresh=True,
                      codec=spec.get("codec"))
    try:
        extsort.merge_runs(runs, lev0, dedupe=True)
    finally:
        for r in runs:
            r.destroy()
    st["all"].add_run(lev0)
    st["cur"] = lev0
    return lev0.size


def _w_bfs_expand(ctx: ShardContext, spec: dict, gen_next, epoch: int,
                  lev: int = 0) -> int:
    """Expand the local frontier: locally-owned neighbours stream straight
    into this shard's RunBuilder (the level's ONE sort pass, paid as the
    rows are generated); remote neighbours go to the owner's bucket.
    Seals the epoch's buckets — map completion is the barrier."""
    if faults.ACTIVE:     # the worker-kill-at-level-k site
        faults.fire("worker_level", shard=ctx.shard, level=lev)
    st = ctx.objects[spec["name"]]
    with obs.span("bfs.level", level=lev, shard=ctx.shard, phase="expand"):
        builder = extsort.RunBuilder(
            os.path.join(ctx.dir, f"{spec['name']}_tmp"), spec["width"],
            chunk_rows=spec["chunk_rows"], run_rows=spec["run_rows"],
            codec=spec.get("codec"))
        writer = ctx.writer(spec)
        for chunk in st["cur"].iter_chunks():
            nbrs = np.ascontiguousarray(gen_next(np.asarray(chunk)),
                                        np.uint32).reshape(-1, spec["width"])
            owner = hash_owner_np(nbrs, ctx.nshards)
            local = owner == ctx.shard
            if local.any():
                builder.add(nbrs[local])
            if not local.all():
                writer.put(owner[~local], nbrs[~local])
        st["builder"] = builder
        return int(writer.seal(epoch, publish_done=ctx.pipelined).sum())


def _w_bfs_absorb(ctx: ShardContext, spec: dict, epoch: int) -> int:
    """Finish the level: received frontier rows join the SAME RunBuilder
    (still the one sort pass), then merge+dedupe+subtract against the
    local visited runs — the shard-local copy of bfs.level_step."""
    from .bfs import _merge_subtract
    st = ctx.objects[spec["name"]]
    with obs.span("bfs.level", level=st["lev"] + 1, shard=ctx.shard,
                  phase="absorb"):
        builder = st.pop("builder")
        # Expansion rows come from the workers (the coordinator only ever
        # seeds); in pipelined mode this recv is live — each peer's rows
        # join the builder as soon as its markers land.
        for _src, rows in ctx.recv(spec, epoch, range(ctx.nshards)):
            builder.add(rows)
        runs = builder.finish()
        st["all"].maybe_compact()
        st["lev"] += 1
        nxt = ChunkStore(
            os.path.join(ctx.dir, f"{spec['name']}_lev{st['lev']}"),
            spec["width"], chunk_rows=spec["chunk_rows"], fresh=True,
            codec=spec.get("codec"))
        try:
            _merge_subtract(runs, st["all"].runs, nxt)
        finally:
            for r in runs:
                r.destroy()
        if nxt.size:
            st["all"].add_run(nxt)
            st["cur"] = nxt
        else:
            nxt.destroy()
            st["cur"] = ChunkStore(
                os.path.join(ctx.dir, f"{spec['name']}_empty"), spec["width"],
                chunk_rows=spec["chunk_rows"], fresh=True)
            st["cur"].flush(mark_sorted=True)
        return nxt.size


def _w_bfs_level(ctx: ShardContext, spec: dict, gen_next, epoch: int,
                 lev: int) -> tuple:
    """One whole pipelined level: expand + seal with completion markers,
    then absorb peers' rows as their markers land — this shard applies
    inbound buckets while slower shards are still producing, and the only
    barrier left is the map completing at the level boundary.  Returns
    (dropped, next_frontier_size); budgets unchanged (the level's one
    sort pass is the same RunBuilder the barrier path fills)."""
    dropped = _w_bfs_expand(ctx, spec, gen_next, epoch, lev)
    return dropped, _w_bfs_absorb(ctx, spec, epoch)


def _w_bfs_snapshot(ctx: ShardContext, spec: dict, stage_root: str,
                    prev_root: Optional[str]) -> dict:
    """Snapshot this shard's partition of a sorted-list search — the
    visited run stack and the current frontier — into its subdirectory of
    the coordinator's staging dir.  Runs at the level barrier (a completed
    map IS the barrier), so every shard's snapshot describes the same
    level.  Runs this worker already exported into the previous published
    snapshot (``prev_root``, tracked worker-side in ``st["ckpt_names"]``)
    hard-link instead of re-copying.  Returns the picklable per-shard
    state for the manifest."""
    st = ctx.objects[spec["name"]]
    sub = f"shard{ctx.shard:03d}"
    prev_dir = os.path.join(prev_root, sub) if prev_root else None
    state = ckpt.snapshot_sorted_state(
        os.path.join(stage_root, sub), st["all"], st["cur"],
        prev_dir=prev_dir, prev_names=st.get("ckpt_names"))
    st["ckpt_names"] = set(state["runs"])
    state["lev"] = st["lev"]
    return state


def _w_bfs_restore(ctx: ShardContext, spec: dict, snap_root: str,
                   state: dict) -> None:
    """Rebuild this shard's search state from a sealed snapshot (the
    inverse of :func:`_w_bfs_snapshot`); a ``cur_index`` of None means the
    shard's frontier was empty at snapshot time."""
    _w_bfs_init(ctx, spec)
    st = ctx.objects[spec["name"]]
    cur = ckpt.restore_sorted_state(
        os.path.join(snap_root, f"shard{ctx.shard:03d}"), state, st["all"],
        ctx.dir, spec["width"], spec["chunk_rows"])
    if cur is None:
        cur = ChunkStore(os.path.join(ctx.dir, f"{spec['name']}_empty"),
                         spec["width"], chunk_rows=spec["chunk_rows"],
                         fresh=True)
        cur.flush(mark_sorted=True)
    st["cur"] = cur
    st["lev"] = int(state["lev"])


def _w_bfs_visited_size(ctx: ShardContext, name: str) -> int:
    return ctx.objects[name]["all"].size()


def _w_bfs_visited_read(ctx: ShardContext, name: str) -> np.ndarray:
    return ctx.objects[name]["all"].read_all()


def _w_bfs_destroy(ctx: ShardContext, name: str) -> None:
    st = ctx.objects.pop(name, None)
    if st is not None:
        st["all"].destroy()
    shutil.rmtree(os.path.join(ctx.dir, f"{name}_tmp"), ignore_errors=True)
    ctx._writers.pop(name, None)


class ShardedVisited:
    """Handle over the per-shard visited SortedRunSets (size/read_all/
    destroy — the same surface the single-process engines return)."""

    def __init__(self, runtime: ShardRuntime, spec: dict, dropped: int):
        self.runtime = runtime
        self.spec = spec
        self.name = spec["name"]
        self.dropped = dropped        # exact bucket-overflow loss, whole search
        self._own_runtime = False

    def size(self) -> int:
        return sum(self.runtime.bcast(_w_bfs_visited_size, self.name))

    def read_all(self) -> np.ndarray:
        parts = self.runtime.bcast(_w_bfs_visited_read, self.name)
        rows = np.concatenate(parts, axis=0)
        return extsort.sort_rows(rows) if rows.shape[0] else rows

    def destroy(self) -> None:
        self.runtime.bcast(_w_bfs_destroy, self.name)
        self.runtime.wipe_exchange(self.name)
        if self._own_runtime:
            self.runtime.shutdown()


def _ckpt_sharded_sorted(ck: SearchCheckpoint, runtime: ShardRuntime,
                         spec: dict, level_sizes: List[int],
                         dropped: int, prev: dict) -> None:
    """One coordinated checkpoint epoch (sorted engine): every shard
    snapshots its partition at the level barrier, then the coordinator
    seals and publishes — so the manifest is either absent (crash
    mid-stage: previous checkpoint adoptable) or names a snapshot every
    shard completed.  ``prev`` carries this search's previous sealed
    snapshot dir so shards hard-link unchanged runs; updated in place."""
    version = ck.next_version()
    stage = ck.begin(version)
    shards = runtime.bcast(_w_bfs_snapshot, spec, stage, prev.get("dir"))
    prev["dir"] = ck.publish(version, {
        "engine": "sorted", "sharded": True, "nshards": runtime.nshards,
        "width": spec["width"], "n_states": 0,
        "level_sizes": list(level_sizes), "dropped": int(dropped),
        "golden": ckpt.golden_owner_values(runtime.nshards, spec["width"], 0),
        "shards": shards})


def _roll_back(runtime: ShardRuntime, ck: Optional[SearchCheckpoint],
               spec: dict, exc: BaseException, lev: int,
               recoveries: int, max_recoveries: int) -> dict:
    """In-run recovery shared by both sharded BFS engines.

    Called when a level's collective (or its checkpoint publish) failed
    with ``exc``.  Either readies the runtime for re-adoption of the last
    coordinated checkpoint and returns its manifest state (the caller
    rebuilds every shard from it), or raises a structured
    :class:`ShardFailure` — never hangs, never leaves the pool
    desynchronized.  Steps: validate that recovery is possible (an
    adoptable checkpoint exists, the ``max_recoveries`` budget is not
    exhausted), drain and respawn the worker pool (:meth:`ShardRuntime.
    recover`), wipe the structure's exchange dir (in-flight buckets of
    the failed epoch are dead traffic).  Books the rollback under
    ``extsort.STATS['recoveries']`` and the levels that must be re-run
    under ``'replayed_levels'`` — separate from the pass ledgers, so the
    per-level pass budgets still hold for the non-replayed work."""
    shard = getattr(exc, "shard", None)
    site = getattr(exc, "phase", None) or type(exc).__name__
    # The span closes on the failure raises too — an unrecoverable run
    # still traces WHERE it died (shard_lost / site / level attrs).
    with obs.span("recovery.rollback", level=lev, shard_lost=shard,
                  site=site, attempt=recoveries + 1):
        state = None
        if ck is not None:
            try:
                state = ck.latest()
            except ckpt.CheckpointError:
                state = None
        if state is None:
            raise ShardFailure(
                "sharded BFS failed and no coordinated checkpoint is "
                "adoptable — enable checkpoint_dir= to make runs recoverable",
                shard=shard, site=site, epoch=runtime.epoch, level=lev,
                recoveries=recoveries) from exc
        if recoveries >= max_recoveries:
            raise ShardFailure(
                f"sharded BFS failed and the recovery budget is exhausted "
                f"({recoveries}/{max_recoveries} used) — raise "
                "max_recoveries= to keep self-healing",
                shard=shard, site=site, epoch=runtime.epoch, level=lev,
                recoveries=recoveries) from exc
        extsort.STATS["recoveries"] += 1
        runtime.recover()
        runtime.wipe_exchange(spec["name"])
        extsort.STATS["replayed_levels"] += max(
            0, lev - (len(state["level_sizes"]) - 1))
        return state


def sharded_bfs(runtime: ShardRuntime, start_rows: np.ndarray, gen_next,
                width: int, chunk_rows: int = 1 << 16,
                max_levels: int = 10_000, run_rows: int = 1 << 18,
                max_runs: int = 8, compaction: str = "full",
                size_ratio: int = 2, bucket_capacity: Optional[int] = None,
                checkpoint_dir: Optional[str] = None,
                checkpoint_every: int = 1, resume: bool = False,
                max_recoveries: int = 0, compress: bool = False):
    """Distributed sorted-list BFS: each shard owns the states hashing to
    it, sorts only its own partition (one sort pass per level per shard),
    and ships cross-shard expansion rows through the bucket exchange.

    In spawn mode ``gen_next`` must be picklable (a module-level class
    instance — see examples/pancake_bfs.py).  Returns (level_sizes,
    ShardedVisited); level counts are exactly the single-process
    engine's for any nshards.

    ``checkpoint_dir=`` adds the coordinated checkpoint epoch of
    docs/checkpointing.md: each shard snapshots its partition at the
    level (sync) barrier, the coordinator publishes atomically.  Resume
    re-validates nshards and the owner-function golden values before any
    shard adopts its partition.

    ``max_recoveries=`` > 0 arms in-run self-healing: a worker death,
    collective timeout, or fatal I/O error rolls every shard back to the
    last coordinated checkpoint and resumes from that level (respawning
    the spawn pool), up to the budget — with level counts provably equal
    to the fault-free run (docs/fault-tolerance.md).  When recovery is
    impossible the run raises a structured :class:`ShardFailure`.
    """
    spec = {"kind": "bfs", "name": runtime.next_name("bfs"), "width": width,
            "chunk_rows": chunk_rows, "run_rows": run_rows,
            "max_runs": max_runs, "compaction": compaction,
            "size_ratio": size_ratio, "rec_width": width,
            "rec_dtype": "uint32", "capacity": bucket_capacity,
            "codec": "keys" if compress else None}
    ck = SearchCheckpoint(checkpoint_dir) if checkpoint_dir else None
    ck_prev: dict = {}

    def _adopt(st: dict):
        """Rebuild every shard from a sealed snapshot; returns the
        (level_sizes, dropped) the manifest pins."""
        snap = ck.snapshot_dir(st)
        runtime.map(_w_bfs_restore,
                    [(spec, snap, st["shards"][s])
                     for s in range(runtime.nshards)])
        return [int(x) for x in st["level_sizes"]], int(st.get("dropped", 0))

    state = ck.latest() if (ck is not None and resume) else None
    if state is not None:
        ckpt.validate_resume(state, "sorted", runtime.nshards, width, 0,
                             sharded=True)
        runtime.bcast(_w_bfs_init, spec)
        level_sizes, dropped = _adopt(state)
    else:
        runtime.bcast(_w_bfs_init, spec)
        start_rows = np.ascontiguousarray(start_rows,
                                          np.uint32).reshape(-1, width)
        with obs.span("bfs.level", level=0, engine="sorted",
                      nshards=runtime.nshards):
            writer = runtime.driver.writer(spec)
            writer.put(hash_owner_np(start_rows, runtime.nshards), start_rows)
            epoch = runtime.next_epoch()
            dropped = runtime.seal_driver(spec, epoch)
            sizes = runtime.bcast(_w_bfs_seed, spec, epoch)
            runtime.collect_obs()
        level_sizes = [sum(sizes)]
        if level_sizes[0] == 0:
            return [], ShardedVisited(runtime, spec, dropped)
        if ck is not None:      # level-0 snapshot: any kill is resumable
            _ckpt_sharded_sorted(ck, runtime, spec, level_sizes, dropped,
                                 ck_prev)
    recoveries = 0
    lev = len(level_sizes)
    high = lev - 1            # highest level ever started (replay tagging)
    while lev <= max_levels:
        # Coordinator-side level span: closes at the barrier, so its
        # metric deltas include the worker totals collect_obs folds in.
        # Levels re-run after a rollback carry replay=True.
        attrs = {"level": lev, "engine": "sorted", "nshards": runtime.nshards}
        if lev <= high:
            attrs["replay"] = True
        high = max(high, lev)
        try:
            with obs.span("bfs.level", **attrs):
                epoch = runtime.next_epoch()
                if runtime.pipelined:
                    res = runtime.bcast(_w_bfs_level, spec, gen_next,
                                        epoch, lev)
                    dropped += sum(d for d, _t in res)
                    total = sum(t for _d, t in res)
                else:
                    dropped += sum(runtime.bcast(_w_bfs_expand, spec,
                                                 gen_next, epoch, lev))
                    total = sum(runtime.bcast(_w_bfs_absorb, spec, epoch))
                runtime.collect_obs()
                if total == 0:
                    break
                level_sizes.append(total)
                if ck is not None and lev % checkpoint_every == 0:
                    _ckpt_sharded_sorted(ck, runtime, spec, level_sizes,
                                         dropped, ck_prev)
        except (RuntimeError, OSError) as exc:
            # Worker death/timeout (WorkerLost), an in-worker exception, or
            # a coordinator-side fatal I/O error: roll back to the last
            # coordinated checkpoint and replay, or die loudly.
            state = _roll_back(runtime, ck, spec, exc, lev, recoveries,
                               max_recoveries)
            runtime.bcast(_w_bfs_init, spec)
            level_sizes, dropped = _adopt(state)
            recoveries += 1
            # Respawned workers carry no incremental-link history: the next
            # snapshot full-copies (safe; linking resumes after it).
            ck_prev.clear()
            lev = len(level_sizes)
            continue
        lev += 1
    return level_sizes, ShardedVisited(runtime, spec, dropped)


# ================================================= distributed BFS (implicit)

def _w_ibfs_pass(ctx: ShardContext, spec: dict, gen_neighbors,
                 epoch_in: int, srcs_in: tuple, epoch_out: int, seed: bool,
                 lev: int = 0) -> tuple:
    """One fused BFS level on this shard's block of the bit array.

    Absorbs the marks bucket-shipped here at epoch_in (they join the
    locally queued marks in the op-log snapshot), then runs the SAME
    single fused read-write pass as the single-process engine — apply
    marks, rotate, count, expand.  Expansion marks for local states queue
    straight into the (snapshot-isolated) op log; marks for remote states
    go to the owner's bucket, sealed at epoch_out.  Per-shard budget:
    exactly ONE rw pass over the local array per level, zero sorts."""
    if faults.ACTIVE:     # the worker-kill-at-level-k site
        faults.fire("worker_level", shard=ctx.shard, level=lev)
    with obs.span("bfs.level", level=lev, shard=ctx.shard, phase="pass"):
        obj: DiskBitArray = ctx.objects[spec["name"]]
        base = ctx.shard * spec["per"]
        n, nshards = spec["n"], ctx.nshards
        expand_batch = spec["expand_batch"]
        writer = ctx.writer(spec)
        for _src, rec in ctx.recv(spec, epoch_in, srcs_in):
            obj.update(rec[:, 0] - base, rec[:, 1].astype(np.uint8))

        count = 0

        def count_cur(chunk_start: int, vals: np.ndarray) -> None:
            nonlocal count
            count += int(np.count_nonzero(vals == CUR))

        def rotate(chunk_start: int, vals: np.ndarray) -> np.ndarray:
            vals = np.where(vals == CUR, np.uint8(DONE), vals)
            return np.where(vals == NEXT, np.uint8(CUR), vals)

        def expand(chunk_start: int, vals: np.ndarray) -> None:
            (cur_pos,) = np.nonzero(vals == CUR)
            for lo in range(0, cur_pos.size, expand_batch):
                idx = (base + chunk_start
                       + cur_pos[lo:lo + expand_batch].astype(np.int64))
                nbrs = np.asarray(gen_neighbors(idx), np.int64).reshape(-1)
                ok = (nbrs >= 0) & (nbrs < n)
                nbrs = nbrs[ok]
                owner = block_owner_np(nbrs, n, nshards)
                local = owner == ctx.shard
                if local.any():      # snapshot-isolated: defers to next pass
                    obj.update(nbrs[local] - base,
                               np.full(int(local.sum()), NEXT, np.uint8))
                if not local.all():
                    rec = np.empty((nbrs.shape[0] - int(local.sum()), 2),
                                   np.int64)
                    rec[:, 0] = nbrs[~local]
                    rec[:, 1] = NEXT
                    writer.put(owner[~local], rec)

        if seed:
            # Fresh zeroed array: CUR lives only in chunks with queued
            # seed ops.
            obj.run_pass(PassPlan("bfs-seed", dirty_only=True)
                         .reads(count_cur).reads(expand))
        else:
            obj.run_pass(PassPlan("bfs-level").writes(rotate)
                         .reads(count_cur).reads(expand),
                         combine=_mark_first, apply=_apply_unseen)
        return count, int(writer.seal(epoch_out,
                                      publish_done=ctx.pipelined).sum())


def _w_ibfs_level(ctx: ShardContext, spec: dict, gen_neighbors,
                  epoch_in: int, srcs_in: tuple, epoch_out: int,
                  seed: bool, lev: int) -> tuple:
    """One whole pipelined implicit level: (seed only) absorb the
    coordinator's sealed marks, run the fused pass + seal with markers,
    then absorb peers' epoch_out marks as their markers land — they queue
    into the snapshot-isolated op log for the NEXT pass, exactly where
    the barrier path's start-of-next-level absorb puts them (local marks
    first, then remote ascending src), so the op-log order and the one
    rw-pass-per-level budget are unchanged.  Returns (count, dropped)."""
    count, dropped = _w_ibfs_pass(ctx, spec, gen_neighbors, epoch_in,
                                  srcs_in, epoch_out, seed, lev)
    obj: DiskBitArray = ctx.objects[spec["name"]]
    base = ctx.shard * spec["per"]
    for _src, rec in ctx.recv(spec, epoch_out, range(ctx.nshards)):
        obj.update(rec[:, 0] - base, rec[:, 1].astype(np.uint8))
    return count, dropped


def _w_ibfs_snapshot(ctx: ShardContext, spec: dict, stage_root: str,
                     epoch_pending: int, srcs_pending: tuple) -> dict:
    """Snapshot this shard's block of the bit array at the level barrier.

    Marks bucket-shipped here at ``epoch_pending`` (the epoch the pass we
    just ran sealed, not yet absorbed) are folded into the local op log
    FIRST, so the snapshot is self-contained: bucket files are consumed,
    and the live run's next pass simply finds that epoch already drained.
    In pipelined mode the level's tail absorb already drained it —
    ``srcs_pending`` is empty and this absorbs nothing."""
    obj: DiskBitArray = ctx.objects[spec["name"]]
    base = ctx.shard * spec["per"]
    for _src, rec in ctx.recv(spec, epoch_pending, srcs_pending):
        obj.update(rec[:, 0] - base, rec[:, 1].astype(np.uint8))
    return ckpt.snapshot_implicit_state(
        os.path.join(stage_root, f"shard{ctx.shard:03d}"), obj)


def _w_ibfs_restore(ctx: ShardContext, spec: dict, snap_root: str) -> None:
    """Adopt this shard's block (packed chunks + queued-mark logs) from a
    sealed snapshot, replacing the freshly zeroed local array."""
    ckpt.restore_implicit_state(
        os.path.join(snap_root, f"shard{ctx.shard:03d}"),
        ctx.objects[spec["name"]])


def sharded_implicit_bfs(runtime: ShardRuntime, n_states: int, start_idx,
                         gen_neighbors, chunk_elems: int = 1 << 22,
                         max_levels: int = 10_000,
                         expand_batch: int = 1 << 16,
                         log_buf_rows: int = 1 << 20,
                         bucket_capacity: Optional[int] = None,
                         checkpoint_dir: Optional[str] = None,
                         checkpoint_every: int = 1, resume: bool = False,
                         max_recoveries: int = 0, compress: bool = False):
    """Distributed implicit BFS: the 2-bit array is block-distributed,
    each shard runs ONE fused mark/rotate/count/expand pass per level
    over its own block, and cross-shard marks ride the bucket exchange
    into the owner's snapshot-isolated op log.

    In spawn mode ``gen_neighbors`` must be picklable.  Returns
    (level_sizes, ShardedDiskBitArray).

    ``checkpoint_dir=`` adds the coordinated checkpoint epoch
    (docs/checkpointing.md): each shard absorbs its pending bucket marks
    into the local op log and snapshots its block at the level barrier;
    the coordinator publishes atomically.  Resume re-validates nshards,
    n_states, the chunk layout, and the owner-function golden values
    before any shard adopts its block.

    ``max_recoveries=`` > 0 arms in-run self-healing exactly as in
    :func:`sharded_bfs`: roll back to the last coordinated checkpoint,
    respawn the pool, replay — or raise :class:`ShardFailure` loudly.
    """
    ck = SearchCheckpoint(checkpoint_dir) if checkpoint_dir else None
    state = ck.latest() if (ck is not None and resume) else None
    if state is not None:
        ckpt.validate_resume(state, "implicit", runtime.nshards, 1,
                             n_states, sharded=True)
        # The snapshot pins the chunk layout: adopt with ITS chunk_elems.
        chunk_elems = int(state["chunk_elems"])
    # On resume every chunk arrives from the snapshot: skip the zero-fill.
    bits = ShardedDiskBitArray(runtime, n_states, chunk_elems=chunk_elems,
                               log_buf_rows=log_buf_rows,
                               capacity=bucket_capacity,
                               init_chunks=state is None,
                               compress=compress)
    spec = dict(bits.spec)
    spec["expand_batch"] = expand_batch
    if state is not None:
        runtime.bcast(_w_ibfs_restore, spec, ck.snapshot_dir(state))
        level_sizes: List[int] = [int(x) for x in state["level_sizes"]]
        dropped = int(state.get("dropped", 0))
        seed = False
        # All queued marks live in the adopted op logs; a fresh epoch has
        # no sealed traffic, so the first resumed pass absorbs nothing.
        epoch_in = runtime.next_epoch()
        srcs_in: tuple = ()
    else:
        start = np.unique(np.asarray(start_idx, np.int64).reshape(-1))
        assert start.size and start.min() >= 0 and start.max() < n_states
        bits.update(start, np.full(start.shape, CUR, np.uint8))
        epoch = runtime.next_epoch()
        dropped = runtime.seal_driver(bits.spec, epoch)
        # The first worker pass absorbs the sealed seed buckets itself
        # (epoch_in == the seed epoch, source = the coordinator): seeds
        # queue as delayed ops, the dirty-only seed pass
        # applies/counts/expands them.
        level_sizes = []
        seed = True
        epoch_in = epoch
        srcs_in = (runtime.nshards,)
    recoveries = 0
    high = len(level_sizes) - 1   # highest level ever computed (replay tag)
    while len(level_sizes) - 1 < max_levels:
        lev_now = len(level_sizes)     # the level this pass computes
        attrs = {"level": lev_now, "engine": "implicit",
                 "nshards": runtime.nshards}
        if lev_now <= high:
            attrs["replay"] = True
        high = max(high, lev_now)
        try:
            with obs.span("bfs.level", **attrs):
                epoch_out = runtime.next_epoch()
                fn = _w_ibfs_level if runtime.pipelined else _w_ibfs_pass
                res = runtime.map(fn,
                                  [(spec, gen_neighbors, epoch_in, srcs_in,
                                    epoch_out, seed, lev_now)]
                                  * runtime.nshards)
                runtime.collect_obs()
                total = sum(c for c, _d in res)
                dropped += sum(d for _c, d in res)
                if not seed and total == 0:
                    break
                level_sizes.append(total)
                seed = False
                epoch_in = epoch_out
                # Pipelined levels tail-absorb their own epoch: the next
                # pass (and any snapshot) finds it already drained.
                srcs_in = (() if runtime.pipelined
                           else tuple(range(runtime.nshards)))
                lev = len(level_sizes) - 1
                if ck is not None and lev % checkpoint_every == 0:
                    version = ck.next_version()
                    stage = ck.begin(version)
                    runtime.bcast(_w_ibfs_snapshot, spec, stage, epoch_in,
                                  srcs_in)
                    ck.publish(version, {
                        "engine": "implicit", "sharded": True,
                        "nshards": runtime.nshards,
                        "width": 1, "n_states": int(n_states),
                        "chunk_elems": int(chunk_elems),
                        "level_sizes": list(level_sizes),
                        "dropped": int(dropped),
                        "golden": ckpt.golden_owner_values(runtime.nshards, 1,
                                                           int(n_states))})
        except (RuntimeError, OSError) as exc:
            state = _roll_back(runtime, ck, spec, exc, len(level_sizes),
                               recoveries, max_recoveries)
            # Respawned workers re-make their (empty) blocks and adopt the
            # snapshot: packed chunks + queued-mark op logs.  The adopted
            # logs carry all in-flight marks, and a fresh epoch has no
            # bucket files, so the replayed pass absorbs nothing stale.
            rspec = dict(spec)
            rspec["init_chunks"] = False
            runtime.bcast(_w_make, rspec)
            runtime.bcast(_w_ibfs_restore, spec, ck.snapshot_dir(state))
            level_sizes = [int(x) for x in state["level_sizes"]]
            dropped = int(state.get("dropped", 0))
            seed = False
            epoch_in = runtime.next_epoch()
            srcs_in = ()
            recoveries += 1
            continue
    bits.dropped = dropped
    return level_sizes, bits
