"""Tier D — the paper-faithful out-of-core Roomy implementation.

Real chunked disk files, streaming passes, external merge sort; see
DESIGN.md §2. The JAX tier (repro.core) mirrors this API on-device.
"""
# trace is intentionally NOT imported here: pre-importing it makes
# ``python -m repro.core.disk.trace`` warn about the double import, and
# ``from repro.core.disk import trace`` resolves the submodule anyway.
from . import faults
from .bfs import breadth_first_search, implicit_bfs, level_step
from .bitarray import DiskBitArray
from .buckets import block_owner_np, hash_owner_np, hash_rows_np
from .checkpoint import CheckpointError, SearchCheckpoint
from .cluster import (ShardedDiskBitArray, ShardedDiskHashTable,
                      ShardedDiskList, ShardFailure, ShardRuntime,
                      WorkerLost)
from .darray import DiskArray
from .dhash import DiskHashTable
from .dlist import DiskList
from .extsort import (MembershipProbe, external_sort, merge_difference,
                      row_keys, sort_rows, stream_dedupe)
from .lsm import SortedRunSet
from .passes import PassPlan
from .store import ChunkStore

__all__ = [
    "CheckpointError", "ChunkStore", "DiskArray", "DiskBitArray",
    "DiskHashTable", "DiskList", "MembershipProbe", "PassPlan",
    "SearchCheckpoint", "ShardFailure", "ShardRuntime",
    "ShardedDiskBitArray", "ShardedDiskHashTable", "ShardedDiskList",
    "SortedRunSet", "WorkerLost", "block_owner_np", "breadth_first_search",
    "external_sort", "faults", "hash_owner_np", "hash_rows_np",
    "implicit_bfs", "level_step", "merge_difference", "row_keys",
    "sort_rows", "stream_dedupe",
]
