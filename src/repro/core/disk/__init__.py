"""Tier D — the paper-faithful out-of-core Roomy implementation.

Real chunked disk files, streaming passes, external merge sort; see
DESIGN.md §2. The JAX tier (repro.core) mirrors this API on-device.
"""
from .bfs import breadth_first_search
from .darray import DiskArray
from .dhash import DiskHashTable
from .dlist import DiskList
from .extsort import external_sort, merge_difference, row_keys, sort_rows
from .store import ChunkStore

__all__ = [
    "ChunkStore", "DiskArray", "DiskHashTable", "DiskList",
    "breadth_first_search", "external_sort", "merge_difference",
    "row_keys", "sort_rows",
]
