"""Tier D — the paper-faithful out-of-core Roomy implementation.

Real chunked disk files, streaming passes, external merge sort; see
DESIGN.md §2. The JAX tier (repro.core) mirrors this API on-device.

This module is the public facade of the disk tier.  ``__all__`` below is
the supported surface — structures, search engines, the cluster/search
config API, the sharded runtime, and the transport extension point:

  structures   ChunkStore, DiskArray, DiskBitArray, DiskHashTable,
               DiskList, SortedRunSet, PassPlan, MembershipProbe
  search       breadth_first_search, implicit_bfs, level_step
               (single-process or sharded via ``cluster=``)
  config       ClusterConfig, CheckpointConfig, RecoveryConfig
               (docs/transports.md — collapses the legacy
               nshards/shard_mode/checkpoint_dir/... kwargs)
  cluster      ShardRuntime, sharded_bfs, sharded_implicit_bfs, the
               Sharded* structures, ShardFailure, WorkerLost
  transport    Transport, make_transport, TRANSPORT_KINDS
               (pluggable bucket wire: "fs", "tcp", "loopback")
  checkpoint   SearchCheckpoint, CheckpointError
  serving      publish_oracle, DistanceOracle, ShardedOracle, OracleError
               (docs/serving.md — sealed read-only artifacts + batched
               query serving over an LRU chunk cache)
  compression  codec (submodule), CodecError — varint-delta sorted-run
               keys + RLE 2-bit chunks (docs/compression.md); opt in via
               ``compress=True`` on the engines / ``publish_oracle``,
               ``ClusterConfig(wire_compress=True)`` on mailbox wires
  submodules   faults (fault injection), trace (run traces), extsort,
               buckets, ...  — importable, but their internals
               (``_w_*`` worker commands, owner-map helpers) are
               implementation detail, not API.

Owner-map internals (``hash_rows_np``/``hash_owner_np``/
``block_owner_np``) moved off this facade — they are a cross-tier
*contract* pinned by golden tests, not a user API; reach them via
``repro.core.disk.buckets`` if you are implementing a structure.
"""
# trace is intentionally NOT imported here: pre-importing it makes
# ``python -m repro.core.disk.trace`` warn about the double import, and
# ``from repro.core.disk import trace`` resolves the submodule anyway.
from . import codec, faults
from .bfs import breadth_first_search, implicit_bfs, level_step
from .codec import CodecError
from .bitarray import DiskBitArray
from .checkpoint import CheckpointError, SearchCheckpoint
from .cluster import (ShardedDiskBitArray, ShardedDiskHashTable,
                      ShardedDiskList, ShardFailure, ShardRuntime,
                      WorkerLost, sharded_bfs, sharded_implicit_bfs)
from .config import CheckpointConfig, ClusterConfig, RecoveryConfig
from .darray import DiskArray
from .dhash import DiskHashTable
from .dlist import DiskList
from .extsort import (MembershipProbe, external_sort, merge_difference,
                      row_keys, sort_rows, stream_dedupe)
from .lsm import SortedRunSet
from .oracle import (DistanceOracle, OracleError, ShardedOracle,
                     publish_oracle)
from .passes import PassPlan
from .store import ChunkStore
from .transport import TRANSPORT_KINDS, Transport, make_transport

__all__ = [
    "CheckpointConfig", "CheckpointError", "ChunkStore", "ClusterConfig",
    "CodecError", "DiskArray", "DiskBitArray", "DiskHashTable", "DiskList",
    "DistanceOracle", "MembershipProbe", "OracleError", "PassPlan",
    "RecoveryConfig", "SearchCheckpoint", "ShardFailure", "ShardRuntime",
    "ShardedDiskBitArray", "ShardedDiskHashTable", "ShardedDiskList",
    "ShardedOracle", "SortedRunSet", "TRANSPORT_KINDS", "Transport",
    "WorkerLost", "breadth_first_search", "codec", "external_sort", "faults",
    "implicit_bfs", "level_step", "make_transport", "merge_difference",
    "publish_oracle", "row_keys", "sharded_bfs", "sharded_implicit_bfs",
    "sort_rows", "stream_dedupe",
]
