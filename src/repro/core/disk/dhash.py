"""DiskHashTable — the paper's RoomyHashTable on real disk (Tier D).

(key, value) pairs are bucketed by ``hash(key) % nbuckets`` into per-bucket
files kept sorted by key; delayed inserts/updates/removes append to
per-bucket op logs. ``sync`` merges each bucket's log into its table file in
one pass — the same sorted merge Tier J's hashtable.py performs on device.
"""
from __future__ import annotations

import os
import shutil
import uuid
from typing import Callable

import numpy as np

# The canonical numpy row hash (buckets.py) — the sharded runtime buckets
# keys with the SAME function, so a key's table bucket and its owner shard
# are derived from one hash definition, pinned by golden-value tests.
from .buckets import hash_rows_np as _hash_rows


def _keycols(kw: int):
    return [f"k{j}" for j in range(kw)]


class DiskHashTable:
    OP_PUT, OP_DEL = 0, 1

    def __init__(self, workdir: str, key_width: int, val_width: int,
                 nbuckets: int = 64, name: str | None = None):
        self.kw, self.vw = key_width, val_width
        self.nbuckets = nbuckets
        name = name or f"dhash_{uuid.uuid4().hex[:8]}"
        self.path = os.path.join(workdir, name)
        if os.path.isdir(self.path):
            shutil.rmtree(self.path)
        os.makedirs(self.path)
        self._logs = [[] for _ in range(nbuckets)]

    def _tab_path(self, b):
        return os.path.join(self.path, f"t{b:04d}.npz")

    # ------------------------------------------------------ delayed ops
    def _queue(self, keys, vals, op):
        keys = np.asarray(keys, np.uint32).reshape(-1, self.kw)
        vals = np.asarray(vals, np.int64).reshape(keys.shape[0], self.vw)
        ops = np.full(keys.shape[0], op, np.int64)
        b = _hash_rows(keys) % np.uint32(self.nbuckets)
        order = np.argsort(b, kind="stable")
        keys, vals, ops, b = keys[order], vals[order], ops[order], b[order]
        bounds = np.searchsorted(b, np.arange(self.nbuckets + 1))
        for i in range(self.nbuckets):
            lo, hi = bounds[i], bounds[i + 1]
            if hi > lo:
                self._logs[i].append((keys[lo:hi], vals[lo:hi], ops[lo:hi]))

    def insert(self, keys, vals):
        self._queue(keys, vals, self.OP_PUT)

    def remove(self, keys):
        self._queue(keys, np.zeros((np.asarray(keys).reshape(-1, self.kw).shape[0],
                                    self.vw), np.int64), self.OP_DEL)

    # -------------------------------------------------------------- sync
    def _load_bucket(self, b):
        if os.path.exists(self._tab_path(b)):
            z = np.load(self._tab_path(b))
            return z["keys"], z["vals"]
        return (np.zeros((0, self.kw), np.uint32),
                np.zeros((0, self.vw), np.int64))

    def sync(self, combine: Callable = None, apply: Callable = None) -> None:
        """combine(v1, v2) merges queued payloads per key; apply(old, agg,
        present_mask) produces the stored value. Defaults: overwrite.

        Op-log ORDER is honoured per key (the queue's stable sort keeps
        issue order within a key): a DEL wipes the key *and every earlier
        queued PUT*, and PUTs after the last DEL resurrect the key — their
        combine-fold applies against ``present=False`` (the old value is
        gone). A key whose last op is DEL is removed. This is exactly
        sequential execution of the log; Tier J's hashtable.py applies the
        same rule (TestRoomyHashTableOpOrder mirrors the pins here).
        """
        if combine is None:
            combine = lambda a, b: b
        if apply is None:
            apply = lambda old, agg, present: agg
        for b in range(self.nbuckets):
            if not self._logs[b]:
                continue
            qk = np.concatenate([x[0] for x in self._logs[b]], axis=0)
            qv = np.concatenate([x[1] for x in self._logs[b]], axis=0)
            qo = np.concatenate([x[2] for x in self._logs[b]], axis=0)
            self._logs[b] = []
            tk, tv = self._load_bucket(b)

            # sort queue by key (stable keeps op order within key)
            from .extsort import row_keys
            order = np.argsort(row_keys(qk), kind="stable")
            qk, qv, qo = qk[order], qv[order], qo[order]
            kk = row_keys(qk)
            starts = np.ones(kk.shape[0], bool)
            starts[1:] = kk[1:] != kk[:-1]
            seg = np.cumsum(starts) - 1
            nseg = int(starts.sum())
            uniq_k = qk[starts]
            run_pos = np.arange(kk.shape[0]) - np.maximum.accumulate(
                np.where(starts, np.arange(kk.shape[0]), 0))
            # Position of each key's last DEL (-1 if none): PUTs strictly
            # after it are "live"; everything at or before it is wiped.
            is_del = qo == self.OP_DEL
            last_del = np.full(nseg, -1, np.int64)
            np.maximum.at(last_del, seg, np.where(is_del, run_pos, -1))
            had_del = last_del >= 0
            live_op = (~is_del) & (run_pos > last_del[seg])
            # A key with no surviving PUT is deleted (it must have a DEL:
            # no-DEL keys keep all their PUTs).
            deleted = np.bincount(seg, weights=live_op.astype(np.int64),
                                  minlength=nseg) == 0
            # combine-fold over the live PUTs only, in issue order.
            from .extsort import segment_combine_ordered
            agg = np.zeros_like(qv[:nseg])
            if live_op.any():
                uniq_seg, agg_l = segment_combine_ordered(
                    seg[live_op], qv[live_op], combine)
                agg[uniq_seg] = agg_l

            # merge with table bucket
            tkk = row_keys(tk) if tk.shape[0] else np.zeros(0, row_keys(uniq_k).dtype)
            ukk = row_keys(uniq_k)
            pos = np.searchsorted(tkk, ukk)
            present = np.zeros(ukk.shape[0], bool)
            inb = pos < tkk.shape[0]
            present[inb] = tkk[pos[inb]] == ukk[inb]
            # A DEL before the surviving PUTs wiped the stored value: the
            # resurrecting fold applies as an insert, not an update.
            present_eff = present & ~had_del
            old = np.zeros_like(agg)
            old[present_eff] = tv[pos[present_eff]]
            newv = apply(old, agg, present_eff)

            keep_tab = np.ones(tk.shape[0], bool)
            keep_tab[pos[present]] = False       # replaced or deleted
            live = ~deleted
            mk = np.concatenate([tk[keep_tab], uniq_k[live]], axis=0)
            mv = np.concatenate([tv[keep_tab], newv[live]], axis=0)
            o2 = np.argsort(row_keys(mk), kind="stable")
            np.savez(self._tab_path(b), keys=mk[o2], vals=mv[o2])

    # ------------------------------------------------------------- read
    def lookup(self, keys):
        keys = np.asarray(keys, np.uint32).reshape(-1, self.kw)
        from .extsort import row_keys
        out = np.zeros((keys.shape[0], self.vw), np.int64)
        found = np.zeros(keys.shape[0], bool)
        b = _hash_rows(keys) % np.uint32(self.nbuckets)
        for bb in np.unique(b):
            sel = b == bb
            tk, tv = self._load_bucket(int(bb))
            if not tk.shape[0]:
                continue
            tkk, qkk = row_keys(tk), row_keys(keys[sel])
            pos = np.searchsorted(tkk, qkk)
            inb = pos < tkk.shape[0]
            hit = np.zeros(qkk.shape[0], bool)
            hit[inb] = tkk[pos[inb]] == qkk[inb]
            idx = np.where(sel)[0]
            found[idx[hit]] = True
            out[idx[hit]] = tv[pos[hit]]
        return out, found

    def size(self) -> int:
        n = 0
        for b in range(self.nbuckets):
            tk, _ = self._load_bucket(b)
            n += tk.shape[0]
        return n

    def items(self):
        for b in range(self.nbuckets):
            tk, tv = self._load_bucket(b)
            if tk.shape[0]:
                yield tk, tv

    def destroy(self) -> None:
        shutil.rmtree(self.path, ignore_errors=True)
