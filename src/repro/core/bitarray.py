"""RoomyBitArray — packed 2-bit-element RoomyArray (Tier J).

The device twin of disk/bitarray.py: 16 two-bit elements per uint32 word,
so N states cost N/8 bytes of HBM — the representation behind the paper's
pancake result, where a permutation's Myrvold–Ruskey rank (ranking.py) IS
its index and the element value is a BFS mark (UNSEEN/CUR/NEXT/DONE).

Two delayed-update routes, matching the repo's two execution shapes:

  * single device — ``update`` queues (index, value) ops like array.py;
    ``sync(combine, apply)`` sorts the queue by index, segment-combines,
    and applies through a **disjoint-bit packed scatter**: per touched
    element a clear mask ``3 << shift`` and a value mask ``val << shift``
    are scatter-added per word (distinct elements of one word occupy
    disjoint bits, so add == or), then ``data & ~clr | set`` — no unpacked
    (8× larger) copy of the array is ever materialized.

  * sharded — ``sharded_mark_sync`` is called inside ``jax.shard_map``:
    ops are binned by owner shard and routed through ONE all_to_all
    (delayed.BucketExchange), then applied on the owner with the masked
    ``.at[].set`` mark (or the bitpack Pallas kernel on TPU).

``mark_rotate_count`` is the implicit-BFS hot path (constructs.
implicit_bfs): the delayed-mark scatter and the rotate+count LUT pass
fused into one kernel — one HBM traversal of the packed words per BFS
level, the device twin of the disk pass planner's fused level.
``mark_packed`` / ``rotate_count`` are the unfused halves, kept as the
reference composition.  All dispatch to kernels/bitpack.py via ops.py.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import delayed as D
from . import types as T
from ..kernels import ops as K

FIELDS_PER_WORD = 16

# BFS mark values — single definition shared with Tier D (UNSEEN is 0: a
# fresh array is all-unseen for free).
from .disk.bitarray import CUR, DONE, NEXT, UNSEEN  # noqa: E402

# LUT for the per-level rotate: CUR→DONE, NEXT→CUR, others fixed.
ROTATE_LUT = (UNSEEN << (2 * UNSEEN)) | (DONE << (2 * CUR)) \
    | (CUR << (2 * NEXT)) | (DONE << (2 * DONE))


class RoomyBitArray(NamedTuple):
    data: jax.Array    # (nwords,) uint32 — packed 2-bit elements
    q_idx: jax.Array   # (qcap,) int32 — element index, == capacity if empty
    q_val: jax.Array   # (qcap,) uint32 — queued 2-bit values
    q_n: jax.Array     # () int32

    @property
    def capacity(self) -> int:
        return self.data.shape[0] * FIELDS_PER_WORD

    @property
    def queue_capacity(self) -> int:
        return self.q_idx.shape[0]


def n_words(n: int) -> int:
    return -(-n // FIELDS_PER_WORD)


def make(n: int, queue_capacity: int = 0) -> RoomyBitArray:
    w = n_words(n)
    cap = w * FIELDS_PER_WORD
    return RoomyBitArray(
        data=jnp.zeros((w,), jnp.uint32),
        q_idx=jnp.full((queue_capacity,), cap, jnp.int32),
        q_val=jnp.zeros((queue_capacity,), jnp.uint32),
        q_n=jnp.zeros((), jnp.int32),
    )


# ------------------------------------------------------------ pack codec

def pack_values(vals: jax.Array) -> jax.Array:
    """(k,) values 0..3 → (ceil(k/16),) uint32 (tail fields padded 0)."""
    k = vals.shape[0]
    pad = (-k) % FIELDS_PER_WORD
    v = jnp.concatenate([vals.astype(jnp.uint32),
                         jnp.zeros((pad,), jnp.uint32)])
    v = v.reshape(-1, FIELDS_PER_WORD) & 3
    shifts = (jnp.arange(FIELDS_PER_WORD, dtype=jnp.uint32) * 2)[None, :]
    return jnp.sum(v << shifts, axis=1).astype(jnp.uint32)  # disjoint bits


def unpack_values(data: jax.Array) -> jax.Array:
    """(w,) uint32 → (w·16,) uint32 values 0..3."""
    shifts = (jnp.arange(FIELDS_PER_WORD, dtype=jnp.uint32) * 2)[None, :]
    return ((data[:, None] >> shifts) & 3).reshape(-1)


def get(ba: RoomyBitArray, idx: jax.Array) -> jax.Array:
    """Batched random read of 2-bit elements (resolved delayed access)."""
    return get_packed(ba.data, idx)


def get_packed(data: jax.Array, idx: jax.Array) -> jax.Array:
    idx = idx.astype(jnp.int32)
    word = data[jnp.clip(idx // FIELDS_PER_WORD, 0, data.shape[0] - 1)]
    sh = (2 * (idx % FIELDS_PER_WORD)).astype(jnp.uint32)
    return (word >> sh) & 3


# ------------------------------------------------------------ delayed ops

def update(ba: RoomyBitArray, idx: jax.Array, vals: jax.Array,
           valid: jax.Array | None = None):
    """Queue delayed writes vals∈0..3 at idx. Returns (array, overflow)."""
    if valid is None:
        valid = jnp.ones(idx.shape, bool)
    qcap = ba.queue_capacity
    dest = ba.q_n + jnp.cumsum(valid.astype(jnp.int32)) - 1
    dest = jnp.where(valid, dest, qcap)
    q_idx = ba.q_idx.at[dest].set(idx.astype(jnp.int32), mode="drop")
    q_val = ba.q_val.at[dest].set(vals.astype(jnp.uint32) & 3, mode="drop")
    nvalid = jnp.sum(valid.astype(jnp.int32))
    overflow = ba.q_n + nvalid > qcap
    q_n = jnp.minimum(ba.q_n + nvalid, qcap)
    return ba._replace(q_idx=q_idx, q_val=q_val, q_n=q_n), overflow


def _packed_write(data: jax.Array, idx: jax.Array, vals: jax.Array) -> jax.Array:
    """Scatter 2-bit vals at UNIQUE element indices (cap ⇒ drop) without
    unpacking: disjoint-bit clear/set masks accumulated per word."""
    nw = data.shape[0]
    cap = nw * FIELDS_PER_WORD
    word = jnp.where(idx < cap, idx // FIELDS_PER_WORD, nw)
    sh = (2 * (idx % FIELDS_PER_WORD)).astype(jnp.uint32)
    clr = jnp.zeros((nw,), jnp.uint32).at[word].add(
        jnp.uint32(3) << sh, mode="drop")
    setm = jnp.zeros((nw,), jnp.uint32).at[word].add(
        (vals.astype(jnp.uint32) & 3) << sh, mode="drop")
    return (data & ~clr) | setm


def sync(ba: RoomyBitArray, combine: Optional[Callable] = None,
         apply: Optional[Callable] = None) -> RoomyBitArray:
    """Execute queued updates in one batch (array.py's sync contract).

    combine(p1, p2): associative merge of values aimed at one index
    (default bitwise OR); apply(old, agg) -> new values at touched
    indices (default overwrite).  The index sort is an integer argsort,
    never a row lexsort — rank indexing is what removed the row keys.
    """
    if combine is None:
        combine = jnp.bitwise_or
    if apply is None:
        apply = lambda old, agg: agg
    cap = ba.capacity
    qcap = ba.queue_capacity
    if qcap == 0:               # nothing can be queued: sync is a no-op
        return ba
    in_q = jnp.arange(qcap) < ba.q_n
    idx = jnp.where(in_q, ba.q_idx, cap)
    order = jnp.argsort(idx, stable=True)
    idx_s = idx[order]
    val_s = ba.q_val[order]
    starts = jnp.concatenate([jnp.ones((1,), bool), idx_s[1:] != idx_s[:-1]])
    agg = T.segmented_reduce_last(val_s, starts, combine)
    last = jnp.concatenate([idx_s[1:] != idx_s[:-1], jnp.ones((1,), bool)])
    target = jnp.where(last & (idx_s < cap), idx_s, cap)
    old = get_packed(ba.data, jnp.minimum(target, cap - 1))
    new = apply(old, agg)
    data = _packed_write(ba.data, target, new)
    return RoomyBitArray(data, jnp.full((qcap,), cap, jnp.int32),
                         jnp.zeros((qcap,), jnp.uint32),
                         jnp.zeros((), jnp.int32))


# ------------------------------------------------------- BFS hot paths

def mark_packed(data: jax.Array, idx: jax.Array,
                valid: jax.Array | None = None, *, mark: int = NEXT,
                only_if: int = UNSEEN, impl: str = "auto") -> jax.Array:
    """data[idx] ← mark where the element holds only_if — the delayed-mark
    apply.  Safe under duplicate indices (all writers agree); invalid /
    out-of-range indices drop.  Dispatches to the bitpack Pallas kernel."""
    cap = data.shape[0] * FIELDS_PER_WORD
    idx = idx.astype(jnp.int32)
    if valid is not None:
        idx = jnp.where(valid, idx, cap)
    return K.bitpack_scatter_mark(data, idx, mark=mark, only_if=only_if,
                                  impl=impl)


def rotate_count(data: jax.Array, n: int, *, lut: int = ROTATE_LUT,
                 count_val: int = CUR, impl: str = "auto"):
    """Map every element through the 4-entry lut and count elements that
    map to count_val among the first n — the fused per-level rotate+count
    pass.  Returns (new_data, count).

    Arrays with tail padding (n < 16·words) require a zero-preserving
    lut (lut[0] == 0, as ROTATE_LUT is): the tail-count correction
    assumes padding fields hold 0, which only a zero-preserving lut
    keeps true across calls."""
    pad = data.shape[0] * FIELDS_PER_WORD - n
    assert pad == 0 or (lut & 3) == 0, \
        "padded arrays need a zero-preserving lut (lut[0] == 0)"
    new, cnt = K.bitpack_lut_count(data, lut, count_val, impl=impl)
    if pad and (lut & 3) == count_val:  # padding fields hold 0 → lut[0]
        cnt = cnt - pad
    return new, cnt


def mark_rotate_count(data: jax.Array, idx: jax.Array, n: int, *,
                      lut: int = ROTATE_LUT, count_val: int = CUR,
                      mark: int = NEXT, only_if: int = UNSEEN,
                      impl: str = "auto"):
    """Fused per-level pass: ``data[idx] ← mark`` where the element holds
    ``only_if`` (the delayed-mark apply), THEN map every element through
    the 4-entry lut and count elements mapping to ``count_val`` among the
    first n — one kernel, one HBM read-write traversal of the packed
    words, where mark_packed + rotate_count costs two
    (kernels/bitpack.py bitpack_mark_rotate_count).  Returns
    (new_data, count).

    Arrays with tail padding require a zero-preserving lut (lut[0] == 0;
    see rotate_count) and mark indices within [0, n) — a mark landing in
    a padding field would also break the tail-count correction."""
    cap = data.shape[0] * FIELDS_PER_WORD
    pad = cap - n
    assert pad == 0 or (lut & 3) == 0, \
        "padded arrays need a zero-preserving lut (lut[0] == 0)"
    new, cnt = K.bitpack_mark_rotate_count(
        data, idx.astype(jnp.int32), lut, count_val, mark=mark,
        only_if=only_if, impl=impl)
    if pad and (lut & 3) == count_val:  # padding fields hold 0 → lut[0]
        cnt = cnt - pad
    return new, cnt


def count_value(ba: RoomyBitArray, value: int, n: int | None = None) -> jax.Array:
    """predicateCount for one 2-bit value over the first n elements."""
    vals = unpack_values(ba.data)
    n = ba.capacity if n is None else n
    hit = (vals == value) & (jnp.arange(ba.capacity) < n)
    return jnp.sum(hit.astype(jnp.int32))


# ---------------------------------------------------------- sharded sync

def sharded_mark_sync(
    data_local: jax.Array,   # (nwords_local,) uint32 — this shard's slice
    idx: jax.Array,          # (m,) global element indices
    valid: jax.Array,        # (m,) bool
    axis_name: str,
    nshards: int,
    capacity: int,           # per-(src,dst) bucket capacity
    *,
    mark: int = NEXT,
    only_if: int = UNSEEN,
    impl: str = "auto",
):
    """Delayed mark sync over a mesh axis — call inside ``jax.shard_map``.

    Elements are sharded contiguously: shard s owns global indices
    [s·E, (s+1)·E) with E = nwords_local·16.  Ops are binned by owner
    (bin_by_dest), exchanged with one all_to_all, and applied on the owner
    with the masked set.  Returns (new_data_local, dropped) — ``dropped``
    counts ops that overflowed their bucket (size capacity accordingly).
    """
    elems_local = data_local.shape[0] * FIELDS_PER_WORD
    idx = idx.astype(jnp.int32)
    dest = idx // elems_local
    local = idx % elems_local
    valid = valid & (dest >= 0) & (dest < nshards)

    def owner_apply(state, flat_local, flat_valid):
        return mark_packed(state, flat_local, flat_valid, mark=mark,
                           only_if=only_if, impl=impl)

    return D.bucket_sync_update(dest, local, valid, axis_name, nshards,
                                capacity, owner_apply, data_local)
