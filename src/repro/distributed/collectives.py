"""Cross-pod collectives: wire-level compressed gradient exchange.

``crosspod_int8_mean`` runs INSIDE a shard_map that is *manual over the
pod axis only* (jax.shard_map(..., axis_names={"pod"})): each pod
quantizes its gradients to int8 (per-256-block scales), all-gathers the
int8 payload across pods — so the inter-pod wire carries ~¼ the bytes of
an f32 ring all-reduce — then dequantizes and averages locally. Error
feedback (the per-pod quantization residual) is returned so the caller
can carry it to the next step, preserving convergence (optim/compress.py
contract, tested).

The in-pod reduction stays XLA's own f32 reduce-scatter/all-gather (ICI
inside a pod is cheap); only the scarce pod-to-pod links get the
compressed format — the DESIGN.md §8 split.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..optim import compress


def crosspod_int8_mean(grads, axis: str = "pod"):
    """grads (per-pod, f32 pytree) → (mean across pods, residual pytree).

    Call inside a shard_map manual over ``axis``.
    """
    msg, residual = compress.int8_compress(grads, None)
    n = jax.lax.axis_size(axis)

    def gather_avg(q, s, t):
        q_all = jax.lax.all_gather(q, axis)          # int8 on the wire
        s_all = jax.lax.all_gather(s, axis)          # f32 scales (1/256th)
        x = jnp.sum(q_all.astype(jnp.float32) * s_all[..., None], axis=0)
        x = (x / n).reshape(-1)[:t.size].reshape(t.shape)
        return x

    mean = jax.tree.map(gather_avg, msg.q, msg.scale, grads)
    return mean, residual


def crosspod_f32_mean(grads, axis: str = "pod"):
    """Uncompressed baseline: plain psum/mean (f32 wire)."""
    return jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads), None
