"""Per-arch sharding rules: params, batches, caches → PartitionSpecs.

Policy (DESIGN.md §5): TP over ``model`` on head/ff/expert/vocab dims where
the dim divides evenly; FSDP (ZeRO-3) over ``data`` (+``pod``) on the
opposite dim; batch/tokens over (``pod``, ``data``). Divisibility fallbacks
replicate the offending dim and are reported by ``describe()`` so every
dry-run logs exactly which fallbacks fired.

Leaf rules are keyed by parameter name with a *trailing-dims role pattern*;
any extra leading dims (the layer-stack axes) get None automatically, so
the same table serves flat, (L, …) and (L/2, 2, …) stacked layouts.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

# trailing-dim role patterns per leaf name
_PATTERNS: Dict[str, Tuple[str, ...]] = {
    # embeddings: vocab TP over model; d over model only as the fallback
    # when vocab doesn't divide (never over data — batch owns that axis in
    # the gather; see §Perf iteration 0 in EXPERIMENTS.md)
    "table": ("vocab", "d_embed"),
    "head": ("d_embed", "vocab"),
    # attention
    "wq": ("fsdp", "tp_q"),
    "wk": ("fsdp", "tp_kv"),
    "wv": ("fsdp", "tp_kv"),
    "wo": ("tp_q", "fsdp"),
    # dense mlp
    "up": ("fsdp", "tp_ff"),
    "gate": ("fsdp", "tp_ff"),
    "down": ("tp_ff", "fsdp"),
    # moe (detected by ndim: expert leaves have a leading E dim)
    "router": ("fsdp", "none"),
    # mamba
    "in_proj": ("fsdp", "tp_di"),
    "conv_w": ("none", "tp_conv"),
    "conv_b": ("tp_conv",),
    "x_proj": ("tp_di", "none"),
    "dt_proj": ("none", "tp_di"),
    "dt_bias": ("none",),
    "a_log": ("tp_di", "none"),
    "d_skip": ("tp_di",),
    "out_proj": ("tp_di", "fsdp"),
    # norms
    "ln1": ("none",), "ln2": ("none",), "post_ln1": ("none",),
    "post_ln2": ("none",), "ln": ("none",), "norm": ("none",),
    "final_norm": ("none",),
}

_MOE_PATTERNS: Dict[str, Tuple[str, ...]] = {
    "up": ("ep", "fsdp", "none"),
    "gate": ("ep", "fsdp", "none"),
    "down": ("ep", "none", "fsdp"),
}


class ShardingRules:
    def __init__(self, cfg: ModelConfig, mesh: Mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.tp = mesh.shape.get("model", 1)
        self.fsdp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        self.fsdp = math.prod(mesh.shape[a] for a in self.fsdp_axes) or 1
        self.dp_axes = self.fsdp_axes
        self.fallbacks: List[str] = []

    # ------------------------------------------------------- role → axis
    def _axis_for(self, role: str, dim: int, leaf: str) -> Optional[object]:
        cfg, tp = self.cfg, self.tp
        if role == "none":
            return None
        if role == "fsdp":
            if self.fsdp > 1 and dim % self.fsdp == 0:
                return self.fsdp_axes if len(self.fsdp_axes) > 1 \
                    else self.fsdp_axes[0]
            if self.fsdp > 1:
                self.fallbacks.append(f"{leaf}: dim {dim} !% fsdp {self.fsdp}")
            return None
        if role == "d_embed":
            # only shard d over model when the vocab dim could not be
            if cfg.vocab_padded % tp != 0 and tp > 1 and dim % tp == 0:
                return "model"
            return None
        # TP roles — require clean division by the model axis
        ok = dim % tp == 0
        if role == "tp_q":
            ok = ok and cfg.n_heads % tp == 0
        elif role == "tp_kv":
            ok = ok and cfg.n_kv_heads % tp == 0
        elif role == "vocab":
            ok = ok and cfg.vocab_padded % tp == 0
        if not ok:
            if tp > 1:
                self.fallbacks.append(f"{leaf}: role {role} dim {dim} "
                                      f"replicated (tp={tp})")
            return None
        if role == "ep":
            return "model"
        return "model" if tp > 1 else None

    def _spec_for(self, path: str, shape: Tuple[int, ...]) -> P:
        leaf = path.split("/")[-1]
        in_moe = "/moe/" in path or path.endswith("moe")
        pattern = (_MOE_PATTERNS.get(leaf) if in_moe and leaf in _MOE_PATTERNS
                   else _PATTERNS.get(leaf))
        if pattern is None:
            return P()                                    # replicate unknown
        roles = ("none",) * (len(shape) - len(pattern)) + pattern
        axes = [self._axis_for(r, d, f"{path}{shape}")
                for r, d in zip(roles, shape)]
        # vocab not divisible → try FSDP on the other dim is already in the
        # pattern; nothing else to do.
        return P(*axes)

    # ----------------------------------------------------------- pytrees
    def param_specs(self, params_shape) -> dict:
        """params_shape: pytree of ShapeDtypeStruct (jax.eval_shape)."""
        flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
        specs = {}
        for kp, leaf in flat:
            path = "/".join(str(getattr(k, "key", k)) for k in kp)
            specs[path] = self._spec_for(path, leaf.shape)
        treedef = jax.tree_util.tree_structure(params_shape)
        return jax.tree_util.tree_unflatten(
            treedef, [specs["/".join(str(getattr(k, "key", k)) for k in kp)]
                      for kp, _ in flat])

    def batch_spec(self) -> P:
        """(B, S) token batches: batch over (pod, data)."""
        ax = self.dp_axes if len(self.dp_axes) > 1 else (
            self.dp_axes[0] if self.dp_axes else None)
        return P(ax)

    def token_spec(self, extra_dims: int = 1) -> P:
        ax = self.dp_axes if len(self.dp_axes) > 1 else (
            self.dp_axes[0] if self.dp_axes else None)
        return P(ax, *([None] * extra_dims))

    def activation_spec(self) -> P:
        """(B, S, d) activations."""
        return self.token_spec(extra_dims=2)

    def cache_specs(self, caches_shape, batch: int) -> dict:
        """Decode caches. batch≥fsdp → shard batch dims; batch==1 (long
        context) → shard the page/state dims over data (context
        parallelism, DESIGN.md §5)."""
        dp = self.dp_axes if len(self.dp_axes) > 1 else (
            self.dp_axes[0] if self.dp_axes else None)

        def spec(kp, leaf):
            path = "/".join(str(getattr(k, "key", k)) for k in kp)
            name = path.split("/")[-1].lstrip(".")   # NamedTuple GetAttrKey
            # leaves: k_pages/v_pages (L, P, page, kvh, hd); page_table
            # (L, B, pps); lengths (L, B); ssm conv (L, B, k, C), h (L, B, di, N)
            if name in ("k_pages", "v_pages"):
                if batch == 1:
                    return P(None, dp, None, None, None)
                return P(*([None] * (leaf.ndim - 4)), dp, None, None, None)
            if name in ("page_table",):
                if batch == 1:
                    return P(*([None] * leaf.ndim))
                return P(*([None] * (leaf.ndim - 2)), dp, None)
            if name in ("lengths",):
                if batch == 1:
                    return P(*([None] * leaf.ndim))
                return P(*([None] * (leaf.ndim - 1)), dp)
            if name == "h":                      # (L, B, di, N)
                if batch == 1:
                    return P(*([None] * (leaf.ndim - 2)), "model", None)
                return P(*([None] * (leaf.ndim - 3)), dp, None, None)
            if name == "conv":                   # (L, B, k, channels)
                if batch == 1:
                    return P(*([None] * (leaf.ndim - 1)), "model")
                return P(*([None] * (leaf.ndim - 3)), dp, None, None)
            return P()

        flat, treedef = jax.tree_util.tree_flatten_with_path(caches_shape)
        return jax.tree_util.tree_unflatten(
            treedef, [spec(kp, leaf) for kp, leaf in flat])

    def describe(self) -> str:
        lines = [f"mesh={dict(self.mesh.shape)} tp={self.tp} "
                 f"fsdp={self.fsdp} axes={self.fsdp_axes}"]
        if self.fallbacks:
            lines.append("sharding fallbacks (replicated dims):")
            lines += [f"  - {f}" for f in sorted(set(self.fallbacks))]
        else:
            lines.append("no sharding fallbacks")
        return "\n".join(lines)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
