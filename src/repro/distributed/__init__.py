"""Distribution: per-arch sharding rules and mesh placement helpers."""
from .sharding_rules import ShardingRules, named
__all__ = ["ShardingRules", "named"]
