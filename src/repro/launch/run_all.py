"""Drive the full dry-run matrix: every (arch × shape) × {single, multi}.

Runs each cell in its own subprocess (isolates the 512-device jax runtime
and any per-cell failure), a few at a time. Results land as JSON in
--out-dir; failures are recorded as JSON too so the roofline table shows
them. Resume-safe: existing result files are skipped unless --force.

  PYTHONPATH=src python -m repro.launch.run_all --out-dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor, as_completed


def _cells():
    # import here: keep module import cheap
    from ..configs.registry import ARCH_IDS
    from ..configs.shapes import SHAPES
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                out.append((arch, shape, mesh))
    return out


def run_one(arch: str, shape: str, mesh: str, out_dir: str,
            timeout_s: int, extra: list) -> dict:
    name = f"{arch}__{shape}__{mesh}"
    out_file = os.path.join(out_dir, f"{name}.json")
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--mesh", mesh,
           "--out-dir", out_dir] + extra
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout_s)
        ok = proc.returncode == 0 and os.path.exists(out_file)
        if not ok:
            err = (proc.stderr or "")[-2000:]
            with open(out_file, "w") as f:
                json.dump({"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mesh == "multi" else "16x16",
                           "failed": True, "returncode": proc.returncode,
                           "stderr_tail": err}, f, indent=2)
        return {"cell": name, "ok": ok, "wall_s": round(time.time() - t0, 1)}
    except subprocess.TimeoutExpired:
        with open(out_file, "w") as f:
            json.dump({"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mesh == "multi" else "16x16",
                       "failed": True, "timeout_s": timeout_s}, f, indent=2)
        return {"cell": name, "ok": False, "wall_s": timeout_s,
                "timeout": True}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--timeout", type=int, default=1200)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only-mesh", choices=("single", "multi"))
    ap.add_argument("extra", nargs="*",
                    help="extra dryrun flags, e.g. --skip-flops")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    todo = []
    for arch, shape, mesh in _cells():
        if args.only_mesh and mesh != args.only_mesh:
            continue
        out_file = os.path.join(args.out_dir, f"{arch}__{shape}__{mesh}.json")
        if not args.force and os.path.exists(out_file):
            continue
        todo.append((arch, shape, mesh))
    print(f"{len(todo)} cells to run, {args.jobs} at a time", flush=True)

    results = []
    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        futs = {ex.submit(run_one, a, s, m, args.out_dir, args.timeout,
                          list(args.extra)): (a, s, m)
                for (a, s, m) in todo}
        for fut in as_completed(futs):
            r = fut.result()
            results.append(r)
            print(f"[{len(results)}/{len(todo)}] "
                  f"{'OK ' if r['ok'] else 'FAIL'} {r['cell']} "
                  f"({r['wall_s']}s)", flush=True)
    bad = [r for r in results if not r["ok"]]
    print(f"done: {len(results) - len(bad)} ok, {len(bad)} failed")
    for r in bad:
        print("FAILED:", r["cell"])


if __name__ == "__main__":
    main()
