"""Launchers: production mesh, multi-pod dry-run, roofline, train, serve.
No jax imports at package level (dryrun must set XLA_FLAGS first)."""
