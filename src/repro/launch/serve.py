"""Serving launcher: batched requests through the continuous-batching
server (runtime/serve_loop.py) over Roomy paged KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b --smoke \
      --requests 6 --max-new 12
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import numpy as np
    from ..configs import get_config
    from ..models import init_params
    from ..runtime import Request, Server

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = cfg.replace(kernels="ref")
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    server = Server(cfg, params, max_batch=args.max_batch,
                    max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    outs = server.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in outs.values())
    for rid, toks_out in sorted(outs.items()):
        print(f"req {rid}: {toks_out}")
    print(f"{toks} tokens in {dt:.2f}s = {toks/dt:.1f} tok/s "
          f"(stats: {server.stats})")


if __name__ == "__main__":
    main()
