"""Training launcher.

Host-scale real runs (this container, examples, CI):
  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke \
      --steps 100 --batch 8 --seq 128

Production runs target the same entry point with --mesh single|multi on a
real pod (the dry-run proves those configs compile; see dryrun.py).
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="wsd",
                    choices=("wsd", "cosine", "constant"))
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=("none", "int8", "topk"))
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tp", type=int, default=1,
                    help="host-mesh tensor-parallel size")
    args = ap.parse_args()

    from ..configs import get_config
    from ..runtime import TrainSettings, train
    from .mesh import make_host_mesh

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = cfg.replace(kernels="ref")
    settings = TrainSettings(
        batch=args.batch, seq=args.seq, steps=args.steps, lr=args.lr,
        schedule=args.schedule, num_microbatches=args.microbatches,
        grad_compression=args.grad_compression,
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir, seed=args.seed)
    mesh = make_host_mesh(tp=args.tp) if args.tp > 1 else None
    out = train(cfg, settings, mesh=mesh)
    print(f"final loss {out['losses'][-1]:.4f} "
          f"({len(out['losses'])} steps, {out['restarts']} restarts)")


if __name__ == "__main__":
    main()
