"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: 512
placeholder CPU devices stand in for 2 TPU v5e pods; ``.lower().compile()``
must succeed and yields memory_analysis (fits?), cost_analysis (FLOPs /
bytes) and the partitioned HLO whose collective schedule feeds §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch minicpm-2b \
      --shape train_4k --mesh single --out-dir experiments/dryrun
  ... --list  prints all runnable cells.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import optim
from ..configs import SHAPES, get_config, shape_applicable
from ..configs.inputs import input_specs
from ..configs.registry import ARCH_IDS
from ..distributed.sharding_rules import ShardingRules, named
from ..models import lm
from .mesh import make_production_mesh

# ----------------------------------------------------- HLO collective scan

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64"
                       r"|u64|c64|c128)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo: str, n_devices: int) -> Dict:
    """Per-device operand bytes + wire-bytes estimate per collective kind.

    Shapes in the partitioned module are per-device shards. Conventions:
      all-reduce         operand = result;      wire ≈ 2·B·(g-1)/g
      all-gather         operand = result/g;    wire ≈ (result/g)·(g-1)
      reduce-scatter     operand = result·g;    wire ≈ result·(g-1)
      all-to-all         operand = result;      wire ≈ B·(g-1)/g
      collective-permute operand = result;      wire = B
    """
    stats = {k: {"count": 0, "operand_bytes": 0, "wire_bytes": 0.0}
             for k in ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute")}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result, kind = m.group(1), m.group(2)
        b = _shape_bytes(result)
        gm = _GROUPS_IOTA_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            g = len(gl.group(1).split(",")) if gl else n_devices
        g = max(g, 1)
        if kind == "all-reduce":
            op_b, wire = b, 2.0 * b * (g - 1) / g
        elif kind == "all-gather":
            op_b, wire = b // g, (b // g) * (g - 1)
        elif kind == "reduce-scatter":
            op_b, wire = b * g, b * (g - 1)
        elif kind == "all-to-all":
            op_b, wire = b, b * (g - 1) / g
        else:
            op_b, wire = b, float(b)
        s = stats[kind]
        s["count"] += 1
        s["operand_bytes"] += op_b
        s["wire_bytes"] += wire
    stats["total_operand_bytes"] = sum(
        s["operand_bytes"] for s in stats.values() if isinstance(s, dict))
    stats["total_wire_bytes"] = sum(
        s["wire_bytes"] for s in stats.values() if isinstance(s, dict))
    return stats


# ------------------------------------------------------------- cell runner

def _batch_shardings(tree, mesh, rules: ShardingRules):
    """Shard every batch-dim-leading input leaf over the dp axes (replicate
    when the batch doesn't tile them, e.g. long_500k's batch=1)."""
    dp = rules.dp_axes
    dp_n = rules.fsdp

    def spec(leaf):
        b = leaf.shape[0] if leaf.ndim else 0
        if dp and b % dp_n == 0 and b > 0:
            ax = dp if len(dp) > 1 else dp[0]
            return NamedSharding(mesh, P(ax, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P())
    return jax.tree.map(spec, tree)


def _build_lowered(cfg, shape, mesh, rules: ShardingRules, donate: bool,
                   microbatches: int = 1):
    """Lower the cell's step function (train/prefill/decode)."""
    params_shape = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = rules.param_specs(params_shape)
    p_shard = named(mesh, pspecs)
    specs_in = input_specs(cfg, shape)

    if shape.kind == "train":
        opt_shape = jax.eval_shape(optim.init, params_shape)
        opt_shard = optim.AdamWState(
            step=NamedSharding(mesh, P()),
            m=named(mesh, pspecs), v=named(mesh, pspecs))
        batch_shard = _batch_shardings(specs_in, mesh, rules)

        def step(params, opt_state, batch):
            if microbatches > 1:
                mb = jax.tree.map(
                    lambda x: x.reshape(
                        (microbatches, x.shape[0] // microbatches)
                        + x.shape[1:]), batch)

                def body(acc, mbatch):
                    loss, g = jax.value_and_grad(
                        lambda p: lm.loss_fn(p, mbatch, cfg, mesh))(params)
                    g32 = jax.tree.map(lambda y: y.astype(jnp.float32), g)
                    return (jax.tree.map(jnp.add, acc[0], g32),
                            acc[1] + loss), None

                zero = (jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params),
                        jnp.zeros((), jnp.float32))
                (gsum, lsum), _ = jax.lax.scan(body, zero, mb)
                grads = jax.tree.map(lambda g: g / microbatches, gsum)
                loss = lsum / microbatches
            else:
                loss, grads = jax.value_and_grad(
                    lambda p: lm.loss_fn(p, batch, cfg, mesh))(params)
            params, opt_state, gnorm = optim.update(
                grads, opt_state, params, lr=1e-4)
            return params, opt_state, loss

        jitted = jax.jit(
            step,
            in_shardings=(p_shard, opt_shard, batch_shard),
            out_shardings=(p_shard, opt_shard, NamedSharding(mesh, P())),
            donate_argnums=(0, 1) if donate else ())
        return jitted.lower(params_shape, opt_shape, specs_in)
    if shape.kind == "prefill":
        batch_shard = _batch_shardings(specs_in, mesh, rules)

        def step(params, batch):
            return lm.prefill(params, batch["inputs"], cfg, mesh)

        jitted = jax.jit(step, in_shardings=(p_shard, batch_shard))
        return jitted.lower(params_shape, specs_in)
    # decode
    cache_spec_tree = rules.cache_specs(specs_in["caches"],
                                        shape.global_batch)
    cache_shard = named(mesh, cache_spec_tree)
    in_shard = _batch_shardings(specs_in["inputs"], mesh, rules)

    def step(params, inputs, caches):
        return lm.decode_step(params, inputs, caches, cfg, mesh)

    jitted = jax.jit(
        step, in_shardings=(p_shard, in_shard, cache_shard),
        out_shardings=(NamedSharding(mesh, P()), cache_shard),
        donate_argnums=(2,) if donate else ())
    return jitted.lower(params_shape, specs_in["inputs"],
                        specs_in["caches"])


def _flops_points(cfg) -> tuple:
    """(k1, k2) unrolled depths for per-layer FLOP extrapolation."""
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        k = cfg.shared_attn_every
        return k, 2 * k
    if cfg.local_global_pattern:
        return 2, 4
    return 2, 4


def _counted_flops(cfg, shape, mesh, rules) -> Dict:
    """Unrolled-twin FLOP count with layer extrapolation (scan bodies are
    counted once by XLA — measured; see EXPERIMENTS.md §Roofline method)."""
    k1, k2 = _flops_points(cfg)
    block_k = max(shape.seq_len, 512)
    fs = []
    for k in (k1, k2):
        cfg_k = cfg.replace(n_layers=k, scan_layers=False,
                            attn_block_k=block_k)
        lowered = _build_lowered(cfg_k, shape, mesh, rules, donate=False)
        fs.append(lowered.compile().cost_analysis().get("flops", 0.0))
    per_layer = (fs[1] - fs[0]) / (k2 - k1)
    total = fs[0] + per_layer * (cfg.n_layers - k1)
    # Sequential time-scan correction (ssm/hybrid): the mamba recurrence is
    # a while loop over time in every mode; add its analytic FLOPs.
    corr = 0.0
    if cfg.mamba_version and shape.kind != "decode":
        tokens = shape.global_batch * shape.seq_len
        per_tok_layer = 7.0 * cfg.d_inner * cfg.ssm_state
        mult = 3.0 if shape.kind == "train" else 1.0   # fwd+bwd(≈2×)
        if shape.kind == "train" and cfg.remat:
            mult += 1.0                                # remat refwd
        corr = (tokens * per_tok_layer * cfg.n_layers * mult
                / mesh.devices.size)
    return {"flops_k1": fs[0], "flops_k2": fs[1],
            "flops_per_layer": per_layer,
            "scan_time_correction": corr,
            "flops_per_device_counted": total + corr}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             moe_dispatch: Optional[str] = None,
             embed_dispatch: Optional[str] = None,
             remat: Optional[bool] = None,
             donate: bool = True,
             count_flops: bool = True,
             microbatches: int = 1,
             attn_shard: Optional[str] = None,
             ssm_impl: Optional[str] = None,
             save_hlo: Optional[str] = None) -> Dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch).replace(kernels="ref")
    if moe_dispatch:
        cfg = cfg.replace(moe_dispatch=moe_dispatch)
    if embed_dispatch:
        cfg = cfg.replace(embedding_dispatch=embed_dispatch)
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    if attn_shard is not None:
        cfg = cfg.replace(attn_activation_shard=attn_shard)
    if ssm_impl is not None:
        cfg = cfg.replace(mamba2_use_ssd=(ssm_impl == "ssd"))

    meta = {"arch": arch, "shape": shape_name, "microbatches": microbatches,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "kind": shape.kind, "seq_len": shape.seq_len,
            "global_batch": shape.global_batch,
            "moe_dispatch": cfg.moe_dispatch,
            "embed_dispatch": cfg.embedding_dispatch,
            "remat": cfg.remat,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count()}
    if not shape_applicable(shape, cfg.family):
        return {**meta, "skipped":
                "long_500k needs sub-quadratic attention (full-attention "
                "arch) — see DESIGN.md §6"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rules = ShardingRules(cfg, mesh)

    t0 = time.time()
    lowered = _build_lowered(cfg, shape, mesh, rules, donate,
                             microbatches=microbatches)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_stats(hlo, n_dev)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    flops_info = {}
    if count_flops:
        try:
            flops_info = _counted_flops(cfg, shape, mesh, rules)
        except Exception as e:                       # pragma: no cover
            flops_info = {"flops_count_error": repr(e)}

    result = {
        **meta,
        "devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_per_device": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
        },
        "collectives": coll,
        **flops_info,
        "sharding_notes": rules.describe(),
    }
    return result


def cells(include_multi: bool = True):
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            yield arch, shape_name, False
            if include_multi:
                yield arch, shape_name, True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--moe-dispatch", choices=("roomy", "einsum"))
    ap.add_argument("--embed-dispatch", choices=("gspmd", "roomy"))
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--skip-flops", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--attn-shard", choices=("auto", "none"))
    ap.add_argument("--ssm-impl", choices=("ssd", "seq"))
    ap.add_argument("--tag", default="")
    ap.add_argument("--save-hlo")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for arch, shape, multi in cells():
            print(f"{arch} {shape} {'multi' if multi else 'single'}")
        return

    os.makedirs(args.out_dir, exist_ok=True)
    res = run_cell(args.arch, args.shape, args.mesh == "multi",
                   moe_dispatch=args.moe_dispatch,
                   embed_dispatch=args.embed_dispatch,
                   remat=False if args.no_remat else None,
                   donate=not args.no_donate,
                   count_flops=not args.skip_flops,
                   microbatches=args.microbatches,
                   attn_shard=args.attn_shard,
                   ssm_impl=args.ssm_impl,
                   save_hlo=args.save_hlo)
    tag = f"__{args.tag}" if args.tag else ""
    out = os.path.join(
        args.out_dir,
        f"{args.arch}__{args.shape}__{args.mesh}{tag}.json")
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("sharding_notes",)}, indent=2))
    print(res.get("sharding_notes", ""))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
