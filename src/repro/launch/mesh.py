"""Production mesh construction (DESIGN.md §5).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state. Callers (dryrun.py) set the 512-placeholder-
device XLA flag *before* any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi_pod → 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(tp: int = 1):
    """Whatever this host actually has (CI smoke tests, examples)."""
    n = len(jax.devices())
    dp = n // tp
    return jax.make_mesh(
        (dp, tp), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
