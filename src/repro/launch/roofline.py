"""Roofline analysis over dry-run JSON results (§Roofline of the brief).

Per (arch × shape × mesh) cell:

  compute term    = HLO_FLOPs_per_device / peak_FLOPs          (s)
  memory term     = HLO_bytes_per_device / HBM_bw              (s)
  collective term = wire_bytes_per_device / link_bw            (s)

HLO_FLOPs comes from the unrolled-twin count (dryrun.py: XLA counts scan
bodies once, so the scanned module under-reports; the dry-run lowers an
unrolled twin at two depths and extrapolates — exact for homogeneous
stacks, ±2 % for zamba2's segment remainder).  HLO_bytes comes from the
scanned module's cost_analysis "bytes accessed" (the memory-realistic
form). collective bytes are parsed from the partitioned HLO (operand-bytes
per the brief, wire-bytes per collective algebra — both reported; the term
uses wire bytes as that is what crosses a link).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI
per link.

MODEL_FLOPS = 6·N_active·D for train (2·N_active·D decode/prefill); the
MODEL/HLO ratio exposes remat/replication waste.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link


def analyze(cell: Dict) -> Dict:
    if "skipped" in cell:
        return {**cell, "dominant": "skipped"}
    n_dev = cell["devices"]
    flops = cell.get("flops_per_device_counted",
                     cell.get("flops_per_device", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory = cell["bytes_per_device"] / HBM_BW
    wire = cell["collectives"]["total_wire_bytes"]
    t_coll = wire / LINK_BW

    tokens = cell["global_batch"] * (cell["seq_len"]
                                     if cell["kind"] != "decode"
                                     else 1)
    mult = 6.0 if cell["kind"] == "train" else 2.0
    model_flops = mult * cell["active_params"] * tokens / n_dev
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        **cell,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_device": model_flops,
        "model_over_hlo": model_flops / flops if flops else 0.0,
        "roofline_fraction": (model_flops / PEAK_FLOPS) / bound
        if bound else 0.0,
    }


def _fmt_row(r: Dict) -> str:
    if r.get("dominant") == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"SKIP | — | — |")
    return ("| {arch} | {shape} | {mesh} | {tc:.4f} | {tm:.4f} | {tl:.4f} "
            "| {dom} | {ratio:.2f} | {frac:.2f} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        tc=r["t_compute_s"], tm=r["t_memory_s"], tl=r["t_collective_s"],
        dom=r["dominant"], ratio=r["model_over_hlo"],
        frac=r["roofline_fraction"])


def table(results: List[Dict]) -> str:
    head = ("| arch | shape | mesh | compute (s) | memory (s) | "
            "collective (s) | bound | MODEL/HLO | roofline frac |\n"
            "|---|---|---|---|---|---|---|---|---|")
    rows = [_fmt_row(analyze(r)) for r in results]
    return "\n".join([head] + rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in-dir", default="experiments/dryrun")
    ap.add_argument("--glob", default="*.json")
    ap.add_argument("--out")
    args = ap.parse_args()
    results = []
    for f in sorted(glob.glob(os.path.join(args.in_dir, args.glob))):
        with open(f) as fh:
            results.append(json.load(fh))
    t = table(results)
    print(t)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(t + "\n")


if __name__ == "__main__":
    main()
