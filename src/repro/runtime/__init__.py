"""Runtime: fault-tolerant train loop, batched serving, straggler watchdog."""
from .serve_loop import Request, Server
from .train_loop import FaultInjector, TrainSettings, make_train_step, train
from .watchdog import StepTimer, StragglerWatchdog
__all__ = ["FaultInjector", "Request", "Server", "StepTimer",
           "StragglerWatchdog", "TrainSettings", "make_train_step", "train"]
