"""Batched serving runtime: continuous-batching scheduler over paged KV.

Requests arrive with prompt token lists; the scheduler packs up to
``max_batch`` active sequences, prefills new arrivals (padded to one shared
length per admission wave), then decodes all active sequences in lockstep
— each decode step touches the Roomy paged caches through one batched
gather/scatter (core/paged.py). Finished sequences (EOS or max_new) free
their slots for waiting requests.

This is the degenerate single-host form of a disaggregated server; the
dry-run lowers the same ``decode_step`` against the production mesh.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm
from ..models.config import ModelConfig


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_len: int = 256, greedy: bool = True):
        assert not cfg.frontend_stub, "serving demo uses token-input archs"
        self.cfg, self.params = cfg, params
        self.max_batch, self.max_len = max_batch, max_len
        self.greedy = greedy
        self._decode = jax.jit(
            lambda p, t, c: lm.decode_step(p, t, c, cfg))
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens_out": 0}

    # ----------------------------------------------------------- engine
    def _prefill_one(self, req: Request, caches, slot: int):
        """Prefill via decode steps (exact for every family, incl. SSM)."""
        for tok in req.prompt:
            inputs = {"tokens": jnp.full((self.max_batch, 1), tok, jnp.int32),
                      "positions": jnp.zeros((self.max_batch, 1), jnp.int32)}
            logits, caches = self._masked_step(inputs, caches, slot)
        self.stats["prefills"] += 1
        return logits, caches

    def _masked_step(self, inputs, caches, slot: Optional[int] = None):
        """One decode step; when ``slot`` is given, only that row's caches
        advance — other active rows keep their pre-step state (otherwise a
        mid-flight prefill would pollute their pages)."""
        logits, new_caches = self._decode(self.params, inputs, caches)
        if slot is None:
            return logits, new_caches
        b = self.max_batch

        def merge(kp, new, old):
            name = str(getattr(kp[-1], "key", kp[-1])).lstrip(".")
            if new.ndim == 0:
                return new
            if name in ("k_pages", "v_pages"):
                pps = old.shape[-4] // b          # num_pages = B*pps
                pages = jnp.arange(old.shape[-4])
                m = (pages // pps) == slot        # (num_pages,)
                m = m.reshape((1,) * (old.ndim - 4) + (-1, 1, 1, 1))
                return jnp.where(m, new, old)
            if name == "page_table":
                m = (jnp.arange(b) == slot).reshape(
                    (1,) * (old.ndim - 2) + (-1, 1))
                return jnp.where(m, new, old)
            if name == "lengths":
                m = (jnp.arange(b) == slot).reshape(
                    (1,) * (old.ndim - 1) + (-1,))
                return jnp.where(m, new, old)
            if name == "conv":                     # (L, B, k, C)
                m = (jnp.arange(b) == slot).reshape(
                    (1,) * (old.ndim - 3) + (-1, 1, 1))
                return jnp.where(m, new, old)
            if name == "h":                        # (L, B, di, N)
                m = (jnp.arange(b) == slot).reshape(
                    (1,) * (old.ndim - 3) + (-1, 1, 1))
                return jnp.where(m, new, old)
            return new

        merged = jax.tree_util.tree_map_with_path(merge, new_caches, caches)
        return logits, merged

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        cfg = self.cfg
        waiting = list(requests)
        active: List[Optional[Request]] = [None] * self.max_batch
        caches = lm.make_cache(cfg, self.max_batch, self.max_len)
        last_tok = np.zeros((self.max_batch,), np.int32)

        while waiting or any(a is not None for a in active):
            # ----- admission
            for slot in range(self.max_batch):
                if active[slot] is None and waiting:
                    req = waiting.pop(0)
                    logits, caches = self._prefill_one(req, caches, slot)
                    last = int(np.asarray(logits)[slot, 0].argmax()) \
                        if self.greedy else 0
                    req.out.append(last)
                    last_tok[slot] = last
                    active[slot] = req
            if not any(a is not None for a in active):
                break
            # ----- one lockstep decode wave
            inputs = {"tokens": jnp.asarray(last_tok)[:, None],
                      "positions": jnp.zeros((self.max_batch, 1), jnp.int32)}
            logits, caches = self._masked_step(inputs, caches)
            self.stats["decode_steps"] += 1
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            for slot, req in enumerate(active):
                if req is None:
                    continue
                tok = int(nxt[slot])
                req.out.append(tok)
                last_tok[slot] = tok
                self.stats["tokens_out"] += 1
                if len(req.out) >= req.max_new:
                    req.done = True
                    active[slot] = None
        return {r.rid: r.out for r in requests}
