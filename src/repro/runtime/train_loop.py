"""Fault-tolerant training runtime.

The step function composes, per TrainSettings:
  * microbatched gradient accumulation (scan over microbatches; the
    per-microbatch reduce-scatter overlaps with the next microbatch's
    backward under XLA's latency-hiding scheduler)
  * optional cross-pod int8/top-k gradient compression with error feedback
  * AdamW + schedule (WSD default), global-norm clip
  * donated params/opt-state (in-place update, halves peak param memory)

The host loop adds: deterministic (seed, step)-keyed data, periodic async
checkpoints, crash/restore supervision (env-injectable fault for tests),
and the straggler watchdog. Restore replays the data stream from the
restored step — bitwise-identical continuation (tested).
"""
from __future__ import annotations

import dataclasses
import functools
import os
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import optim
from ..checkpoint import manager as ckpt
from ..data.pipeline import make_batch
from ..models import lm
from ..models.config import ModelConfig
from ..optim import compress as compress_lib
from ..optim import schedule as sched_lib
from .watchdog import StepTimer, StragglerWatchdog


@dataclass(frozen=True)
class TrainSettings:
    batch: int = 8
    seq: int = 128
    steps: int = 50
    lr: float = 3e-4
    warmup_steps: int = 10
    schedule: str = "wsd"            # wsd | cosine | constant
    num_microbatches: int = 1
    grad_compression: str = "none"   # none | int8 | topk
    ckpt_every: int = 0              # 0 = off
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    clip_norm: float = 1.0
    weight_decay: float = 0.1
    log_every: int = 10


def make_lr_fn(s: TrainSettings):
    if s.schedule == "wsd":
        stable = max(1, int(s.steps * 0.7) - s.warmup_steps)
        decay = max(1, s.steps - s.warmup_steps - stable)
        return sched_lib.wsd(s.lr, s.warmup_steps, stable, decay)
    if s.schedule == "cosine":
        return sched_lib.cosine(s.lr, s.warmup_steps, s.steps)
    return sched_lib.constant(s.lr)


def make_train_step(cfg: ModelConfig, s: TrainSettings, mesh=None,
                    axis_pod: Optional[str] = None):
    """Returns step_fn(params, opt_state, residual, batch, step) →
    (params, opt_state, residual, metrics)."""
    lr_fn = make_lr_fn(s)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: lm.loss_fn(p, batch, cfg, mesh))(params)

    def step_fn(params, opt_state, residual, batch, step):
        if s.num_microbatches > 1:
            mb = jax.tree.map(
                lambda x: x.reshape((s.num_microbatches,
                                     x.shape[0] // s.num_microbatches)
                                    + x.shape[1:]), batch)

            def body(acc, mbatch):
                loss, g = grads_of(params, mbatch)
                acc = jax.tree.map(jnp.add, acc,
                                   (jax.tree.map(
                                       lambda x: x.astype(jnp.float32), g),
                                    loss))
                return acc, None

            zero = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                 params), jnp.zeros((), jnp.float32))
            (gsum, lsum), _ = jax.lax.scan(body, zero, mb)
            grads = jax.tree.map(lambda g: g / s.num_microbatches, gsum)
            loss = lsum / s.num_microbatches
        else:
            loss, grads = grads_of(params, batch)

        if s.grad_compression != "none":
            # Cross-pod wire compression with error feedback. Under pjit
            # the psum over 'pod' is implicit in the sharded reduction; the
            # codec round-trip (quantize→dequantize) models the wire format
            # and keeps the residual bookkeeping exact (tests).
            if s.grad_compression == "int8":
                msg, residual = compress_lib.int8_compress(grads, residual)
                grads = compress_lib.int8_decompress(msg, grads)
            else:
                msg, residual = compress_lib.topk_compress(grads, residual)
                grads = compress_lib.topk_decompress(msg, grads)

        lr = lr_fn(step)
        params, opt_state, gnorm = optim.update(
            grads, opt_state, params, lr=lr, clip_norm=s.clip_norm,
            weight_decay=s.weight_decay)
        metrics = {"loss": loss, "gnorm": gnorm, "lr": lr}
        return params, opt_state, residual, metrics

    return step_fn


class FaultInjector:
    """Deterministic crash for supervision tests: raises at a given step
    once, controlled by env REPRO_FAULT_STEP (or constructor arg)."""

    def __init__(self, fault_step: Optional[int] = None):
        env = os.environ.get("REPRO_FAULT_STEP")
        self.fault_step = fault_step if fault_step is not None else (
            int(env) if env else None)
        self.fired = False

    def maybe_fire(self, step: int):
        if self.fault_step is not None and step == self.fault_step \
                and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected fault at step {step}")


def train(cfg: ModelConfig, s: TrainSettings, mesh=None,
          fault: Optional[FaultInjector] = None,
          param_shardings=None, verbose: bool = True) -> Dict:
    """Supervised train loop: run → (crash → restore → replay) → done.

    Returns {"losses": [...], "restarts": int, "final_params": ...}.
    """
    fault = fault or FaultInjector()
    watchdog = StragglerWatchdog()
    step_fn = jax.jit(make_train_step(cfg, s, mesh), donate_argnums=(0, 1, 2))

    def fresh_state():
        params = lm.init_params(cfg, jax.random.PRNGKey(s.seed))
        if param_shardings is not None:
            params = jax.tree.map(jax.device_put, params, param_shardings)
        opt_state = optim.init(params)
        residual = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                 params)
                    if s.grad_compression != "none" else jnp.zeros(()))
        return params, opt_state, residual

    params, opt_state, residual = fresh_state()
    start_step = 0
    ckpt_mgr = (ckpt.AsyncCheckpointer(s.ckpt_dir) if s.ckpt_every else None)
    if s.ckpt_every:
        last = ckpt.latest_step(s.ckpt_dir)
        if last is not None:
            params, opt_state = _restore(s, last, params, opt_state)
            start_step = last

    losses, restarts = [], 0
    step = start_step
    while step < s.steps:
        try:
            batch = make_batch(cfg, s.seed, step, s.batch, s.seq)
            batch = jax.tree.map(jnp.asarray, batch)
            fault.maybe_fire(step)
            with StepTimer() as t:
                params, opt_state, residual, metrics = step_fn(
                    params, opt_state, residual, batch,
                    jnp.asarray(step, jnp.int32))
                loss = float(metrics["loss"])
            verdict = watchdog.observe(step, t.seconds)
            losses.append(loss)
            if verbose and (step % s.log_every == 0 or step == s.steps - 1):
                print(f"step {step:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['gnorm']):8.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"{t.seconds*1e3:7.1f} ms [{verdict}]")
            if ckpt_mgr and step and step % s.ckpt_every == 0:
                ckpt_mgr.save(step, {"params": params, "opt": opt_state})
            step += 1
        except RuntimeError as e:
            if "injected fault" not in str(e):
                raise
            restarts += 1
            if verbose:
                print(f"!! {e} — restoring and replaying")
            last = ckpt.latest_step(s.ckpt_dir) if s.ckpt_every else None
            if last is not None:
                if ckpt_mgr:
                    ckpt_mgr.wait()
                params, opt_state, residual = fresh_state()
                params, opt_state = _restore(s, last, params, opt_state)
                step = last
            else:
                params, opt_state, residual = fresh_state()
                step = 0
    if ckpt_mgr:
        ckpt_mgr.wait()
        ckpt_mgr.close()
    return {"losses": losses, "restarts": restarts, "final_params": params,
            "watchdog_events": watchdog.events}


def _restore(s: TrainSettings, step: int, params, opt_state):
    tree = ckpt.restore(s.ckpt_dir, step,
                        {"params": params, "opt": opt_state})
    return tree["params"], tree["opt"]
