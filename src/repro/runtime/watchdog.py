"""Straggler detection + elastic re-mesh decision logic (DESIGN.md §8).

On a real cluster the watchdog wraps the per-step host loop: a step whose
wall time exceeds ``threshold × EWMA`` marks its slowest participant as a
straggler; repeated offenses trigger the elastic path (checkpoint → shrink
mesh → resume), which on this container is exercised by the checkpoint
elastic-restore tests. The detector itself is pure host-side logic and is
unit-tested directly.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class StragglerWatchdog:
    ewma_alpha: float = 0.2
    threshold: float = 2.5          # step is "slow" above threshold×EWMA
    strikes_to_evict: int = 3
    warmup_steps: int = 5           # compile steps excluded

    _ewma: Optional[float] = None
    _seen: int = 0
    strikes: int = 0
    events: List[str] = field(default_factory=list)

    def observe(self, step: int, seconds: float) -> str:
        """Returns one of: 'warmup' | 'ok' | 'slow' | 'evict'."""
        self._seen += 1
        if self._seen <= self.warmup_steps:
            return "warmup"
        if self._ewma is None:
            self._ewma = seconds
            return "ok"
        slow = seconds > self.threshold * self._ewma
        # Slow steps do not poison the EWMA (classic watchdog rule).
        if not slow:
            self._ewma = (1 - self.ewma_alpha) * self._ewma \
                + self.ewma_alpha * seconds
            self.strikes = max(0, self.strikes - 1)
            return "ok"
        self.strikes += 1
        self.events.append(
            f"step {step}: {seconds:.3f}s > {self.threshold:.1f}×"
            f"{self._ewma:.3f}s (strike {self.strikes})")
        if self.strikes >= self.strikes_to_evict:
            self.strikes = 0
            return "evict"
        return "slow"

    @property
    def ewma(self) -> Optional[float]:
        return self._ewma


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False
