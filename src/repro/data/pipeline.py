"""Data pipeline: deterministic synthetic LM stream + disk-backed dataset.

Determinism contract (fault tolerance depends on it): batch content is a
pure function of (seed, step) — after a restore-from-checkpoint the stream
replays identically from the restored step, no iterator state to persist.

Two sources:
  SyntheticStream   hash-based token synthesis (no storage at all)
  DiskTokenStream   tokens stored in a Roomy Tier-D ChunkStore and
                    streamed chunk-at-a-time — the paper's disks-as-memory
                    applied to the input pipeline (larger-than-RAM corpora)

Both yield {"inputs": {"tokens", "positions"[, "embeds"]}, "labels"} ready
for loss_fn, with a background prefetch thread (depth 2).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from ..core.disk.store import ChunkStore
from ..models.config import ModelConfig


def synth_tokens(seed: int, step: int, batch: int, seq: int,
                 vocab: int) -> np.ndarray:
    """Deterministic (seed, step)-keyed token block — a Markov-ish mix so
    the loss is learnable (next token correlates with current)."""
    rng = np.random.default_rng(np.uint64(seed) * np.uint64(1_000_003)
                                + np.uint64(step))
    base = rng.integers(0, vocab, size=(batch, 1), dtype=np.int64)
    steps = rng.integers(1, 7, size=(batch, seq), dtype=np.int64)
    toks = (base + np.cumsum(steps, axis=1)) % vocab
    return toks.astype(np.int32)


def _positions(cfg: ModelConfig, batch: int, seq: int) -> np.ndarray:
    pos = np.tile(np.arange(seq, dtype=np.int32)[None, :], (batch, 1))
    if cfg.mrope:
        return np.tile(pos[:, :, None], (1, 1, 3))
    return pos


def make_batch(cfg: ModelConfig, seed: int, step: int, batch: int,
               seq: int) -> Dict:
    toks = synth_tokens(seed, step, batch, seq + 1, cfg.vocab_size)
    inputs = {"positions": _positions(cfg, batch, seq)}
    if cfg.frontend_stub:
        # Stub frontend: embed ids with a fixed random codebook (the
        # "precomputed frame/patch embeddings" of the assignment).
        rng = np.random.default_rng(1234)
        book = rng.standard_normal((cfg.vocab_size, cfg.d_model)).astype(
            np.float32) * 0.02
        inputs["embeds"] = book[toks[:, :seq]]
    else:
        inputs["tokens"] = toks[:, :seq]
    return {"inputs": inputs, "labels": toks[:, 1:seq + 1]}


class SyntheticStream:
    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0, start_step: int = 0, prefetch: int = 2):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self.step
        while not self._stop.is_set():
            b = make_batch(self.cfg, self.seed, step, self.batch, self.seq)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, b = self._q.get()
        self.step = step + 1
        return b

    def __iter__(self) -> Iterator[Dict]:
        return self

    def close(self):
        self._stop.set()


class DiskTokenStream:
    """Roomy Tier-D backed corpus: out-of-core token storage, streamed.

    Build once with ``write_corpus``; batches are then read by chunk index —
    still a pure function of step, so replay-after-restore holds.
    """

    def __init__(self, store_dir: str, cfg: ModelConfig, batch: int,
                 seq: int, start_step: int = 0):
        self.store = ChunkStore(store_dir, width=1, dtype="uint32",
                                chunk_rows=(seq + 1) * batch)
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.step = start_step
        assert self.store.n_chunks > 0, "corpus empty — run write_corpus"

    @staticmethod
    def write_corpus(store_dir: str, cfg: ModelConfig, batch: int, seq: int,
                     n_steps: int, seed: int = 0) -> None:
        store = ChunkStore(store_dir, width=1, dtype="uint32",
                           chunk_rows=(seq + 1) * batch, fresh=True)
        for step in range(n_steps):
            toks = synth_tokens(seed, step, batch, seq + 1, cfg.vocab_size)
            store.append(toks.reshape(-1, 1).astype(np.uint32))
        store.flush()

    def __next__(self) -> Dict:
        chunk_i = self.step % self.store.n_chunks
        rows = np.asarray(
            np.load(self.store._chunk_path(chunk_i), mmap_mode="r"))
        toks = rows.reshape(self.batch, self.seq + 1).astype(np.int32)
        inputs = {"positions": _positions(self.cfg, self.batch, self.seq)}
        inputs["tokens"] = toks[:, :self.seq]
        self.step += 1
        return {"inputs": inputs, "labels": toks[:, 1:]}

    def __iter__(self):
        return self
