"""Data pipeline: deterministic synthetic + Roomy disk-backed streams."""
from .pipeline import DiskTokenStream, SyntheticStream, make_batch, synth_tokens
__all__ = ["DiskTokenStream", "SyntheticStream", "make_batch", "synth_tokens"]
