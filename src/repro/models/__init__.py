"""Composable LM stack covering all ten assigned architectures."""
from .config import ModelConfig
from .lm import (decode_step, forward_hidden, init_params, loss_fn,
                 make_cache, prefill)

__all__ = ["ModelConfig", "decode_step", "forward_hidden", "init_params",
           "loss_fn", "make_cache", "prefill"]
