"""Model configuration — one dataclass covers all ten assigned families.

Field semantics are documented inline; per-arch instances live in
``repro/configs/<arch>.py``. The config is a frozen dataclass so it can be
a static argument to jit.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                 # query heads; 0 for attention-free archs
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_dispatch: str = "roomy"      # roomy (paper) | einsum (baseline)
    capacity_factor: float = 1.25

    # --- SSM (mamba) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    mamba_version: int = 0           # 0=none, 1=mamba1, 2=mamba2
    mamba2_head_dim: int = 64
    mamba2_use_ssd: bool = True      # chunked matmul (SSD) form — §Perf C
    ssd_chunk: int = 128

    # --- attention variants ---
    local_window: int = 0            # sliding-window size (gemma2 local layers)
    local_global_pattern: bool = False
    logit_softcap: float = 0.0       # final-logit tanh cap (gemma2: 30)
    attn_softcap: float = 0.0        # attention-logit tanh cap (gemma2: 50)
    post_norm: bool = False          # gemma2 post-block RMSNorms
    rope_theta: float = 10_000.0
    mrope: bool = False              # qwen2-vl M-RoPE (3 position streams)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)

    # --- MLP ---
    mlp_act: str = "silu"            # silu | gelu | relu2
    mlp_gated: bool = True

    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0       # shared attn+mlp block every k layers

    # --- embeddings / head ---
    tie_embeddings: bool = True
    frontend_stub: bool = False      # audio/vlm: inputs are embeddings
    embedding_dispatch: str = "gspmd"  # gspmd | roomy
    scale_embeddings: bool = False   # gemma2: multiply embeds by sqrt(d)

    # --- distribution ---
    attn_activation_shard: str = "auto"   # auto | none — when q-heads don't
    # divide the model axis, reshard attention activations (batch or seq
    # over 'model') instead of replicating the compute (§Perf iteration 1)

    # --- numerics / compilation ---
    rms_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    kernels: str = "auto"            # auto | pallas | interpret | ref
    attn_block_k: int = 512          # ref-attention kv chunk

    # ----------------------------------------------------------- derived
    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, math.ceil(self.d_model / 16))

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim always
        TP-shards over the model axis (pad-to-shard; padded logit rows are
        masked to -inf in lm_head). 122753→122880, 49155→49408, etc."""
        return -(-self.vocab_size // 256) * 256

    @property
    def experts_padded(self) -> int:
        """Experts padded to a multiple of 16 so the expert axis shards over
        the model mesh axis (dead experts are router-masked; their cost is
        visible in the roofline MODEL/HLO FLOP ratio — see EXPERIMENTS.md)."""
        if not self.is_moe:
            return 0
        return -(-self.n_experts // 16) * 16

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        n = 0
        # embeddings
        n += v * d if (self.tie_embeddings or self.frontend_stub) else 2 * v * d
        per_layer = 0
        if self.family in ("ssm",):
            per_layer += self._mamba_params(1)
        elif self.family == "hybrid":
            per_layer += self._mamba_params(2)
        else:
            per_layer += self._attn_params() + self._mlp_params()
        per_layer += 2 * d                       # norms
        n += self.n_layers * per_layer
        if self.family == "hybrid" and self.shared_attn_every:
            n += self._attn_params() + self._mlp_params() + 2 * self.d_model
        n += d                                   # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only, per layer)."""
        if not self.is_moe:
            return self.param_count()
        expert_p = self._expert_params()
        total = self.param_count()
        return total - self.n_layers * (self.n_experts - self.top_k) * expert_p

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        return (self.n_heads * hd * d * 2            # q, o
                + self.n_kv_heads * hd * d * 2)      # k, v

    def _expert_params(self) -> int:
        d, ff = self.d_model, self.d_ff
        return d * ff * (3 if self.mlp_gated else 2)

    def _mlp_params(self) -> int:
        if self.is_moe:
            return self.n_experts * self._expert_params() + self.d_model * self.n_experts
        return self._expert_params()

    def _mamba_params(self, version: int) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        if version == 1:
            return (d * 2 * di                       # in_proj
                    + di * self.ssm_conv             # conv
                    + di * (self.dt_rank + 2 * n)    # x_proj
                    + self.dt_rank * di + di         # dt_proj
                    + di * n + di                    # A, D
                    + di * d)                        # out_proj
        heads = di // self.mamba2_head_dim
        return (d * (2 * di + 2 * n + heads)         # in_proj (x,z,B,C,dt)
                + (di + 2 * n) * self.ssm_conv
                + heads * 2                          # A, D per head
                + di                                 # norm
                + di * d)

    def train_flops_per_token(self) -> float:
        """MODEL_FLOPS/token = 6·N_active (fwd+bwd) — §Roofline convention."""
        return 6.0 * self.active_param_count()

    def decode_flops_per_token(self) -> float:
        return 2.0 * self.active_param_count()

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
