"""Residual blocks assembled from the layer zoo, one init/apply per family.

Scan structuring (compile-time control, DESIGN.md §5):
  dense/moe/audio/vlm  uniform blocks, params stacked (L, …)
  gemma2               (local, global) pairs stacked (L/2, 2, …) — avoids
                       per-layer control flow entirely
  ssm                  uniform mamba1 blocks (L, …)
  hybrid (zamba2)      mamba2 runs between shared-attn applications;
                       segments are sliced statically so only real
                       attention layers carry KV caches
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import paged
from .attention import attention, decode_attention, init_attention
from .config import ModelConfig
from .layers import cdtype, init_mlp, mlp, rms_norm
from .moe import init_moe, moe
from .ssm import (SSMState, init_mamba1, init_mamba2, init_ssm_state, mamba1,
                  mamba1_decode, mamba1_prefill, mamba2, mamba2_decode,
                  mamba2_prefill)


# ------------------------------------------------- transformer block

def init_transformer_block(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    p = {"ln1": jnp.zeros((d,), jnp.float32),
         "ln2": jnp.zeros((d,), jnp.float32),
         "attn": init_attention(ks[0], cfg)}
    p["moe" if cfg.is_moe else "mlp"] = (
        init_moe(ks[1], cfg) if cfg.is_moe else init_mlp(ks[1], cfg))
    if cfg.post_norm:
        p["post_ln1"] = jnp.zeros((d,), jnp.float32)
        p["post_ln2"] = jnp.zeros((d,), jnp.float32)
    return p


def transformer_block(p: dict, x: jax.Array, positions: jax.Array,
                      cfg: ModelConfig, *, window: Optional[int] = None,
                      mesh=None, return_kv: bool = False):
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    if return_kv:
        h, kv = attention(p["attn"], h, positions, cfg, window=window,
                          return_kv=True, mesh=mesh)
    else:
        h = attention(p["attn"], h, positions, cfg, window=window, mesh=mesh)
    if cfg.post_norm:
        h = rms_norm(h, p["post_ln1"], cfg.rms_eps)
    x = x + h
    h = rms_norm(x, p["ln2"], cfg.rms_eps)
    h = moe(p["moe"], h, cfg, mesh) if cfg.is_moe else mlp(p["mlp"], h, cfg)
    if cfg.post_norm:
        h = rms_norm(h, p["post_ln2"], cfg.rms_eps)
    x = x + h
    if return_kv:
        return x, kv
    return x


def transformer_block_decode(p: dict, x: jax.Array, cache: paged.PagedKV,
                             cfg: ModelConfig, *,
                             window: Optional[int] = None, mesh=None
                             ) -> Tuple[jax.Array, paged.PagedKV]:
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    h, cache = decode_attention(p["attn"], h, cache, cfg, window=window,
                                mesh=mesh)
    if cfg.post_norm:
        h = rms_norm(h, p["post_ln1"], cfg.rms_eps)
    x = x + h
    h = rms_norm(x, p["ln2"], cfg.rms_eps)
    h = moe(p["moe"], h, cfg, mesh) if cfg.is_moe else mlp(p["mlp"], h, cfg)
    if cfg.post_norm:
        h = rms_norm(h, p["post_ln2"], cfg.rms_eps)
    return x + h, cache


# ------------------------------------------------------ mamba blocks

def init_mamba_block(key, cfg: ModelConfig, version: int) -> dict:
    init = init_mamba1 if version == 1 else init_mamba2
    return {"ln": jnp.zeros((cfg.d_model,), jnp.float32),
            "mamba": init(key, cfg)}


def mamba_block(p: dict, x: jax.Array, cfg: ModelConfig, version: int):
    h = rms_norm(x, p["ln"], cfg.rms_eps)
    h = mamba1(p["mamba"], h, cfg) if version == 1 else mamba2(p["mamba"], h, cfg)
    return x + h


def mamba_block_decode(p: dict, x: jax.Array, state: SSMState,
                       cfg: ModelConfig, version: int):
    h = rms_norm(x, p["ln"], cfg.rms_eps)
    fn = mamba1_decode if version == 1 else mamba2_decode
    h, state = fn(p["mamba"], h, state, cfg)
    return x + h, state


def mamba_block_prefill(p: dict, x: jax.Array, cfg: ModelConfig,
                        version: int):
    h = rms_norm(x, p["ln"], cfg.rms_eps)
    fn = mamba1_prefill if version == 1 else mamba2_prefill
    h, state = fn(p["mamba"], h, cfg)
    return x + h, state
