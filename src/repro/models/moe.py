"""Mixture-of-Experts with two dispatch engines (DESIGN.md §3.1).

``einsum``  — the GSPMD/Switch-style baseline: one-hot dispatch/combine
              matmuls, capacity-bucketed per batch row. Simple, but the
              one-hot matmuls burn O(T·E·C·d) extra FLOPs and dead padded
              experts still occupy capacity.

``roomy``   — the paper's engine: every (token, expert-choice) is a delayed
              access op; sync bins ops by owner shard, runs ONE all_to_all
              each way, and second-level-bins per local expert on the owner
              (Roomy bucketing twice). No one-hot matmuls, no dead-expert
              compute; overflow drops are counted exactly like Roomy bucket
              overflow.

Expert axis is padded to a multiple of 16 (``cfg.experts_padded``) so it
shards over the model mesh axis; the router masks padded experts to -inf.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import delayed as roomy_delayed
from .config import ModelConfig
from .layers import cdtype, dense_init, _act


def init_moe(key, cfg: ModelConfig) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.experts_padded
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e)),
        "up": dense_init(ks[1], (e, d, ff), in_axis=1),
        "down": dense_init(ks[2], (e, ff, d), in_axis=1),
    }
    if cfg.mlp_gated:
        p["gate"] = dense_init(ks[3], (e, d, ff), in_axis=1)
    return p


def _route(p: dict, x: jax.Array, cfg: ModelConfig):
    """x (..., d) → (weights (..., k), ids (..., k)). f32 router math."""
    e = cfg.experts_padded
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    dead = jnp.arange(e) >= cfg.n_experts
    logits = jnp.where(dead, -jnp.inf, logits)
    top, ids = jax.lax.top_k(logits, cfg.top_k)
    weights = jax.nn.softmax(top, axis=-1)
    return weights, ids.astype(jnp.int32)


def _expert_ffn(p: dict, xin: jax.Array, cfg: ModelConfig) -> jax.Array:
    """xin (E, C, d) → (E, C, d), batched over the expert axis."""
    dt = xin.dtype
    act = _act(cfg.mlp_act)
    h = jnp.einsum("ecd,edf->ecf", xin, p["up"].astype(dt))
    if cfg.mlp_gated:
        g = jnp.einsum("ecd,edf->ecf", xin, p["gate"].astype(dt))
        h = act(g) * h
    else:
        h = act(h)
    return jnp.einsum("ecf,efd->ecd", h, p["down"].astype(dt))


# --------------------------------------------------------------- einsum

def moe_einsum(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Baseline dispatch. x: (B, S, d); capacity group = batch row."""
    b, s, d = x.shape
    e, k = cfg.experts_padded, cfg.top_k
    cap = max(1, int(math.ceil(s * k / e * cfg.capacity_factor)))
    dt = x.dtype

    w, ids = _route(p, x, cfg)                    # (b, s, k)
    flat_ids = ids.reshape(b, s * k)
    oh = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)        # (b, sk, e)
    slot = jnp.cumsum(oh, axis=1) * oh                        # 1-indexed
    slot = jnp.sum(slot, axis=-1) - 1                         # (b, sk)
    keep = (slot >= 0) & (slot < cap)
    slot = jnp.where(keep, slot, cap)                         # park dropped
    disp = (jax.nn.one_hot(flat_ids, e, dtype=dt)[..., :, None]
            * jax.nn.one_hot(slot, cap, dtype=dt)[..., None, :]
            * keep[..., None, None].astype(dt))               # (b, sk, e, c)
    disp = disp.reshape(b, s, k, e, cap)
    disp_x = jnp.sum(disp, axis=2)                            # (b, s, e, c)
    comb = jnp.sum(disp * w[..., None, None].astype(dt), axis=2)

    # expert axis leading for the batched FFN:
    xin = jnp.einsum("bsd,bsec->ebcd", x, disp_x).reshape(e, b * cap, d)
    hout = _expert_ffn(p, xin, cfg).reshape(e, b, cap, d)
    out = jnp.einsum("ebcd,bsec->bsd", hout, comb)
    return out


# ---------------------------------------------------------------- roomy

def moe_roomy(p: dict, x: jax.Array, cfg: ModelConfig, mesh) -> jax.Array:
    """Paper-technique dispatch: bucket exchange over the model axis."""
    b, s, d = x.shape
    e, k = cfg.experts_padded, cfg.top_k
    s_model = mesh.shape["model"]
    e_loc = e // s_model
    n_dev = 1
    for a in mesh.axis_names:
        n_dev *= mesh.shape[a]
    t_loc = max(1, (b * s) // n_dev)              # tokens per device
    m = t_loc * k                                  # delayed ops per device
    cap1 = max(8, int(math.ceil(m / s_model * cfg.capacity_factor)))
    cap2 = max(8, int(math.ceil(s_model * cap1 / e_loc * cfg.capacity_factor)))

    w_all, ids_all = _route(p, x, cfg)            # (b, s, k) — replicated math

    def local(x_loc, w_loc, ids_loc, up, down, *gate):
        # x_loc (t, d); w/ids (t, k)
        t = x_loc.shape[0]
        xk = jnp.repeat(x_loc, k, axis=0)                       # (t*k, d)
        ek = ids_loc.reshape(-1)                                # (t*k,)
        dest = (ek // e_loc).astype(jnp.int32)
        e_local = (ek % e_loc).astype(x_loc.dtype)
        payload = jnp.concatenate([xk, e_local[:, None]], axis=1)
        valid = jnp.ones((t * k,), bool)

        def owner_fn(recv, recv_valid):
            # recv (S, C1, d+1) — second-level bin by local expert id
            flat = recv.reshape(-1, d + 1)
            fv = recv_valid.reshape(-1)
            e_id = flat[:, d].astype(jnp.int32)
            binned = roomy_delayed.bin_by_dest(e_id, flat[:, :d], fv,
                                               e_loc, cap2)
            pp = {"up": up, "down": down}
            if gate:
                pp["gate"] = gate[0]
            y = _expert_ffn(pp, binned.payload, cfg)            # (E_loc, C2, d)
            y = jnp.where(binned.valid[..., None], y, 0.0)
            back = roomy_delayed.unbin(y, binned.src_idx, flat.shape[0])
            return back.reshape(recv.shape[0], recv.shape[1], d)

        y, ok, _ = roomy_delayed.bucket_sync_access(
            dest, payload, valid, "model", s_model, cap1, owner_fn)
        y = jnp.where(ok[:, None], y, 0.0).reshape(t, k, d)
        return jnp.sum(y * w_loc[..., None].astype(y.dtype), axis=1)

    token_axes = tuple(a for a in mesh.axis_names)
    in_specs = [P(token_axes, None), P(token_axes, None), P(token_axes, None),
                P("model", None, None), P("model", None, None)]
    args = [x.reshape(b * s, d), w_all.reshape(b * s, k),
            ids_all.reshape(b * s, k), p["up"].astype(x.dtype),
            p["down"].astype(x.dtype)]
    if cfg.mlp_gated:
        in_specs.append(P("model", None, None))
        args.append(p["gate"].astype(x.dtype))
    fn = jax.shard_map(local, mesh=mesh, in_specs=tuple(in_specs),
                       out_specs=P(token_axes, None))
    return fn(*args).reshape(b, s, d)


def moe(p: dict, x: jax.Array, cfg: ModelConfig, mesh=None) -> jax.Array:
    if cfg.moe_dispatch == "roomy" and mesh is not None \
            and "model" in mesh.axis_names:
        n_dev = 1
        for a in mesh.axis_names:
            n_dev *= mesh.shape[a]
        # Roomy dispatch needs tokens to tile the device grid; tiny decode
        # batches fall back to the einsum path (capacity 1-2 there anyway).
        if (x.shape[0] * x.shape[1]) % n_dev == 0:
            return moe_roomy(p, x, cfg, mesh)
    return moe_einsum(p, x, cfg)
