"""State-space blocks: mamba1 (falcon-mamba) and mamba2 (zamba2 hybrid).

Both reduce to the same selective-scan kernel (kernels/mamba_scan.py) —
mamba2's scalar-per-head decay is broadcast into the (d_inner, N) form at
trace time (zero-cost under XLA fusion; see the kernel docstring).

Decode keeps O(1) state per layer: a (conv-1)-token convolution tail and
the (d_inner, N) SSM state — this is why the SSM archs run the long_500k
cell (DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .config import ModelConfig
from .layers import cdtype, dense_init, rms_norm


class SSMState(NamedTuple):
    conv: jax.Array    # (B, conv-1, conv_channels)
    h: jax.Array       # (B, d_inner, N) f32


# ------------------------------------------------------------- mamba1

def init_mamba1(key, cfg: ModelConfig) -> dict:
    d, di, n, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    a_init = jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1)))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di)),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * n)),
        "dt_proj": dense_init(ks[3], (dtr, di)),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),   # softplus ≈ 0.01
        "a_log": a_init,
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. x: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + b.astype(x.dtype)


def mamba1(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt_c = cdtype(cfg)
    b, s, _ = x.shape
    di, n, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = x @ p["in_proj"].astype(dt_c)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"]))
    proj = x_c @ p["x_proj"].astype(dt_c)
    dt_in, b_mat, c_mat = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"].astype(dt_c)
                         + p["dt_bias"].astype(dt_c))
    a = -jnp.exp(p["a_log"])
    y = kops.mamba_scan(x_c, dt, a, b_mat, c_mat, p["d_skip"],
                        impl=cfg.kernels)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(dt_c)


def mamba1_decode(p: dict, x: jax.Array, state: SSMState,
                  cfg: ModelConfig) -> Tuple[jax.Array, SSMState]:
    """x: (B, 1, d) → (out (B, 1, d), state)."""
    dt_c = cdtype(cfg)
    b = x.shape[0]
    di, n, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = x[:, 0] @ p["in_proj"].astype(dt_c)
    x_in, z = jnp.split(xz, 2, axis=-1)                  # (B, di)
    window = jnp.concatenate([state.conv, x_in[:, None]], axis=1)
    conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                      p["conv_w"]) + p["conv_b"]
    x_c = jax.nn.silu(conv).astype(dt_c)
    proj = x_c @ p["x_proj"].astype(dt_c)
    dt_in, b_mat, c_mat = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"].astype(dt_c)
                         + p["dt_bias"].astype(dt_c))    # (B, di)
    a = -jnp.exp(p["a_log"])                             # (di, n)
    dtf, xf = dt.astype(jnp.float32), x_c.astype(jnp.float32)
    da = jnp.exp(dtf[..., None] * a[None])               # (B, di, n)
    h = state.h * da + (dtf * xf)[..., None] * b_mat.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bin,bn->bi", h, c_mat.astype(jnp.float32)) \
        + xf * p["d_skip"][None]
    y = y.astype(dt_c) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt_c)
    return out[:, None], SSMState(conv=window[:, 1:], h=h)


# ------------------------------------------------------------- mamba2

def init_mamba2(key, cfg: ModelConfig) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    hd = cfg.mamba2_head_dim
    heads = di // hd
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + heads)),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch),
                                    jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "dt_bias": jnp.full((heads,), -4.6, jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, heads)),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "norm": jnp.zeros((di,), jnp.float32),
        "out_proj": dense_init(ks[2], (di, d)),
    }


def _mamba2_split(p, xz, cfg):
    di, n = cfg.d_inner, cfg.ssm_state
    heads = di // cfg.mamba2_head_dim
    return jnp.split(xz, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)


def mamba2(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt_c = cdtype(cfg)
    b, s, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    hd = cfg.mamba2_head_dim
    heads = di // hd
    xz = x @ p["in_proj"].astype(dt_c)
    z, x_in, b_mat, c_mat, dt_h = _mamba2_split(p, xz, cfg)
    conv_in = jnp.concatenate([x_in, b_mat, c_mat], axis=-1)
    conv = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    x_c, b_mat, c_mat = jnp.split(conv, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_h + p["dt_bias"].astype(dt_c))   # (B, S, H)
    if cfg.mamba2_use_ssd:
        # chunked SSD (matmul) form — the §Perf-C optimization
        from ..kernels.ref import mamba2_ssd
        a = -jnp.exp(p["a_log"])
        y4, _ = mamba2_ssd(x_c.reshape(b, s, heads, hd), dt, a,
                           b_mat, c_mat, p["d_skip"], chunk=cfg.ssd_chunk)
        y = y4.reshape(b, s, di).astype(dt_c)
    else:
        # broadcast head-scalars to the mamba1 kernel form
        dt_full = jnp.repeat(dt, hd, axis=-1)                # (B, S, di)
        a_full = -jnp.exp(jnp.repeat(p["a_log"], hd))[:, None]
        a_full = jnp.broadcast_to(a_full, (di, n))
        d_full = jnp.repeat(p["d_skip"], hd)
        y = kops.mamba_scan(x_c, dt_full, a_full, b_mat, c_mat, d_full,
                            impl=cfg.kernels)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.rms_eps)
    return y @ p["out_proj"].astype(dt_c)


def mamba2_decode(p: dict, x: jax.Array, state: SSMState,
                  cfg: ModelConfig) -> Tuple[jax.Array, SSMState]:
    dt_c = cdtype(cfg)
    di, n = cfg.d_inner, cfg.ssm_state
    hd = cfg.mamba2_head_dim
    xz = x[:, 0] @ p["in_proj"].astype(dt_c)
    z, x_in, b_mat, c_mat, dt_h = _mamba2_split(p, xz, cfg)
    conv_in = jnp.concatenate([x_in, b_mat, c_mat], axis=-1)  # (B, conv_ch)
    window = jnp.concatenate([state.conv, conv_in[:, None]], axis=1)
    conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                      p["conv_w"]) + p["conv_b"]
    conv = jax.nn.silu(conv).astype(dt_c)
    x_c, b_mat, c_mat = jnp.split(conv, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_h + p["dt_bias"].astype(dt_c))    # (B, H)
    dt_full = jnp.repeat(dt, hd, axis=-1).astype(jnp.float32)
    a_full = jnp.broadcast_to(
        -jnp.exp(jnp.repeat(p["a_log"], hd))[:, None], (di, n))
    da = jnp.exp(dt_full[..., None] * a_full[None])
    xf = x_c.astype(jnp.float32)
    h = state.h * da + (dt_full * xf)[..., None] \
        * b_mat.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bin,bn->bi", h, c_mat.astype(jnp.float32)) \
        + xf * jnp.repeat(p["d_skip"], hd)[None]
    y = rms_norm(y.astype(dt_c) * jax.nn.silu(z), p["norm"], cfg.rms_eps)
    out = y @ p["out_proj"].astype(dt_c)
    return out[:, None], SSMState(conv=window[:, 1:], h=h)


def mamba1_prefill(p: dict, x: jax.Array, cfg: ModelConfig
                   ) -> Tuple[jax.Array, SSMState]:
    """Full-sequence forward that also returns the decode state (the
    prefill path; sequential-scan ref form — see kernels/ref.py)."""
    from ..kernels.ref import mamba_scan_seq_stateful
    dt_c = cdtype(cfg)
    di, n, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    k = cfg.ssm_conv
    xz = x @ p["in_proj"].astype(dt_c)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"]))
    proj = x_c @ p["x_proj"].astype(dt_c)
    dt_in, b_mat, c_mat = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"].astype(dt_c)
                         + p["dt_bias"].astype(dt_c))
    a = -jnp.exp(p["a_log"])
    y, h_last = mamba_scan_seq_stateful(x_c, dt, a, b_mat, c_mat,
                                        p["d_skip"])
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt_c)
    conv_tail = _conv_tail(x_in, k)
    return out, SSMState(conv=conv_tail, h=h_last)


def mamba2_prefill(p: dict, x: jax.Array, cfg: ModelConfig
                   ) -> Tuple[jax.Array, SSMState]:
    from ..kernels.ref import mamba_scan_seq_stateful
    dt_c = cdtype(cfg)
    di, n = cfg.d_inner, cfg.ssm_state
    hd = cfg.mamba2_head_dim
    k = cfg.ssm_conv
    xz = x @ p["in_proj"].astype(dt_c)
    z, x_in, b_mat, c_mat, dt_h = _mamba2_split(p, xz, cfg)
    conv_in = jnp.concatenate([x_in, b_mat, c_mat], axis=-1)
    conv = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    x_c, b_mat, c_mat = jnp.split(conv, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_h + p["dt_bias"].astype(dt_c))
    b_sz, s = x.shape[0], x.shape[1]
    heads = di // hd
    if cfg.mamba2_use_ssd:
        from ..kernels.ref import mamba2_ssd
        a = -jnp.exp(p["a_log"])
        y4, h4 = mamba2_ssd(x_c.reshape(b_sz, s, heads, hd), dt, a,
                            b_mat, c_mat, p["d_skip"], chunk=cfg.ssd_chunk)
        y = y4.reshape(b_sz, s, di).astype(dt_c)
        h_last = h4.reshape(b_sz, di, n)
    else:
        dt_full = jnp.repeat(dt, hd, axis=-1)
        a_full = jnp.broadcast_to(
            -jnp.exp(jnp.repeat(p["a_log"], hd))[:, None], (di, n))
        d_full = jnp.repeat(p["d_skip"], hd)
        y, h_last = mamba_scan_seq_stateful(x_c, dt_full, a_full, b_mat,
                                            c_mat, d_full)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.rms_eps)
    out = y @ p["out_proj"].astype(dt_c)
    return out, SSMState(conv=_conv_tail(conv_in, k), h=h_last)


def _conv_tail(x_in: jax.Array, k: int) -> jax.Array:
    """Last k-1 conv inputs (zero-padded on the left for short seqs)."""
    b, s, c = x_in.shape
    if s >= k - 1:
        return x_in[:, s - (k - 1):]
    pad = jnp.zeros((b, (k - 1) - s, c), x_in.dtype)
    return jnp.concatenate([pad, x_in], axis=1)


def init_ssm_state(cfg: ModelConfig, batch: int, version: int) -> SSMState:
    di, n = cfg.d_inner, cfg.ssm_state
    conv_ch = di if version == 1 else di + 2 * n
    return SSMState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), cdtype(cfg)),
        h=jnp.zeros((batch, di, n), jnp.float32),
    )
