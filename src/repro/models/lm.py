"""Model assembly: init, forward, loss, prefill, decode — all ten archs.

Entry points (all pure; mesh optional — None on single-device CI):

  init_params(cfg, key)                         → params pytree
  forward_hidden(params, inputs, cfg, mesh)     → (B, S, d)
  loss_fn(params, batch, cfg, mesh)             → scalar CE loss
  prefill(params, inputs, cfg, mesh)            → (logits_last, caches)
  decode_step(params, inputs, caches, cfg, mesh)→ (logits, caches)
  make_cache(cfg, batch, max_len)               → empty caches pytree

``inputs``: {"tokens": (B,S) i32} or {"embeds": (B,S,d)} for frontend-stub
archs, plus "positions" ((B,S) or (B,S,3) for M-RoPE).
Caches: PagedKV pytrees stacked over layers (per-family structure, see
blocks.py docstring) and SSMState stacks for mamba archs.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import paged
from .blocks import (init_mamba_block, init_transformer_block, mamba_block,
                     mamba_block_decode, transformer_block,
                     transformer_block_decode)
from .config import ModelConfig
from .layers import cdtype, embed_tokens, init_embedding, lm_head, rms_norm
from .ssm import init_ssm_state

PAGE_SIZE = 128


# ---------------------------------------------------------------- init

def _hybrid_segments(cfg: ModelConfig):
    """[(start, end, apply_shared_after)] covering all layers."""
    k = cfg.shared_attn_every
    segs = []
    start = 0
    for i in range(cfg.n_layers):
        if k and (i + 1) % k == 0:
            segs.append((start, i + 1, True))
            start = i + 1
    if start < cfg.n_layers:
        segs.append((start, cfg.n_layers, False))
    return segs


def n_shared_applications(cfg: ModelConfig) -> int:
    return sum(1 for *_, s in _hybrid_segments(cfg) if s)


def init_params(cfg: ModelConfig, key) -> dict:
    k_embed, k_blocks, k_shared, k_final = jax.random.split(key, 4)
    params: dict = {"embed": init_embedding(k_embed, cfg),
                    "final_norm": jnp.zeros((cfg.d_model,), jnp.float32)}
    layer_keys = jax.random.split(k_blocks, cfg.n_layers)
    if cfg.family == "ssm":
        params["blocks"] = jax.vmap(
            lambda k: init_mamba_block(k, cfg, version=1))(layer_keys)
    elif cfg.family == "hybrid":
        params["blocks"] = jax.vmap(
            lambda k: init_mamba_block(k, cfg, version=2))(layer_keys)
        params["shared"] = init_transformer_block(k_shared, cfg)
    else:
        blocks = jax.vmap(lambda k: init_transformer_block(k, cfg))(layer_keys)
        if cfg.local_global_pattern:
            assert cfg.n_layers % 2 == 0
            blocks = jax.tree.map(
                lambda a: a.reshape((cfg.n_layers // 2, 2) + a.shape[1:]),
                blocks)
        params["blocks"] = blocks
    return params


# ------------------------------------------------------------- forward

def _dp_axes(mesh):
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _constrain(x, mesh, spec):
    if mesh is None:
        return x
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _constrain_tokens(x, mesh):
    """(B, S, …) activations → batch over the dp axes (when they tile)."""
    dp = _dp_axes(mesh)
    if not dp:
        return x
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    if x.shape[0] % n != 0:
        return x
    ax = dp if len(dp) > 1 else dp[0]
    from jax.sharding import PartitionSpec as P
    return _constrain(x, mesh, P(ax, *([None] * (x.ndim - 1))))


def _embed(params, inputs: Dict, cfg: ModelConfig, mesh):
    if cfg.frontend_stub and "embeds" in inputs:
        x = inputs["embeds"].astype(cdtype(cfg))
    else:
        x = embed_tokens(params["embed"], inputs["tokens"], cfg, mesh)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return _constrain_tokens(x, mesh)


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _layer_loop(body, x, stacked, cfg: ModelConfig, with_ys: bool = False):
    """scan-over-layers (compile-time compact) or python unroll.

    The unrolled path exists for FLOP accounting: XLA's cost analysis
    counts a while-loop body once (verified — see EXPERIMENTS.md §Roofline
    method), so the dry-run lowers an unrolled twin to count real FLOPs.
    with_ys: also return the stacked per-layer outputs (prefill caches).
    """
    if cfg.scan_layers:
        x, ys = jax.lax.scan(body, x, stacked)
        return (x, ys) if with_ys else x
    n = jax.tree.leaves(stacked)[0].shape[0]
    ys_list = []
    for i in range(n):
        p_l = jax.tree.map(lambda a: a[i], stacked)
        x, y = body(x, p_l)
        ys_list.append(y)
    if not with_ys:
        return x
    ys = jax.tree.map(lambda *leaves: jnp.stack(leaves), *ys_list)
    return x, ys


def forward_hidden(params, inputs: Dict, cfg: ModelConfig, mesh=None):
    x = _embed(params, inputs, cfg, mesh)
    positions = inputs["positions"]

    if cfg.family == "ssm":
        def body(h, p_l):
            return mamba_block(p_l, h, cfg, version=1), None
        x = _layer_loop(_maybe_remat(body, cfg), x, params["blocks"], cfg)

    elif cfg.family == "hybrid":
        def body(h, p_l):
            return mamba_block(p_l, h, cfg, version=2), None
        body = _maybe_remat(body, cfg)
        for (s0, s1, sh) in _hybrid_segments(cfg):
            seg = jax.tree.map(lambda a: a[s0:s1], params["blocks"])
            x = _layer_loop(body, x, seg, cfg)
            if sh:
                x = transformer_block(params["shared"], x, positions, cfg,
                                      mesh=mesh)

    elif cfg.local_global_pattern:
        w = cfg.local_window

        def body(h, p_pair):
            p_local = jax.tree.map(lambda a: a[0], p_pair)
            p_global = jax.tree.map(lambda a: a[1], p_pair)
            h = transformer_block(p_local, h, positions, cfg, window=w,
                                  mesh=mesh)
            h = transformer_block(p_global, h, positions, cfg, window=None,
                                  mesh=mesh)
            return h, None
        x = _layer_loop(_maybe_remat(body, cfg), x, params["blocks"], cfg)

    else:
        def body(h, p_l):
            return transformer_block(p_l, h, positions, cfg, mesh=mesh), None
        x = _layer_loop(_maybe_remat(body, cfg), x, params["blocks"], cfg)

    return rms_norm(x, params["final_norm"], cfg.rms_eps)


def logits_fn(params, hidden, cfg: ModelConfig):
    return lm_head(params["embed"], hidden, cfg)


def loss_fn(params, batch: Dict, cfg: ModelConfig, mesh=None):
    """Mean CE over positions with label >= 0.

    Logits are constrained (batch over dp, vocab over model when it
    divides) so the big (B, S, V) temporaries stay sharded both ways —
    the fix recorded as §Perf iteration 0."""
    hidden = forward_hidden(params, batch["inputs"], cfg, mesh)
    logits = logits_fn(params, hidden, cfg).astype(jnp.float32)
    if mesh is not None:
        from jax.sharding import PartitionSpec as P
        dp = _dp_axes(mesh)
        n = 1
        for a in dp:
            n *= mesh.shape[a]
        tp = mesh.shape.get("model", 1)
        b_ax = (dp if len(dp) > 1 else dp[0]) if dp and \
            logits.shape[0] % max(n, 1) == 0 else None
        v_ax = "model" if tp > 1 and cfg.vocab_padded % tp == 0 else None
        logits = _constrain(logits, mesh, P(b_ax, None, v_ax))
    labels = batch["labels"]
    mask = labels >= 0
    labels = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1)


# ------------------------------------------------------ caches / decode

def _kv_to_pages(k, v, max_len, cfg: ModelConfig, mesh):
    """(B, S, kvh, hd) → (B·pps, ps, kvh, hd) page layout.

    Under the identity page table this is a pure reshape (no scatter), and
    the pages get an explicit dp sharding so prefill writes land where
    decode will read them (§Perf iteration 3 — the scatter/vmap form cost
    ~10× in resharding collectives)."""
    b, s, kvh, hd = k.shape
    ps = PAGE_SIZE
    pps = max_len // ps
    if pps * ps != s:
        k = jnp.pad(k, ((0, 0), (0, pps * ps - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pps * ps - s), (0, 0), (0, 0)))
    k_r = k.reshape(b * pps, ps, kvh, hd).astype(cdtype(cfg))
    v_r = v.reshape(b * pps, ps, kvh, hd).astype(cdtype(cfg))
    dp = _dp_axes(mesh)
    if dp and (b * pps) % _dp_size(mesh) == 0:
        from jax.sharding import PartitionSpec as P
        ax = dp if len(dp) > 1 else dp[0]
        k_r = _constrain(k_r, mesh, P(ax, None, None, None))
        v_r = _constrain(v_r, mesh, P(ax, None, None, None))
    return k_r, v_r


def _dp_size(mesh):
    n = 1
    for a in _dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def _assemble_cache(k_pages, v_pages, lengths_val, batch, max_len,
                    cfg: ModelConfig, n_stack: int):
    """Build a layer-stacked PagedKV from page-form ys. Leaves carry a
    leading (n_stack, …) axis; table/lengths are identical per layer."""
    pps = max_len // PAGE_SIZE
    table = (jnp.arange(batch)[:, None] * pps
             + jnp.arange(pps)[None, :]).astype(jnp.int32)
    table = jnp.broadcast_to(table, (n_stack, batch, pps))
    lengths = jnp.full((n_stack, batch), lengths_val, jnp.int32)
    return paged.PagedKV(k_pages=k_pages, v_pages=v_pages,
                         page_table=table, lengths=lengths)


def _fill_cache(k, v, lengths, max_len, cfg: ModelConfig, mesh=None):
    b = k.shape[0]
    k_r, v_r = _kv_to_pages(k, v, max_len, cfg, mesh)
    cache = paged.make(b, max_len, cfg.n_kv_heads, cfg.head_dim,
                       page_size=PAGE_SIZE, dtype=cdtype(cfg))
    return cache._replace(k_pages=k_r, v_pages=v_r,
                          lengths=jnp.asarray(lengths, jnp.int32))


def make_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Empty decode caches (the dry-run lowers decode_step against these)."""
    max_len = -(-max_len // PAGE_SIZE) * PAGE_SIZE
    mk = lambda: paged.make(batch, max_len, cfg.n_kv_heads, cfg.head_dim,
                            page_size=PAGE_SIZE, dtype=cdtype(cfg))
    if cfg.family == "ssm":
        states = [init_ssm_state(cfg, batch, 1) for _ in range(cfg.n_layers)]
        return {"ssm": jax.tree.map(lambda *xs: jnp.stack(xs), *states)}
    if cfg.family == "hybrid":
        states = [init_ssm_state(cfg, batch, 2) for _ in range(cfg.n_layers)]
        kv = [mk() for _ in range(n_shared_applications(cfg))]
        return {"ssm": jax.tree.map(lambda *xs: jnp.stack(xs), *states),
                "kv": jax.tree.map(lambda *xs: jnp.stack(xs), *kv)}
    n = cfg.n_layers
    if cfg.local_global_pattern:
        kv = [mk() for _ in range(n)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *kv)
        return {"kv": jax.tree.map(
            lambda a: a.reshape((n // 2, 2) + a.shape[1:]), stacked)}
    kv = [mk() for _ in range(n)]
    return {"kv": jax.tree.map(lambda *xs: jnp.stack(xs), *kv)}


def prefill(params, inputs: Dict, cfg: ModelConfig, mesh=None,
            max_len: Optional[int] = None):
    """Full forward building decode caches; returns (last logits, caches)."""
    positions = inputs["positions"]
    x = _embed(params, inputs, cfg, mesh)
    b, s = x.shape[0], x.shape[1]
    max_len = max_len or s
    max_len = -(-max_len // PAGE_SIZE) * PAGE_SIZE
    lengths = jnp.full((b,), s, jnp.int32)

    if cfg.family == "ssm":
        from .blocks import mamba_block_prefill

        def body(h, p_l):
            h, st = mamba_block_prefill(p_l, h, cfg, version=1)
            return h, st
        x, states = _layer_loop(_maybe_remat(body, cfg), x,
                                params["blocks"], cfg, with_ys=True)
        hidden = rms_norm(x, params["final_norm"], cfg.rms_eps)
        return logits_fn(params, hidden[:, -1:], cfg), {"ssm": states}

    if cfg.family == "hybrid":
        from .blocks import mamba_block_prefill

        def body(h, p_l):
            h, st = mamba_block_prefill(p_l, h, cfg, version=2)
            return h, st
        body = _maybe_remat(body, cfg)
        kvs, states = [], []
        for (s0, s1, sh) in _hybrid_segments(cfg):
            seg = jax.tree.map(lambda a: a[s0:s1], params["blocks"])
            x, st = _layer_loop(body, x, seg, cfg, with_ys=True)
            states.append(st)
            if sh:
                x, kv = transformer_block(params["shared"], x, positions,
                                          cfg, mesh=mesh, return_kv=True)
                kvs.append(_fill_cache(kv[0], kv[1], lengths, max_len, cfg,
                                       mesh))
        caches = {"kv": jax.tree.map(lambda *xs: jnp.stack(xs), *kvs),
                  "ssm": jax.tree.map(lambda *xs: jnp.concatenate(xs),
                                      *states)}
        hidden = rms_norm(x, params["final_norm"], cfg.rms_eps)
        return logits_fn(params, hidden[:, -1:], cfg), caches

    if cfg.local_global_pattern:
        w = cfg.local_window

        def body(h, p_pair):
            p_local = jax.tree.map(lambda a: a[0], p_pair)
            p_global = jax.tree.map(lambda a: a[1], p_pair)
            h, kv_l = transformer_block(p_local, h, positions, cfg, window=w,
                                        mesh=mesh, return_kv=True)
            h, kv_g = transformer_block(p_global, h, positions, cfg,
                                        window=None, mesh=mesh, return_kv=True)
            pages = [_kv_to_pages(kv[0], kv[1], max_len, cfg, mesh)
                     for kv in (kv_l, kv_g)]
            ys = jax.tree.map(lambda a_, b_: jnp.stack([a_, b_]),
                              pages[0], pages[1])
            return h, ys
        x, (kp, vp) = _layer_loop(_maybe_remat(body, cfg), x,
                                  params["blocks"], cfg, with_ys=True)
        # kp: (L/2, 2, B·pps, ps, kvh, hd)
        half = cfg.n_layers // 2
        cache = _assemble_cache(
            kp.reshape((cfg.n_layers,) + kp.shape[2:]),
            vp.reshape((cfg.n_layers,) + vp.shape[2:]),
            s, b, max_len, cfg, cfg.n_layers)
        cache = jax.tree.map(
            lambda a: a.reshape((half, 2) + a.shape[1:]), cache)
        hidden = rms_norm(x, params["final_norm"], cfg.rms_eps)
        return logits_fn(params, hidden[:, -1:], cfg), {"kv": cache}

    def body(h, p_l):
        h, kv = transformer_block(p_l, h, positions, cfg, mesh=mesh,
                                  return_kv=True)
        return h, _kv_to_pages(kv[0], kv[1], max_len, cfg, mesh)
    x, (kp, vp) = _layer_loop(_maybe_remat(body, cfg), x, params["blocks"],
                              cfg, with_ys=True)
    cache = _assemble_cache(kp, vp, s, b, max_len, cfg, cfg.n_layers)
    hidden = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return logits_fn(params, hidden[:, -1:], cfg), {"kv": cache}


def decode_step(params, inputs: Dict, caches, cfg: ModelConfig, mesh=None):
    """One-token step. inputs: {"tokens": (B, 1)} (or embeds).

    Returns (logits (B, 1, V), updated caches).
    """
    x = _embed(params, inputs, cfg, mesh)

    if cfg.family == "ssm":
        def body(h, xs):
            p_l, st = xs
            h, st = mamba_block_decode(p_l, h, st, cfg, version=1)
            return h, st
        x, states = jax.lax.scan(body, x, (params["blocks"], caches["ssm"]))
        caches = {"ssm": states}

    elif cfg.family == "hybrid":
        def body(h, xs):
            p_l, st = xs
            h, st = mamba_block_decode(p_l, h, st, cfg, version=2)
            return h, st
        new_states, new_kvs = [], []
        shared_i = 0
        for (s0, s1, sh) in _hybrid_segments(cfg):
            seg = jax.tree.map(lambda a: a[s0:s1], params["blocks"])
            st = jax.tree.map(lambda a: a[s0:s1], caches["ssm"])
            x, st = jax.lax.scan(body, x, (seg, st))
            new_states.append(st)
            if sh:
                kv_i = jax.tree.map(lambda a: a[shared_i], caches["kv"])
                x, kv_i = transformer_block_decode(params["shared"], x, kv_i,
                                                   cfg, mesh=mesh)
                new_kvs.append(kv_i)
                shared_i += 1
        caches = {
            "ssm": jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_states),
            "kv": jax.tree.map(lambda *xs: jnp.stack(xs), *new_kvs),
        }

    elif cfg.local_global_pattern:
        w = cfg.local_window

        def body(h, xs):
            p_pair, c_pair = xs
            p_l = jax.tree.map(lambda a: a[0], p_pair)
            p_g = jax.tree.map(lambda a: a[1], p_pair)
            c_l = jax.tree.map(lambda a: a[0], c_pair)
            c_g = jax.tree.map(lambda a: a[1], c_pair)
            h, c_l = transformer_block_decode(p_l, h, c_l, cfg, window=w,
                                              mesh=mesh)
            h, c_g = transformer_block_decode(p_g, h, c_g, cfg, window=None,
                                              mesh=mesh)
            return h, jax.tree.map(lambda a_, b_: jnp.stack([a_, b_]), c_l, c_g)
        x, cache = jax.lax.scan(body, x, (params["blocks"], caches["kv"]))
        caches = {"kv": cache}

    else:
        def body(h, xs):
            p_l, c_l = xs
            h, c_l = transformer_block_decode(p_l, h, c_l, cfg, mesh=mesh)
            return h, c_l
        x, cache = jax.lax.scan(body, x, (params["blocks"], caches["kv"]))
        caches = {"kv": cache}

    hidden = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return logits_fn(params, hidden, cfg), caches
