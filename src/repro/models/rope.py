"""Rotary position embeddings — standard RoPE and qwen2-vl's M-RoPE.

M-RoPE splits the head_dim rotary frequencies into sections driven by
separate position streams (temporal, height, width). The vision frontend
is a stub per the assignment, so the 3-row position ids arrive as inputs
(text tokens simply repeat the same position in all three rows, which
makes M-RoPE collapse to standard RoPE — a property the tests use).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S) int32."""
    half = x.shape[-1] // 2
    freqs = _freqs(x.shape[-1], theta)                      # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def mrope(x: jax.Array, positions3: jax.Array, theta: float,
          sections: Tuple[int, ...]) -> jax.Array:
    """x: (..., S, H, D); positions3: (..., S, 3) — (t, h, w) streams.

    sections: per-stream count of rotary frequency pairs; must sum to D/2.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = _freqs(x.shape[-1], theta)                      # (half,)
    # Pick the position stream for each frequency band.
    sec_id = jnp.repeat(
        jnp.arange(len(sections)),
        jnp.array(sections),
        total_repeat_length=half)                           # (half,)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sec_id, positions3.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1)                                            # (..., S, half)
    ang = pos * freqs
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)
