"""Shared layers: RMSNorm, MLP variants, embeddings (GSPMD + Roomy paths).

Initialization follows the llama family: truncated-normal fan-in scaling
for projections, ones for norm gains. Params are stored f32 (master copy);
every block casts to the config compute dtype at use (the optimizer sees
f32 — the usual mixed-precision split).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import delayed as roomy_delayed
from .config import ModelConfig


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis: int = 0):
    fan_in = shape[in_axis]
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            / jnp.sqrt(fan_in))


def rms_norm(x: jax.Array, gain: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gain.astype(jnp.float32))
    return out.astype(dt)


def _act(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return functools.partial(jax.nn.gelu, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ------------------------------------------------------------------- MLP

def init_mlp(key, cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], (d, ff)),
         "down": dense_init(ks[1], (ff, d))}
    if cfg.mlp_gated:
        p["gate"] = dense_init(ks[2], (d, ff))
    return p


def mlp(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = cdtype(cfg)
    act = _act(cfg.mlp_act)
    h = x @ p["up"].astype(dt)
    if cfg.mlp_gated:
        h = act(x @ p["gate"].astype(dt)) * h
    else:
        h = act(h)
    return h @ p["down"].astype(dt)


# ------------------------------------------------------------ embeddings

def init_embedding(key, cfg: ModelConfig) -> dict:
    e = jax.random.normal(key, (cfg.vocab_padded, cfg.d_model),
                          jnp.float32) * 0.02
    p = {"table": e}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(jax.random.fold_in(key, 1),
                               (cfg.d_model, cfg.vocab_padded))
    return p


def embed_tokens(p: dict, ids: jax.Array, cfg: ModelConfig,
                 mesh=None) -> jax.Array:
    """ids (B, S) → (B, S, d). GSPMD path: plain take (XLA inserts the
    vocab-shard collective). Roomy path: explicit bucket exchange over the
    model axis — the paper's delayed-access pattern (DESIGN.md §3.2)."""
    dt = cdtype(cfg)
    if cfg.embedding_dispatch == "roomy" and mesh is not None \
            and "model" in mesh.axis_names:
        n_dev = 1
        for a in mesh.axis_names:
            n_dev *= mesh.shape[a]
        if (ids.shape[0] * ids.shape[1]) % n_dev == 0:
            return _roomy_embed(p["table"], ids, cfg, mesh).astype(dt)
    return p["table"].astype(dt)[ids]


def _roomy_embed(table: jax.Array, ids: jax.Array, cfg: ModelConfig, mesh):
    """Explicit Roomy gather: tokens issue delayed accesses to the vocab-
    sharded table; one all_to_all each way resolves the whole batch.

    Ownership is *striped* (owner = id mod S) so frequent low ids spread
    across shards; buckets carry 4× the uniform per-owner load (overflow →
    zero embedding, counted like MoE token drops; factor-4 makes it
    vanishingly rare — tested in tests/test_roomy_lm.py)."""
    s_model = mesh.shape["model"]
    v = cfg.vocab_padded
    rows_per = -(-v // s_model)
    b, s = ids.shape
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    n_dev = 1
    for a in mesh.axis_names:
        n_dev *= mesh.shape[a]
    tokens_local = max(1, (b * s) // n_dev)
    capacity = max(8, min(tokens_local, 4 * (-(-tokens_local // s_model))))

    def local(ids_loc, table_loc):
        flat = ids_loc.reshape(-1)
        dest = (flat % s_model).astype(jnp.int32)
        valid = jnp.ones_like(flat, dtype=bool)

        def owner_fn(recv, recv_valid):
            # recv: (S, C, 1) global ids; striped layout → local row id//S
            local_idx = recv[..., 0].astype(jnp.int32) // s_model
            local_idx = jnp.minimum(local_idx, table_loc.shape[0] - 1)
            return table_loc[local_idx]

        out, ok, _ = roomy_delayed.bucket_sync_access(
            dest, flat[:, None].astype(jnp.int32), valid, "model",
            s_model, capacity, owner_fn)
        out = jnp.where(ok[:, None], out, 0.0)
        return out.reshape(ids_loc.shape + (cfg.d_model,))

    shard_axes = data_axes + ("model",)
    # Striped table layout: row r of shard s holds vocab id r*S + s.
    tab = _pad_rows(table, rows_per * s_model)
    tab = tab.reshape(rows_per, s_model, cfg.d_model).transpose(1, 0, 2) \
             .reshape(rows_per * s_model, cfg.d_model)
    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(shard_axes, None), P("model", None)),
        out_specs=P(shard_axes, None, None),
    )
    return fn(ids.reshape(b * s, 1), tab).reshape(b, s, cfg.d_model)


def _pad_rows(x: jax.Array, n: int) -> jax.Array:
    if x.shape[0] == n:
        return x
    return jnp.pad(x, ((0, n - x.shape[0]),) + ((0, 0),) * (x.ndim - 1))


def lm_head(p_embed: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = cdtype(cfg)
    if cfg.tie_embeddings:
        w = p_embed["table"].astype(dt).T
    else:
        w = p_embed["head"].astype(dt)
    logits = x @ w
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    if cfg.vocab_padded != cfg.vocab_size:      # mask pad-to-shard rows
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return logits
