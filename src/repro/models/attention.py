"""Attention layer: train/prefill (flash path) and paged decode.

Shapes follow (B, S, H, D) activations; the kernel path transposes to
(B, H, S, D). GQA divisibility fallbacks (DESIGN.md §5) are *sharding*
concerns, handled in distributed/sharding_rules.py — the math here is
layout-agnostic.

Decode uses the Roomy paged-KV store (core/paged.py): append is a delayed
update executed as one scatter; the attention read is one batched gather —
never per-token random access.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import paged
from ..kernels import ops as kops
from ..kernels import ref as kref
from .config import ModelConfig
from .layers import cdtype, dense_init
from .rope import mrope, rope


def init_attention(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd)),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd)),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd)),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d)),
    }


def _qkv(p: dict, x: jax.Array, cfg: ModelConfig):
    dt = cdtype(cfg)
    b, s, _ = x.shape
    q = (x @ p["wq"].astype(dt)).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"].astype(dt)).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def _apply_rope(q, k, positions, cfg: ModelConfig):
    if cfg.mrope:
        q = mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k


def _attn_act_spec(cfg: ModelConfig, mesh, b: int, s: int):
    """When q-heads don't divide the model axis (attention weights are
    replicated by the sharding rules), spread the attention *activations*
    over 'model' instead — batch if it tiles the whole grid, else sequence.
    Returns (in_spec, out_spec) or None."""
    from jax.sharding import PartitionSpec as P
    if cfg.attn_activation_shard != "auto" or mesh is None:
        return None
    tp = mesh.shape.get("model", 1)
    if tp <= 1 or cfg.n_heads % tp == 0:
        return None                       # weights TP-shard fine already
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    dp_ax = dp if len(dp) > 1 else (dp[0] if dp else None)
    if dp and b % (n_dp * tp) == 0:
        return (P(dp + ("model",), None, None), P(dp_ax, None, None))
    if s % tp == 0 and (not dp or b % n_dp == 0):
        return (P(dp_ax, "model", None), P(dp_ax, None, None))
    return None


def attention(p: dict, x: jax.Array, positions: jax.Array,
              cfg: ModelConfig, *, window: Optional[int] = None,
              return_kv: bool = False, mesh=None):
    """Full-sequence causal attention (training / prefill).

    window: sliding-window size for this layer (overrides cfg default);
    None = global.
    """
    from jax.sharding import NamedSharding
    b, s, _ = x.shape
    spec = _attn_act_spec(cfg, mesh, b, s)
    if spec is not None:
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec[0]))
    q, k, v = _qkv(p, x, cfg)
    q, k = _apply_rope(q, k, positions, cfg)
    softcap = cfg.attn_softcap or None
    out = kops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=True, window=window, softcap=softcap,
        impl=cfg.kernels, block_k=cfg.attn_block_k)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    out = out @ p["wo"].astype(cdtype(cfg))
    if spec is not None:
        out = jax.lax.with_sharding_constraint(
            out, NamedSharding(mesh, spec[1]))
    if return_kv:
        return out, (k, v)
    return out


def decode_attention(p: dict, x: jax.Array, cache: paged.PagedKV,
                     cfg: ModelConfig, *, window: Optional[int] = None,
                     mesh=None) -> Tuple[jax.Array, paged.PagedKV]:
    """One-token decode step against the Roomy paged cache.

    x: (B, 1, d). Returns (out (B, 1, d), updated cache).

    With a mesh, the whole append+gather+attend runs INSIDE shard_map so
    pages never leave their owner (the Roomy owner-compute discipline):
      batch % dp == 0 → batch-sharded: each shard serves its own rows
      batch == 1      → context-parallel: each shard attends over its own
                        pages; one log-sum-exp merge (flash-decoding)
    Without a mesh: plain batched gather (single host).
    """
    b = x.shape[0]
    q, k, v = _qkv(p, x, cfg)                       # (B, 1, H/KVH, D)
    positions = cache.lengths[:, None]              # (B, 1)
    if cfg.mrope:
        pos3 = jnp.repeat(positions[..., None], 3, axis=-1)
        q, k = _apply_rope(q, k, pos3, cfg)
    else:
        q, k = _apply_rope(q, k, positions, cfg)
    softcap = cfg.attn_softcap or None
    dp_axes = tuple(a for a in ("pod", "data")
                    if mesh is not None and a in mesh.axis_names)
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a] if mesh is not None else 1

    if dp_axes and b > 1 and b % n_dp == 0:
        out, cache = _paged_decode_batched(q[:, 0], k[:, 0], v[:, 0],
                                           cache, cfg, mesh, dp_axes,
                                           softcap, window)
    elif dp_axes and b == 1 and window is None:
        out, cache = _paged_decode_cp(q[:, 0], k[:, 0], v[:, 0], cache,
                                      cfg, mesh, dp_axes, softcap)
    else:
        cache = paged.append(cache, k[:, 0], v[:, 0])
        kf, vf, mask = paged.gather(cache)          # batched access
        if window is not None:
            pos_in_seq = jnp.arange(mask.shape[1])[None, :]
            cur = cache.lengths[:, None] - 1
            mask = mask & (pos_in_seq >= cur - window)
        out = kref.decode_attention_ref(q[:, 0], kf, vf, mask,
                                        softcap=softcap)
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"].astype(cdtype(cfg)), cache


def _paged_decode_batched(q, k_new, v_new, cache: paged.PagedKV,
                          cfg: ModelConfig, mesh, dp_axes, softcap, window):
    """Batch-sharded decode: rows and their pages live on the same shard
    (batch-major identity page layout), so append + gather stay local."""
    from jax.sharding import PartitionSpec as P
    b = q.shape[0]
    ps = cache.page_size
    num_pages = cache.k_pages.shape[0]
    pps = cache.pages_per_seq

    def local(q_l, k_l, v_l, kp, vp, table_l, len_l):
        p_loc = kp.shape[0]
        idx = jax.lax.axis_index(dp_axes)
        off = idx * p_loc
        # append (Roomy delayed update, one scatter)
        page_log = len_l // ps
        offset = len_l % ps
        phys_g = jnp.take_along_axis(table_l, page_log[:, None], axis=1)[:, 0]
        phys_l = phys_g - off
        kp = kp.at[phys_l, offset].set(k_l.astype(kp.dtype))
        vp = vp.at[phys_l, offset].set(v_l.astype(vp.dtype))
        new_len = len_l + 1
        # gather (local batched access)
        tbl_l = table_l - off                       # local physical ids
        kf = kp[tbl_l]                              # (B_l, pps, ps, kvh, hd)
        vf = vp[tbl_l]
        b_l = q_l.shape[0]
        kf = kf.reshape(b_l, pps * ps, *kf.shape[3:])
        vf = vf.reshape(b_l, pps * ps, *vf.shape[3:])
        mask = jnp.arange(pps * ps)[None, :] < new_len[:, None]
        if window is not None:
            cur = new_len[:, None] - 1
            mask = mask & (jnp.arange(pps * ps)[None, :] >= cur - window)
        out = kref.decode_attention_ref(q_l, kf, vf, mask, softcap=softcap)
        return out, kp, vp, new_len

    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, None), P(dp, None, None), P(dp, None, None),
                  P(dp, None, None, None), P(dp, None, None, None),
                  P(dp, None), P(dp)),
        out_specs=(P(dp, None, None), P(dp, None, None, None),
                   P(dp, None, None, None), P(dp)))
    out, kp, vp, lengths = fn(q, k_new, v_new, cache.k_pages,
                              cache.v_pages, cache.page_table,
                              cache.lengths)
    return out, cache._replace(k_pages=kp, v_pages=vp, lengths=lengths)


def _paged_decode_cp(q, k_new, v_new, cache: paged.PagedKV,
                     cfg: ModelConfig, mesh, dp_axes, softcap):
    """Context-parallel single-sequence decode (identity page table).

    Pages shard over dp_axes; the owner of the current tail page takes the
    append; every shard attends over its local pages; partials merge with
    one pmax + two psums — the Roomy owner-compute pattern (DESIGN.md §3.3).
    """
    from jax.sharding import PartitionSpec as P
    import math as _math
    scale = 1.0 / _math.sqrt(cfg.head_dim)
    ps = cache.page_size

    def local(q_loc, k_l, v_l, kp, vp, lengths):
        p_loc = kp.shape[0]
        idx = jax.lax.axis_index(dp_axes)
        off = idx * p_loc
        # append: only the owner of the tail page writes
        phys = lengths[0] // ps                     # identity table
        offset = lengths[0] % ps
        loc = phys - off
        mine = (loc >= 0) & (loc < p_loc)
        loc_c = jnp.clip(loc, 0, p_loc - 1)
        old_k = kp[loc_c, offset]
        old_v = vp[loc_c, offset]
        kp = kp.at[loc_c, offset].set(
            jnp.where(mine, k_l[0].astype(kp.dtype), old_k))
        vp = vp.at[loc_c, offset].set(
            jnp.where(mine, v_l[0].astype(vp.dtype), old_v))
        new_len = lengths[0] + 1
        kvh, hd = kp.shape[2], kp.shape[3]
        g = cfg.n_heads // cfg.n_kv_heads
        kf = kp.reshape(p_loc * ps, kvh, hd).astype(jnp.float32)
        vf = vp.reshape(p_loc * ps, kvh, hd).astype(jnp.float32)
        kf = jnp.repeat(kf, g, axis=1)              # (S_loc, Hq, hd)
        vf = jnp.repeat(vf, g, axis=1)
        pos = off * ps + jnp.arange(p_loc * ps)
        mask = pos < new_len
        logits = jnp.einsum("hd,shd->hs", q_loc[0].astype(jnp.float32),
                            kf) * scale
        if softcap is not None:
            logits = jnp.tanh(logits / softcap) * softcap
        logits = jnp.where(mask[None, :], logits, kref.NEG_INF)
        m_loc = jnp.max(logits, axis=1)                       # (Hq,)
        m_glob = jax.lax.pmax(m_loc, dp_axes)
        p_ = jnp.exp(logits - m_glob[:, None])
        p_ = jnp.where(mask[None, :], p_, 0.0)
        l_loc = jnp.sum(p_, axis=1)
        acc = jnp.einsum("hs,shd->hd", p_, vf)
        l_glob = jax.lax.psum(l_loc, dp_axes)
        acc = jax.lax.psum(acc, dp_axes)
        l_glob = jnp.where(l_glob == 0.0, 1.0, l_glob)
        out = (acc / l_glob[:, None]).astype(q_loc.dtype)[None]
        return out, kp, vp

    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(), P(),
                  P(dp, None, None, None), P(dp, None, None, None), P()),
        out_specs=(P(), P(dp, None, None, None), P(dp, None, None, None)))
    out, kp, vp = fn(q, k_new, v_new, cache.k_pages, cache.v_pages,
                     cache.lengths)
    cache = cache._replace(k_pages=kp, v_pages=vp,
                           lengths=cache.lengths + 1)
    return out, cache
