"""Sharded, atomic, async checkpointing with elastic restore.

Layout: one ``.npy`` per pytree leaf (path-encoded filename) + manifest
JSON, written to ``step_XXXXXXXX.tmp`` then atomically renamed — a crashed
writer can never corrupt the latest checkpoint. An async writer thread
overlaps serialization with the next train steps (the arrays are fetched
to host synchronously first, which is the only blocking part).

Elastic restore: leaves are stored as *global* arrays; ``restore`` places
them onto whatever mesh/sharding the restoring job provides, so a job can
come back on a different device count (tested 4→2 and 4→8 in
tests/test_checkpoint.py). On a real multi-host pod each host writes its
addressable shards and the manifest records the global shape — the
single-host file format here is the degenerate case of that layout.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

_SEP = "__"


def _leaf_path(kp) -> str:
    return _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Blocking save. Returns final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": {}}
    for kp, leaf in flat:
        name = _leaf_path(kp)
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"][name] = {"shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic
    _gc(ckpt_dir, keep=3)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template: Any,
            shardings: Any = None) -> Any:
    """Restore onto the template's structure; place with ``shardings`` if
    given (elastic: any mesh works since leaves are global arrays)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (kp, leaf), sh in zip(flat, shard_flat):
        arr = np.load(os.path.join(path, _leaf_path(kp) + ".npy"))
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Fetch-to-host synchronously, write in a background thread."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, host_tree = item
            try:
                save(self.ckpt_dir, step, host_tree)
            except BaseException as e:          # surfaced on next save()
                self._err = e
            finally:
                self._q.task_done()

    def save(self, step: int, tree: Any) -> None:
        if self._err is not None:
            raise self._err
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self._q.put((step, host_tree))          # blocks if one in flight

    def wait(self) -> None:
        self._q.join()
        if self._err is not None:
            raise self._err

    def close(self) -> None:
        self._q.join()
        self._q.put(None)
        self._thread.join(timeout=60)
