"""Atomic sharded checkpointing with async writes and elastic restore."""
from .manager import AsyncCheckpointer, latest_step, restore, save
__all__ = ["AsyncCheckpointer", "latest_step", "restore", "save"]
