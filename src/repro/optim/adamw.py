"""AdamW with global-norm clipping — self-contained (no optax dependency).

State (m, v) mirrors the param tree; everything is f32 regardless of the
compute dtype (mixed-precision master copy lives in the params themselves,
which are f32 — see layers.py). Fully sharded: the train launcher places
m/v with the same specs as the params (ZeRO-3), and the update is
elementwise so no collectives are added beyond the grad reduction.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(
    grads, state: AdamWState, params, *,
    lr: jax.Array | float,
    b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
    weight_decay: float = 0.1, clip_norm: Optional[float] = 1.0,
):
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         state.v, grads)

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        return (p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)
                ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm
