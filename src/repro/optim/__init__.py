"""Optimizer substrate: AdamW, schedules (WSD default), grad compression."""
from . import compress, schedule
from .adamw import AdamWState, clip_by_global_norm, global_norm, init, update

__all__ = ["AdamWState", "clip_by_global_norm", "compress", "global_norm",
           "init", "schedule", "update"]
