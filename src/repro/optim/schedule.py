"""LR schedules. WSD (warmup–stable–decay) is minicpm-2b's native schedule
(arXiv:2404.06395) and the framework default; cosine and linear included.
All are step → lr callables safe to trace (pure jnp)."""
from __future__ import annotations

import jax.numpy as jnp


def wsd(peak_lr: float, warmup_steps: int, stable_steps: int,
        decay_steps: int, final_ratio: float = 0.1):
    """Warmup → stable plateau → exponential-ish (linear here) decay."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        decay_frac = (step - warmup_steps - stable_steps) / jnp.maximum(
            decay_steps, 1)
        decay = peak_lr * (1.0 - (1.0 - final_ratio)
                           * jnp.clip(decay_frac, 0.0, 1.0))
        out = jnp.where(step < warmup_steps, warm,
                        jnp.where(step < warmup_steps + stable_steps,
                                  peak_lr, decay))
        return out
    return lr


def cosine(peak_lr: float, warmup_steps: int, total_steps: int,
           final_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = final_ratio + (1 - final_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)
    return lr


def constant(peak_lr: float):
    return lambda step: jnp.asarray(peak_lr, jnp.float32)
