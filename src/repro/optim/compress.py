"""Gradient compression for the cross-pod reduction (DESIGN.md §8).

Two codecs, both with error feedback (the residual of what compression
discarded is carried to the next step, preserving convergence):

  int8   per-block symmetric quantization (block = 256 elements);
         4× wire reduction on the cross-pod all-reduce
  topk   keep the largest-|g| fraction per leaf (indices + values);
         wire reduction = 1/density

Usage in the train step (runtime/train_loop.py):
    msg, residual = compress(grads, residual)
    msg = psum(msg, axis="pod")          # cheap cross-pod wire format
    grads = decompress(msg, template) / n_pods
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_BLOCK = 256


def _zeros_like_f32(tree):
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), tree)


def _blockify(x: jax.Array) -> jax.Array:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, _BLOCK)


class Int8Msg(NamedTuple):
    q: dict
    scale: dict


def int8_compress(grads, residual):
    """Returns (Int8Msg, new_residual). residual=None → zeros."""
    if residual is None:
        residual = _zeros_like_f32(grads)
    acc = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, residual)

    def enc(x):
        blocks = _blockify(x)
        scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1) / 127.0, 1e-12)
        q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127
                     ).astype(jnp.int8)
        return q, scale

    enc_tree = jax.tree.map(lambda x: enc(x), acc)
    qs = jax.tree.map(lambda t: t[0], enc_tree,
                      is_leaf=lambda t: isinstance(t, tuple))
    scales = jax.tree.map(lambda t: t[1], enc_tree,
                          is_leaf=lambda t: isinstance(t, tuple))
    msg = Int8Msg(qs, scales)
    deq = int8_decompress(msg, acc)
    new_residual = jax.tree.map(lambda a, d: a - d, acc, deq)
    return msg, new_residual


def int8_decompress(msg: Int8Msg, template) -> dict:
    def dec(q, s, t):
        x = (q.astype(jnp.float32) * s[:, None]).reshape(-1)
        return x[:t.size].reshape(t.shape)
    return jax.tree.map(dec, msg.q, msg.scale, template)


class TopkMsg(NamedTuple):
    idx: dict
    val: dict


def topk_compress(grads, residual, density: float = 0.05):
    if residual is None:
        residual = _zeros_like_f32(grads)
    acc = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, residual)

    def enc(x):
        flat = x.reshape(-1)
        k = max(1, int(flat.shape[0] * density))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        return idx.astype(jnp.int32), flat[idx]

    enc_tree = jax.tree.map(lambda x: enc(x), acc)
    idxs = jax.tree.map(lambda t: t[0], enc_tree,
                        is_leaf=lambda t: isinstance(t, tuple))
    vals = jax.tree.map(lambda t: t[1], enc_tree,
                        is_leaf=lambda t: isinstance(t, tuple))
    msg = TopkMsg(idxs, vals)
    deq = topk_decompress(msg, acc)
    new_residual = jax.tree.map(lambda a, d: a - d, acc, deq)
    return msg, new_residual


def topk_decompress(msg: TopkMsg, template) -> dict:
    def dec(idx, val, t):
        return jnp.zeros((t.size,), jnp.float32).at[idx].add(val
                                                             ).reshape(t.shape)
    return jax.tree.map(dec, msg.idx, msg.val, template)


def wire_bytes(msg) -> int:
    """Bytes this message puts on the cross-pod link (reporting helper)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(msg))
