"""repro — Roomy-JAX: space-limited computation as a first-class feature
of a multi-pod JAX training/serving framework. See DESIGN.md."""
__version__ = "0.1.0"
