"""falcon-mamba-7b [ssm] — pure mamba1, attention-free.

[arXiv:2410.05355; unverified] 64L d4096 (d_inner 8192) ssm_state 16,
vocab 65024, no attention, no MLP (d_ff=0 — the mamba block IS the mixer).
"""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=65024,
    ssm_state=16, ssm_expand=2, mamba_version=1,
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=3, d_model=32, vocab_size=89, ssm_state=4, dtype="float32",
)
