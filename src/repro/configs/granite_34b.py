"""granite-34b [dense] — deep MQA code model.

[arXiv:2405.04324; hf] 88L d6144 48H (kv=1 → MQA, head_dim 128)
d_ff 24576, vocab 49152. KV projections replicate over the model axis
(1 kv head); Q/O shard 48/16 = 3 heads per chip.
"""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152,
    mlp_act="silu", mlp_gated=True, tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=97, dtype="float32",
)
