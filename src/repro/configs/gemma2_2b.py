"""gemma2-2b [dense] — local/global alternating attention, logit softcaps.

[arXiv:2408.00118; hf] 26L d2304 8H (kv=4, head_dim 256) d_ff 9216,
vocab 256000; sliding window 4096 on local layers; attn softcap 50,
final-logit softcap 30; pre+post RMSNorms; embeddings scaled by sqrt(d).
8 q-heads < 16 ⇒ attention weights replicate over the model axis
(sharding fallback, DESIGN.md §5); MLP/vocab still TP-shard.
"""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    local_window=4096, local_global_pattern=True,
    attn_softcap=50.0, logit_softcap=30.0, post_norm=True,
    scale_embeddings=True,
    mlp_act="gelu", mlp_gated=True, tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=4, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
    d_ff=96, vocab_size=199, local_window=8, dtype="float32",
)
