"""nemotron-4-15b [dense] — GQA + squared-ReLU MLP, 256k vocab.

[arXiv:2402.16819; unverified] 32L d6144 48H (kv=8, head_dim 128)
d_ff 24576, vocab 256000. Non-gated squared-ReLU MLP; untied embeddings.
The 256k vocab makes this the strongest Roomy-embedding case (DESIGN.md §6).
"""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=256000,
    mlp_act="relu2", mlp_gated=False, tie_embeddings=False,
    rope_theta=10_000.0,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
    d_ff=192, vocab_size=331, dtype="float32",
)
