"""Per-architecture configs (one module per assigned arch) + registry."""
from .registry import ARCH_IDS, all_configs, get_config
from .shapes import SHAPES, ShapeSpec, shape_applicable

__all__ = ["ARCH_IDS", "SHAPES", "ShapeSpec", "all_configs", "get_config",
           "shape_applicable"]
