"""Assigned input-shape set (same four shapes for every LM arch).

``train_4k``/``prefill_32k`` lower ``train_step``/``prefill``;
``decode_32k``/``long_500k`` lower ``serve_step`` (one new token against a
KV cache of seq_len). ``long_500k`` requires sub-quadratic attention → it
only runs for the ssm/hybrid archs (DESIGN.md §6 skip table).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(shape: ShapeSpec, family: str) -> bool:
    """long_500k needs sub-quadratic attention: ssm/hybrid only."""
    if shape.name == "long_500k":
        return family in ("ssm", "hybrid")
    return True
