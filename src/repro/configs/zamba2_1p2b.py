"""zamba2-1.2b [hybrid] — mamba2 backbone + shared attention block.

[arXiv:2411.15242; hf] 38L d2048 32H (kv=32 → MHA, head_dim 64) shared-MLP
d_ff 8192, vocab 32000, ssm_state 64. The single shared transformer block
(attn+MLP, one weight set) is applied after every 6th mamba2 block
(6 applications over 38 layers) — the Zamba2 weight-sharing scheme.
"""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_expand=2, mamba_version=2, mamba2_head_dim=64,
    shared_attn_every=6,
    mlp_act="gelu", mlp_gated=True, tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=5, d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
    d_ff=64, vocab_size=127, ssm_state=8, mamba2_head_dim=16,
    shared_attn_every=2, dtype="float32",
)
