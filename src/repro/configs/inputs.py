"""Input stand-ins per (arch × shape): ShapeDtypeStructs for the dry-run
and concrete arrays for smoke tests — weak-type-correct, shardable, no
device allocation on the specs path.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm
from ..models.config import ModelConfig
from .shapes import ShapeSpec


def _pos_shape(cfg: ModelConfig, b: int, s: int) -> Tuple[int, ...]:
    return (b, s, 3) if cfg.mrope else (b, s)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    """ShapeDtypeStruct tree for the step inputs of this cell.

    train   → {"inputs": …, "labels": …}            (feeds train_step)
    prefill → {"inputs": …}                          (feeds prefill)
    decode  → {"inputs": one-token, "caches": …}     (feeds decode_step)
    """
    sds = jax.ShapeDtypeStruct
    b = shape.global_batch
    dtype = jnp.dtype(cfg.dtype)

    if shape.kind in ("train", "prefill"):
        s = shape.seq_len
        inputs = {"positions": sds(_pos_shape(cfg, b, s), jnp.int32)}
        if cfg.frontend_stub:
            inputs["embeds"] = sds((b, s, cfg.d_model), dtype)
        else:
            inputs["tokens"] = sds((b, s), jnp.int32)
        if shape.kind == "train":
            return {"inputs": inputs, "labels": sds((b, s), jnp.int32)}
        return {"inputs": inputs}

    # decode: one new token against a seq_len-deep cache
    inputs = {"positions": sds(_pos_shape(cfg, b, 1), jnp.int32)}
    if cfg.frontend_stub:
        inputs["embeds"] = sds((b, 1, cfg.d_model), dtype)
    else:
        inputs["tokens"] = sds((b, 1), jnp.int32)
    caches = jax.eval_shape(
        lambda: lm.make_cache(cfg, b, max_len=shape.seq_len))
    return {"inputs": inputs, "caches": caches}


def make_inputs(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0) -> Dict:
    """Concrete (host numpy) inputs matching input_specs — smoke scale only."""
    rng = np.random.default_rng(seed)
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1

    def pos(b_, s_):
        p = np.tile(np.arange(s_, dtype=np.int32)[None], (b_, 1))
        return np.tile(p[:, :, None], (1, 1, 3)) if cfg.mrope else p

    inputs = {"positions": pos(b, s)}
    if cfg.frontend_stub:
        inputs["embeds"] = rng.standard_normal(
            (b, s, cfg.d_model)).astype(np.float32) * 0.02
    else:
        inputs["tokens"] = rng.integers(
            0, cfg.vocab_size, (b, s)).astype(np.int32)
    if shape.kind == "train":
        return {"inputs": inputs,
                "labels": rng.integers(0, cfg.vocab_size,
                                       (b, s)).astype(np.int32)}
    if shape.kind == "prefill":
        return {"inputs": inputs}
    return {"inputs": inputs,
            "caches": lm.make_cache(cfg, b, max_len=shape.seq_len)}
