"""minicpm-2b [dense] — llama-like with WSD schedule.

[arXiv:2404.06395; hf] 40L d2304 36H (kv=36 → MHA, head_dim 64) d_ff 5760,
vocab 122753. The WSD (warmup-stable-decay) schedule lives in
optim/schedule.py and is this arch's default.
"""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, head_dim=64,
    d_ff=5760, vocab_size=122753,
    mlp_act="silu", mlp_gated=True, tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
    d_ff=96, vocab_size=157, dtype="float32",
)
