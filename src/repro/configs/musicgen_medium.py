"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

[arXiv:2306.05284; hf] 48L d1536 24H (kv=24 → MHA, head_dim 64) d_ff 6144,
vocab 2048. The EnCodec frontend is a stub: input_specs() provides
precomputed frame embeddings (B, S, d); the 2048-way head predicts codec
tokens. Non-gated GELU MLP (vanilla transformer decoder).
"""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048,
    mlp_act="gelu", mlp_gated=False, tie_embeddings=True,
    frontend_stub=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
    d_ff=96, vocab_size=67, dtype="float32",
)
