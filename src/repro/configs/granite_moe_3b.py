"""granite-moe-3b-a800m [moe] — 40 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] 32L d1536 24H (kv=8)
per-expert d_ff 512, vocab 49155. head_dim = 1536/24 = 64.
Note: the assignment's primary line says 40e top-8 while its bracket note
says 32e — we implement the primary line (DESIGN.md §6). 40 experts pad to
48 so the expert axis shards over model=16 (3/shard).
"""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    n_experts=40, top_k=8, moe_dispatch="roomy",
    mlp_act="silu", mlp_gated=True, tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
    d_ff=32, vocab_size=211, n_experts=5, top_k=3, dtype="float32",
)
