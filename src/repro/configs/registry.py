"""Architecture registry — maps ``--arch`` ids to (FULL, SMOKE) configs."""
from __future__ import annotations

from typing import Dict, Tuple

from ..models.config import ModelConfig
from . import (falcon_mamba_7b, gemma2_2b, granite_34b, granite_moe_3b,
               minicpm_2b, musicgen_medium, nemotron4_15b, phi35_moe_42b,
               qwen2_vl_2b, zamba2_1p2b)

_MODULES = {
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b,
    "granite-moe-3b-a800m": granite_moe_3b,
    "zamba2-1.2b": zamba2_1p2b,
    "musicgen-medium": musicgen_medium,
    "falcon-mamba-7b": falcon_mamba_7b,
    "minicpm-2b": minicpm_2b,
    "gemma2-2b": gemma2_2b,
    "granite-34b": granite_34b,
    "nemotron-4-15b": nemotron4_15b,
    "qwen2-vl-2b": qwen2_vl_2b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = _MODULES[arch]
    return mod.SMOKE if smoke else mod.FULL


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
