"""qwen2-vl-2b [vlm] — M-RoPE backbone; vision frontend stubbed.

[arXiv:2409.12191; hf] 28L d1536 12H (kv=2, head_dim 128) d_ff 8960,
vocab 151936. M-RoPE: head_dim/2 = 64 rotary pairs split (16, 24, 24)
across (temporal, height, width) position streams; input_specs() provides
patch embeddings + 3-row positions (frontend stub per assignment).
"""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    mrope=True, mrope_sections=(16, 24, 24),
    mlp_act="silu", mlp_gated=True, tie_embeddings=True,
    frontend_stub=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=173, mrope_sections=(2, 3, 3), dtype="float32",
)
